//! Quickstart: train a tiny transformer LM with ET2 preconditioning for a
//! handful of steps and watch the loss fall.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Everything on the hot path is rust + PJRT; the compute graph (including
//! the Pallas extreme-tensoring kernels) was AOT-compiled by
//! `python/compile/aot.py` into `artifacts/lm_tiny_et2.hlo.txt`.

use extensor::optim::Schedule;
use extensor::train::{RunConfig, Trainer};

fn main() -> anyhow::Result<()> {
    let cfg = RunConfig {
        name: "quickstart".into(),
        artifact: "lm_tiny_et2".into(),
        eval_artifact: Some("lm_tiny_eval".into()),
        steps: 80,
        eval_every: 40,
        log_every: 10,
        schedule: Schedule::scaled_lm(0.5, 10),
        ..RunConfig::default()
    };
    println!("loading artifact '{}' ...", cfg.artifact);
    let mut trainer = Trainer::new(cfg)?;
    let m = &trainer.engine().manifest;
    println!(
        "model: {} params across {} groups; optimizer state: {} scalars ({}x overhead)",
        m.total_params(),
        m.params.len(),
        m.total_opt_state(),
        m.total_opt_state() as f64 / m.total_params() as f64,
    );
    let result = trainer.run()?;
    println!("\nloss curve (step, train loss):");
    for (step, loss) in &result.loss_history {
        let bar = "#".repeat((loss * 6.0) as usize);
        println!("  {step:>4}  {loss:>7.3}  {bar}");
    }
    let s = &result.summary;
    println!(
        "\nfinal: val ppl {:.2} after {} steps in {:.1}s ({:.0} tokens/s)",
        s.final_eval_ppl, s.steps, s.wall_seconds, s.tokens_per_sec
    );
    Ok(())
}

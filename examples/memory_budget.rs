//! The budget planner end-to-end: give the optimizer a byte budget and let
//! `budget::plan` decide, per parameter group, how much preconditioner each
//! group deserves — ET level × state backend (f32 / q8 / nf4), with the
//! paper's own byte accounting as the cost model.
//!
//! No artifacts needed (pure rust):
//!
//!     cargo run --release --example memory_budget [budget, e.g. 64k]
//!
//! Prints the plan table, proves the bytes respect the budget, then runs a
//! few hundred synthetic steps through the planned optimizer to show the
//! mixed configuration actually trains.

use extensor::budget::{build_planned, plan, PlannerOptions};
use extensor::optim::{Hyper, Optimizer};
use extensor::tensoring::{model_state_bytes, OptimizerKind, StateBackend};
use extensor::util::cli::parse_byte_size;
use extensor::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    let budget = parse_byte_size(
        &std::env::args().nth(1).unwrap_or_else(|| "64k".to_string()),
    )?;
    // A small transformer's parameter groups (shared with the scaling
    // experiment and benches).
    let groups = extensor::testing::transformer_groups(2, 2000, 256, 1024);
    let solved = plan(&groups, budget, &PlannerOptions::default())?;

    println!("=== budget::plan — {} B optimizer-state budget ===\n", budget);
    println!(
        "{:<10} {:>14} {:>9} {:>8} {:>9} {:>10}",
        "group", "shape", "choice", "backend", "bytes", "DOF/param"
    );
    for (g, c) in groups.iter().zip(&solved.per_group) {
        println!(
            "{:<10} {:>14} {:>9} {:>8} {:>9} {:>10.4}",
            c.group,
            format!("{:?}", c.shape),
            c.kind.name(),
            c.backend.name(),
            c.bytes,
            c.expressivity / g.numel().max(1) as f64
        );
    }
    let total = solved.total_bytes();
    assert!(
        total as u64 <= budget,
        "plan exceeded its budget: {total} > {budget}"
    );
    println!(
        "\ntotal: {} B of {} B budget ({:.1}%), expressivity {:.0}",
        total,
        budget,
        100.0 * total as f64 / budget as f64,
        solved.total_expressivity()
    );

    // Context: what the uniform endpoints would have cost.
    let shapes: Vec<Vec<usize>> = groups.iter().map(|g| g.shape.clone()).collect();
    let adagrad = model_state_bytes(OptimizerKind::AdaGrad, &shapes, StateBackend::DenseF32);
    let et3 = model_state_bytes(OptimizerKind::Et(3), &shapes, StateBackend::DenseF32);
    println!("uniform AdaGrad/f32 would need {adagrad} B; uniform ET3/f32 {et3} B");

    // And the plan is executable: a few synthetic steps through the planned
    // (possibly mixed f32/q8/nf4) optimizer descend a quadratic.
    let mut opt = build_planned(&groups, &solved, &Hyper::default())?;
    let mut rng = Pcg64::seeded(7);
    let mut params: Vec<Vec<f32>> = groups
        .iter()
        .map(|g| {
            let mut v = vec![0.0f32; g.numel()];
            rng.fill_normal(&mut v, 0.5);
            v
        })
        .collect();
    let loss = |ps: &[Vec<f32>]| -> f64 {
        ps.iter().flatten().map(|&x| 0.5 * x as f64 * x as f64).sum()
    };
    let initial = loss(&params);
    for _ in 0..200 {
        let grads: Vec<Vec<f32>> = params.to_vec(); // grad of 0.5 x^2
        opt.next_step();
        opt.step_all(&mut params, &grads, 0.1)?;
    }
    let fin = loss(&params);
    println!(
        "\nplanned optimizer ({} B live state): loss {initial:.1} -> {fin:.3} in 200 steps",
        opt.state_bytes()
    );
    assert!(fin < initial * 0.5, "planned optimizer failed to descend");
    println!("=> the budget bought preconditioning exactly where it pays (paper §5.2, solved)");
    Ok(())
}

//! The §5.2 story as a runnable demo: under a *fixed total memory budget*
//! (params + optimizer state), extreme tensoring lets you spend the freed
//! accumulator memory on a bigger model — and win.
//!
//! Compares, at equal total memory:
//!   (a) small transformer + AdaGrad   (full per-coordinate accumulator)
//!   (b) doubled transformer + ET2     (slice-sum accumulators)
//!
//!     make artifacts && cargo run --release --example memory_budget [steps]

use extensor::optim::Schedule;
use extensor::runtime::{Client, Engine};
use extensor::train::{RunConfig, Trainer};

fn total_memory(engine: &Engine) -> usize {
    engine.manifest.total_params() + engine.manifest.total_opt_state()
}

fn run(artifact: &str, eval: &str, steps: u64, name: &str) -> anyhow::Result<extensor::train::RunSummary> {
    let cfg = RunConfig {
        name: name.into(),
        artifact: artifact.into(),
        eval_artifact: Some(eval.into()),
        steps,
        eval_every: steps,
        log_every: (steps / 20).max(1),
        schedule: Schedule::scaled_lm(0.5, (steps / 8).max(4)),
        ..RunConfig::default()
    };
    Ok(Trainer::new(cfg)?.run()?.summary)
}

fn main() -> anyhow::Result<()> {
    let steps: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(150);
    let client = Client::cpu()?;
    let dir = extensor::runtime::default_artifact_dir();

    let small_ada = Engine::load(&client, &dir, "lm_tiny_adagrad")?;
    let big_et2 = Engine::load(&client, &dir, "lm_big_et2")?;
    println!("=== equal-memory comparison (the paper's §5.2 argument) ===\n");
    println!(
        "(a) small model + AdaGrad : {:>9} params + {:>9} opt state = {:>9} floats",
        small_ada.manifest.total_params(),
        small_ada.manifest.total_opt_state(),
        total_memory(&small_ada)
    );
    println!(
        "(b) doubled model + ET2   : {:>9} params + {:>9} opt state = {:>9} floats",
        big_et2.manifest.total_params(),
        big_et2.manifest.total_opt_state(),
        total_memory(&big_et2)
    );
    let ratio = total_memory(&big_et2) as f64 / total_memory(&small_ada) as f64;
    println!("total memory ratio (b)/(a): {ratio:.2}x\n");
    drop((small_ada, big_et2, client));

    let a = run("lm_tiny_adagrad", "lm_tiny_eval", steps, "membudget_small_adagrad")?;
    let b = run("lm_big_et2", "lm_big_eval", steps, "membudget_big_et2")?;

    println!("\nafter {steps} steps each:");
    println!("(a) small + AdaGrad : val ppl {:.2}", a.final_eval_ppl);
    println!("(b) doubled + ET2   : val ppl {:.2}", b.final_eval_ppl);
    if b.final_eval_ppl < a.final_eval_ppl {
        println!("\n=> the freed optimizer memory bought model quality (paper's Table 2 shape)");
    } else {
        println!("\n=> at this tiny scale the doubled model hasn't paid off yet; run more steps");
    }
    Ok(())
}

//! The budget planner end-to-end: give the optimizer a byte budget and let
//! `budget::plan` decide, per parameter group, how much preconditioner each
//! group deserves — ET level × state backend (f32 / q8 / nf4), with the
//! paper's own byte accounting as the cost model.
//!
//! No artifacts needed (pure rust):
//!
//!     cargo run --release --example memory_budget [budget, e.g. 64k]
//!
//! Prints the plan table, proves the bytes respect the budget, then runs a
//! budget-planned convex job through the session executor — twice, so the
//! drained event stream shows the progress counters and the session's
//! dataset cache going from miss to hit.

use extensor::budget::{plan, PlannerOptions};
use extensor::convex::ConvexConfig;
use extensor::session::{
    run_job, CacheCounts, ConvexOpt, ConvexSpec, EventSink, JobEvent, JobSpec, Session,
};
use extensor::tensoring::{model_state_bytes, OptimizerKind, StateBackend};
use extensor::util::cli::parse_byte_size;

fn main() -> anyhow::Result<()> {
    let budget = parse_byte_size(
        &std::env::args().nth(1).unwrap_or_else(|| "64k".to_string()),
    )?;
    // A small transformer's parameter groups (shared with the scaling
    // experiment and benches).
    let groups = extensor::testing::transformer_groups(2, 2000, 256, 1024);
    let solved = plan(&groups, budget, &PlannerOptions::default())?;

    println!("=== budget::plan — {} B optimizer-state budget ===\n", budget);
    println!(
        "{:<10} {:>14} {:>9} {:>8} {:>9} {:>10}",
        "group", "shape", "choice", "backend", "bytes", "DOF/param"
    );
    for (g, c) in groups.iter().zip(&solved.per_group) {
        println!(
            "{:<10} {:>14} {:>9} {:>8} {:>9} {:>10.4}",
            c.group,
            format!("{:?}", c.shape),
            c.kind.name(),
            c.backend.name(),
            c.bytes,
            c.expressivity / g.numel().max(1) as f64
        );
    }
    let total = solved.total_bytes();
    assert!(
        total as u64 <= budget,
        "plan exceeded its budget: {total} > {budget}"
    );
    println!(
        "\ntotal: {} B of {} B budget ({:.1}%), expressivity {:.0}",
        total,
        budget,
        100.0 * total as f64 / budget as f64,
        solved.total_expressivity()
    );

    // Context: what the uniform endpoints would have cost.
    let shapes: Vec<Vec<usize>> = groups.iter().map(|g| g.shape.clone()).collect();
    let adagrad = model_state_bytes(OptimizerKind::AdaGrad, &shapes, StateBackend::DenseF32);
    let et3 = model_state_bytes(OptimizerKind::Et(3), &shapes, StateBackend::DenseF32);
    println!("uniform AdaGrad/f32 would need {adagrad} B; uniform ET3/f32 {et3} B");

    // And a plan is executable: run a budget-planned convex job through
    // the session executor with a collecting sink, so the same progress
    // and cache events a scheduled batch logs are visible here.
    let data = ConvexConfig { n: 1000, d: 128, k: 10, cond: 1e3, householder: 2, seed: 7 };
    let job = |name: &str| {
        JobSpec::convex(
            name,
            ConvexSpec {
                data: data.clone(),
                iters: 200,
                lr: 0.05,
                opt: ConvexOpt::Planned { budget },
                measure_after: true,
                ..ConvexSpec::default()
            },
        )
    };
    let session = Session::new();
    let (sink, events) = EventSink::collect("planned_demo");
    let out = run_job(&job("planned_demo"), &session, &sink)?;
    let out = out.as_convex().expect("convex outcome");
    let drained = events.drain();
    let progress =
        drained.iter().filter(|e| matches!(e.event, JobEvent::Progress { .. })).count();
    let first = CacheCounts::from_events(&drained);
    println!(
        "\nplanned job ({} via {} B live state): final loss {:.4}, accuracy {:.3}",
        out.optimizer, out.state_bytes, out.final_loss, out.accuracy
    );
    println!("event stream: {progress} progress events, cache counters {first:?}");
    assert!(progress > 0, "the executor must report step progress");
    assert_eq!(first.corpus_misses, 1, "first run synthesizes the dataset");
    assert!(out.accuracy > 0.5, "planned optimizer failed to learn");

    // Same dataset, same session: the second run hits the corpus cache.
    let (sink, events) = EventSink::collect("planned_demo_again");
    run_job(&job("planned_demo_again"), &session, &sink)?;
    let again = CacheCounts::from_events(&events.drain());
    println!("second run on the same session: cache counters {again:?}");
    assert_eq!(again.corpus_hits, 1, "second run must reuse the cached dataset");
    println!("=> the budget bought preconditioning exactly where it pays (paper §5.2, solved)");
    Ok(())
}

//! End-to-end training driver — the full system on a real (synthetic-corpus)
//! workload, proving all three layers compose:
//!
//!   rust data pipeline (corpus -> tokenizer -> packer -> prefetch loader)
//!     -> PJRT train-step artifact (JAX transformer fwd/bwd + Pallas
//!        extreme-tensoring kernels, AOT-lowered)
//!     -> rust schedule/eval/checkpoint/metrics
//!
//! Trains the doubled-depth transformer (lm_big, ~1M params at this
//! testbed's scale) for several hundred steps with ET2, logging the loss
//! curve to runs/e2e/metrics.jsonl and printing it here. Recorded in
//! EXPERIMENTS.md §End-to-end.
//!
//!     make artifacts && cargo run --release --example e2e_train [steps]

use extensor::optim::Schedule;
use extensor::train::{RunConfig, Trainer};

fn main() -> anyhow::Result<()> {
    let steps: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let cfg = RunConfig {
        name: "e2e".into(),
        artifact: "lm_big_et2".into(),
        eval_artifact: Some("lm_big_eval".into()),
        steps,
        eval_every: (steps / 6).max(1),
        eval_batches: 8,
        log_every: (steps / 60).max(1),
        checkpoint_every: (steps / 2).max(1),
        schedule: Schedule::scaled_lm(0.5, (steps / 8).max(4)),
        track_traces: false,
        ..RunConfig::default()
    };
    let mut trainer = Trainer::new(cfg)?;
    let m = &trainer.engine().manifest;
    println!("=== end-to-end driver ===");
    println!(
        "model: transformer ({} layers, d_model {}), {} params",
        m.model.get("layers").and_then(|v| v.as_usize()).unwrap_or(0),
        m.model.get("d_model").and_then(|v| v.as_usize()).unwrap_or(0),
        m.total_params()
    );
    println!(
        "optimizer: {} — {} state scalars ({:.4}x of params; AdaGrad would need 1.0x)",
        m.optimizer.get("kind").and_then(|v| v.as_str()).unwrap_or("?"),
        m.total_opt_state(),
        m.total_opt_state() as f64 / m.total_params() as f64
    );

    let result = trainer.run()?;

    println!("\ntrain loss curve:");
    let max_loss =
        result.loss_history.iter().map(|(_, l)| *l).fold(f64::MIN, f64::max).max(1e-9);
    for (step, loss) in &result.loss_history {
        let bar = "#".repeat(((loss / max_loss) * 48.0) as usize);
        println!("  {step:>5}  {loss:>7.3}  {bar}");
    }
    println!("\nvalidation perplexity:");
    for rec in &result.eval_history {
        println!("  step {:>5}: ppl {:.2} ({:.0} tokens)", rec.step, rec.ppl(), rec.tokens);
    }
    let s = &result.summary;
    println!(
        "\nsummary: {} steps, final train loss {:.4}, final val ppl {:.2}, \
         {:.1}s wall, {:.0} tokens/s",
        s.steps, s.final_train_loss, s.final_eval_ppl, s.wall_seconds, s.tokens_per_sec
    );
    println!("metrics: runs/e2e/metrics.jsonl; checkpoint: runs/e2e/final.ck");
    Ok(())
}

//! Online convex optimization with regret measurement — the theory side of
//! the paper (§4) made concrete.
//!
//! We run extreme tensoring as an *online* learner on the §5.4 logistic
//! regression stream and measure (a) cumulative regret against the best
//! fixed comparator in hindsight, checking sublinear growth, and (b) the
//! trace quantities of Theorem 4.1, checking that the measured regret is
//! inside the bound's scale.
//!
//!     cargo run --release --example regret_convex

use extensor::convex::{ConvexConfig, ConvexDataset, SoftmaxRegression};
use extensor::optim::{self, GroupSpec, Optimizer};
use extensor::regret::{RegretMeter, TraceTracker};
use extensor::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    let cfg = ConvexConfig { n: 4000, d: 256, k: 8, cond: 1e4, householder: 6, seed: 3 };
    println!("generating online stream: d={}, k={}, cond={:.0}", cfg.d, cfg.k, cfg.cond);
    let ds = ConvexDataset::generate(&cfg);
    let obj = SoftmaxRegression::new(&ds);
    let groups = vec![GroupSpec::new("w", &[cfg.k, cfg.d])];

    // Comparator: a near-optimal fixed W from an offline AdaGrad run.
    let full: Vec<usize> = (0..ds.n).collect();
    let mut comparator = vec![0.0f32; obj.dim()];
    {
        let mut opt = optim::build(
            extensor::tensoring::OptimizerKind::AdaGrad,
            &groups,
            &optim::Hyper::default(),
        );
        let mut grad = vec![0.0f32; obj.dim()];
        for _ in 0..300 {
            obj.loss_grad(&comparator, &full, &mut grad);
            opt.step(0, &mut comparator, &grad, 0.1)?;
        }
        println!("comparator loss (offline AdaGrad): {:.4}", obj.loss(&comparator, &full));
    }

    // Online learner: ET depth 2 over the feature dimension.
    let dims = vec![vec![cfg.k, 16, cfg.d / 16]];
    let mut learner =
        optim::extreme::custom_et(&groups, dims.clone(), 1e-8, None).expect("dims cover");
    let mut tracker = TraceTracker::new(&[("w".into(), dims[0].clone())], 1e-8)?;
    let mut meter = RegretMeter::new();
    let mut w = vec![0.0f32; obj.dim()];
    let mut grad = vec![0.0f32; obj.dim()];
    let mut rng = Pcg64::seeded(99);

    let rounds = 600usize;
    let batch = 32usize;
    for t in 0..rounds {
        // adversary reveals a random minibatch loss f_t
        let idx: Vec<usize> = (0..batch).map(|_| rng.below(ds.n as u64) as usize).collect();
        let learner_loss = obj.loss_grad(&w, &idx, &mut grad);
        let comp_loss = obj.loss(&comparator, &idx);
        meter.observe(learner_loss, comp_loss);
        tracker.observe(&[&grad])?;
        learner.step(0, &mut w, &grad, 0.3)?;
        if (t + 1) % 100 == 0 {
            println!(
                "round {:>4}: learner loss {:.4}, cumulative regret {:.2}",
                t + 1,
                learner_loss,
                meter.regret()
            );
        }
    }

    // Sublinearity check: compare regret growth in the two halves.
    let curve = meter.regret_curve();
    let half = curve[rounds / 2 - 1];
    let total = curve[rounds - 1];
    println!("\nregret at T/2: {half:.2}, at T: {total:.2}");
    println!(
        "second-half increment {:.2} vs first half {half:.2} (sublinear if smaller)",
        total - half
    );

    let report = tracker.report();
    println!(
        "\nTheorem 4.1 traces after T={rounds}: Tr(H_T) = {:.3e}, Tr(Ĥ_T) = {:.3e}",
        report.trace_h, report.trace_h_hat
    );
    println!(
        "regret-bound gap vs AdaGrad: sqrt(Tr(H)/Tr(Ĥ)) = {:.2} (paper measures ≈ 5.7 at scale)",
        report.ratio
    );
    Ok(())
}

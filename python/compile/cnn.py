"""Layer 2: small convolutional classifier for the appendix vision
experiment (Table 4 / Figure 4 substitute — see DESIGN.md §3).

Architecture: two 3x3 conv + relu + 2x2 avg-pool stages, then a linear
classifier. Parameter shapes are conv-shaped `(out, in, kh, kw)` so the
Table 3 factorization presets apply directly.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CnnConfig:
    classes: int = 10
    img: int = 32
    in_ch: int = 3
    ch1: int = 16
    ch2: int = 32
    batch: int = 32

    @property
    def fc_in(self) -> int:
        return self.ch2 * (self.img // 4) * (self.img // 4)


def param_specs(cfg: CnnConfig):
    """Ordered (name, shape, init, init_scale) — the artifact contract."""
    return [
        ("conv1", (cfg.ch1, cfg.in_ch, 3, 3), "normal", (cfg.in_ch * 9) ** -0.5),
        ("b1", (cfg.ch1,), "zeros", 0.0),
        ("conv2", (cfg.ch2, cfg.ch1, 3, 3), "normal", (cfg.ch1 * 9) ** -0.5),
        ("b2", (cfg.ch2,), "zeros", 0.0),
        ("fc", (cfg.fc_in, cfg.classes), "normal", cfg.fc_in ** -0.5),
        ("fcb", (cfg.classes,), "zeros", 0.0),
    ]


def init_params(cfg: CnnConfig, key):
    params = []
    for _, shape, init, scale in param_specs(cfg):
        if init == "normal":
            key, sub = jax.random.split(key)
            params.append(jax.random.normal(sub, shape, jnp.float32) * scale)
        else:
            params.append(jnp.zeros(shape, jnp.float32))
    return params


def _conv(x, w, b):
    # x: (B, C, H, W), w: (O, I, kh, kw) -> same-padded conv
    out = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out + b[None, :, None, None]


def _pool2(x):
    return jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, 1, 2, 2), (1, 1, 2, 2), "VALID"
    ) * 0.25


def logits_fn(params, images, cfg: CnnConfig):
    """images f32[B, 3, 32, 32] -> logits f32[B, classes]."""
    conv1, b1, conv2, b2, fc, fcb = params
    h = _pool2(jax.nn.relu(_conv(images, conv1, b1)))
    h = _pool2(jax.nn.relu(_conv(h, conv2, b2)))
    h = h.reshape(h.shape[0], -1)
    return h @ fc + fcb


def nll_fn(params, images, labels, cfg: CnnConfig):
    """(total_nll, count) cross-entropy."""
    logits = logits_fn(params, images, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    tnll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return jnp.sum(tnll), jnp.float32(labels.shape[0])


def mean_loss_fn(params, images, labels, cfg: CnnConfig):
    total, count = nll_fn(params, images, labels, cfg)
    return total / count


def error_count_fn(params, images, labels, cfg: CnnConfig):
    """(wrong_count, count) for test-error aggregation (eval artifact)."""
    logits = logits_fn(params, images, cfg)
    pred = jnp.argmax(logits, axis=-1).astype(labels.dtype)
    wrong = jnp.sum((pred != labels).astype(jnp.float32))
    return wrong, jnp.float32(labels.shape[0])


def loss_and_grads(params, images, labels, cfg: CnnConfig):
    return jax.value_and_grad(lambda ps: mean_loss_fn(ps, images, labels, cfg))(params)

"""AOT compiler: lowers every (model x optimizer) train/eval/grad step to
HLO **text** + a JSON manifest the rust runtime consumes.

HLO text — not ``.serialize()`` — is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version the published ``xla`` rust crate binds) rejects; the text
parser reassigns ids. See /opt/xla-example/README.md.

Also emits golden fixtures (tiny inputs + expected outputs as JSON) under
``artifacts/golden/`` for the rust cross-layer tests.

Usage:
    python -m compile.aot --out-dir ../artifacts [--only lm_tiny_et2 ...]

Incremental: an artifact is skipped when its .hlo.txt and .json exist and
the stored source-hash matches (``make artifacts`` stays a no-op when
python sources are unchanged).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import math
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import cnn as cnn_mod
from . import model as lm_mod
from . import optim_jax

# ---------------------------------------------------------------------------
# configs
# ---------------------------------------------------------------------------

LM_CONFIGS = {
    # micro: golden tests + fast integration tests
    "lm_micro": lm_mod.LmConfig(vocab=64, d_model=32, layers=1, heads=2, d_ff=64,
                                rows=2, seq=16),
    # tiny: the Table 1 / Figure 1 workhorse
    "lm_tiny": lm_mod.LmConfig(vocab=1904, d_model=128, layers=2, heads=4, d_ff=512,
                               rows=8, seq=64),
    # big: doubled depth for Table 2 (§5.2)
    "lm_big": lm_mod.LmConfig(vocab=1904, d_model=128, layers=4, heads=4, d_ff=512,
                              rows=8, seq=64),
}

CNN_CONFIG = cnn_mod.CnnConfig()

LM_OPTIMIZERS = ["sgd", "adagrad", "adam", "adafactor", "et1", "et2", "et3", "etinf"]
BIG_OPTIMIZERS = ["et1", "et2", "et3", "etinf"]
CNN_OPTIMIZERS = ["sgd", "adam", "et1", "et2", "et3", "etinf"]
MICRO_OPTIMIZERS = ["et1", "et2", "et3", "etinf", "adagrad", "adam", "adafactor", "sgd"]

# ET accumulator decay: None for LM (paper found decay unhelpful there),
# 0.99 for vision (paper appendix A.1).
ET_BETA2_LM = None
ET_BETA2_CNN = 0.99
EPS = 1e-8


# ---------------------------------------------------------------------------
# lowering helpers
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _source_hash() -> str:
    """Hash of every python source under compile/ — the cache key."""
    h = hashlib.sha256()
    root = pathlib.Path(__file__).parent
    for p in sorted(root.rglob("*.py")):
        h.update(p.name.encode())
        h.update(p.read_bytes())
    return h.hexdigest()[:16]


def _write_manifest(out_dir, name, kind, pspecs, sspecs, data_inputs,
                    extra_inputs, model_meta, opt_meta, src_hash):
    manifest = {
        "name": name,
        "kind": kind,
        "hlo": f"{name}.hlo.txt",
        "source_hash": src_hash,
        "model": model_meta,
        "optimizer": opt_meta,
        "params": [
            {"name": n, "shape": list(s), "init": init, "init_scale": scale}
            for n, s, init, scale in pspecs
        ],
        "opt_state": [
            {"name": n, "shape": list(s), "init": "zeros"} for n, s in sspecs
        ],
        "data_inputs": data_inputs,
        "extra_inputs": extra_inputs,
    }
    (out_dir / f"{name}.json").write_text(json.dumps(manifest, indent=1))


def _is_current(out_dir: pathlib.Path, name: str, src_hash: str) -> bool:
    mpath = out_dir / f"{name}.json"
    hpath = out_dir / f"{name}.hlo.txt"
    if not (mpath.exists() and hpath.exists()):
        return False
    try:
        return json.loads(mpath.read_text()).get("source_hash") == src_hash
    except json.JSONDecodeError:
        return False


# ---------------------------------------------------------------------------
# LM artifacts
# ---------------------------------------------------------------------------


def lm_train_step_fn(cfg, opt_kind, n_params, n_state, et_beta2):
    pspecs = lm_mod.param_specs(cfg)

    def fn(*args):
        params = list(args[:n_params])
        opt_state = list(args[n_params : n_params + n_state])
        tokens = args[n_params + n_state]
        lr = args[n_params + n_state + 1]
        step = args[n_params + n_state + 2]
        loss, grads = lm_mod.loss_and_grads(params, tokens, cfg)
        new_params, new_state = optim_jax.apply_updates(
            opt_kind, pspecs, params, grads, opt_state, lr, step,
            eps=EPS, et_beta2=et_beta2,
        )
        return tuple([loss] + new_params + new_state)

    return fn


def build_lm_artifact(out_dir, cfg_name, cfg, opt_kind, src_hash, et_beta2):
    name = f"{cfg_name}_{opt_kind}"
    if _is_current(out_dir, name, src_hash):
        return False
    pspecs = lm_mod.param_specs(cfg)
    sspecs = optim_jax.state_specs(opt_kind, pspecs)
    fn = lm_train_step_fn(cfg, opt_kind, len(pspecs), len(sspecs), et_beta2)
    args = (
        [_spec(s) for _, s, _, _ in pspecs]
        + [_spec(s) for _, s in sspecs]
        + [_spec((cfg.rows, cfg.seq), jnp.int32), _spec(()), _spec(())]
    )
    lowered = jax.jit(fn, keep_unused=True).lower(*args)
    (out_dir / f"{name}.hlo.txt").write_text(to_hlo_text(lowered))
    _write_manifest(
        out_dir, name, "train_step", pspecs, sspecs,
        [{"name": "tokens", "shape": [cfg.rows, cfg.seq], "dtype": "i32"}],
        ["lr", "step"],
        {"family": "transformer_lm", "vocab": cfg.vocab, "d_model": cfg.d_model,
         "layers": cfg.layers, "heads": cfg.heads, "d_ff": cfg.d_ff,
         "rows": cfg.rows, "seq": cfg.seq,
         "total_params": sum(math.prod(s) for _, s, _, _ in pspecs)},
        {"kind": opt_kind, "eps": EPS, "beta2": et_beta2,
         "state_scalars": sum(math.prod(s) for _, s in sspecs)},
        src_hash,
    )
    return True


def build_lm_eval(out_dir, cfg_name, cfg, src_hash):
    name = f"{cfg_name}_eval"
    if _is_current(out_dir, name, src_hash):
        return False
    pspecs = lm_mod.param_specs(cfg)

    def fn(*args):
        params = list(args[:-1])
        tokens = args[-1]
        total, count = lm_mod.nll_fn(params, tokens, cfg)
        return (total, count)

    args = [_spec(s) for _, s, _, _ in pspecs] + [_spec((cfg.rows, cfg.seq), jnp.int32)]
    lowered = jax.jit(fn, keep_unused=True).lower(*args)
    (out_dir / f"{name}.hlo.txt").write_text(to_hlo_text(lowered))
    _write_manifest(
        out_dir, name, "eval_step", pspecs, [],
        [{"name": "tokens", "shape": [cfg.rows, cfg.seq], "dtype": "i32"}],
        [], {"family": "transformer_lm", "vocab": cfg.vocab}, {"kind": "none"},
        src_hash,
    )
    return True


def build_lm_grad(out_dir, cfg_name, cfg, src_hash):
    name = f"{cfg_name}_grad"
    if _is_current(out_dir, name, src_hash):
        return False
    pspecs = lm_mod.param_specs(cfg)

    def fn(*args):
        params = list(args[:-1])
        tokens = args[-1]
        loss, grads = lm_mod.loss_and_grads(params, tokens, cfg)
        return tuple([loss] + list(grads))

    args = [_spec(s) for _, s, _, _ in pspecs] + [_spec((cfg.rows, cfg.seq), jnp.int32)]
    lowered = jax.jit(fn, keep_unused=True).lower(*args)
    (out_dir / f"{name}.hlo.txt").write_text(to_hlo_text(lowered))
    _write_manifest(
        out_dir, name, "grad_step", pspecs, [],
        [{"name": "tokens", "shape": [cfg.rows, cfg.seq], "dtype": "i32"}],
        [], {"family": "transformer_lm", "vocab": cfg.vocab}, {"kind": "none"},
        src_hash,
    )
    return True


# ---------------------------------------------------------------------------
# CNN artifacts
# ---------------------------------------------------------------------------


def build_cnn_artifact(out_dir, opt_kind, src_hash):
    cfg = CNN_CONFIG
    name = f"cnn_{opt_kind}"
    if _is_current(out_dir, name, src_hash):
        return False
    pspecs = cnn_mod.param_specs(cfg)
    sspecs = optim_jax.state_specs(opt_kind, pspecs)
    np_, ns = len(pspecs), len(sspecs)

    def fn(*args):
        params = list(args[:np_])
        opt_state = list(args[np_ : np_ + ns])
        images, labels, lr, step = args[np_ + ns :]
        loss, grads = cnn_mod.loss_and_grads(params, images, labels, cfg)
        new_params, new_state = optim_jax.apply_updates(
            opt_kind, pspecs, params, grads, opt_state, lr, step,
            eps=EPS, et_beta2=ET_BETA2_CNN, beta1=0.0,  # paper: Adam beta1=0
        )
        return tuple([loss] + new_params + new_state)

    args = (
        [_spec(s) for _, s, _, _ in pspecs]
        + [_spec(s) for _, s in sspecs]
        + [
            _spec((cfg.batch, cfg.in_ch, cfg.img, cfg.img)),
            _spec((cfg.batch,), jnp.int32),
            _spec(()),
            _spec(()),
        ]
    )
    lowered = jax.jit(fn, keep_unused=True).lower(*args)
    (out_dir / f"{name}.hlo.txt").write_text(to_hlo_text(lowered))
    _write_manifest(
        out_dir, name, "train_step", pspecs, sspecs,
        [
            {"name": "images", "shape": [cfg.batch, cfg.in_ch, cfg.img, cfg.img],
             "dtype": "f32"},
            {"name": "labels", "shape": [cfg.batch], "dtype": "i32"},
        ],
        ["lr", "step"],
        {"family": "cnn", "classes": cfg.classes, "batch": cfg.batch},
        {"kind": opt_kind, "eps": EPS, "beta2": ET_BETA2_CNN,
         "state_scalars": sum(math.prod(s) for _, s in sspecs)},
        src_hash,
    )
    return True


def build_cnn_eval(out_dir, src_hash):
    cfg = CNN_CONFIG
    name = "cnn_eval"
    if _is_current(out_dir, name, src_hash):
        return False
    pspecs = cnn_mod.param_specs(cfg)

    def fn(*args):
        params = list(args[:-2])
        images, labels = args[-2:]
        wrong, count = cnn_mod.error_count_fn(params, images, labels, cfg)
        return (wrong, count)

    args = [_spec(s) for _, s, _, _ in pspecs] + [
        _spec((cfg.batch, cfg.in_ch, cfg.img, cfg.img)),
        _spec((cfg.batch,), jnp.int32),
    ]
    lowered = jax.jit(fn, keep_unused=True).lower(*args)
    (out_dir / f"{name}.hlo.txt").write_text(to_hlo_text(lowered))
    _write_manifest(
        out_dir, name, "eval_step", pspecs, [],
        [
            {"name": "images", "shape": [cfg.batch, cfg.in_ch, cfg.img, cfg.img],
             "dtype": "f32"},
            {"name": "labels", "shape": [cfg.batch], "dtype": "i32"},
        ],
        [], {"family": "cnn", "classes": cfg.classes}, {"kind": "none"},
        src_hash,
    )
    return True


# ---------------------------------------------------------------------------
# golden fixtures (rust cross-layer tests)
# ---------------------------------------------------------------------------


def build_goldens(out_dir: pathlib.Path, src_hash: str):
    """Tiny fixed inputs + expected outputs, as JSON, for rust to diff
    against both the compiled artifact and its own native ET oracle."""
    gdir = out_dir / "golden"
    gdir.mkdir(parents=True, exist_ok=True)
    stamp = gdir / "source_hash.txt"
    if stamp.exists() and stamp.read_text() == src_hash:
        return False
    rng = np.random.default_rng(20200417)

    # 1. kernel-level golden: slice sums + one Algorithm-1 update
    dims = (4, 5, 6)
    n = math.prod(dims)
    g = rng.normal(size=(n,)).astype(np.float32)
    x = rng.normal(size=(n,)).astype(np.float32)
    from .kernels import ref

    sums = ref.slice_sq_sums(jnp.asarray(g), dims)
    new_x = ref.et_update(jnp.asarray(x), jnp.asarray(g), sums, EPS, 0.37)
    (gdir / "et_kernel.json").write_text(json.dumps({
        "dims": list(dims), "eps": EPS, "lr": 0.37,
        "g": g.tolist(), "x": x.tolist(),
        "sums": [np.asarray(s).tolist() for s in sums],
        "new_x": np.asarray(new_x).tolist(),
    }))

    # 2. micro train-step golden: two fused et2 steps from fixed params
    cfg = LM_CONFIGS["lm_micro"]
    pspecs = lm_mod.param_specs(cfg)
    sspecs = optim_jax.state_specs("et2", pspecs)
    params_init = []
    for name, shape, init, scale in pspecs:
        if init == "normal":
            params_init.append(jnp.asarray(
                rng.normal(size=shape).astype(np.float32) * scale))
        elif init == "ones":
            params_init.append(jnp.ones(shape, jnp.float32))
        else:
            params_init.append(jnp.zeros(shape, jnp.float32))
    params = list(params_init)
    state = [jnp.zeros(s, jnp.float32) for _, s in sspecs]
    tokens = rng.integers(1, cfg.vocab, size=(cfg.rows, cfg.seq)).astype(np.int32)
    losses = []
    for step in (1.0, 2.0):
        loss, grads = lm_mod.loss_and_grads(params, jnp.asarray(tokens), cfg)
        params, state = optim_jax.apply_updates(
            "et2", pspecs, params, grads, state,
            jnp.float32(0.05), jnp.float32(step), eps=EPS, et_beta2=ET_BETA2_LM)
        losses.append(float(loss))
    (gdir / "lm_micro_et2_steps.json").write_text(json.dumps({
        "config": "lm_micro", "optimizer": "et2", "lr": 0.05, "steps": 2,
        "tokens": tokens.reshape(-1).tolist(),
        "param_init": [
            {"name": pspecs[i][0],
             "values": np.asarray(p).reshape(-1).tolist()}
            for i, p in enumerate(params_init)
        ],
        "losses": losses,
        "final_param_checksums": [
            {"name": pspecs[i][0], "sum_abs": float(jnp.sum(jnp.abs(p)))}
            for i, p in enumerate(params)
        ],
        "final_state_checksums": [
            {"name": sspecs[i][0], "sum": float(jnp.sum(s))}
            for i, s in enumerate(state)
        ],
    }))
    stamp.write_text(src_hash)
    return True


# ---------------------------------------------------------------------------
# main
# ---------------------------------------------------------------------------


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", nargs="*", default=None,
                    help="artifact name filter (substring match)")
    ns = ap.parse_args(argv)
    out_dir = pathlib.Path(ns.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    src_hash = _source_hash()

    jobs = []
    for opt in MICRO_OPTIMIZERS:
        jobs.append((f"lm_micro_{opt}",
                     lambda o=opt: build_lm_artifact(
                         out_dir, "lm_micro", LM_CONFIGS["lm_micro"], o,
                         src_hash, ET_BETA2_LM)))
    jobs.append(("lm_micro_eval",
                 lambda: build_lm_eval(out_dir, "lm_micro", LM_CONFIGS["lm_micro"], src_hash)))
    jobs.append(("lm_micro_grad",
                 lambda: build_lm_grad(out_dir, "lm_micro", LM_CONFIGS["lm_micro"], src_hash)))
    for opt in LM_OPTIMIZERS:
        jobs.append((f"lm_tiny_{opt}",
                     lambda o=opt: build_lm_artifact(
                         out_dir, "lm_tiny", LM_CONFIGS["lm_tiny"], o,
                         src_hash, ET_BETA2_LM)))
    jobs.append(("lm_tiny_eval",
                 lambda: build_lm_eval(out_dir, "lm_tiny", LM_CONFIGS["lm_tiny"], src_hash)))
    jobs.append(("lm_tiny_grad",
                 lambda: build_lm_grad(out_dir, "lm_tiny", LM_CONFIGS["lm_tiny"], src_hash)))
    for opt in BIG_OPTIMIZERS:
        jobs.append((f"lm_big_{opt}",
                     lambda o=opt: build_lm_artifact(
                         out_dir, "lm_big", LM_CONFIGS["lm_big"], o,
                         src_hash, ET_BETA2_LM)))
    jobs.append(("lm_big_eval",
                 lambda: build_lm_eval(out_dir, "lm_big", LM_CONFIGS["lm_big"], src_hash)))
    for opt in CNN_OPTIMIZERS:
        jobs.append((f"cnn_{opt}", lambda o=opt: build_cnn_artifact(out_dir, o, src_hash)))
    jobs.append(("cnn_eval", lambda: build_cnn_eval(out_dir, src_hash)))
    jobs.append(("golden", lambda: build_goldens(out_dir, src_hash)))

    built = skipped = 0
    for name, job in jobs:
        if ns.only and not any(f in name for f in ns.only):
            continue
        if job():
            built += 1
            print(f"[aot] built {name}", flush=True)
        else:
            skipped += 1
    print(f"[aot] done: {built} built, {skipped} up-to-date "
          f"(source hash {src_hash})")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Factorization planner — python mirror of ``rust/src/tensoring/planner.rs``.

The tensor-index dims chosen here are baked into the AOT artifacts (the
optimizer-state shapes in each manifest), so the rust side never re-plans
for artifact-driven training; the rust planner exists for the native
(convex/regret) experiments and is tested against the same paper tables.
Keeping the two implementations in lockstep is enforced by the golden tests
(the manifest opt-state shapes are produced here and consumed there).

Scheme (paper Table 3 / Appendix B.1):
  * ET1: the parameter's natural tensor (conv spatial dims merged).
  * ET(k+1): split every ET(k) factor > 10 into (a, n/a), a = largest
    divisor <= sqrt(n). Primes pass through.
"""

from __future__ import annotations

import math

SPLIT_THRESHOLD = 10


def balanced_divisor(n: int) -> int:
    """Largest divisor of n that is <= sqrt(n); 1 when n is prime."""
    best = 1
    a = 1
    while a * a <= n:
        if n % a == 0:
            best = a
        a += 1
    return best


def natural_dims(shape: tuple[int, ...]) -> list[int]:
    """ET1 dims: drop size-1 axes; merge conv spatial dims (rank >= 4)."""
    dims = [d for d in shape if d > 1]
    if not dims:
        dims = [1]
    if len(dims) >= 4:
        spatial = math.prod(dims[2:])
        dims = dims[:2] + [spatial]
    return dims


def _split_factor(n: int, out: list[int]) -> None:
    if n <= SPLIT_THRESHOLD:
        out.append(n)
        return
    a = balanced_divisor(n)
    if a == 1:
        out.append(n)  # prime
    else:
        out.append(a)
        out.append(n // a)


def plan(shape: tuple[int, ...], level: int) -> list[int]:
    """Tensor-index dims for ``shape`` at ET level ``level`` (>= 1)."""
    dims = natural_dims(tuple(shape))
    for _ in range(max(level, 1) - 1):
        nxt: list[int] = []
        for f in dims:
            _split_factor(f, nxt)
        dims = nxt
    return dims


def plan_state_len(dims: list[int]) -> int:
    return sum(dims)

"""Layer 2: decoder-only transformer language model in pure jnp.

Mirrors the paper's base architecture family (Tensor2Tensor "base"
Transformer, decoder-only, shared embedding/softmax weights, sinusoidal
positions) at configurable scale. Parameters are an *ordered list* —
the order is the artifact contract consumed by the rust runtime, recorded
in the manifest by ``aot.py``.

Loss is next-token cross-entropy over the packed stream with PAD (id 0)
targets masked, returning ``(total_nll, token_count)`` so the rust side
can aggregate exact corpus perplexity across batches.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

PAD_ID = 0


@dataclasses.dataclass(frozen=True)
class LmConfig:
    vocab: int = 1904
    d_model: int = 128
    layers: int = 2
    heads: int = 4
    d_ff: int = 512
    rows: int = 8
    seq: int = 64

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.heads == 0
        return self.d_model // self.heads


def param_specs(cfg: LmConfig):
    """Ordered (name, shape, init, init_scale) — the artifact contract."""
    specs = [("embed", (cfg.vocab, cfg.d_model), "normal", cfg.d_model ** -0.5)]
    wscale = cfg.d_model ** -0.5
    fscale = cfg.d_ff ** -0.5
    for l in range(cfg.layers):
        specs += [
            (f"l{l}.ln1", (cfg.d_model,), "ones", 0.0),
            (f"l{l}.wq", (cfg.d_model, cfg.d_model), "normal", wscale),
            (f"l{l}.wk", (cfg.d_model, cfg.d_model), "normal", wscale),
            (f"l{l}.wv", (cfg.d_model, cfg.d_model), "normal", wscale),
            (f"l{l}.wo", (cfg.d_model, cfg.d_model), "normal", wscale),
            (f"l{l}.ln2", (cfg.d_model,), "ones", 0.0),
            (f"l{l}.ff1", (cfg.d_model, cfg.d_ff), "normal", wscale),
            (f"l{l}.ff1b", (cfg.d_ff,), "zeros", 0.0),
            (f"l{l}.ff2", (cfg.d_ff, cfg.d_model), "normal", fscale),
            (f"l{l}.ff2b", (cfg.d_model,), "zeros", 0.0),
        ]
    specs.append(("ln_f", (cfg.d_model,), "ones", 0.0))
    return specs


def init_params(cfg: LmConfig, key):
    """Test-time initializer (the rust runtime has its own, seeded from the
    manifest; this one is only for python-side tests)."""
    params = []
    for name, shape, init, scale in param_specs(cfg):
        if init == "normal":
            key, sub = jax.random.split(key)
            params.append(jax.random.normal(sub, shape, jnp.float32) * scale)
        elif init == "ones":
            params.append(jnp.ones(shape, jnp.float32))
        else:
            params.append(jnp.zeros(shape, jnp.float32))
    return params


def _sinusoidal(seq: int, dim: int):
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    half = dim // 2
    freq = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=1)


def _layer_norm(x, gain):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return gain * (x - mu) * jax.lax.rsqrt(var + 1e-6)


def _unpack(params, cfg: LmConfig):
    names = [s[0] for s in param_specs(cfg)]
    return dict(zip(names, params))


def logits_fn(params, tokens, cfg: LmConfig):
    """tokens i32[rows, seq] -> logits f32[rows, seq, vocab]."""
    p = _unpack(params, cfg)
    b, s = tokens.shape
    h = p["embed"][tokens] * (cfg.d_model ** 0.5) + _sinusoidal(s, cfg.d_model)[None]
    causal = jnp.tril(jnp.ones((s, s), jnp.bool_))
    for l in range(cfg.layers):
        # --- pre-norm multi-head self-attention ---
        x = _layer_norm(h, p[f"l{l}.ln1"])
        q = (x @ p[f"l{l}.wq"]).reshape(b, s, cfg.heads, cfg.head_dim)
        k = (x @ p[f"l{l}.wk"]).reshape(b, s, cfg.heads, cfg.head_dim)
        v = (x @ p[f"l{l}.wv"]).reshape(b, s, cfg.heads, cfg.head_dim)
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(cfg.head_dim)
        att = jnp.where(causal[None, None], att, -1e9)
        att = jax.nn.softmax(att, axis=-1)
        ctx = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b, s, cfg.d_model)
        h = h + ctx @ p[f"l{l}.wo"]
        # --- pre-norm feed-forward ---
        x = _layer_norm(h, p[f"l{l}.ln2"])
        ff = jax.nn.relu(x @ p[f"l{l}.ff1"] + p[f"l{l}.ff1b"])
        h = h + ff @ p[f"l{l}.ff2"] + p[f"l{l}.ff2b"]
    h = _layer_norm(h, p["ln_f"])
    # weight-tied softmax
    return h @ p["embed"].T


def nll_fn(params, tokens, cfg: LmConfig):
    """(total_nll, token_count) for next-token prediction, PAD masked."""
    logits = logits_fn(params, tokens[:, :-1], cfg)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    tnll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    mask = (targets != PAD_ID).astype(jnp.float32)
    return jnp.sum(tnll * mask), jnp.sum(mask)


def mean_loss_fn(params, tokens, cfg: LmConfig):
    total, count = nll_fn(params, tokens, cfg)
    return total / jnp.maximum(count, 1.0)


def loss_and_grads(params, tokens, cfg: LmConfig):
    """(mean_nll, grads) — what the train-step artifacts differentiate."""
    return jax.value_and_grad(lambda ps: mean_loss_fn(ps, tokens, cfg))(params)

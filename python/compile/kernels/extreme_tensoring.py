"""Pallas kernels for the extreme-tensoring hot spots (Layer 1).

Two compute patterns dominate Algorithm 1:

1. **Slice-sum reduction** (line 6): per-mode sums of squared gradient
   entries. Any mode-``i`` slice sum of a ``p``-order tensor is a row-sum
   of squares of a 2-D view ``(a * d_i, b)`` followed by a tiny ``(a, d_i)``
   reduction, so one tiled 2-D kernel (`rowsum_sq`) covers every mode of
   every order.

2. **Fused preconditioner apply** (lines 7-8): the elementwise update
   ``x - lr * g * (eps + prod)^(-1/(2p))``. `et_apply_flat` fuses the power,
   multiply and subtraction so ``x`` and ``g`` stream through VMEM exactly
   once (arithmetic intensity ~4 flops/element — bandwidth-bound, as an
   optimizer update should be). For the common matrix case (p = 2) the
   rank-one product is consumed directly from the two accumulator vectors
   by `et_apply_2d`, skipping the materialized product vector entirely.

All kernels run with ``interpret=True``: at AOT-lowering time this expands
to plain HLO (so the rust CPU-PJRT runtime executes compiled XLA, not a
python interpreter); on a real TPU the same BlockSpecs express the
HBM->VMEM schedule.

Block sizes are chosen as divisors of the array dims (tensor-index dims are
products of small factors by construction, so good divisors always exist)
to avoid masked edge tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM budget heuristic: keep each operand block <= ~128K f32 (~512 KiB),
# comfortably inside a TPU core's ~16 MiB VMEM with double-buffering.
_BLOCK_TARGET_ROWS = 256
_BLOCK_TARGET_COLS = 512
_BLOCK_TARGET_FLAT = 64 * 1024


def _divisor_block(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (n itself if n <= target)."""
    if n <= target:
        return n
    best = 1
    a = 1
    while a * a <= n:
        if n % a == 0:
            for c in (a, n // a):
                if c <= target and c > best:
                    best = c
        a += 1
    return best


def rowsum_sq(x, *, block_rows: int = _BLOCK_TARGET_ROWS, block_cols: int = _BLOCK_TARGET_COLS):
    """Tiled row sums of squares: out[i] = sum_j x[i, j]^2.

    Grid is (row_blocks, col_blocks); the column dimension is innermost, so
    each output block is initialized on the first column tile and
    accumulated across the rest (the standard Pallas reduction pattern).
    """
    m, n = x.shape
    bm = _divisor_block(m, block_rows)
    bn = _divisor_block(n, block_cols)

    def kernel(x_ref, o_ref):
        @pl.when(pl.program_id(1) == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        blk = x_ref[...]
        o_ref[...] += jnp.sum(blk * blk, axis=1)

    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), x.dtype),
        interpret=True,
    )(x)


def mode_slice_sums(g_flat, dims):
    """Per-mode squared slice sums via the 2-D rowsum kernel.

    For mode i with dims = (a, d_i, b) split: reshape the gradient to
    ``(a * d_i, b)``, rowsum-square (the O(d) heavy pass), then fold the
    leading ``a`` copies with a cheap ``(a, d_i)`` sum.
    """
    p = len(dims)
    out = []
    for i in range(p):
        a = 1
        for d in dims[:i]:
            a *= d
        b = 1
        for d in dims[i + 1 :]:
            b *= d
        di = dims[i]
        if b == 1:
            # mode is innermost: rows are already (a, d_i) columns
            per_row = rowsum_sq(jnp.reshape(g_flat, (a * di, 1)))
        else:
            per_row = rowsum_sq(jnp.reshape(g_flat, (a * di, b)))
        out.append(jnp.sum(jnp.reshape(per_row, (a, di)), axis=0))
    return out


def et_apply_flat(x_flat, g_flat, prod_flat, lr, eps: float, p: int,
                  *, block: int = _BLOCK_TARGET_FLAT):
    """Fused Algorithm-1 update on flat vectors:

    ``out = x - lr * g * (eps + prod) ** (-1/(2p))``

    `prod_flat` is the materialized rank-one product ``prod_i S_i[I_i]``
    (built by `kron_chain`); `lr` is a traced scalar (the schedule lives in
    rust). One read of x/g/prod, one write of out.
    """
    (n,) = x_flat.shape
    bn = _divisor_block(n, block)
    inv_exp = -1.0 / (2.0 * p)

    def kernel(x_ref, g_ref, prod_ref, lr_ref, o_ref):
        delta = jnp.power(eps + prod_ref[...], inv_exp)
        o_ref[...] = x_ref[...] - lr_ref[0] * g_ref[...] * delta

    return pl.pallas_call(
        kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x_flat.dtype),
        interpret=True,
    )(x_flat, g_flat, prod_flat, jnp.reshape(lr, (1,)))


def et_apply_2d(x, g, sr, sc, lr, eps: float,
                *, block_rows: int = _BLOCK_TARGET_ROWS,
                block_cols: int = _BLOCK_TARGET_COLS):
    """p=2 fused update without materializing the product vector:

    ``out[i,j] = x[i,j] - lr * g[i,j] * (eps + sr[i]*sc[j]) ** (-1/4)``
    """
    m, n = x.shape
    bm = _divisor_block(m, block_rows)
    bn = _divisor_block(n, block_cols)

    def kernel(x_ref, g_ref, sr_ref, sc_ref, lr_ref, o_ref):
        denom = eps + sr_ref[...][:, None] * sc_ref[...][None, :]
        delta = jnp.power(denom, -0.25)
        o_ref[...] = x_ref[...] - lr_ref[0] * g_ref[...] * delta

    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, g, sr, sc, jnp.reshape(lr, (1,)))


def kron_chain(sums):
    """Materialize ``prod_i S_i[I_i]`` as a flat length-d vector by repeated
    outer products (log-p doublings, ~2d total work)."""
    prod = sums[0]
    for s in sums[1:]:
        prod = (prod[:, None] * s[None, :]).reshape(-1)
    return prod


@functools.partial(jax.jit, static_argnames=("dims", "eps", "p"))
def et_group_update(x_flat, g_flat, sums, lr, *, dims, eps: float, p: int):
    """Convenience jit wrapper used by tests: full slice-sum + apply for one
    group, given pre-accumulated sums."""
    del dims
    prod = kron_chain(list(sums))
    return et_apply_flat(x_flat, g_flat, prod, lr, eps, p)

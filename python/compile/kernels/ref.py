"""Pure-jnp reference oracles for the Pallas kernels (Layer 1 correctness).

Everything here is straight-line jnp with no tiling tricks — the simplest
possible statement of Algorithm 1's two compute hot-spots:

* ``slice_sq_sums``: per-mode sums of squared gradient entries over tensor
  slices (Algorithm 1, line 6).
* ``et_step_sizes`` / ``et_apply``: the rank-one inverse-2p-root
  preconditioner (Algorithm 1, lines 7-8).

The pytest + hypothesis suites assert the Pallas kernels match these on
shape/value sweeps, and ``aot.py`` embeds golden outputs for the rust
cross-checks.
"""

from __future__ import annotations

import jax.numpy as jnp


def slice_sq_sums(g, dims):
    """Per-mode squared slice sums of ``g`` reshaped to ``dims``.

    Returns a list of arrays, one per mode i with shape (dims[i],):
    ``S[i][j] = sum_{I: I_i = j} g[I]^2``.
    """
    t = jnp.reshape(g, dims)
    sq = t * t
    p = len(dims)
    return [jnp.sum(sq, axis=tuple(a for a in range(p) if a != i)) for i in range(p)]


def et_step_sizes(sums, eps):
    """delta[I] = (eps + prod_i S[i][I_i]) ** (-1/(2p)), flattened."""
    p = len(sums)
    prod = sums[0]
    for i in range(1, p):
        prod = prod[..., None] * sums[i]
    return jnp.power(eps + prod, -1.0 / (2.0 * p)).reshape(-1)


def et_apply(g, sums, eps):
    """Preconditioned gradient ``delta * g`` (flat, same length as g)."""
    delta = et_step_sizes(sums, eps)
    return jnp.reshape(g, (-1,)) * delta


def et_update(x, g, sums, eps, lr):
    """Full Algorithm 1 inner update given *already accumulated* sums."""
    return jnp.reshape(x, (-1,)) - lr * et_apply(g, sums, eps)


def rowsum_sq(x):
    """Row sums of squares of a 2-D array: out[i] = sum_j x[i,j]^2."""
    return jnp.sum(x * x, axis=1)


def colsum_sq(x):
    """Column sums of squares of a 2-D array: out[j] = sum_i x[i,j]^2."""
    return jnp.sum(x * x, axis=0)


def et_apply_2d(g, sr, sc, eps):
    """p=2 fused preconditioner apply:
    out[i,j] = g[i,j] * (eps + sr[i]*sc[j]) ** (-1/4).
    """
    denom = eps + sr[:, None] * sc[None, :]
    return g * jnp.power(denom, -0.25)

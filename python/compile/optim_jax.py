"""Layer 2: optimizer updates in JAX — one fused train step per
(model, optimizer) pair gets AOT-lowered by ``aot.py``.

The extreme-tensoring path calls the Layer-1 Pallas kernels
(`mode_slice_sums` for the reduction, `et_apply_2d` / `et_apply_flat` for
the fused update), so the kernels lower into the same HLO the rust runtime
executes. Baselines (SGD/AdaGrad/Adam/Adafactor) are plain jnp.

Update rules intentionally match ``rust/src/optim/`` scalar-for-scalar:
the cross-layer golden tests diff a compiled artifact step against the
rust oracle.
"""

from __future__ import annotations

import math

import jax.numpy as jnp

from . import planner
from .kernels import extreme_tensoring as ek

# ---------------------------------------------------------------------------
# state-spec construction (shared with aot.py's manifest writer)
# ---------------------------------------------------------------------------


def state_specs(kind: str, param_specs):
    """Ordered optimizer-state (name, shape) for ``kind`` over the model's
    parameter specs. Empty for SGD."""
    out = []
    for name, shape, _init, _scale in param_specs:
        numel = math.prod(shape)
        if kind == "sgd":
            continue
        elif kind == "adagrad":
            out.append((f"{name}.acc", tuple(shape)))
        elif kind == "adam":
            out.append((f"{name}.m", tuple(shape)))
            out.append((f"{name}.v", tuple(shape)))
        elif kind == "adafactor":
            nat = planner.natural_dims(shape)
            if len(nat) >= 2:
                rows = math.prod(nat[:-1])
                out.append((f"{name}.r", (rows,)))
                out.append((f"{name}.c", (nat[-1],)))
            else:
                out.append((f"{name}.acc", tuple(shape)))
        elif kind.startswith("et") and kind != "etinf":
            level = int(kind[2:])
            dims = planner.plan(shape, level)
            for i, d in enumerate(dims):
                out.append((f"{name}.s{i}", (d,)))
        elif kind == "etinf":
            out.append((f"{name}.s", (1,)))
        else:
            raise ValueError(f"unknown optimizer kind '{kind}'")
        del numel
    return out


# ---------------------------------------------------------------------------
# update rules
# ---------------------------------------------------------------------------


def _et_group_update(x, g, sums, dims, lr, step, eps, beta2):
    """Algorithm 1 for one parameter group. ``sums`` are this group's
    accumulator vectors (manifest order); returns (new_x, new_sums).

    With ``beta2`` set (the decayed Adam/RMSprop analogue, paper remark 1),
    the accumulators are EMAs and the step is rescaled by the Adam-style
    sqrt bias correction — matching
    ``SliceAccumulators::apply_update_bias_corrected`` on the rust side.
    """
    p = len(dims)
    g_flat = jnp.reshape(g, (-1,))
    fresh = ek.mode_slice_sums(g_flat, tuple(dims))  # L1 Pallas reduction
    if beta2 is None:
        new_sums = [s + f for s, f in zip(sums, fresh)]
        lr_eff = lr
    else:
        new_sums = [beta2 * s + (1.0 - beta2) * f for s, f in zip(sums, fresh)]
        corr = 1.0 - jnp.power(jnp.float32(beta2), step)
        lr_eff = lr * jnp.sqrt(corr)
    if p == 2 and len(x.shape) == 2 and tuple(x.shape) == tuple(dims):
        new_x = ek.et_apply_2d(x, g, new_sums[0], new_sums[1], lr_eff, eps)
    else:
        prod = ek.kron_chain(new_sums)
        new_flat = ek.et_apply_flat(
            jnp.reshape(x, (-1,)), g_flat, prod, lr_eff, eps, p
        )
        new_x = jnp.reshape(new_flat, x.shape)
    return new_x, new_sums


def apply_updates(kind: str, param_specs, params, grads, opt_state, lr, step,
                  *, eps: float = 1e-8, beta1: float = 0.9,
                  beta2: float = 0.999, et_beta2=None):
    """Apply one optimizer step. ``opt_state`` is the flat ordered list from
    ``state_specs``; returns (new_params, new_opt_state).

    ``lr`` and ``step`` are traced f32 scalars supplied by the rust
    coordinator each step (L3 owns the schedule).
    """
    new_params = []
    new_state = []
    si = 0  # opt_state cursor

    for (name, shape, _i, _s), x, g in zip(param_specs, params, grads):
        if kind == "sgd":
            new_params.append(x - lr * g)

        elif kind == "adagrad":
            acc = opt_state[si]; si += 1
            acc = acc + g * g
            new_params.append(x - lr * g / jnp.sqrt(eps + acc))
            new_state.append(acc)

        elif kind == "adam":
            m = opt_state[si]; v = opt_state[si + 1]; si += 2
            m = beta1 * m + (1.0 - beta1) * g
            v = beta2 * v + (1.0 - beta2) * g * g
            bc1 = 1.0 - jnp.power(jnp.float32(beta1), step)
            bc2 = 1.0 - jnp.power(jnp.float32(beta2), step)
            new_params.append(x - lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps))
            new_state.append(m)
            new_state.append(v)

        elif kind == "adafactor":
            nat = planner.natural_dims(shape)
            if len(nat) >= 2:
                rows = math.prod(nat[:-1])
                cols = nat[-1]
                r = opt_state[si]; c = opt_state[si + 1]; si += 2
                g2 = jnp.reshape(g, (rows, cols))
                g2sq = g2 * g2
                # cumulative (AdaGrad-style) sums, matching rust beta2=None
                r = r + jnp.mean(g2sq, axis=1)
                c = c + jnp.mean(g2sq, axis=0)
                mean_r = jnp.mean(r)
                vhat = (r / mean_r)[:, None] * c[None, :]
                upd = g2 / jnp.sqrt(vhat + eps)
                new_params.append(x - lr * jnp.reshape(upd, x.shape))
                new_state.append(r)
                new_state.append(c)
            else:
                acc = opt_state[si]; si += 1
                acc = acc + g * g
                new_params.append(x - lr * g / jnp.sqrt(acc + eps))
                new_state.append(acc)

        elif kind.startswith("et") and kind != "etinf":
            level = int(kind[2:])
            dims = planner.plan(shape, level)
            p = len(dims)
            sums = opt_state[si : si + p]; si += p
            new_x, new_sums = _et_group_update(x, g, sums, dims, lr, step, eps, et_beta2)
            new_params.append(new_x)
            new_state.extend(new_sums)

        elif kind == "etinf":
            s = opt_state[si]; si += 1
            s = s + jnp.sum(g * g)
            new_params.append(x - lr * g / jnp.sqrt(eps + s))
            new_state.append(jnp.reshape(s, (1,)))

        else:
            raise ValueError(f"unknown optimizer kind '{kind}'")

    assert si == len(opt_state), f"state cursor {si} != {len(opt_state)}"
    return new_params, new_state

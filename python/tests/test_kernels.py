"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle, swept over
shapes and value regimes with hypothesis."""

import math

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import extreme_tensoring as ek
from compile.kernels import ref

settings.register_profile("kernels", max_examples=25, deadline=None)
settings.load_profile("kernels")


def _rand(shape, seed, style="normal"):
    rng = np.random.default_rng(seed)
    if style == "normal":
        x = rng.normal(size=shape)
    elif style == "sparse":
        x = rng.normal(size=shape) * (rng.random(shape) < 0.1)
    else:  # wide dynamic range
        x = rng.normal(size=shape) * 10.0 ** rng.uniform(-4, 3, size=shape)
    return jnp.asarray(x.astype(np.float32))


# ---------------------------------------------------------------------------
# rowsum_sq
# ---------------------------------------------------------------------------


@given(m=st.integers(1, 65), n=st.integers(1, 130), seed=st.integers(0, 2**31),
       style=st.sampled_from(["normal", "sparse", "wide"]))
def test_rowsum_sq_matches_ref(m, n, seed, style):
    x = _rand((m, n), seed, style)
    got = ek.rowsum_sq(x)
    want = ref.rowsum_sq(x)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-6)


def test_rowsum_sq_tiled_path():
    # force multi-tile grid in both dimensions
    x = _rand((64, 128), 7)
    got = ek.rowsum_sq(x, block_rows=16, block_cols=32)
    np.testing.assert_allclose(got, ref.rowsum_sq(x), rtol=1e-4)


def test_divisor_block():
    assert ek._divisor_block(512, 256) == 256
    assert ek._divisor_block(100, 30) == 25
    assert ek._divisor_block(13, 8) == 1  # prime > target
    assert ek._divisor_block(8, 256) == 8


# ---------------------------------------------------------------------------
# mode_slice_sums
# ---------------------------------------------------------------------------


@given(dims=st.lists(st.integers(1, 9), min_size=1, max_size=4),
       seed=st.integers(0, 2**31))
def test_mode_slice_sums_matches_ref(dims, seed):
    dims = tuple(dims)
    n = math.prod(dims)
    g = _rand((n,), seed)
    got = ek.mode_slice_sums(g, dims)
    want = ref.slice_sq_sums(g, dims)
    assert len(got) == len(dims)
    for i, (a, b) in enumerate(zip(got, want)):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=1e-6,
                                   err_msg=f"mode {i} of dims {dims}")


def test_mode_slice_sums_conservation():
    # sum of each mode's buckets == total sum of squares
    dims = (8, 4, 16)
    g = _rand((math.prod(dims),), 3)
    total = float(jnp.sum(g * g))
    for s in ek.mode_slice_sums(g, dims):
        assert abs(float(jnp.sum(s)) - total) < 1e-3 * total


# ---------------------------------------------------------------------------
# fused applies
# ---------------------------------------------------------------------------


@given(dims=st.lists(st.integers(2, 8), min_size=1, max_size=4),
       seed=st.integers(0, 2**31), lr=st.floats(1e-4, 1.0))
def test_et_apply_flat_matches_ref(dims, seed, lr):
    dims = tuple(dims)
    n = math.prod(dims)
    g = _rand((n,), seed)
    x = _rand((n,), seed + 1)
    sums = ref.slice_sq_sums(g, dims)
    prod = ek.kron_chain(list(sums))
    got = ek.et_apply_flat(x, g, prod, jnp.float32(lr), 1e-8, len(dims))
    want = ref.et_update(x, g, sums, 1e-8, lr)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=1e-6)


@given(m=st.integers(2, 48), n=st.integers(2, 96), seed=st.integers(0, 2**31))
def test_et_apply_2d_matches_ref(m, n, seed):
    g = _rand((m, n), seed)
    x = _rand((m, n), seed + 1)
    sr, sc = ref.rowsum_sq(g), ref.colsum_sq(g)
    got = ek.et_apply_2d(x, g, sr, sc, jnp.float32(0.2), 1e-8)
    want = x - 0.2 * ref.et_apply_2d(g, sr, sc, 1e-8)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=1e-6)


def test_kron_chain_order_and_values():
    a = jnp.asarray([1.0, 2.0])
    b = jnp.asarray([3.0, 5.0])
    got = ek.kron_chain([a, b])
    np.testing.assert_allclose(got, [3.0, 5.0, 6.0, 10.0])


def test_p1_reduces_to_adagrad():
    # With p=1 the ET update is exactly AdaGrad's.
    n = 33
    g = _rand((n,), 11)
    x = _rand((n,), 12)
    sums = ref.slice_sq_sums(g, (n,))
    got = ek.et_apply_flat(x, g, ek.kron_chain(list(sums)), jnp.float32(0.1),
                           1e-8, 1)
    want = x - 0.1 * g / jnp.sqrt(1e-8 + g * g)
    np.testing.assert_allclose(got, want, rtol=1e-4)


def test_lemma_4_3_underestimate():
    # ET per-coordinate rates never exceed AdaGrad's (small eps).
    dims = (6, 7)
    n = math.prod(dims)
    g = _rand((n,), 5)
    sums = ref.slice_sq_sums(g, dims)
    delta_et = ref.et_step_sizes(sums, 1e-10)
    delta_ada = jnp.power(1e-10 + g * g, -0.5)
    assert bool(jnp.all(delta_et <= delta_ada * (1.0 + 1e-3)))

"""AOT pipeline checks: manifests are consistent with the lowered HLO, HLO
text parses, and the caching layer behaves."""

import json
import math
import pathlib
import re

import pytest

from compile import aot, optim_jax
from compile import model as lm_mod

ART = pathlib.Path(__file__).resolve().parents[2] / "artifacts"

pytestmark = pytest.mark.skipif(
    not (ART / "lm_micro_et2.json").exists(),
    reason="artifacts not built (run `make artifacts`)",
)


def _manifest(name):
    return json.loads((ART / f"{name}.json").read_text())


def _entry_param_count(name):
    text = (ART / f"{name}.hlo.txt").read_text()
    entry = text[text.index("ENTRY") :]
    body = entry[: entry.index("\n}")]
    return len(set(re.findall(r"parameter\((\d+)\)", body)))


@pytest.mark.parametrize("name", ["lm_micro_et1", "lm_micro_et2", "lm_micro_et3",
                                  "lm_micro_adagrad", "lm_micro_adam",
                                  "lm_micro_adafactor", "lm_micro_sgd",
                                  "lm_micro_etinf"])
def test_manifest_arity_matches_hlo(name):
    m = _manifest(name)
    want = (len(m["params"]) + len(m["opt_state"]) + len(m["data_inputs"])
            + len(m["extra_inputs"]))
    assert _entry_param_count(name) == want


def test_eval_manifest_arity():
    m = _manifest("lm_micro_eval")
    want = len(m["params"]) + len(m["data_inputs"])
    assert _entry_param_count("lm_micro_eval") == want


def test_opt_state_shapes_match_state_specs():
    m = _manifest("lm_micro_et2")
    cfg = aot.LM_CONFIGS["lm_micro"]
    pspecs = lm_mod.param_specs(cfg)
    want = optim_jax.state_specs("et2", pspecs)
    got = [(s["name"], tuple(s["shape"])) for s in m["opt_state"]]
    assert got == [(n, tuple(s)) for n, s in want]


def test_et_memory_column_is_sublinear():
    cfg = aot.LM_CONFIGS["lm_micro"]
    total = sum(math.prod(s) for _, s, _, _ in lm_mod.param_specs(cfg))
    for kind, bound in [("et1", 0.2), ("et2", 0.05), ("et3", 0.04)]:
        m = _manifest(f"lm_micro_{kind}")
        scalars = m["optimizer"]["state_scalars"]
        assert scalars < total * bound, f"{kind}: {scalars} vs {total}"


def test_hlo_text_has_tuple_root():
    text = (ART / "lm_micro_et2.hlo.txt").read_text()
    assert "ROOT" in text and "tuple(" in text


def test_source_hash_marks_current():
    src = aot._source_hash()
    assert aot._is_current(ART, "lm_micro_et2", src)
    assert not aot._is_current(ART, "lm_micro_et2", "bogus")
    assert not aot._is_current(ART, "no_such_artifact", src)


def test_golden_fixture_wellformed():
    g = json.loads((ART / "golden" / "lm_micro_et2_steps.json").read_text())
    assert g["optimizer"] == "et2"
    assert len(g["losses"]) == g["steps"] == 2
    assert g["losses"][1] < g["losses"][0]  # training reduces memorized loss
    cfg = aot.LM_CONFIGS["lm_micro"]
    assert len(g["tokens"]) == cfg.rows * cfg.seq
    pspecs = lm_mod.param_specs(cfg)
    assert [p["name"] for p in g["param_init"]] == [n for n, *_ in pspecs]

"""Layer-2 optimizer updates vs plain numpy reference implementations."""

import math

import jax.numpy as jnp
import numpy as np
import pytest

from compile import optim_jax, planner

PSPECS = [
    ("w", (6, 8), "normal", 0.1),
    ("b", (8,), "zeros", 0.0),
    ("conv", (4, 2, 3, 3), "normal", 0.1),
]


def _params_grads(seed=0):
    rng = np.random.default_rng(seed)
    params = [jnp.asarray(rng.normal(size=s).astype(np.float32) * 0.1)
              for _, s, _, _ in PSPECS]
    grads = [jnp.asarray(rng.normal(size=s).astype(np.float32))
             for _, s, _, _ in PSPECS]
    return params, grads


def _zeros_state(kind):
    return [jnp.zeros(s, jnp.float32) for _, s in optim_jax.state_specs(kind, PSPECS)]


@pytest.mark.parametrize("kind", ["sgd", "adagrad", "adam", "adafactor",
                                  "et1", "et2", "et3", "etinf"])
def test_state_specs_and_update_shapes(kind):
    params, grads = _params_grads()
    state = _zeros_state(kind)
    new_p, new_s = optim_jax.apply_updates(
        kind, PSPECS, params, grads, state, jnp.float32(0.1), jnp.float32(1.0))
    assert len(new_p) == len(params)
    assert len(new_s) == len(state)
    for p, np_ in zip(params, new_p):
        assert p.shape == np_.shape
        assert bool(jnp.all(jnp.isfinite(np_)))
    for s, ns in zip(state, new_s):
        assert s.shape == ns.shape


def test_sgd_exact():
    params, grads = _params_grads()
    new_p, _ = optim_jax.apply_updates(
        "sgd", PSPECS, params, grads, [], jnp.float32(0.5), jnp.float32(1.0))
    for p, g, np_ in zip(params, grads, new_p):
        np.testing.assert_allclose(np_, p - 0.5 * g, rtol=1e-6)


def test_adagrad_exact():
    params, grads = _params_grads()
    state = _zeros_state("adagrad")
    new_p, new_s = optim_jax.apply_updates(
        "adagrad", PSPECS, params, grads, state, jnp.float32(0.5), jnp.float32(1.0))
    for p, g, np_ in zip(params, grads, new_p):
        want = p - 0.5 * g / jnp.sqrt(1e-8 + g * g)
        np.testing.assert_allclose(np_, want, rtol=1e-5)
    for g, s in zip(grads, new_s):
        np.testing.assert_allclose(s, g * g, rtol=1e-6)


def test_adam_first_step_is_lr_sized():
    params, grads = _params_grads()
    state = _zeros_state("adam")
    new_p, _ = optim_jax.apply_updates(
        "adam", PSPECS, params, grads, state, jnp.float32(0.01), jnp.float32(1.0),
        eps=1e-12)
    for p, g, np_ in zip(params, grads, new_p):
        step = np.abs(np.asarray(np_ - p))
        nz = np.abs(np.asarray(g)) > 1e-6
        np.testing.assert_allclose(step[nz], 0.01, rtol=1e-3)


def test_et1_matches_adagrad_on_vectors():
    # For a 1-D parameter, ET1 has p=1 => identical to AdaGrad.
    pspecs = [("v", (16,), "zeros", 0.0)]
    rng = np.random.default_rng(1)
    p = [jnp.asarray(rng.normal(size=(16,)).astype(np.float32))]
    g = [jnp.asarray(rng.normal(size=(16,)).astype(np.float32))]
    sa = [jnp.zeros(s, jnp.float32) for _, s in optim_jax.state_specs("adagrad", pspecs)]
    se = [jnp.zeros(s, jnp.float32) for _, s in optim_jax.state_specs("et1", pspecs)]
    pa, _ = optim_jax.apply_updates("adagrad", pspecs, p, g, sa,
                                    jnp.float32(0.1), jnp.float32(1.0))
    pe, _ = optim_jax.apply_updates("et1", pspecs, p, g, se,
                                    jnp.float32(0.1), jnp.float32(1.0))
    np.testing.assert_allclose(pa[0], pe[0], rtol=1e-4)


def test_et_state_sizes_shrink_with_level():
    sizes = {}
    for kind in ["adagrad", "et1", "et2", "et3"]:
        specs = optim_jax.state_specs(kind, PSPECS)
        sizes[kind] = sum(math.prod(s) for _, s in specs)
    assert sizes["et1"] <= sizes["adagrad"]
    assert sizes["et2"] <= sizes["et1"]
    assert sizes["et3"] <= sizes["et2"]


def test_etinf_one_scalar_per_group():
    specs = optim_jax.state_specs("etinf", PSPECS)
    assert len(specs) == len(PSPECS)
    assert all(s == (1,) for _, s in specs)


def test_et_decay_bias_correction_shrinks_early_steps():
    # With beta2 decay + bias correction, the first-step update magnitude
    # matches the non-decayed one (corr cancels the (1-b2) scaling).
    pspecs = [("w", (8, 8), "zeros", 0.0)]
    rng = np.random.default_rng(2)
    p = [jnp.zeros((8, 8), jnp.float32)]
    g = [jnp.asarray(rng.normal(size=(8, 8)).astype(np.float32))]
    s0 = [jnp.zeros(s, jnp.float32) for _, s in optim_jax.state_specs("et1", pspecs)]
    p_plain, _ = optim_jax.apply_updates("et1", pspecs, p, g, s0,
                                         jnp.float32(0.1), jnp.float32(1.0),
                                         et_beta2=None)
    p_decay, _ = optim_jax.apply_updates("et1", pspecs, p, g, s0,
                                         jnp.float32(0.1), jnp.float32(1.0),
                                         et_beta2=0.99)
    # plain: S=rowsum; decayed: S=(1-b2)rowsum, lr_eff=lr*sqrt(1-b2)
    # => identical first step.
    np.testing.assert_allclose(p_plain[0], p_decay[0], rtol=1e-3)


def test_planner_matches_rust_tables():
    # Table B.1 rows
    assert sorted(planner.plan((512, 512), 2)) == sorted([16, 32, 16, 32])
    assert sorted(planner.plan((2000, 512), 3)) == sorted([5, 8, 5, 10, 4, 4, 4, 8])
    # Table 3 rows
    assert sorted(planner.plan((64, 3, 3, 3), 2)) == sorted([8, 8, 3, 9])
    assert sorted(planner.plan((512, 128, 1, 1), 3)) == sorted([8, 4, 4, 4, 4, 4, 8])
    # product invariant
    for shape in [(7, 13), (100,), (12, 34, 2)]:
        for lvl in (1, 2, 3):
            assert math.prod(planner.plan(shape, lvl)) == math.prod(shape)

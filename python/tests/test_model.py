"""Layer-2 model correctness: shapes, masking, loss properties, gradients."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import cnn as cnn_mod
from compile import model as lm_mod

CFG = lm_mod.LmConfig(vocab=50, d_model=16, layers=2, heads=2, d_ff=32, rows=2, seq=12)


def _params():
    return lm_mod.init_params(CFG, jax.random.PRNGKey(0))


def _tokens(seed=0, lo=1):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(lo, CFG.vocab, size=(CFG.rows, CFG.seq)).astype(np.int32))


def test_logits_shape():
    logits = lm_mod.logits_fn(_params(), _tokens(), CFG)
    assert logits.shape == (CFG.rows, CFG.seq, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_untrained_loss_near_uniform():
    total, count = lm_mod.nll_fn(_params(), _tokens(), CFG)
    mean = float(total) / float(count)
    assert abs(mean - np.log(CFG.vocab)) < 1.0


def test_pad_targets_masked():
    params = _params()
    tok = np.asarray(_tokens(3))
    tok_pad = tok.copy()
    tok_pad[:, -4:] = lm_mod.PAD_ID  # pad the tail
    _, count_full = lm_mod.nll_fn(params, jnp.asarray(tok), CFG)
    _, count_pad = lm_mod.nll_fn(params, jnp.asarray(tok_pad), CFG)
    assert float(count_pad) < float(count_full)
    # exactly 4 targets per row masked
    assert float(count_full) - float(count_pad) == 2 * 4


def test_causality():
    """Changing a future token must not change past logits."""
    params = _params()
    tok = np.asarray(_tokens(1))
    logits_a = lm_mod.logits_fn(params, jnp.asarray(tok), CFG)
    tok_b = tok.copy()
    tok_b[:, -1] = (tok_b[:, -1] % (CFG.vocab - 1)) + 1  # change last token
    logits_b = lm_mod.logits_fn(params, jnp.asarray(tok_b), CFG)
    np.testing.assert_allclose(logits_a[:, :-1], logits_b[:, :-1], atol=1e-5)


def test_grads_cover_all_params_and_are_finite():
    params = _params()
    loss, grads = lm_mod.loss_and_grads(params, _tokens(2), CFG)
    assert np.isfinite(float(loss))
    specs = lm_mod.param_specs(CFG)
    assert len(grads) == len(specs)
    nonzero = 0
    for (name, shape, _, _), g in zip(specs, grads):
        assert g.shape == tuple(shape), name
        assert bool(jnp.all(jnp.isfinite(g))), name
        if float(jnp.max(jnp.abs(g))) > 0:
            nonzero += 1
    assert nonzero >= len(specs) - 1  # everything but maybe a bias gets grad


def test_one_sgd_step_reduces_loss_on_fixed_batch():
    params = _params()
    tok = _tokens(4)
    loss0, grads = lm_mod.loss_and_grads(params, tok, CFG)
    params2 = [p - 0.5 * g for p, g in zip(params, grads)]
    loss1 = lm_mod.mean_loss_fn(params2, tok, CFG)
    assert float(loss1) < float(loss0)


# ---------------------------------------------------------------------------
# CNN
# ---------------------------------------------------------------------------

CCFG = cnn_mod.CnnConfig(classes=4, batch=8)


def _cnn_data(seed=0):
    rng = np.random.default_rng(seed)
    imgs = jnp.asarray(rng.normal(size=(CCFG.batch, 3, 32, 32)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, CCFG.classes, size=(CCFG.batch,)).astype(np.int32))
    return imgs, labels


def test_cnn_shapes_and_loss():
    params = cnn_mod.init_params(CCFG, jax.random.PRNGKey(1))
    imgs, labels = _cnn_data()
    logits = cnn_mod.logits_fn(params, imgs, CCFG)
    assert logits.shape == (CCFG.batch, CCFG.classes)
    total, count = cnn_mod.nll_fn(params, imgs, labels, CCFG)
    assert abs(float(total) / float(count) - np.log(CCFG.classes)) < 1.0


def test_cnn_error_count():
    params = cnn_mod.init_params(CCFG, jax.random.PRNGKey(2))
    imgs, labels = _cnn_data(1)
    wrong, count = cnn_mod.error_count_fn(params, imgs, labels, CCFG)
    assert 0.0 <= float(wrong) <= float(count)
    assert float(count) == CCFG.batch


def test_cnn_learns_fixed_batch():
    params = cnn_mod.init_params(CCFG, jax.random.PRNGKey(3))
    imgs, labels = _cnn_data(2)
    loss0, _ = cnn_mod.loss_and_grads(params, imgs, labels, CCFG)
    for _ in range(30):
        _, grads = cnn_mod.loss_and_grads(params, imgs, labels, CCFG)
        params = [p - 0.1 * g for p, g in zip(params, grads)]
    loss1 = cnn_mod.mean_loss_fn(params, imgs, labels, CCFG)
    assert float(loss1) < float(loss0) * 0.7

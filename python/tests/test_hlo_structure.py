"""L2 performance-structure checks on the lowered HLO: the properties the
§Perf plan requires must be visible in the artifact text, not assumed.

- the ET train step is ONE fused module (no python round trips possible);
- the fused preconditioner apply exists as elementwise ops over the
  parameter tensors (power/multiply/subtract), i.e. Algorithm 1 lowered
  into the same HLO as fwd/bwd;
- module size scales sanely (no accidental unrolling explosions);
- ET modules do not materialize full-size second-moment buffers: their
  output arity and state shapes stay the manifest's slice vectors.
"""

import json
import pathlib
import re

import pytest

ART = pathlib.Path(__file__).resolve().parents[2] / "artifacts"

pytestmark = pytest.mark.skipif(
    not (ART / "lm_tiny_et2.hlo.txt").exists(),
    reason="artifacts not built (run `make artifacts`)",
)


def _text(name):
    return (ART / f"{name}.hlo.txt").read_text()


def _manifest(name):
    return json.loads((ART / f"{name}.json").read_text())


def _entry_root_arity(text):
    """Output-tuple arity of the ENTRY computation (inner fused
    computations have their own ROOT tuples; take the ENTRY block's)."""
    entry = text[text.index("ENTRY ") :]
    root = re.search(r"ROOT [^=]+= \(([^)]*)\) tuple", entry)
    assert root is not None, "no ENTRY root tuple"
    # Count typed elements (layout braces `{1,0}` contain commas, so a
    # plain split would overcount): each element is `dtype[dims]{layout}`.
    return len(re.findall(r"\w+\[", root.group(1)))


def test_single_entry_module():
    text = _text("lm_tiny_et2")
    assert text.count("ENTRY ") == 1


def test_et_apply_ops_present():
    # The fused apply needs power (the -1/2p root), multiply and subtract
    # over f32 tensors.
    text = _text("lm_tiny_et2")
    assert re.search(r"\bpower\(", text) or "power" in text
    assert "multiply" in text and "subtract" in text


def test_et_state_stays_sublinear_in_hlo():
    # No f32 tensor the size of a full second-moment accumulator should be
    # produced as an *output* of an ET module beyond the params themselves:
    # output tuple arity == 1 + params + slice-vector states.
    m = _manifest("lm_tiny_et2")
    arity = _entry_root_arity(_text("lm_tiny_et2"))
    assert arity == 1 + len(m["params"]) + len(m["opt_state"])


def test_module_sizes_do_not_explode():
    # Sanity bound: unrolled layers at this scale should keep modules under
    # a few MB of text; an accidental seq-length unroll would blow this up.
    for name in ["lm_tiny_et2", "lm_tiny_adam", "lm_big_et2", "cnn_et2"]:
        size = (ART / f"{name}.hlo.txt").stat().st_size
        assert size < 8_000_000, f"{name}: {size} bytes"


def test_et2_not_larger_than_adam_module():
    # interpret=True Pallas expands each kernel into explicit HLO loops, so
    # the ET module is larger than Adam's handful of elementwise ops —
    # measured ~6.4x at lm_tiny scale. Bound it at 10x so a structural
    # regression (e.g. accidental per-coordinate unrolling) still fails.
    et2 = (ART / "lm_tiny_et2.hlo.txt").stat().st_size
    adam = (ART / "lm_tiny_adam.hlo.txt").stat().st_size
    assert et2 < 10 * adam, f"et2 {et2} vs adam {adam}"


def test_grad_artifact_has_no_optimizer_state():
    m = _manifest("lm_tiny_grad")
    assert m["opt_state"] == []
    arity = _entry_root_arity(_text("lm_tiny_grad"))
    assert arity == 1 + len(m["params"])

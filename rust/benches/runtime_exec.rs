//! PJRT runtime dispatch costs on the micro artifacts: step latency, the
//! host<->device state round-trip (the decompose_tuple path — see
//! DESIGN.md §8), and eval dispatch. Separates runtime overhead from model
//! compute so the table1_step numbers can be attributed.

use extensor::runtime::{Client, DataArg, Engine};
use extensor::testing::bench::{bench, header};
use extensor::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    let dir = extensor::runtime::default_artifact_dir();
    if !dir.join("lm_micro_et2.json").exists() {
        eprintln!("artifacts not built — run `make artifacts` first");
        return Ok(());
    }
    let client = Client::cpu()?;
    header("runtime_exec (lm_micro: 70k params)");

    let mut rng = Pcg64::seeded(3);
    let tokens: Vec<i32> = (0..32).map(|_| 1 + rng.below(60) as i32).collect();

    for name in ["lm_micro_sgd", "lm_micro_et2", "lm_micro_adam"] {
        let engine = Engine::load(&client, &dir, name)?;
        let mut state = engine.init_state(1)?;
        let r = bench(&format!("train_step/{name}"), 5, 40, || {
            engine.train_step_tokens(&mut state, &tokens, 1e-3).unwrap();
        });
        r.report();
    }

    let eval = Engine::load(&client, &dir, "lm_micro_eval")?;
    let train = Engine::load(&client, &dir, "lm_micro_et2")?;
    let state = train.init_state(1)?;
    let r = bench("eval_step/lm_micro_eval", 5, 40, || {
        eval.eval_step(&state, &[DataArg::I32(&tokens)]).unwrap();
    });
    r.report();

    // compile cost (one-time per process, amortized across a run)
    let r = bench("load_and_compile/lm_micro_et2", 0, 3, || {
        std::hint::black_box(Engine::load(&client, &dir, "lm_micro_et2").unwrap());
    });
    r.report();

    // state init cost
    let engine = Engine::load(&client, &dir, "lm_micro_et2")?;
    let r = bench("init_state/lm_micro_et2", 2, 20, || {
        std::hint::black_box(engine.init_state(7).unwrap());
    });
    r.report();
    Ok(())
}

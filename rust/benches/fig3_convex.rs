//! Convex-experiment throughput (Figure 3's workload): full-batch
//! loss+gradient evaluation of the softmax regression substrate, and one
//! optimizer step per ET depth. Separates substrate cost (the gradient)
//! from preconditioner cost (the step) — at paper scale the gradient
//! dominates, which is why the paper can afford full-batch plots.

use extensor::convex::{ConvexConfig, ConvexDataset, SoftmaxRegression};
use extensor::optim::{self, GroupSpec, Hyper, Optimizer};
use extensor::tensoring::OptimizerKind;
use extensor::testing::bench::{bench, header};

fn main() -> anyhow::Result<()> {
    let cfg = ConvexConfig { n: 2000, d: 512, k: 10, cond: 1e4, householder: 8, seed: 1 };
    let ds = ConvexDataset::generate(&cfg);
    let obj = SoftmaxRegression::new(&ds);
    let idx: Vec<usize> = (0..ds.n).collect();
    let groups = vec![GroupSpec::new("w", &[cfg.k, cfg.d])];

    header(&format!("fig3_convex (n={}, d={}, k={})", cfg.n, cfg.d, cfg.k));

    let w = vec![0.01f32; obj.dim()];
    let mut grad = vec![0.0f32; obj.dim()];
    let r = bench("full_batch_loss_grad (vectorized)", 2, 10, || {
        std::hint::black_box(obj.loss_grad(&w, &idx, &mut grad));
    });
    r.report_with_rate((ds.n * obj.dim()) as f64, "elem/s");

    // The pre-optimization implementation (scalar f64 dot/axpy) is kept
    // here as the §Perf baseline so the before/after is measurable, not
    // anecdotal.
    let r = bench("full_batch_loss_grad (scalar-f64 ref)", 1, 5, || {
        std::hint::black_box(loss_grad_scalar(&ds, &w, &idx, &mut grad));
    });
    r.report_with_rate((ds.n * obj.dim()) as f64, "elem/s");

    let variants: Vec<(&str, Vec<usize>)> = vec![
        ("et_depth1 (10,512)", vec![10, 512]),
        ("et_depth2 (10,16,32)", vec![10, 16, 32]),
        ("et_depth3 (10,8,8,8)", vec![10, 8, 8, 8]),
    ];
    for (name, dims) in variants {
        let mut opt =
            optim::extreme::custom_et(&groups, vec![dims], 1e-8, None).expect("dims cover");
        let mut wv = vec![0.01f32; obj.dim()];
        let r = bench(&format!("step/{name}"), 3, 50, || {
            opt.step(0, &mut wv, &grad, 0.01).unwrap();
        });
        r.report_with_rate(obj.dim() as f64, "elem/s");
    }
    let mut ada = optim::build(OptimizerKind::AdaGrad, &groups, &Hyper::default());
    let mut wv = vec![0.01f32; obj.dim()];
    let r = bench("step/adagrad (full)", 3, 50, || {
        ada.step(0, &mut wv, &grad, 0.01).unwrap();
    });
    r.report_with_rate(obj.dim() as f64, "elem/s");
    Ok(())
}

/// Pre-optimization softmax-regression gradient: scalar loops with f64
/// `dot`/`axpy` helpers (what `SoftmaxRegression::loss_grad` shipped as
/// before the §Perf pass). Kept verbatim for the before/after measurement.
fn loss_grad_scalar(
    ds: &ConvexDataset,
    w: &[f32],
    idx: &[usize],
    grad: &mut [f32],
) -> f64 {
    use extensor::util::math::{axpy, dot, log_sum_exp};
    let (d, k) = (ds.d, ds.k);
    grad.iter_mut().for_each(|g| *g = 0.0);
    let mut logits = vec![0.0f32; k];
    let mut total = 0.0f64;
    let scale = 1.0 / idx.len().max(1) as f32;
    for &i in idx {
        let row = &ds.x[i * d..(i + 1) * d];
        for c in 0..k {
            logits[c] = dot(&w[c * d..(c + 1) * d], row) as f32;
        }
        let lse = log_sum_exp(&logits);
        let yi = ds.y[i] as usize;
        total += (lse - logits[yi]) as f64;
        for c in 0..k {
            let p = (logits[c] - lse).exp();
            let coef = (p - if c == yi { 1.0 } else { 0.0 }) * scale;
            if coef != 0.0 {
                axpy(coef, row, &mut grad[c * d..(c + 1) * d]);
            }
        }
    }
    total / idx.len().max(1) as f64
}

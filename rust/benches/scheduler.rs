//! Scheduler overhead and scaling: one fixed batch of convex jobs run
//! through `session::run_batch` at increasing worker counts. Measures the
//! end-to-end batch wall time — job execution plus queueing, admission,
//! and event plumbing — so regressions in the scheduler's coordination
//! cost show up directly. The jobs share one session-cached dataset, so
//! the sweep also exercises the cache under contention.

use extensor::convex::ConvexConfig;
use extensor::session::{
    run_batch, ConvexOpt, ConvexSpec, JobSpec, SchedulerOptions, Session,
};
use extensor::tensoring::OptimizerKind;
use extensor::testing::bench::{bench, header};

fn batch() -> Vec<JobSpec> {
    let data = ConvexConfig { n: 1000, d: 64, k: 4, cond: 1e3, householder: 2, seed: 11 };
    let kinds = [
        OptimizerKind::AdaGrad,
        OptimizerKind::Adam,
        OptimizerKind::Et(1),
        OptimizerKind::Et(2),
        OptimizerKind::Et(3),
        OptimizerKind::EtInf,
        OptimizerKind::Adafactor,
        OptimizerKind::RmsProp,
    ];
    kinds
        .iter()
        .enumerate()
        .map(|(i, &kind)| {
            JobSpec::convex(
                format!("bench{i}"),
                ConvexSpec {
                    data: data.clone(),
                    iters: 60,
                    lr: if kind == OptimizerKind::EtInf { 0.5 } else { 0.05 },
                    opt: ConvexOpt::Kind(kind),
                    ..ConvexSpec::default()
                },
            )
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    extensor::util::logging::set_verbosity(extensor::util::logging::Level::Warn);
    let specs = batch();
    header(&format!("scheduler — {}-job convex batch, workers sweep", specs.len()));
    for workers in [1usize, 2, 4, 8] {
        // One warm session per worker count: the dataset is synthesized in
        // the warmup iteration, so the timed iterations measure scheduling
        // + execution, not corpus synthesis.
        let session = Session::new();
        let opts = SchedulerOptions { workers, ..Default::default() };
        let r = bench(&format!("run_batch/workers={workers}"), 1, 5, || {
            let report = run_batch(&session, &specs, &opts).unwrap();
            assert!(report.failed().is_empty());
        });
        r.report_with_rate(specs.len() as f64, "jobs/s");
    }
    Ok(())
}

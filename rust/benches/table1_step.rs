//! End-to-end step latency per optimizer on the Table-1 workload
//! (lm_tiny): the perf counterpart of the paper's comparison. The claim
//! under test: extreme tensoring's fused preconditioner adds *negligible
//! step-time overhead* over SGD while AdaGrad/Adam pay for full
//! accumulators, and the hierarchy of optimizer-state sizes (printed
//! alongside) spans three orders of magnitude.

use extensor::runtime::{Client, Engine};
use extensor::testing::bench::{bench, fmt_ns, header};
use extensor::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    let dir = extensor::runtime::default_artifact_dir();
    if !dir.join("lm_tiny_sgd.json").exists() {
        eprintln!("artifacts not built — run `make artifacts` first");
        return Ok(());
    }
    let client = Client::cpu()?;
    header("table1_step (lm_tiny: 1M params, 512 tokens/step)");

    let mut rng = Pcg64::seeded(4);
    let tokens: Vec<i32> = (0..8 * 64).map(|_| 1 + rng.below(1900) as i32).collect();

    let mut baseline_ns = None;
    for kind in ["sgd", "adagrad", "adam", "adafactor", "et1", "et2", "et3", "etinf"] {
        let engine = Engine::load(&client, &dir, &format!("lm_tiny_{kind}"))?;
        let mut state = engine.init_state(1)?;
        let r = bench(&format!("train_step/{kind}"), 3, 15, || {
            engine.train_step_tokens(&mut state, &tokens, 1e-3).unwrap();
        });
        let opt_state = engine.manifest.total_opt_state();
        if kind == "sgd" {
            baseline_ns = Some(r.median_ns);
        }
        let overhead = baseline_ns
            .map(|b| format!("{:+.1}% vs sgd", (r.median_ns / b - 1.0) * 100.0))
            .unwrap_or_default();
        println!(
            "{:<24} {:>12} median  {:>10} opt-state floats   {}",
            r.name,
            fmt_ns(r.median_ns),
            opt_state,
            overhead
        );
    }
    println!("\ntokens/s at median: see values above (512 tokens per step)");
    Ok(())
}

//! Pure-rust optimizer hot-path throughput: elements/s of one full step per
//! optimizer kind on transformer-shaped groups. This is the L3-native
//! equivalent of the paper's "optimizer overhead" concern — ET's update
//! must stay bandwidth-bound and within a small factor of SGD.
//!
//! Three sections:
//!
//! * `loop/...` — the legacy shape: one `Box<dyn Optimizer>` virtual call
//!   per *group* per step (dense backend);
//! * `step_all/<kind>/<backend>` — one virtual call per *step*, for both
//!   the dense `f32` and the block-quantized `q8` state backend (the q8
//!   rows measure the decode/encode round trip through the reusable
//!   scratch);
//! * `apply/p<p>/<mode>/...` — the ET apply kernel in isolation, reference
//!   per-element walker vs the fused kernel (`tensoring::kernels`), per
//!   tensor order and eps mode. The PerFactor rows are the separable
//!   root-factor win (O(sum d_i) transcendentals instead of O(numel));
//!   the acceptance gate is >= 2x at p >= 2.
//!
//! Besides the human-readable report, the run emits a machine-readable
//! `BENCH_optim.json` (override with `BENCH_OPTIM_OUT`) — ns/element per
//! optimizer kind x tensor order x state backend plus steps/sec — which CI
//! uploads as an artifact so future PRs have a perf trajectory to compare
//! against (see EXPERIMENTS.md §Perf).

use extensor::optim::{self, GroupSpec, Hyper, Optimizer};
use extensor::tensoring::{kernels, plan, EpsMode, Level, OptimizerKind, StateBackend};
use extensor::testing::bench::{bench, header};
use extensor::util::json::Json;
use extensor::util::rng::Pcg64;

fn main() {
    let shapes: Vec<(&str, Vec<usize>)> = vec![
        ("embed", vec![2000, 512]),
        ("attn", vec![512, 512]),
        ("ff1", vec![512, 2048]),
        ("ln", vec![512]),
    ];
    let groups: Vec<GroupSpec> =
        shapes.iter().map(|(n, s)| GroupSpec::new(*n, s)).collect();
    let total: usize = groups.iter().map(|g| g.numel()).sum();

    let mut rng = Pcg64::seeded(1);
    let grads: Vec<Vec<f32>> = groups
        .iter()
        .map(|g| {
            let mut v = vec![0.0f32; g.numel()];
            rng.fill_normal(&mut v, 1.0);
            v
        })
        .collect();

    let mut records: Vec<Json> = Vec::new();

    header(&format!("optim_hot — one full step over {total} parameters"));
    let kinds = [
        OptimizerKind::Sgd,
        OptimizerKind::AdaGrad,
        OptimizerKind::Adam,
        OptimizerKind::Adafactor,
        OptimizerKind::Et(1),
        OptimizerKind::Et(2),
        OptimizerKind::Et(3),
        OptimizerKind::EtInf,
    ];
    for kind in kinds {
        // Per-group dynamic-dispatch loop (the pre-refactor driver shape),
        // dense backend only — it exists to show the dispatch overhead.
        let hyper = Hyper::default();
        let mut opt = optim::build(kind, &groups, &hyper);
        let mut params: Vec<Vec<f32>> =
            groups.iter().map(|g| vec![0.1f32; g.numel()]).collect();
        let r = bench(&format!("loop/{}", kind.name()), 3, 30, || {
            opt.next_step();
            for (gi, (p, g)) in params.iter_mut().zip(&grads).enumerate() {
                opt.step(gi, p, g, 1e-4).unwrap();
            }
        });
        r.report_with_rate(total as f64, "elem/s");
        records.push(step_record("loop", kind, &groups, StateBackend::DenseF32, &r, total));

        // Batched entry point: one dynamic dispatch for the whole step —
        // under both state backends.
        for backend in [StateBackend::DenseF32, StateBackend::q8()] {
            let hyper = Hyper { backend, ..Hyper::default() };
            let mut opt = optim::build(kind, &groups, &hyper);
            let mut params: Vec<Vec<f32>> =
                groups.iter().map(|g| vec![0.1f32; g.numel()]).collect();
            let r = bench(
                &format!("step_all/{}/{}", kind.name(), backend.name()),
                3,
                30,
                || {
                    opt.next_step();
                    opt.step_all(&mut params, &grads, 1e-4).unwrap();
                },
            );
            r.report_with_rate(total as f64, "elem/s");
            records.push(step_record("step_all", kind, &groups, backend, &r, total));
        }
    }

    header("ET apply kernel — reference walker vs fused kernel, per (p, eps mode)");
    let kernel_dims: Vec<Vec<usize>> = vec![
        vec![512, 512],
        vec![64, 64, 64],
        vec![32, 16, 32, 16],
        vec![4, 4, 4, 4, 4, 4, 4, 4],
    ];
    for dims in &kernel_dims {
        let p = dims.len();
        let n: usize = dims.iter().product();
        let mut g = vec![0.0f32; n];
        rng.fill_normal(&mut g, 1.0);
        let mut s: Vec<Vec<f32>> = dims.iter().map(|&d| vec![0.0f32; d]).collect();
        let mut scratch = kernels::Scratch::new();
        for _ in 0..3 {
            kernels::accumulate(dims, &mut s, None, &g, &mut scratch).unwrap();
        }
        for mode in [EpsMode::InsideProduct, EpsMode::PerFactor] {
            let mode_name = match mode {
                EpsMode::InsideProduct => "inside",
                EpsMode::PerFactor => "perfactor",
            };
            let mut x = vec![0.0f32; n];
            let r_ref = bench(&format!("apply/p{p}/{mode_name}/reference"), 3, 50, || {
                kernels::reference::apply(dims, &s, 1e-8, mode, None, 1, &mut x, &g, 1e-6);
            });
            r_ref.report_with_rate(n as f64, "elem/s");
            let mut x = vec![0.0f32; n];
            let r_ker = bench(&format!("apply/p{p}/{mode_name}/kernel"), 3, 50, || {
                kernels::apply(dims, &s, 1e-8, mode, None, 1, &mut x, &g, 1e-6, &mut scratch);
            });
            r_ker.report_with_rate(n as f64, "elem/s");
            let speedup = r_ref.median_ns / r_ker.median_ns.max(1.0);
            println!("{:<40} {speedup:>11.2}x", format!("  -> speedup p={p} {mode_name}"));
            for (variant, r) in [("reference", &r_ref), ("kernel", &r_ker)] {
                records.push(Json::obj(vec![
                    ("section", Json::str("kernel_apply")),
                    ("name", Json::str(format!("apply/p{p}/{mode_name}/{variant}"))),
                    ("p", Json::num(p as f64)),
                    ("eps_mode", Json::str(mode_name)),
                    ("variant", Json::str(variant)),
                    ("numel", Json::num(n as f64)),
                    ("ns_per_element", Json::num(r.median_ns / n as f64)),
                    ("elements_per_sec", Json::num(r.throughput(n as f64))),
                    ("speedup_vs_reference", Json::num(r_ref.median_ns / r.median_ns.max(1.0))),
                ]));
            }
        }
    }

    let out = Json::obj(vec![
        ("schema", Json::str("bench_optim/v1")),
        ("total_params", Json::num(total as f64)),
        ("records", Json::Arr(records)),
    ]);
    let path =
        std::env::var("BENCH_OPTIM_OUT").unwrap_or_else(|_| "BENCH_optim.json".to_string());
    match std::fs::write(&path, out.to_string_pretty()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
    println!(
        "(ET overhead vs SGD is the paper's 'negligible memory AND compute' claim;\n \
         loop-vs-step_all is the per-group dispatch overhead the batched API removes;\n \
         apply/*/kernel-vs-reference is the fused-kernel win — see EXPERIMENTS.md §Perf)"
    );
}

/// One machine-readable record for a full-step benchmark.
fn step_record(
    section: &str,
    kind: OptimizerKind,
    groups: &[GroupSpec],
    backend: StateBackend,
    r: &extensor::testing::bench::BenchResult,
    total: usize,
) -> Json {
    // The "tensor order" axis: the largest planned index order across
    // groups for ET kinds (deeper levels split into higher orders), 1
    // otherwise.
    let order = match kind {
        OptimizerKind::Et(level) => groups
            .iter()
            .map(|g| plan(&g.shape, Level::Et(level)).len())
            .max()
            .unwrap_or(1),
        _ => 1,
    };
    Json::obj(vec![
        ("section", Json::str("step")),
        ("name", Json::str(format!("{section}/{}/{}", kind.name(), backend.name()))),
        ("variant", Json::str(section)),
        ("kind", Json::str(kind.name())),
        ("backend", Json::str(backend.name())),
        ("max_index_order", Json::num(order as f64)),
        ("ns_per_element", Json::num(r.median_ns / total as f64)),
        ("elements_per_sec", Json::num(r.throughput(total as f64))),
        ("steps_per_sec", Json::num(1e9 / r.median_ns.max(1.0))),
    ])
}

//! Pure-rust optimizer hot-path throughput: elements/s of one full step per
//! optimizer kind on transformer-shaped groups. This is the L3-native
//! equivalent of the paper's "optimizer overhead" concern — ET's update
//! must stay bandwidth-bound and within a small factor of SGD.
//!
//! Two variants per kind measure the dispatch overhead the batched API
//! removes:
//!
//! * `loop/...` — the legacy shape: one `Box<dyn Optimizer>` virtual call
//!   per *group* per step;
//! * `step_all/...` — one virtual call per *step*; the per-group loop runs
//!   statically dispatched inside the update rule.

use extensor::optim::{self, GroupSpec, Hyper, Optimizer};
use extensor::tensoring::OptimizerKind;
use extensor::testing::bench::{bench, header};
use extensor::util::rng::Pcg64;

fn main() {
    let shapes: Vec<(&str, Vec<usize>)> = vec![
        ("embed", vec![2000, 512]),
        ("attn", vec![512, 512]),
        ("ff1", vec![512, 2048]),
        ("ln", vec![512]),
    ];
    let groups: Vec<GroupSpec> =
        shapes.iter().map(|(n, s)| GroupSpec::new(*n, s)).collect();
    let total: usize = groups.iter().map(|g| g.numel()).sum();

    let mut rng = Pcg64::seeded(1);
    let grads: Vec<Vec<f32>> = groups
        .iter()
        .map(|g| {
            let mut v = vec![0.0f32; g.numel()];
            rng.fill_normal(&mut v, 1.0);
            v
        })
        .collect();

    header(&format!("optim_hot — one full step over {total} parameters"));
    let hyper = Hyper::default();
    for kind in [
        OptimizerKind::Sgd,
        OptimizerKind::AdaGrad,
        OptimizerKind::Adam,
        OptimizerKind::Adafactor,
        OptimizerKind::Et(1),
        OptimizerKind::Et(2),
        OptimizerKind::Et(3),
        OptimizerKind::EtInf,
    ] {
        // Per-group dynamic-dispatch loop (the pre-refactor driver shape).
        let mut opt = optim::build(kind, &groups, &hyper);
        let mut params: Vec<Vec<f32>> =
            groups.iter().map(|g| vec![0.1f32; g.numel()]).collect();
        let r = bench(&format!("loop/{}", kind.name()), 3, 30, || {
            opt.next_step();
            for (gi, (p, g)) in params.iter_mut().zip(&grads).enumerate() {
                opt.step(gi, p, g, 1e-4).unwrap();
            }
        });
        r.report_with_rate(total as f64, "elem/s");

        // Batched entry point: one dynamic dispatch for the whole step.
        let mut opt = optim::build(kind, &groups, &hyper);
        let mut params: Vec<Vec<f32>> =
            groups.iter().map(|g| vec![0.1f32; g.numel()]).collect();
        let r = bench(&format!("step_all/{}", kind.name()), 3, 30, || {
            opt.next_step();
            opt.step_all(&mut params, &grads, 1e-4).unwrap();
        });
        r.report_with_rate(total as f64, "elem/s");
    }
    println!(
        "\n(ET overhead vs SGD is the paper's 'negligible memory AND compute' claim;\n \
         loop-vs-step_all is the per-group dispatch overhead the batched API removes)"
    );
}

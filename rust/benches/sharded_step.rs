//! Sharded optimizer-engine throughput: one full `step_all` over
//! transformer-shaped groups, per optimizer kind and shard count, against
//! the single-threaded suite as baseline. The paper's tiny-state result is
//! exactly what makes this shard cleanly — no preconditioner entry ever
//! crosses a shard boundary, so scaling is bounded by memory bandwidth and
//! the fan-out barrier, not by state movement.

use extensor::optim::{self, Hyper, Optimizer};
use extensor::shard::ShardedOptimizer;
use extensor::tensoring::OptimizerKind;
use extensor::testing::bench::{bench, header};
use extensor::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    // Same model shapes as `ettrain experiment sharding`, by construction.
    let gs = extensor::testing::transformer_groups(4, 2000, 512, 2048);
    let total: usize = gs.iter().map(|g| g.numel()).sum();
    let mut rng = Pcg64::seeded(2);
    let grads: Vec<Vec<f32>> = gs
        .iter()
        .map(|g| {
            let mut v = vec![0.0f32; g.numel()];
            rng.fill_normal(&mut v, 1.0);
            v
        })
        .collect();

    header(&format!("sharded_step — one full step over {total} parameters"));
    let hyper = Hyper::default();
    for kind in [
        OptimizerKind::AdaGrad,
        OptimizerKind::Et(1),
        OptimizerKind::Et(3),
        OptimizerKind::EtInf,
    ] {
        let mut params: Vec<Vec<f32>> = gs.iter().map(|g| vec![0.1f32; g.numel()]).collect();
        let mut baseline = optim::build(kind, &gs, &hyper);
        let r = bench(&format!("single/{}", kind.name()), 2, 12, || {
            baseline.next_step();
            for (gi, (p, g)) in params.iter_mut().zip(&grads).enumerate() {
                baseline.step(gi, p, g, 1e-4).unwrap();
            }
        });
        r.report_with_rate(total as f64, "elem/s");

        for shards in [1usize, 2, 4, 8] {
            let mut params: Vec<Vec<f32>> =
                gs.iter().map(|g| vec![0.1f32; g.numel()]).collect();
            let mut opt = ShardedOptimizer::new(kind, &gs, &hyper, shards)?;
            let peak = opt.peak_state_scalars();
            let r = bench(&format!("shard{shards}x/{}", kind.name()), 2, 12, || {
                opt.next_step();
                opt.step_all(&mut params, &grads, 1e-4).unwrap();
            });
            r.report_with_rate(total as f64, "elem/s");
            println!(
                "{:<40} {:>12} peak opt scalars on one shard",
                format!("  ({} shards, state)", shards),
                peak
            );
        }
    }
    println!("\n(peak per-shard state + scaling tables: `ettrain experiment sharding`)");
    Ok(())
}

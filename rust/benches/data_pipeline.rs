//! Data-pipeline throughput: corpus synthesis, packing, batch assembly, and
//! the prefetch loader, in tokens/s. The pipeline must comfortably outrun
//! the PJRT step (see table1_step) so the trainer is never input-bound.

use extensor::data::{Batcher, Corpus, Loader, SyntheticConfig, Tokenizer};
use extensor::testing::bench::{bench, header};
use extensor::util::rng::Pcg64;

fn main() {
    header("data_pipeline");

    let cfg = SyntheticConfig::default();
    let r = bench("corpus_synthesis(20k sentences)", 1, 5, || {
        std::hint::black_box(Corpus::synthetic(&cfg));
    });
    r.report();

    let corpus = Corpus::synthetic(&cfg);
    let tok = Tokenizer::from_corpus(&corpus);
    let (train, _) = corpus.split(10);
    let total_tokens: usize = train.iter().map(|s| s.len() + 2).sum();

    let r = bench("pack_stream(full corpus)", 1, 10, || {
        std::hint::black_box(Batcher::new(&tok, &train, 64, 8));
    });
    r.report_with_rate(total_tokens as f64, "tokens/s");

    let batcher = Batcher::new(&tok, &train, 64, 8);
    let order = batcher.epoch_order(0, 42);
    let nb = batcher.batches_per_epoch();
    let mut rng = Pcg64::seeded(5);
    let r = bench("assemble_batch(8x64)", 10, 200, || {
        let b = rng.below(nb as u64) as usize;
        std::hint::black_box(batcher.batch(&order, b));
    });
    r.report_with_rate(512.0, "tokens/s");

    // loader end-to-end: consume 200 prefetched batches
    let r = bench("loader_stream(200 batches)", 1, 5, || {
        let batcher = Batcher::new(&tok, &train, 64, 8);
        let mut loader = Loader::spawn(batcher, 1, 200, 4);
        let mut n = 0;
        while let Some(b) = loader.next() {
            std::hint::black_box(&b);
            n += 1;
        }
        assert_eq!(n, 200);
    });
    r.report_with_rate(200.0 * 512.0, "tokens/s");
}

//! Fuzz the worker-spec frame decoder: arbitrary bytes must produce
//! `Ok` or a typed `Err` — never a panic, never an unbounded allocation.
#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    let mut r = data;
    let _ = extensor::transport::wire::read_worker_spec(&mut r);
});

//! Fuzz the ETSS state-stream importer: arbitrary bytes must produce
//! `Ok` or a typed `Err` — never a panic, never an unbounded allocation.
//! The buffer bound mirrors what real callers pass (2x the largest group).
#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    let mut r = data;
    let _ = extensor::optim::stream::read_export_stream(&mut r, 1 << 16);
});

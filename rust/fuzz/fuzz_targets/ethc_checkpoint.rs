//! Fuzz the ETHC host-checkpoint loader against a fixed group layout
//! (matching the seed corpus): arbitrary bytes must produce `Ok` or a
//! typed `Err` — never a panic, never an unbounded allocation.
#![no_main]

use extensor::optim::GroupSpec;
use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    let groups = [GroupSpec::new("w", &[4, 3]), GroupSpec::new("b", &[3])];
    let mut r = data;
    let _ = extensor::train::checkpoint::read_host(&groups, &mut r);
});

//! etlint — in-repo invariant linter for the extreme-tensoring codebase.
//!
//! Enforces five invariants over scrubbed source text (see lexer.rs),
//! scoped by the checked-in `etlint.toml`:
//!
//! 1. determinism      — no HashMap/HashSet/clocks/RNG on the step path
//! 2. zero-alloc       — no allocating calls in kernel hot-path functions
//! 3. no-panic         — no unwrap/expect/panic!/indexing in transport code
//! 4. unsafe-hygiene   — every `unsafe` documented, raw-parts allowlisted
//! 5. wire-exhaustive  — every frame tag has encode + decode arms + a test
//!
//! Usage: `cargo run -p etlint [-- --root <dir> --config <file>]`
//! Exit codes: 0 clean, 1 findings, 2 usage/config/io error.

mod config;
mod lexer;
mod rules;

use rules::Finding;
use std::path::PathBuf;

fn run() -> Result<Vec<Finding>, String> {
    let mut root = PathBuf::from(".");
    let mut config_path = PathBuf::from("etlint.toml");
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => {
                root = PathBuf::from(
                    args.next().ok_or_else(|| "--root needs a value".to_string())?,
                );
            }
            "--config" => {
                config_path = PathBuf::from(
                    args.next().ok_or_else(|| "--config needs a value".to_string())?,
                );
            }
            other => return Err(format!("unknown argument {other:?}")),
        }
    }
    let text = std::fs::read_to_string(&config_path)
        .map_err(|e| format!("read config {}: {e}", config_path.display()))?;
    let tables = config::parse(&text)?;
    if tables.is_empty() {
        return Err(format!("{}: no rule tables", config_path.display()));
    }

    let mut findings = Vec::new();
    for table in &tables {
        let batch = match table.name.as_str() {
            "determinism" => rules::determinism(&root, table)?,
            "zero_alloc" => rules::zero_alloc(&root, table)?,
            "no_panic" => rules::no_panic(&root, table)?,
            "unsafe_hygiene" => rules::unsafe_hygiene(&root, table)?,
            "wire" => rules::wire_exhaustive(&root, table)?,
            other => return Err(format!("unknown rule table [{other}]")),
        };
        findings.extend(batch);
    }
    Ok(findings)
}

fn main() {
    match run() {
        Ok(findings) if findings.is_empty() => {
            println!("etlint: clean");
        }
        Ok(findings) => {
            for f in &findings {
                println!("{f}");
            }
            println!("etlint: {} finding(s)", findings.len());
            std::process::exit(1);
        }
        Err(e) => {
            eprintln!("etlint: error: {e}");
            std::process::exit(2);
        }
    }
}

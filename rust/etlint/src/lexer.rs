//! Comment/literal scrubbing and structural region analysis over one Rust
//! source file.
//!
//! The offline toolchain has no `syn`, so etlint works on *scrubbed* text:
//! a copy of the source where every comment and every string/char literal
//! body is replaced by spaces (newlines preserved, so line numbers match
//! the original). Token scans over scrubbed text cannot be fooled by
//! banned names appearing in docs or log messages, which removes the
//! classic grep false positives; what remains is a deliberately
//! conservative approximation of the AST (see each rule's notes on the
//! residual gap).
//!
//! On top of the scrubbed text this module extracts the three structures
//! the rules need: `#[cfg(test)]`/`#[test]` line regions, named inline
//! `mod` spans, and `fn` body spans.

use std::path::Path;

/// A named inline module's line range (1-indexed, inclusive).
#[derive(Debug, Clone)]
pub struct ModSpan {
    pub name: String,
    pub start_line: usize,
    pub end_line: usize,
}

/// A function with a body. Lines are 1-indexed, inclusive; the body range
/// covers the `{`..`}` block only, not the signature.
#[derive(Debug, Clone)]
pub struct FnSpan {
    pub name: String,
    pub sig_line: usize,
    pub body_start_line: usize,
    pub body_end_line: usize,
}

/// One analyzed source file.
#[derive(Debug)]
pub struct SourceFile {
    pub rel_path: String,
    /// Original lines — used only for `// SAFETY:` comment lookup, which
    /// by definition must see comments.
    pub raw_lines: Vec<String>,
    /// Scrubbed lines: comments and literal bodies blanked.
    pub code_lines: Vec<String>,
    /// Per 0-indexed line: inside a `#[cfg(test)]` or `#[test]` region.
    test_lines: Vec<bool>,
    pub mods: Vec<ModSpan>,
    pub fns: Vec<FnSpan>,
}

impl SourceFile {
    pub fn load(root: &Path, rel_path: &str) -> std::io::Result<SourceFile> {
        let raw = std::fs::read_to_string(root.join(rel_path))?;
        Ok(SourceFile::parse(rel_path, &raw))
    }

    pub fn parse(rel_path: &str, raw: &str) -> SourceFile {
        let code = scrub(raw);
        let raw_lines: Vec<String> = raw.lines().map(str::to_string).collect();
        let code_lines: Vec<String> = code.lines().map(str::to_string).collect();
        let line_of = byte_lines(&code);
        let n_lines = code_lines.len();
        let test_lines = test_regions(&code, &line_of, n_lines);
        let mods = mod_spans(&code, &line_of);
        let fns = fn_spans(&code, &line_of);
        SourceFile {
            rel_path: rel_path.to_string(),
            raw_lines,
            code_lines,
            test_lines,
            mods,
            fns,
        }
    }

    pub fn is_test_line(&self, line0: usize) -> bool {
        self.test_lines.get(line0).copied().unwrap_or(false)
    }

    /// Innermost function whose body contains 0-indexed `line0`.
    pub fn enclosing_fn(&self, line0: usize) -> Option<&FnSpan> {
        let line = line0 + 1;
        self.fns
            .iter()
            .filter(|f| f.body_start_line <= line && line <= f.body_end_line)
            .min_by_key(|f| f.body_end_line - f.body_start_line)
    }

    /// Whether 0-indexed `line0` is inside a `mod <name> { .. }` block.
    pub fn in_mod(&self, line0: usize, name: &str) -> bool {
        let line = line0 + 1;
        self.mods
            .iter()
            .any(|m| m.name == name && m.start_line <= line && line <= m.end_line)
    }
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Replace comments and string/char literal bodies with spaces, preserving
/// every newline so line numbers stay aligned with the original source.
pub fn scrub(src: &str) -> String {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut out = String::with_capacity(src.len());
    let blank = |c: char| if c == '\n' { '\n' } else { ' ' };
    let mut i = 0usize;
    while i < n {
        let c = b[i];
        // Line comment.
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            while i < n && b[i] != '\n' {
                out.push(' ');
                i += 1;
            }
            continue;
        }
        // Block comment, with nesting.
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let mut depth = 0usize;
            while i < n {
                if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                    if depth == 0 {
                        break;
                    }
                } else {
                    out.push(blank(b[i]));
                    i += 1;
                }
            }
            continue;
        }
        // String literals: "", b"", r"", r#""#, br#""#.
        let prev_ident = i > 0 && (b[i - 1].is_ascii_alphanumeric() || b[i - 1] == '_');
        if c == '"' || ((c == 'r' || c == 'b') && !prev_ident) {
            if let Some((content, hashes, raw_str)) = string_open(&b, i) {
                // Blank the opener too; the rules never need to see quotes.
                for k in i..content {
                    out.push(blank(b[k]));
                }
                i = content;
                if raw_str {
                    // Close on '"' followed by `hashes` '#'s.
                    'raw: while i < n {
                        if b[i] == '"' {
                            let mut h = 0usize;
                            while h < hashes && i + 1 + h < n && b[i + 1 + h] == '#' {
                                h += 1;
                            }
                            if h == hashes {
                                for k in i..=(i + hashes).min(n - 1) {
                                    out.push(blank(b[k]));
                                }
                                i += 1 + hashes;
                                break 'raw;
                            }
                        }
                        out.push(blank(b[i]));
                        i += 1;
                    }
                } else {
                    while i < n {
                        if b[i] == '\\' && i + 1 < n {
                            out.push(' ');
                            out.push(blank(b[i + 1]));
                            i += 2;
                        } else if b[i] == '"' {
                            out.push(' ');
                            i += 1;
                            break;
                        } else {
                            out.push(blank(b[i]));
                            i += 1;
                        }
                    }
                }
                continue;
            }
        }
        // Char literal vs lifetime: 'x' / '\n' are literals, 'a in
        // `&'a str` is a lifetime (no closing quote one-or-two ahead).
        if c == '\'' {
            let is_char =
                (i + 1 < n && b[i + 1] == '\\') || (i + 2 < n && b[i + 2] == '\'' && b[i + 1] != '\'');
            if is_char {
                out.push(' ');
                i += 1;
                while i < n {
                    if b[i] == '\\' && i + 1 < n {
                        out.push(' ');
                        out.push(blank(b[i + 1]));
                        i += 2;
                    } else if b[i] == '\'' {
                        out.push(' ');
                        i += 1;
                        break;
                    } else {
                        out.push(blank(b[i]));
                        i += 1;
                    }
                }
                continue;
            }
        }
        out.push(c);
        i += 1;
    }
    out
}

/// If a string literal opens at char index `i`, return
/// `(content_start, n_hashes, is_raw)`.
fn string_open(b: &[char], i: usize) -> Option<(usize, usize, bool)> {
    let n = b.len();
    let mut j = i;
    if j < n && b[j] == 'b' {
        j += 1;
    }
    if j < n && b[j] == 'r' {
        j += 1;
        let mut hashes = 0usize;
        while j < n && b[j] == '#' {
            hashes += 1;
            j += 1;
        }
        if j < n && b[j] == '"' {
            return Some((j + 1, hashes, true));
        }
        return None;
    }
    if j < n && b[j] == '"' {
        return Some((j + 1, 0, false));
    }
    None
}

/// Map each byte offset of `code` to its 0-indexed line.
fn byte_lines(code: &str) -> Vec<usize> {
    let mut v = Vec::with_capacity(code.len());
    let mut line = 0usize;
    for &c in code.as_bytes() {
        v.push(line);
        if c == b'\n' {
            line += 1;
        }
    }
    v
}

fn line_at(line_of: &[usize], idx: usize) -> usize {
    if line_of.is_empty() {
        return 0;
    }
    line_of[idx.min(line_of.len() - 1)]
}

/// Byte index of the `}` matching the `{` at `open_idx`.
fn match_brace(bytes: &[u8], open_idx: usize) -> Option<usize> {
    let mut depth = 0usize;
    for (k, &c) in bytes.iter().enumerate().skip(open_idx) {
        match c {
            b'{' => depth += 1,
            b'}' => {
                depth = depth.checked_sub(1)?;
                if depth == 0 {
                    return Some(k);
                }
            }
            _ => {}
        }
    }
    None
}

/// Mark every line covered by a `#[cfg(test)]` or `#[test]` item. The
/// attribute's item extends to its matched `{ .. }` block, or to a `;` for
/// braceless items.
fn test_regions(code: &str, line_of: &[usize], n_lines: usize) -> Vec<bool> {
    let mut flags = vec![false; n_lines];
    let bytes = code.as_bytes();
    for pat in ["#[cfg(test)]", "#[test]"] {
        let mut from = 0usize;
        while let Some(off) = code[from..].find(pat) {
            let start = from + off;
            from = start + pat.len();
            let mut k = start + pat.len();
            let end = loop {
                if k >= bytes.len() {
                    break bytes.len().saturating_sub(1);
                }
                match bytes[k] {
                    b'{' => break match_brace(bytes, k).unwrap_or(bytes.len() - 1),
                    b';' => break k,
                    _ => k += 1,
                }
            };
            let (ls, le) = (line_at(line_of, start), line_at(line_of, end));
            for flag in flags.iter_mut().take((le + 1).min(n_lines)).skip(ls) {
                *flag = true;
            }
        }
    }
    flags
}

/// Spans of named inline modules (`mod name { .. }`).
fn mod_spans(code: &str, line_of: &[usize]) -> Vec<ModSpan> {
    let bytes = code.as_bytes();
    let mut spans = Vec::new();
    let mut from = 0usize;
    while let Some(off) = code[from..].find("mod") {
        let start = from + off;
        from = start + 3;
        let prev_ok = start == 0 || !is_ident_byte(bytes[start - 1]);
        let next_ok = start + 3 >= bytes.len() || !is_ident_byte(bytes[start + 3]);
        if !prev_ok || !next_ok {
            continue;
        }
        let mut k = start + 3;
        while k < bytes.len() && bytes[k].is_ascii_whitespace() {
            k += 1;
        }
        let name_start = k;
        while k < bytes.len() && is_ident_byte(bytes[k]) {
            k += 1;
        }
        if k == name_start {
            continue;
        }
        let name = code[name_start..k].to_string();
        while k < bytes.len() && bytes[k].is_ascii_whitespace() {
            k += 1;
        }
        if k < bytes.len() && bytes[k] == b'{' {
            if let Some(close) = match_brace(bytes, k) {
                spans.push(ModSpan {
                    name,
                    start_line: line_at(line_of, start) + 1,
                    end_line: line_at(line_of, close) + 1,
                });
            }
        }
    }
    spans
}

/// Spans of functions with bodies. The body `{` is the first brace at
/// paren depth 0 after the name (signatures in this codebase never contain
/// braces); a `;` first means a bodiless trait declaration.
fn fn_spans(code: &str, line_of: &[usize]) -> Vec<FnSpan> {
    let bytes = code.as_bytes();
    let mut spans = Vec::new();
    let mut from = 0usize;
    while let Some(off) = code[from..].find("fn") {
        let start = from + off;
        from = start + 2;
        let prev_ok = start == 0 || !is_ident_byte(bytes[start - 1]);
        let next_ok = start + 2 >= bytes.len() || !is_ident_byte(bytes[start + 2]);
        if !prev_ok || !next_ok {
            continue;
        }
        let mut k = start + 2;
        while k < bytes.len() && bytes[k].is_ascii_whitespace() {
            k += 1;
        }
        let name_start = k;
        while k < bytes.len() && is_ident_byte(bytes[k]) {
            k += 1;
        }
        if k == name_start {
            // `fn(` pointer type, `impl Fn` etc.
            continue;
        }
        let name = code[name_start..k].to_string();
        let mut paren = 0i64;
        let body = loop {
            if k >= bytes.len() {
                break None;
            }
            match bytes[k] {
                b'(' => paren += 1,
                b')' => paren -= 1,
                b'{' if paren == 0 => break Some(k),
                b';' if paren == 0 => break None,
                _ => {}
            }
            k += 1;
        };
        if let Some(open) = body {
            if let Some(close) = match_brace(bytes, open) {
                spans.push(FnSpan {
                    name,
                    sig_line: line_at(line_of, start) + 1,
                    body_start_line: line_at(line_of, open) + 1,
                    body_end_line: line_at(line_of, close) + 1,
                });
            }
        }
    }
    spans
}

/// Whether `tok` occurs in `line` at an identifier boundary (the char
/// before a leading ident char and after a trailing ident char must be
/// non-ident). Tokens that start or end with punctuation skip that side's
/// check, so `.unwrap()` and `rand::` behave as expected.
pub fn token_hits(line: &str, tok: &str) -> Option<usize> {
    let lb = line.as_bytes();
    let tb = tok.as_bytes();
    if tb.is_empty() {
        return None;
    }
    let mut from = 0usize;
    while let Some(off) = line[from..].find(tok) {
        let s = from + off;
        from = s + 1;
        let before_ok = if is_ident_byte(tb[0]) {
            s == 0 || !is_ident_byte(lb[s - 1])
        } else {
            true
        };
        let last = tb[tb.len() - 1];
        let after_ok = if is_ident_byte(last) {
            s + tb.len() >= lb.len() || !is_ident_byte(lb[s + tb.len()])
        } else {
            true
        };
        if before_ok && after_ok {
            return Some(s);
        }
    }
    None
}

/// Columns of indexing expressions: `[` immediately preceded by an
/// identifier char, `)`, or `]` — i.e. `x[i]`, `f()[0]`, `m[a][b]` — which
/// are exactly the bracket uses that can panic. Type positions (`[u8; 4]`,
/// `&[f32]`), attributes (`#[..]`), and macros (`vec![..]`) are preceded
/// by punctuation and never match.
pub fn indexing_cols(line: &str) -> Vec<usize> {
    let b = line.as_bytes();
    let mut v = Vec::new();
    for i in 1..b.len() {
        if b[i] == b'[' {
            let p = b[i - 1];
            if is_ident_byte(p) || p == b')' || p == b']' {
                v.push(i);
            }
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scrub_blanks_comments_and_strings() {
        let src = "let a = \"HashMap\"; // HashMap here\nlet b = 1; /* vec! */ let c = 2;\n";
        let out = scrub(src);
        assert!(!out.contains("HashMap"));
        assert!(!out.contains("vec!"));
        assert!(out.contains("let a ="));
        assert!(out.contains("let c = 2;"));
        assert_eq!(out.matches('\n').count(), src.matches('\n').count());
    }

    #[test]
    fn scrub_handles_raw_strings_and_char_literals() {
        let src = "let r = r#\"unsafe { panic!() }\"#;\nlet c = '\\'';\nlet l: &'static str = x;\nlet q = 'a';\n";
        let out = scrub(src);
        assert!(!out.contains("panic!"));
        assert!(!out.contains("unsafe"));
        assert!(out.contains("'static"), "lifetime survived: {out}");
        assert!(!out.contains("'a'"));
    }

    #[test]
    fn nested_block_comments() {
        let src = "a /* x /* y */ z */ b\n";
        let out = scrub(src);
        assert!(out.contains('a') && out.contains('b'));
        assert!(!out.contains('x') && !out.contains('y') && !out.contains('z'));
    }

    #[test]
    fn test_regions_cover_mod_and_fn() {
        let src = "fn live() { x.unwrap(); }\n#[cfg(test)]\nmod tests {\n    fn t() { y.unwrap(); }\n}\n#[test]\nfn solo() { z.unwrap(); }\nfn live2() {}\n";
        let f = SourceFile::parse("x.rs", src);
        assert!(!f.is_test_line(0));
        assert!(f.is_test_line(2) && f.is_test_line(3) && f.is_test_line(4));
        assert!(f.is_test_line(6));
        assert!(!f.is_test_line(7));
    }

    #[test]
    fn fn_and_mod_spans() {
        let src = "mod reference {\n    pub fn apply(x: &[f32]) -> f32 {\n        x[0]\n    }\n}\nfn apply() {\n    ()\n}\n";
        let f = SourceFile::parse("x.rs", src);
        assert!(f.in_mod(2, "reference"));
        assert!(!f.in_mod(6, "reference"));
        let spans: Vec<_> = f.fns.iter().map(|s| (s.name.as_str(), s.sig_line)).collect();
        assert_eq!(spans, vec![("apply", 2), ("apply", 6)]);
        assert_eq!(f.enclosing_fn(2).map(|s| s.sig_line), Some(2));
    }

    #[test]
    fn token_boundaries() {
        assert!(token_hits("use std::collections::HashMap;", "HashMap").is_some());
        assert!(token_hits("let map_of_hashes = 1;", "HashMap").is_none());
        assert!(token_hits("x.unwrap();", ".unwrap()").is_some());
        assert!(token_hits("x.unwrap_or_else(f);", ".unwrap()").is_none());
        assert!(token_hits("rand::thread_rng()", "rand::").is_some());
        assert!(token_hits("operand::foo()", "rand::").is_none());
    }

    #[test]
    fn indexing_detection() {
        assert_eq!(indexing_cols("let y = xs[i];").len(), 1);
        assert_eq!(indexing_cols("let y = f()[0];").len(), 1);
        assert!(indexing_cols("#[derive(Debug)]").is_empty());
        assert!(indexing_cols("let v: &[f32] = &x;").is_empty());
        assert!(indexing_cols("vec![0; 4]").is_empty());
        assert_eq!(indexing_cols("&msg[..end]").len(), 1);
    }
}

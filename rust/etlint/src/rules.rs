//! The five invariant rules. Each rule takes its scope from `etlint.toml`
//! and emits [`Finding`]s; main.rs renders and counts them.

use crate::config::Table;
use crate::lexer::{indexing_cols, token_hits, SourceFile};
use std::path::Path;

/// One rule violation, pointed at a source line.
#[derive(Debug)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// Recursively collect `.rs` files under `rel` (a file or directory path
/// relative to `root`), sorted for deterministic report order.
pub fn rs_files(root: &Path, rel: &str) -> Result<Vec<String>, String> {
    let full = root.join(rel);
    if full.is_file() {
        return Ok(vec![rel.to_string()]);
    }
    if !full.is_dir() {
        return Err(format!("scope path {rel:?} is neither a file nor a directory"));
    }
    let mut out = Vec::new();
    let mut stack = vec![rel.to_string()];
    while let Some(dir) = stack.pop() {
        let mut entries: Vec<_> = std::fs::read_dir(root.join(&dir))
            .map_err(|e| format!("read_dir {dir:?}: {e}"))?
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .collect();
        entries.sort();
        for name in entries {
            let rel_child = format!("{dir}/{name}");
            let full_child = root.join(&rel_child);
            if full_child.is_dir() {
                stack.push(rel_child);
            } else if name.ends_with(".rs") {
                out.push(rel_child);
            }
        }
    }
    out.sort();
    Ok(out)
}

fn load(root: &Path, rel: &str) -> Result<SourceFile, String> {
    SourceFile::load(root, rel).map_err(|e| format!("read {rel:?}: {e}"))
}

/// Rule 1 — determinism: step-path modules must not name nondeterministic
/// constructs (hash-order iteration, wall clocks, RNG construction)
/// outside test code. Banning the names outright (not just iteration) is
/// deliberate: in these modules there is no legitimate use at all, and a
/// name ban is checkable without type information.
pub fn determinism(root: &Path, cfg: &Table) -> Result<Vec<Finding>, String> {
    let banned = cfg.list("banned");
    if banned.is_empty() {
        return Err("[determinism] needs a `banned` token list".to_string());
    }
    let mut findings = Vec::new();
    for scope in cfg.list("paths") {
        for rel in rs_files(root, &scope)? {
            let f = load(root, &rel)?;
            for (l0, line) in f.code_lines.iter().enumerate() {
                if f.is_test_line(l0) {
                    continue;
                }
                for tok in &banned {
                    if token_hits(line, tok).is_some() {
                        findings.push(Finding {
                            file: rel.clone(),
                            line: l0 + 1,
                            rule: "determinism",
                            message: format!("nondeterministic construct `{tok}` on the step path"),
                        });
                    }
                }
            }
        }
    }
    Ok(findings)
}

/// Rule 2 — zero-alloc: listed hot-path functions must not contain
/// allocating calls. Complements the runtime counting-allocator test
/// (`rust/tests/alloc_regression.rs`): the test proves steady state, this
/// proves the source can't regress warm-up-only paths into per-step ones.
pub fn zero_alloc(root: &Path, cfg: &Table) -> Result<Vec<Finding>, String> {
    let file = cfg
        .str("file")
        .ok_or_else(|| "[[zero_alloc]] entry needs `file`".to_string())?;
    let functions = cfg.list("functions");
    if functions.is_empty() {
        return Err(format!("[[zero_alloc]] entry for {file:?} needs `functions`"));
    }
    let banned = cfg.list("banned");
    if banned.is_empty() {
        return Err(format!("[[zero_alloc]] entry for {file:?} needs `banned`"));
    }
    let exclude_mods = cfg.list("exclude_mods");
    let f = load(root, file)?;
    let mut findings = Vec::new();
    for span in &f.fns {
        if !functions.iter().any(|n| n == &span.name) {
            continue;
        }
        let l0 = span.sig_line - 1;
        if f.is_test_line(l0) || exclude_mods.iter().any(|m| f.in_mod(l0, m)) {
            continue;
        }
        for l in span.body_start_line..=span.body_end_line {
            let line = &f.code_lines[l - 1];
            for tok in &banned {
                if token_hits(line, tok).is_some() {
                    findings.push(Finding {
                        file: file.to_string(),
                        line: l,
                        rule: "zero-alloc",
                        message: format!("allocating call `{tok}` in hot-path fn `{}`", span.name),
                    });
                }
            }
        }
    }
    Ok(findings)
}

/// Rule 3 — no-panic: transport/codec/scheduler code must propagate typed
/// errors, never panic. `check_indexing = false` scopes document their
/// audited loop-bounded indexing in the config.
pub fn no_panic(root: &Path, cfg: &Table) -> Result<Vec<Finding>, String> {
    let path = cfg.str("path").ok_or_else(|| "[[no_panic]] entry needs `path`".to_string())?;
    let banned = cfg.list("banned");
    if banned.is_empty() {
        return Err(format!("[[no_panic]] entry for {path:?} needs `banned`"));
    }
    let check_indexing = cfg.bool_or("check_indexing", true);
    let mut findings = Vec::new();
    for rel in rs_files(root, path)? {
        let f = load(root, &rel)?;
        for (l0, line) in f.code_lines.iter().enumerate() {
            if f.is_test_line(l0) {
                continue;
            }
            for tok in &banned {
                if token_hits(line, tok).is_some() {
                    findings.push(Finding {
                        file: rel.clone(),
                        line: l0 + 1,
                        rule: "no-panic",
                        message: format!("panicking call `{tok}` in no-panic scope"),
                    });
                }
            }
            if check_indexing && !indexing_cols(line).is_empty() {
                findings.push(Finding {
                    file: rel.clone(),
                    line: l0 + 1,
                    rule: "no-panic",
                    message: "slice/array indexing in no-panic scope (use .get()/.get_mut())"
                        .to_string(),
                });
            }
        }
    }
    Ok(findings)
}

/// Rule 4 — unsafe hygiene: every `unsafe` token needs a `// SAFETY:`
/// comment within `comment_window` raw lines above (or on the same line),
/// and every `from_raw_parts` site must sit in an allowlisted function.
pub fn unsafe_hygiene(root: &Path, cfg: &Table) -> Result<Vec<Finding>, String> {
    let window = cfg.int_or("comment_window", 8).max(0) as usize;
    let allow: Vec<String> = cfg.list("allow_from_raw_parts");
    let mut findings = Vec::new();
    for scope in cfg.list("paths") {
        for rel in rs_files(root, &scope)? {
            let f = load(root, &rel)?;
            for (l0, line) in f.code_lines.iter().enumerate() {
                if token_hits(line, "unsafe").is_some() {
                    let lo = l0.saturating_sub(window);
                    let documented = f.raw_lines[lo..=l0].iter().any(|r| r.contains("SAFETY:"));
                    if !documented {
                        findings.push(Finding {
                            file: rel.clone(),
                            line: l0 + 1,
                            rule: "unsafe-hygiene",
                            message: format!(
                                "`unsafe` without a `// SAFETY:` comment within {window} lines"
                            ),
                        });
                    }
                }
                // Substring, not token: must also catch `from_raw_parts_mut`.
                if line.contains("from_raw_parts") {
                    let site = match f.enclosing_fn(l0) {
                        Some(span) => format!("{rel}::{}", span.name),
                        None => format!("{rel}::<file-scope>"),
                    };
                    if !allow.iter().any(|a| a == &site) {
                        findings.push(Finding {
                            file: rel.clone(),
                            line: l0 + 1,
                            rule: "unsafe-hygiene",
                            message: format!(
                                "`from_raw_parts` at unaudited site `{site}` (add it to \
                                 allow_from_raw_parts after review)"
                            ),
                        });
                    }
                }
            }
        }
    }
    Ok(findings)
}

/// Rule 5 — wire exhaustiveness: every frame tag constant declared in the
/// wire module must be used at least `min_code_uses` times outside tests
/// (its encode and decode arms) and at least once in test code, so no tag
/// can exist without both directions and coverage.
pub fn wire_exhaustive(root: &Path, cfg: &Table) -> Result<Vec<Finding>, String> {
    let decl_file = cfg.str("decl_file").ok_or_else(|| "[wire] needs `decl_file`".to_string())?;
    let prefixes = cfg.list("tag_prefixes");
    if prefixes.is_empty() {
        return Err("[wire] needs `tag_prefixes`".to_string());
    }
    let use_paths = cfg.list("use_paths");
    let test_paths = cfg.list("test_paths");
    let min_code_uses = cfg.int_or("min_code_uses", 2).max(0) as usize;

    let decl = load(root, decl_file)?;
    // Collect `const NAME` declarations whose name carries a tag prefix.
    let mut tags: Vec<(String, usize)> = Vec::new();
    for (l0, line) in decl.code_lines.iter().enumerate() {
        if decl.is_test_line(l0) {
            continue;
        }
        if let Some(col) = token_hits(line, "const") {
            let rest = &line[col + 5..];
            let name: String = rest
                .trim_start()
                .chars()
                .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
                .collect();
            if prefixes.iter().any(|p| name.starts_with(p.as_str())) {
                tags.push((name, l0 + 1));
            }
        }
    }

    // Count usages across the transport layer and its tests.
    let mut counts: Vec<(usize, usize)> = vec![(0, 0); tags.len()]; // (code, test)
    for scope in &use_paths {
        let scope_is_test = test_paths.iter().any(|t| scope.starts_with(t.as_str()));
        for rel in rs_files(root, scope)? {
            let f = load(root, &rel)?;
            for (l0, line) in f.code_lines.iter().enumerate() {
                for (ti, (name, decl_line)) in tags.iter().enumerate() {
                    if token_hits(line, name).is_none() {
                        continue;
                    }
                    if rel == decl_file && l0 + 1 == *decl_line {
                        continue; // the declaration itself
                    }
                    if scope_is_test || f.is_test_line(l0) {
                        counts[ti].1 += 1;
                    } else {
                        counts[ti].0 += 1;
                    }
                }
            }
        }
    }

    let mut findings = Vec::new();
    for ((name, decl_line), (code_uses, test_uses)) in tags.iter().zip(&counts) {
        if *code_uses < min_code_uses {
            findings.push(Finding {
                file: decl_file.to_string(),
                line: *decl_line,
                rule: "wire-exhaustive",
                message: format!(
                    "tag `{name}` has {code_uses} non-test use(s); needs ≥ {min_code_uses} \
                     (encode + decode arms)"
                ),
            });
        }
        if *test_uses == 0 {
            findings.push(Finding {
                file: decl_file.to_string(),
                line: *decl_line,
                rule: "wire-exhaustive",
                message: format!("tag `{name}` never appears in a test"),
            });
        }
    }
    Ok(findings)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use std::path::PathBuf;

    /// Write a throwaway fixture tree and return its root.
    fn fixture(files: &[(&str, &str)]) -> PathBuf {
        static NEXT: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);
        let id = NEXT.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let root = std::env::temp_dir().join(format!("etlint-fix-{}-{id}", std::process::id()));
        for (rel, text) in files {
            let path = root.join(rel);
            std::fs::create_dir_all(path.parent().unwrap()).unwrap();
            std::fs::write(path, text).unwrap();
        }
        root
    }

    fn table(text: &str) -> config::Table {
        config::parse(text).unwrap().into_iter().next().unwrap()
    }

    #[test]
    fn determinism_flags_live_code_not_tests_or_strings() {
        let root = fixture(&[(
            "src/step.rs",
            "use std::collections::HashMap;\nfn f() { let t = std::time::Instant::now(); }\nfn ok() { let s = \"HashMap\"; }\n#[cfg(test)]\nmod tests {\n    use std::collections::HashMap;\n}\n",
        )]);
        let cfg = table(
            "[determinism]\npaths = [\"src/step.rs\"]\nbanned = [\"HashMap\", \"Instant::now\"]\n",
        );
        let f = determinism(&root, &cfg).unwrap();
        let lines: Vec<usize> = f.iter().map(|x| x.line).collect();
        assert_eq!(lines, vec![1, 2], "{f:?}");
    }

    #[test]
    fn zero_alloc_scopes_to_named_fns_and_skips_excluded_mods() {
        let root = fixture(&[(
            "src/kern.rs",
            "pub fn apply(s: &mut [f32]) {\n    let v = x.to_vec();\n}\npub fn cold() {\n    let v = vec![0; 4];\n}\npub mod reference {\n    pub fn apply() {\n        let v = vec![0usize; 4];\n    }\n}\n",
        )]);
        let cfg = table(
            "[[zero_alloc]]\nfile = \"src/kern.rs\"\nfunctions = [\"apply\"]\nexclude_mods = [\"reference\"]\nbanned = [\".to_vec()\", \"vec!\"]\n",
        );
        let f = zero_alloc(&root, &cfg).unwrap();
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].line, 2);
    }

    #[test]
    fn no_panic_flags_unwrap_and_indexing() {
        let root = fixture(&[(
            "src/t.rs",
            "fn f(v: &[u8]) -> u8 {\n    let a = v.first().unwrap();\n    v[0]\n}\nfn g(v: &[u8]) -> Option<u8> {\n    v.first().copied()\n}\n",
        )]);
        let cfg = table(
            "[[no_panic]]\npath = \"src/t.rs\"\nbanned = [\".unwrap()\", \".expect(\", \"panic!\"]\n",
        );
        let f = no_panic(&root, &cfg).unwrap();
        assert_eq!(f.len(), 2, "{f:?}");
        let no_idx = table(
            "[[no_panic]]\npath = \"src/t.rs\"\ncheck_indexing = false\nbanned = [\".unwrap()\"]\n",
        );
        assert_eq!(no_panic(&root, &no_idx).unwrap().len(), 1);
    }

    #[test]
    fn unsafe_hygiene_wants_safety_comments_and_allowlist() {
        let root = fixture(&[(
            "src/u.rs",
            "fn doc() {\n    // SAFETY: contract here.\n    let x = unsafe { f() };\n}\nfn bare() {\n    let x = unsafe { f() };\n}\nfn raw() {\n    // SAFETY: fine.\n    let s = unsafe { std::slice::from_raw_parts(p, n) };\n}\n",
        )]);
        // Window of 2: wide enough to pair each comment with its block,
        // narrow enough that `bare`'s unsafe can't see `doc`'s comment.
        let cfg = table(
            "[unsafe_hygiene]\npaths = [\"src/u.rs\"]\ncomment_window = 2\nallow_from_raw_parts = [\"src/u.rs::raw\"]\n",
        );
        let f = unsafe_hygiene(&root, &cfg).unwrap();
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].line, 6);
        let strict = table("[unsafe_hygiene]\npaths = [\"src/u.rs\"]\ncomment_window = 2\n");
        let f2 = unsafe_hygiene(&root, &strict).unwrap();
        assert!(f2.iter().any(|x| x.message.contains("unaudited site")), "{f2:?}");
    }

    #[test]
    fn wire_exhaustive_needs_both_arms_and_a_test() {
        let root = fixture(&[
            (
                "src/wire.rs",
                "pub const OP_A: u32 = 1;\npub const OP_B: u32 = 2;\nfn encode() { put(OP_A); put(OP_B); }\nfn decode() { match op { OP_A => {} OP_B => {} _ => {} } }\n",
            ),
            ("tests/wire.rs", "fn t() { assert_eq!(OP_A, 1); }\n"),
        ]);
        let cfg = table(
            "[wire]\ndecl_file = \"src/wire.rs\"\ntag_prefixes = [\"OP_\"]\nuse_paths = [\"src\", \"tests\"]\ntest_paths = [\"tests\"]\n",
        );
        let f = wire_exhaustive(&root, &cfg).unwrap();
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].message.contains("OP_B"));
        assert!(f[0].message.contains("never appears in a test"));
    }
}

//! Minimal TOML-subset parser for `etlint.toml`.
//!
//! The offline environment has no `toml` crate, so this parses exactly the
//! subset the config schema uses: `[table]` and `[[array-of-table]]`
//! headers, string / bool / integer values, and (possibly multi-line)
//! arrays of strings. Comments (`#`) are stripped outside quotes. Anything
//! else is a hard error — the config is checked in, so failing loudly on
//! an unsupported construct beats silently ignoring it.

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Bool(bool),
    Int(i64),
    List(Vec<String>),
}

/// One `[name]` or `[[name]]` section with its key/value entries, in file
/// order (no hashing anywhere — parse order is report order).
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub name: String,
    pub entries: Vec<(String, Value)>,
}

impl Table {
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }

    pub fn str(&self, key: &str) -> Option<&str> {
        match self.get(key) {
            Some(Value::Str(s)) => Some(s),
            _ => None,
        }
    }

    pub fn list(&self, key: &str) -> Vec<String> {
        match self.get(key) {
            Some(Value::List(v)) => v.clone(),
            Some(Value::Str(s)) => vec![s.clone()],
            _ => Vec::new(),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            Some(Value::Bool(b)) => *b,
            _ => default,
        }
    }

    pub fn int_or(&self, key: &str, default: i64) -> i64 {
        match self.get(key) {
            Some(Value::Int(i)) => *i,
            _ => default,
        }
    }
}

/// Strip a `#` comment, respecting double-quoted strings.
fn strip_comment(line: &str) -> &str {
    let b = line.as_bytes();
    let mut in_str = false;
    let mut i = 0usize;
    while i < b.len() {
        match b[i] {
            b'\\' if in_str => i += 1,
            b'"' => in_str = !in_str,
            b'#' if !in_str => return &line[..i],
            _ => {}
        }
        i += 1;
    }
    line
}

/// All double-quoted strings in `text`, in order (the item syntax inside
/// `[` .. `]` arrays).
fn quoted_strings(text: &str) -> Result<Vec<String>, String> {
    let b: Vec<char> = text.chars().collect();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < b.len() {
        if b[i] == '"' {
            let mut s = String::new();
            i += 1;
            loop {
                if i >= b.len() {
                    return Err("unterminated string".to_string());
                }
                match b[i] {
                    '\\' if i + 1 < b.len() => {
                        s.push(b[i + 1]);
                        i += 2;
                    }
                    '"' => {
                        i += 1;
                        break;
                    }
                    c => {
                        s.push(c);
                        i += 1;
                    }
                }
            }
            out.push(s);
        } else {
            i += 1;
        }
    }
    Ok(out)
}

fn parse_scalar(text: &str, line_no: usize) -> Result<Value, String> {
    let t = text.trim();
    if let Some(rest) = t.strip_prefix('"') {
        if let Some(body) = rest.strip_suffix('"') {
            let strs = quoted_strings(&format!("\"{body}\""))?;
            return strs
                .into_iter()
                .next()
                .map(Value::Str)
                .ok_or_else(|| format!("line {line_no}: empty string parse"));
        }
        return Err(format!("line {line_no}: unterminated string value"));
    }
    match t {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    t.parse::<i64>()
        .map(Value::Int)
        .map_err(|_| format!("line {line_no}: unsupported value {t:?}"))
}

/// Parse the config text into tables, in file order.
pub fn parse(text: &str) -> Result<Vec<Table>, String> {
    let mut tables: Vec<Table> = Vec::new();
    let lines: Vec<&str> = text.lines().collect();
    let mut i = 0usize;
    while i < lines.len() {
        let line_no = i + 1;
        let line = strip_comment(lines[i]).trim().to_string();
        i += 1;
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("[[") {
            let name = rest
                .strip_suffix("]]")
                .ok_or_else(|| format!("line {line_no}: malformed [[table]] header"))?;
            tables.push(Table { name: name.trim().to_string(), entries: Vec::new() });
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| format!("line {line_no}: malformed [table] header"))?;
            tables.push(Table { name: name.trim().to_string(), entries: Vec::new() });
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| format!("line {line_no}: expected `key = value`, got {line:?}"))?;
        let key = line[..eq].trim().to_string();
        let mut value_text = line[eq + 1..].trim().to_string();
        let value = if value_text.starts_with('[') {
            // Array of strings, possibly spanning multiple lines.
            while !value_text.trim_end().ends_with(']') {
                if i >= lines.len() {
                    return Err(format!("line {line_no}: unterminated array for key {key:?}"));
                }
                value_text.push(' ');
                value_text.push_str(strip_comment(lines[i]).trim());
                i += 1;
            }
            Value::List(quoted_strings(&value_text).map_err(|e| format!("line {line_no}: {e}"))?)
        } else {
            parse_scalar(&value_text, line_no)?
        };
        let table = tables
            .last_mut()
            .ok_or_else(|| format!("line {line_no}: key {key:?} before any [table] header"))?;
        table.entries.push((key, value));
    }
    Ok(tables)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_tables_arrays_and_scalars() {
        let text = r##"
# comment
[unsafe_hygiene]
paths = ["rust/src", "rust/tests"]  # trailing comment
comment_window = 8

[[no_panic]]
path = "rust/src/transport"
check_indexing = true

[[no_panic]]
path = "rust/src/session/scheduler.rs"
check_indexing = false
banned = [
    ".unwrap()",
    ".expect(",
]
"##;
        let tables = parse(text).unwrap();
        assert_eq!(tables.len(), 3);
        assert_eq!(tables[0].name, "unsafe_hygiene");
        assert_eq!(tables[0].list("paths"), vec!["rust/src", "rust/tests"]);
        assert_eq!(tables[0].int_or("comment_window", 0), 8);
        assert_eq!(tables[1].str("path"), Some("rust/src/transport"));
        assert!(tables[1].bool_or("check_indexing", false));
        assert!(!tables[2].bool_or("check_indexing", true));
        assert_eq!(tables[2].list("banned"), vec![".unwrap()", ".expect("]);
    }

    #[test]
    fn hash_inside_quotes_is_not_a_comment() {
        let tables = parse("[t]\nkey = \"a#b\"\n").unwrap();
        assert_eq!(tables[0].str("key"), Some("a#b"));
    }

    #[test]
    fn rejects_junk() {
        assert!(parse("key = 1\n").is_err());
        assert!(parse("[t]\nkey 1\n").is_err());
        assert!(parse("[t]\nkey = 1.5\n").is_err());
    }
}

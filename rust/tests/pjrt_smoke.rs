//! Smoke test for the PJRT execution contract the runtime depends on:
//! multi-output HLO modules return ONE tuple-shaped buffer per replica on
//! this client (xla_extension 0.5.1 CPU); elements are recovered with
//! `to_literal_sync().decompose_tuple()`. Plain literals can then be fed
//! back as next-step inputs (state loop). Do NOT call `size_bytes`/`shape`
//! on a tuple-shaped literal — ShapeUtil::ByteSizeOf aborts on tuples.

use anyhow::Result;

fn run(path: &str) -> Result<usize> {
    let client = xla::PjRtClient::cpu()?;
    let proto = xla::HloModuleProto::from_text_file(path)?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp)?;
    let x = xla::Literal::vec1(&[1f32, 2., 3., 4.]);
    let y = xla::Literal::vec1(&[10f32, 20., 30., 40.]);
    let out = exe.execute(&[x, y])?;
    println!("{path}: replicas={} outputs_per_replica={}", out.len(), out[0].len());
    let mut lit = out[0][0].to_literal_sync()?;
    let parts = lit.decompose_tuple()?;
    println!("  decomposed into {} parts", parts.len());
    assert_eq!(parts.len(), 3, "expected 3 leaves for 3-output function");
    let sum = parts[0].to_vec::<f32>()?;
    assert_eq!(sum, vec![11f32, 22., 33., 44.]);
    // Feed plain literals back through execute (state loop pattern).
    let fed = exe.execute(&[&parts[0], &parts[1]])?;
    let mut fed_lit = fed[0][0].to_literal_sync()?;
    let fed_parts = fed_lit.decompose_tuple()?;
    let v = fed_parts[0].to_vec::<f32>()?;
    println!("  feedback out[0] = {v:?}");
    assert_eq!(v, vec![21f32, 62., 123., 204.]); // (x+y) + x*y
    Ok(parts.len())
}

#[test]
fn multi_output_contract() -> Result<()> {
    for p in ["/tmp/multi_rt.hlo.txt", "/tmp/multi_nort.hlo.txt"] {
        if std::path::Path::new(p).exists() {
            run(p)?;
        } else {
            eprintln!("skip {p} (not generated)");
        }
    }
    Ok(())
}

//! Integration tests for the zero-alloc tracing subsystem
//! (`extensor::trace`): ring overflow semantics, histogram bin edges at
//! the public API, deterministic-clock span ordering, Chrome trace JSON
//! schema validity, and the registry `timing` field round-trip.
//!
//! Tracing state (the enable flag, the clock, the span rings) is global,
//! so every test serializes on one gate mutex and restores the
//! monotonic clock + disabled state before releasing it.

use extensor::registry::{Registry, RunRecord};
use extensor::trace::{
    self, chrome_trace_json, install_clock, install_monotonic, SpanKind, TestClock, NO_JOB,
    NO_SHARD, SPAN_CAPACITY, TRACE_SCHEMA,
};
use extensor::util::json::Json;
use std::sync::{Arc, Mutex, MutexGuard, OnceLock, PoisonError};

/// Serialize tests sharing the global trace state; restore defaults on
/// acquisition so a prior test (or panic) cannot leak state in.
fn gate() -> MutexGuard<'static, ()> {
    static GATE: OnceLock<Mutex<()>> = OnceLock::new();
    let g = GATE.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(PoisonError::into_inner);
    trace::disable();
    install_monotonic();
    g
}

/// The drained spans recorded by *this* thread (other test threads'
/// rings exist in the registry but are empty inside a gated window).
fn my_spans() -> Vec<extensor::trace::SpanRecord> {
    let mut drained: Vec<_> =
        trace::drain().into_iter().filter(|t| !t.spans.is_empty()).collect();
    assert_eq!(drained.len(), 1, "exactly one thread recorded: {drained:?}");
    drained.pop().unwrap().spans
}

#[test]
fn deterministic_clock_pins_span_order_and_ticks() {
    let _g = gate();
    install_clock(Arc::new(TestClock::new(10)));
    trace::enable();
    drop(trace::span(SpanKind::WireSend, 0, NO_JOB));
    drop(trace::span(SpanKind::WireRecv, 0, NO_JOB));
    {
        let mut claim = trace::span(SpanKind::Claim, NO_SHARD, NO_JOB);
        claim.set_job(7);
    }
    trace::disable();
    install_monotonic();

    let spans = my_spans();
    assert_eq!(spans.len(), 3);
    // Each span reads the clock twice; the TestClock advances by 10 per
    // read, so the exact ticks are pinned.
    assert_eq!((spans[0].begin, spans[0].end), (10, 20));
    assert_eq!((spans[1].begin, spans[1].end), (30, 40));
    assert_eq!((spans[2].begin, spans[2].end), (50, 60));
    assert_eq!(SpanKind::from_u16(spans[0].kind), Some(SpanKind::WireSend));
    assert_eq!(SpanKind::from_u16(spans[1].kind), Some(SpanKind::WireRecv));
    assert_eq!(SpanKind::from_u16(spans[2].kind), Some(SpanKind::Claim));
    assert_eq!(spans[0].shard, 0);
    assert_eq!(spans[0].job, u16::MAX, "NO_JOB stays unattributed");
    assert_eq!(spans[2].job, 7, "set_job after open is recorded");
    // Chronological within the thread.
    assert!(spans.windows(2).all(|w| w[0].end <= w[1].begin));
}

#[test]
fn ring_overflow_overwrites_oldest_and_counts_drops() {
    let _g = gate();
    install_clock(Arc::new(TestClock::new(1)));
    trace::enable();
    let extra = 5usize;
    for _ in 0..SPAN_CAPACITY + extra {
        drop(trace::span(SpanKind::OptimStep, NO_SHARD, NO_JOB));
    }
    trace::disable();
    install_monotonic();

    let spans = my_spans();
    assert_eq!(spans.len(), SPAN_CAPACITY, "ring never grows past capacity");
    let drained = trace::drain(); // rings already cleared by my_spans' drain
    assert!(drained.iter().all(|t| t.spans.is_empty() && t.dropped == 0));

    // Span i (0-based) has begin = 2i+1 under a step-1 TestClock; the
    // oldest `extra` spans were overwritten, so the first retained span
    // is span `extra`, and order stays chronological across the wrap.
    assert_eq!(spans[0].begin, (2 * extra + 1) as u64);
    assert_eq!(spans.last().unwrap().begin, (2 * (SPAN_CAPACITY + extra - 1) + 1) as u64);
    assert!(spans.windows(2).all(|w| w[0].begin < w[1].begin));
}

#[test]
fn dropped_counter_reports_exact_overflow() {
    let _g = gate();
    trace::enable();
    for _ in 0..SPAN_CAPACITY + 3 {
        drop(trace::span(SpanKind::OptimStep, NO_SHARD, NO_JOB));
    }
    trace::disable();
    let t = trace::drain().into_iter().find(|t| !t.spans.is_empty()).unwrap();
    assert_eq!(t.dropped, 3, "one drop per overwritten span");
    // enable() resets the tally along with the rings.
    trace::enable();
    drop(trace::span(SpanKind::OptimStep, NO_SHARD, NO_JOB));
    trace::disable();
    let t = trace::drain().into_iter().find(|t| !t.spans.is_empty()).unwrap();
    assert_eq!(t.dropped, 0);
    assert_eq!(t.spans.len(), 1);
}

#[test]
fn histogram_percentiles_quantize_to_log2_bin_edges() {
    let _g = gate();
    // Duration per span = one clock step; 1000 ns lands in bin 9
    // ([512, 1024)), whose upper edge is 1024 ns.
    install_clock(Arc::new(TestClock::new(1000)));
    trace::enable();
    let before = trace::snapshot();
    for _ in 0..8 {
        drop(trace::span(SpanKind::StepAll, NO_SHARD, NO_JOB));
    }
    let delta = trace::snapshot().delta(&before);
    trace::disable();
    install_monotonic();
    trace::drain();

    let s = delta.kind_summary(SpanKind::StepAll);
    assert_eq!(s.count, 8);
    assert_eq!(s.p50_ns, 1024, "percentiles report the log2 bin upper edge");
    assert_eq!(s.p99_ns, 1024);
    assert_eq!(s.max_ns, 1000, "max is exact, not quantized");
    assert_eq!(s.total_ns, 8 * 1000);

    // timing_json: 8 StepAll spans x 1000 ns over a 10_000 ns wall.
    let j = delta.timing_json(10_000);
    assert_eq!(j.get("schema").and_then(|v| v.as_str()), Some("trace_timing/v1"));
    let cov = j.get("coverage_pct").and_then(|v| v.as_f64()).unwrap();
    assert!((cov - 80.0).abs() < 1e-9, "{cov}");
}

#[test]
fn chrome_trace_json_is_schema_valid() {
    let _g = gate();
    install_clock(Arc::new(TestClock::new(500)));
    trace::enable();
    drop(trace::span(SpanKind::WireSend, 3, NO_JOB));
    drop(trace::span(SpanKind::Claim, NO_SHARD, 2));
    trace::disable();
    install_monotonic();
    let threads: Vec<_> =
        trace::drain().into_iter().filter(|t| !t.spans.is_empty()).collect();

    let doc = chrome_trace_json(&threads);
    // Round-trip through the serializer: the export must be valid JSON.
    let doc = Json::parse(&doc.to_string()).unwrap();
    assert_eq!(doc.get("schema").and_then(|v| v.as_str()), Some(TRACE_SCHEMA));
    assert_eq!(doc.get("displayTimeUnit").and_then(|v| v.as_str()), Some("ms"));
    assert_eq!(doc.get("dropped_spans").and_then(|v| v.as_usize()), Some(0));
    let events = doc.get("traceEvents").and_then(|v| v.as_arr()).unwrap();

    let metas: Vec<&Json> =
        events.iter().filter(|e| e.get("ph").and_then(|v| v.as_str()) == Some("M")).collect();
    assert_eq!(metas.len(), 1, "one thread_name metadata event per thread");
    assert_eq!(metas[0].get("name").and_then(|v| v.as_str()), Some("thread_name"));

    let xs: Vec<&Json> =
        events.iter().filter(|e| e.get("ph").and_then(|v| v.as_str()) == Some("X")).collect();
    assert_eq!(xs.len(), 2);
    for e in &xs {
        assert!(e.get("name").and_then(|v| v.as_str()).is_some());
        assert_eq!(e.get("cat").and_then(|v| v.as_str()), Some("ettrain"));
        assert_eq!(e.get("pid").and_then(|v| v.as_usize()), Some(1));
        assert!(e.get("tid").is_some());
        // ts/dur are microsecond floats; TestClock step 500 ns = 0.5 us.
        assert!(e.get("ts").and_then(|v| v.as_f64()).is_some());
        assert!((e.get("dur").and_then(|v| v.as_f64()).unwrap() - 0.5).abs() < 1e-9);
    }
    let send = xs.iter().find(|e| e.get("name").and_then(|v| v.as_str()) == Some("wire_send"));
    let args = send.unwrap().get("args").unwrap();
    assert_eq!(args.get("shard").and_then(|v| v.as_usize()), Some(3));
    assert!(args.get("job").is_none(), "unattributed ids are omitted");
    let claim = xs.iter().find(|e| e.get("name").and_then(|v| v.as_str()) == Some("claim"));
    let args = claim.unwrap().get("args").unwrap();
    assert!(args.get("shard").is_none());
    assert_eq!(args.get("job").and_then(|v| v.as_usize()), Some(2));

    // The file writer produces the same document on disk.
    let path = std::env::temp_dir()
        .join(format!("et-trace-{}", std::process::id()))
        .join("t.trace.json");
    extensor::trace::write_chrome_trace(&path, &threads).unwrap();
    let on_disk = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert_eq!(on_disk.get("schema").and_then(|v| v.as_str()), Some(TRACE_SCHEMA));
    std::fs::remove_dir_all(path.parent().unwrap()).ok();
}

#[test]
fn registry_timing_field_round_trips_both_encodings() {
    let _g = gate();
    install_clock(Arc::new(TestClock::new(750)));
    trace::enable();
    let before = trace::snapshot();
    for _ in 0..4 {
        drop(trace::span(SpanKind::StepAll, NO_SHARD, NO_JOB));
    }
    let timing = trace::snapshot().delta(&before).timing_json(5_000);
    trace::disable();
    install_monotonic();
    trace::drain();

    let rec = RunRecord {
        run_id: "1-0-traced".to_string(),
        job: "traced".to_string(),
        kind: "shard-bench".to_string(),
        commit: "deadbeef".to_string(),
        started_unix: 1,
        utc: "1970-01-01T00:00:01Z".to_string(),
        spec_toml: "[job.traced]\ntype = \"shard-bench\"\n".to_string(),
        plan: None,
        status: "ok".to_string(),
        error: String::new(),
        metrics: Json::obj(vec![("steps_per_sec", Json::num(800.0))]),
        artifact_hits: 0,
        artifact_misses: 0,
        corpus_hits: 0,
        corpus_misses: 0,
        wall_seconds: 0.005,
        queue_seconds: 0.0,
        event_log: String::new(),
        recoveries: 0,
        error_kind: String::new(),
        timing,
    };

    let dir = std::env::temp_dir().join(format!("et-trace-reg-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let registry = Registry::open(&dir).unwrap();
    registry.append(std::slice::from_ref(&rec)).unwrap();
    let jsonl = Registry::load(&dir).unwrap();
    assert_eq!(jsonl, vec![rec.clone()], "JSONL round trip preserves the timing profile");
    let csv = Registry::load_csv(&dir).unwrap();
    assert_eq!(csv, vec![rec.clone()], "CSV round trip preserves the timing profile");

    let t = &jsonl[0].timing;
    assert_eq!(t.get("schema").and_then(|v| v.as_str()), Some("trace_timing/v1"));
    assert_eq!(
        t.get("kinds").and_then(|k| k.get("step_all")).and_then(|s| s.get("count")).and_then(
            |c| c.as_usize()
        ),
        Some(4)
    );
    std::fs::remove_dir_all(&dir).ok();
}

//! Session-scheduler contract tests, all artifact-free (convex and
//! shard-bench workloads only):
//!
//! * **Determinism** — the same `JobSpec` batch run at `--jobs 1` and
//!   `--jobs 4` produces bitwise-identical per-run metrics and final
//!   weights ("checkpoints"): seeds are per-job and jobs share no mutable
//!   state, so worker count may only change wall-clock and event
//!   interleaving.
//! * **Admission control** — under a `--mem-budget` that fits one job at a
//!   time, an over-budget job queues (`Deferred`) instead of running, and
//!   only starts after a running job releases its reservation; admission
//!   is FIFO, so smaller jobs cannot overtake (starve) a deferred one; a
//!   job that could never fit fails at submission instead of deadlocking.
//! * **Resource caching** — the session synthesizes each dataset at most
//!   once per batch, visible through the cache-hit counters in the event
//!   stream (the acceptance counters for `experiment quantized-state`).

use extensor::convex::ConvexConfig;
use extensor::session::{
    run_batch, ConvexOpt, ConvexSpec, JobEvent, JobOutcome, JobSpec, SchedulerOptions, Session,
};
use extensor::tensoring::{OptimizerKind, StateBackend};

fn tiny_data(seed: u64) -> ConvexConfig {
    ConvexConfig { n: 400, d: 32, k: 4, cond: 1e3, householder: 2, seed }
}

/// A mixed batch: several optimizers x backends over a shared dataset,
/// plus one job with its own dataset/seed.
fn mixed_batch() -> Vec<JobSpec> {
    let shared = tiny_data(7);
    let mut specs = Vec::new();
    for (i, (kind, backend)) in [
        (OptimizerKind::AdaGrad, StateBackend::DenseF32),
        (OptimizerKind::Adam, StateBackend::q8()),
        (OptimizerKind::Et(2), StateBackend::DenseF32),
        (OptimizerKind::Et(3), StateBackend::q8()),
        (OptimizerKind::EtInf, StateBackend::DenseF32),
    ]
    .into_iter()
    .enumerate()
    {
        specs.push(JobSpec::convex(
            format!("job{i}"),
            ConvexSpec {
                data: shared.clone(),
                iters: 40,
                lr: if kind == OptimizerKind::EtInf { 0.5 } else { 0.05 },
                backend,
                opt: ConvexOpt::Kind(kind),
                measure_after: true,
                curve_every: 10,
            },
        ));
    }
    specs.push(JobSpec::convex(
        "job_own_data",
        ConvexSpec {
            data: tiny_data(99),
            iters: 40,
            lr: 0.05,
            opt: ConvexOpt::CustomEt { dims: vec![4, 4, 8] },
            ..ConvexSpec::default()
        },
    ));
    specs
}

fn outcomes(specs: &[JobSpec], workers: usize) -> Vec<(String, u64, u64, Vec<u32>)> {
    // Fresh session per run: caches must not leak between the compared
    // executions.
    let session = Session::new();
    let report = run_batch(
        &session,
        specs,
        &SchedulerOptions { workers, mem_budget: None, log_path: None, registry_dir: None },
    )
    .unwrap();
    report
        .results
        .iter()
        .map(|r| {
            let out = r.outcome.as_ref().expect("job failed");
            let c = match out {
                JobOutcome::Convex(c) => c,
                _ => panic!("expected convex outcome"),
            };
            (
                r.name.clone(),
                c.final_loss.to_bits(),
                c.accuracy.to_bits(),
                c.w.iter().map(|x| x.to_bits()).collect(),
            )
        })
        .collect()
}

/// The determinism satellite: jobs=1 vs jobs=4, bitwise.
#[test]
fn batch_results_identical_at_1_and_4_workers() {
    let specs = mixed_batch();
    let serial = outcomes(&specs, 1);
    let parallel = outcomes(&specs, 4);
    assert_eq!(serial.len(), parallel.len());
    for (a, b) in serial.iter().zip(&parallel) {
        assert_eq!(a.0, b.0, "submission order changed");
        assert_eq!(a.1, b.1, "{}: final loss differs bitwise", a.0);
        assert_eq!(a.2, b.2, "{}: accuracy differs bitwise", a.0);
        assert_eq!(a.3, b.3, "{}: final weights (checkpoint) differ bitwise", a.0);
    }
}

/// Shard-bench memory columns are also worker-count independent (timing
/// columns are not, and are not compared).
#[test]
fn shard_bench_memory_columns_deterministic() {
    use extensor::session::ShardBenchSpec;
    let spec = |shards: usize| {
        JobSpec::shard_bench(
            format!("sb{shards}"),
            ShardBenchSpec {
                kind: OptimizerKind::Et(2),
                shards,
                iters: 2,
                layers: 1,
                vocab: 64,
                d_model: 16,
                d_ff: 32,
                seed: 3,
                ..ShardBenchSpec::default()
            },
        )
    };
    let specs = vec![spec(1), spec(2)];
    let run = |workers: usize| {
        let session = Session::new();
        run_batch(
            &session,
            &specs,
            &SchedulerOptions { workers, mem_budget: None, log_path: None, registry_dir: None },
        )
        .unwrap()
        .into_outcomes()
        .unwrap()
        .into_iter()
        .map(|o| match o {
            JobOutcome::ShardBench(s) => {
                (s.shards, s.peak_state_bytes_per_shard, s.total_state_scalars)
            }
            _ => panic!("expected shard-bench outcome"),
        })
        .collect::<Vec<_>>()
    };
    assert_eq!(run(1), run(2));
}

/// The session synthesizes each distinct dataset exactly once per batch;
/// every other lookup is a cache hit (the acceptance counters).
#[test]
fn datasets_synthesized_at_most_once_per_batch() {
    let specs = mixed_batch(); // 5 jobs share one dataset + 1 own dataset
    let session = Session::new();
    let report = run_batch(
        &session,
        &specs,
        &SchedulerOptions { workers: 4, mem_budget: None, log_path: None, registry_dir: None },
    )
    .unwrap();
    let counts = report.cache_counts();
    assert_eq!(counts.corpus_misses, 2, "two distinct datasets -> two syntheses");
    assert_eq!(counts.corpus_hits, 4, "the other four lookups must hit the cache");
    assert_eq!(session.stats().corpus_misses, 2);
}

/// The admission-control satellite, end to end: with a budget that fits
/// one job at a time, the second job defers and runs only after the first
/// releases.
#[test]
fn over_budget_job_queues_instead_of_running() {
    // Long enough per job (~hundreds of ms) that the pool provably
    // overlaps the first job's execution with the second job's admission
    // attempt.
    let data = ConvexConfig { n: 2000, ..tiny_data(5) };
    let specs: Vec<JobSpec> = (0..2)
        .map(|i| {
            JobSpec::convex(
                format!("budget{i}"),
                ConvexSpec {
                    data: data.clone(),
                    iters: 300,
                    opt: ConvexOpt::Kind(OptimizerKind::AdaGrad),
                    ..ConvexSpec::default()
                },
            )
        })
        .collect();
    let cost = specs[0].cost_bytes().unwrap();
    // Budget fits one job, not two.
    let budget = cost + cost / 2;
    let session = Session::new();
    let report = run_batch(
        &session,
        &specs,
        &SchedulerOptions { workers: 4, mem_budget: Some(budget), ..Default::default() },
    )
    .unwrap();
    assert!(report.failed().is_empty(), "both jobs must eventually run");

    // Exactly one job was deferred, and no two jobs ever ran concurrently:
    // in the event order, the second admission comes after a finish.
    let seq: Vec<&JobEvent> = report.events.iter().map(|e| &e.event).collect();
    let deferred = seq.iter().filter(|e| matches!(e, JobEvent::Deferred { .. })).count();
    assert_eq!(deferred, 1, "the over-budget job must defer exactly once");
    let mut running = 0usize;
    for e in &seq {
        match e {
            JobEvent::Admitted { in_use_bytes, .. } => {
                running += 1;
                assert!(running <= 1, "two jobs admitted concurrently under the budget");
                assert!(*in_use_bytes <= budget, "admission exceeded the budget");
            }
            JobEvent::Finished { .. } | JobEvent::Failed { .. } => {
                running = running.saturating_sub(1);
            }
            _ => {}
        }
    }
}

/// The starvation fix: admission is FIFO, so a deferred large job is
/// admitted before any smaller job submitted after it — a stream of small
/// jobs that would individually fit the leftover budget cannot overtake
/// (and thereby starve) the large one.
#[test]
fn deferred_job_is_not_starved_by_smaller_ones() {
    let small = |name: &str, seed: u64| {
        JobSpec::convex(
            name,
            ConvexSpec {
                data: ConvexConfig { n: 2000, ..tiny_data(seed) },
                iters: 300,
                opt: ConvexOpt::Kind(OptimizerKind::AdaGrad),
                ..ConvexSpec::default()
            },
        )
    };
    let huge = JobSpec::convex(
        "huge",
        ConvexSpec {
            data: ConvexConfig { n: 20_000, ..tiny_data(6) },
            iters: 20,
            opt: ConvexOpt::Kind(OptimizerKind::AdaGrad),
            ..ConvexSpec::default()
        },
    );
    let mut specs = vec![small("small0", 5), huge];
    for i in 1..=4 {
        specs.push(small(&format!("small{i}"), 5));
    }
    let cost_small = specs[0].cost_bytes().unwrap();
    let cost_huge = specs[1].cost_bytes().unwrap();
    assert!(cost_huge > 2 * cost_small, "test shapes must make the huge job dominate");
    // small0 fits; huge then does not (small0 holds cost_small >
    // cost_small/2 of slack), and every later small job would fit the
    // leftover — the exact overtaking scenario.
    let budget = cost_huge + cost_small / 2;
    let session = Session::new();
    let report = run_batch(
        &session,
        &specs,
        &SchedulerOptions { workers: 2, mem_budget: Some(budget), ..Default::default() },
    )
    .unwrap();
    assert!(report.failed().is_empty(), "all jobs must eventually run");

    let admitted: Vec<&str> = report
        .events
        .iter()
        .filter_map(|e| match &e.event {
            JobEvent::Admitted { job, .. } => Some(job.as_str()),
            _ => None,
        })
        .collect();
    assert_eq!(admitted.len(), specs.len());
    assert_eq!(admitted[0], "small0");
    assert_eq!(
        admitted[1], "huge",
        "the deferred job must get the next admission (FIFO); order: {admitted:?}"
    );
    let huge_deferrals = report
        .events
        .iter()
        .filter(|e| matches!(&e.event, JobEvent::Deferred { job, .. } if job == "huge"))
        .count();
    assert_eq!(huge_deferrals, 1, "the huge job defers exactly once, then holds its place");
}

/// A job that can never fit the total budget fails at submission with a
/// clear error (instead of deadlocking the queue); the rest of the batch
/// still runs.
#[test]
fn impossible_job_fails_cleanly() {
    let specs = vec![
        JobSpec::convex(
            "small",
            ConvexSpec {
                data: tiny_data(1),
                iters: 10,
                opt: ConvexOpt::Kind(OptimizerKind::AdaGrad),
                ..ConvexSpec::default()
            },
        ),
        JobSpec::convex(
            "huge",
            ConvexSpec {
                data: ConvexConfig { n: 100_000, d: 512, ..tiny_data(2) },
                iters: 10,
                opt: ConvexOpt::Kind(OptimizerKind::AdaGrad),
                ..ConvexSpec::default()
            },
        ),
    ];
    let budget = specs[0].cost_bytes().unwrap() + 1024;
    let session = Session::new();
    let report = run_batch(
        &session,
        &specs,
        &SchedulerOptions { workers: 2, mem_budget: Some(budget), ..Default::default() },
    )
    .unwrap();
    assert!(report.outcome("small").is_ok());
    let err = match report.outcome("huge") {
        Err(e) => e.to_string(),
        Ok(_) => panic!("the impossible job must fail"),
    };
    assert!(err.contains("mem-budget"), "unexpected error: {err}");
}

/// Duplicate job names are rejected up front.
#[test]
fn duplicate_names_rejected() {
    let spec = JobSpec::convex(
        "dup",
        ConvexSpec { data: tiny_data(1), iters: 5, ..ConvexSpec::default() },
    );
    let session = Session::new();
    let err = run_batch(
        &session,
        &[spec.clone(), spec],
        &SchedulerOptions::default(),
    );
    assert!(err.is_err());
}

/// The schedule JSONL log is written and parseable.
#[test]
fn schedule_log_is_valid_jsonl() {
    let dir = std::env::temp_dir().join(format!("et-sched-{}", std::process::id()));
    let log = dir.join("schedule.jsonl");
    let specs = vec![JobSpec::convex(
        "logged",
        ConvexSpec { data: tiny_data(3), iters: 10, ..ConvexSpec::default() },
    )];
    let session = Session::new();
    run_batch(
        &session,
        &specs,
        &SchedulerOptions { workers: 1, log_path: Some(log.clone()), ..Default::default() },
    )
    .unwrap();
    let records = extensor::util::logging::read_jsonl(&log).unwrap();
    assert!(!records.is_empty());
    let kinds: Vec<&str> =
        records.iter().filter_map(|r| r.get("event").and_then(|v| v.as_str())).collect();
    assert!(kinds.contains(&"queued"));
    assert!(kinds.contains(&"admitted"));
    assert!(kinds.contains(&"finished"));
    std::fs::remove_dir_all(&dir).ok();
}

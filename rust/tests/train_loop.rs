//! Trainer-level integration tests over the micro artifacts: full run with
//! eval + metrics, determinism across runs, checkpoint round-trip, and the
//! vision loop. Skipped when artifacts aren't built.

use anyhow::Result;
use extensor::optim::Schedule;
use extensor::runtime::{Client, Engine};
use extensor::train::{checkpoint, RunConfig, Trainer};
use extensor::util::logging::read_jsonl;

fn artifacts_ready() -> bool {
    let ok = extensor::runtime::default_artifact_dir().join("lm_micro_et2.json").exists();
    if !ok {
        eprintln!("skip: artifacts not built (run `make artifacts`)");
    }
    ok
}

fn micro_cfg(name: &str, steps: u64) -> RunConfig {
    RunConfig {
        name: name.into(),
        artifact: "lm_micro_et2".into(),
        eval_artifact: Some("lm_micro_eval".into()),
        artifact_dir: extensor::runtime::default_artifact_dir(),
        out_dir: std::env::temp_dir().join(format!("etruns-{}", std::process::id())),
        steps,
        eval_every: steps / 2,
        eval_batches: 2,
        log_every: 2,
        checkpoint_every: 0,
        schedule: Schedule::Constant(0.05),
        seed: 7,
        corpus_vocab: 56, // model vocab is 64; 56 + 4 specials fits
        corpus_sentences: 400,
        max_seconds: 0.0,
        track_traces: false,
        trace_every: 1,
        ..RunConfig::default()
    }
}

#[test]
fn full_run_writes_metrics_and_learns() -> Result<()> {
    if !artifacts_ready() {
        return Ok(());
    }
    let cfg = micro_cfg("itest_full", 30);
    let out_dir = cfg.out_dir.clone();
    let result = Trainer::new(cfg)?.run()?;
    assert_eq!(result.summary.steps, 30);
    assert!(result.summary.final_train_loss.is_finite());
    // loss must drop vs the first logged value
    let first = result.loss_history.first().unwrap().1;
    let last = result.loss_history.last().unwrap().1;
    assert!(last < first, "no learning: {first} -> {last}");
    // metrics file has train + eval + summary records
    let recs = read_jsonl(out_dir.join("itest_full/metrics.jsonl"))?;
    let kinds: Vec<&str> =
        recs.iter().filter_map(|r| r.get("kind").and_then(|k| k.as_str())).collect();
    assert!(kinds.contains(&"train"));
    assert!(kinds.contains(&"eval"));
    assert!(kinds.contains(&"summary"));
    std::fs::remove_dir_all(&out_dir).ok();
    Ok(())
}

#[test]
fn training_is_deterministic() -> Result<()> {
    if !artifacts_ready() {
        return Ok(());
    }
    let run = |name: &str| -> Result<f64> {
        let cfg = micro_cfg(name, 12);
        let out = cfg.out_dir.clone();
        let r = Trainer::new(cfg)?.run()?;
        std::fs::remove_dir_all(out).ok();
        Ok(r.summary.final_train_loss)
    };
    let a = run("itest_det_a")?;
    let b = run("itest_det_b")?;
    assert_eq!(a, b, "same seed must give identical runs");
    Ok(())
}

#[test]
fn trace_tracking_reports_gap_ge_one() -> Result<()> {
    if !artifacts_ready() {
        return Ok(());
    }
    let mut cfg = micro_cfg("itest_traces", 10);
    cfg.track_traces = true;
    cfg.trace_every = 2;
    let out = cfg.out_dir.clone();
    let result = Trainer::new(cfg)?.run()?;
    let tr = result.trace_report.expect("trace report present");
    assert!(tr.ratio >= 1.0 - 1e-6, "ratio {} < 1", tr.ratio);
    assert!(tr.trace_h.is_finite() && tr.trace_h > 0.0);
    std::fs::remove_dir_all(out).ok();
    Ok(())
}

#[test]
fn checkpoint_roundtrip_preserves_state() -> Result<()> {
    if !artifacts_ready() {
        return Ok(());
    }
    let dir = extensor::runtime::default_artifact_dir();
    let client = Client::cpu()?;
    let engine = Engine::load(&client, &dir, "lm_micro_et2")?;
    let mut state = engine.init_state(3)?;
    let tokens: Vec<i32> = (0..32).map(|i| 1 + (i * 7 % 60) as i32).collect();
    for _ in 0..3 {
        engine.train_step_tokens(&mut state, &tokens, 0.05)?;
    }
    let path = std::env::temp_dir().join(format!("etck-{}.ck", std::process::id()));
    checkpoint::save(&engine, &state, &path)?;
    let restored = checkpoint::load(&engine, &path)?;
    assert_eq!(restored.step, state.step);

    // One more identical step from both must produce identical losses.
    let mut a = state;
    let mut b = restored;
    let la = engine.train_step_tokens(&mut a, &tokens, 0.05)?.loss;
    let lb = engine.train_step_tokens(&mut b, &tokens, 0.05)?.loss;
    assert_eq!(la, lb, "checkpoint round-trip diverged");
    std::fs::remove_file(&path).ok();
    Ok(())
}

#[test]
fn checkpoint_rejects_wrong_model() -> Result<()> {
    if !artifacts_ready() {
        return Ok(());
    }
    let dir = extensor::runtime::default_artifact_dir();
    let client = Client::cpu()?;
    let et2 = Engine::load(&client, &dir, "lm_micro_et2")?;
    // ET1 has a different opt-state layout than ET2 (one accumulator per
    // natural axis vs per split factor) -> load must fail loudly.
    // (ET2 vs ET3 coincide at micro scale: all factors are already <= 10.)
    let et1 = Engine::load(&client, &dir, "lm_micro_et1")?;
    let state = et2.init_state(1)?;
    let path = std::env::temp_dir().join(format!("etck-x-{}.ck", std::process::id()));
    checkpoint::save(&et2, &state, &path)?;
    assert!(checkpoint::load(&et1, &path).is_err());
    std::fs::remove_file(&path).ok();
    Ok(())
}

#[test]
fn vision_loop_learns() -> Result<()> {
    if !artifacts_ready() {
        return Ok(());
    }
    if !extensor::runtime::default_artifact_dir().join("cnn_et2.json").exists() {
        eprintln!("skip: cnn artifacts not built");
        return Ok(());
    }
    let client = Client::cpu()?;
    let data_cfg = extensor::vision::VisionConfig {
        classes: 10,
        train: 640,
        test: 128,
        blobs: 5,
        noise: 0.3,
        mix_max: 0.0,
        seed: 5,
    };
    let mut t = extensor::train::vision::VisionTrainer::new(
        &client,
        &extensor::runtime::default_artifact_dir(),
        "et2",
        &data_cfg,
    )?;
    let run = t.run(40, 0.05, 20, 11)?;
    assert!(run.final_train_loss.is_finite());
    // 10 classes, chance error 0.9; a short run should already beat it
    assert!(
        run.best_test_error < 0.82,
        "vision model failed to learn: err {}",
        run.best_test_error
    );
    Ok(())
}

//! Malformed-input hardening for the three untrusted-byte decoders:
//! the wire worker-spec frame, the ETSS state stream, and the ETHC host
//! checkpoint — plus the codec primitives under them. The contract under
//! test: arbitrary bytes produce `Ok` or a typed `Err`, never a panic and
//! never an implausible allocation.
//!
//! The fixed cases are the checked-in fuzz seed corpora under
//! `rust/fuzz/corpus/` — the same files CI's fuzz-smoke job mutates on
//! nightly are asserted byte-for-byte here on stable, so a corpus seed
//! that regresses fails every build, not just the fuzz job.

use extensor::optim::stream::read_export_stream;
use extensor::optim::GroupSpec;
use extensor::testing::prop::props;
use extensor::train::checkpoint::read_host;
use extensor::transport::wire::{read_worker_spec, ProtocolViolation};
use extensor::util::codec;

fn corpus_dir(target: &str) -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("fuzz/corpus").join(target)
}

fn corpus(target: &str) -> Vec<(String, Vec<u8>)> {
    let dir = corpus_dir(target);
    let mut out: Vec<(String, Vec<u8>)> = std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("fuzz corpus missing at {dir:?}: {e}"))
        .map(|e| {
            let e = e.unwrap();
            let name = e.file_name().to_string_lossy().into_owned();
            (name, std::fs::read(e.path()).unwrap())
        })
        .collect();
    out.sort();
    assert!(!out.is_empty(), "empty fuzz corpus at {dir:?}");
    out
}

fn ethc_groups() -> Vec<GroupSpec> {
    // Must match the layout baked into fuzz_targets/ethc_checkpoint.rs.
    vec![GroupSpec::new("w", &[4, 3]), GroupSpec::new("b", &[3])]
}

#[test]
fn wire_corpus_seeds_decode_as_expected() {
    for (name, bytes) in corpus("wire_frame") {
        let res = read_worker_spec(&mut bytes.as_slice());
        if name.starts_with("uniform_spec") {
            res.unwrap_or_else(|e| panic!("seed {name} must decode: {e:#}"));
        } else {
            let err = res.err().unwrap_or_else(|| panic!("seed {name} must be rejected"));
            if name.starts_with("oversized") || name.starts_with("unknown_tag") {
                assert!(
                    err.chain().any(|c| c.downcast_ref::<ProtocolViolation>().is_some()),
                    "seed {name}: expected a typed ProtocolViolation, got {err:#}"
                );
            }
        }
    }
}

#[test]
fn etss_corpus_seeds_decode_as_expected() {
    for (name, bytes) in corpus("etss_stream") {
        let res = read_export_stream(&mut bytes.as_slice(), 1 << 16);
        if name.starts_with("valid") {
            let export = res.unwrap_or_else(|e| panic!("seed {name} must decode: {e:#}"));
            assert_eq!(export.groups.len(), 2);
            assert_eq!(export.step, 5);
        } else {
            assert!(res.is_err(), "seed {name} must be rejected");
        }
    }
}

#[test]
fn ethc_corpus_seeds_decode_as_expected() {
    let groups = ethc_groups();
    for (name, bytes) in corpus("ethc_checkpoint") {
        let res = read_host(&groups, &mut bytes.as_slice());
        if name.starts_with("valid") {
            let (params, state, step) =
                res.unwrap_or_else(|e| panic!("seed {name} must decode: {e:#}"));
            assert_eq!(params.len(), 2);
            assert_eq!(params[0].len(), 12);
            assert_eq!(state.groups.len(), 2);
            assert_eq!(step, 7);
        } else {
            assert!(res.is_err(), "seed {name} must be rejected");
        }
    }
}

/// Every proper prefix of a valid frame is a clean error: the decoders hit
/// EOF (or a checksum mismatch) and report it — no panic, no partial Ok.
#[test]
fn every_truncation_of_valid_inputs_errors_cleanly() {
    let (_, spec) = corpus("wire_frame")
        .into_iter()
        .find(|(n, _)| n.starts_with("uniform_spec"))
        .unwrap();
    for cut in 0..spec.len() {
        assert!(
            read_worker_spec(&mut &spec[..cut]).is_err(),
            "spec prefix of {cut}/{} bytes decoded",
            spec.len()
        );
    }

    let (_, stream) =
        corpus("etss_stream").into_iter().find(|(n, _)| n.starts_with("valid")).unwrap();
    for cut in 0..stream.len() {
        assert!(
            read_export_stream(&mut &stream[..cut], 1 << 16).is_err(),
            "stream prefix of {cut}/{} bytes decoded",
            stream.len()
        );
    }

    let groups = ethc_groups();
    let (_, ck) =
        corpus("ethc_checkpoint").into_iter().find(|(n, _)| n.starts_with("valid")).unwrap();
    for cut in 0..ck.len() {
        assert!(
            read_host(&groups, &mut &ck[..cut]).is_err(),
            "checkpoint prefix of {cut}/{} bytes decoded",
            ck.len()
        );
    }
}

/// Random corruption of valid frames never panics. Flips inside
/// checksum-covered regions must be *detected* (Err); flips elsewhere may
/// legitimately decode, so only the no-panic contract is asserted.
#[test]
fn random_bit_flips_never_panic() {
    let (_, spec) = corpus("wire_frame")
        .into_iter()
        .find(|(n, _)| n.starts_with("uniform_spec"))
        .unwrap();
    let (_, stream) =
        corpus("etss_stream").into_iter().find(|(n, _)| n.starts_with("valid")).unwrap();
    let (_, ck) =
        corpus("ethc_checkpoint").into_iter().find(|(n, _)| n.starts_with("valid")).unwrap();
    let groups = ethc_groups();

    props("bit_flips_never_panic", 300, |g| {
        let (which, base) = match g.usize_in(0, 2) {
            0 => (0, &spec),
            1 => (1, &stream),
            _ => (2, &ck),
        };
        let mut bytes = base.clone();
        for _ in 0..g.usize_in(1, 3) {
            let i = g.usize_in(0, bytes.len() - 1);
            let bit = g.usize_in(0, 7);
            bytes[i] ^= 1 << bit;
        }
        match which {
            0 => {
                let _ = read_worker_spec(&mut bytes.as_slice());
            }
            1 => {
                let _ = read_export_stream(&mut bytes.as_slice(), 1 << 16);
            }
            _ => {
                let _ = read_host(&groups, &mut bytes.as_slice());
            }
        }
    });
}

/// Pure random garbage never panics and (except for the degenerate empty
/// prefix cases) never decodes.
#[test]
fn random_garbage_never_panics() {
    let groups = ethc_groups();
    props("garbage_never_panics", 300, |g| {
        let n = g.usize_in(0, 512);
        let mut bytes = vec![0u8; n];
        for b in bytes.iter_mut() {
            *b = g.usize_in(0, 255) as u8;
        }
        assert!(read_worker_spec(&mut bytes.as_slice()).is_err() || n >= 8);
        let _ = read_export_stream(&mut bytes.as_slice(), 1 << 16);
        let _ = read_host(&groups, &mut bytes.as_slice());
    });
}

/// Codec primitives reject implausible or malformed payloads with typed
/// errors before allocating.
#[test]
fn codec_rejects_malformed_payloads() {
    // String length beyond the cap.
    let mut buf = Vec::new();
    codec::write_u32(&mut buf, u32::MAX).unwrap();
    buf.extend_from_slice(b"xx");
    assert!(codec::read_str(&mut buf.as_slice()).is_err());

    // Valid length prefix, non-UTF-8 payload.
    let mut buf = Vec::new();
    codec::write_u32(&mut buf, 2).unwrap();
    buf.extend_from_slice(&[0xff, 0xfe]);
    assert!(codec::read_str(&mut buf.as_slice()).is_err());

    // f32 block declaring more scalars than the caller's bound.
    let mut buf = Vec::new();
    codec::write_f32s(&mut buf, &[1.0, 2.0, 3.0, 4.0]).unwrap();
    assert!(codec::read_f32s(&mut buf.as_slice(), 3).is_err());
    assert_eq!(codec::read_f32s(&mut buf.as_slice(), 4).unwrap().len(), 4);

    // Truncated scalar reads.
    assert!(codec::read_u64(&mut [0u8; 3].as_slice()).is_err());
    assert!(codec::read_f32(&mut [0u8; 2].as_slice()).is_err());

    // Truncated f32 payload behind an honest count.
    let mut buf = Vec::new();
    codec::write_f32s(&mut buf, &[1.0, 2.0, 3.0, 4.0]).unwrap();
    buf.truncate(buf.len() - 5);
    assert!(codec::read_f32s(&mut buf.as_slice(), 8).is_err());
}

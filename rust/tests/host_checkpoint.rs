//! Shard-aware host-optimizer checkpointing, end to end: run the sharded
//! engine, snapshot params + externalized optimizer state to disk
//! (`checkpoint::save_host`), restore into a *fresh* engine
//! (`checkpoint::load_host` + `ShardedOptimizer::import_state`), and
//! assert training continues **bitwise-identically** to an uninterrupted
//! run — for every optimizer kind, at `run.shards` ∈ {1, 2, 4}, and across
//! shard-count changes (a snapshot taken at 2 shards restores at 1 or 4).
//!
//! No artifacts required: this drives the pure-rust suite on seeded
//! synthetic gradients, exactly like `sharded_parity.rs`.

use extensor::optim::{GroupSpec, Hyper, Optimizer};
use extensor::shard::ShardedOptimizer;
use extensor::tensoring::OptimizerKind;
use extensor::train::checkpoint;
use extensor::util::rng::Pcg64;
use std::path::PathBuf;

fn groups() -> Vec<GroupSpec> {
    vec![
        GroupSpec::new("embed", &[50, 16]),
        GroupSpec::new("wq", &[16, 16]),
        GroupSpec::new("ln1", &[16]),
        GroupSpec::new("ff1", &[16, 32]),
        GroupSpec::new("ff1b", &[32]),
        GroupSpec::new("conv", &[8, 4, 3, 3]),
        GroupSpec::new("ln_f", &[16]),
    ]
}

fn all_kinds() -> Vec<OptimizerKind> {
    vec![
        OptimizerKind::Sgd,
        OptimizerKind::AdaGrad,
        OptimizerKind::Adam,
        OptimizerKind::RmsProp,
        OptimizerKind::AdaDelta,
        OptimizerKind::Adafactor,
        OptimizerKind::Et(1),
        OptimizerKind::Et(2),
        OptimizerKind::Et(3),
        OptimizerKind::EtInf,
    ]
}

fn grad_stream(gs: &[GroupSpec], steps: usize, seed: u64) -> Vec<Vec<Vec<f32>>> {
    let mut rng = Pcg64::seeded(seed);
    (0..steps)
        .map(|_| {
            gs.iter()
                .map(|g| {
                    let mut v = vec![0.0f32; g.numel()];
                    rng.fill_normal(&mut v, 1.0);
                    v
                })
                .collect()
        })
        .collect()
}

fn init_params(gs: &[GroupSpec]) -> Vec<Vec<f32>> {
    let mut rng = Pcg64::seeded(0xBEEF);
    gs.iter()
        .map(|g| {
            let mut v = vec![0.0f32; g.numel()];
            rng.fill_uniform(&mut v, -0.5, 0.5);
            v
        })
        .collect()
}

fn tmp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("ethc-it-{}-{tag}.hck", std::process::id()))
}

/// Uninterrupted reference trajectory.
fn run_uninterrupted(
    kind: OptimizerKind,
    gs: &[GroupSpec],
    stream: &[Vec<Vec<f32>>],
    lr: f32,
    shards: usize,
) -> Vec<Vec<f32>> {
    let mut opt = ShardedOptimizer::new(kind, gs, &Hyper::default(), shards).unwrap();
    let mut params = init_params(gs);
    for grads in stream {
        opt.next_step();
        opt.step_all(&mut params, grads, lr).unwrap();
    }
    params
}

/// Run `split` steps, checkpoint to disk, tear everything down, restore
/// into a fresh engine with `restore_shards` workers, finish the stream.
fn run_with_restart(
    kind: OptimizerKind,
    gs: &[GroupSpec],
    stream: &[Vec<Vec<f32>>],
    lr: f32,
    save_shards: usize,
    restore_shards: usize,
    tag: &str,
) -> Vec<Vec<f32>> {
    let path = tmp_path(tag);
    let split = stream.len() / 2;
    {
        let mut opt = ShardedOptimizer::new(kind, gs, &Hyper::default(), save_shards).unwrap();
        let mut params = init_params(gs);
        for grads in &stream[..split] {
            opt.next_step();
            opt.step_all(&mut params, grads, lr).unwrap();
        }
        let state = opt.export_state().unwrap();
        checkpoint::save_host(gs, &params, &state, split as u64, &path).unwrap();
        // Engine dropped here: workers shut down, state only lives on disk.
    }
    let (mut params, state, step) = checkpoint::load_host(gs, &path).unwrap();
    assert_eq!(step, split as u64);
    let mut opt = ShardedOptimizer::new(kind, gs, &Hyper::default(), restore_shards).unwrap();
    opt.import_state(&state).unwrap();
    for grads in &stream[split..] {
        opt.next_step();
        opt.step_all(&mut params, grads, lr).unwrap();
    }
    std::fs::remove_file(&path).ok();
    params
}

/// The satellite acceptance test: save/load at 1, 2, and 4 shards; the
/// restarted run must be bitwise-identical to the uninterrupted one for
/// every optimizer kind.
#[test]
fn checkpoint_roundtrip_is_bitwise_at_1_2_4_shards() {
    let gs = groups();
    let stream = grad_stream(&gs, 6, 41);
    for kind in all_kinds() {
        let lr = if kind == OptimizerKind::AdaDelta { 1.0 } else { 0.05 };
        for shards in [1usize, 2, 4] {
            let want = run_uninterrupted(kind, &gs, &stream, lr, shards);
            let got = run_with_restart(
                kind,
                &gs,
                &stream,
                lr,
                shards,
                shards,
                &format!("{kind:?}-{shards}"),
            );
            assert_eq!(
                want, got,
                "kind {kind:?} at {shards} shards: restart diverged from uninterrupted run"
            );
        }
    }
}

/// A checkpoint is shard-count independent: saved at 2 shards, restored at
/// 1 and 4 (the uninterrupted reference is itself shard-count invariant by
/// the parity contract, so any mismatch is the checkpoint path's fault).
#[test]
fn checkpoint_migrates_across_shard_counts() {
    let gs = groups();
    let stream = grad_stream(&gs, 6, 43);
    for kind in [OptimizerKind::Adam, OptimizerKind::Et(2), OptimizerKind::EtInf] {
        let want = run_uninterrupted(kind, &gs, &stream, 0.05, 2);
        for restore_shards in [1usize, 4] {
            let got = run_with_restart(
                kind,
                &gs,
                &stream,
                0.05,
                2,
                restore_shards,
                &format!("mig-{kind:?}-{restore_shards}"),
            );
            assert_eq!(
                want, got,
                "kind {kind:?}: 2-shard checkpoint restored at {restore_shards} diverged"
            );
        }
    }
}

/// A checkpoint from one optimizer kind must not restore into another.
#[test]
fn checkpoint_rejects_wrong_kind() {
    let gs = groups();
    let stream = grad_stream(&gs, 2, 47);
    let path = tmp_path("wrong-kind");
    {
        let mut opt =
            ShardedOptimizer::new(OptimizerKind::Adam, &gs, &Hyper::default(), 2).unwrap();
        let mut params = init_params(&gs);
        for grads in &stream {
            opt.next_step();
            opt.step_all(&mut params, grads, 0.05).unwrap();
        }
        let state = opt.export_state().unwrap();
        checkpoint::save_host(&gs, &params, &state, 2, &path).unwrap();
    }
    let (_, state, _) = checkpoint::load_host(&gs, &path).unwrap();
    let mut other =
        ShardedOptimizer::new(OptimizerKind::AdaGrad, &gs, &Hyper::default(), 2).unwrap();
    assert!(other.import_state(&state).is_err());
    std::fs::remove_file(&path).ok();
}

//! Golden-value parity for the externalized-state refactor: for every
//! `OptimizerKind`, the new `OptState`-backed `step` must reproduce the
//! pre-refactor update **bitwise** on fixed seeded inputs.
//!
//! The goldens are captured as code, not numbers: the `reference` module
//! below is the pre-refactor embedded-state arithmetic, copied verbatim
//! from the seed optimizers (same loop structure, same operation order —
//! float summation order matters for bitwise equality). Comparing against
//! a re-run of the old arithmetic instead of hard-coded vectors keeps the
//! test exact on any platform/libm.

use extensor::optim::{self, GroupSpec, Hyper, Optimizer};
use extensor::tensoring::OptimizerKind;
use extensor::util::rng::Pcg64;

/// Pre-refactor update rules, verbatim. One struct per kind, each owning
/// its state privately — exactly the shape the suite had before the
/// externalized-state API.
mod reference {
    use extensor::optim::GroupSpec;
    use extensor::tensoring::{natural_dims, plan, Level};
    use extensor::util::math::sq_norm;

    pub trait RefOptimizer {
        fn step(&mut self, gi: usize, x: &mut [f32], g: &[f32], lr: f32);
        fn next_step(&mut self) {}
        fn state_scalars(&self) -> usize;
    }

    pub struct Sgd;

    impl RefOptimizer for Sgd {
        fn step(&mut self, _gi: usize, x: &mut [f32], g: &[f32], lr: f32) {
            for (xi, &gi_) in x.iter_mut().zip(g) {
                *xi -= lr * gi_;
            }
        }
        fn state_scalars(&self) -> usize {
            0
        }
    }

    pub struct AdaGrad {
        eps: f32,
        s: Vec<Vec<f32>>,
    }

    impl AdaGrad {
        pub fn new(groups: &[GroupSpec], eps: f32) -> Self {
            AdaGrad { eps, s: groups.iter().map(|g| vec![0.0; g.numel()]).collect() }
        }
    }

    impl RefOptimizer for AdaGrad {
        fn step(&mut self, gi: usize, x: &mut [f32], g: &[f32], lr: f32) {
            let s = &mut self.s[gi];
            for i in 0..s.len() {
                s[i] += g[i] * g[i];
                x[i] -= lr * g[i] / (self.eps + s[i]).sqrt();
            }
        }
        fn state_scalars(&self) -> usize {
            self.s.iter().map(|v| v.len()).sum()
        }
    }

    pub struct Adam {
        beta1: f32,
        beta2: f32,
        eps: f32,
        t: u64,
        m: Vec<Vec<f32>>,
        v: Vec<Vec<f32>>,
    }

    impl Adam {
        pub fn new(groups: &[GroupSpec], beta1: f32, beta2: f32, eps: f32) -> Self {
            Adam {
                beta1,
                beta2,
                eps,
                t: 0,
                m: groups.iter().map(|g| vec![0.0; g.numel()]).collect(),
                v: groups.iter().map(|g| vec![0.0; g.numel()]).collect(),
            }
        }
    }

    impl RefOptimizer for Adam {
        fn step(&mut self, gi: usize, x: &mut [f32], g: &[f32], lr: f32) {
            let (m, v) = (&mut self.m[gi], &mut self.v[gi]);
            let t = self.t.max(1) as i32;
            let bc1 = 1.0 - self.beta1.powi(t);
            let bc2 = 1.0 - self.beta2.powi(t);
            for i in 0..m.len() {
                m[i] = self.beta1 * m[i] + (1.0 - self.beta1) * g[i];
                v[i] = self.beta2 * v[i] + (1.0 - self.beta2) * g[i] * g[i];
                let mhat = m[i] / bc1;
                let vhat = v[i] / bc2;
                x[i] -= lr * mhat / (vhat.sqrt() + self.eps);
            }
        }
        fn next_step(&mut self) {
            self.t += 1;
        }
        fn state_scalars(&self) -> usize {
            self.m.iter().map(|v| v.len()).sum::<usize>() * 2
        }
    }

    pub struct RmsProp {
        beta2: f32,
        eps: f32,
        v: Vec<Vec<f32>>,
    }

    impl RmsProp {
        pub fn new(groups: &[GroupSpec], beta2: f32, eps: f32) -> Self {
            RmsProp { beta2, eps, v: groups.iter().map(|g| vec![0.0; g.numel()]).collect() }
        }
    }

    impl RefOptimizer for RmsProp {
        fn step(&mut self, gi: usize, x: &mut [f32], g: &[f32], lr: f32) {
            let v = &mut self.v[gi];
            for i in 0..v.len() {
                v[i] = self.beta2 * v[i] + (1.0 - self.beta2) * g[i] * g[i];
                x[i] -= lr * g[i] / (v[i].sqrt() + self.eps);
            }
        }
        fn state_scalars(&self) -> usize {
            self.v.iter().map(|v| v.len()).sum()
        }
    }

    pub struct AdaDelta {
        rho: f32,
        eps: f32,
        eg2: Vec<Vec<f32>>,
        ex2: Vec<Vec<f32>>,
    }

    impl AdaDelta {
        pub fn new(groups: &[GroupSpec], rho: f32, eps: f32) -> Self {
            AdaDelta {
                rho,
                eps,
                eg2: groups.iter().map(|g| vec![0.0; g.numel()]).collect(),
                ex2: groups.iter().map(|g| vec![0.0; g.numel()]).collect(),
            }
        }
    }

    impl RefOptimizer for AdaDelta {
        fn step(&mut self, gi: usize, x: &mut [f32], g: &[f32], lr: f32) {
            let (eg2, ex2) = (&mut self.eg2[gi], &mut self.ex2[gi]);
            for i in 0..eg2.len() {
                eg2[i] = self.rho * eg2[i] + (1.0 - self.rho) * g[i] * g[i];
                let dx = ((ex2[i] + self.eps) / (eg2[i] + self.eps)).sqrt() * g[i];
                ex2[i] = self.rho * ex2[i] + (1.0 - self.rho) * dx * dx;
                x[i] -= lr * dx;
            }
        }
        fn state_scalars(&self) -> usize {
            self.eg2.iter().map(|v| v.len()).sum::<usize>() * 2
        }
    }

    enum FactorState {
        Factored { rows: usize, cols: usize, r: Vec<f32>, c: Vec<f32> },
        Full(Vec<f32>),
    }

    pub struct Adafactor {
        beta2: Option<f32>,
        eps: f32,
        state: Vec<FactorState>,
    }

    impl Adafactor {
        pub fn new(groups: &[GroupSpec], beta2: Option<f32>, eps: f32) -> Self {
            let state = groups
                .iter()
                .map(|g| {
                    let nat = natural_dims(&g.shape);
                    if nat.len() >= 2 {
                        let cols = nat[nat.len() - 1];
                        let rows: usize = nat[..nat.len() - 1].iter().product();
                        FactorState::Factored {
                            rows,
                            cols,
                            r: vec![0.0; rows],
                            c: vec![0.0; cols],
                        }
                    } else {
                        FactorState::Full(vec![0.0; g.numel()])
                    }
                })
                .collect();
            Adafactor { beta2, eps, state }
        }
    }

    impl RefOptimizer for Adafactor {
        fn step(&mut self, gi: usize, x: &mut [f32], g: &[f32], lr: f32) {
            match &mut self.state[gi] {
                FactorState::Full(v) => {
                    for i in 0..v.len() {
                        let sq = g[i] * g[i];
                        v[i] = match self.beta2 {
                            Some(b2) => b2 * v[i] + (1.0 - b2) * sq,
                            None => v[i] + sq,
                        };
                        x[i] -= lr * g[i] / (v[i] + self.eps).sqrt();
                    }
                }
                FactorState::Factored { rows, cols, r, c } => {
                    let (rows, cols) = (*rows, *cols);
                    let mut row_ms = vec![0.0f32; rows];
                    let mut col_ms = vec![0.0f32; cols];
                    for i in 0..rows {
                        let grow = &g[i * cols..(i + 1) * cols];
                        let mut acc = 0.0f32;
                        for (j, &v) in grow.iter().enumerate() {
                            let sq = v * v;
                            acc += sq;
                            col_ms[j] += sq;
                        }
                        row_ms[i] = acc / cols as f32;
                    }
                    for v in col_ms.iter_mut() {
                        *v /= rows as f32;
                    }
                    match self.beta2 {
                        Some(b2) => {
                            for i in 0..rows {
                                r[i] = b2 * r[i] + (1.0 - b2) * row_ms[i];
                            }
                            for j in 0..cols {
                                c[j] = b2 * c[j] + (1.0 - b2) * col_ms[j];
                            }
                        }
                        None => {
                            for i in 0..rows {
                                r[i] += row_ms[i];
                            }
                            for j in 0..cols {
                                c[j] += col_ms[j];
                            }
                        }
                    }
                    let mean_r: f32 = r.iter().sum::<f32>() / rows as f32;
                    let inv_mean_r = if mean_r > 0.0 { 1.0 / mean_r } else { 0.0 };
                    for i in 0..rows {
                        let ri = r[i] * inv_mean_r;
                        let xrow = &mut x[i * cols..(i + 1) * cols];
                        let grow = &g[i * cols..(i + 1) * cols];
                        for j in 0..cols {
                            let vhat = ri * c[j];
                            xrow[j] -= lr * grow[j] / (vhat + self.eps).sqrt();
                        }
                    }
                }
            }
        }
        fn state_scalars(&self) -> usize {
            self.state
                .iter()
                .map(|s| match s {
                    FactorState::Factored { r, c, .. } => r.len() + c.len(),
                    FactorState::Full(v) => v.len(),
                })
                .sum()
        }
    }

    /// `x^(-1/(2p))` exactly as the seed accumulator computed it.
    fn inv_root_2p(x: f32, p: usize) -> f32 {
        match p {
            1 => 1.0 / x.sqrt(),
            2 => 1.0 / x.sqrt().sqrt(),
            4 => 1.0 / x.sqrt().sqrt().sqrt(),
            8 => 1.0 / x.sqrt().sqrt().sqrt().sqrt(),
            _ => x.powf(-1.0 / (2.0 * p as f32)),
        }
    }

    /// Seed extreme tensoring (non-decayed, Algorithm-1 eps-inside-product
    /// form — the `Hyper::default()` configuration): slice-sum accumulate
    /// in the seed's exact branch/order structure, then the prefix-product
    /// preconditioner walk.
    pub struct ExtremeTensoring {
        eps: f32,
        dims: Vec<Vec<usize>>,
        s: Vec<Vec<Vec<f32>>>,
    }

    impl ExtremeTensoring {
        pub fn new(groups: &[GroupSpec], level: u8, eps: f32) -> Self {
            let dims: Vec<Vec<usize>> =
                groups.iter().map(|g| plan(&g.shape, Level::Et(level))).collect();
            let s = dims
                .iter()
                .map(|d| d.iter().map(|&di| vec![0.0f32; di]).collect())
                .collect();
            ExtremeTensoring { eps, dims, s }
        }
    }

    impl RefOptimizer for ExtremeTensoring {
        fn step(&mut self, gi: usize, x: &mut [f32], g: &[f32], lr: f32) {
            let dims = self.dims[gi].clone();
            let s = &mut self.s[gi];
            // accumulate (w = 1, no decay) — seed branch structure
            match dims.len() {
                1 => {
                    let s0 = &mut s[0];
                    for (j, &gj) in g.iter().enumerate() {
                        s0[j] += gj * gj;
                    }
                }
                2 => {
                    let (d0, d1) = (dims[0], dims[1]);
                    let (s01, s1x) = s.split_at_mut(1);
                    let (s0, s1) = (&mut s01[0], &mut s1x[0]);
                    for r in 0..d0 {
                        let row = &g[r * d1..(r + 1) * d1];
                        let mut acc = 0.0f32;
                        for (c, &grc) in row.iter().enumerate() {
                            let sq = grc * grc;
                            acc += sq;
                            s1[c] += sq;
                        }
                        s0[r] += acc;
                    }
                }
                _ => {
                    let p = dims.len();
                    let mut coords = vec![0usize; p];
                    for &gj in g.iter() {
                        let sq = gj * gj;
                        for i in 0..p {
                            s[i][coords[i]] += sq;
                        }
                        for i in (0..p).rev() {
                            coords[i] += 1;
                            if coords[i] < dims[i] {
                                break;
                            }
                            coords[i] = 0;
                        }
                    }
                }
            }
            // apply (InsideProduct eps, prefix-product walk) — seed order
            let p = dims.len();
            let n: usize = dims.iter().product();
            let mut coords = vec![0usize; p];
            let mut prefix = vec![0.0f32; p];
            let mut rebuild_from = 0usize;
            for j in 0..n {
                for i in rebuild_from..p {
                    let base = if i == 0 { 1.0 } else { prefix[i - 1] };
                    prefix[i] = base * s[i][coords[i]];
                }
                let denom = self.eps + prefix[p - 1];
                x[j] -= lr * inv_root_2p(denom, p) * g[j];
                rebuild_from = p;
                for i in (0..p).rev() {
                    coords[i] += 1;
                    if coords[i] < dims[i] {
                        rebuild_from = i;
                        break;
                    }
                    coords[i] = 0;
                }
            }
        }
        fn state_scalars(&self) -> usize {
            self.dims.iter().flatten().sum()
        }
    }

    pub struct EtInf {
        eps: f32,
        s: Vec<f64>,
    }

    impl EtInf {
        pub fn new(groups: &[GroupSpec], eps: f32) -> Self {
            EtInf { eps, s: vec![0.0; groups.len()] }
        }
    }

    impl RefOptimizer for EtInf {
        fn step(&mut self, gi: usize, x: &mut [f32], g: &[f32], lr: f32) {
            self.s[gi] += sq_norm(g);
            let rate = lr / (self.eps as f64 + self.s[gi]).sqrt() as f32;
            for (xi, &gj) in x.iter_mut().zip(g) {
                *xi -= rate * gj;
            }
        }
        fn state_scalars(&self) -> usize {
            self.s.len()
        }
    }
}

/// Transformer-flavored group mix: big matrices, a conv kernel, and a tail
/// of small vectors — exercises the 1-D, 2-D, and general-p accumulate
/// branches and Adafactor's factored + full paths.
fn groups() -> Vec<GroupSpec> {
    vec![
        GroupSpec::new("embed", &[50, 16]),
        GroupSpec::new("wq", &[16, 16]),
        GroupSpec::new("ln1", &[16]),
        GroupSpec::new("ff1", &[16, 32]),
        GroupSpec::new("ff1b", &[32]),
        GroupSpec::new("conv", &[8, 4, 3, 3]),
        GroupSpec::new("ln_f", &[16]),
    ]
}

fn grad_stream(gs: &[GroupSpec], steps: usize, seed: u64) -> Vec<Vec<Vec<f32>>> {
    let mut rng = Pcg64::seeded(seed);
    (0..steps)
        .map(|_| {
            gs.iter()
                .map(|g| {
                    let mut v = vec![0.0f32; g.numel()];
                    rng.fill_normal(&mut v, 1.0);
                    v
                })
                .collect()
        })
        .collect()
}

fn init_params(gs: &[GroupSpec], seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg64::seeded(seed ^ 0xA11CE);
    gs.iter()
        .map(|g| {
            let mut v = vec![0.0f32; g.numel()];
            rng.fill_uniform(&mut v, -0.5, 0.5);
            v
        })
        .collect()
}

/// Run the *new* externalized-state optimizer.
fn run_new(
    kind: OptimizerKind,
    gs: &[GroupSpec],
    stream: &[Vec<Vec<f32>>],
    lr: f32,
) -> Vec<Vec<f32>> {
    let mut opt = optim::build(kind, gs, &Hyper::default());
    let mut params = init_params(gs, 1);
    for grads in stream {
        opt.next_step();
        for (gi, (p, g)) in params.iter_mut().zip(grads).enumerate() {
            opt.step(gi, p, g, lr).unwrap();
        }
    }
    params
}

/// Run a pre-refactor reference implementation on the same inputs.
fn run_reference(
    opt: &mut dyn reference::RefOptimizer,
    gs: &[GroupSpec],
    stream: &[Vec<Vec<f32>>],
    lr: f32,
) -> Vec<Vec<f32>> {
    let mut params = init_params(gs, 1);
    for grads in stream {
        opt.next_step();
        for (gi, (p, g)) in params.iter_mut().zip(grads).enumerate() {
            opt.step(gi, p, g, lr);
        }
    }
    params
}

fn assert_bitwise_eq(kind: OptimizerKind, want: &[Vec<f32>], got: &[Vec<f32>]) {
    assert_eq!(want.len(), got.len(), "{kind:?}: group count");
    for (gi, (w, g)) in want.iter().zip(got).enumerate() {
        assert_eq!(w.len(), g.len(), "{kind:?}: group {gi} length");
        for (j, (a, b)) in w.iter().zip(g).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{kind:?}: group {gi} coord {j}: reference {a} vs new {b}"
            );
        }
    }
}

/// The satellite acceptance test: every kind, multi-step seeded run,
/// bitwise equality against the pre-refactor arithmetic. Resolved
/// hyperparameters mirror `optim::build` under `Hyper::default()`
/// (beta2 = 0.999 everywhere it applies, eps = 1e-8, ET non-decayed).
#[test]
fn externalized_state_matches_pre_refactor_bitwise() {
    let gs = groups();
    let stream = grad_stream(&gs, 5, 7);
    let eps = Hyper::EPS;
    let b2 = Hyper::ADAM_BETA2;
    let cases: Vec<(OptimizerKind, Box<dyn reference::RefOptimizer>, f32)> = vec![
        (OptimizerKind::Sgd, Box::new(reference::Sgd), 0.05),
        (OptimizerKind::AdaGrad, Box::new(reference::AdaGrad::new(&gs, eps)), 0.05),
        (OptimizerKind::Adam, Box::new(reference::Adam::new(&gs, Hyper::BETA1, b2, eps)), 0.05),
        (OptimizerKind::RmsProp, Box::new(reference::RmsProp::new(&gs, b2, eps)), 0.05),
        (OptimizerKind::AdaDelta, Box::new(reference::AdaDelta::new(&gs, b2, eps)), 1.0),
        (OptimizerKind::Adafactor, Box::new(reference::Adafactor::new(&gs, Some(b2), eps)), 0.05),
        (OptimizerKind::Et(1), Box::new(reference::ExtremeTensoring::new(&gs, 1, eps)), 0.05),
        (OptimizerKind::Et(2), Box::new(reference::ExtremeTensoring::new(&gs, 2, eps)), 0.05),
        (OptimizerKind::Et(3), Box::new(reference::ExtremeTensoring::new(&gs, 3, eps)), 0.05),
        (OptimizerKind::EtInf, Box::new(reference::EtInf::new(&gs, eps)), 0.05),
    ];
    for (kind, mut reference_opt, lr) in cases {
        let want = run_reference(reference_opt.as_mut(), &gs, &stream, lr);
        let got = run_new(kind, &gs, &stream, lr);
        assert_bitwise_eq(kind, &want, &got);
        let new_opt = optim::build(kind, &gs, &Hyper::default());
        assert_eq!(
            new_opt.state_scalars(),
            reference_opt.state_scalars(),
            "{kind:?}: state accounting drifted"
        );
    }
}

/// The batched `step_all` path must be bitwise-equal to the reference too
/// (it is the path the trainer and shard workers actually run).
#[test]
fn step_all_matches_pre_refactor_bitwise() {
    let gs = groups();
    let stream = grad_stream(&gs, 4, 13);
    for (kind, lr) in [
        (OptimizerKind::AdaGrad, 0.05f32),
        (OptimizerKind::Adam, 0.05),
        (OptimizerKind::Et(2), 0.05),
        (OptimizerKind::EtInf, 0.05),
    ] {
        let mut reference_opt: Box<dyn reference::RefOptimizer> = match kind {
            OptimizerKind::AdaGrad => Box::new(reference::AdaGrad::new(&gs, Hyper::EPS)),
            OptimizerKind::Adam => {
                Box::new(reference::Adam::new(&gs, Hyper::BETA1, Hyper::ADAM_BETA2, Hyper::EPS))
            }
            OptimizerKind::Et(2) => Box::new(reference::ExtremeTensoring::new(&gs, 2, Hyper::EPS)),
            _ => Box::new(reference::EtInf::new(&gs, Hyper::EPS)),
        };
        let want = run_reference(reference_opt.as_mut(), &gs, &stream, lr);

        let mut opt = optim::build(kind, &gs, &Hyper::default());
        let mut got = init_params(&gs, 1);
        for grads in &stream {
            opt.next_step();
            opt.step_all(&mut got, grads, lr).unwrap();
        }
        assert_bitwise_eq(kind, &want, &got);
    }
}

//! Allocation regression for the optimizer hot path: after a warm-up pass
//! (which grows the `OptState`-owned scratch arena to its high-water mark),
//! a full `step_all` over every optimizer kind performs **zero** heap
//! allocations —
//! under both the dense `f32` and the block-quantized `q8` state backend.
//!
//! The counter is a thread-local inside a wrapping global allocator, so
//! only allocations made by *this* test's thread are counted (the harness
//! may run other threads). `Cell<u64>` is const-initialized and has no
//! destructor, so the counter itself never allocates or recurses.
//!
//! The matrix runs twice: tracing disabled (the original PR-8 contract)
//! and tracing **enabled** — the span record path (ring slot write +
//! histogram updates) must itself be allocation-free after the thread's
//! ring registers during warm-up. The two tests share a gate mutex
//! because the trace enable flag is process-global.

use extensor::optim::{self, GroupSpec, Hyper, Optimizer};
use extensor::tensoring::{OptimizerKind, StateBackend};
use extensor::trace;
use extensor::util::rng::Pcg64;
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::{Mutex, MutexGuard, OnceLock, PoisonError};

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

// SAFETY: every method delegates to `System`, which upholds the
// `GlobalAlloc` contract; the only addition is a thread-local counter bump,
// which neither allocates (const-init `Cell`, no destructor) nor unwinds.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: caller's layout contract is forwarded to `System` unchanged.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    // SAFETY: `ptr`/`layout` came from this allocator, which is `System`.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    // SAFETY: `ptr`/`layout` came from this allocator, which is `System`.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }

    // SAFETY: caller's layout contract is forwarded to `System` unchanged.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.with(|c| c.get())
}

/// Transformer-flavored groups, deliberately including a general-p conv
/// shape so the chunked accumulate path (not just the 1-D/2-D fast paths)
/// is exercised.
fn groups() -> Vec<GroupSpec> {
    vec![
        GroupSpec::new("embed", &[200, 64]),
        GroupSpec::new("wq", &[64, 64]),
        GroupSpec::new("ln", &[64]),
        GroupSpec::new("conv", &[8, 4, 3, 3]),
    ]
}

/// Serialize the traced and untraced matrices: the trace enable flag is
/// process-global, so the other test's window must not leak in.
fn gate() -> MutexGuard<'static, ()> {
    static GATE: OnceLock<Mutex<()>> = OnceLock::new();
    GATE.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(PoisonError::into_inner)
}

/// The zero-alloc matrix: every optimizer kind × both state backends,
/// 3 warm-up steps then 5 counted steady-state steps, asserting zero
/// allocations. `label` names the tracing mode in failure messages.
fn assert_step_all_matrix_alloc_free(label: &str) {
    let gs = groups();
    let mut rng = Pcg64::seeded(42);
    let grads: Vec<Vec<f32>> = gs
        .iter()
        .map(|g| {
            let mut v = vec![0.0f32; g.numel()];
            rng.fill_normal(&mut v, 1.0);
            v
        })
        .collect();

    // Every kind, not just ET: after the `with_buf1_in`/`with_buf2_in`
    // refactor the classical baselines are allocation-free too (Adafactor's
    // row/col mean-squares live in `StepScratch`, not per-step Vecs).
    let kinds = [
        OptimizerKind::Sgd,
        OptimizerKind::AdaGrad,
        OptimizerKind::Adam,
        OptimizerKind::RmsProp,
        OptimizerKind::AdaDelta,
        OptimizerKind::Adafactor,
        OptimizerKind::Et(1),
        OptimizerKind::Et(2),
        OptimizerKind::Et(3),
        OptimizerKind::EtInf,
    ];
    for backend in [StateBackend::DenseF32, StateBackend::q8()] {
        for kind in kinds {
            let hyper = Hyper { backend, ..Hyper::default() };
            let mut opt = optim::build_state(kind, &gs, &hyper);
            let mut params: Vec<Vec<f32>> =
                gs.iter().map(|g| vec![0.1f32; g.numel()]).collect();
            // Warm-up: grows the scratch arena (kernel buffers + q8 decode
            // vectors) to its high-water mark across all groups — and, when
            // tracing, registers this thread's span ring (the one
            // allocating step of the record path).
            for _ in 0..3 {
                opt.next_step();
                opt.step_all(&mut params, &grads, 1e-3).unwrap();
            }
            // Steady state: zero heap allocations over several full steps.
            let before = allocs();
            for _ in 0..5 {
                opt.next_step();
                opt.step_all(&mut params, &grads, 1e-3).unwrap();
            }
            let after = allocs();
            assert_eq!(
                after - before,
                0,
                "{kind:?} under {backend:?} ({label}): {} allocations in 5 steady-state steps",
                after - before
            );
        }
    }
}

#[test]
fn et_step_all_is_allocation_free_after_warmup() {
    let _g = gate();
    trace::disable();
    assert_step_all_matrix_alloc_free("tracing off");
}

/// The PR-10 extension of the contract: `step_all` stays zero-alloc with
/// tracing **enabled** — recording a span is a TLS read, an uncontended
/// lock, and fixed array writes once the ring exists.
#[test]
fn et_step_all_is_allocation_free_with_tracing_enabled() {
    let _g = gate();
    trace::enable();
    assert_step_all_matrix_alloc_free("tracing on");
    trace::disable();
    // Sanity: the window actually recorded optimizer spans.
    let recorded = trace::snapshot().kind_summary(extensor::trace::SpanKind::OptimStep).count;
    assert!(recorded > 0, "tracing was enabled but recorded no optim_step spans");
    trace::drain();
}

/// The counter itself must observe ordinary allocations, or the zero
/// assertion above would be vacuous.
#[test]
fn counter_sees_allocations() {
    let before = allocs();
    let v: Vec<u64> = (0..100).collect();
    std::hint::black_box(&v);
    let after = allocs();
    assert!(after > before, "counting allocator not engaged");
}

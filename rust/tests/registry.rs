//! Run-registry contract tests (artifact-free, convex workloads only):
//!
//! * **Determinism** — a recorded `spec_toml` re-executed on a fresh
//!   session reproduces the recorded metrics bitwise: the registry is a
//!   replayable experiment log, not just bookkeeping.
//! * **Completeness** — `run_batch` with `registry_dir` set writes exactly
//!   one `registry/v1` record per job, prefailed jobs included, and the
//!   records re-load through both encodings.
//! * **Codec** — the CSV mirror round-trips cells carrying commas,
//!   quotes, and newlines (spec TOML has all three), and f64 metrics
//!   survive both encodings bit-for-bit.
//! * **Event stream** — the schedule JSONL leads with a
//!   `job_events/v1` header record; `Released` events balance `Admitted`
//!   ones so the log alone reconstructs budget occupancy; deferred jobs
//!   report their queue wait.

use extensor::convex::ConvexConfig;
use extensor::registry::gate::{check_optim_schema, check_pareto_schema};
use extensor::registry::{dashboard, Registry, RunRecord};
use extensor::session::{
    batch_from_config, run_batch, run_job, ConvexOpt, ConvexSpec, EventSink, JobEvent, JobSpec,
    SchedulerOptions, Session,
};
use extensor::tensoring::OptimizerKind;
use extensor::util::config::Config;
use extensor::util::json::Json;
use extensor::util::logging::read_jsonl;
use std::path::{Path, PathBuf};

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("et-registry-{tag}-{}", std::process::id()))
}

fn convex_job(name: &str, data: ConvexConfig, iters: usize, opt: ConvexOpt) -> JobSpec {
    JobSpec::convex(
        name,
        ConvexSpec { data, iters, lr: 0.05, opt, measure_after: true, ..ConvexSpec::default() },
    )
}

/// The tentpole acceptance check: re-execute a recorded spec TOML on a
/// fresh session and compare the metrics to the record bit-for-bit.
#[test]
fn recorded_spec_reexecutes_bitwise() {
    let dir = tmp("bitwise");
    std::fs::remove_dir_all(&dir).ok();
    let data = ConvexConfig { n: 400, d: 32, k: 4, cond: 1e3, householder: 2, seed: 11 };
    let specs = vec![convex_job("replayed", data, 60, ConvexOpt::Planned { budget: 1024 })];
    let report = run_batch(
        &Session::new(),
        &specs,
        &SchedulerOptions { registry_dir: Some(dir.clone()), ..Default::default() },
    )
    .unwrap();
    assert!(report.failed().is_empty());

    let records = Registry::load(&dir).unwrap();
    assert_eq!(records.len(), 1);
    let rec = &records[0];
    assert_eq!(rec.job, "replayed");
    assert_eq!(rec.kind, "convex");
    assert_eq!(rec.status, "ok");
    assert!(rec.utc.ends_with('Z'), "utc {:?} not ISO-8601", rec.utc);
    assert!(rec.run_id.ends_with("-replayed"));
    let plan = rec.plan.as_ref().expect("planned job records its solved StatePlan");
    assert_eq!(plan.get("schema").and_then(|v| v.as_str()), Some("state_plan/v1"));

    // Replay: parse the canonical TOML back into a spec and run it.
    let cfg = Config::parse(&rec.spec_toml).unwrap();
    let replay = batch_from_config(&cfg).unwrap();
    assert_eq!(replay.len(), 1);
    assert_eq!(replay[0].name, "replayed");
    let sink = EventSink::discard("replayed");
    let out = run_job(&replay[0], &Session::new(), &sink).unwrap();
    let out = out.as_convex().unwrap();
    let bits = |k: &str| {
        rec.metrics.get(k).and_then(|v| v.as_f64()).unwrap_or_else(|| panic!("metric {k}"))
    };
    assert_eq!(bits("final_loss").to_bits(), out.final_loss.to_bits());
    assert_eq!(bits("accuracy").to_bits(), out.accuracy.to_bits());
    assert_eq!(bits("state_bytes") as u64, out.state_bytes as u64);
    std::fs::remove_dir_all(&dir).ok();
}

/// One record per job — prefailed included — plus the event-stream
/// satellites: `job_events/v1` header, Released/Admitted balance, and the
/// deferred job's queue wait. The records re-load through the dashboard.
#[test]
fn batch_records_every_job_and_event_log_reconstructs_occupancy() {
    let dir = tmp("batch");
    std::fs::remove_dir_all(&dir).ok();
    let log = dir.join("schedule.jsonl");
    let data = ConvexConfig { n: 2000, d: 64, k: 8, cond: 1e3, householder: 2, seed: 3 };
    let a = convex_job("a", data.clone(), 300, ConvexOpt::Kind(OptimizerKind::AdaGrad));
    let b = convex_job(
        "b",
        ConvexConfig { seed: 4, ..data.clone() },
        300,
        ConvexOpt::Kind(OptimizerKind::AdaGrad),
    );
    // Same shape, so equal costs: a 1.5x budget admits one at a time.
    let cost = a.cost_bytes().unwrap();
    let huge = convex_job(
        "huge",
        ConvexConfig { n: 8000, d: 256, k: 32, ..data },
        10,
        ConvexOpt::Kind(OptimizerKind::Sgd),
    );
    assert!(huge.cost_bytes().unwrap() > cost + cost / 2, "huge must exceed the budget");

    let specs = vec![a, b, huge];
    let report = run_batch(
        &Session::new(),
        &specs,
        &SchedulerOptions {
            workers: 2,
            mem_budget: Some(cost + cost / 2),
            log_path: Some(log.clone()),
            registry_dir: Some(dir.clone()),
        },
    )
    .unwrap();
    assert_eq!(report.failed().len(), 1, "only 'huge' fails");

    // Exactly one record per job, status telling them apart.
    let records = Registry::load(&dir).unwrap();
    assert_eq!(records.len(), 3);
    for name in ["a", "b", "huge"] {
        assert_eq!(records.iter().filter(|r| r.job == name).count(), 1, "one record for {name}");
    }
    let failed = records.iter().find(|r| r.job == "huge").unwrap();
    assert_eq!(failed.status, "failed");
    assert!(failed.error.contains("exceeding"), "error {:?}", failed.error);
    assert_eq!(failed.metrics, Json::obj(vec![]));
    for r in records.iter().filter(|r| r.status == "ok") {
        assert!(r.spec_toml.starts_with("[job."), "canonical spec TOML recorded");
        assert!(r.metrics.get("final_loss").is_some());
        assert_eq!(r.event_log, log.display().to_string());
    }

    // Budget contention: one of a/b deferred, and its record carries the
    // defer->admit wait (bitwise equal to the in-memory report's figure).
    let deferred: Vec<&str> = report
        .events
        .iter()
        .filter_map(|e| match &e.event {
            JobEvent::Deferred { job, .. } => Some(job.as_str()),
            _ => None,
        })
        .collect();
    assert!(!deferred.is_empty(), "1.5x budget must defer the second job");
    let waited = records.iter().find(|r| r.job == deferred[0]).unwrap();
    assert!(waited.queue_seconds > 0.0, "deferred job waited {}", waited.queue_seconds);
    let in_memory = report.results.iter().find(|r| r.name == deferred[0]).unwrap();
    assert_eq!(waited.queue_seconds.to_bits(), in_memory.queue_seconds.to_bits());

    // Released balances Admitted (huge was never admitted), and the final
    // release returns the budget to zero.
    let admitted = report
        .events
        .iter()
        .filter(|e| matches!(e.event, JobEvent::Admitted { .. }))
        .count();
    let released: Vec<u64> = report
        .events
        .iter()
        .filter_map(|e| match &e.event {
            JobEvent::Released { in_use_bytes, .. } => Some(*in_use_bytes),
            _ => None,
        })
        .collect();
    assert_eq!(admitted, 2);
    assert_eq!(released.len(), 2);
    assert_eq!(*released.last().unwrap(), 0, "all reservations returned");

    // Schedule log: header record first, events byte-identical after it.
    let raw = read_jsonl(&log).unwrap();
    let head = &raw[0];
    assert_eq!(head.get("schema").and_then(|v| v.as_str()), Some("job_events/v1"));
    for k in ["commit", "started_unix", "host"] {
        assert!(head.get(k).is_some(), "header missing {k}");
    }
    for ev in &raw[1..] {
        assert!(ev.get("schema").is_none(), "only the first record is a header");
        assert!(ev.get("event").is_some() && ev.get("t").is_some());
    }

    // The registry is re-loadable by `ettrain registry report`.
    let out = dir.join("dash");
    dashboard::report(&dir, Some(out.as_path())).unwrap();
    let md = std::fs::read_to_string(out.join("dashboard.md")).unwrap();
    assert!(md.contains("Run trajectory by commit"));
    assert!(out.join("trajectory.csv").exists());
    std::fs::remove_dir_all(&dir).ok();
}

/// Both encodings round-trip records whose cells carry commas, quotes,
/// and newlines, including float bits; headers are written exactly once
/// across appends.
#[test]
fn jsonl_and_csv_roundtrip_tricky_cells() {
    let dir = tmp("roundtrip");
    std::fs::remove_dir_all(&dir).ok();
    let rec = |id: &str| RunRecord {
        run_id: format!("1-{id}-weird"),
        job: "weird".to_string(),
        kind: "convex".to_string(),
        commit: "deadbeef".to_string(),
        started_unix: 1,
        utc: "1970-01-01T00:00:01Z".to_string(),
        spec_toml: "[job.weird]\ntype = \"convex\"\nnote = \"a,b\"\n".to_string(),
        plan: Some(Json::obj(vec![("schema", Json::str("state_plan/v1"))])),
        status: "failed".to_string(),
        error: "line one\nline \"two\", with commas".to_string(),
        metrics: Json::obj(vec![
            ("final_loss", Json::num(0.1 + 0.2)),
            ("accuracy", Json::num(std::f64::consts::PI)),
        ]),
        artifact_hits: 3,
        artifact_misses: 1,
        corpus_hits: 0,
        corpus_misses: 2,
        wall_seconds: 1.0 / 3.0,
        queue_seconds: 0.062_5,
        event_log: String::new(),
        recoveries: 2,
        error_kind: "disconnected".to_string(),
        timing: Json::obj(vec![
            ("schema", Json::str("trace_timing/v1")),
            ("coverage_pct", Json::num(97.5)),
        ]),
    };
    let (r0, r1) = (rec("0"), rec("1"));
    let registry = Registry::open(&dir).unwrap();
    registry.append(std::slice::from_ref(&r0)).unwrap();
    registry.append(std::slice::from_ref(&r1)).unwrap();

    let jsonl = Registry::load(&dir).unwrap();
    assert_eq!(jsonl, vec![r0.clone(), r1.clone()], "JSONL round trip (incl. float bits)");
    let csv = Registry::load_csv(&dir).unwrap();
    assert_eq!(csv, vec![r0, r1], "CSV round trip (incl. float bits)");

    // Headers appear exactly once even across two appends.
    let text = std::fs::read_to_string(dir.join("registry.csv")).unwrap();
    assert_eq!(text.matches("#schema=registry/v1").count(), 1);
    let raw = read_jsonl(dir.join("registry.jsonl")).unwrap();
    assert_eq!(raw.iter().filter(|j| j.get("schema").is_some()).count(), 1);
    std::fs::remove_dir_all(&dir).ok();
}

/// The checked-in bootstrap goldens must satisfy the same schema
/// invariants `ettrain gate --schema-only` enforces on fresh bench runs.
#[test]
fn checked_in_goldens_pass_schema_checks() {
    let goldens = Path::new(env!("CARGO_MANIFEST_DIR")).join("../goldens");
    let optim = Json::parse(&std::fs::read_to_string(goldens.join("BENCH_optim.json")).unwrap())
        .unwrap();
    let errs = check_optim_schema(&optim, "goldens/BENCH_optim.json");
    assert!(errs.is_empty(), "{errs:?}");
    let pareto = Json::parse(&std::fs::read_to_string(goldens.join("BENCH_pareto.json")).unwrap())
        .unwrap();
    let errs = check_pareto_schema(&pareto, "goldens/BENCH_pareto.json");
    assert!(errs.is_empty(), "{errs:?}");
}

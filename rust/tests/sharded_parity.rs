//! Determinism contract of the sharded optimizer-state engine: for every
//! optimizer kind in the suite, `ShardedOptimizer` over 1, 2, and 4 shards
//! must produce parameter updates *bitwise-identical* to the
//! single-threaded optimizer on the same seeded groups and gradient
//! stream — over every transport (in-process worker threads, and
//! out-of-process `ettrain shard-worker` children on UNIX sockets or
//! loopback TCP). There is no
//! tolerance here on purpose — each group's update is computed by exactly
//! one worker with the single-threaded arithmetic, so any drift would mean
//! the engine (or the wire codec) reordered real math.
//!
//! The elastic contract rides on the same identity: `reshard` mid-run
//! (grow 2→4, shrink 4→1) must be bitwise-transparent versus a fixed-shard
//! run, because snapshots are shard-count-independent.

use extensor::optim::{self, GroupSpec, Hyper, Optimizer};
use extensor::shard::{ShardedOptimizer, DEFAULT_MIN_BUCKET_NUMEL};
use extensor::tensoring::OptimizerKind;
use extensor::transport::{InProcess, ShardTransport, SocketTransport, TcpTransport};
use extensor::util::rng::Pcg64;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// A fresh socket transport per engine: each gets its own scratch dir so
/// concurrent engines never collide on `shard-<s>.sock` paths. The worker
/// binary is the `ettrain` cargo just built for this test run.
fn socket_transport() -> Arc<dyn ShardTransport> {
    static SEQ: AtomicUsize = AtomicUsize::new(0);
    let dir = std::env::temp_dir().join(format!(
        "et-parity-sock-{}-{}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    Arc::new(SocketTransport::new(dir, env!("CARGO_BIN_EXE_ettrain")))
}

/// TCP transport on an ephemeral loopback port per worker; same worker
/// binary as the socket transport.
fn tcp_transport() -> Arc<dyn ShardTransport> {
    Arc::new(TcpTransport::new("127.0.0.1:0", env!("CARGO_BIN_EXE_ettrain")))
}

/// Every transport under test, by name.
fn transports() -> Vec<(&'static str, fn() -> Arc<dyn ShardTransport>)> {
    vec![
        ("inproc", || Arc::new(InProcess)),
        ("socket", socket_transport),
        ("tcp", tcp_transport),
    ]
}

/// Transformer-flavored group mix: big matrices, a conv kernel, and a tail
/// of small vectors (the bucketing path must fuse those).
fn groups() -> Vec<GroupSpec> {
    vec![
        GroupSpec::new("embed", &[50, 16]),
        GroupSpec::new("wq", &[16, 16]),
        GroupSpec::new("ln1", &[16]),
        GroupSpec::new("ff1", &[16, 32]),
        GroupSpec::new("ff1b", &[32]),
        GroupSpec::new("ff2", &[32, 16]),
        GroupSpec::new("ff2b", &[16]),
        GroupSpec::new("conv", &[8, 4, 3, 3]),
        GroupSpec::new("ln_f", &[16]),
    ]
}

fn all_kinds() -> Vec<OptimizerKind> {
    vec![
        OptimizerKind::Sgd,
        OptimizerKind::AdaGrad,
        OptimizerKind::Adam,
        OptimizerKind::RmsProp,
        OptimizerKind::AdaDelta,
        OptimizerKind::Adafactor,
        OptimizerKind::Et(1),
        OptimizerKind::Et(2),
        OptimizerKind::Et(3),
        OptimizerKind::EtInf,
    ]
}

/// One gradient vector per group per step, seeded.
fn grad_stream(gs: &[GroupSpec], steps: usize, seed: u64) -> Vec<Vec<Vec<f32>>> {
    let mut rng = Pcg64::seeded(seed);
    (0..steps)
        .map(|_| {
            gs.iter()
                .map(|g| {
                    let mut v = vec![0.0f32; g.numel()];
                    rng.fill_normal(&mut v, 1.0);
                    v
                })
                .collect()
        })
        .collect()
}

fn init_params(gs: &[GroupSpec], seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg64::seeded(seed ^ 0xA11CE);
    gs.iter()
        .map(|g| {
            let mut v = vec![0.0f32; g.numel()];
            rng.fill_uniform(&mut v, -0.5, 0.5);
            v
        })
        .collect()
}

fn run_single(
    kind: OptimizerKind,
    gs: &[GroupSpec],
    stream: &[Vec<Vec<f32>>],
    lr: f32,
) -> Vec<Vec<f32>> {
    let mut opt = optim::build(kind, gs, &Hyper::default());
    let mut params = init_params(gs, 1);
    for grads in stream {
        opt.next_step();
        for (gi, (p, g)) in params.iter_mut().zip(grads).enumerate() {
            opt.step(gi, p, g, lr).unwrap();
        }
    }
    params
}

fn run_sharded(
    kind: OptimizerKind,
    gs: &[GroupSpec],
    stream: &[Vec<Vec<f32>>],
    lr: f32,
    shards: usize,
) -> Vec<Vec<f32>> {
    let mut opt = ShardedOptimizer::new(kind, gs, &Hyper::default(), shards).unwrap();
    let mut params = init_params(gs, 1);
    for grads in stream {
        opt.next_step();
        opt.step_all(&mut params, grads, lr).unwrap();
    }
    params
}

fn run_over_transport(
    kind: OptimizerKind,
    gs: &[GroupSpec],
    stream: &[Vec<Vec<f32>>],
    lr: f32,
    shards: usize,
    transport: Arc<dyn ShardTransport>,
) -> Vec<Vec<f32>> {
    let mut opt = ShardedOptimizer::with_transport(
        kind,
        gs,
        &Hyper::default(),
        shards,
        None,
        DEFAULT_MIN_BUCKET_NUMEL,
        transport,
    )
    .unwrap();
    let mut params = init_params(gs, 1);
    for grads in stream {
        opt.next_step();
        opt.step_all(&mut params, grads, lr).unwrap();
    }
    params
}

/// The acceptance-criterion test: every kind, shards in {1, 2, 4},
/// bitwise equality after a multi-step run (default in-process transport).
#[test]
fn sharded_matches_single_threaded_bitwise() {
    let gs = groups();
    let stream = grad_stream(&gs, 5, 7);
    for kind in all_kinds() {
        let lr = if kind == OptimizerKind::AdaDelta { 1.0 } else { 0.05 };
        let want = run_single(kind, &gs, &stream, lr);
        for shards in [1usize, 2, 4] {
            let got = run_sharded(kind, &gs, &stream, lr, shards);
            assert_eq!(
                want, got,
                "kind {kind:?} with {shards} shards diverged from single-threaded"
            );
        }
    }
}

/// Same identity over every transport: every kind × {1, 2, 4} shards ×
/// {inproc, socket}, bitwise against the single-threaded run. For the
/// socket transport this exercises the full wire round trip — spec
/// serialization, per-step f32 framing, and updated-x readback — for each
/// optimizer's hyperparameters.
#[test]
fn every_transport_matches_single_threaded_bitwise() {
    let gs = groups();
    let stream = grad_stream(&gs, 4, 7);
    for kind in all_kinds() {
        let lr = if kind == OptimizerKind::AdaDelta { 1.0 } else { 0.05 };
        let want = run_single(kind, &gs, &stream, lr);
        for (tname, make) in transports() {
            for shards in [1usize, 2, 4] {
                let got = run_over_transport(kind, &gs, &stream, lr, shards, make());
                assert_eq!(
                    want, got,
                    "kind {kind:?} over {tname} with {shards} shards diverged"
                );
            }
        }
    }
}

/// The elastic acceptance criterion: growing 2→4 and shrinking 4→1
/// mid-run is bitwise-invisible versus fixed-shard runs, on both
/// transports (snapshots are shard-count-independent, so a reshard is
/// export → rebuild → import with no arithmetic).
#[test]
fn reshard_grow_and_shrink_mid_run_bitwise() {
    let gs = groups();
    let stream = grad_stream(&gs, 6, 11);
    let kind = OptimizerKind::Et(2);
    let want = run_single(kind, &gs, &stream, 0.05);
    for (tname, make) in transports() {
        let mut opt = ShardedOptimizer::with_transport(
            kind,
            &gs,
            &Hyper::default(),
            2,
            None,
            DEFAULT_MIN_BUCKET_NUMEL,
            make(),
        )
        .unwrap();
        let mut params = init_params(&gs, 1);
        for (t, grads) in stream.iter().enumerate() {
            // Grow 2→4 after step 2, shrink 4→1 after step 4.
            if t == 2 {
                opt.reshard(4).unwrap();
                assert_eq!(opt.n_shards(), 4, "{tname}");
            }
            if t == 4 {
                opt.reshard(1).unwrap();
                assert_eq!(opt.n_shards(), 1, "{tname}");
            }
            opt.next_step();
            opt.step_all(&mut params, grads, 0.05).unwrap();
        }
        assert_eq!(want, params, "mid-run reshard over {tname} changed results");
    }
}

/// Tracing must be observationally invisible to the arithmetic: the same
/// run with tracing enabled is bitwise identical to tracing off, over the
/// in-process and socket transports (the socket run covers the
/// coordinator-side wire_send/wire_recv proxy spans; span data never
/// touches the wire payloads). Timestamps are observability data only;
/// nothing feeds back.
#[test]
fn tracing_on_vs_off_is_bitwise_invisible() {
    let gs = groups();
    let stream = grad_stream(&gs, 4, 17);
    let kind = OptimizerKind::Et(2);
    let want = run_single(kind, &gs, &stream, 0.05);
    let cases: Vec<(&'static str, fn() -> Arc<dyn ShardTransport>)> =
        vec![("inproc", || Arc::new(InProcess)), ("socket", socket_transport)];
    for (tname, make) in cases {
        let untraced = run_over_transport(kind, &gs, &stream, 0.05, 2, make());
        extensor::trace::enable();
        let traced = run_over_transport(kind, &gs, &stream, 0.05, 2, make());
        extensor::trace::disable();
        extensor::trace::drain();
        assert_eq!(want, untraced, "untraced {tname} run diverged from single-threaded");
        assert_eq!(untraced, traced, "tracing changed results over {tname}");
    }
}

/// The trait-compat path (per-group `step`) must agree with `step_all`.
#[test]
fn trait_step_agrees_with_step_all() {
    let gs = groups();
    let stream = grad_stream(&gs, 3, 21);
    for kind in [OptimizerKind::Adam, OptimizerKind::Et(2)] {
        let mut a = ShardedOptimizer::new(kind, &gs, &Hyper::default(), 3).unwrap();
        let mut b = ShardedOptimizer::new(kind, &gs, &Hyper::default(), 3).unwrap();
        let mut pa = init_params(&gs, 2);
        let mut pb = init_params(&gs, 2);
        for grads in &stream {
            a.next_step();
            b.next_step();
            a.step_all(&mut pa, grads, 0.05).unwrap();
            for (gi, (p, g)) in pb.iter_mut().zip(grads).enumerate() {
                b.step(gi, p, g, 0.05).unwrap();
            }
        }
        assert_eq!(pa, pb, "kind {kind:?}");
    }
}

/// State accounting must be invariant under sharding (the paper's memory
/// model is per group; partitioning cannot change the total).
#[test]
fn state_scalars_invariant_under_sharding() {
    let gs = groups();
    for kind in all_kinds() {
        let single = optim::build(kind, &gs, &Hyper::default());
        for shards in [1usize, 2, 4] {
            let sharded = ShardedOptimizer::new(kind, &gs, &Hyper::default(), shards).unwrap();
            assert_eq!(
                sharded.state_scalars(),
                single.state_scalars(),
                "kind {kind:?} shards {shards}"
            );
            assert!(sharded.peak_state_scalars() <= single.state_scalars().max(1));
        }
    }
}

/// Sharding must not depend on bucket granularity either.
#[test]
fn bucket_granularity_does_not_change_results() {
    let gs = groups();
    let stream = grad_stream(&gs, 4, 13);
    let run = |min_bucket: usize| -> Vec<Vec<f32>> {
        let mut opt = ShardedOptimizer::with_options(
            OptimizerKind::Et(3),
            &gs,
            &Hyper::default(),
            4,
            None,
            min_bucket,
        )
        .unwrap();
        let mut params = init_params(&gs, 3);
        for grads in &stream {
            opt.next_step();
            opt.step_all(&mut params, grads, 0.1).unwrap();
        }
        params
    };
    let fine = run(1);
    assert_eq!(fine, run(512));
    assert_eq!(fine, run(usize::MAX));
}

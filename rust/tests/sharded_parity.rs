//! Determinism contract of the sharded optimizer-state engine: for every
//! optimizer kind in the suite, `ShardedOptimizer` over 1, 2, and 4 shards
//! must produce parameter updates *bitwise-identical* to the
//! single-threaded optimizer on the same seeded groups and gradient
//! stream. There is no tolerance here on purpose — each group's update is
//! computed by exactly one worker with the single-threaded arithmetic, so
//! any drift would mean the engine reordered real math.

use extensor::optim::{self, GroupSpec, Hyper, Optimizer};
use extensor::shard::ShardedOptimizer;
use extensor::tensoring::OptimizerKind;
use extensor::util::rng::Pcg64;

/// Transformer-flavored group mix: big matrices, a conv kernel, and a tail
/// of small vectors (the bucketing path must fuse those).
fn groups() -> Vec<GroupSpec> {
    vec![
        GroupSpec::new("embed", &[50, 16]),
        GroupSpec::new("wq", &[16, 16]),
        GroupSpec::new("ln1", &[16]),
        GroupSpec::new("ff1", &[16, 32]),
        GroupSpec::new("ff1b", &[32]),
        GroupSpec::new("ff2", &[32, 16]),
        GroupSpec::new("ff2b", &[16]),
        GroupSpec::new("conv", &[8, 4, 3, 3]),
        GroupSpec::new("ln_f", &[16]),
    ]
}

fn all_kinds() -> Vec<OptimizerKind> {
    vec![
        OptimizerKind::Sgd,
        OptimizerKind::AdaGrad,
        OptimizerKind::Adam,
        OptimizerKind::RmsProp,
        OptimizerKind::AdaDelta,
        OptimizerKind::Adafactor,
        OptimizerKind::Et(1),
        OptimizerKind::Et(2),
        OptimizerKind::Et(3),
        OptimizerKind::EtInf,
    ]
}

/// One gradient vector per group per step, seeded.
fn grad_stream(gs: &[GroupSpec], steps: usize, seed: u64) -> Vec<Vec<Vec<f32>>> {
    let mut rng = Pcg64::seeded(seed);
    (0..steps)
        .map(|_| {
            gs.iter()
                .map(|g| {
                    let mut v = vec![0.0f32; g.numel()];
                    rng.fill_normal(&mut v, 1.0);
                    v
                })
                .collect()
        })
        .collect()
}

fn init_params(gs: &[GroupSpec], seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg64::seeded(seed ^ 0xA11CE);
    gs.iter()
        .map(|g| {
            let mut v = vec![0.0f32; g.numel()];
            rng.fill_uniform(&mut v, -0.5, 0.5);
            v
        })
        .collect()
}

fn run_single(
    kind: OptimizerKind,
    gs: &[GroupSpec],
    stream: &[Vec<Vec<f32>>],
    lr: f32,
) -> Vec<Vec<f32>> {
    let mut opt = optim::build(kind, gs, &Hyper::default());
    let mut params = init_params(gs, 1);
    for grads in stream {
        opt.next_step();
        for (gi, (p, g)) in params.iter_mut().zip(grads).enumerate() {
            opt.step(gi, p, g, lr).unwrap();
        }
    }
    params
}

fn run_sharded(
    kind: OptimizerKind,
    gs: &[GroupSpec],
    stream: &[Vec<Vec<f32>>],
    lr: f32,
    shards: usize,
) -> Vec<Vec<f32>> {
    let mut opt = ShardedOptimizer::new(kind, gs, &Hyper::default(), shards).unwrap();
    let mut params = init_params(gs, 1);
    for grads in stream {
        opt.next_step();
        opt.step_all(&mut params, grads, lr).unwrap();
    }
    params
}

/// The acceptance-criterion test: every kind, shards in {1, 2, 4},
/// bitwise equality after a multi-step run.
#[test]
fn sharded_matches_single_threaded_bitwise() {
    let gs = groups();
    let stream = grad_stream(&gs, 5, 7);
    for kind in all_kinds() {
        let lr = if kind == OptimizerKind::AdaDelta { 1.0 } else { 0.05 };
        let want = run_single(kind, &gs, &stream, lr);
        for shards in [1usize, 2, 4] {
            let got = run_sharded(kind, &gs, &stream, lr, shards);
            assert_eq!(
                want, got,
                "kind {kind:?} with {shards} shards diverged from single-threaded"
            );
        }
    }
}

/// The trait-compat path (per-group `step`) must agree with `step_all`.
#[test]
fn trait_step_agrees_with_step_all() {
    let gs = groups();
    let stream = grad_stream(&gs, 3, 21);
    for kind in [OptimizerKind::Adam, OptimizerKind::Et(2)] {
        let mut a = ShardedOptimizer::new(kind, &gs, &Hyper::default(), 3).unwrap();
        let mut b = ShardedOptimizer::new(kind, &gs, &Hyper::default(), 3).unwrap();
        let mut pa = init_params(&gs, 2);
        let mut pb = init_params(&gs, 2);
        for grads in &stream {
            a.next_step();
            b.next_step();
            a.step_all(&mut pa, grads, 0.05).unwrap();
            for (gi, (p, g)) in pb.iter_mut().zip(grads).enumerate() {
                b.step(gi, p, g, 0.05).unwrap();
            }
        }
        assert_eq!(pa, pb, "kind {kind:?}");
    }
}

/// State accounting must be invariant under sharding (the paper's memory
/// model is per group; partitioning cannot change the total).
#[test]
fn state_scalars_invariant_under_sharding() {
    let gs = groups();
    for kind in all_kinds() {
        let single = optim::build(kind, &gs, &Hyper::default());
        for shards in [1usize, 2, 4] {
            let sharded = ShardedOptimizer::new(kind, &gs, &Hyper::default(), shards).unwrap();
            assert_eq!(
                sharded.state_scalars(),
                single.state_scalars(),
                "kind {kind:?} shards {shards}"
            );
            assert!(sharded.peak_state_scalars() <= single.state_scalars().max(1));
        }
    }
}

/// Sharding must not depend on bucket granularity either.
#[test]
fn bucket_granularity_does_not_change_results() {
    let gs = groups();
    let stream = grad_stream(&gs, 4, 13);
    let run = |min_bucket: usize| -> Vec<Vec<f32>> {
        let mut opt = ShardedOptimizer::with_options(
            OptimizerKind::Et(3),
            &gs,
            &Hyper::default(),
            4,
            None,
            min_bucket,
        )
        .unwrap();
        let mut params = init_params(&gs, 3);
        for grads in &stream {
            opt.next_step();
            opt.step_all(&mut params, grads, 0.1).unwrap();
        }
        params
    };
    let fine = run(1);
    assert_eq!(fine, run(512));
    assert_eq!(fine, run(usize::MAX));
}

//! Budget-planner contracts:
//!
//! 1. **Budget respect** — for random group sets and budgets, the solved
//!    plan's total bytes never exceed the budget (both solver regimes).
//! 2. **Monotonicity** — more budget never decreases total expressivity
//!    (both regimes; the DP frontier is monotone by construction, the
//!    greedy walk by its concave-ladder ordering).
//! 3. **Degenerate budgets** — below the summed cheapest configs the
//!    solver fails with an error naming the shortfall; at exactly the
//!    floor it returns every group's cheapest config.
//! 4. **Uniform-f32 parity** — a plan forcing uniform (kind, f32) executes
//!    bitwise-identically to today's `StateOptimizer` of that kind, for
//!    every plannable kind (the planned path adds no arithmetic of its
//!    own). Uniform q8 plans match the uniform q8 optimizer the same way.
//! 5. **NF4 backend** — round-trips export/import exactly (idempotent
//!    re-encode) and still optimizes the convex task.

use extensor::budget::{build_planned, candidates, plan, PlannerOptions, StatePlan};
use extensor::convex::ConvexConfig;
use extensor::optim::{self, GroupSpec, Hyper, Optimizer};
use extensor::session::{run_job, ConvexOpt, ConvexSpec, EventSink, JobSpec, Session};
use extensor::tensoring::{OptimizerKind, StateBackend};
use extensor::testing::prop::{props, Gen};
use extensor::util::rng::Pcg64;

fn random_groups(g: &mut Gen, n: usize) -> Vec<GroupSpec> {
    (0..n)
        .map(|i| {
            let rank = g.usize_in(1, 3);
            let shape: Vec<usize> = (0..rank).map(|_| g.usize_in(1, 96)).collect();
            GroupSpec::new(format!("g{i}"), &shape)
        })
        .collect()
}

fn min_feasible(groups: &[GroupSpec], opts: &PlannerOptions) -> u64 {
    groups.iter().map(|g| candidates(g, opts)[0].bytes as u64).sum()
}

/// Both solver regimes on the same inputs: DP (forced via a high
/// `dp_max_groups`) and greedy (forced via 0).
fn regimes() -> [(&'static str, PlannerOptions); 2] {
    [
        ("dp", PlannerOptions { dp_max_groups: 64, ..PlannerOptions::default() }),
        ("greedy", PlannerOptions { dp_max_groups: 0, ..PlannerOptions::default() }),
    ]
}

#[test]
fn prop_budget_is_never_exceeded() {
    props("budget_respected", 120, |g: &mut Gen| {
        let groups = random_groups(g, g.usize_in(1, 12));
        for (label, opts) in regimes() {
            let floor = min_feasible(&groups, &opts);
            let budget = floor + g.usize_in(0, 1 << 20) as u64;
            let p = plan(&groups, budget, &opts).unwrap();
            assert!(
                p.total_bytes() as u64 <= budget,
                "[{label}] {} > {budget} for {} groups",
                p.total_bytes(),
                groups.len()
            );
            assert_eq!(p.per_group.len(), groups.len());
            // Per-group bytes agree with the recorded choices.
            for c in &p.per_group {
                assert!(c.bytes > 0 || c.expressivity == 0.0);
            }
        }
    });
}

#[test]
fn prop_expressivity_is_monotone_in_budget() {
    props("budget_monotone", 120, |g: &mut Gen| {
        let groups = random_groups(g, g.usize_in(1, 12));
        for (label, opts) in regimes() {
            let floor = min_feasible(&groups, &opts);
            let b1 = floor + g.usize_in(0, 1 << 18) as u64;
            let b2 = b1 + g.usize_in(0, 1 << 18) as u64;
            let p1 = plan(&groups, b1, &opts).unwrap();
            let p2 = plan(&groups, b2, &opts).unwrap();
            assert!(
                p2.total_expressivity() >= p1.total_expressivity() - 1e-9,
                "[{label}] budget {b1} -> {b2} lost expressivity: {} -> {}",
                p1.total_expressivity(),
                p2.total_expressivity()
            );
        }
    });
}

#[test]
fn degenerate_budgets_fail_clearly_or_fall_back_to_cheapest() {
    let groups = vec![
        GroupSpec::new("embed", &[500, 64]),
        GroupSpec::new("w", &[64, 64]),
        GroupSpec::new("b", &[64]),
    ];
    for (label, opts) in regimes() {
        let floor = min_feasible(&groups, &opts);
        // Below the floor: a clear, named error — never a panic, never a
        // silently over-budget plan.
        let err = plan(&groups, floor - 1, &opts).unwrap_err().to_string();
        assert!(err.contains("cheapest feasible"), "[{label}] {err}");
        assert!(err.contains(&format!("{floor}")), "[{label}] floor not named: {err}");
        let err0 = plan(&groups, 0, &opts).unwrap_err().to_string();
        assert!(err0.contains("budget 0"), "[{label}] {err0}");
        // Exactly the floor: every group at its cheapest feasible config.
        let p = plan(&groups, floor, &opts).unwrap();
        assert_eq!(p.total_bytes() as u64, floor, "[{label}]");
        for (c, g) in p.per_group.iter().zip(&groups) {
            assert_eq!(c.bytes, candidates(g, &opts)[0].bytes, "[{label}] {}", g.name);
        }
        // Empty group lists are rejected.
        assert!(plan(&[], 1 << 20, &opts).is_err());
    }
}

fn random_grad_stream(groups: &[GroupSpec], seed: u64, steps: usize) -> Vec<Vec<Vec<f32>>> {
    let mut rng = Pcg64::seeded(seed);
    (0..steps)
        .map(|_| {
            groups
                .iter()
                .map(|g| {
                    let mut v = vec![0.0f32; g.numel()];
                    rng.fill_normal(&mut v, 1.0);
                    v
                })
                .collect()
        })
        .collect()
}

/// A plan forcing uniform (kind, backend) must reproduce the plain
/// `StateOptimizer` trajectory **bitwise** — the acceptance contract that
/// keeps golden_parity/sharded_parity/host_checkpoint meaningful under
/// planned execution.
#[test]
fn uniform_plans_match_state_optimizer_bitwise() {
    let groups = vec![
        GroupSpec::new("w", &[16, 32]),
        GroupSpec::new("b", &[32]),
        GroupSpec::new("conv", &[8, 4, 3, 3]),
        GroupSpec::new("ln", &[16]),
    ];
    let stream = random_grad_stream(&groups, 0xb1d6, 5);
    let cases: Vec<(OptimizerKind, StateBackend)> = vec![
        (OptimizerKind::AdaGrad, StateBackend::DenseF32),
        (OptimizerKind::Et(1), StateBackend::DenseF32),
        (OptimizerKind::Et(2), StateBackend::DenseF32),
        (OptimizerKind::Et(3), StateBackend::DenseF32),
        (OptimizerKind::EtInf, StateBackend::DenseF32),
        (OptimizerKind::AdaGrad, StateBackend::q8()),
        (OptimizerKind::Et(2), StateBackend::q8()),
        (OptimizerKind::Et(2), StateBackend::nf4()),
    ];
    for (kind, backend) in cases {
        let hyper = Hyper { backend, ..Hyper::default() };
        let mut reference = optim::build_state(kind, &groups, &hyper);
        let mut want: Vec<Vec<f32>> = groups.iter().map(|g| vec![0.4f32; g.numel()]).collect();
        for grads in &stream {
            reference.next_step();
            reference.step_all(&mut want, grads, 0.07).unwrap();
        }

        let forced = StatePlan::uniform(kind, backend, &groups).unwrap();
        let mut planned = build_planned(&groups, &forced, &hyper).unwrap();
        let mut got: Vec<Vec<f32>> = groups.iter().map(|g| vec![0.4f32; g.numel()]).collect();
        for grads in &stream {
            planned.next_step();
            planned.step_all(&mut got, grads, 0.07).unwrap();
        }
        assert_eq!(want, got, "{kind:?} under {backend:?} diverged from StateOptimizer");
        assert_eq!(
            planned.state_bytes(),
            reference.state_bytes(),
            "{kind:?} under {backend:?}: byte accounting diverged"
        );
    }
}

/// NF4 state survives an export/import round trip exactly (decode →
/// re-encode is idempotent: the block absmax maps to the ±1.0 code, every
/// other value to its own level) and the restored optimizer continues
/// bitwise.
#[test]
fn nf4_state_roundtrips_export_import() {
    let groups = vec![GroupSpec::new("w", &[16, 32]), GroupSpec::new("b", &[32])];
    let hyper = Hyper { backend: StateBackend::nf4(), ..Hyper::default() };
    let stream = random_grad_stream(&groups, 0x4f4, 6);

    let mut full = optim::build_state(OptimizerKind::AdaGrad, &groups, &hyper);
    let mut want: Vec<Vec<f32>> = groups.iter().map(|g| vec![0.3f32; g.numel()]).collect();
    for grads in &stream {
        full.next_step();
        full.step_all(&mut want, grads, 0.05).unwrap();
    }

    let mut first = optim::build_state(OptimizerKind::AdaGrad, &groups, &hyper);
    let mut got: Vec<Vec<f32>> = groups.iter().map(|g| vec![0.3f32; g.numel()]).collect();
    for grads in &stream[..3] {
        first.next_step();
        first.step_all(&mut got, grads, 0.05).unwrap();
    }
    let snapshot = first.export();
    // The snapshot is dense; importing re-encodes into fresh NF4 buffers
    // without drift.
    let mut second = optim::build_state(OptimizerKind::AdaGrad, &groups, &hyper);
    second.import(&snapshot).unwrap();
    assert_eq!(second.export(), snapshot, "NF4 re-encode of a decode drifted");
    for grads in &stream[3..] {
        second.next_step();
        second.step_all(&mut got, grads, 0.05).unwrap();
    }
    assert_eq!(want, got, "NF4 resume diverged");
}

/// NF4-backed state still optimizes the paper's convex task, and a
/// budget-planned convex job stays within its budget end to end.
#[test]
fn nf4_and_planned_jobs_descend_on_the_convex_task() {
    let session = Session::new();
    let sink = EventSink::discard("budget_plan_test");
    let data = ConvexConfig { n: 400, d: 64, k: 4, cond: 1e3, householder: 4, seed: 11 };
    let run = |opt: ConvexOpt, backend: StateBackend, iters: usize| {
        let spec = JobSpec::convex(
            "cell",
            ConvexSpec {
                data: data.clone(),
                iters,
                lr: 0.05,
                backend,
                opt,
                measure_after: true,
                curve_every: 0,
            },
        );
        let out = run_job(&spec, &session, &sink).unwrap();
        out.as_convex().expect("convex outcome").clone()
    };
    // NF4 AdaGrad: loss after 200 iters beats loss after 2.
    let early = run(ConvexOpt::Kind(OptimizerKind::AdaGrad), StateBackend::nf4(), 2);
    let late = run(ConvexOpt::Kind(OptimizerKind::AdaGrad), StateBackend::nf4(), 200);
    assert!(late.final_loss.is_finite() && early.final_loss.is_finite());
    assert!(
        late.final_loss < early.final_loss * 0.9,
        "nf4 AdaGrad did not descend: {} -> {}",
        early.final_loss,
        late.final_loss
    );
    // Stochastic-rounding variant descends too.
    let sr = run(ConvexOpt::Kind(OptimizerKind::AdaGrad), StateBackend::nf4sr(), 200);
    assert!(
        sr.final_loss < early.final_loss * 0.9,
        "nf4sr AdaGrad did not descend: {} -> {}",
        early.final_loss,
        sr.final_loss
    );
    // A planned job's live state respects its budget.
    let budget = 2048u64;
    let planned = run(ConvexOpt::Planned { budget }, StateBackend::DenseF32, 200);
    assert!(planned.state_bytes as u64 <= budget, "{} > {budget}", planned.state_bytes);
    assert!(planned.final_loss.is_finite());
    assert!(
        planned.final_loss < early.final_loss,
        "planned optimizer did not descend: {} vs {}",
        planned.final_loss,
        early.final_loss
    );
}

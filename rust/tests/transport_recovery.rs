//! Crash recovery over the socket transport, end to end.
//!
//! The manual half: a shard worker process is SIGKILLed mid-run, the
//! in-flight step fails with a fatal transport error, and
//! `take_snapshot`/`recover` rebuild the engine on the surviving
//! workers — after which replaying from the snapshot step completes the
//! run *bitwise-identical* to an uninterrupted single-threaded run.
//! Determinism makes crash recovery testable exactly: there is no "close
//! enough" after a worker dies.
//!
//! The supervised half drives the same engine through
//! [`SupervisedOptimizer`] under deterministic [`FaultPlan`] schedules —
//! real SIGKILLs, injected timeout storms, a disconnect in the middle of
//! a snapshot export, a second fault during recovery itself, and an
//! exhausted recovery budget — asserting bitwise completion (or the
//! typed failure) plus the recovery event stream for each.

use extensor::optim::{self, GroupSpec, Hyper, Optimizer};
use extensor::shard::{
    RecoveryPolicy, ShardedOptimizer, SupervisedOptimizer, SupervisorError,
    DEFAULT_MIN_BUCKET_NUMEL,
};
use extensor::tensoring::OptimizerKind;
use extensor::transport::{FaultPlan, FaultTransport, SocketTransport, TransportTuning};
use extensor::util::rng::Pcg64;
use std::sync::{Arc, Mutex};

const STEPS: usize = 6;
const SNAP_AT: usize = 3;
const LR: f32 = 0.05;

fn groups() -> Vec<GroupSpec> {
    vec![
        GroupSpec::new("embed", &[40, 16]),
        GroupSpec::new("ff1", &[16, 24]),
        GroupSpec::new("ff2", &[24, 16]),
        GroupSpec::new("bias", &[24]),
    ]
}

fn grad_stream(gs: &[GroupSpec], steps: usize, seed: u64) -> Vec<Vec<Vec<f32>>> {
    let mut rng = Pcg64::seeded(seed);
    (0..steps)
        .map(|_| {
            gs.iter()
                .map(|g| {
                    let mut v = vec![0.0f32; g.numel()];
                    rng.fill_normal(&mut v, 1.0);
                    v
                })
                .collect()
        })
        .collect()
}

fn init_params(gs: &[GroupSpec]) -> Vec<Vec<f32>> {
    let mut rng = Pcg64::seeded(0xF00D);
    gs.iter()
        .map(|g| {
            let mut v = vec![0.0f32; g.numel()];
            rng.fill_uniform(&mut v, -0.5, 0.5);
            v
        })
        .collect()
}

fn socket_transport(tag: &str) -> Arc<SocketTransport> {
    let dir = std::env::temp_dir().join(format!("et-recover-{}-{tag}", std::process::id()));
    Arc::new(SocketTransport::new(dir, env!("CARGO_BIN_EXE_ettrain")).with_tuning(
        TransportTuning { read_timeout_ms: 20_000, ..TransportTuning::default() },
    ))
}

/// The uninterrupted reference: single-threaded, same seeds.
fn reference_params(gs: &[GroupSpec], stream: &[Vec<Vec<f32>>]) -> Vec<Vec<f32>> {
    let mut opt = optim::build(OptimizerKind::Et(2), gs, &Hyper::default());
    let mut params = init_params(gs);
    for grads in stream {
        opt.next_step();
        for (gi, (p, g)) in params.iter_mut().zip(grads).enumerate() {
            opt.step(gi, p, g, LR).unwrap();
        }
    }
    params
}

#[test]
fn killed_socket_worker_recovers_and_completes_bitwise() {
    let gs = groups();
    let stream = grad_stream(&gs, STEPS, 29);
    let want = reference_params(&gs, &stream);

    let transport = socket_transport("kill");
    let mut opt = ShardedOptimizer::with_transport(
        OptimizerKind::Et(2),
        &gs,
        &Hyper::default(),
        2,
        None,
        DEFAULT_MIN_BUCKET_NUMEL,
        transport.clone(),
    )
    .unwrap();
    assert_eq!(opt.transport_name(), "socket");

    let mut params = init_params(&gs);
    // Run to the snapshot boundary, then snapshot both the optimizer state
    // (inside the engine) and our own copy of the parameters — crash
    // recovery rewinds to the last consistent (params, state) pair.
    for grads in stream.iter().take(SNAP_AT) {
        opt.next_step();
        opt.step_all(&mut params, grads, LR).unwrap();
    }
    let snap_step = opt.take_snapshot().unwrap();
    assert_eq!(snap_step, SNAP_AT as u64);
    assert_eq!(opt.snapshot_step(), Some(SNAP_AT as u64));
    let params_at_snapshot = params.clone();

    // Keep running past the snapshot, then SIGKILL shard 1's worker
    // process. The next dispatch must fail with a *fatal* transport error
    // (possibly leaving `params` partially updated — that is exactly why
    // recovery rewinds them).
    opt.next_step();
    opt.step_all(&mut params, &stream[SNAP_AT], LR).unwrap();

    let pids = transport.spawned_pids();
    assert_eq!(pids.len(), 2, "two shards -> two spawned workers");
    let victim = pids[1];
    let killed = std::process::Command::new("kill")
        .args(["-9", &victim.to_string()])
        .status()
        .expect("spawn kill");
    assert!(killed.success(), "kill -9 {victim} failed");

    let mut died = false;
    for grads in stream.iter().skip(SNAP_AT + 1) {
        opt.next_step();
        if opt.step_all(&mut params, grads, LR).is_err() {
            died = true;
            break;
        }
    }
    assert!(died, "step_all must fail after a worker is SIGKILLed");

    // Recover onto the survivors and replay from the snapshot.
    let resume_step = opt.recover().unwrap();
    assert_eq!(resume_step, SNAP_AT as u64);
    assert_eq!(opt.n_shards(), 1, "one of two workers died -> rebuilt on the survivor");
    params = params_at_snapshot;
    for grads in stream.iter().skip(SNAP_AT) {
        opt.next_step();
        opt.step_all(&mut params, grads, LR).unwrap();
    }

    assert_eq!(
        want, params,
        "post-recovery completion diverged from the uninterrupted run"
    );
}

/// Snapshot/recover is not tied to a crash: recovering with every worker
/// alive is just a rebuild-and-replay, and still bitwise.
#[test]
fn recover_with_all_workers_alive_replays_bitwise() {
    let gs = groups();
    let stream = grad_stream(&gs, STEPS, 31);
    let want = reference_params(&gs, &stream);

    let transport = socket_transport("alive");
    let mut opt = ShardedOptimizer::with_transport(
        OptimizerKind::Et(2),
        &gs,
        &Hyper::default(),
        2,
        None,
        DEFAULT_MIN_BUCKET_NUMEL,
        transport,
    )
    .unwrap();
    let mut params = init_params(&gs);
    for grads in stream.iter().take(SNAP_AT) {
        opt.next_step();
        opt.step_all(&mut params, grads, LR).unwrap();
    }
    opt.take_snapshot().unwrap();
    let params_at_snapshot = params.clone();
    for grads in stream.iter().skip(SNAP_AT) {
        opt.next_step();
        opt.step_all(&mut params, grads, LR).unwrap();
    }

    let resume = opt.recover().unwrap();
    assert_eq!(resume, SNAP_AT as u64);
    assert_eq!(opt.n_shards(), 2, "no worker died -> same shard count");
    params = params_at_snapshot;
    for grads in stream.iter().skip(SNAP_AT) {
        opt.next_step();
        opt.step_all(&mut params, grads, LR).unwrap();
    }
    assert_eq!(want, params);
}

// ---------------------------------------------------------------------------
// Supervised fault matrix: SupervisedOptimizer x FaultPlan over real
// socket workers.
// ---------------------------------------------------------------------------

fn sigkill(pid: u32) {
    let status = std::process::Command::new("kill")
        .args(["-9", &pid.to_string()])
        .status()
        .expect("spawn kill");
    assert!(status.success(), "kill -9 {pid} failed");
}

/// A fault-injecting transport over real socket workers: `kill` actions
/// SIGKILL the most recently spawned worker for the shard, so the engine
/// sees genuine process death, not a synthesized error.
fn faulty_socket(tag: &str, plan: &str) -> Arc<FaultTransport> {
    let socket = socket_transport(tag);
    let killer = Arc::clone(&socket);
    Arc::new(
        FaultTransport::new(socket, FaultPlan::parse(plan).unwrap()).with_killer(move |shard| {
            if let Some(pid) = killer.pid_of(shard) {
                sigkill(pid);
            }
        }),
    )
}

fn policy() -> RecoveryPolicy {
    RecoveryPolicy { snapshot_every: SNAP_AT as u64, max_recoveries: 4, backoff_ms: 1 }
}

/// Build a supervised 2-shard ET(2) engine whose recovery events are
/// appended (as tags) to `tags`.
fn supervised(
    transport: Arc<FaultTransport>,
    gs: &[GroupSpec],
    policy: RecoveryPolicy,
    tags: &Arc<Mutex<Vec<String>>>,
) -> SupervisedOptimizer {
    let engine = ShardedOptimizer::with_transport(
        OptimizerKind::Et(2),
        gs,
        &Hyper::default(),
        2,
        None,
        DEFAULT_MIN_BUCKET_NUMEL,
        transport,
    )
    .unwrap();
    let sink = Arc::clone(tags);
    SupervisedOptimizer::new(engine, policy)
        .unwrap()
        .with_events(move |e| sink.lock().unwrap().push(e.tag().to_string()))
}

fn count(tags: &Arc<Mutex<Vec<String>>>, tag: &str) -> usize {
    tags.lock().unwrap().iter().filter(|t| *t == tag).count()
}

/// The acceptance scenario: a worker is SIGKILLed mid-run by the fault
/// plan; the supervised run completes bitwise-identical to the
/// uninterrupted reference, with the incident visible in the events.
#[test]
fn supervised_sigkill_over_socket_heals_bitwise() {
    let gs = groups();
    let stream = grad_stream(&gs, STEPS, 37);
    let want = reference_params(&gs, &stream);

    let tags = Arc::new(Mutex::new(Vec::new()));
    let mut sup = supervised(faulty_socket("sup-kill", "kill@1:5"), &gs, policy(), &tags);
    let mut params = init_params(&gs);
    for grads in &stream {
        sup.run_step(&mut params, grads, LR).unwrap();
    }

    assert_eq!(want, params, "supervised SIGKILL run diverged from the reference");
    assert_eq!(sup.recoveries(), 1);
    assert_eq!(sup.engine().n_shards(), 1, "healed onto the survivor");
    assert_eq!(count(&tags, "incident"), 1);
    assert_eq!(count(&tags, "recovered"), 1);
    assert!(count(&tags, "snapshot") >= 2, "snapshots at steps 0 and {SNAP_AT}");
}

/// A two-deep timeout storm: each swallowed dispatch is healed by
/// rewind-and-replay (other shards may have applied the step), and the
/// run still finishes bitwise on the full shard count.
#[test]
fn supervised_timeout_storm_heals_bitwise() {
    let gs = groups();
    let stream = grad_stream(&gs, STEPS, 41);
    let want = reference_params(&gs, &stream);

    let tags = Arc::new(Mutex::new(Vec::new()));
    let mut sup = supervised(faulty_socket("sup-timeout", "timeout@0:4x2"), &gs, policy(), &tags);
    let mut params = init_params(&gs);
    for grads in &stream {
        sup.run_step(&mut params, grads, LR).unwrap();
    }

    assert_eq!(want, params, "timeout storm diverged from the reference");
    assert_eq!(sup.recoveries(), 2, "one heal per swallowed dispatch");
    assert_eq!(sup.engine().n_shards(), 2, "timeouts cost no workers");
    assert_eq!(sup.last_error_kind(), Some("timeout"));
}

/// A disconnect in the middle of a snapshot *export*: the engine keeps
/// the previous snapshot, heals, replays, and retakes the snapshot.
#[test]
fn supervised_export_disconnect_heals_bitwise() {
    let gs = groups();
    let stream = grad_stream(&gs, STEPS, 43);
    let want = reference_params(&gs, &stream);

    // Exports are per-shard ordinals: #1 at step 0, #2 at step SNAP_AT.
    let tags = Arc::new(Mutex::new(Vec::new()));
    let mut sup = supervised(faulty_socket("sup-export", "export-drop@1:2"), &gs, policy(), &tags);
    let mut params = init_params(&gs);
    for grads in &stream {
        sup.run_step(&mut params, grads, LR).unwrap();
    }

    assert_eq!(want, params, "mid-export disconnect diverged from the reference");
    assert_eq!(sup.recoveries(), 1);
    assert_eq!(sup.engine().n_shards(), 1, "the dropped shard is gone");
}

/// Recovery itself is interrupted: the first kill takes shard 1, and the
/// second takes the rebuilt engine's only worker during the retry. Both
/// draw from the same budget; the run still completes bitwise.
#[test]
fn supervised_double_fault_during_recovery_heals_bitwise() {
    let gs = groups();
    let stream = grad_stream(&gs, STEPS, 47);
    let want = reference_params(&gs, &stream);

    let tags = Arc::new(Mutex::new(Vec::new()));
    let mut sup =
        supervised(faulty_socket("sup-double", "kill@1:4;kill@0:5"), &gs, policy(), &tags);
    let mut params = init_params(&gs);
    for grads in &stream {
        sup.run_step(&mut params, grads, LR).unwrap();
    }

    assert_eq!(want, params, "interrupted recovery diverged from the reference");
    assert_eq!(sup.recoveries(), 2, "the mid-recovery fault is its own incident");
    assert_eq!(count(&tags, "recovered"), 2);
}

/// An unbounded timeout storm against a budget of one: the run fails
/// with the *typed* exhaustion error, and the give-up is an event.
#[test]
fn supervised_exhausted_budget_fails_typed() {
    let gs = groups();
    let stream = grad_stream(&gs, STEPS, 53);

    let tags = Arc::new(Mutex::new(Vec::new()));
    let tight = RecoveryPolicy { max_recoveries: 1, ..policy() };
    let mut sup = supervised(faulty_socket("sup-exhaust", "timeout@0:4x100"), &gs, tight, &tags);
    let mut params = init_params(&gs);
    let mut failure = None;
    for grads in &stream {
        if let Err(e) = sup.run_step(&mut params, grads, LR) {
            failure = Some(e);
            break;
        }
    }

    let err = failure.expect("a 100-deep storm must outlast a budget of 1");
    match err.downcast_ref::<SupervisorError>() {
        Some(SupervisorError::Exhausted { recoveries, kind, .. }) => {
            assert_eq!(*recoveries, 1);
            assert_eq!(*kind, "timeout");
        }
        other => panic!("expected Exhausted, got {other:?}"),
    }
    assert_eq!(count(&tags, "gave-up"), 1);
}

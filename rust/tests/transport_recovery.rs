//! Crash recovery over the socket transport, end to end: a shard worker
//! process is SIGKILLed mid-run, the in-flight step fails with a fatal
//! transport error, and `take_snapshot`/`recover` rebuild the engine on
//! the surviving workers — after which replaying from the snapshot step
//! completes the run *bitwise-identical* to an uninterrupted
//! single-threaded run. Determinism makes crash recovery testable exactly:
//! there is no "close enough" after a worker dies.

use extensor::optim::{self, GroupSpec, Hyper, Optimizer};
use extensor::shard::{ShardedOptimizer, DEFAULT_MIN_BUCKET_NUMEL};
use extensor::tensoring::OptimizerKind;
use extensor::transport::SocketTransport;
use extensor::util::rng::Pcg64;
use std::sync::Arc;
use std::time::Duration;

const STEPS: usize = 6;
const SNAP_AT: usize = 3;
const LR: f32 = 0.05;

fn groups() -> Vec<GroupSpec> {
    vec![
        GroupSpec::new("embed", &[40, 16]),
        GroupSpec::new("ff1", &[16, 24]),
        GroupSpec::new("ff2", &[24, 16]),
        GroupSpec::new("bias", &[24]),
    ]
}

fn grad_stream(gs: &[GroupSpec], steps: usize, seed: u64) -> Vec<Vec<Vec<f32>>> {
    let mut rng = Pcg64::seeded(seed);
    (0..steps)
        .map(|_| {
            gs.iter()
                .map(|g| {
                    let mut v = vec![0.0f32; g.numel()];
                    rng.fill_normal(&mut v, 1.0);
                    v
                })
                .collect()
        })
        .collect()
}

fn init_params(gs: &[GroupSpec]) -> Vec<Vec<f32>> {
    let mut rng = Pcg64::seeded(0xF00D);
    gs.iter()
        .map(|g| {
            let mut v = vec![0.0f32; g.numel()];
            rng.fill_uniform(&mut v, -0.5, 0.5);
            v
        })
        .collect()
}

fn socket_transport(tag: &str) -> Arc<SocketTransport> {
    let dir = std::env::temp_dir().join(format!("et-recover-{}-{tag}", std::process::id()));
    Arc::new(
        SocketTransport::new(dir, env!("CARGO_BIN_EXE_ettrain"))
            .with_timeouts(Duration::from_secs(20), Duration::from_secs(10)),
    )
}

/// The uninterrupted reference: single-threaded, same seeds.
fn reference_params(gs: &[GroupSpec], stream: &[Vec<Vec<f32>>]) -> Vec<Vec<f32>> {
    let mut opt = optim::build(OptimizerKind::Et(2), gs, &Hyper::default());
    let mut params = init_params(gs);
    for grads in stream {
        opt.next_step();
        for (gi, (p, g)) in params.iter_mut().zip(grads).enumerate() {
            opt.step(gi, p, g, LR).unwrap();
        }
    }
    params
}

#[test]
fn killed_socket_worker_recovers_and_completes_bitwise() {
    let gs = groups();
    let stream = grad_stream(&gs, STEPS, 29);
    let want = reference_params(&gs, &stream);

    let transport = socket_transport("kill");
    let mut opt = ShardedOptimizer::with_transport(
        OptimizerKind::Et(2),
        &gs,
        &Hyper::default(),
        2,
        None,
        DEFAULT_MIN_BUCKET_NUMEL,
        transport.clone(),
    )
    .unwrap();
    assert_eq!(opt.transport_name(), "socket");

    let mut params = init_params(&gs);
    // Run to the snapshot boundary, then snapshot both the optimizer state
    // (inside the engine) and our own copy of the parameters — crash
    // recovery rewinds to the last consistent (params, state) pair.
    for grads in stream.iter().take(SNAP_AT) {
        opt.next_step();
        opt.step_all(&mut params, grads, LR).unwrap();
    }
    let snap_step = opt.take_snapshot().unwrap();
    assert_eq!(snap_step, SNAP_AT as u64);
    assert_eq!(opt.snapshot_step(), Some(SNAP_AT as u64));
    let params_at_snapshot = params.clone();

    // Keep running past the snapshot, then SIGKILL shard 1's worker
    // process. The next dispatch must fail with a *fatal* transport error
    // (possibly leaving `params` partially updated — that is exactly why
    // recovery rewinds them).
    opt.next_step();
    opt.step_all(&mut params, &stream[SNAP_AT], LR).unwrap();

    let pids = transport.spawned_pids();
    assert_eq!(pids.len(), 2, "two shards -> two spawned workers");
    let victim = pids[1];
    let killed = std::process::Command::new("kill")
        .args(["-9", &victim.to_string()])
        .status()
        .expect("spawn kill");
    assert!(killed.success(), "kill -9 {victim} failed");

    let mut died = false;
    for grads in stream.iter().skip(SNAP_AT + 1) {
        opt.next_step();
        if opt.step_all(&mut params, grads, LR).is_err() {
            died = true;
            break;
        }
    }
    assert!(died, "step_all must fail after a worker is SIGKILLed");

    // Recover onto the survivors and replay from the snapshot.
    let resume_step = opt.recover().unwrap();
    assert_eq!(resume_step, SNAP_AT as u64);
    assert_eq!(opt.n_shards(), 1, "one of two workers died -> rebuilt on the survivor");
    params = params_at_snapshot;
    for grads in stream.iter().skip(SNAP_AT) {
        opt.next_step();
        opt.step_all(&mut params, grads, LR).unwrap();
    }

    assert_eq!(
        want, params,
        "post-recovery completion diverged from the uninterrupted run"
    );
}

/// Snapshot/recover is not tied to a crash: recovering with every worker
/// alive is just a rebuild-and-replay, and still bitwise.
#[test]
fn recover_with_all_workers_alive_replays_bitwise() {
    let gs = groups();
    let stream = grad_stream(&gs, STEPS, 31);
    let want = reference_params(&gs, &stream);

    let transport = socket_transport("alive");
    let mut opt = ShardedOptimizer::with_transport(
        OptimizerKind::Et(2),
        &gs,
        &Hyper::default(),
        2,
        None,
        DEFAULT_MIN_BUCKET_NUMEL,
        transport,
    )
    .unwrap();
    let mut params = init_params(&gs);
    for grads in stream.iter().take(SNAP_AT) {
        opt.next_step();
        opt.step_all(&mut params, grads, LR).unwrap();
    }
    opt.take_snapshot().unwrap();
    let params_at_snapshot = params.clone();
    for grads in stream.iter().skip(SNAP_AT) {
        opt.next_step();
        opt.step_all(&mut params, grads, LR).unwrap();
    }

    let resume = opt.recover().unwrap();
    assert_eq!(resume, SNAP_AT as u64);
    assert_eq!(opt.n_shards(), 2, "no worker died -> same shard count");
    params = params_at_snapshot;
    for grads in stream.iter().skip(SNAP_AT) {
        opt.next_step();
        opt.step_all(&mut params, grads, LR).unwrap();
    }
    assert_eq!(want, params);
}

//! Streaming state-export (ETSS) and wire-codec contracts of the transport
//! subsystem:
//!
//! * **Bounded buffering** — streaming a multi-group, multi-backend
//!   optimizer state with a small chunk cap never hands the underlying
//!   writer more than one chunk's worth of payload at a time, for both the
//!   live-state writer (`write_state_stream`) and the materialized-export
//!   writer (`write_export_stream`). This is the acceptance criterion for
//!   "peak buffering stays under the chunk cap regardless of model size".
//! * **Chunk framing** — every `CHUNK` frame in the byte stream declares at
//!   most the cap's worth of scalars (cap rounded to the quantization
//!   block), and the stream still round-trips bitwise.
//! * **Spec wire codec** — a `WorkerSpec` (the frame that launches a socket
//!   worker) survives the write/read round trip exactly, including a
//!   budget-planned per-group state plan.

use extensor::budget::{plan, PlannerOptions};
use extensor::optim::stream::{
    read_export_stream, write_export_stream, write_state_stream, STREAM_CHUNK_NUMEL,
};
use extensor::optim::{self, GroupSpec, Hyper, Optimizer};
use extensor::tensoring::{OptimizerKind, StateBackend};
use extensor::transport::wire::{read_worker_spec, write_worker_spec};
use extensor::transport::WorkerSpec;
use std::io::Write;

/// A writer that forwards to a buffer while recording the largest single
/// `write` it was handed — the observable peak of the producer's
/// serialization buffering.
#[derive(Default)]
struct MaxWrite {
    bytes: Vec<u8>,
    largest: usize,
}

impl Write for MaxWrite {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.largest = self.largest.max(buf.len());
        self.bytes.extend_from_slice(buf);
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// Multi-group transformer-ish state, stepped so every buffer is non-trivial.
fn stepped_state(
    kind: OptimizerKind,
    backend: StateBackend,
) -> (Vec<GroupSpec>, optim::StateOptimizer) {
    let gs = vec![
        GroupSpec::new("embed", &[120, 64]),
        GroupSpec::new("ff1", &[64, 96]),
        GroupSpec::new("ff2", &[96, 64]),
        GroupSpec::new("bias", &[96]),
    ];
    let hyper = Hyper { backend, ..Hyper::default() };
    let mut opt = optim::build_state(kind, &gs, &hyper);
    let mut params: Vec<Vec<f32>> = gs.iter().map(|g| vec![0.2f32; g.numel()]).collect();
    let grads: Vec<Vec<f32>> = gs
        .iter()
        .map(|g| (0..g.numel()).map(|i| ((i % 17) as f32 - 8.0) * 0.03).collect())
        .collect();
    for _ in 0..3 {
        opt.next_step();
        opt.step_all(&mut params, &grads, 0.02).unwrap();
    }
    (gs, opt)
}

/// Walk the raw stream and collect every CHUNK frame's declared scalar
/// count, using the public reader for everything else. Implemented as a
/// forwarding reader that inspects the byte positions of chunk headers
/// would be brittle; instead re-parse the frames directly with the same
/// layout the module documents.
fn chunk_sizes(bytes: &[u8]) -> Vec<usize> {
    // Frame layout (little-endian): see optim::stream module docs.
    let u32_at = |p: usize| u32::from_le_bytes(bytes[p..p + 4].try_into().unwrap()) as usize;
    let u64_at = |p: usize| u64::from_le_bytes(bytes[p..p + 8].try_into().unwrap()) as usize;
    let mut p = 4 + 4; // magic + version
    let kind_len = u32_at(p);
    p += 4 + kind_len; // kind str
    p += 8; // step
    let n_groups = u32_at(p);
    p += 4;
    let mut sizes = Vec::new();
    for _ in 0..n_groups {
        assert_eq!(u32_at(p), 1, "expected GROUP opcode");
        p += 4;
        let name_len = u32_at(p);
        p += 4 + name_len;
        p += 8; // steps
        let n_wide = u32_at(p);
        p += 4 + 8 * n_wide;
        let n_bufs = u32_at(p);
        p += 4;
        for _ in 0..n_bufs {
            let bname_len = u32_at(p);
            p += 4 + bname_len;
            let total = u64_at(p);
            p += 8;
            let mut got = 0usize;
            while got < total {
                assert_eq!(u32_at(p), 2, "expected CHUNK opcode");
                p += 4;
                let n = u64_at(p);
                p += 8 + 4 * n;
                sizes.push(n);
                got += n;
            }
        }
    }
    assert_eq!(u32_at(p), 3, "expected END opcode");
    sizes
}

#[test]
fn streaming_export_peak_buffering_stays_under_the_chunk_cap() {
    const CHUNK: usize = 64;
    for backend in [StateBackend::DenseF32, StateBackend::q8(), StateBackend::nf4()] {
        let (_, opt) = stepped_state(OptimizerKind::Adam, backend);
        let export = opt.export();
        let total_scalars: usize = export
            .groups
            .iter()
            .flat_map(|g| g.bufs.iter().map(|(_, d)| d.len()))
            .sum();
        assert!(
            total_scalars > 40 * CHUNK,
            "{backend:?}: state too small to prove chunking ({total_scalars} scalars)"
        );

        let mut live = MaxWrite::default();
        write_state_stream(&mut live, opt.state(), CHUNK).unwrap();
        // The block-aligned chunk step never exceeds the cap (64 is a
        // multiple of every default quantization block), so no single
        // write — chunk payloads included — may exceed one chunk of f32s.
        assert!(
            live.largest <= 4 * CHUNK,
            "{backend:?}: live writer handed the sink {} bytes at once (cap {})",
            live.largest,
            4 * CHUNK
        );
        // Every declared chunk is within the cap, and they cover the state.
        let sizes = chunk_sizes(&live.bytes);
        assert!(sizes.iter().all(|&n| n > 0 && n <= CHUNK), "{backend:?}: oversized chunk");
        assert_eq!(sizes.iter().sum::<usize>(), total_scalars);

        // The materialized-export writer obeys the same bound and both
        // streams decode to the same snapshot.
        let mut mat = MaxWrite::default();
        write_export_stream(&mut mat, &export, CHUNK).unwrap();
        assert!(mat.largest <= 4 * CHUNK, "{backend:?}: export writer exceeded the cap");
        let a = read_export_stream(&mut live.bytes.as_slice(), 1 << 20).unwrap();
        let b = read_export_stream(&mut mat.bytes.as_slice(), 1 << 20).unwrap();
        assert_eq!(a, export, "{backend:?}: live stream lost data");
        assert_eq!(b, export, "{backend:?}: export stream lost data");
    }
}

/// The default cap exists so callers that don't pick one still get bounded
/// buffering: one frame is at most 64 KiB of payload.
#[test]
fn default_chunk_cap_bounds_frames_for_large_state() {
    let (_, opt) = stepped_state(OptimizerKind::AdaGrad, StateBackend::DenseF32);
    let mut w = MaxWrite::default();
    write_state_stream(&mut w, opt.state(), STREAM_CHUNK_NUMEL).unwrap();
    assert!(w.largest <= 4 * STREAM_CHUNK_NUMEL);
}

#[test]
fn worker_spec_round_trips_over_the_wire() {
    let gs = vec![GroupSpec::new("w", &[48, 32]), GroupSpec::new("b", &[32])];
    let hyper = Hyper { backend: StateBackend::q8(), ..Hyper::default() };

    let uniform = WorkerSpec::Uniform {
        kind: OptimizerKind::Et(3),
        groups: gs.clone(),
        hyper: hyper.clone(),
    };
    let mut bytes = Vec::new();
    write_worker_spec(&mut bytes, &uniform).unwrap();
    let back = read_worker_spec(&mut bytes.as_slice()).unwrap();
    match (&uniform, &back) {
        (
            WorkerSpec::Uniform { kind: ka, groups: ga, hyper: ha },
            WorkerSpec::Uniform { kind: kb, groups: gb, hyper: hb },
        ) => {
            assert_eq!(ka, kb);
            assert_eq!(ga, gb);
            assert_eq!(ha.backend, hb.backend);
            assert_eq!(ha.eps.to_bits(), hb.eps.to_bits());
        }
        _ => panic!("uniform spec changed variant in round trip"),
    }

    // A budget-planned spec: the per-group plan travels as JSON inside the
    // frame and must survive exactly (the worker rebuilds the planned
    // optimizer from it).
    let state_plan = plan(&gs, 16 << 10, &PlannerOptions::default()).unwrap();
    let planned = WorkerSpec::Planned { groups: gs.clone(), plan: state_plan.clone(), hyper };
    let mut bytes = Vec::new();
    write_worker_spec(&mut bytes, &planned).unwrap();
    match read_worker_spec(&mut bytes.as_slice()).unwrap() {
        WorkerSpec::Planned { groups, plan: p, .. } => {
            assert_eq!(groups, gs);
            assert_eq!(p, state_plan);
        }
        _ => panic!("planned spec changed variant in round trip"),
    }
}

//! Cross-layer integration: load the AOT lm_micro artifacts, run train and
//! eval steps from rust, and verify (a) the execution contract, (b) loss
//! decreases under training, (c) the compiled ET2 artifact agrees with the
//! pure-rust extreme-tensoring oracle on the golden fixture.
//!
//! These tests are skipped (with a note) when `artifacts/` has not been
//! built — run `make artifacts` first.

use anyhow::Result;
use extensor::optim::{GroupSpec, Optimizer};
use extensor::runtime::{Client, DataArg, Engine};
use extensor::util::json::Json;
use std::path::Path;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = extensor::runtime::default_artifact_dir();
    if dir.join("lm_micro_et2.json").exists() {
        Some(dir)
    } else {
        eprintln!("skip: artifacts not built (run `make artifacts`)");
        None
    }
}

fn micro_tokens(seed: u64, rows: usize, seq: usize, vocab: usize) -> Vec<i32> {
    let mut rng = extensor::util::rng::Pcg64::seeded(seed);
    (0..rows * seq).map(|_| (1 + rng.below(vocab as u64 - 1)) as i32).collect()
}

#[test]
fn train_step_runs_and_loss_decreases() -> Result<()> {
    let Some(dir) = artifacts_dir() else { return Ok(()) };
    let client = Client::cpu()?;
    let engine = Engine::load(&client, &dir, "lm_micro_et2")?;
    let mut state = engine.init_state(42)?;
    let vocab = engine.manifest.model.get("vocab").unwrap().as_usize().unwrap();
    let (rows, seq) = (2, 16);

    // Repeated steps on one fixed batch must drive its loss down hard.
    let tokens = micro_tokens(7, rows, seq, vocab);
    let first = engine.train_step_tokens(&mut state, &tokens, 0.1)?.loss;
    let mut last = first;
    for _ in 0..30 {
        last = engine.train_step_tokens(&mut state, &tokens, 0.1)?.loss;
    }
    assert!(first.is_finite() && last.is_finite());
    assert!(
        last < first * 0.7,
        "memorization failed: {first} -> {last}"
    );
    Ok(())
}

#[test]
fn eval_artifact_aggregates_nll() -> Result<()> {
    let Some(dir) = artifacts_dir() else { return Ok(()) };
    let client = Client::cpu()?;
    let train = Engine::load(&client, &dir, "lm_micro_et2")?;
    let eval = Engine::load(&client, &dir, "lm_micro_eval")?;
    let state = train.init_state(1)?;
    let vocab = train.manifest.model.get("vocab").unwrap().as_usize().unwrap();
    let tokens = micro_tokens(9, 2, 16, vocab);
    let out = eval.eval_step(&state, &[DataArg::I32(&tokens)])?;
    assert!(out.token_count > 0.0);
    let mean = out.total_nll / out.token_count;
    // Untrained model on vocab-64 data: mean NLL should be near ln(64).
    assert!(
        (mean - (vocab as f64).ln()).abs() < 1.5,
        "untrained mean nll {mean} far from ln(V) {}",
        (vocab as f64).ln()
    );
    Ok(())
}

/// The golden fixture: python ran two fused ET2 steps; rust must reproduce
/// the same losses from the same initial params/tokens via the compiled
/// artifact, and the same final parameter checksums.
#[test]
fn golden_et2_two_steps_match_python() -> Result<()> {
    let Some(dir) = artifacts_dir() else { return Ok(()) };
    let gpath = dir.join("golden/lm_micro_et2_steps.json");
    let golden = Json::parse(&std::fs::read_to_string(&gpath)?)
        .map_err(|e| anyhow::anyhow!("golden json: {e}"))?;

    let client = Client::cpu()?;
    let engine = Engine::load(&client, &dir, "lm_micro_et2")?;

    // Initial params from the fixture, opt state zeros.
    let params: Vec<Vec<f32>> = golden
        .get("param_init")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|p| {
            p.get("values")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|v| v.as_f64().unwrap() as f32)
                .collect()
        })
        .collect();
    let opt_state: Vec<Vec<f32>> =
        engine.manifest.opt_state.iter().map(|s| vec![0.0f32; s.numel()]).collect();
    let mut state = engine.state_from_vecs(&params, &opt_state, 0)?;

    let tokens: Vec<i32> = golden
        .get("tokens")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_i64().unwrap() as i32)
        .collect();
    let lr = golden.get("lr").unwrap().as_f64().unwrap() as f32;
    let want_losses: Vec<f64> = golden
        .get("losses")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap())
        .collect();

    for (i, want) in want_losses.iter().enumerate() {
        let got = engine.train_step_tokens(&mut state, &tokens, lr)?.loss as f64;
        let rel = (got - want).abs() / want.abs().max(1e-9);
        assert!(rel < 2e-4, "step {i}: loss {got} vs python {want} (rel {rel:.2e})");
    }

    // Final parameter checksums.
    for entry in golden.get("final_param_checksums").unwrap().as_arr().unwrap() {
        let name = entry.get("name").unwrap().as_str().unwrap();
        let want = entry.get("sum_abs").unwrap().as_f64().unwrap();
        let got: f64 = state
            .param_to_vec(&engine.manifest, name)?
            .iter()
            .map(|&x| x.abs() as f64)
            .sum();
        let rel = (got - want).abs() / want.max(1e-9);
        assert!(rel < 5e-4, "param {name}: checksum {got} vs {want} (rel {rel:.2e})");
    }
    Ok(())
}

/// The compiled ET2 artifact and the pure-rust ET oracle must produce the
/// same parameter update when fed the same gradients. We use the grad
/// artifact to extract the HLO-side gradients, then apply the rust
/// optimizer to the same initial params and compare against one artifact
/// train step.
#[test]
fn artifact_update_matches_rust_oracle() -> Result<()> {
    let Some(dir) = artifacts_dir() else { return Ok(()) };
    if !dir.join("lm_micro_grad.json").exists() {
        eprintln!("skip: lm_micro_grad not built");
        return Ok(());
    }
    let client = Client::cpu()?;
    let train = Engine::load(&client, &dir, "lm_micro_et2")?;
    let grad = Engine::load(&client, &dir, "lm_micro_grad")?;

    let mut state = train.init_state(123)?;
    let vocab = train.manifest.model.get("vocab").unwrap().as_usize().unwrap();
    let tokens = micro_tokens(55, 2, 16, vocab);

    // Host copies of the initial params.
    let params_host: Vec<Vec<f32>> = train
        .manifest
        .params
        .iter()
        .map(|p| state.param_to_vec(&train.manifest, &p.name))
        .collect::<Result<_>>()?;

    // HLO-side gradients at the initial params.
    let (_, grads) = grad.grad_step(&state, &[DataArg::I32(&tokens)])?;

    // Rust oracle: ET2 on the same groups (externalized-state suite).
    let groups: Vec<GroupSpec> = train.manifest.group_specs();
    let mut oracle = extensor::optim::build(
        extensor::tensoring::OptimizerKind::Et(2),
        &groups,
        &extensor::optim::Hyper::default(),
    );
    let mut oracle_params = params_host.clone();
    for (gi, (p, g)) in oracle_params.iter_mut().zip(&grads).enumerate() {
        oracle.step(gi, p, g, 0.05)?;
    }

    // One artifact train step from the same state.
    train.train_step_tokens(&mut state, &tokens, 0.05)?;

    for (gi, spec) in train.manifest.params.iter().enumerate() {
        let got = state.param_to_vec(&train.manifest, &spec.name)?;
        let want = &oracle_params[gi];
        let mut max_rel = 0.0f64;
        for (a, b) in got.iter().zip(want) {
            let rel = ((a - b).abs() as f64) / (b.abs() as f64).max(1e-5);
            max_rel = max_rel.max(rel);
        }
        assert!(
            max_rel < 5e-3,
            "param {}: artifact vs rust oracle max rel diff {max_rel:.2e}",
            spec.name
        );
    }
    Ok(())
}

//! The structured event stream a scheduler run emits: every job's life
//! cycle (`queued → admitted → progress → finished|failed`), plus the
//! session-cache observations (`artifact-cache` / `corpus-cache` hits) that
//! make resource reuse auditable. Events are timestamped against the batch
//! clock, narrated to the CLI as they happen, appended to a JSONL log, and
//! returned in-order inside [`crate::session::BatchReport`] so tests can
//! assert on scheduling behavior (admission order, overlap, cache-hit
//! counts).

use crate::util::json::Json;
use crate::util::timer::Timer;
use std::sync::mpsc::Sender;
use std::sync::Arc;

/// One scheduler event. Every variant names the job it concerns.
#[derive(Clone, Debug, PartialEq)]
pub enum JobEvent {
    /// The job entered the queue with its admission cost.
    Queued { job: String, cost_bytes: u64 },
    /// The job was admitted and started executing; `in_use_bytes` is the
    /// budget consumption *including* this job.
    Admitted { job: String, cost_bytes: u64, in_use_bytes: u64 },
    /// The job could not be admitted right now (budget exhausted) and
    /// stays queued. Emitted at most once per job.
    Deferred { job: String, cost_bytes: u64, available_bytes: u64 },
    /// Periodic step progress from inside a running job.
    Progress { job: String, step: u64, of: u64, loss: f64 },
    /// The job asked the session for a compiled artifact engine.
    ArtifactCache { job: String, artifact: String, hit: bool },
    /// The job asked the session for a synthesized corpus/dataset.
    CorpusCache { job: String, key: String, hit: bool },
    /// An admitted job returned its budget reservation; `in_use_bytes`
    /// is the consumption *after* the release, so budget occupancy is
    /// reconstructible from the log alone (pair with [`JobEvent::Admitted`]).
    Released { job: String, in_use_bytes: u64 },
    /// A supervision decision inside a self-healing sharded job: `phase`
    /// is the [`crate::shard::RecoveryEvent`] tag (`snapshot`, `incident`,
    /// `recovered`, `gave-up`), `step` the supervised step it happened at,
    /// `kind` the transport-error taxonomy bucket (empty for snapshots),
    /// and `detail` the human-readable account.
    Recovery { job: String, phase: String, step: u64, kind: String, detail: String },
    /// The job completed successfully.
    Finished { job: String, wall_seconds: f64 },
    /// The job failed (the batch continues; the error is also in the
    /// job's [`crate::session::JobResult`]).
    Failed { job: String, error: String },
}

impl JobEvent {
    /// The job this event concerns.
    pub fn job(&self) -> &str {
        match self {
            JobEvent::Queued { job, .. }
            | JobEvent::Admitted { job, .. }
            | JobEvent::Deferred { job, .. }
            | JobEvent::Progress { job, .. }
            | JobEvent::ArtifactCache { job, .. }
            | JobEvent::CorpusCache { job, .. }
            | JobEvent::Released { job, .. }
            | JobEvent::Recovery { job, .. }
            | JobEvent::Finished { job, .. }
            | JobEvent::Failed { job, .. } => job,
        }
    }

    /// The event-kind tag used in the JSONL log.
    pub fn kind(&self) -> &'static str {
        match self {
            JobEvent::Queued { .. } => "queued",
            JobEvent::Admitted { .. } => "admitted",
            JobEvent::Deferred { .. } => "deferred",
            JobEvent::Progress { .. } => "progress",
            JobEvent::ArtifactCache { .. } => "artifact_cache",
            JobEvent::CorpusCache { .. } => "corpus_cache",
            JobEvent::Released { .. } => "released",
            JobEvent::Recovery { .. } => "recovery",
            JobEvent::Finished { .. } => "finished",
            JobEvent::Failed { .. } => "failed",
        }
    }

    fn fields(&self) -> Vec<(&'static str, Json)> {
        match self {
            JobEvent::Queued { cost_bytes, .. } => {
                vec![("cost_bytes", Json::num(*cost_bytes as f64))]
            }
            JobEvent::Admitted { cost_bytes, in_use_bytes, .. } => vec![
                ("cost_bytes", Json::num(*cost_bytes as f64)),
                ("in_use_bytes", Json::num(*in_use_bytes as f64)),
            ],
            JobEvent::Deferred { cost_bytes, available_bytes, .. } => vec![
                ("cost_bytes", Json::num(*cost_bytes as f64)),
                ("available_bytes", Json::num(*available_bytes as f64)),
            ],
            JobEvent::Progress { step, of, loss, .. } => vec![
                ("step", Json::num(*step as f64)),
                ("of", Json::num(*of as f64)),
                ("loss", Json::num(*loss)),
            ],
            JobEvent::ArtifactCache { artifact, hit, .. } => vec![
                ("artifact", Json::str(artifact.clone())),
                ("hit", Json::Bool(*hit)),
            ],
            JobEvent::CorpusCache { key, hit, .. } => {
                vec![("key", Json::str(key.clone())), ("hit", Json::Bool(*hit))]
            }
            JobEvent::Released { in_use_bytes, .. } => {
                vec![("in_use_bytes", Json::num(*in_use_bytes as f64))]
            }
            JobEvent::Recovery { phase, step, kind, detail, .. } => vec![
                ("phase", Json::str(phase.clone())),
                ("step", Json::num(*step as f64)),
                ("kind", Json::str(kind.clone())),
                ("detail", Json::str(detail.clone())),
            ],
            JobEvent::Finished { wall_seconds, .. } => {
                vec![("wall_seconds", Json::num(*wall_seconds))]
            }
            JobEvent::Failed { error, .. } => vec![("error", Json::str(error.clone()))],
        }
    }
}

/// A [`JobEvent`] stamped with seconds since the batch started — the
/// wall-clock axis that makes job overlap visible in the run log.
#[derive(Clone, Debug, PartialEq)]
pub struct StampedEvent {
    /// Seconds since the batch clock started.
    pub t: f64,
    pub event: JobEvent,
}

impl StampedEvent {
    /// JSONL record: `{"t":…, "event":…, "job":…, …fields}`.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("t", Json::num(self.t)),
            ("event", Json::str(self.event.kind())),
            ("job", Json::str(self.event.job().to_string())),
        ];
        pairs.extend(self.event.fields());
        Json::obj(pairs)
    }
}

/// A cheap, clonable handle a running job uses to emit events for itself.
/// Sending never blocks and never fails loudly: if the collector is gone
/// (or the sink was built with [`EventSink::discard`]) events vanish.
#[derive(Clone)]
pub struct EventSink {
    job: String,
    tx: Sender<StampedEvent>,
    clock: Arc<Timer>,
}

impl EventSink {
    /// A sink feeding a collector channel; `clock` is the shared batch
    /// timer events are stamped against.
    pub fn new(job: impl Into<String>, tx: Sender<StampedEvent>, clock: Arc<Timer>) -> EventSink {
        EventSink { job: job.into(), tx, clock }
    }

    /// A sink whose events go nowhere — for driving job executors outside
    /// a scheduler when the event stream genuinely doesn't matter. When
    /// it does (examples asserting on their own cache/progress counters),
    /// use [`EventSink::collect`] instead.
    pub fn discard(job: impl Into<String>) -> EventSink {
        let (tx, _rx) = std::sync::mpsc::channel();
        EventSink { job: job.into(), tx, clock: Arc::new(Timer::start()) }
    }

    /// A sink buffering its events in-process, plus the drain handle to
    /// read them back — the standalone-executor counterpart of the
    /// scheduler's collector thread.
    pub fn collect(job: impl Into<String>) -> (EventSink, CollectedEvents) {
        let (tx, rx) = std::sync::mpsc::channel();
        let sink = EventSink { job: job.into(), tx, clock: Arc::new(Timer::start()) };
        (sink, CollectedEvents { rx })
    }

    /// The job this sink reports for.
    pub fn job(&self) -> &str {
        &self.job
    }

    /// Emit an arbitrary event (the scheduler's own life-cycle events).
    pub fn emit(&self, event: JobEvent) {
        let _ = self.tx.send(StampedEvent { t: self.clock.elapsed_secs(), event });
    }

    /// Report step progress.
    pub fn progress(&self, step: u64, of: u64, loss: f64) {
        self.emit(JobEvent::Progress { job: self.job.clone(), step, of, loss });
    }

    /// Report an artifact-engine cache lookup.
    pub fn artifact_cache(&self, artifact: &str, hit: bool) {
        self.emit(JobEvent::ArtifactCache {
            job: self.job.clone(),
            artifact: artifact.to_string(),
            hit,
        });
    }

    /// Report a corpus/dataset cache lookup.
    pub fn corpus_cache(&self, key: &str, hit: bool) {
        self.emit(JobEvent::CorpusCache { job: self.job.clone(), key: key.to_string(), hit });
    }

    /// Report a supervision decision (snapshot/incident/recovered/gave-up)
    /// from a self-healing sharded job.
    pub fn recovery(&self, phase: &str, step: u64, kind: &str, detail: &str) {
        self.emit(JobEvent::Recovery {
            job: self.job.clone(),
            phase: phase.to_string(),
            step,
            kind: kind.to_string(),
            detail: detail.to_string(),
        });
    }
}

/// The drain side of [`EventSink::collect`]: buffers every event the
/// paired sink emitted until [`CollectedEvents::drain`] is called.
pub struct CollectedEvents {
    rx: std::sync::mpsc::Receiver<StampedEvent>,
}

impl CollectedEvents {
    /// Every event emitted so far, in order, without blocking.
    pub fn drain(&self) -> Vec<StampedEvent> {
        self.rx.try_iter().collect()
    }
}

/// Cache-lookup totals extracted from an event stream — the counters the
/// acceptance checks assert on ("each artifact loaded and each corpus
/// synthesized at most once per batch").
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheCounts {
    pub artifact_hits: usize,
    pub artifact_misses: usize,
    pub corpus_hits: usize,
    pub corpus_misses: usize,
}

impl CacheCounts {
    /// Tally the cache events in `events`.
    pub fn from_events(events: &[StampedEvent]) -> CacheCounts {
        let mut c = CacheCounts::default();
        for e in events {
            match &e.event {
                JobEvent::ArtifactCache { hit: true, .. } => c.artifact_hits += 1,
                JobEvent::ArtifactCache { hit: false, .. } => c.artifact_misses += 1,
                JobEvent::CorpusCache { hit: true, .. } => c.corpus_hits += 1,
                JobEvent::CorpusCache { hit: false, .. } => c.corpus_misses += 1,
                _ => {}
            }
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn events_carry_job_and_kind() {
        let e = JobEvent::Admitted { job: "a".into(), cost_bytes: 10, in_use_bytes: 10 };
        assert_eq!(e.job(), "a");
        assert_eq!(e.kind(), "admitted");
        let s = StampedEvent { t: 0.5, event: e };
        let j = s.to_json();
        assert_eq!(j.get("event").and_then(|v| v.as_str()), Some("admitted"));
        assert_eq!(j.get("job").and_then(|v| v.as_str()), Some("a"));
    }

    #[test]
    fn sink_stamps_and_delivers() {
        let (tx, rx) = channel();
        let sink = EventSink::new("j", tx, Arc::new(Timer::start()));
        sink.progress(3, 10, 1.25);
        sink.artifact_cache("lm_tiny_et1", true);
        sink.corpus_cache("lm:v1900", false);
        drop(sink);
        let got: Vec<StampedEvent> = rx.iter().collect();
        assert_eq!(got.len(), 3);
        assert!(got.iter().all(|e| e.event.job() == "j"));
        let counts = CacheCounts::from_events(&got);
        assert_eq!(
            counts,
            CacheCounts { artifact_hits: 1, artifact_misses: 0, corpus_hits: 0, corpus_misses: 1 }
        );
    }

    #[test]
    fn discard_sink_is_silent() {
        let sink = EventSink::discard("x");
        sink.progress(1, 2, 0.0); // must not panic on the closed channel
    }

    #[test]
    fn collect_sink_buffers_and_drains() {
        let (sink, events) = EventSink::collect("c");
        sink.progress(1, 4, 2.0);
        sink.corpus_cache("k", false);
        let got = events.drain();
        assert_eq!(got.len(), 2);
        assert!(got.iter().all(|e| e.event.job() == "c"));
        assert!(events.drain().is_empty(), "drain must not replay");
        sink.progress(2, 4, 1.5);
        assert_eq!(events.drain().len(), 1);
    }

    #[test]
    fn recovery_event_shape() {
        let (sink, events) = EventSink::collect("sb");
        sink.recovery("incident", 5, "disconnected", "shard 1: worker disconnected");
        let got = events.drain();
        assert_eq!(got.len(), 1);
        let j = got[0].to_json();
        assert_eq!(j.get("event").and_then(|v| v.as_str()), Some("recovery"));
        assert_eq!(j.get("phase").and_then(|v| v.as_str()), Some("incident"));
        assert_eq!(j.get("step").and_then(|v| v.as_usize()), Some(5));
        assert_eq!(j.get("kind").and_then(|v| v.as_str()), Some("disconnected"));
    }

    #[test]
    fn released_event_shape() {
        let e = JobEvent::Released { job: "r".into(), in_use_bytes: 64 };
        assert_eq!(e.kind(), "released");
        assert_eq!(e.job(), "r");
        let j = StampedEvent { t: 1.0, event: e }.to_json();
        assert_eq!(j.get("in_use_bytes").and_then(|v| v.as_usize()), Some(64));
    }
}

//! The unified run-execution layer: `JobSpec → Session → Scheduler`.
//!
//! ```text
//!   JobSpec (spec)          what to run: typed, validated, serializable
//!      │                    (LM artifact run | convex | shard-bench | vision)
//!      ▼
//!   Session (this module)   process-wide shared resources: one PJRT
//!      │                    client, an artifact/Engine cache keyed by
//!      │                    path+name, corpus & convex-dataset caches
//!      │                    keyed by synthesis params (cache-hit counters
//!      ▼                    surface as JobEvents)
//!   Scheduler (scheduler)   N worker threads, memory-budget admission
//!      │                    control costed by tensoring::memory
//!      ▼
//!   JobEvent stream (events): queued → admitted → progress → finished/failed,
//!   narrated to the CLI and appended to a JSONL log
//! ```
//!
//! Before this layer existed, every entry point (`Trainer::new(cfg)?.run()`,
//! the `ExpOptions` experiment functions, `ablation::run`) re-created its
//! own PJRT client, re-compiled artifacts, and re-synthesized corpora, and
//! everything ran strictly serially. Now `ettrain train`/`experiment` are
//! thin wrappers over this API, every table/figure sweep submits a
//! `JobSpec` batch, and `ettrain batch <jobs.toml>` runs user-authored
//! fleets — with the paper's own memory accounting
//! ([`crate::tensoring::memory`]) deciding how many preconditioned runs fit
//! in a host budget at once.
//!
//! Determinism: a job's results depend only on its spec (per-job seeds,
//! no shared mutable state), so `--jobs 4` produces bitwise-identical
//! per-run metrics and checkpoints to `--jobs 1`
//! (`rust/tests/scheduler.rs`).

pub mod events;
pub mod scheduler;
pub mod spec;

pub use events::{CacheCounts, CollectedEvents, EventSink, JobEvent, StampedEvent};
pub use scheduler::{run_batch, Admission, BatchReport, JobResult, SchedulerOptions};
pub use spec::{
    batch_from_config, batch_to_toml, ConvexOpt, ConvexSpec, JobSpec, ShardBenchSpec, VisionSpec,
    Workload,
};

use crate::convex::{ConvexConfig, ConvexDataset, SoftmaxRegression};
use crate::data::{Corpus, SyntheticConfig, Tokenizer};
use crate::optim::{self, GroupSpec, Hyper, Optimizer};
use crate::runtime::{Client, Engine};
use crate::shard::ShardedOptimizer;
use crate::tensoring::{EpsMode, SliceAccumulators, StateBackend, TensorIndex};
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::util::timer::Timer;
use crate::vision::{VisionConfig, VisionDataset};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

/// Lock a session cache, recovering from poisoning: the caches only ever
/// hold fully-constructed `Arc`s (a panicking insert-path job leaves at
/// worst a missing entry), so a poisoned lock must not cascade into
/// failing every later job in the batch.
fn lock_cache<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

// ---------------------------------------------------------------------------
// Session: shared process-wide resources
// ---------------------------------------------------------------------------

/// A synthesized LM corpus with its fitted tokenizer, shared read-only
/// between jobs.
pub struct LmData {
    pub corpus: Corpus,
    pub tokenizer: Tokenizer,
}

#[derive(Clone, Hash, PartialEq, Eq)]
struct LmKey {
    vocab: usize,
    sentences: usize,
    mean_len: usize,
    branching: usize,
    seed: u64,
}

#[derive(Clone, Hash, PartialEq, Eq)]
struct ConvexKey {
    n: usize,
    d: usize,
    k: usize,
    cond_bits: u64,
    householder: usize,
    seed: u64,
}

/// Generated vision train/test datasets, shared read-only between jobs.
pub struct VisionData {
    pub train: VisionDataset,
    pub test: VisionDataset,
}

#[derive(Clone, Hash, PartialEq, Eq)]
struct VisionKey {
    classes: usize,
    train: usize,
    test: usize,
    blobs: usize,
    noise_bits: u32,
    mix_max_bits: u32,
    seed: u64,
}

/// Point-in-time cache counters (process totals, across batches).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SessionStats {
    pub artifact_hits: usize,
    pub artifact_misses: usize,
    pub corpus_hits: usize,
    pub corpus_misses: usize,
}

/// Owner of everything concurrent jobs share: the PJRT client (created
/// once, lazily), compiled artifact engines keyed by `dir::name`, and
/// synthesized datasets keyed by their synthesis parameters. All lookups
/// return `(Arc<resource>, cache_hit)` so callers can surface
/// [`JobEvent::ArtifactCache`]/[`JobEvent::CorpusCache`] events; process
/// totals are also tracked in [`SessionStats`].
#[derive(Default)]
pub struct Session {
    client: Mutex<Option<Client>>,
    engines: Mutex<HashMap<String, Arc<Engine>>>,
    lm_data: Mutex<HashMap<LmKey, Arc<LmData>>>,
    convex: Mutex<HashMap<ConvexKey, Arc<ConvexDataset>>>,
    vision: Mutex<HashMap<VisionKey, Arc<VisionData>>>,
    artifact_hits: AtomicUsize,
    artifact_misses: AtomicUsize,
    corpus_hits: AtomicUsize,
    corpus_misses: AtomicUsize,
}

impl Session {
    pub fn new() -> Session {
        Session::default()
    }

    /// The shared PJRT client (created on first use; clones share one
    /// underlying client).
    pub fn client(&self) -> Result<Client> {
        let mut guard = lock_cache(&self.client);
        if let Some(c) = &*guard {
            return Ok(c.clone());
        }
        let c = Client::cpu()?;
        *guard = Some(c.clone());
        Ok(c)
    }

    /// The compiled engine for `dir/<name>`, loading and compiling at most
    /// once per session. Returns `(engine, cache_hit)`.
    ///
    /// The cache lock is held across a miss's load+compile, which
    /// serializes concurrent artifact loads — deliberate: it also
    /// guarantees an artifact is never compiled twice by racing jobs.
    pub fn engine(&self, dir: &Path, name: &str) -> Result<(Arc<Engine>, bool)> {
        let key = format!("{}::{name}", dir.display());
        let mut cache = lock_cache(&self.engines);
        if let Some(e) = cache.get(&key) {
            self.artifact_hits.fetch_add(1, Ordering::Relaxed);
            return Ok((e.clone(), true));
        }
        let client = self.client()?;
        let engine = Arc::new(Engine::load(&client, dir, name)?);
        cache.insert(key, engine.clone());
        self.artifact_misses.fetch_add(1, Ordering::Relaxed);
        Ok((engine, false))
    }

    /// The synthesized LM corpus + tokenizer for `cfg`, generated at most
    /// once per session. Returns `(data, cache_hit)`.
    pub fn lm_data(&self, cfg: &SyntheticConfig) -> (Arc<LmData>, bool) {
        let key = LmKey {
            vocab: cfg.vocab,
            sentences: cfg.sentences,
            mean_len: cfg.mean_len,
            branching: cfg.branching,
            seed: cfg.seed,
        };
        let mut cache = lock_cache(&self.lm_data);
        if let Some(d) = cache.get(&key) {
            self.corpus_hits.fetch_add(1, Ordering::Relaxed);
            return (d.clone(), true);
        }
        let corpus = Corpus::synthetic(cfg);
        let tokenizer = Tokenizer::from_corpus(&corpus);
        let data = Arc::new(LmData { corpus, tokenizer });
        cache.insert(key, data.clone());
        self.corpus_misses.fetch_add(1, Ordering::Relaxed);
        (data, false)
    }

    /// The event-log label for an LM corpus cache lookup.
    pub fn lm_data_key(cfg: &SyntheticConfig) -> String {
        format!("lm:v{}:s{}:seed{:x}", cfg.vocab, cfg.sentences, cfg.seed)
    }

    /// The convex dataset for `cfg`, generated at most once per session.
    /// Returns `(dataset, cache_hit)`.
    pub fn convex_dataset(&self, cfg: &ConvexConfig) -> (Arc<ConvexDataset>, bool) {
        let key = ConvexKey {
            n: cfg.n,
            d: cfg.d,
            k: cfg.k,
            cond_bits: cfg.cond.to_bits(),
            householder: cfg.householder,
            seed: cfg.seed,
        };
        let mut cache = lock_cache(&self.convex);
        if let Some(d) = cache.get(&key) {
            self.corpus_hits.fetch_add(1, Ordering::Relaxed);
            return (d.clone(), true);
        }
        crate::info!(
            "generating convex dataset (n={}, d={}, cond={})",
            cfg.n,
            cfg.d,
            cfg.cond
        );
        let data = Arc::new(ConvexDataset::generate(cfg));
        cache.insert(key, data.clone());
        self.corpus_misses.fetch_add(1, Ordering::Relaxed);
        (data, false)
    }

    /// The event-log label for a convex dataset cache lookup.
    pub fn convex_key(cfg: &ConvexConfig) -> String {
        format!("convex:n{}:d{}:k{}:seed{:x}", cfg.n, cfg.d, cfg.k, cfg.seed)
    }

    /// The vision train/test datasets for `cfg`, generated at most once
    /// per session. Returns `(data, cache_hit)`.
    pub fn vision_data(&self, cfg: &VisionConfig) -> (Arc<VisionData>, bool) {
        let key = VisionKey {
            classes: cfg.classes,
            train: cfg.train,
            test: cfg.test,
            blobs: cfg.blobs,
            noise_bits: cfg.noise.to_bits(),
            mix_max_bits: cfg.mix_max.to_bits(),
            seed: cfg.seed,
        };
        let mut cache = lock_cache(&self.vision);
        if let Some(d) = cache.get(&key) {
            self.corpus_hits.fetch_add(1, Ordering::Relaxed);
            return (d.clone(), true);
        }
        let (train, test) = VisionDataset::generate(cfg);
        let data = Arc::new(VisionData { train, test });
        cache.insert(key, data.clone());
        self.corpus_misses.fetch_add(1, Ordering::Relaxed);
        (data, false)
    }

    /// The event-log label for a vision dataset cache lookup.
    pub fn vision_key(cfg: &VisionConfig) -> String {
        format!("vision:c{}:tr{}:te{}:seed{:x}", cfg.classes, cfg.train, cfg.test, cfg.seed)
    }

    /// Process-total cache counters.
    pub fn stats(&self) -> SessionStats {
        SessionStats {
            artifact_hits: self.artifact_hits.load(Ordering::Relaxed),
            artifact_misses: self.artifact_misses.load(Ordering::Relaxed),
            corpus_hits: self.corpus_hits.load(Ordering::Relaxed),
            corpus_misses: self.corpus_misses.load(Ordering::Relaxed),
        }
    }
}

// ---------------------------------------------------------------------------
// Job outcomes + the executor
// ---------------------------------------------------------------------------

/// The typed result a completed job hands back to the batch submitter.
pub enum JobOutcome {
    Lm(Box<crate::train::RunResult>),
    Convex(Box<ConvexOutcome>),
    ShardBench(ShardBenchOutcome),
    Vision(Box<crate::train::vision::VisionRun>),
}

impl JobOutcome {
    pub fn as_lm(&self) -> Option<&crate::train::RunResult> {
        match self {
            JobOutcome::Lm(r) => Some(r),
            _ => None,
        }
    }

    pub fn as_convex(&self) -> Option<&ConvexOutcome> {
        match self {
            JobOutcome::Convex(r) => Some(r),
            _ => None,
        }
    }

    pub fn as_shard_bench(&self) -> Option<&ShardBenchOutcome> {
        match self {
            JobOutcome::ShardBench(r) => Some(r),
            _ => None,
        }
    }

    pub fn as_vision(&self) -> Option<&crate::train::vision::VisionRun> {
        match self {
            JobOutcome::Vision(r) => Some(r),
            _ => None,
        }
    }

    /// The `trace_timing/v1` span-histogram profile the job recorded,
    /// when tracing was enabled (shard-bench jobs only for now) — folded
    /// into the job's registry record by [`crate::registry::record_batch`].
    pub fn timing_json(&self) -> Option<&crate::util::json::Json> {
        match self {
            JobOutcome::ShardBench(s) => s.timing.as_ref(),
            _ => None,
        }
    }

    /// Workload-specific final metrics as a flat JSON object — what the
    /// run registry records for a finished job (see [`crate::registry`]).
    pub fn metrics_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        match self {
            JobOutcome::Lm(r) => {
                let s = &r.summary;
                Json::obj(vec![
                    ("optimizer", Json::str(s.optimizer.clone())),
                    ("optimizer_scalars", Json::num(s.optimizer_scalars as f64)),
                    ("model_params", Json::num(s.model_params as f64)),
                    ("steps", Json::num(s.steps as f64)),
                    ("final_train_loss", Json::num(s.final_train_loss)),
                    ("final_eval_ppl", Json::num(s.final_eval_ppl)),
                    ("tokens_per_sec", Json::num(s.tokens_per_sec)),
                ])
            }
            JobOutcome::Convex(c) => Json::obj(vec![
                ("optimizer", Json::str(c.optimizer.clone())),
                ("state_scalars", Json::num(c.state_scalars as f64)),
                ("state_bytes", Json::num(c.state_bytes as f64)),
                ("final_loss", Json::num(c.final_loss)),
                ("accuracy", Json::num(c.accuracy)),
            ]),
            JobOutcome::ShardBench(s) => {
                let mut fields = vec![
                    ("optimizer", Json::str(s.optimizer.clone())),
                    ("shards", Json::num(s.shards as f64)),
                    ("steps_per_sec", Json::num(s.steps_per_sec)),
                    ("total_params", Json::num(s.total_params as f64)),
                    (
                        "peak_state_bytes_per_shard",
                        Json::num(s.peak_state_bytes_per_shard as f64),
                    ),
                    ("total_state_scalars", Json::num(s.total_state_scalars as f64)),
                    ("work_imbalance", Json::num(s.work_imbalance)),
                    ("recoveries", Json::num(s.recoveries as f64)),
                ];
                if let Some(kind) = &s.error_kind {
                    fields.push(("error_kind", Json::str(kind.clone())));
                }
                if let Some(timing) = &s.timing {
                    fields.push((
                        "coverage_pct",
                        timing.get("coverage_pct").cloned().unwrap_or(Json::Null),
                    ));
                }
                Json::obj(fields)
            }
            JobOutcome::Vision(v) => Json::obj(vec![
                ("optimizer", Json::str(v.optimizer.clone())),
                ("optimizer_scalars", Json::num(v.optimizer_scalars as f64)),
                ("model_params", Json::num(v.model_params as f64)),
                ("steps", Json::num(v.steps as f64)),
                ("final_test_error", Json::num(v.final_test_error)),
                ("best_test_error", Json::num(v.best_test_error)),
                ("final_train_loss", Json::num(v.final_train_loss)),
            ]),
        }
    }
}

/// Result of a convex-workload job.
#[derive(Clone, Debug)]
pub struct ConvexOutcome {
    /// Display name of the optimizer that ran.
    pub optimizer: String,
    pub state_scalars: usize,
    pub state_bytes: usize,
    pub final_loss: f64,
    pub accuracy: f64,
    /// Sampled `(iter, pre-update loss)` curve (empty unless requested).
    pub curve: Vec<(usize, f64)>,
    /// Final weights — the job's "checkpoint", compared bitwise by the
    /// scheduler determinism tests.
    pub w: Vec<f32>,
}

/// Result of a shard-bench job.
#[derive(Clone, Debug)]
pub struct ShardBenchOutcome {
    pub optimizer: String,
    pub shards: usize,
    pub steps_per_sec: f64,
    pub total_params: usize,
    pub peak_state_bytes_per_shard: usize,
    pub total_state_scalars: usize,
    pub work_imbalance: f64,
    /// Incidents healed by the supervisor (0 for unsupervised runs).
    pub recoveries: u32,
    /// [`crate::transport::TransportError::kind_label`] of the last
    /// incident the supervisor saw, if any.
    pub error_kind: Option<String>,
    /// `trace_timing/v1` span-histogram summary of the timed loop
    /// (`None` unless tracing was enabled during the run). Folded into
    /// the job's registry record; see [`crate::trace`].
    pub timing: Option<Json>,
}

/// Execute one job against the session, emitting progress and cache events
/// through `sink`. This is the single entry point the scheduler workers
/// call; it is also usable directly (with [`EventSink::discard`]) to run a
/// spec without a scheduler.
pub fn run_job(spec: &JobSpec, session: &Session, sink: &EventSink) -> Result<JobOutcome> {
    spec.validate()?;
    match &spec.workload {
        Workload::Lm(cfg) => {
            let mut t =
                crate::train::Trainer::with_session((**cfg).clone(), session, Some(sink.clone()))?;
            Ok(JobOutcome::Lm(Box::new(t.run()?)))
        }
        Workload::Convex(c) => Ok(JobOutcome::Convex(Box::new(run_convex(c, session, sink)?))),
        Workload::ShardBench(s) => Ok(JobOutcome::ShardBench(run_shard_bench(s, sink)?)),
        Workload::Vision(v) => {
            let mut t = crate::train::vision::VisionTrainer::with_session(
                session,
                &v.artifact_dir,
                &v.optimizer,
                &v.data,
                Some(sink.clone()),
            )?;
            Ok(JobOutcome::Vision(Box::new(t.run(v.steps, v.lr, v.eval_every, v.seed)?)))
        }
    }
}

/// The optimizer driver a convex job steps: either a suite [`Optimizer`]
/// or the raw slice-accumulator (ablation) path.
enum ConvexDriver {
    Opt(Box<dyn Optimizer>),
    /// Accumulators plus their state-scalar count.
    Acc(SliceAccumulators, usize),
}

fn run_convex(spec: &ConvexSpec, session: &Session, sink: &EventSink) -> Result<ConvexOutcome> {
    let (ds, hit) = session.convex_dataset(&spec.data);
    sink.corpus_cache(&Session::convex_key(&spec.data), hit);
    let obj = SoftmaxRegression::new(&ds);
    let idx: Vec<usize> = (0..ds.n).collect();
    let groups = vec![GroupSpec::new("w", &[spec.data.k, spec.data.d])];
    let hyper = Hyper { backend: spec.backend, ..Hyper::default() };

    let mut driver = match &spec.opt {
        ConvexOpt::Kind(kind) => ConvexDriver::Opt(optim::build(*kind, &groups, &hyper)),
        ConvexOpt::Planned { budget } => {
            let plan = crate::budget::plan(
                &groups,
                *budget,
                &crate::budget::PlannerOptions::default(),
            )?;
            ConvexDriver::Opt(Box::new(crate::budget::build_planned(&groups, &plan, &hyper)?))
        }
        ConvexOpt::CustomEt { dims } => ConvexDriver::Opt(Box::new(optim::extreme::custom_et(
            &groups,
            vec![dims.clone()],
            hyper.eps,
            None,
        )?)),
        ConvexOpt::Ablate { dims, eps, beta2, per_factor_eps } => {
            let mode =
                if *per_factor_eps { EpsMode::PerFactor } else { EpsMode::InsideProduct };
            ConvexDriver::Acc(
                SliceAccumulators::new(TensorIndex::new(dims)?, *eps, *beta2, mode),
                dims.iter().sum(),
            )
        }
    };

    let mut w = vec![0.0f32; obj.dim()];
    let mut grad = vec![0.0f32; obj.dim()];
    let mut curve = Vec::new();
    let mut last_inloop = f64::NAN;
    let progress_every = (spec.iters / 10).max(1);
    for t in 0..spec.iters {
        let loss = obj.loss_grad(&w, &idx, &mut grad);
        last_inloop = loss;
        if spec.curve_every > 0 && t % spec.curve_every == 0 {
            curve.push((t, loss));
        }
        if t % progress_every == 0 {
            sink.progress(t as u64, spec.iters as u64, loss);
        }
        match &mut driver {
            ConvexDriver::Opt(o) => {
                o.next_step();
                o.step(0, &mut w, &grad, spec.lr)?;
            }
            ConvexDriver::Acc(acc, _) => {
                acc.accumulate(&grad)?;
                acc.apply_update_bias_corrected(&mut w, &grad, spec.lr);
            }
        }
    }
    let final_loss = if spec.measure_after { obj.loss(&w, &idx) } else { last_inloop };
    let accuracy = obj.accuracy(&w, &idx);
    let (optimizer, state_scalars, state_bytes) = match &driver {
        ConvexDriver::Opt(o) => (o.name(), o.state_scalars(), o.state_bytes()),
        ConvexDriver::Acc(_, s) => ("ET-ablate".to_string(), *s, 4 * *s),
    };
    Ok(ConvexOutcome { optimizer, state_scalars, state_bytes, final_loss, accuracy, curve, w })
}

/// A [`crate::transport::SocketTransport`] rooted in a per-process temp
/// directory. The worker binary is `ETTRAIN_WORKER_BIN` when set (CI and
/// integration tests point it at the freshly built `ettrain`), else the
/// running executable itself.
fn socket_transport_for(tag: &str) -> Result<crate::transport::SocketTransport> {
    let bin = match std::env::var_os("ETTRAIN_WORKER_BIN") {
        Some(p) if !p.is_empty() => std::path::PathBuf::from(p),
        _ => std::env::current_exe().context("socket transport: resolve worker binary")?,
    };
    let dir = std::env::temp_dir().join(format!("ettrain-sock-{}-{tag}", std::process::id()));
    Ok(crate::transport::SocketTransport::new(dir, bin))
}

/// A [`crate::transport::TcpTransport`] bound at `addr`, resolving the
/// worker binary the same way as [`socket_transport_for`].
fn tcp_transport_for(addr: &str) -> Result<crate::transport::TcpTransport> {
    let bin = match std::env::var_os("ETTRAIN_WORKER_BIN") {
        Some(p) if !p.is_empty() => std::path::PathBuf::from(p),
        _ => std::env::current_exe().context("tcp transport: resolve worker binary")?,
    };
    Ok(crate::transport::TcpTransport::new(addr, bin))
}

/// SIGKILL a spawned worker by pid. Handed to the fault layer for real
/// (out-of-process) transports so `kill` faults exercise genuine worker
/// death rather than just severing the proxy.
fn kill_worker(pid: Option<u32>) {
    if let Some(pid) = pid {
        let _ = std::process::Command::new("kill").args(["-9", &pid.to_string()]).status();
    }
}

fn run_shard_bench(spec: &ShardBenchSpec, sink: &EventSink) -> Result<ShardBenchOutcome> {
    let groups =
        crate::testing::transformer_groups(spec.layers, spec.vocab, spec.d_model, spec.d_ff);
    let total: usize = groups.iter().map(|g| g.numel()).sum();
    let mut rng = Pcg64::seeded(spec.seed);
    let grads: Vec<Vec<f32>> = groups
        .iter()
        .map(|g| {
            let mut v = vec![0.0f32; g.numel()];
            rng.fill_normal(&mut v, 1.0);
            v
        })
        .collect();
    let mut params: Vec<Vec<f32>> = groups.iter().map(|g| vec![0.1f32; g.numel()]).collect();
    let hyper = Hyper::default();

    // Base transport, plus (for out-of-process kinds) a SIGKILL closure the
    // fault layer uses so `kill` faults hit the real worker process.
    use crate::transport::{FaultTransport, ShardTransport, TransportKind};
    type Killer = Box<dyn Fn(usize) + Send + Sync>;
    let tag = format!("bench-{}-{}", spec.kind.name(), spec.shards);
    let (base, killer): (Arc<dyn ShardTransport>, Option<Killer>) = match &spec.transport {
        TransportKind::InProcess => (Arc::new(crate::transport::InProcess), None),
        TransportKind::Socket => {
            let t = Arc::new(socket_transport_for(&tag)?.with_tuning(spec.tuning));
            let handle = Arc::clone(&t);
            (t, Some(Box::new(move |shard| kill_worker(handle.pid_of(shard)))))
        }
        TransportKind::Tcp(addr) => {
            let t = Arc::new(tcp_transport_for(addr)?.with_tuning(spec.tuning));
            let handle = Arc::clone(&t);
            (t, Some(Box::new(move |shard| kill_worker(handle.pid_of(shard)))))
        }
    };
    let transport: Arc<dyn ShardTransport> = match &spec.fault {
        Some(plan) => {
            let ft = FaultTransport::new(base, plan.clone());
            Arc::new(match killer {
                Some(kill) => ft.with_killer(move |shard| kill(shard)),
                None => ft,
            })
        }
        None => base,
    };
    let mut opt = ShardedOptimizer::with_transport(
        spec.kind,
        &groups,
        &hyper,
        spec.shards,
        None,
        crate::shard::DEFAULT_MIN_BUCKET_NUMEL,
        transport,
    )?;

    let (secs, recoveries, error_kind, timing, opt) = match &spec.recovery {
        Some(policy) => {
            // Supervised run: the engine heals itself per the policy, and
            // every supervision decision lands in the job's event stream.
            let events = sink.clone();
            let mut sup = crate::shard::SupervisedOptimizer::new(opt, *policy)?.with_events(
                move |e| match e {
                    crate::shard::RecoveryEvent::Snapshot { step } => {
                        events.recovery("snapshot", *step, "", "replay window reset");
                    }
                    crate::shard::RecoveryEvent::Incident { step, kind, transient, detail } => {
                        let transient = if *transient { " (transient)" } else { "" };
                        events.recovery("incident", *step, kind, &format!("{detail}{transient}"));
                    }
                    crate::shard::RecoveryEvent::Recovered { step, from_step, shards, replayed } => {
                        events.recovery(
                            "recovered",
                            *step,
                            "",
                            &format!(
                                "rewound to step {from_step}, replayed {replayed} step(s) on \
                                 {shards} shard(s)"
                            ),
                        );
                    }
                    crate::shard::RecoveryEvent::GaveUp { step, recoveries, kind, detail } => {
                        events.recovery(
                            "gave-up",
                            *step,
                            kind,
                            &format!("after {recoveries} recoveries: {detail}"),
                        );
                    }
                },
            );
            for _ in 0..2 {
                sup.run_step(&mut params, &grads, 1e-3)?;
            }
            // Histogram delta over exactly the timed loop, so warm-up
            // spans never skew the recorded timing profile.
            let hist0 = crate::trace::is_enabled().then(crate::trace::snapshot);
            let timer = Timer::start();
            for t in 0..spec.iters {
                sup.run_step(&mut params, &grads, 1e-3)?;
                sink.progress(t as u64 + 1, spec.iters as u64, f64::NAN);
            }
            let secs = timer.elapsed_secs();
            let timing = hist0
                .map(|h0| crate::trace::snapshot().delta(&h0).timing_json((secs * 1e9) as u64));
            let recoveries = sup.recoveries();
            let error_kind = sup.last_error_kind().map(str::to_string);
            (secs, recoveries, error_kind, timing, sup.into_engine())
        }
        None => {
            for _ in 0..2 {
                opt.next_step();
                opt.step_all(&mut params, &grads, 1e-3)?;
            }
            let hist0 = crate::trace::is_enabled().then(crate::trace::snapshot);
            let timer = Timer::start();
            for t in 0..spec.iters {
                opt.next_step();
                opt.step_all(&mut params, &grads, 1e-3)?;
                sink.progress(t as u64 + 1, spec.iters as u64, f64::NAN);
            }
            let secs = timer.elapsed_secs();
            let timing = hist0
                .map(|h0| crate::trace::snapshot().delta(&h0).timing_json((secs * 1e9) as u64));
            (secs, 0u32, None, timing, opt)
        }
    };
    // Real per-shard bytes, not scalars*4 — ET∞'s wide accumulator is an
    // f64, so the two differ (see tensoring::memory).
    let peak = opt.plan().peak_state_bytes(&groups, StateBackend::DenseF32);
    Ok(ShardBenchOutcome {
        optimizer: spec.kind.name(),
        shards: spec.shards,
        steps_per_sec: spec.iters as f64 / secs.max(1e-12),
        total_params: total,
        peak_state_bytes_per_shard: peak,
        total_state_scalars: opt.state_scalars(),
        work_imbalance: opt.plan().work_imbalance(),
        recoveries,
        error_kind,
        timing,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_convex() -> ConvexConfig {
        ConvexConfig { n: 200, d: 32, k: 4, cond: 100.0, householder: 2, seed: 9 }
    }

    #[test]
    fn dataset_caches_hit_on_same_params() {
        let s = Session::new();
        let (a, hit_a) = s.convex_dataset(&tiny_convex());
        let (b, hit_b) = s.convex_dataset(&tiny_convex());
        assert!(!hit_a && hit_b);
        assert!(Arc::ptr_eq(&a, &b));
        // a different seed is a different dataset
        let (_, hit_c) = s.convex_dataset(&ConvexConfig { seed: 10, ..tiny_convex() });
        assert!(!hit_c);
        assert_eq!(
            s.stats(),
            SessionStats { corpus_hits: 1, corpus_misses: 2, ..SessionStats::default() }
        );
    }

    #[test]
    fn lm_data_caches_by_synthesis_params() {
        let s = Session::new();
        let cfg = SyntheticConfig { vocab: 50, sentences: 100, seed: 3, ..Default::default() };
        let (a, hit_a) = s.lm_data(&cfg);
        let (b, hit_b) = s.lm_data(&cfg);
        assert!(!hit_a && hit_b);
        assert!(Arc::ptr_eq(&a, &b));
        assert!(a.tokenizer.vocab_size() > 0);
    }

    /// Running the same convex spec twice produces bitwise-identical
    /// weights (the executor has no hidden state).
    #[test]
    fn convex_job_is_deterministic() {
        let spec = ConvexSpec {
            data: tiny_convex(),
            iters: 30,
            lr: 0.05,
            opt: ConvexOpt::Kind(crate::tensoring::OptimizerKind::Et(2)),
            ..ConvexSpec::default()
        };
        let session = Session::new();
        let sink = EventSink::discard("t");
        let a = run_convex(&spec, &session, &sink).unwrap();
        let b = run_convex(&spec, &session, &sink).unwrap();
        assert_eq!(a.w, b.w);
        assert_eq!(a.final_loss.to_bits(), b.final_loss.to_bits());
        assert!(a.final_loss.is_finite());
    }

    /// The ablation driver agrees with the suite ET optimizer when both
    /// use the same dims and the inside-product eps (they share the
    /// accumulator kernels).
    #[test]
    fn ablate_matches_custom_et_at_default_eps() {
        let data = tiny_convex();
        let dims = vec![4usize, 4, 8];
        let session = Session::new();
        let sink = EventSink::discard("t");
        let a = run_convex(
            &ConvexSpec {
                data: data.clone(),
                iters: 25,
                lr: 0.05,
                opt: ConvexOpt::CustomEt { dims: dims.clone() },
                measure_after: false,
                ..ConvexSpec::default()
            },
            &session,
            &sink,
        )
        .unwrap();
        let b = run_convex(
            &ConvexSpec {
                data,
                iters: 25,
                lr: 0.05,
                opt: ConvexOpt::Ablate {
                    dims,
                    eps: crate::optim::Hyper::EPS,
                    beta2: None,
                    per_factor_eps: false,
                },
                measure_after: false,
                ..ConvexSpec::default()
            },
            &session,
            &sink,
        )
        .unwrap();
        assert_eq!(a.w, b.w, "custom_et and the ablation driver diverged");
    }

    #[test]
    fn shard_bench_runs_and_reports() {
        let spec = ShardBenchSpec {
            kind: crate::tensoring::OptimizerKind::Et(1),
            shards: 2,
            iters: 2,
            layers: 1,
            vocab: 64,
            d_model: 16,
            d_ff: 32,
            seed: 5,
            ..Default::default()
        };
        let out = run_shard_bench(&spec, &EventSink::discard("sb")).unwrap();
        assert_eq!(out.shards, 2);
        assert!(out.steps_per_sec > 0.0);
        assert!(out.total_state_scalars > 0);
        assert_eq!(out.recoveries, 0);
        assert_eq!(out.error_kind, None);
    }

    /// A supervised bench with an injected kill heals, finishes, and
    /// reports the incident in both the outcome and the event stream.
    #[test]
    fn shard_bench_supervised_fault_run_heals_and_reports() {
        let spec = ShardBenchSpec {
            kind: crate::tensoring::OptimizerKind::Et(1),
            shards: 2,
            iters: 6,
            layers: 1,
            vocab: 64,
            d_model: 16,
            d_ff: 32,
            seed: 5,
            recovery: Some(crate::shard::RecoveryPolicy {
                snapshot_every: 2,
                max_recoveries: 3,
                backoff_ms: 0,
            }),
            fault: Some(crate::transport::FaultPlan::parse("kill@1:4").unwrap()),
            ..Default::default()
        };
        let (sink, events) = EventSink::collect("sbf");
        let out = run_shard_bench(&spec, &sink).unwrap();
        assert!(out.recoveries >= 1, "fault plan should force at least one recovery");
        assert_eq!(out.error_kind.as_deref(), Some("disconnected"));
        let phases: Vec<String> = events
            .drain()
            .into_iter()
            .filter_map(|e| match e.event {
                JobEvent::Recovery { phase, .. } => Some(phase),
                _ => None,
            })
            .collect();
        assert!(phases.iter().any(|p| p == "snapshot"), "phases: {phases:?}");
        assert!(phases.iter().any(|p| p == "incident"), "phases: {phases:?}");
        assert!(phases.iter().any(|p| p == "recovered"), "phases: {phases:?}");
    }
}

//! The concurrent multi-run scheduler: executes a batch of [`JobSpec`]s on
//! a worker pool under memory-budget admission control.
//!
//! Each job is costed in resident host bytes by
//! [`JobSpec::cost_bytes`] (optimizer-state footprint per backend from
//! `tensoring::memory`, plus parameters/gradients/dataset buffers); a job
//! is admitted only while the sum of running jobs' costs stays within
//! `--mem-budget`. Admission is strictly FIFO: a job that does not fit
//! *right now* stays queued (a [`JobEvent::Deferred`] is emitted), keeps
//! its place at the head of the queue, and has first claim — at its full
//! requested bytes — whenever a running job releases its reservation, so a
//! stream of small jobs can never starve a large deferred one. A job that
//! could never fit the total budget fails at submission with a clear error
//! instead of deadlocking the pool.
//!
//! Determinism contract: per-run numerical results are independent of the
//! worker count. Jobs share no mutable state (per-job seeds, per-run output
//! directories, read-only `Arc` datasets from the session caches), so the
//! only things concurrency changes are wall-clock figures and event
//! interleaving — enforced in `rust/tests/scheduler.rs` by running the same
//! batch at 1 and 4 workers and comparing outcomes bitwise.

use super::events::{EventSink, JobEvent, StampedEvent};
use super::spec::JobSpec;
use super::{run_job, JobOutcome, Session};
use crate::util::json::Json;
use crate::util::logging::JsonlWriter;
use crate::util::timer::Timer;
use anyhow::{bail, Result};
use std::collections::HashSet;
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};

/// How a batch is executed.
#[derive(Clone, Debug)]
pub struct SchedulerOptions {
    /// Concurrent worker threads (`--jobs`). Each runs one job at a time.
    pub workers: usize,
    /// Total admission budget in bytes (`--mem-budget`); `None` = no limit.
    pub mem_budget: Option<u64>,
    /// Append the stamped event stream to this JSONL file.
    pub log_path: Option<PathBuf>,
    /// Append one `registry/v1` record per executed job to the registry
    /// under this directory (see [`crate::registry`]). `None` = no
    /// registry write.
    pub registry_dir: Option<PathBuf>,
}

impl Default for SchedulerOptions {
    fn default() -> Self {
        SchedulerOptions { workers: 1, mem_budget: None, log_path: None, registry_dir: None }
    }
}

/// Budget bookkeeping, separated from the thread machinery so the
/// admission policy is unit-testable.
#[derive(Clone, Debug)]
pub struct Admission {
    budget: Option<u64>,
    in_use: u64,
}

impl Admission {
    pub fn new(budget: Option<u64>) -> Admission {
        Admission { budget, in_use: 0 }
    }

    /// Would a job of `cost` bytes fit right now?
    pub fn fits(&self, cost: u64) -> bool {
        match self.budget {
            None => true,
            Some(b) => self.in_use.saturating_add(cost) <= b,
        }
    }

    /// Reserve `cost` bytes (caller must have checked [`Admission::fits`]).
    pub fn acquire(&mut self, cost: u64) {
        self.in_use = self.in_use.saturating_add(cost);
    }

    /// Release a reservation.
    pub fn release(&mut self, cost: u64) {
        self.in_use = self.in_use.saturating_sub(cost);
    }

    pub fn in_use(&self) -> u64 {
        self.in_use
    }

    /// Bytes still available (`u64::MAX` when unbudgeted).
    pub fn available(&self) -> u64 {
        match self.budget {
            None => u64::MAX,
            Some(b) => b.saturating_sub(self.in_use),
        }
    }
}

/// One job's terminal state.
pub struct JobResult {
    pub name: String,
    /// The outcome, or the rendered error chain for failed jobs.
    pub outcome: std::result::Result<JobOutcome, String>,
    /// Execution wall time (0 for jobs that failed before admission).
    pub wall_seconds: f64,
    /// Time spent queued before admission — ≈0 for immediately admitted
    /// jobs, the full defer→admit wait for budget-deferred ones (0 for
    /// jobs that failed before admission).
    pub queue_seconds: f64,
}

/// Everything a finished batch produced: per-job results in submission
/// order plus the full stamped event stream.
pub struct BatchReport {
    pub results: Vec<JobResult>,
    pub events: Vec<StampedEvent>,
    pub wall_seconds: f64,
}

impl BatchReport {
    /// Cache-lookup totals over the whole batch.
    pub fn cache_counts(&self) -> super::events::CacheCounts {
        super::events::CacheCounts::from_events(&self.events)
    }

    /// The named job's outcome, as a hard error if it failed.
    pub fn outcome(&self, name: &str) -> Result<&JobOutcome> {
        let r = self
            .results
            .iter()
            .find(|r| r.name == name)
            .ok_or_else(|| anyhow::anyhow!("no job '{name}' in batch"))?;
        match &r.outcome {
            Ok(o) => Ok(o),
            Err(e) => bail!("job '{name}' failed: {e}"),
        }
    }

    /// Results of jobs that failed.
    pub fn failed(&self) -> Vec<&JobResult> {
        self.results.iter().filter(|r| r.outcome.is_err()).collect()
    }

    /// All outcomes in submission order; errors if any job failed.
    pub fn into_outcomes(self) -> Result<Vec<JobOutcome>> {
        self.results
            .into_iter()
            .map(|r| match r.outcome {
                Ok(o) => Ok(o),
                Err(e) => bail!("job '{}' failed: {e}", r.name),
            })
            .collect()
    }
}

struct QueueState {
    /// Indices (into the spec list) still waiting to start, FIFO.
    pending: Vec<usize>,
    admission: Admission,
    results: Vec<Option<JobResult>>,
    deferred_emitted: Vec<bool>,
    /// Batch-clock instant each job entered the queue, for
    /// [`JobResult::queue_seconds`].
    queued_t: Vec<f64>,
}

/// Execute `specs` to completion and return the batch report. Failed jobs
/// do not abort the batch; their errors are carried in the results (and
/// [`BatchReport::into_outcomes`] turns any of them into a hard error).
pub fn run_batch(
    session: &Session,
    specs: &[JobSpec],
    opts: &SchedulerOptions,
) -> Result<BatchReport> {
    let n = specs.len();
    let mut seen = HashSet::new();
    for s in specs {
        s.validate()?;
        if !seen.insert(s.name.as_str()) {
            bail!("duplicate job name '{}' in batch", s.name);
        }
    }

    let clock = Arc::new(Timer::start());
    let (tx, rx) = channel::<StampedEvent>();

    // Cost every job up front. A job whose cost cannot be computed (e.g.
    // missing artifacts) or that exceeds the *total* budget fails here —
    // the latter would otherwise wait forever.
    let mut costs = vec![0u64; n];
    let mut prefailed: Vec<Option<String>> = vec![None; n];
    for (i, s) in specs.iter().enumerate() {
        match s.cost_bytes() {
            Ok(c) => match opts.mem_budget {
                Some(b) if c > b => {
                    prefailed[i] =
                        Some(format!("needs {c} bytes, exceeding the total --mem-budget {b}"));
                }
                _ => costs[i] = c,
            },
            Err(e) => prefailed[i] = Some(format!("{e:#}")),
        }
    }

    let state = Mutex::new(QueueState {
        pending: (0..n).filter(|&i| prefailed[i].is_none()).collect(),
        admission: Admission::new(opts.mem_budget),
        results: (0..n).map(|_| None).collect(),
        deferred_emitted: vec![false; n],
        queued_t: vec![0.0; n],
    });
    let cvar = Condvar::new();

    let workers = opts.workers.max(1).min(n.max(1));
    let log_path = opts.log_path.clone();

    let events = std::thread::scope(|scope| {
        let collector = scope.spawn(move || collect_events(rx, log_path));

        // Announce the queue (and the pre-failures) before work starts.
        {
            // Poisoned queue state is still structurally valid (a panicked
            // worker can't half-apply these field writes), so recover the
            // data instead of propagating the panic.
            let mut q = state.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            for (i, s) in specs.iter().enumerate() {
                let ev = match &prefailed[i] {
                    None => {
                        q.queued_t[i] = clock.elapsed_secs();
                        JobEvent::Queued { job: s.name.clone(), cost_bytes: costs[i] }
                    }
                    Some(e) => {
                        q.results[i] = Some(JobResult {
                            name: s.name.clone(),
                            outcome: Err(e.clone()),
                            wall_seconds: 0.0,
                            queue_seconds: 0.0,
                        });
                        JobEvent::Failed { job: s.name.clone(), error: e.clone() }
                    }
                };
                let _ = tx.send(StampedEvent { t: clock.elapsed_secs(), event: ev });
            }
        }

        let state_ref = &state;
        let cvar_ref = &cvar;
        let costs_ref: &[u64] = &costs;
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let tx = tx.clone();
            let clock = clock.clone();
            handles.push(scope.spawn(move || {
                worker_loop(specs, costs_ref, state_ref, cvar_ref, session, &tx, &clock)
            }));
        }
        for h in handles {
            let _ = h.join();
        }
        drop(tx);
        // A panicked collector loses the in-memory event copy but must not
        // take down the batch: the per-job results below are authoritative.
        collector.join().unwrap_or_default()
    });

    let qs = state.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner);
    let results: Vec<JobResult> = qs
        .results
        .into_iter()
        .enumerate()
        .map(|(i, r)| {
            r.unwrap_or_else(|| JobResult {
                name: specs[i].name.clone(),
                outcome: Err("job was never executed (worker pool exited early)".into()),
                wall_seconds: 0.0,
                queue_seconds: 0.0,
            })
        })
        .collect();
    let report = BatchReport { results, events, wall_seconds: clock.elapsed_secs() };
    // Registry writes are observability, never a batch failure.
    if let Some(dir) = &opts.registry_dir {
        if let Err(e) =
            crate::registry::record_batch(dir, specs, &report, opts.log_path.as_deref())
        {
            crate::warnln!("registry write to {dir:?} failed: {e:#}");
        }
    }
    Ok(report)
}

fn worker_loop(
    specs: &[JobSpec],
    costs: &[u64],
    state: &Mutex<QueueState>,
    cvar: &Condvar,
    session: &Session,
    tx: &Sender<StampedEvent>,
    clock: &Arc<Timer>,
) {
    loop {
        // Claim the job at the head of the queue when it fits the budget,
        // or wait for a release. Admission is strictly FIFO: a job that
        // does not fit blocks everything behind it (announced as Deferred
        // once) and keeps first claim on released bytes, so a stream of
        // small jobs can never starve a large deferred one. Jobs that can
        // never fit the total budget were already failed at submission, so
        // head-of-line blocking cannot deadlock. Exits when the queue is
        // drained.
        let mut claim_span = crate::trace::span(
            crate::trace::SpanKind::Claim,
            crate::trace::NO_SHARD,
            crate::trace::NO_JOB,
        );
        let claimed = {
            // See run_batch: QueueState stays structurally valid across a
            // worker panic, so poison recovery is safe here and below.
            let mut q = state.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            loop {
                let Some(&front) = q.pending.first() else {
                    break None;
                };
                if q.admission.fits(costs[front]) {
                    let _admit_span = crate::trace::span(
                        crate::trace::SpanKind::Admit,
                        crate::trace::NO_SHARD,
                        front as u32,
                    );
                    q.pending.remove(0);
                    q.admission.acquire(costs[front]);
                    let waited = (clock.elapsed_secs() - q.queued_t[front]).max(0.0);
                    break Some((front, q.admission.in_use(), waited));
                }
                if !q.deferred_emitted[front] {
                    q.deferred_emitted[front] = true;
                    let _ = tx.send(StampedEvent {
                        t: clock.elapsed_secs(),
                        event: JobEvent::Deferred {
                            job: specs[front].name.clone(),
                            cost_bytes: costs[front],
                            available_bytes: q.admission.available(),
                        },
                    });
                }
                q = cvar.wait(q).unwrap_or_else(std::sync::PoisonError::into_inner);
            }
        };
        let Some((i, in_use, queue_seconds)) = claimed else { return };
        claim_span.set_job(i as u32);
        drop(claim_span);

        let sink = EventSink::new(specs[i].name.clone(), tx.clone(), clock.clone());
        sink.emit(JobEvent::Admitted {
            job: specs[i].name.clone(),
            cost_bytes: costs[i],
            in_use_bytes: in_use,
        });
        let t0 = Timer::start();
        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_job(&specs[i], session, &sink)
        }));
        let wall = t0.elapsed_secs();
        let outcome = match run {
            Ok(Ok(out)) => {
                sink.emit(JobEvent::Finished { job: specs[i].name.clone(), wall_seconds: wall });
                Ok(out)
            }
            Ok(Err(e)) => {
                let msg = format!("{e:#}");
                sink.emit(JobEvent::Failed { job: specs[i].name.clone(), error: msg.clone() });
                Err(msg)
            }
            Err(_) => {
                let msg = "job panicked".to_string();
                sink.emit(JobEvent::Failed { job: specs[i].name.clone(), error: msg.clone() });
                Err(msg)
            }
        };

        let release_span = crate::trace::span(
            crate::trace::SpanKind::Release,
            crate::trace::NO_SHARD,
            i as u32,
        );
        let mut q = state.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        q.admission.release(costs[i]);
        // Post-release occupancy, so the log alone reconstructs budget
        // residency between Admitted/Released pairs.
        let _ = tx.send(StampedEvent {
            t: clock.elapsed_secs(),
            event: JobEvent::Released {
                job: specs[i].name.clone(),
                in_use_bytes: q.admission.in_use(),
            },
        });
        drop(release_span);
        q.results[i] = Some(JobResult {
            name: specs[i].name.clone(),
            outcome,
            wall_seconds: wall,
            queue_seconds,
        });
        cvar.notify_all();
    }
}

fn collect_events(rx: Receiver<StampedEvent>, log_path: Option<PathBuf>) -> Vec<StampedEvent> {
    let mut log = match &log_path {
        Some(p) => match JsonlWriter::create(p) {
            Ok(mut w) => {
                // Header record first: `StampedEvent.t` is batch-relative,
                // so the absolute start (+ commit/host) lives here. Event
                // records after it are byte-identical to the pre-header
                // format.
                let _ = w.write(&Json::obj(vec![
                    ("schema", Json::str("job_events/v1")),
                    ("commit", Json::str(crate::registry::commit_string())),
                    ("started_unix", Json::num(crate::registry::unix_now() as f64)),
                    ("host", Json::str(crate::registry::host())),
                ]));
                Some(w)
            }
            Err(e) => {
                crate::warnln!("cannot open schedule log {p:?}: {e:#}");
                None
            }
        },
        None => None,
    };
    let mut events = Vec::new();
    for ev in rx {
        narrate(&ev);
        if let Some(w) = &mut log {
            let _ = w.write(&ev.to_json());
        }
        events.push(ev);
    }
    if let Some(w) = &mut log {
        let _ = w.flush();
    }
    events
}

fn narrate(ev: &StampedEvent) {
    let t = ev.t;
    match &ev.event {
        JobEvent::Admitted { job, cost_bytes, in_use_bytes } => {
            crate::info!(
                "[sched +{t:.1}s] run '{job}' ({cost_bytes} bytes; {in_use_bytes} in use)"
            );
        }
        JobEvent::Deferred { job, cost_bytes, available_bytes } => {
            crate::info!(
                "[sched +{t:.1}s] defer '{job}' ({cost_bytes} bytes > {available_bytes} free)"
            );
        }
        JobEvent::Finished { job, wall_seconds } => {
            crate::info!("[sched +{t:.1}s] done '{job}' in {wall_seconds:.1}s");
        }
        JobEvent::Failed { job, error } => {
            crate::warnln!("[sched +{t:.1}s] FAILED '{job}': {error}");
        }
        JobEvent::Progress { job, step, of, loss } => {
            crate::debugln!("[sched +{t:.1}s] '{job}' step {step}/{of} loss {loss:.4}");
        }
        JobEvent::Released { job, in_use_bytes } => {
            crate::debugln!("[sched +{t:.1}s] release '{job}' ({in_use_bytes} bytes in use)");
        }
        JobEvent::Recovery { job, phase, step, kind, detail } => {
            if phase == "snapshot" {
                crate::debugln!("[sched +{t:.1}s] '{job}' snapshot at step {step}");
            } else {
                crate::warnln!("[sched +{t:.1}s] '{job}' {phase} at step {step} ({kind}): {detail}");
            }
        }
        JobEvent::Queued { .. }
        | JobEvent::ArtifactCache { .. }
        | JobEvent::CorpusCache { .. } => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The admission-control satellite: an over-budget job is not admitted
    /// while the budget is held, and fits again after release.
    #[test]
    fn over_budget_job_waits_for_release() {
        let mut a = Admission::new(Some(100));
        assert!(a.fits(60));
        a.acquire(60);
        assert_eq!(a.in_use(), 60);
        assert!(!a.fits(60), "second 60-byte job must not fit a 100-byte budget");
        assert!(a.fits(40), "a smaller job still fits");
        a.release(60);
        assert!(a.fits(60), "after release the job fits again");
        assert_eq!(a.available(), 100);
    }

    #[test]
    fn unbudgeted_admission_always_fits() {
        let mut a = Admission::new(None);
        a.acquire(u64::MAX / 2);
        assert!(a.fits(u64::MAX / 2));
        assert_eq!(a.available(), u64::MAX);
    }

    #[test]
    fn release_never_underflows() {
        let mut a = Admission::new(Some(10));
        a.release(5);
        assert_eq!(a.in_use(), 0);
    }
}

//! [`JobSpec`] — the one typed, validated, serializable description of a
//! unit of work the execution layer runs. It subsumes what used to be
//! spread across `Trainer::new(RunConfig)`, the `ExpOptions`-driven
//! experiment functions, and `ablation::run`: every workload the
//! coordinator knows how to execute is one of the [`Workload`] variants,
//! every table/figure sweep is a `Vec<JobSpec>` batch, and `ettrain batch
//! <jobs.toml>` runs user-authored batches through the same scheduler.
//!
//! A job is self-contained and seeded: executing it touches no mutable
//! state shared with other jobs (per-run output directories, per-job RNG
//! streams), which is what makes the scheduler's concurrency bitwise
//! invisible in per-run results.

use crate::convex::ConvexConfig;
use crate::runtime::Manifest;
use crate::shard::RecoveryPolicy;
use crate::tensoring::{model_state_bytes, OptimizerKind, StateBackend};
use crate::train::RunConfig;
use crate::transport::{FaultPlan, TransportKind, TransportTuning};
use crate::util::config::{Config, Value};
use crate::vision::VisionConfig;
use anyhow::{bail, Context, Result};
use std::path::PathBuf;

/// A named, schedulable unit of work.
#[derive(Clone, Debug)]
pub struct JobSpec {
    /// Unique (per batch) job name; doubles as the run name for LM jobs.
    pub name: String,
    pub workload: Workload,
}

/// What a job actually executes.
#[derive(Clone, Debug)]
pub enum Workload {
    /// An artifact-driven LM training run (fused train-step or the
    /// host-optimizer/sharded path — exactly what `ettrain train` runs).
    Lm(Box<RunConfig>),
    /// A pure-rust convex softmax-regression run (§5.4 substrate): the
    /// Figure 3 variants, the quantized-state sweep, and the ablations.
    Convex(ConvexSpec),
    /// A sharded-optimizer throughput measurement (one shard-count ×
    /// optimizer configuration of the scaling experiment).
    ShardBench(ShardBenchSpec),
    /// A synthetic-CIFAR convnet run (appendix A / Table 4).
    Vision(VisionSpec),
}

/// Which optimizer a convex job drives.
#[derive(Clone, Debug, PartialEq)]
pub enum ConvexOpt {
    /// A suite optimizer built by `optim::build`.
    Kind(OptimizerKind),
    /// A budget-planned optimizer: `budget::plan` picks the best
    /// (ET level, backend) for the weight group within `budget` bytes and
    /// the job executes the plan (the `ettrain experiment pareto` cell).
    Planned { budget: u64 },
    /// An ET optimizer with explicit tensor-index dims for the single
    /// `k x d` weight group (the Figure 3 depth variants).
    CustomEt { dims: Vec<usize> },
    /// The raw slice-accumulator driver with a selectable eps placement —
    /// the Algorithm-1 ablations.
    Ablate {
        dims: Vec<usize>,
        eps: f32,
        beta2: Option<f32>,
        /// `true` = per-factor eps (Lemma 4.3 form); `false` = eps inside
        /// the product (Algorithm 1 as printed).
        per_factor_eps: bool,
    },
}

/// A convex-workload job.
#[derive(Clone, Debug)]
pub struct ConvexSpec {
    pub data: ConvexConfig,
    pub iters: usize,
    pub lr: f32,
    pub backend: StateBackend,
    pub opt: ConvexOpt,
    /// `true`: report the loss at the final parameters (quantized-state
    /// convention). `false`: report the last in-loop loss, i.e. at the
    /// parameters *before* the final update (Figure 3 / ablation
    /// convention).
    pub measure_after: bool,
    /// Sample an `(iter, loss)` curve point every this many iterations
    /// (0 = no curve).
    pub curve_every: usize,
}

impl Default for ConvexSpec {
    fn default() -> Self {
        ConvexSpec {
            data: ConvexConfig::default(),
            iters: 300,
            lr: 0.05,
            backend: StateBackend::DenseF32,
            opt: ConvexOpt::Kind(OptimizerKind::AdaGrad),
            measure_after: true,
            curve_every: 0,
        }
    }
}

/// One configuration of the sharded-engine scaling benchmark:
/// transformer-shaped groups, synthetic gradients, timed `step_all`s.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardBenchSpec {
    pub kind: OptimizerKind,
    pub shards: usize,
    /// Timed steps (after a 2-step warmup).
    pub iters: usize,
    pub layers: usize,
    pub vocab: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub seed: u64,
    /// How workers are launched: in-process threads (default), `ettrain
    /// shard-worker` child processes over UNIX sockets, or the same over
    /// TCP (`tcp:<addr>`).
    pub transport: TransportKind,
    /// Transport timing knobs (`run.transport.*`): read timeout, worker
    /// connect retries and backoff.
    pub tuning: TransportTuning,
    /// `Some` runs the bench under [`crate::shard::SupervisedOptimizer`]
    /// with this policy (`run.recovery.*`): automatic snapshots, fault
    /// classification, bitwise replay recovery. `None` is the raw engine.
    pub recovery: Option<RecoveryPolicy>,
    /// Deterministic fault injection: a parsed
    /// [`crate::transport::FaultPlan`] wrapped around the transport.
    /// Requires `recovery` — injecting faults without supervision just
    /// kills the job.
    pub fault: Option<FaultPlan>,
}

impl Default for ShardBenchSpec {
    fn default() -> Self {
        ShardBenchSpec {
            kind: OptimizerKind::Et(1),
            shards: 1,
            iters: 10,
            layers: 4,
            vocab: 2000,
            d_model: 512,
            d_ff: 2048,
            seed: 42,
            transport: TransportKind::InProcess,
            tuning: TransportTuning::default(),
            recovery: None,
            fault: None,
        }
    }
}

/// A vision (synthetic-CIFAR convnet) job.
#[derive(Clone, Debug)]
pub struct VisionSpec {
    /// Optimizer spelling selecting the `cnn_<optimizer>` artifact.
    pub optimizer: String,
    pub lr: f32,
    pub steps: u64,
    pub eval_every: u64,
    pub seed: u64,
    pub artifact_dir: PathBuf,
    pub data: VisionConfig,
}

impl JobSpec {
    /// An LM training job; the job name becomes the run name (and run
    /// output directory).
    pub fn lm(name: impl Into<String>, mut cfg: RunConfig) -> JobSpec {
        let name = name.into();
        cfg.name = name.clone();
        JobSpec { name, workload: Workload::Lm(Box::new(cfg)) }
    }

    /// A convex-workload job.
    pub fn convex(name: impl Into<String>, spec: ConvexSpec) -> JobSpec {
        JobSpec { name: name.into(), workload: Workload::Convex(spec) }
    }

    /// A shard-bench job.
    pub fn shard_bench(name: impl Into<String>, spec: ShardBenchSpec) -> JobSpec {
        JobSpec { name: name.into(), workload: Workload::ShardBench(spec) }
    }

    /// A vision job.
    pub fn vision(name: impl Into<String>, spec: VisionSpec) -> JobSpec {
        JobSpec { name: name.into(), workload: Workload::Vision(spec) }
    }

    /// The workload-kind tag (also the `type` key in batch TOML).
    pub fn workload_label(&self) -> &'static str {
        match &self.workload {
            Workload::Lm(_) => "lm",
            Workload::Convex(_) => "convex",
            Workload::ShardBench(_) => "shard-bench",
            Workload::Vision(_) => "vision",
        }
    }

    /// Structural validation (cheap; no filesystem access).
    pub fn validate(&self) -> Result<()> {
        if self.name.trim().is_empty() {
            bail!("job name must be non-empty");
        }
        // Allow-list, not deny-list: the name is a `[job.<name>]` TOML
        // section header and a run-directory component, so anything beyond
        // alphanumerics, '-' and '_' would break the serialized round trip
        // or the filesystem layout.
        if !self.name.chars().all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_')) {
            bail!(
                "job name '{}' may only contain ASCII letters, digits, '-' and '_'",
                self.name
            );
        }
        match &self.workload {
            Workload::Lm(cfg) => {
                if cfg.artifact.trim().is_empty() {
                    bail!("job '{}': artifact must be non-empty", self.name);
                }
                if cfg.steps == 0 {
                    bail!("job '{}': steps must be >= 1", self.name);
                }
            }
            Workload::Convex(c) => {
                if c.iters == 0 {
                    bail!("job '{}': iters must be >= 1", self.name);
                }
                if !(c.lr > 0.0 && c.lr.is_finite()) {
                    bail!("job '{}': lr must be positive and finite", self.name);
                }
                match &c.opt {
                    ConvexOpt::CustomEt { dims } | ConvexOpt::Ablate { dims, .. } => {
                        if dims.is_empty() || dims.iter().any(|&d| d == 0) {
                            bail!("job '{}': ET dims must be non-empty and positive", self.name);
                        }
                        let numel = c.data.k * c.data.d;
                        let product: usize = dims.iter().product();
                        if product != numel {
                            bail!(
                                "job '{}': ET dims {:?} do not cover the {}x{} weight group",
                                self.name,
                                dims,
                                c.data.k,
                                c.data.d
                            );
                        }
                    }
                    ConvexOpt::Kind(_) => {}
                    ConvexOpt::Planned { budget } => {
                        if *budget == 0 {
                            bail!("job '{}': planned budget must be >= 1 byte", self.name);
                        }
                    }
                }
            }
            Workload::ShardBench(s) => {
                if s.shards == 0 || s.iters == 0 {
                    bail!("job '{}': shards and iters must be >= 1", self.name);
                }
                s.tuning.validate().with_context(|| format!("job '{}'", self.name))?;
                if let Some(policy) = &s.recovery {
                    policy.validate().with_context(|| format!("job '{}'", self.name))?;
                }
                if s.fault.is_some() && s.recovery.is_none() {
                    bail!(
                        "job '{}': fault_plan needs run.recovery.* (a fault plan without \
                         supervision just kills the job)",
                        self.name
                    );
                }
            }
            Workload::Vision(v) => {
                if v.optimizer.trim().is_empty() {
                    bail!("job '{}': optimizer must be non-empty", self.name);
                }
                if v.steps == 0 {
                    bail!("job '{}': steps must be >= 1", self.name);
                }
            }
        }
        Ok(())
    }

    /// The job's admission cost in resident host bytes: parameters (and
    /// gradients where host-resident) plus the optimizer-state footprint
    /// from [`crate::tensoring::memory`] under the job's state backend,
    /// plus the dominant dataset buffers. LM/vision costs read the
    /// artifact manifest (cheap JSON parse, no compilation) and therefore
    /// fail when artifacts are not built — the scheduler turns that into a
    /// per-job failure rather than rejecting the whole batch.
    pub fn cost_bytes(&self) -> Result<u64> {
        let cost = match &self.workload {
            Workload::Lm(cfg) => {
                let m = Manifest::load(&cfg.artifact_dir, &cfg.artifact).with_context(|| {
                    format!(
                        "job '{}': cost accounting needs artifact '{}'",
                        self.name, cfg.artifact
                    )
                })?;
                match (cfg.opt_memory_budget, cfg.host_optimizer) {
                    // Budget-planned host path: the optimizer-state charge
                    // is the solved plan's exact bytes (≤ the budget), not
                    // a uniform-backend estimate.
                    (Some(budget), _) => {
                        let groups = m.group_specs();
                        let plan = crate::budget::plan(
                            &groups,
                            budget,
                            &crate::budget::PlannerOptions::default(),
                        )
                        .with_context(|| {
                            format!("job '{}': cost accounting for the state plan", self.name)
                        })?;
                        8 * m.total_params() + plan.total_bytes()
                    }
                    // Host path: params + grads live as host vectors; the
                    // optimizer state lives shard-local under the chosen
                    // backend (sharding partitions the same total).
                    (None, Some(kind)) => {
                        let shapes: Vec<Vec<usize>> =
                            m.params.iter().map(|p| p.shape.clone()).collect();
                        8 * m.total_params()
                            + model_state_bytes(kind, &shapes, cfg.state_backend)
                    }
                    // Fused path: params + opt state as f32 literals.
                    (None, None) => 4 * (m.total_params() + m.total_opt_state()),
                }
            }
            Workload::Convex(c) => {
                let data = 4 * c.data.n * c.data.d + 4 * c.data.n;
                let wg = 8 * c.data.k * c.data.d; // weights + grad
                let state = match &c.opt {
                    ConvexOpt::Kind(kind) => model_state_bytes(
                        *kind,
                        &[vec![c.data.k, c.data.d]],
                        c.backend,
                    ),
                    ConvexOpt::Planned { budget } => {
                        let groups =
                            vec![crate::optim::GroupSpec::new("w", &[c.data.k, c.data.d])];
                        crate::budget::plan(
                            &groups,
                            *budget,
                            &crate::budget::PlannerOptions::default(),
                        )
                        .with_context(|| {
                            format!("job '{}': cost accounting for the state plan", self.name)
                        })?
                        .total_bytes()
                    }
                    ConvexOpt::CustomEt { dims } | ConvexOpt::Ablate { dims, .. } => {
                        4 * dims.iter().sum::<usize>()
                    }
                };
                data + wg + state
            }
            Workload::ShardBench(s) => {
                let groups =
                    crate::testing::transformer_groups(s.layers, s.vocab, s.d_model, s.d_ff);
                let shapes: Vec<Vec<usize>> = groups.iter().map(|g| g.shape.clone()).collect();
                let numel: usize = groups.iter().map(|g| g.numel()).sum();
                match s.transport {
                    TransportKind::InProcess => {
                        8 * numel + model_state_bytes(s.kind, &shapes, StateBackend::DenseF32)
                    }
                    // Socket/TCP workers hold the optimizer state in their
                    // own processes; this process keeps params + grads plus
                    // a bounded per-shard serialization buffer (one ETSS
                    // chunk each way).
                    TransportKind::Socket | TransportKind::Tcp(_) => {
                        8 * numel + s.shards * 8 * crate::optim::stream::STREAM_CHUNK_NUMEL
                    }
                }
            }
            Workload::Vision(v) => {
                let m = Manifest::load(&v.artifact_dir, &format!("cnn_{}", v.optimizer))
                    .with_context(|| {
                        format!(
                            "job '{}': cost accounting needs artifact 'cnn_{}'",
                            self.name, v.optimizer
                        )
                    })?;
                let pix = crate::vision::CHANNELS * crate::vision::IMG * crate::vision::IMG;
                4 * (m.total_params() + m.total_opt_state())
                    + 4 * (v.data.train + v.data.test) * pix
            }
        };
        Ok(cost as u64)
    }
}

// ---------------------------------------------------------------------------
// Batch TOML (de)serialization — `ettrain batch <jobs.toml>`
// ---------------------------------------------------------------------------

fn q(s: &str) -> String {
    format!("\"{s}\"")
}

impl JobSpec {
    /// Serialize as one `[job.<name>]` TOML section (parsable by
    /// [`batch_from_config`]).
    pub fn to_toml(&self) -> String {
        let mut out = format!("[job.{}]\n", self.name);
        let mut kv = |k: &str, v: String| out.push_str(&format!("{k} = {v}\n"));
        kv("type", q(self.workload_label()));
        match &self.workload {
            Workload::Lm(cfg) => {
                kv("artifact", q(&cfg.artifact));
                if let Some(ev) = &cfg.eval_artifact {
                    kv("eval_artifact", q(ev));
                }
                kv("artifact_dir", q(&cfg.artifact_dir.display().to_string()));
                kv("out_dir", q(&cfg.out_dir.display().to_string()));
                kv("steps", cfg.steps.to_string());
                kv("eval_every", cfg.eval_every.to_string());
                kv("eval_batches", cfg.eval_batches.to_string());
                kv("log_every", cfg.log_every.to_string());
                kv("checkpoint_every", cfg.checkpoint_every.to_string());
                kv("schedule", q(&cfg.schedule.spec()));
                kv("seed", cfg.seed.to_string());
                kv("vocab", cfg.corpus_vocab.to_string());
                kv("sentences", cfg.corpus_sentences.to_string());
                kv("max_seconds", cfg.max_seconds.to_string());
                kv("track_traces", cfg.track_traces.to_string());
                kv("trace_every", cfg.trace_every.to_string());
                kv("shards", cfg.shards.to_string());
                if let Some(k) = cfg.host_optimizer {
                    kv("host_optimizer", q(&k.name()));
                }
                kv("state_backend", q(&cfg.state_backend.name()));
                if let Some(b) = cfg.opt_memory_budget {
                    kv("opt_memory_budget", b.to_string());
                }
                kv("resume", cfg.resume.to_string());
            }
            Workload::Convex(c) => {
                match &c.opt {
                    ConvexOpt::Kind(kind) => kv("optimizer", q(&kind.name())),
                    ConvexOpt::Planned { budget } => {
                        kv("optimizer", q("planned"));
                        kv("budget", budget.to_string());
                    }
                    ConvexOpt::CustomEt { dims } => {
                        kv("optimizer", q("custom_et"));
                        kv("dims", format!("{dims:?}"));
                    }
                    ConvexOpt::Ablate { dims, eps, beta2, per_factor_eps } => {
                        kv("optimizer", q("ablate"));
                        kv("dims", format!("{dims:?}"));
                        kv("eps", eps.to_string());
                        if let Some(b2) = beta2 {
                            kv("beta2", b2.to_string());
                        }
                        kv("per_factor_eps", per_factor_eps.to_string());
                    }
                }
                kv("backend", q(&c.backend.name()));
                kv("lr", c.lr.to_string());
                kv("iters", c.iters.to_string());
                kv("n", c.data.n.to_string());
                kv("d", c.data.d.to_string());
                kv("k", c.data.k.to_string());
                kv("cond", c.data.cond.to_string());
                kv("householder", c.data.householder.to_string());
                kv("seed", c.data.seed.to_string());
                kv("measure_after", c.measure_after.to_string());
                kv("curve_every", c.curve_every.to_string());
            }
            Workload::ShardBench(s) => {
                kv("kind", q(&s.kind.name()));
                kv("shards", s.shards.to_string());
                kv("iters", s.iters.to_string());
                kv("layers", s.layers.to_string());
                kv("vocab", s.vocab.to_string());
                kv("d_model", s.d_model.to_string());
                kv("d_ff", s.d_ff.to_string());
                kv("seed", s.seed.to_string());
                kv("transport", q(&s.transport.name()));
                kv("read_timeout_ms", s.tuning.read_timeout_ms.to_string());
                kv("connect_retries", s.tuning.connect_retries.to_string());
                kv("backoff_ms", s.tuning.backoff_ms.to_string());
                if let Some(r) = &s.recovery {
                    kv("snapshot_every", r.snapshot_every.to_string());
                    kv("max_recoveries", r.max_recoveries.to_string());
                    kv("recovery_backoff_ms", r.backoff_ms.to_string());
                }
                if let Some(f) = &s.fault {
                    kv("fault_plan", q(&f.to_string()));
                }
            }
            Workload::Vision(v) => {
                kv("optimizer", q(&v.optimizer));
                kv("lr", v.lr.to_string());
                kv("steps", v.steps.to_string());
                kv("eval_every", v.eval_every.to_string());
                kv("seed", v.seed.to_string());
                kv("artifact_dir", q(&v.artifact_dir.display().to_string()));
                kv("classes", v.data.classes.to_string());
                kv("train", v.data.train.to_string());
                kv("test", v.data.test.to_string());
                kv("blobs", v.data.blobs.to_string());
                kv("noise", v.data.noise.to_string());
                kv("mix_max", v.data.mix_max.to_string());
                kv("data_seed", v.data.seed.to_string());
            }
        }
        out
    }
}

/// Serialize a batch as one TOML document.
pub fn batch_to_toml(specs: &[JobSpec]) -> String {
    specs.iter().map(|s| s.to_toml()).collect::<Vec<_>>().join("\n")
}

/// Parse every `[job.<name>]` section of a batch config into specs.
///
/// Jobs come back ordered by name (the underlying key map is sorted), so a
/// batch file defines a deterministic submission order regardless of
/// section layout. Keys outside `job.*` sections are rejected — a typoed
/// section must not be silently ignored.
pub fn batch_from_config(cfg: &Config) -> Result<Vec<JobSpec>> {
    let mut names: Vec<String> = Vec::new();
    for key in cfg.keys() {
        let Some(rest) = key.strip_prefix("job.") else {
            bail!("unexpected key '{key}' (batch files contain only [job.<name>] sections)");
        };
        let Some((name, _)) = rest.split_once('.') else {
            bail!("key '{key}' is not of the form job.<name>.<key>");
        };
        if names.last().map(|n| n.as_str()) != Some(name) {
            names.push(name.to_string());
        }
    }
    names.dedup();
    if names.is_empty() {
        bail!("batch config defines no [job.<name>] sections");
    }
    names.iter().map(|n| job_from_config(cfg, n)).collect()
}

/// Reject unknown keys inside a `[job.<name>]` section — a typoed key
/// (`step` for `steps`) must be a hard error, not a silently applied
/// default (the same policy `parse_set_overrides` enforces for `--set`).
fn check_job_keys(cfg: &Config, prefix: &str, name: &str, allowed: &[&str]) -> Result<()> {
    let pfx = format!("{prefix}.");
    for key in cfg.keys() {
        if let Some(rest) = key.strip_prefix(&pfx) {
            if !allowed.contains(&rest) {
                bail!("job '{name}': unknown key '{rest}' (allowed: {allowed:?})");
            }
        }
    }
    Ok(())
}

const LM_KEYS: &[&str] = &[
    "type", "artifact", "eval_artifact", "artifact_dir", "out_dir", "steps", "eval_every",
    "eval_batches", "log_every", "checkpoint_every", "schedule", "seed", "vocab", "sentences",
    "max_seconds", "track_traces", "trace_every", "shards", "host_optimizer", "state_backend",
    "opt_memory_budget", "resume",
];
const CONVEX_KEYS: &[&str] = &[
    "type", "optimizer", "dims", "eps", "beta2", "per_factor_eps", "backend", "budget", "lr",
    "iters", "n", "d", "k", "cond", "householder", "seed", "measure_after", "curve_every",
];
const SHARD_BENCH_KEYS: &[&str] = &[
    "type",
    "kind",
    "shards",
    "iters",
    "layers",
    "vocab",
    "d_model",
    "d_ff",
    "seed",
    "transport",
    // run.transport.* timing knobs
    "read_timeout_ms",
    "connect_retries",
    "backoff_ms",
    // run.recovery.* supervision policy (any of these => supervised run)
    "supervised",
    "snapshot_every",
    "max_recoveries",
    "recovery_backoff_ms",
    // deterministic fault injection (requires supervision)
    "fault_plan",
];
const VISION_KEYS: &[&str] = &[
    "type", "optimizer", "lr", "steps", "eval_every", "seed", "artifact_dir", "classes", "train",
    "test", "blobs", "noise", "mix_max", "data_seed",
];

fn job_from_config(cfg: &Config, name: &str) -> Result<JobSpec> {
    let p = format!("job.{name}");
    let key = |k: &str| format!("{p}.{k}");
    let ty = cfg.req_str(&key("type")).with_context(|| format!("job '{name}'"))?;
    let allowed = match ty.as_str() {
        "lm" => LM_KEYS,
        "convex" => CONVEX_KEYS,
        "shard-bench" => SHARD_BENCH_KEYS,
        "vision" => VISION_KEYS,
        other => bail!("job '{name}': unknown type '{other}' (lm|convex|shard-bench|vision)"),
    };
    check_job_keys(cfg, &p, name, allowed)?;
    let spec = match ty.as_str() {
        "lm" => {
            // Remap the flat job keys onto the RunConfig TOML schema and
            // reuse its loader (single source of truth for defaults and
            // validation).
            let mut sub = Config::default();
            for k in cfg.keys().map(String::from).collect::<Vec<_>>() {
                let Some(rest) = k.strip_prefix(&format!("{p}.")) else { continue };
                let mapped = match rest {
                    "type" => continue,
                    "vocab" => "data.vocab".to_string(),
                    "sentences" => "data.sentences".to_string(),
                    "schedule" => "optim.schedule".to_string(),
                    other => format!("run.{other}"),
                };
                sub.insert(&mapped, cfg.get(&k).expect("key exists").clone());
            }
            sub.insert("run.name", Value::Str(name.to_string()));
            let rc = RunConfig::from_config(&sub).with_context(|| format!("job '{name}'"))?;
            JobSpec::lm(name, rc)
        }
        "convex" => {
            let d = ConvexSpec::default();
            let opt_name = cfg.req_str(&key("optimizer"))?;
            let dims = cfg.get(&key("dims")).and_then(|v| v.as_usize_arr());
            let opt = match opt_name.as_str() {
                "planned" => {
                    let raw = cfg
                        .get(&key("budget"))
                        .context("planned needs a budget = <bytes> key")?;
                    let budget = match raw {
                        Value::Int(i) if *i > 0 => *i as u64,
                        // Accept the same "64m"-style spelling as
                        // run.opt_memory_budget.
                        Value::Str(s) => crate::util::cli::parse_byte_size(s)
                            .with_context(|| format!("job '{name}': bad budget '{s}'"))?,
                        other => bail!(
                            "job '{name}': budget must be positive bytes or a \
                             \"64m\"-style string, got {other:?}"
                        ),
                    };
                    ConvexOpt::Planned { budget }
                }
                "custom_et" => ConvexOpt::CustomEt {
                    dims: dims.context("custom_et needs a dims = [..] array")?,
                },
                "ablate" => ConvexOpt::Ablate {
                    dims: dims.context("ablate needs a dims = [..] array")?,
                    eps: cfg.f64(&key("eps"), 1e-8) as f32,
                    beta2: cfg.get(&key("beta2")).and_then(|v| v.as_f64()).map(|b| b as f32),
                    per_factor_eps: cfg.bool(&key("per_factor_eps"), false),
                },
                other => ConvexOpt::Kind(
                    OptimizerKind::parse(other)
                        .with_context(|| format!("job '{name}': unknown optimizer '{other}'"))?,
                ),
            };
            let backend_name = cfg.str(&key("backend"), "f32");
            let dd = ConvexConfig::default();
            JobSpec::convex(
                name,
                ConvexSpec {
                    data: ConvexConfig {
                        n: cfg.usize(&key("n"), dd.n),
                        d: cfg.usize(&key("d"), dd.d),
                        k: cfg.usize(&key("k"), dd.k),
                        cond: cfg.f64(&key("cond"), dd.cond),
                        householder: cfg.usize(&key("householder"), dd.householder),
                        seed: cfg.usize(&key("seed"), dd.seed as usize) as u64,
                    },
                    iters: cfg.usize(&key("iters"), d.iters),
                    lr: cfg.f64(&key("lr"), d.lr as f64) as f32,
                    backend: StateBackend::parse(&backend_name)
                        .with_context(|| format!("job '{name}': bad backend '{backend_name}'"))?,
                    opt,
                    measure_after: cfg.bool(&key("measure_after"), d.measure_after),
                    curve_every: cfg.usize(&key("curve_every"), d.curve_every),
                },
            )
        }
        "shard-bench" => {
            let d = ShardBenchSpec::default();
            let kind_name = cfg.req_str(&key("kind"))?;
            let dt = TransportTuning::default();
            let dr = RecoveryPolicy::default();
            // Any run.recovery.* key (or supervised = true) turns the
            // supervision layer on; absent keys fall back to policy
            // defaults.
            let supervised = cfg.bool(&key("supervised"), false)
                || cfg.get(&key("snapshot_every")).is_some()
                || cfg.get(&key("max_recoveries")).is_some()
                || cfg.get(&key("recovery_backoff_ms")).is_some();
            let recovery = supervised.then(|| RecoveryPolicy {
                snapshot_every: cfg.usize(&key("snapshot_every"), dr.snapshot_every as usize)
                    as u64,
                max_recoveries: cfg.usize(&key("max_recoveries"), dr.max_recoveries as usize)
                    as u32,
                backoff_ms: cfg.usize(&key("recovery_backoff_ms"), dr.backoff_ms as usize)
                    as u64,
            });
            let fault = match cfg.get(&key("fault_plan")) {
                Some(Value::Str(plan)) => Some(
                    FaultPlan::parse(plan)
                        .with_context(|| format!("job '{name}': bad fault_plan"))?,
                ),
                Some(other) => {
                    bail!("job '{name}': fault_plan must be a string, got {other:?}")
                }
                None => None,
            };
            JobSpec::shard_bench(
                name,
                ShardBenchSpec {
                    kind: OptimizerKind::parse(&kind_name)
                        .with_context(|| format!("job '{name}': unknown kind '{kind_name}'"))?,
                    shards: cfg.usize(&key("shards"), d.shards),
                    iters: cfg.usize(&key("iters"), d.iters),
                    layers: cfg.usize(&key("layers"), d.layers),
                    vocab: cfg.usize(&key("vocab"), d.vocab),
                    d_model: cfg.usize(&key("d_model"), d.d_model),
                    d_ff: cfg.usize(&key("d_ff"), d.d_ff),
                    seed: cfg.usize(&key("seed"), d.seed as usize) as u64,
                    transport: {
                        let t = cfg.str(&key("transport"), &d.transport.name());
                        TransportKind::parse(&t)
                            .with_context(|| format!("job '{name}': bad transport '{t}'"))?
                    },
                    tuning: TransportTuning {
                        read_timeout_ms: cfg
                            .usize(&key("read_timeout_ms"), dt.read_timeout_ms as usize)
                            as u64,
                        connect_retries: cfg
                            .usize(&key("connect_retries"), dt.connect_retries as usize)
                            as u32,
                        backoff_ms: cfg.usize(&key("backoff_ms"), dt.backoff_ms as usize) as u64,
                    },
                    recovery,
                    fault,
                },
            )
        }
        "vision" => {
            let dv = VisionConfig::default();
            JobSpec::vision(
                name,
                VisionSpec {
                    optimizer: cfg.req_str(&key("optimizer"))?,
                    lr: cfg.f64(&key("lr"), 0.05) as f32,
                    steps: cfg.usize(&key("steps"), 300) as u64,
                    eval_every: cfg.usize(&key("eval_every"), 60) as u64,
                    seed: cfg.usize(&key("seed"), 42) as u64,
                    artifact_dir: PathBuf::from(cfg.str(&key("artifact_dir"), "artifacts")),
                    data: VisionConfig {
                        classes: cfg.usize(&key("classes"), dv.classes),
                        train: cfg.usize(&key("train"), dv.train),
                        test: cfg.usize(&key("test"), dv.test),
                        blobs: cfg.usize(&key("blobs"), dv.blobs),
                        noise: cfg.f64(&key("noise"), dv.noise as f64) as f32,
                        mix_max: cfg.f64(&key("mix_max"), dv.mix_max as f64) as f32,
                        seed: cfg.usize(&key("data_seed"), dv.seed as usize) as u64,
                    },
                },
            )
        }
        _ => unreachable!("job type validated against the allowlist match above"),
    };
    spec.validate()?;
    Ok(spec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::Schedule;

    fn sample_batch() -> Vec<JobSpec> {
        let lm = RunConfig {
            artifact: "lm_tiny_et2".into(),
            eval_artifact: Some("lm_tiny_eval".into()),
            steps: 120,
            schedule: Schedule::scaled_lm(0.5, 15),
            host_optimizer: Some(OptimizerKind::Et(2)),
            shards: 2,
            state_backend: StateBackend::q8(),
            opt_memory_budget: Some(64 << 10),
            ..RunConfig::default()
        };
        vec![
            JobSpec::lm("lm_a", lm),
            JobSpec::convex(
                "pareto_cell",
                ConvexSpec {
                    opt: ConvexOpt::Planned { budget: 4096 },
                    data: ConvexConfig { n: 300, d: 32, k: 4, ..ConvexConfig::default() },
                    iters: 50,
                    ..ConvexSpec::default()
                },
            ),
            JobSpec::convex(
                "qs_adam",
                ConvexSpec {
                    opt: ConvexOpt::Kind(OptimizerKind::Adam),
                    backend: StateBackend::q8(),
                    data: ConvexConfig { n: 300, d: 32, k: 4, ..ConvexConfig::default() },
                    iters: 50,
                    ..ConvexSpec::default()
                },
            ),
            JobSpec::convex(
                "abl_eps",
                ConvexSpec {
                    opt: ConvexOpt::Ablate {
                        dims: vec![4, 4, 8],
                        eps: 1e-4,
                        beta2: Some(0.99),
                        per_factor_eps: true,
                    },
                    data: ConvexConfig { n: 300, d: 32, k: 4, ..ConvexConfig::default() },
                    iters: 50,
                    measure_after: false,
                    ..ConvexSpec::default()
                },
            ),
            JobSpec::shard_bench(
                "sb_et3",
                ShardBenchSpec { kind: OptimizerKind::Et(3), shards: 4, ..Default::default() },
            ),
            JobSpec::shard_bench(
                "sb_sock",
                ShardBenchSpec {
                    kind: OptimizerKind::AdaGrad,
                    shards: 2,
                    transport: TransportKind::Socket,
                    tuning: TransportTuning {
                        read_timeout_ms: 15_000,
                        connect_retries: 12,
                        backoff_ms: 20,
                    },
                    ..Default::default()
                },
            ),
            JobSpec::shard_bench(
                "sb_tcp_healed",
                ShardBenchSpec {
                    kind: OptimizerKind::Et(2),
                    shards: 2,
                    transport: TransportKind::Tcp("127.0.0.1:0".into()),
                    recovery: Some(RecoveryPolicy {
                        snapshot_every: 3,
                        max_recoveries: 2,
                        backoff_ms: 10,
                    }),
                    fault: Some(FaultPlan::parse("kill@1:5;timeout@0:3x2").unwrap()),
                    ..Default::default()
                },
            ),
        ]
    }

    #[test]
    fn toml_roundtrip_preserves_every_field() {
        let specs = sample_batch();
        let toml = batch_to_toml(&specs);
        let cfg = Config::parse(&toml).unwrap();
        let back = batch_from_config(&cfg).unwrap();
        // batch_from_config returns jobs sorted by name
        let mut want: Vec<&JobSpec> = specs.iter().collect();
        want.sort_by(|a, b| a.name.cmp(&b.name));
        assert_eq!(back.len(), want.len());
        for (got, want) in back.iter().zip(want) {
            assert_eq!(got.name, want.name);
            match (&got.workload, &want.workload) {
                (Workload::Lm(a), Workload::Lm(b)) => {
                    assert_eq!(a.artifact, b.artifact);
                    assert_eq!(a.eval_artifact, b.eval_artifact);
                    assert_eq!(a.steps, b.steps);
                    assert_eq!(a.schedule, b.schedule);
                    assert_eq!(a.host_optimizer, b.host_optimizer);
                    assert_eq!(a.shards, b.shards);
                    assert_eq!(a.state_backend, b.state_backend);
                    assert_eq!(a.opt_memory_budget, b.opt_memory_budget);
                    assert_eq!(a.seed, b.seed);
                }
                (Workload::Convex(a), Workload::Convex(b)) => {
                    assert_eq!(a.opt, b.opt);
                    assert_eq!(a.backend, b.backend);
                    assert_eq!(a.iters, b.iters);
                    assert_eq!(a.lr, b.lr);
                    assert_eq!(a.measure_after, b.measure_after);
                    assert_eq!(a.data.n, b.data.n);
                    assert_eq!(a.data.seed, b.data.seed);
                }
                (Workload::ShardBench(a), Workload::ShardBench(b)) => assert_eq!(a, b),
                _ => panic!("workload kind changed in round trip"),
            }
        }
    }

    #[test]
    fn validation_rejects_bad_specs() {
        let mut j = JobSpec::convex("ok-name_2", ConvexSpec::default());
        assert!(j.validate().is_ok());
        for bad in ["has.dot", "has space", "has]bracket", "has\"quote", ""] {
            j.name = bad.into();
            assert!(j.validate().is_err(), "name '{bad}' must be rejected");
        }
        // ET dims must cover the weight group
        let bad = JobSpec::convex(
            "bad",
            ConvexSpec {
                opt: ConvexOpt::CustomEt { dims: vec![3, 3] },
                data: ConvexConfig { n: 10, d: 32, k: 4, ..ConvexConfig::default() },
                ..ConvexSpec::default()
            },
        );
        assert!(bad.validate().is_err());
        let zero_steps =
            JobSpec::lm("z", RunConfig { steps: 0, ..RunConfig::default() });
        assert!(zero_steps.validate().is_err());
        let zero_budget = JobSpec::convex(
            "zb",
            ConvexSpec { opt: ConvexOpt::Planned { budget: 0 }, ..ConvexSpec::default() },
        );
        assert!(zero_budget.validate().is_err());
        // A fault plan without supervision is rejected up front.
        let unsupervised_fault = JobSpec::shard_bench(
            "uf",
            ShardBenchSpec {
                fault: Some(FaultPlan::parse("kill@0:3").unwrap()),
                ..ShardBenchSpec::default()
            },
        );
        let err = unsupervised_fault.validate().unwrap_err().to_string();
        assert!(err.contains("run.recovery"), "{err}");
        // Tuning validation errors name the run.transport.* key.
        let bad_tuning = JobSpec::shard_bench(
            "bt",
            ShardBenchSpec {
                tuning: TransportTuning { read_timeout_ms: 0, ..TransportTuning::default() },
                ..ShardBenchSpec::default()
            },
        );
        let err = bad_tuning.validate().unwrap_err();
        assert!(format!("{err:#}").contains("run.transport.read_timeout_ms"), "{err:#}");
        // Recovery validation errors name the run.recovery.* key.
        let bad_policy = JobSpec::shard_bench(
            "bp",
            ShardBenchSpec {
                recovery: Some(RecoveryPolicy { snapshot_every: 0, ..RecoveryPolicy::default() }),
                ..ShardBenchSpec::default()
            },
        );
        let err = bad_policy.validate().unwrap_err();
        assert!(format!("{err:#}").contains("run.recovery.snapshot_every"), "{err:#}");
    }

    #[test]
    fn batch_parse_rejects_garbage() {
        assert!(batch_from_config(&Config::parse("[run]\nartifact = \"x\"").unwrap()).is_err());
        assert!(batch_from_config(&Config::parse("").unwrap()).is_err());
        let missing_type = Config::parse("[job.a]\nartifact = \"x\"").unwrap();
        assert!(batch_from_config(&missing_type).is_err());
        let bad_type = Config::parse("[job.a]\ntype = \"nope\"").unwrap();
        assert!(batch_from_config(&bad_type).is_err());
    }

    /// Planned budgets reject non-positive values and accept the
    /// `run.opt_memory_budget` byte-size spelling.
    #[test]
    fn planned_budget_parses_strictly() {
        let neg = Config::parse(
            "[job.p]\ntype = \"convex\"\noptimizer = \"planned\"\nbudget = -4096",
        )
        .unwrap();
        assert!(batch_from_config(&neg).is_err(), "negative budget must not wrap to u64");
        let zero = Config::parse(
            "[job.p]\ntype = \"convex\"\noptimizer = \"planned\"\nbudget = 0",
        )
        .unwrap();
        assert!(batch_from_config(&zero).is_err());
        let suffixed = Config::parse(
            "[job.p]\ntype = \"convex\"\noptimizer = \"planned\"\nbudget = \"64k\"",
        )
        .unwrap();
        let specs = batch_from_config(&suffixed).unwrap();
        match &specs[0].workload {
            Workload::Convex(c) => assert_eq!(c.opt, ConvexOpt::Planned { budget: 64 << 10 }),
            _ => panic!("expected convex"),
        }
    }

    /// A typoed key inside a job section is a hard error, not a silently
    /// applied default (`step` instead of `steps`, `iter` vs `iters`).
    #[test]
    fn unknown_job_keys_rejected() {
        let typo_lm = Config::parse(
            "[job.a]\ntype = \"lm\"\nartifact = \"x\"\nstep = 100",
        )
        .unwrap();
        let err = batch_from_config(&typo_lm).map(|_| ()).unwrap_err().to_string();
        assert!(err.contains("step"), "error must name the bad key: {err}");

        let typo_convex = Config::parse(
            "[job.b]\ntype = \"convex\"\noptimizer = \"adam\"\niter = 500",
        )
        .unwrap();
        assert!(batch_from_config(&typo_convex).is_err());

        // All emitted keys are accepted back (the allowlists cover to_toml).
        let good = Config::parse(&batch_to_toml(&sample_batch())).unwrap();
        assert!(batch_from_config(&good).is_ok());
    }

    #[test]
    fn convex_cost_counts_data_and_state() {
        let spec = JobSpec::convex(
            "c",
            ConvexSpec {
                data: ConvexConfig { n: 100, d: 16, k: 4, ..ConvexConfig::default() },
                opt: ConvexOpt::Kind(OptimizerKind::Adam),
                ..ConvexSpec::default()
            },
        );
        let cost = spec.cost_bytes().unwrap();
        // data (100x16 f32 + labels) + w/grad (2 * 64 f32) + Adam state (2 * 64 f32)
        assert_eq!(cost, (4 * 100 * 16 + 4 * 100 + 8 * 64 + 8 * 64) as u64);
    }
}

//! Self-healing supervision over [`ShardedOptimizer`]: automatic
//! snapshots, typed fault classification, and bitwise-deterministic
//! crash recovery — the driver loop never sees a transient transport
//! fault.
//!
//! The engine already has the recovery *mechanisms* (`take_snapshot`,
//! `recover`, shard-count-independent state export); what it lacks is
//! *policy*: when to snapshot, which failures to retry, how many times,
//! and who replays the lost step window. [`SupervisedOptimizer`] owns
//! exactly that. A driver replaces
//!
//! ```text
//! opt.next_step();
//! opt.step_all(&mut params, grads, lr)?;   // dies on any worker fault
//! ```
//!
//! with `sup.run_step(&mut params, grads, lr)?`, and the supervisor:
//!
//! 1. **Snapshots** optimizer state (inside the workers) *and* a copy of
//!    the parameters every [`RecoveryPolicy::snapshot_every`] completed
//!    steps, clearing the replay window at each boundary.
//! 2. **Records** every completed step's `(grads, lr)` into the replay
//!    window, so recovery can replay forward from the snapshot with the
//!    exact gradient sequence — bitwise, not approximately.
//! 3. On a step failure, **classifies** the engine's typed
//!    [`TransportError`]s: worker-reported application errors are
//!    deterministic and would recur, so they fail fast; timeout storms
//!    are transient and back off (doubling, clock-free) before healing;
//!    disconnects/protocol violations heal immediately.
//! 4. **Heals** through a single unified path regardless of fault kind:
//!    rebuild the engine on the surviving workers ([`recover`]), rewind
//!    the caller's parameters to the snapshot copy, replay the window,
//!    then retry the in-flight step. One path means even a "transient"
//!    timeout — which may have left *other* shards already updated for
//!    the failed step — cannot double-apply anything.
//! 5. **Gives up** with a typed [`SupervisorError`] once
//!    [`RecoveryPolicy::max_recoveries`] is exhausted (or immediately on
//!    unrecoverable faults). A fault *during* recovery (the failure mode
//!    that kills most checkpoint systems) is just another incident: the
//!    engine keeps its snapshot when an import fails, so healing is
//!    itself retried under the same budget.
//!
//! Every decision is surfaced as a [`RecoveryEvent`] through an optional
//! callback, which the session layer forwards into the run's JSONL event
//! stream and the run registry's incident fields. Determinism contract:
//! a supervised run that survives any schedule of injected faults (see
//! [`crate::transport::FaultPlan`]) produces final parameters and
//! optimizer state bitwise-identical to an uninterrupted run — tested in
//! `rust/tests/transport_recovery.rs`.
//!
//! [`recover`]: ShardedOptimizer::recover

use super::ShardedOptimizer;
use crate::transport::TransportError;
use anyhow::{bail, Result};
use std::time::Duration;

/// Declarative recovery policy for a supervised run. Spec-visible as the
/// `run.recovery.*` keys of a shard bench.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RecoveryPolicy {
    /// Take a snapshot every this-many completed steps (and always before
    /// the first step). Smaller = shorter replay window, more export
    /// traffic. Must be >= 1.
    pub snapshot_every: u64,
    /// Total recovery budget for the run: how many incidents (including
    /// failures during recovery itself) may be healed before giving up.
    pub max_recoveries: u32,
    /// Base backoff before healing a *transient* (all-timeout) incident;
    /// doubles per incident, capped at 32x. Zero disables backoff.
    pub backoff_ms: u64,
}

impl Default for RecoveryPolicy {
    fn default() -> RecoveryPolicy {
        RecoveryPolicy { snapshot_every: 8, max_recoveries: 4, backoff_ms: 25 }
    }
}

impl RecoveryPolicy {
    /// Validate, naming the offending spec key.
    pub fn validate(&self) -> Result<()> {
        if self.snapshot_every == 0 {
            bail!("run.recovery.snapshot_every must be >= 1");
        }
        Ok(())
    }

    /// Backoff before healing the `n`-th transient incident (1-based):
    /// `backoff_ms * 2^(n-1)`, capped at 32x the base. Clock-free and
    /// deterministic — the delay depends only on the incident count.
    pub fn backoff_for(&self, incident: u32) -> Duration {
        let factor = match incident.saturating_sub(1) {
            shift if shift >= 5 => 32,
            shift => 1u64 << shift,
        };
        Duration::from_millis(self.backoff_ms.saturating_mul(factor))
    }
}

/// One supervision decision, in the order it happened. The session layer
/// forwards these into the run's event stream; tests assert on them.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RecoveryEvent {
    /// Snapshot taken at a step boundary; the replay window restarts here.
    Snapshot { step: u64 },
    /// A step (or snapshot) failed. `kind` is the dominant
    /// [`TransportError::kind_label`]; `transient` means the incident
    /// backs off before healing.
    Incident { step: u64, kind: &'static str, transient: bool, detail: String },
    /// Healed: engine rebuilt on `shards` workers, parameters rewound to
    /// `from_step`, `replayed` steps replayed bitwise from the window.
    Recovered { step: u64, from_step: u64, shards: usize, replayed: u64 },
    /// Supervision ended the run: budget exhausted or the fault class is
    /// unrecoverable.
    GaveUp { step: u64, recoveries: u32, kind: &'static str, detail: String },
}

impl RecoveryEvent {
    /// Short tag for logs and event streams.
    pub fn tag(&self) -> &'static str {
        match self {
            RecoveryEvent::Snapshot { .. } => "snapshot",
            RecoveryEvent::Incident { .. } => "incident",
            RecoveryEvent::Recovered { .. } => "recovered",
            RecoveryEvent::GaveUp { .. } => "gave-up",
        }
    }
}

/// Typed terminal failure of a supervised run. Wrapped in
/// [`anyhow::Error`]; callers downcast to tell budget exhaustion apart
/// from unrecoverable faults.
#[derive(Debug)]
pub enum SupervisorError {
    /// The recovery budget ran out; `last` is the final incident.
    Exhausted { recoveries: u32, kind: &'static str, last: String },
    /// The fault class cannot be healed by rebuild-and-replay: a
    /// deterministic worker-side failure would simply recur, and a
    /// non-transport error has nothing to recover from.
    Unrecoverable { kind: &'static str, detail: String },
}

impl SupervisorError {
    /// The taxonomy bucket of the terminal fault (registry `error_kind`).
    pub fn kind(&self) -> &'static str {
        match self {
            SupervisorError::Exhausted { kind, .. } => kind,
            SupervisorError::Unrecoverable { kind, .. } => kind,
        }
    }
}

impl std::fmt::Display for SupervisorError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SupervisorError::Exhausted { recoveries, kind, last } => write!(
                f,
                "recovery budget exhausted after {recoveries} recoveries ({kind}): {last}"
            ),
            SupervisorError::Unrecoverable { kind, detail } => {
                write!(f, "unrecoverable {kind} failure: {detail}")
            }
        }
    }
}

impl std::error::Error for SupervisorError {}

/// How an incident's error set classifies. See
/// [`SupervisedOptimizer::classify`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct Classified {
    kind: &'static str,
    transient: bool,
    recoverable: bool,
}

type EventSink = Box<dyn FnMut(&RecoveryEvent) + Send>;

/// Supervision wrapper: owns a [`ShardedOptimizer`], a replay window,
/// and the snapshot-time parameter copy. See the module docs for the
/// full control flow.
pub struct SupervisedOptimizer {
    engine: ShardedOptimizer,
    policy: RecoveryPolicy,
    on_event: Option<EventSink>,
    /// `(grads, lr)` of every step completed since the last snapshot, in
    /// order — the bitwise replay source.
    window: Vec<(Vec<Vec<f32>>, f32)>,
    /// The caller's parameters as of the last snapshot. Parameters live
    /// with the caller, not the workers, so the supervisor keeps the
    /// rewind copy itself.
    params_at_snapshot: Vec<Vec<f32>>,
    /// Completed supervised steps.
    step: u64,
    recoveries: u32,
    steps_replayed: u64,
    shards_lost: usize,
    last_error_kind: Option<&'static str>,
}

impl SupervisedOptimizer {
    pub fn new(engine: ShardedOptimizer, policy: RecoveryPolicy) -> Result<SupervisedOptimizer> {
        policy.validate()?;
        Ok(SupervisedOptimizer {
            engine,
            policy,
            on_event: None,
            window: Vec::new(),
            params_at_snapshot: Vec::new(),
            step: 0,
            recoveries: 0,
            steps_replayed: 0,
            shards_lost: 0,
            last_error_kind: None,
        })
    }

    /// Install an event callback; every [`RecoveryEvent`] is delivered in
    /// order, synchronously.
    pub fn with_events(
        mut self,
        sink: impl FnMut(&RecoveryEvent) + Send + 'static,
    ) -> SupervisedOptimizer {
        self.on_event = Some(Box::new(sink));
        self
    }

    pub fn policy(&self) -> &RecoveryPolicy {
        &self.policy
    }

    /// Completed supervised steps.
    pub fn completed_steps(&self) -> u64 {
        self.step
    }

    /// Incidents healed so far (not counting a terminal give-up).
    pub fn recoveries(&self) -> u32 {
        self.recoveries
    }

    /// Total steps replayed from the window across all recoveries.
    pub fn steps_replayed(&self) -> u64 {
        self.steps_replayed
    }

    /// Workers lost across all recoveries (shard-count shrinkage).
    pub fn shards_lost(&self) -> usize {
        self.shards_lost
    }

    /// Taxonomy bucket of the most recent incident, if any.
    pub fn last_error_kind(&self) -> Option<&'static str> {
        self.last_error_kind
    }

    pub fn engine(&self) -> &ShardedOptimizer {
        &self.engine
    }

    pub fn engine_mut(&mut self) -> &mut ShardedOptimizer {
        &mut self.engine
    }

    pub fn into_engine(self) -> ShardedOptimizer {
        self.engine
    }

    fn emit(&mut self, event: RecoveryEvent) {
        if let Some(sink) = self.on_event.as_mut() {
            sink(&event);
        }
    }

    /// Classify the engine's typed errors from the operation that just
    /// failed. An empty error set means the failure was not a transport
    /// fault (caller-side validation, missing snapshot) — nothing to
    /// heal. Any worker-reported error is deterministic and unrecoverable
    /// (replaying the same gradients reproduces it). An all-timeout set
    /// is transient; anything else heals without backoff.
    fn classify(errors: &[TransportError]) -> Classified {
        if errors.is_empty() {
            return Classified { kind: "internal", transient: false, recoverable: false };
        }
        if errors.iter().any(|e| matches!(e, TransportError::Worker { .. })) {
            return Classified { kind: "worker", transient: false, recoverable: false };
        }
        if errors.iter().all(|e| matches!(e, TransportError::Timeout { .. })) {
            return Classified { kind: "timeout", transient: true, recoverable: true };
        }
        // Mixed fatal set: report the first non-timeout error's bucket.
        let kind = errors
            .iter()
            .find(|e| !matches!(e, TransportError::Timeout { .. }))
            .map(TransportError::kind_label)
            .unwrap_or("io");
        Classified { kind, transient: false, recoverable: true }
    }

    /// One supervised optimizer step: snapshot if due, advance the step
    /// counter, fan out the update — healing any fault along the way.
    /// On `Ok`, `params` hold the updated values and the step is recorded
    /// in the replay window. On `Err`, supervision has given up; the
    /// error downcasts to [`SupervisorError`].
    pub fn run_step(&mut self, params: &mut [Vec<f32>], grads: &[Vec<f32>], lr: f32) -> Result<()> {
        self.maybe_snapshot(params)?;
        loop {
            self.engine.next_step();
            match self.engine.step_all(params, grads, lr) {
                Ok(()) => {
                    self.window.push((grads.to_vec(), lr));
                    self.step += 1;
                    return Ok(());
                }
                Err(err) => self.heal(params, err)?,
            }
        }
    }

    /// Snapshot at the policy cadence: worker-side optimizer state via
    /// the engine, caller-side parameters into the rewind copy. A failed
    /// snapshot is an incident like any other — the engine keeps its
    /// previous snapshot, so healing rewinds to *that* and the snapshot
    /// is retried once the world is healthy again.
    fn maybe_snapshot(&mut self, params: &mut [Vec<f32>]) -> Result<()> {
        if self.step % self.policy.snapshot_every != 0 && !self.params_at_snapshot.is_empty() {
            return Ok(());
        }
        loop {
            let sp = crate::trace::span(
                crate::trace::SpanKind::Snapshot,
                crate::trace::NO_SHARD,
                crate::trace::NO_JOB,
            );
            let taken = self.engine.take_snapshot();
            drop(sp);
            match taken {
                Ok(step) => {
                    self.params_at_snapshot = params.to_vec();
                    self.window.clear();
                    self.emit(RecoveryEvent::Snapshot { step });
                    return Ok(());
                }
                Err(err) => self.heal(params, err)?,
            }
        }
    }

    /// The unified heal path. Loops because recovery can itself fail (a
    /// second fault mid-replay); every attempt draws from the same
    /// [`RecoveryPolicy::max_recoveries`] budget.
    fn heal(&mut self, params: &mut [Vec<f32>], first: anyhow::Error) -> Result<()> {
        let mut err = first;
        loop {
            let class = Self::classify(self.engine.last_errors());
            self.last_error_kind = Some(class.kind);
            if !class.recoverable {
                let terminal = SupervisorError::Unrecoverable {
                    kind: class.kind,
                    detail: err.to_string(),
                };
                self.emit(RecoveryEvent::GaveUp {
                    step: self.step,
                    recoveries: self.recoveries,
                    kind: class.kind,
                    detail: err.to_string(),
                });
                return Err(anyhow::Error::new(terminal));
            }
            if self.recoveries >= self.policy.max_recoveries {
                let terminal = SupervisorError::Exhausted {
                    recoveries: self.recoveries,
                    kind: class.kind,
                    last: err.to_string(),
                };
                self.emit(RecoveryEvent::GaveUp {
                    step: self.step,
                    recoveries: self.recoveries,
                    kind: class.kind,
                    detail: err.to_string(),
                });
                return Err(anyhow::Error::new(terminal));
            }
            self.recoveries += 1;
            {
                let _sp = crate::trace::span(
                    crate::trace::SpanKind::Incident,
                    crate::trace::NO_SHARD,
                    crate::trace::NO_JOB,
                );
                self.emit(RecoveryEvent::Incident {
                    step: self.step,
                    kind: class.kind,
                    transient: class.transient,
                    detail: err.to_string(),
                });
            }
            if class.transient {
                let pause = self.policy.backoff_for(self.recoveries);
                if !pause.is_zero() {
                    std::thread::sleep(pause);
                }
            }
            match self.recover_and_replay(params) {
                Ok(()) => return Ok(()),
                Err(next) => err = next,
            }
        }
    }

    /// Rebuild on the survivors, rewind `params` to the snapshot copy,
    /// replay the window bitwise. Any failure propagates back to
    /// [`heal`](Self::heal) as the next incident.
    fn recover_and_replay(&mut self, params: &mut [Vec<f32>]) -> Result<()> {
        let _sp = crate::trace::span(
            crate::trace::SpanKind::Recover,
            crate::trace::NO_SHARD,
            crate::trace::NO_JOB,
        );
        let before = self.engine.n_shards();
        let from_step = self.engine.recover()?;
        let after = self.engine.n_shards();
        self.shards_lost += before.saturating_sub(after);
        for (p, snap) in params.iter_mut().zip(&self.params_at_snapshot) {
            p.copy_from_slice(snap);
        }
        let mut replayed = 0u64;
        for (grads, lr) in &self.window {
            self.engine.next_step();
            self.engine.step_all(params, grads, *lr)?;
            replayed += 1;
        }
        self.steps_replayed += replayed;
        self.emit(RecoveryEvent::Recovered {
            step: self.step,
            from_step,
            shards: after,
            replayed,
        });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{self, GroupSpec, Hyper, Optimizer};
    use crate::shard::DEFAULT_MIN_BUCKET_NUMEL;
    use crate::tensoring::OptimizerKind;
    use crate::transport::{FaultPlan, FaultTransport, InProcess};
    use crate::util::rng::Pcg64;
    use std::sync::{Arc, Mutex};

    fn groups() -> Vec<GroupSpec> {
        vec![
            GroupSpec::new("w", &[12, 8]),
            GroupSpec::new("b", &[8]),
            GroupSpec::new("v", &[6, 5]),
        ]
    }

    fn grad_stream(gs: &[GroupSpec], steps: usize, seed: u64) -> Vec<Vec<Vec<f32>>> {
        let mut rng = Pcg64::seeded(seed);
        (0..steps)
            .map(|_| {
                gs.iter()
                    .map(|g| {
                        let mut v = vec![0.0f32; g.numel()];
                        rng.fill_normal(&mut v, 1.0);
                        v
                    })
                    .collect()
            })
            .collect()
    }

    fn init_params(gs: &[GroupSpec]) -> Vec<Vec<f32>> {
        let mut rng = Pcg64::seeded(0xBEEF);
        gs.iter()
            .map(|g| {
                let mut v = vec![0.0f32; g.numel()];
                rng.fill_uniform(&mut v, -0.5, 0.5);
                v
            })
            .collect()
    }

    fn reference_params(gs: &[GroupSpec], stream: &[Vec<Vec<f32>>], lr: f32) -> Vec<Vec<f32>> {
        let mut opt = optim::build(OptimizerKind::Et(2), gs, &Hyper::default());
        let mut params = init_params(gs);
        for grads in stream {
            opt.next_step();
            opt.step_all(&mut params, grads, lr).unwrap();
        }
        params
    }

    fn engine(transport: Arc<dyn crate::transport::ShardTransport>) -> ShardedOptimizer {
        ShardedOptimizer::with_transport(
            OptimizerKind::Et(2),
            &groups(),
            &Hyper::default(),
            2,
            None,
            DEFAULT_MIN_BUCKET_NUMEL,
            transport,
        )
        .unwrap()
    }

    fn policy() -> RecoveryPolicy {
        RecoveryPolicy { snapshot_every: 3, max_recoveries: 4, backoff_ms: 0 }
    }

    #[test]
    fn policy_validation_names_the_offending_key() {
        let err = RecoveryPolicy { snapshot_every: 0, ..RecoveryPolicy::default() }
            .validate()
            .unwrap_err();
        assert!(err.to_string().contains("run.recovery.snapshot_every"), "{err}");
    }

    #[test]
    fn backoff_doubles_and_caps() {
        let p = RecoveryPolicy { backoff_ms: 10, ..RecoveryPolicy::default() };
        assert_eq!(p.backoff_for(1), Duration::from_millis(10));
        assert_eq!(p.backoff_for(2), Duration::from_millis(20));
        assert_eq!(p.backoff_for(6), Duration::from_millis(320));
        assert_eq!(p.backoff_for(60), Duration::from_millis(320));
    }

    #[test]
    fn fault_free_supervised_run_is_bitwise_and_snapshots_on_cadence() {
        let gs = groups();
        let stream = grad_stream(&gs, 7, 11);
        let want = reference_params(&gs, &stream, 0.05);

        let events = Arc::new(Mutex::new(Vec::new()));
        let sink = Arc::clone(&events);
        let mut sup = SupervisedOptimizer::new(engine(Arc::new(InProcess)), policy())
            .unwrap()
            .with_events(move |e| sink.lock().unwrap().push(e.clone()));
        let mut params = init_params(&gs);
        for grads in &stream {
            sup.run_step(&mut params, grads, 0.05).unwrap();
        }
        assert_eq!(want, params);
        assert_eq!(sup.recoveries(), 0);
        assert_eq!(sup.completed_steps(), 7);
        let events = events.lock().unwrap();
        let snaps: Vec<u64> = events
            .iter()
            .filter_map(|e| match e {
                RecoveryEvent::Snapshot { step } => Some(*step),
                _ => None,
            })
            .collect();
        assert_eq!(snaps, vec![0, 3, 6], "snapshot_every=3 over 7 steps");
    }

    #[test]
    fn injected_disconnect_heals_bitwise_inprocess() {
        let gs = groups();
        let stream = grad_stream(&gs, 8, 13);
        let want = reference_params(&gs, &stream, 0.05);

        let plan = FaultPlan::parse("kill@1:5").unwrap();
        let transport = Arc::new(FaultTransport::new(Arc::new(InProcess), plan));
        let events = Arc::new(Mutex::new(Vec::new()));
        let sink = Arc::clone(&events);
        let mut sup = SupervisedOptimizer::new(engine(transport), policy())
            .unwrap()
            .with_events(move |e| sink.lock().unwrap().push(e.clone()));
        let mut params = init_params(&gs);
        for grads in &stream {
            sup.run_step(&mut params, grads, 0.05).unwrap();
        }
        assert_eq!(want, params, "healed run diverged from uninterrupted reference");
        assert_eq!(sup.recoveries(), 1);
        assert_eq!(sup.last_error_kind(), Some("disconnected"));
        assert_eq!(sup.engine().n_shards(), 1, "dead shard -> rebuilt on the survivor");
        let events = events.lock().unwrap();
        let tags: Vec<&str> = events.iter().map(|e| e.tag()).collect();
        assert!(tags.contains(&"incident") && tags.contains(&"recovered"), "{tags:?}");
    }

    #[test]
    fn timeout_storm_is_transient_and_heals() {
        let gs = groups();
        let stream = grad_stream(&gs, 6, 17);
        let want = reference_params(&gs, &stream, 0.05);

        let plan = FaultPlan::parse("timeout@0:4x2").unwrap();
        let transport = Arc::new(FaultTransport::new(Arc::new(InProcess), plan));
        let mut sup = SupervisedOptimizer::new(engine(transport), policy()).unwrap();
        let mut params = init_params(&gs);
        for grads in &stream {
            sup.run_step(&mut params, grads, 0.05).unwrap();
        }
        assert_eq!(want, params);
        assert!(sup.recoveries() >= 1);
        assert_eq!(sup.last_error_kind(), Some("timeout"));
        assert_eq!(sup.engine().n_shards(), 2, "timeouts do not kill workers");
    }

    #[test]
    fn exhausted_budget_is_a_typed_failure() {
        let gs = groups();
        let stream = grad_stream(&gs, 6, 19);
        // More timeout bursts than the budget can absorb.
        let plan = FaultPlan::parse("timeout@0:2x100").unwrap();
        let transport = Arc::new(FaultTransport::new(Arc::new(InProcess), plan));
        let mut sup = SupervisedOptimizer::new(
            engine(transport),
            RecoveryPolicy { snapshot_every: 2, max_recoveries: 1, backoff_ms: 0 },
        )
        .unwrap();
        let mut params = init_params(&gs);
        let mut failed = None;
        for grads in &stream {
            if let Err(e) = sup.run_step(&mut params, grads, 0.05) {
                failed = Some(e);
                break;
            }
        }
        let err = failed.expect("budget of 1 cannot absorb 100 timeout bursts");
        match err.downcast_ref::<SupervisorError>() {
            Some(SupervisorError::Exhausted { recoveries, kind, .. }) => {
                assert_eq!(*recoveries, 1);
                assert_eq!(*kind, "timeout");
            }
            other => panic!("expected Exhausted, got {other:?}"),
        }
    }

    #[test]
    fn double_fault_during_recovery_draws_from_the_same_budget() {
        let gs = groups();
        let stream = grad_stream(&gs, 8, 23);
        let want = reference_params(&gs, &stream, 0.05);

        // First kill at shard 1's step 5; the second fires during the
        // recovery replay (ordinals are monotonic across rebuilds, so
        // step 6 of shard 0 lands mid-replay or on the retried step).
        let plan = FaultPlan::parse("kill@1:5;kill@0:6").unwrap();
        let transport = Arc::new(FaultTransport::new(Arc::new(InProcess), plan));
        let events = Arc::new(Mutex::new(Vec::new()));
        let sink = Arc::clone(&events);
        let mut sup = SupervisedOptimizer::new(engine(transport), policy())
            .unwrap()
            .with_events(move |e| sink.lock().unwrap().push(e.clone()));
        let mut params = init_params(&gs);
        for grads in &stream {
            sup.run_step(&mut params, grads, 0.05).unwrap();
        }
        assert_eq!(want, params, "double-fault run diverged");
        assert_eq!(sup.recoveries(), 2, "each fault is its own incident");
        let events = events.lock().unwrap();
        let incidents = events.iter().filter(|e| e.tag() == "incident").count();
        let recovered = events.iter().filter(|e| e.tag() == "recovered").count();
        assert_eq!((incidents, recovered), (2, 2));
    }

    /// Transport that forwards to in-process workers but reports a
    /// deterministic worker-side application failure on every step ack —
    /// the fault class supervision must *not* burn budget on.
    struct WorkerErrTransport(InProcess);

    struct WorkerErrConn {
        shard: usize,
        inner: Box<dyn crate::transport::ShardConnection>,
    }

    impl crate::transport::ShardTransport for WorkerErrTransport {
        fn connect(
            &self,
            shard: usize,
            spec: crate::transport::WorkerSpec,
            queue_cap: usize,
        ) -> std::result::Result<
            Box<dyn crate::transport::ShardConnection>,
            crate::transport::TransportError,
        > {
            let inner = self.0.connect(shard, spec, queue_cap)?;
            Ok(Box::new(WorkerErrConn { shard, inner }))
        }

        fn name(&self) -> &'static str {
            self.0.name()
        }
    }

    impl crate::transport::ShardConnection for WorkerErrConn {
        fn send_step(
            &mut self,
            lr: f32,
            tasks: Vec<crate::transport::GroupTask>,
        ) -> std::result::Result<(), TransportError> {
            self.inner.send_step(lr, tasks)
        }

        fn recv_step_ack(&mut self) -> std::result::Result<(), TransportError> {
            // Drain the real ack first (the pointer-safety barrier), then
            // report the application failure a broken update rule would.
            self.inner.recv_step_ack()?;
            Err(TransportError::Worker {
                shard: self.shard,
                message: "synthetic update-rule failure".to_string(),
            })
        }

        fn next_step(&mut self) -> std::result::Result<(), TransportError> {
            self.inner.next_step()
        }

        fn state_scalars(&mut self) -> std::result::Result<(usize, usize), TransportError> {
            self.inner.state_scalars()
        }

        fn export_state(
            &mut self,
        ) -> std::result::Result<crate::optim::StateExport, TransportError> {
            self.inner.export_state()
        }

        fn import_state(
            &mut self,
            state: crate::optim::StateExport,
        ) -> std::result::Result<(), TransportError> {
            self.inner.import_state(state)
        }

        fn is_alive(&self) -> bool {
            self.inner.is_alive()
        }

        fn shutdown(&mut self) -> std::result::Result<(), TransportError> {
            self.inner.shutdown()
        }
    }

    #[test]
    fn worker_error_is_unrecoverable_immediately() {
        let gs = groups();
        let mut sup = SupervisedOptimizer::new(
            engine(Arc::new(WorkerErrTransport(InProcess))),
            policy(),
        )
        .unwrap();
        let mut params = init_params(&gs);
        let grads: Vec<Vec<f32>> = gs.iter().map(|g| vec![0.1; g.numel()]).collect();
        let err = sup.run_step(&mut params, &grads, 0.05).unwrap_err();
        match err.downcast_ref::<SupervisorError>() {
            Some(SupervisorError::Unrecoverable { kind, .. }) => assert_eq!(*kind, "worker"),
            other => panic!("expected Unrecoverable, got {other:?}"),
        }
        assert_eq!(sup.recoveries(), 0, "no recovery attempted for worker errors");
    }
}

//! The sharded optimizer engine: fan-out/fan-in over persistent workers.
//!
//! [`ShardedOptimizer`] implements the ordinary [`Optimizer`] trait, so it
//! drops into every call site the single-threaded suite serves; its
//! [`Optimizer::step_all`] override is the hot path that updates *all*
//! groups in one fan-out. Work travels as [`Bucket`]s over a
//! [`ShardConnection`] per shard; the call returns only after every bucket
//! is acknowledged, which is both the memory-safety barrier for the raw
//! slice handoff and the reason the reduction is trivially deterministic:
//! each group is computed by exactly one worker with exactly the
//! single-threaded per-group arithmetic, and no cross-shard arithmetic
//! exists to reorder. Sharded results are therefore bitwise-identical to
//! the single-threaded engine at any shard count — and over any transport
//! (`rust/tests/sharded_parity.rs` checks every optimizer kind over both
//! the in-process and the socket transport).
//!
//! The executor no longer owns threads: it holds one
//! [`ShardConnection`] per shard, built by a [`ShardTransport`]
//! ([`crate::transport::InProcess`] by default;
//! [`crate::transport::SocketTransport`] runs each worker as an
//! `ettrain shard-worker` child process). Because each worker owns an
//! externalized [`crate::optim::OptState`], shard-local state is not
//! trapped with its worker: [`ShardedOptimizer::export_state`] fans in
//! every worker's snapshot and merges them into one global,
//! shard-count-independent [`StateExport`] (groups in global order), and
//! [`ShardedOptimizer::import_state`] fans a global snapshot back out —
//! so a checkpoint taken at 2 shards restores at 1 or 4
//! bitwise-identically (`rust/tests/host_checkpoint.rs`).
//!
//! That shard-count independence is also what makes the worker set
//! *elastic*: [`ShardedOptimizer::reshard`] grows or shrinks the engine at
//! a step boundary (export → rebuild → import, no restart), and
//! [`ShardedOptimizer::take_snapshot`] + [`ShardedOptimizer::recover`]
//! survive worker death by rebuilding over the surviving connection count
//! and replaying from the last snapshot.

use super::bucket::{bucketize, Bucket, DEFAULT_MIN_BUCKET_NUMEL};
use super::partition::{partition, partition_planned, ShardPlan};
use crate::budget::StatePlan;
use crate::optim::{GroupExport, GroupSpec, Hyper, Optimizer, StateExport};
use crate::tensoring::OptimizerKind;
use crate::transport::{
    GroupTask, InProcess, ShardConnection, ShardTransport, TransportError, WorkerSpec,
};
use anyhow::{bail, Context, Result};
use std::sync::Arc;

/// What each worker's optimizer is built from — kept by the executor so it
/// can rebuild the worker set at a different shard count ([`reshard`],
/// [`recover`]).
///
/// [`reshard`]: ShardedOptimizer::reshard
/// [`recover`]: ShardedOptimizer::recover
#[derive(Clone)]
enum SpecSource {
    Uniform { kind: OptimizerKind },
    Planned { plan: StatePlan },
}

pub struct ShardedOptimizer {
    kind: OptimizerKind,
    /// Display label: the uniform kind's name, or "ET-plan" for
    /// plan-driven engines.
    label: String,
    plan: ShardPlan,
    /// Per-shard dispatch units over that shard's owned groups.
    buckets: Vec<Vec<Bucket>>,
    /// group index -> (owning shard, index into the shard-local optimizer).
    local: Vec<(usize, usize)>,
    group_numels: Vec<usize>,
    /// Global group names, for validating state imports.
    group_names: Vec<String>,
    conns: Vec<Box<dyn ShardConnection>>,
    total_state_scalars: usize,
    total_state_bytes: usize,
    // Rebuild inputs, for elastic resharding and crash recovery.
    groups: Vec<GroupSpec>,
    hyper: Hyper,
    source: SpecSource,
    max_state_per_shard: Option<usize>,
    min_bucket_numel: usize,
    transport: Arc<dyn ShardTransport>,
    /// Last state snapshot taken via [`ShardedOptimizer::take_snapshot`];
    /// the recovery point after a worker dies.
    last_snapshot: Option<StateExport>,
    /// Typed transport errors from the most recent failed operation
    /// (`step_all`/`step`/`export_state`/`import_state`). The supervisor's
    /// error-classification surface: `bail!` flattens causes into one
    /// string, this keeps the [`TransportError`] taxonomy inspectable.
    last_errors: Vec<TransportError>,
}

impl ShardedOptimizer {
    /// Partition `groups` onto `n_shards` in-process workers with default
    /// bucketing and no per-shard state budget.
    pub fn new(
        kind: OptimizerKind,
        groups: &[GroupSpec],
        hyper: &Hyper,
        n_shards: usize,
    ) -> Result<ShardedOptimizer> {
        Self::with_options(kind, groups, hyper, n_shards, None, DEFAULT_MIN_BUCKET_NUMEL)
    }

    /// Full-control constructor: optional per-shard optimizer-state budget
    /// (scalars) and the bucket fuse threshold (elements).
    pub fn with_options(
        kind: OptimizerKind,
        groups: &[GroupSpec],
        hyper: &Hyper,
        n_shards: usize,
        max_state_per_shard: Option<usize>,
        min_bucket_numel: usize,
    ) -> Result<ShardedOptimizer> {
        Self::with_transport(
            kind,
            groups,
            hyper,
            n_shards,
            max_state_per_shard,
            min_bucket_numel,
            Arc::new(InProcess),
        )
    }

    /// Uniform engine over an explicit transport.
    pub fn with_transport(
        kind: OptimizerKind,
        groups: &[GroupSpec],
        hyper: &Hyper,
        n_shards: usize,
        max_state_per_shard: Option<usize>,
        min_bucket_numel: usize,
        transport: Arc<dyn ShardTransport>,
    ) -> Result<ShardedOptimizer> {
        Self::build_engine(
            SpecSource::Uniform { kind },
            groups,
            hyper,
            n_shards,
            max_state_per_shard,
            min_bucket_numel,
            transport,
        )
    }

    /// Plan-driven constructor: each worker executes its groups' chosen
    /// `(ET level, backend)` configs from a [`crate::budget::StatePlan`],
    /// and placement is costed from the plan's per-group bytes
    /// ([`super::partition_planned`]) instead of assuming a uniform
    /// backend. `hyper.backend` is ignored — storage follows the plan.
    pub fn with_state_plan(
        groups: &[GroupSpec],
        hyper: &Hyper,
        n_shards: usize,
        state_plan: &StatePlan,
    ) -> Result<ShardedOptimizer> {
        Self::with_state_plan_transport(groups, hyper, n_shards, state_plan, Arc::new(InProcess))
    }

    /// Plan-driven engine over an explicit transport.
    pub fn with_state_plan_transport(
        groups: &[GroupSpec],
        hyper: &Hyper,
        n_shards: usize,
        state_plan: &StatePlan,
        transport: Arc<dyn ShardTransport>,
    ) -> Result<ShardedOptimizer> {
        Self::build_engine(
            SpecSource::Planned { plan: state_plan.clone() },
            groups,
            hyper,
            n_shards,
            None,
            DEFAULT_MIN_BUCKET_NUMEL,
            transport,
        )
    }

    /// Shared constructor body: partition, connect one worker per shard
    /// (each building its own optimizer from an owned [`WorkerSpec`] —
    /// state allocation stays concurrent and worker-local), then run the
    /// deterministic startup reduction in shard order.
    fn build_engine(
        source: SpecSource,
        groups: &[GroupSpec],
        hyper: &Hyper,
        n_shards: usize,
        max_state_per_shard: Option<usize>,
        min_bucket_numel: usize,
        transport: Arc<dyn ShardTransport>,
    ) -> Result<ShardedOptimizer> {
        let (kind, label, plan) = match &source {
            SpecSource::Uniform { kind } => {
                let plan = partition(*kind, groups, n_shards, max_state_per_shard)?;
                (*kind, kind.name(), plan)
            }
            SpecSource::Planned { plan: state_plan } => {
                // Validate the plan (metadata only, no allocation) before
                // any worker exists — per-shard worker builds cannot fail
                // after this.
                crate::budget::validate_plan(groups, state_plan)?;
                let plan = partition_planned(state_plan, groups, n_shards, None)?;
                // ET-family kind tag: the same convention custom-dims ET
                // and the plan rule use (exports/imports round-trip
                // within it).
                (OptimizerKind::Et(1), "ET-plan".to_string(), plan)
            }
        };
        let n_shards = plan.n_shards();
        let mut local = vec![(0usize, 0usize); groups.len()];
        for (s, owned) in plan.shards.iter().enumerate() {
            for (li, &gi) in owned.iter().enumerate() {
                local[gi] = (s, li);
            }
        }
        let buckets: Vec<Vec<Bucket>> = plan
            .shards
            .iter()
            .map(|owned| bucketize(owned, groups, min_bucket_numel.max(1)))
            .collect();

        let mut conns: Vec<Box<dyn ShardConnection>> = Vec::with_capacity(n_shards);
        for s in 0..n_shards {
            // Queue capacity covers a full step's buckets plus control
            // messages, so fan-out never blocks on a slow sibling shard.
            let cap = buckets[s].len().max(1) + 2;
            let shard_groups: Vec<GroupSpec> =
                plan.shards[s].iter().map(|&gi| groups[gi].clone()).collect();
            let spec = match &source {
                SpecSource::Uniform { kind } => WorkerSpec::Uniform {
                    kind: *kind,
                    groups: shard_groups,
                    hyper: hyper.clone(),
                },
                SpecSource::Planned { plan: state_plan } => {
                    // Slice the plan down to this shard's owned groups, in
                    // worker-local order.
                    let sub = StatePlan {
                        budget_bytes: None,
                        per_group: plan.shards[s]
                            .iter()
                            .map(|&gi| state_plan.per_group[gi].clone())
                            .collect(),
                    };
                    WorkerSpec::Planned { groups: shard_groups, plan: sub, hyper: hyper.clone() }
                }
            };
            conns.push(
                transport
                    .connect(s, spec, cap)
                    .map_err(|e| anyhow::anyhow!("shard {s}: worker launch failed: {e}"))?,
            );
        }

        let mut engine = ShardedOptimizer {
            kind,
            label,
            plan,
            buckets,
            local,
            group_numels: groups.iter().map(|g| g.numel()).collect(),
            group_names: groups.iter().map(|g| g.name.clone()).collect(),
            conns,
            total_state_scalars: 0,
            total_state_bytes: 0,
            groups: groups.to_vec(),
            hyper: hyper.clone(),
            source,
            max_state_per_shard,
            min_bucket_numel,
            transport,
            last_snapshot: None,
            last_errors: Vec::new(),
        };
        // Deterministic startup reduction: query workers in shard order.
        // The first query is also the readiness check — a worker whose
        // optimizer build failed reports here as a dead connection.
        let (mut scalars, mut bytes) = (0usize, 0usize);
        for s in 0..n_shards {
            let (sc, by) = engine.conns[s]
                .state_scalars()
                .map_err(|e| anyhow::anyhow!("shard {s}: worker failed at startup: {e}"))?;
            scalars += sc;
            bytes += by;
        }
        engine.total_state_scalars = scalars;
        engine.total_state_bytes = bytes;
        Ok(engine)
    }

    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    pub fn n_shards(&self) -> usize {
        self.plan.n_shards()
    }

    /// Largest optimizer state held by any single worker, in scalars.
    pub fn peak_state_scalars(&self) -> usize {
        self.plan.peak_state_scalars()
    }

    /// The transport label this engine's workers run over.
    pub fn transport_name(&self) -> &'static str {
        self.transport.name()
    }

    /// Fan in every worker's shard-local state snapshot and merge them
    /// into one global [`StateExport`] with groups in *global* group order
    /// — independent of the shard count, so the result can be restored
    /// into an engine with any other shard count (or into a plain
    /// single-threaded [`crate::optim::StateOptimizer`]).
    pub fn export_state(&mut self) -> Result<StateExport> {
        self.last_errors.clear();
        let n_shards = self.n_shards();
        let mut per_shard: Vec<StateExport> = Vec::with_capacity(n_shards);
        for s in 0..n_shards {
            match self.conns[s].export_state() {
                Ok(export) => per_shard.push(export),
                Err(e) => {
                    let msg = format!("state export failed: {e}");
                    self.last_errors.push(e);
                    bail!("{msg}");
                }
            }
        }
        let step = per_shard.first().map(|e| e.step).unwrap_or(0);
        let mut groups: Vec<Option<GroupExport>> = vec![None; self.group_numels.len()];
        for (s, export) in per_shard.into_iter().enumerate() {
            anyhow::ensure!(
                export.groups.len() == self.plan.shards[s].len(),
                "shard {s}: exported {} groups, owns {}",
                export.groups.len(),
                self.plan.shards[s].len()
            );
            anyhow::ensure!(
                export.step == step,
                "shard {s}: step {} diverged from {}",
                export.step,
                step
            );
            for (li, ge) in export.groups.into_iter().enumerate() {
                let gi = self.plan.shards[s][li];
                groups[gi] = Some(ge);
            }
        }
        let groups = groups
            .into_iter()
            .enumerate()
            .map(|(gi, g)| g.with_context(|| format!("group {gi} missing from every shard")))
            .collect::<Result<Vec<_>>>()?;
        Ok(StateExport { kind: self.kind, step, groups })
    }

    /// Fan a global state snapshot (as produced by
    /// [`ShardedOptimizer::export_state`] or
    /// [`crate::optim::StateOptimizer::export`]) back out to the workers,
    /// splitting it by each shard's owned groups.
    pub fn import_state(&mut self, export: &StateExport) -> Result<()> {
        anyhow::ensure!(
            export.kind == self.kind,
            "state import: kind {:?} does not match {:?}",
            export.kind,
            self.kind
        );
        anyhow::ensure!(
            export.groups.len() == self.group_names.len(),
            "state import: {} groups, engine has {}",
            export.groups.len(),
            self.group_names.len()
        );
        for (ge, name) in export.groups.iter().zip(&self.group_names) {
            anyhow::ensure!(
                &ge.name == name,
                "state import: group '{}' does not match '{}'",
                ge.name,
                name
            );
        }
        let n_shards = self.n_shards();
        self.last_errors.clear();
        let mut errs: Vec<String> = Vec::new();
        for s in 0..n_shards {
            let shard_export = StateExport {
                kind: export.kind,
                step: export.step,
                groups: self.plan.shards[s]
                    .iter()
                    .map(|&gi| export.groups[gi].clone())
                    .collect(),
            };
            if let Err(e) = self.conns[s].import_state(shard_export) {
                errs.push(e.to_string());
                self.last_errors.push(e);
            }
        }
        if !errs.is_empty() {
            bail!("sharded state import failed: {}", errs.join("; "));
        }
        Ok(())
    }

    /// Record the engine's current optimizer state as the recovery point
    /// for [`ShardedOptimizer::recover`]. Returns the snapshot's step
    /// counter. Call at a step boundary (after `step_all`, before the next
    /// `next_step`).
    pub fn take_snapshot(&mut self) -> Result<u64> {
        let snapshot = self.export_state()?;
        let step = snapshot.step;
        self.last_snapshot = Some(snapshot);
        Ok(step)
    }

    /// The step counter of the held recovery snapshot, if any.
    pub fn snapshot_step(&self) -> Option<u64> {
        self.last_snapshot.as_ref().map(|s| s.step)
    }

    /// Typed [`TransportError`]s from the most recent failed
    /// `step`/`step_all`/`export_state`/`import_state`. Empty after a
    /// successful operation, or when the failure was a caller-side
    /// validation error rather than a transport fault. This is what the
    /// supervision layer classifies to decide between retry, recovery,
    /// and giving up.
    pub fn last_errors(&self) -> &[TransportError] {
        &self.last_errors
    }

    /// Change the worker-set size at a step boundary without a restart:
    /// export the (shard-count-independent) global state, rebuild the
    /// engine at `n_shards` over the same transport, and import the state
    /// back. The trajectory continues bitwise-identically to an engine
    /// that ran at a fixed shard count throughout.
    pub fn reshard(&mut self, n_shards: usize) -> Result<()> {
        anyhow::ensure!(n_shards >= 1, "reshard: need at least one shard");
        let snapshot = self.export_state().context("reshard: exporting state")?;
        let mut fresh = Self::build_engine(
            self.source.clone(),
            &self.groups,
            &self.hyper,
            n_shards,
            self.max_state_per_shard,
            self.min_bucket_numel,
            Arc::clone(&self.transport),
        )
        .with_context(|| format!("reshard: rebuilding at {n_shards} shards"))?;
        fresh.import_state(&snapshot).context("reshard: importing state")?;
        fresh.last_snapshot = self.last_snapshot.take();
        // Old connections shut their workers down on drop.
        *self = fresh;
        Ok(())
    }

    /// Crash recovery: rebuild the engine over however many connections
    /// are still alive and restore the last [`take_snapshot`] state.
    /// With *every* worker dead the engine degrades to a single fresh
    /// worker rather than giving up — state lives in the snapshot, not
    /// the workers, so one replacement is always enough to continue.
    /// Returns the snapshot's step counter; the caller rewinds its
    /// parameters to that step (from its own copy — parameters live with
    /// the caller, not the workers) and replays forward.
    ///
    /// [`take_snapshot`]: ShardedOptimizer::take_snapshot
    pub fn recover(&mut self) -> Result<u64> {
        let survivors = self.conns.iter().filter(|c| c.is_alive()).count().max(1);
        anyhow::ensure!(
            self.last_snapshot.is_some(),
            "recover: no snapshot held (call take_snapshot at a step boundary)"
        );
        // Build the replacement engine *before* taking the snapshot out, so
        // a failure here (or below) leaves the snapshot held and a later
        // recover() can try again — recovery must itself be recoverable.
        let mut fresh = Self::build_engine(
            self.source.clone(),
            &self.groups,
            &self.hyper,
            survivors,
            self.max_state_per_shard,
            self.min_bucket_numel,
            Arc::clone(&self.transport),
        )
        .with_context(|| format!("recover: rebuilding at {survivors} shards"))?;
        let snapshot = match self.last_snapshot.take() {
            Some(s) => s,
            None => bail!("recover: no snapshot held"),
        };
        let step = snapshot.step;
        if let Err(e) = fresh.import_state(&snapshot) {
            self.last_errors = std::mem::take(&mut fresh.last_errors);
            self.last_snapshot = Some(snapshot);
            return Err(e.context("recover: importing snapshot"));
        }
        fresh.last_snapshot = Some(snapshot);
        *self = fresh;
        Ok(step)
    }
}

impl Optimizer for ShardedOptimizer {
    /// Single-group step, routed synchronously to the owning worker. This
    /// is the trait-compat path (drivers that update groups one at a
    /// time); the throughput path is [`Optimizer::step_all`].
    fn step(&mut self, gi: usize, x: &mut [f32], g: &[f32], lr: f32) -> Result<()> {
        anyhow::ensure!(gi < self.group_numels.len(), "no group {gi}");
        anyhow::ensure!(
            x.len() == self.group_numels[gi] && g.len() == self.group_numels[gi],
            "group {gi}: buffer length mismatch"
        );
        let (s, li) = self.local[gi];
        let task = GroupTask {
            local_gi: li,
            x: x.as_mut_ptr(),
            x_len: x.len(),
            g: g.as_ptr(),
            g_len: g.len(),
        };
        self.last_errors.clear();
        if let Err(e) = self.conns[s].send_step(lr, vec![task]) {
            let msg = e.to_string();
            self.last_errors.push(e);
            bail!("{msg}");
        }
        if let Err(e) = self.conns[s].recv_step_ack() {
            let msg = e.to_string();
            self.last_errors.push(e);
            bail!("{msg}");
        }
        Ok(())
    }

    /// One full optimizer step over every group: fan buckets out to the
    /// shard workers, then block until each bucket is acknowledged.
    ///
    /// The fan-in is a pure ack barrier — each group's update is computed
    /// entirely by its owning worker — so the result is independent of
    /// shard completion order and bitwise-equal to the single-threaded
    /// engine. The barrier is also the safety contract for the raw slice
    /// handoff (see [`crate::transport::GroupTask`]): `params`/`grads`
    /// stay borrowed until every worker is done with them.
    fn step_all(&mut self, params: &mut [Vec<f32>], grads: &[Vec<f32>], lr: f32) -> Result<()> {
        let _step_span = crate::trace::span(
            crate::trace::SpanKind::StepAll,
            crate::trace::NO_SHARD,
            crate::trace::NO_JOB,
        );
        let n = self.group_numels.len();
        anyhow::ensure!(
            params.len() == n && grads.len() == n,
            "step_all: expected {n} groups, got {} params / {} grads",
            params.len(),
            grads.len()
        );
        for gi in 0..n {
            anyhow::ensure!(
                params[gi].len() == self.group_numels[gi]
                    && grads[gi].len() == self.group_numels[gi],
                "step_all: group {gi} buffer length mismatch"
            );
        }
        // Derive every slice pointer up front — one reborrow per group —
        // and do not touch `params`/`grads` again until all acks are in.
        let xs: Vec<(*mut f32, usize)> =
            params.iter_mut().map(|p| (p.as_mut_ptr(), p.len())).collect();
        let gs: Vec<(*const f32, usize)> =
            grads.iter().map(|g| (g.as_ptr(), g.len())).collect();
        let n_shards = self.n_shards();
        let mut pending = vec![0usize; n_shards];
        let mut errs: Vec<String> = Vec::new();
        self.last_errors.clear();
        for s in 0..n_shards {
            let _sp = crate::trace::span(
                crate::trace::SpanKind::Dispatch,
                s as u32,
                crate::trace::NO_JOB,
            );
            for bucket in &self.buckets[s] {
                let mut tasks = Vec::with_capacity(bucket.groups.len());
                for &gi in &bucket.groups {
                    let (_, li) = self.local[gi];
                    let (x, x_len) = xs[gi];
                    let (g, g_len) = gs[gi];
                    tasks.push(GroupTask { local_gi: li, x, x_len, g, g_len });
                }
                if let Err(e) = self.conns[s].send_step(lr, tasks) {
                    errs.push(e.to_string());
                    self.last_errors.push(e);
                    break;
                }
                pending[s] += 1;
            }
        }
        // Fan-in: drain *every* dispatched ack before returning, even on
        // error — returning early would let borrowed pointers outlive the
        // call while workers still hold them. (A fatal transport error
        // closes the connection, which guarantees the worker side will
        // never touch the remaining queued tasks; only then may the drain
        // stop early.)
        for s in 0..n_shards {
            let _sp = crate::trace::span(
                crate::trace::SpanKind::AckBarrier,
                s as u32,
                crate::trace::NO_JOB,
            );
            for _ in 0..pending[s] {
                match self.conns[s].recv_step_ack() {
                    Ok(()) => {}
                    Err(e) => {
                        let fatal = e.is_fatal();
                        errs.push(e.to_string());
                        self.last_errors.push(e);
                        if fatal {
                            break;
                        }
                    }
                }
            }
        }
        if !errs.is_empty() {
            bail!("sharded step failed: {}", errs.join("; "));
        }
        Ok(())
    }

    fn state_scalars(&self) -> usize {
        self.total_state_scalars
    }

    fn state_bytes(&self) -> usize {
        self.total_state_bytes
    }

    fn kind(&self) -> OptimizerKind {
        self.kind
    }

    fn name(&self) -> String {
        format!("{}/{}sh", self.label, self.n_shards())
    }

    fn next_step(&mut self) {
        // Ordered before any later Step by each connection's serial
        // request stream; no ack needed.
        for conn in &mut self.conns {
            let _ = conn.next_step();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim;
    use crate::util::rng::Pcg64;

    fn groups() -> Vec<GroupSpec> {
        vec![
            GroupSpec::new("w", &[16, 32]),
            GroupSpec::new("b", &[32]),
            GroupSpec::new("v", &[8, 4, 3, 3]),
            GroupSpec::new("ln", &[16]),
        ]
    }

    fn grads(gs: &[GroupSpec], seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg64::seeded(seed);
        gs.iter()
            .map(|g| {
                let mut v = vec![0.0f32; g.numel()];
                rng.fill_normal(&mut v, 1.0);
                v
            })
            .collect()
    }

    #[test]
    fn step_all_matches_single_threaded() {
        let gs = groups();
        let gr = grads(&gs, 3);
        let hyper = Hyper::default();

        let mut single = optim::build(OptimizerKind::Et(2), &gs, &hyper);
        let mut want: Vec<Vec<f32>> = gs.iter().map(|g| vec![0.3f32; g.numel()]).collect();
        for _ in 0..4 {
            single.next_step();
            for (gi, (p, g)) in want.iter_mut().zip(&gr).enumerate() {
                single.step(gi, p, g, 0.1).unwrap();
            }
        }

        let mut sharded = ShardedOptimizer::new(OptimizerKind::Et(2), &gs, &hyper, 2).unwrap();
        let mut got: Vec<Vec<f32>> = gs.iter().map(|g| vec![0.3f32; g.numel()]).collect();
        for _ in 0..4 {
            sharded.next_step();
            sharded.step_all(&mut got, &gr, 0.1).unwrap();
        }
        assert_eq!(want, got);
        assert_eq!(sharded.state_scalars(), single.state_scalars());
        assert_eq!(sharded.state_bytes(), single.state_bytes());
    }

    #[test]
    fn trait_step_routes_to_owner() {
        let gs = groups();
        let gr = grads(&gs, 5);
        let hyper = Hyper::default();
        let mut single = optim::build(OptimizerKind::AdaGrad, &gs, &hyper);
        let mut sharded =
            ShardedOptimizer::new(OptimizerKind::AdaGrad, &gs, &hyper, 3).unwrap();
        for gi in 0..gs.len() {
            let mut a = vec![0.5f32; gs[gi].numel()];
            let mut b = a.clone();
            single.step(gi, &mut a, &gr[gi], 0.2).unwrap();
            sharded.step(gi, &mut b, &gr[gi], 0.2).unwrap();
            assert_eq!(a, b, "group {gi}");
        }
    }

    #[test]
    fn more_shards_than_groups_still_correct() {
        let gs = groups();
        let gr = grads(&gs, 7);
        let hyper = Hyper::default();
        let mut single = optim::build(OptimizerKind::EtInf, &gs, &hyper);
        let mut want: Vec<Vec<f32>> = gs.iter().map(|g| vec![1.0f32; g.numel()]).collect();
        for (gi, (p, g)) in want.iter_mut().zip(&gr).enumerate() {
            single.step(gi, p, g, 0.5).unwrap();
        }
        let mut sharded = ShardedOptimizer::new(OptimizerKind::EtInf, &gs, &hyper, 9).unwrap();
        let mut got: Vec<Vec<f32>> = gs.iter().map(|g| vec![1.0f32; g.numel()]).collect();
        sharded.step_all(&mut got, &gr, 0.5).unwrap();
        assert_eq!(want, got);
    }

    #[test]
    fn rejects_wrong_buffer_shapes() {
        let gs = groups();
        let hyper = Hyper::default();
        let mut sharded = ShardedOptimizer::new(OptimizerKind::Sgd, &gs, &hyper, 2).unwrap();
        let mut params: Vec<Vec<f32>> = gs.iter().map(|g| vec![0.0f32; g.numel()]).collect();
        let bad: Vec<Vec<f32>> = gs.iter().map(|_| vec![0.0f32; 3]).collect();
        assert!(sharded.step_all(&mut params, &bad, 0.1).is_err());
        let short = vec![vec![0.0f32; 4]];
        assert!(sharded.step_all(&mut params, &short, 0.1).is_err());
    }

    #[test]
    fn coarse_and_fine_bucketing_agree() {
        let gs = groups();
        let gr = grads(&gs, 11);
        let hyper = Hyper::default();
        let run = |min_bucket: usize| -> Vec<Vec<f32>> {
            let mut opt = ShardedOptimizer::with_options(
                OptimizerKind::Adam,
                &gs,
                &hyper,
                2,
                None,
                min_bucket,
            )
            .unwrap();
            let mut p: Vec<Vec<f32>> = gs.iter().map(|g| vec![0.2f32; g.numel()]).collect();
            for _ in 0..3 {
                opt.next_step();
                opt.step_all(&mut p, &gr, 0.05).unwrap();
            }
            p
        };
        assert_eq!(run(1), run(usize::MAX));
    }

    /// Exported state is in global group order regardless of shard count,
    /// and matches the single-threaded optimizer's export exactly.
    #[test]
    fn export_is_shard_count_independent() {
        let gs = groups();
        let gr = grads(&gs, 17);
        let hyper = Hyper::default();

        let mut single = optim::build_state(OptimizerKind::Adam, &gs, &hyper);
        let mut p: Vec<Vec<f32>> = gs.iter().map(|g| vec![0.1f32; g.numel()]).collect();
        for _ in 0..3 {
            single.next_step();
            single.step_all(&mut p, &gr, 0.05).unwrap();
        }
        let want = single.export();

        for shards in [1usize, 2, 4] {
            let mut sharded =
                ShardedOptimizer::new(OptimizerKind::Adam, &gs, &hyper, shards).unwrap();
            let mut p: Vec<Vec<f32>> = gs.iter().map(|g| vec![0.1f32; g.numel()]).collect();
            for _ in 0..3 {
                sharded.next_step();
                sharded.step_all(&mut p, &gr, 0.05).unwrap();
            }
            assert_eq!(sharded.export_state().unwrap(), want, "{shards} shards");
        }
    }

    /// Import fans a global snapshot out to the workers: a fresh engine
    /// (any shard count) restored from an export continues bitwise like
    /// the donor engine.
    #[test]
    fn import_restores_across_shard_counts() {
        let gs = groups();
        let gr = grads(&gs, 23);
        let hyper = Hyper::default();

        let mut donor = ShardedOptimizer::new(OptimizerKind::Et(3), &gs, &hyper, 2).unwrap();
        let mut want: Vec<Vec<f32>> = gs.iter().map(|g| vec![0.2f32; g.numel()]).collect();
        for _ in 0..3 {
            donor.next_step();
            donor.step_all(&mut want, &gr, 0.1).unwrap();
        }
        let snapshot = donor.export_state().unwrap();
        // Continue the donor two more steps as the reference trajectory.
        for _ in 0..2 {
            donor.next_step();
            donor.step_all(&mut want, &gr, 0.1).unwrap();
        }

        for shards in [1usize, 4] {
            let mut fresh =
                ShardedOptimizer::new(OptimizerKind::Et(3), &gs, &hyper, shards).unwrap();
            fresh.import_state(&snapshot).unwrap();
            let mut got: Vec<Vec<f32>> = gs.iter().map(|g| vec![0.2f32; g.numel()]).collect();
            // Replay the first three steps' parameter effects: the restored
            // engine only holds optimizer state, so start params must match
            // the donor's at snapshot time. Rebuild them by replaying with
            // a scratch engine.
            let mut scratch =
                ShardedOptimizer::new(OptimizerKind::Et(3), &gs, &hyper, shards).unwrap();
            for _ in 0..3 {
                scratch.next_step();
                scratch.step_all(&mut got, &gr, 0.1).unwrap();
            }
            for _ in 0..2 {
                fresh.next_step();
                fresh.step_all(&mut got, &gr, 0.1).unwrap();
            }
            assert_eq!(want, got, "{shards} shards");
        }
    }

    /// Plan-driven sharding is bitwise-identical to the single-threaded
    /// planned optimizer at any shard count — the same contract the uniform
    /// engine carries in `rust/tests/sharded_parity.rs`.
    #[test]
    fn planned_sharding_matches_single_threaded_plan() {
        use crate::budget::{build_planned, plan as budget_plan, PlannerOptions};
        let gs = groups();
        let gr = grads(&gs, 31);
        let hyper = Hyper::default();
        let sp = budget_plan(&gs, 2048, &PlannerOptions::default()).unwrap();

        let mut single = build_planned(&gs, &sp, &hyper).unwrap();
        let mut want: Vec<Vec<f32>> = gs.iter().map(|g| vec![0.3f32; g.numel()]).collect();
        for _ in 0..4 {
            single.next_step();
            single.step_all(&mut want, &gr, 0.1).unwrap();
        }

        for shards in [1usize, 2, 4] {
            let mut sharded =
                ShardedOptimizer::with_state_plan(&gs, &hyper, shards, &sp).unwrap();
            let mut got: Vec<Vec<f32>> = gs.iter().map(|g| vec![0.3f32; g.numel()]).collect();
            for _ in 0..4 {
                sharded.next_step();
                sharded.step_all(&mut got, &gr, 0.1).unwrap();
            }
            assert_eq!(want, got, "{shards} shards");
            assert_eq!(sharded.state_bytes(), sp.total_bytes(), "{shards} shards");
            assert!(sharded.name().contains("ET-plan"));
        }
    }

    #[test]
    fn import_rejects_wrong_shape() {
        let gs = groups();
        let hyper = Hyper::default();
        let mut engine = ShardedOptimizer::new(OptimizerKind::Adam, &gs, &hyper, 2).unwrap();
        let other = optim::build_state(OptimizerKind::AdaGrad, &gs, &hyper);
        assert!(engine.import_state(&other.export()).is_err(), "kind mismatch must fail");
        let fewer: Vec<GroupSpec> = gs[..2].to_vec();
        let small = optim::build_state(OptimizerKind::Adam, &fewer, &hyper);
        assert!(engine.import_state(&small.export()).is_err(), "group count must fail");
    }

    /// Elastic resharding mid-run (grow and shrink) continues the
    /// trajectory bitwise-identically to a fixed-shard engine.
    #[test]
    fn reshard_mid_run_is_bitwise_transparent() {
        let gs = groups();
        let gr = grads(&gs, 41);
        let hyper = Hyper::default();

        let mut fixed = ShardedOptimizer::new(OptimizerKind::Adam, &gs, &hyper, 2).unwrap();
        let mut want: Vec<Vec<f32>> = gs.iter().map(|g| vec![0.2f32; g.numel()]).collect();
        for _ in 0..6 {
            fixed.next_step();
            fixed.step_all(&mut want, &gr, 0.1).unwrap();
        }

        let mut elastic = ShardedOptimizer::new(OptimizerKind::Adam, &gs, &hyper, 2).unwrap();
        let mut got: Vec<Vec<f32>> = gs.iter().map(|g| vec![0.2f32; g.numel()]).collect();
        for step in 0..6 {
            if step == 2 {
                elastic.reshard(4).unwrap();
                assert_eq!(elastic.n_shards(), 4);
            }
            if step == 4 {
                elastic.reshard(1).unwrap();
                assert_eq!(elastic.n_shards(), 1);
            }
            elastic.next_step();
            elastic.step_all(&mut got, &gr, 0.1).unwrap();
        }
        assert_eq!(want, got);
    }

    /// take_snapshot + recover restores the optimizer state held at the
    /// snapshot step (in-process workers don't die, so recovery rebuilds
    /// at the full connection count).
    #[test]
    fn snapshot_and_recover_replays_bitwise() {
        let gs = groups();
        let gr = grads(&gs, 47);
        let hyper = Hyper::default();

        let mut engine = ShardedOptimizer::new(OptimizerKind::Et(2), &gs, &hyper, 2).unwrap();
        let mut params: Vec<Vec<f32>> = gs.iter().map(|g| vec![0.3f32; g.numel()]).collect();
        for _ in 0..3 {
            engine.next_step();
            engine.step_all(&mut params, &gr, 0.1).unwrap();
        }
        let step = engine.take_snapshot().unwrap();
        assert_eq!(engine.snapshot_step(), Some(step));
        let params_at_snapshot = params.clone();

        // Run two more steps to the reference end state.
        for _ in 0..2 {
            engine.next_step();
            engine.step_all(&mut params, &gr, 0.1).unwrap();
        }
        let want = params.clone();

        // "Crash": recover rewinds optimizer state to the snapshot; the
        // caller rewinds params from its own copy and replays.
        let recovered_step = engine.recover().unwrap();
        assert_eq!(recovered_step, step);
        let mut replay = params_at_snapshot;
        for _ in 0..2 {
            engine.next_step();
            engine.step_all(&mut replay, &gr, 0.1).unwrap();
        }
        assert_eq!(want, replay);
    }

    #[test]
    fn recover_without_snapshot_fails_cleanly() {
        let gs = groups();
        let hyper = Hyper::default();
        let mut engine = ShardedOptimizer::new(OptimizerKind::Sgd, &gs, &hyper, 2).unwrap();
        assert!(engine.recover().is_err());
    }
}

//! The sharded optimizer engine: fan-out/fan-in over persistent workers.
//!
//! [`ShardedOptimizer`] implements the ordinary [`Optimizer`] trait, so it
//! drops into every call site the single-threaded suite serves; its
//! [`Optimizer::step_all`] override is the hot path that updates *all*
//! groups in one fan-out. Work travels as [`Bucket`]s over bounded
//! channels; the call returns only after every bucket is acknowledged,
//! which is both the memory-safety barrier for the raw slice handoff and
//! the reason the reduction is trivially deterministic: each group is
//! computed by exactly one worker with exactly the single-threaded
//! per-group arithmetic, and no cross-shard arithmetic exists to reorder.
//! Sharded results are therefore bitwise-identical to the single-threaded
//! engine at any shard count (`rust/tests/sharded_parity.rs` checks every
//! optimizer kind).
//!
//! Because each worker owns an externalized [`crate::optim::OptState`],
//! shard-local state is no longer trapped on its thread:
//! [`ShardedOptimizer::export_state`] fans in every worker's snapshot and
//! merges them into one global, shard-count-independent [`StateExport`]
//! (groups in global order), and [`ShardedOptimizer::import_state`] fans a
//! global snapshot back out — so a checkpoint taken at 2 shards restores
//! at 1 or 4 bitwise-identically (`rust/tests/host_checkpoint.rs`).

use super::bucket::{bucketize, Bucket, DEFAULT_MIN_BUCKET_NUMEL};
use super::partition::{partition, partition_planned, ShardPlan};
use super::worker::{run_worker, GroupTask, Reply, Request, WorkerSpec};
use crate::budget::StatePlan;
use crate::optim::{GroupExport, GroupSpec, Hyper, Optimizer, StateExport};
use crate::tensoring::OptimizerKind;
use anyhow::{bail, Context, Result};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::thread::JoinHandle;

pub struct ShardedOptimizer {
    kind: OptimizerKind,
    /// Display label: the uniform kind's name, or "ET-plan" for
    /// plan-driven engines.
    label: String,
    plan: ShardPlan,
    /// Per-shard dispatch units over that shard's owned groups.
    buckets: Vec<Vec<Bucket>>,
    /// group index -> (owning shard, index into the shard-local optimizer).
    local: Vec<(usize, usize)>,
    group_numels: Vec<usize>,
    /// Global group names, for validating state imports.
    group_names: Vec<String>,
    requests: Vec<SyncSender<Request>>,
    replies: Vec<Receiver<Reply>>,
    handles: Vec<Option<JoinHandle<()>>>,
    total_state_scalars: usize,
    total_state_bytes: usize,
}

impl ShardedOptimizer {
    /// Partition `groups` onto `n_shards` workers with default bucketing
    /// and no per-shard state budget.
    pub fn new(
        kind: OptimizerKind,
        groups: &[GroupSpec],
        hyper: &Hyper,
        n_shards: usize,
    ) -> Result<ShardedOptimizer> {
        Self::with_options(kind, groups, hyper, n_shards, None, DEFAULT_MIN_BUCKET_NUMEL)
    }

    /// Full-control constructor: optional per-shard optimizer-state budget
    /// (scalars) and the bucket fuse threshold (elements).
    pub fn with_options(
        kind: OptimizerKind,
        groups: &[GroupSpec],
        hyper: &Hyper,
        n_shards: usize,
        max_state_per_shard: Option<usize>,
        min_bucket_numel: usize,
    ) -> Result<ShardedOptimizer> {
        let plan = partition(kind, groups, n_shards, max_state_per_shard)?;
        Self::from_parts(kind, kind.name(), groups, plan, min_bucket_numel, |_, shard_groups| {
            WorkerSpec::Uniform { kind, groups: shard_groups.to_vec(), hyper: hyper.clone() }
        })
    }

    /// Plan-driven constructor: each worker executes its groups' chosen
    /// `(ET level, backend)` configs from a [`crate::budget::StatePlan`],
    /// and placement is costed from the plan's per-group bytes
    /// ([`super::partition_planned`]) instead of assuming a uniform
    /// backend. `hyper.backend` is ignored — storage follows the plan.
    pub fn with_state_plan(
        groups: &[GroupSpec],
        hyper: &Hyper,
        n_shards: usize,
        state_plan: &StatePlan,
    ) -> Result<ShardedOptimizer> {
        // Validate the plan (metadata only, no allocation) in the caller's
        // thread, before any worker exists — per-shard worker builds cannot
        // fail after this.
        crate::budget::validate_plan(groups, state_plan)?;
        let plan = partition_planned(state_plan, groups, n_shards, None)?;
        let shards = plan.shards.clone();
        Self::from_parts(
            // ET-family kind tag: the same convention custom-dims ET and
            // the plan rule use (exports/imports round-trip within it).
            OptimizerKind::Et(1),
            "ET-plan".to_string(),
            groups,
            plan,
            DEFAULT_MIN_BUCKET_NUMEL,
            |s, shard_groups| {
                // Slice the plan down to this shard's owned groups, in
                // worker-local order.
                let sub = StatePlan {
                    budget_bytes: None,
                    per_group: shards[s]
                        .iter()
                        .map(|&gi| state_plan.per_group[gi].clone())
                        .collect(),
                };
                WorkerSpec::Planned {
                    groups: shard_groups.to_vec(),
                    plan: sub,
                    hyper: hyper.clone(),
                }
            },
        )
    }

    /// Shared constructor body: spawn one worker per shard, each building
    /// its own optimizer on-thread from `spec_for(shard, shard_groups)` —
    /// state allocation stays concurrent and thread-local, exactly as the
    /// pre-planner engine behaved.
    fn from_parts(
        kind: OptimizerKind,
        label: String,
        groups: &[GroupSpec],
        plan: ShardPlan,
        min_bucket_numel: usize,
        spec_for: impl Fn(usize, &[GroupSpec]) -> WorkerSpec,
    ) -> Result<ShardedOptimizer> {
        let n_shards = plan.n_shards();
        let mut local = vec![(0usize, 0usize); groups.len()];
        for (s, owned) in plan.shards.iter().enumerate() {
            for (li, &gi) in owned.iter().enumerate() {
                local[gi] = (s, li);
            }
        }
        let buckets: Vec<Vec<Bucket>> = plan
            .shards
            .iter()
            .map(|owned| bucketize(owned, groups, min_bucket_numel.max(1)))
            .collect();

        let mut requests = Vec::with_capacity(n_shards);
        let mut replies = Vec::with_capacity(n_shards);
        let mut handles = Vec::with_capacity(n_shards);
        for s in 0..n_shards {
            // Channel capacity covers a full step's buckets plus control
            // messages, so fan-out never blocks on a slow sibling shard.
            let cap = buckets[s].len().max(1) + 2;
            let (req_tx, req_rx) = sync_channel::<Request>(cap);
            let (rep_tx, rep_rx) = sync_channel::<Reply>(cap);
            let shard_groups: Vec<GroupSpec> =
                plan.shards[s].iter().map(|&gi| groups[gi].clone()).collect();
            let spec = spec_for(s, &shard_groups);
            let handle = std::thread::Builder::new()
                .name(format!("et-shard-{s}"))
                .spawn(move || run_worker(s, spec, req_rx, rep_tx))
                .context("spawn shard worker")?;
            requests.push(req_tx);
            replies.push(rep_rx);
            handles.push(Some(handle));
        }

        let mut engine = ShardedOptimizer {
            kind,
            label,
            plan,
            buckets,
            local,
            group_numels: groups.iter().map(|g| g.numel()).collect(),
            group_names: groups.iter().map(|g| g.name.clone()).collect(),
            requests,
            replies,
            handles,
            total_state_scalars: 0,
            total_state_bytes: 0,
        };
        // Deterministic startup reduction: query workers in shard order.
        let (mut scalars, mut bytes) = (0usize, 0usize);
        for s in 0..n_shards {
            engine.requests[s]
                .send(Request::StateScalars)
                .map_err(|_| anyhow::anyhow!("shard {s}: worker unavailable at startup"))?;
            match engine.replies[s].recv() {
                Ok(Reply::StateScalars { scalars: sc, bytes: by }) => {
                    scalars += sc;
                    bytes += by;
                }
                _ => bail!("shard {s}: worker failed at startup"),
            }
        }
        engine.total_state_scalars = scalars;
        engine.total_state_bytes = bytes;
        Ok(engine)
    }

    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    pub fn n_shards(&self) -> usize {
        self.plan.n_shards()
    }

    /// Largest optimizer state held by any single worker, in scalars.
    pub fn peak_state_scalars(&self) -> usize {
        self.plan.peak_state_scalars()
    }

    /// Fan in every worker's shard-local state snapshot and merge them
    /// into one global [`StateExport`] with groups in *global* group order
    /// — independent of the shard count, so the result can be restored
    /// into an engine with any other shard count (or into a plain
    /// single-threaded [`crate::optim::StateOptimizer`]).
    pub fn export_state(&mut self) -> Result<StateExport> {
        let n_shards = self.n_shards();
        let mut per_shard: Vec<StateExport> = Vec::with_capacity(n_shards);
        for s in 0..n_shards {
            if self.requests[s].send(Request::ExportState).is_err() {
                bail!("shard {s}: worker channel closed");
            }
            match self.replies[s].recv() {
                Ok(Reply::State(e)) => per_shard.push(*e),
                _ => bail!("shard {s}: worker died during state export"),
            }
        }
        let step = per_shard.first().map(|e| e.step).unwrap_or(0);
        let mut groups: Vec<Option<GroupExport>> = vec![None; self.group_numels.len()];
        for (s, export) in per_shard.into_iter().enumerate() {
            anyhow::ensure!(
                export.groups.len() == self.plan.shards[s].len(),
                "shard {s}: exported {} groups, owns {}",
                export.groups.len(),
                self.plan.shards[s].len()
            );
            anyhow::ensure!(
                export.step == step,
                "shard {s}: step {} diverged from {}",
                export.step,
                step
            );
            for (li, ge) in export.groups.into_iter().enumerate() {
                let gi = self.plan.shards[s][li];
                groups[gi] = Some(ge);
            }
        }
        let groups = groups
            .into_iter()
            .enumerate()
            .map(|(gi, g)| g.with_context(|| format!("group {gi} missing from every shard")))
            .collect::<Result<Vec<_>>>()?;
        Ok(StateExport { kind: self.kind, step, groups })
    }

    /// Fan a global state snapshot (as produced by
    /// [`ShardedOptimizer::export_state`] or
    /// [`crate::optim::StateOptimizer::export`]) back out to the workers,
    /// splitting it by each shard's owned groups.
    pub fn import_state(&mut self, export: &StateExport) -> Result<()> {
        anyhow::ensure!(
            export.kind == self.kind,
            "state import: kind {:?} does not match {:?}",
            export.kind,
            self.kind
        );
        anyhow::ensure!(
            export.groups.len() == self.group_names.len(),
            "state import: {} groups, engine has {}",
            export.groups.len(),
            self.group_names.len()
        );
        for (ge, name) in export.groups.iter().zip(&self.group_names) {
            anyhow::ensure!(
                &ge.name == name,
                "state import: group '{}' does not match '{}'",
                ge.name,
                name
            );
        }
        let n_shards = self.n_shards();
        // Fan out shard-local slices, then drain every ack (even on error —
        // a half-imported engine must still leave the channels clean).
        let mut pending = vec![false; n_shards];
        let mut errs: Vec<String> = Vec::new();
        for s in 0..n_shards {
            let shard_export = StateExport {
                kind: export.kind,
                step: export.step,
                groups: self.plan.shards[s]
                    .iter()
                    .map(|&gi| export.groups[gi].clone())
                    .collect(),
            };
            if self.requests[s].send(Request::ImportState(Box::new(shard_export))).is_err() {
                errs.push(format!("shard {s}: worker channel closed"));
                continue;
            }
            pending[s] = true;
        }
        for s in 0..n_shards {
            if !pending[s] {
                continue;
            }
            match self.replies[s].recv() {
                Ok(Reply::ImportDone(Ok(()))) => {}
                Ok(Reply::ImportDone(Err(e))) => errs.push(e),
                _ => errs.push(format!("shard {s}: worker died during state import")),
            }
        }
        if !errs.is_empty() {
            bail!("sharded state import failed: {}", errs.join("; "));
        }
        Ok(())
    }
}

impl Optimizer for ShardedOptimizer {
    /// Single-group step, routed synchronously to the owning worker. This
    /// is the trait-compat path (drivers that update groups one at a
    /// time); the throughput path is [`Optimizer::step_all`].
    fn step(&mut self, gi: usize, x: &mut [f32], g: &[f32], lr: f32) -> Result<()> {
        anyhow::ensure!(gi < self.group_numels.len(), "no group {gi}");
        anyhow::ensure!(
            x.len() == self.group_numels[gi] && g.len() == self.group_numels[gi],
            "group {gi}: buffer length mismatch"
        );
        let (s, li) = self.local[gi];
        let task = GroupTask {
            local_gi: li,
            x: x.as_mut_ptr(),
            x_len: x.len(),
            g: g.as_ptr(),
            g_len: g.len(),
        };
        if self.requests[s].send(Request::Step { lr, tasks: vec![task] }).is_err() {
            bail!("shard {s}: worker channel closed");
        }
        match self.replies[s].recv() {
            Ok(Reply::StepDone(Ok(()))) => Ok(()),
            Ok(Reply::StepDone(Err(e))) => bail!("{e}"),
            _ => bail!("shard {s}: worker died mid-step"),
        }
    }

    /// One full optimizer step over every group: fan buckets out to the
    /// shard workers, then block until each bucket is acknowledged.
    ///
    /// The fan-in is a pure ack barrier — each group's update is computed
    /// entirely by its owning worker — so the result is independent of
    /// shard completion order and bitwise-equal to the single-threaded
    /// engine. The barrier is also the safety contract for the raw slice
    /// handoff (see `shard::worker::GroupTask`): `params`/`grads` stay
    /// borrowed until every worker is done with them.
    fn step_all(&mut self, params: &mut [Vec<f32>], grads: &[Vec<f32>], lr: f32) -> Result<()> {
        let n = self.group_numels.len();
        anyhow::ensure!(
            params.len() == n && grads.len() == n,
            "step_all: expected {n} groups, got {} params / {} grads",
            params.len(),
            grads.len()
        );
        for gi in 0..n {
            anyhow::ensure!(
                params[gi].len() == self.group_numels[gi]
                    && grads[gi].len() == self.group_numels[gi],
                "step_all: group {gi} buffer length mismatch"
            );
        }
        // Derive every slice pointer up front — one reborrow per group —
        // and do not touch `params`/`grads` again until all acks are in.
        let xs: Vec<(*mut f32, usize)> =
            params.iter_mut().map(|p| (p.as_mut_ptr(), p.len())).collect();
        let gs: Vec<(*const f32, usize)> =
            grads.iter().map(|g| (g.as_ptr(), g.len())).collect();
        let n_shards = self.n_shards();
        let mut pending = vec![0usize; n_shards];
        let mut errs: Vec<String> = Vec::new();
        for s in 0..n_shards {
            for bucket in &self.buckets[s] {
                let mut tasks = Vec::with_capacity(bucket.groups.len());
                for &gi in &bucket.groups {
                    let (_, li) = self.local[gi];
                    let (x, x_len) = xs[gi];
                    let (g, g_len) = gs[gi];
                    tasks.push(GroupTask { local_gi: li, x, x_len, g, g_len });
                }
                if self.requests[s].send(Request::Step { lr, tasks }).is_err() {
                    errs.push(format!("shard {s}: worker channel closed"));
                    break;
                }
                pending[s] += 1;
            }
        }
        // Fan-in: drain *every* dispatched ack before returning, even on
        // error — returning early would let borrowed pointers outlive the
        // call while workers still hold them.
        for s in 0..n_shards {
            for _ in 0..pending[s] {
                match self.replies[s].recv() {
                    Ok(Reply::StepDone(Ok(()))) => {}
                    Ok(Reply::StepDone(Err(e))) => errs.push(e),
                    Ok(_) => errs.push(format!("shard {s}: protocol error")),
                    Err(_) => {
                        errs.push(format!("shard {s}: worker died mid-step"));
                        break;
                    }
                }
            }
        }
        if !errs.is_empty() {
            bail!("sharded step failed: {}", errs.join("; "));
        }
        Ok(())
    }

    fn state_scalars(&self) -> usize {
        self.total_state_scalars
    }

    fn state_bytes(&self) -> usize {
        self.total_state_bytes
    }

    fn kind(&self) -> OptimizerKind {
        self.kind
    }

    fn name(&self) -> String {
        format!("{}/{}sh", self.label, self.n_shards())
    }

    fn next_step(&mut self) {
        // Ordered before any later Step by each worker's request channel;
        // no ack needed.
        for tx in &self.requests {
            let _ = tx.send(Request::NextStep);
        }
    }
}

impl Drop for ShardedOptimizer {
    fn drop(&mut self) {
        for tx in &self.requests {
            let _ = tx.send(Request::Shutdown);
        }
        for h in self.handles.iter_mut() {
            if let Some(h) = h.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim;
    use crate::util::rng::Pcg64;

    fn groups() -> Vec<GroupSpec> {
        vec![
            GroupSpec::new("w", &[16, 32]),
            GroupSpec::new("b", &[32]),
            GroupSpec::new("v", &[8, 4, 3, 3]),
            GroupSpec::new("ln", &[16]),
        ]
    }

    fn grads(gs: &[GroupSpec], seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg64::seeded(seed);
        gs.iter()
            .map(|g| {
                let mut v = vec![0.0f32; g.numel()];
                rng.fill_normal(&mut v, 1.0);
                v
            })
            .collect()
    }

    #[test]
    fn step_all_matches_single_threaded() {
        let gs = groups();
        let gr = grads(&gs, 3);
        let hyper = Hyper::default();

        let mut single = optim::build(OptimizerKind::Et(2), &gs, &hyper);
        let mut want: Vec<Vec<f32>> = gs.iter().map(|g| vec![0.3f32; g.numel()]).collect();
        for _ in 0..4 {
            single.next_step();
            for (gi, (p, g)) in want.iter_mut().zip(&gr).enumerate() {
                single.step(gi, p, g, 0.1).unwrap();
            }
        }

        let mut sharded = ShardedOptimizer::new(OptimizerKind::Et(2), &gs, &hyper, 2).unwrap();
        let mut got: Vec<Vec<f32>> = gs.iter().map(|g| vec![0.3f32; g.numel()]).collect();
        for _ in 0..4 {
            sharded.next_step();
            sharded.step_all(&mut got, &gr, 0.1).unwrap();
        }
        assert_eq!(want, got);
        assert_eq!(sharded.state_scalars(), single.state_scalars());
        assert_eq!(sharded.state_bytes(), single.state_bytes());
    }

    #[test]
    fn trait_step_routes_to_owner() {
        let gs = groups();
        let gr = grads(&gs, 5);
        let hyper = Hyper::default();
        let mut single = optim::build(OptimizerKind::AdaGrad, &gs, &hyper);
        let mut sharded =
            ShardedOptimizer::new(OptimizerKind::AdaGrad, &gs, &hyper, 3).unwrap();
        for gi in 0..gs.len() {
            let mut a = vec![0.5f32; gs[gi].numel()];
            let mut b = a.clone();
            single.step(gi, &mut a, &gr[gi], 0.2).unwrap();
            sharded.step(gi, &mut b, &gr[gi], 0.2).unwrap();
            assert_eq!(a, b, "group {gi}");
        }
    }

    #[test]
    fn more_shards_than_groups_still_correct() {
        let gs = groups();
        let gr = grads(&gs, 7);
        let hyper = Hyper::default();
        let mut single = optim::build(OptimizerKind::EtInf, &gs, &hyper);
        let mut want: Vec<Vec<f32>> = gs.iter().map(|g| vec![1.0f32; g.numel()]).collect();
        for (gi, (p, g)) in want.iter_mut().zip(&gr).enumerate() {
            single.step(gi, p, g, 0.5).unwrap();
        }
        let mut sharded = ShardedOptimizer::new(OptimizerKind::EtInf, &gs, &hyper, 9).unwrap();
        let mut got: Vec<Vec<f32>> = gs.iter().map(|g| vec![1.0f32; g.numel()]).collect();
        sharded.step_all(&mut got, &gr, 0.5).unwrap();
        assert_eq!(want, got);
    }

    #[test]
    fn rejects_wrong_buffer_shapes() {
        let gs = groups();
        let hyper = Hyper::default();
        let mut sharded = ShardedOptimizer::new(OptimizerKind::Sgd, &gs, &hyper, 2).unwrap();
        let mut params: Vec<Vec<f32>> = gs.iter().map(|g| vec![0.0f32; g.numel()]).collect();
        let bad: Vec<Vec<f32>> = gs.iter().map(|_| vec![0.0f32; 3]).collect();
        assert!(sharded.step_all(&mut params, &bad, 0.1).is_err());
        let short = vec![vec![0.0f32; 4]];
        assert!(sharded.step_all(&mut params, &short, 0.1).is_err());
    }

    #[test]
    fn coarse_and_fine_bucketing_agree() {
        let gs = groups();
        let gr = grads(&gs, 11);
        let hyper = Hyper::default();
        let run = |min_bucket: usize| -> Vec<Vec<f32>> {
            let mut opt = ShardedOptimizer::with_options(
                OptimizerKind::Adam,
                &gs,
                &hyper,
                2,
                None,
                min_bucket,
            )
            .unwrap();
            let mut p: Vec<Vec<f32>> = gs.iter().map(|g| vec![0.2f32; g.numel()]).collect();
            for _ in 0..3 {
                opt.next_step();
                opt.step_all(&mut p, &gr, 0.05).unwrap();
            }
            p
        };
        assert_eq!(run(1), run(usize::MAX));
    }

    /// Exported state is in global group order regardless of shard count,
    /// and matches the single-threaded optimizer's export exactly.
    #[test]
    fn export_is_shard_count_independent() {
        let gs = groups();
        let gr = grads(&gs, 17);
        let hyper = Hyper::default();

        let mut single = optim::build_state(OptimizerKind::Adam, &gs, &hyper);
        let mut p: Vec<Vec<f32>> = gs.iter().map(|g| vec![0.1f32; g.numel()]).collect();
        for _ in 0..3 {
            single.next_step();
            single.step_all(&mut p, &gr, 0.05).unwrap();
        }
        let want = single.export();

        for shards in [1usize, 2, 4] {
            let mut sharded =
                ShardedOptimizer::new(OptimizerKind::Adam, &gs, &hyper, shards).unwrap();
            let mut p: Vec<Vec<f32>> = gs.iter().map(|g| vec![0.1f32; g.numel()]).collect();
            for _ in 0..3 {
                sharded.next_step();
                sharded.step_all(&mut p, &gr, 0.05).unwrap();
            }
            assert_eq!(sharded.export_state().unwrap(), want, "{shards} shards");
        }
    }

    /// Import fans a global snapshot out to the workers: a fresh engine
    /// (any shard count) restored from an export continues bitwise like
    /// the donor engine.
    #[test]
    fn import_restores_across_shard_counts() {
        let gs = groups();
        let gr = grads(&gs, 23);
        let hyper = Hyper::default();

        let mut donor = ShardedOptimizer::new(OptimizerKind::Et(3), &gs, &hyper, 2).unwrap();
        let mut want: Vec<Vec<f32>> = gs.iter().map(|g| vec![0.2f32; g.numel()]).collect();
        for _ in 0..3 {
            donor.next_step();
            donor.step_all(&mut want, &gr, 0.1).unwrap();
        }
        let snapshot = donor.export_state().unwrap();
        // Continue the donor two more steps as the reference trajectory.
        for _ in 0..2 {
            donor.next_step();
            donor.step_all(&mut want, &gr, 0.1).unwrap();
        }

        for shards in [1usize, 4] {
            let mut fresh =
                ShardedOptimizer::new(OptimizerKind::Et(3), &gs, &hyper, shards).unwrap();
            fresh.import_state(&snapshot).unwrap();
            let mut got: Vec<Vec<f32>> = gs.iter().map(|g| vec![0.2f32; g.numel()]).collect();
            // Replay the first three steps' parameter effects: the restored
            // engine only holds optimizer state, so start params must match
            // the donor's at snapshot time. Rebuild them by replaying with
            // a scratch engine.
            let mut scratch =
                ShardedOptimizer::new(OptimizerKind::Et(3), &gs, &hyper, shards).unwrap();
            for _ in 0..3 {
                scratch.next_step();
                scratch.step_all(&mut got, &gr, 0.1).unwrap();
            }
            for _ in 0..2 {
                fresh.next_step();
                fresh.step_all(&mut got, &gr, 0.1).unwrap();
            }
            assert_eq!(want, got, "{shards} shards");
        }
    }

    /// Plan-driven sharding is bitwise-identical to the single-threaded
    /// planned optimizer at any shard count — the same contract the uniform
    /// engine carries in `rust/tests/sharded_parity.rs`.
    #[test]
    fn planned_sharding_matches_single_threaded_plan() {
        use crate::budget::{build_planned, plan as budget_plan, PlannerOptions};
        let gs = groups();
        let gr = grads(&gs, 31);
        let hyper = Hyper::default();
        let sp = budget_plan(&gs, 2048, &PlannerOptions::default()).unwrap();

        let mut single = build_planned(&gs, &sp, &hyper).unwrap();
        let mut want: Vec<Vec<f32>> = gs.iter().map(|g| vec![0.3f32; g.numel()]).collect();
        for _ in 0..4 {
            single.next_step();
            single.step_all(&mut want, &gr, 0.1).unwrap();
        }

        for shards in [1usize, 2, 4] {
            let mut sharded =
                ShardedOptimizer::with_state_plan(&gs, &hyper, shards, &sp).unwrap();
            let mut got: Vec<Vec<f32>> = gs.iter().map(|g| vec![0.3f32; g.numel()]).collect();
            for _ in 0..4 {
                sharded.next_step();
                sharded.step_all(&mut got, &gr, 0.1).unwrap();
            }
            assert_eq!(want, got, "{shards} shards");
            assert_eq!(sharded.state_bytes(), sp.total_bytes(), "{shards} shards");
            assert!(sharded.name().contains("ET-plan"));
        }
    }

    #[test]
    fn import_rejects_wrong_shape() {
        let gs = groups();
        let hyper = Hyper::default();
        let mut engine = ShardedOptimizer::new(OptimizerKind::Adam, &gs, &hyper, 2).unwrap();
        let other = optim::build_state(OptimizerKind::AdaGrad, &gs, &hyper);
        assert!(engine.import_state(&other.export()).is_err(), "kind mismatch must fail");
        let fewer: Vec<GroupSpec> = gs[..2].to_vec();
        let small = optim::build_state(OptimizerKind::Adam, &fewer, &hyper);
        assert!(engine.import_state(&small.export()).is_err(), "group count must fail");
    }
}

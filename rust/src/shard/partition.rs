//! Memory-budget-aware partitioning of parameter groups onto shards.
//!
//! The paper's result is what makes sharding clean: extreme-tensored
//! preconditioner state is so small that a shard can own each of its
//! groups' *complete* slice accumulators and never communicate a
//! preconditioner entry. What still needs balancing is (a) per-step
//! *work*, which scales with the gradient elements a shard touches, and
//! (b) the optimizer-state *footprint*, which for the dense baselines
//! (AdaGrad, Adam) rivals the parameters themselves. Both costs come from
//! the existing accounting in [`crate::tensoring::memory`], so ET's
//! asymmetric profile (huge groups, near-zero state) drives placement —
//! not numel alone.
//!
//! The packer is greedy LPT (longest processing time first) with
//! deterministic tie-breaking, optionally constrained by a per-shard
//! optimizer-state budget in scalars.

use crate::optim::GroupSpec;
use crate::tensoring::memory::group_state_scalars;
use crate::tensoring::OptimizerKind;
use anyhow::{bail, Result};

/// Placement cost of one parameter group under a given optimizer.
#[derive(Clone, Copy, Debug)]
pub struct GroupCost {
    /// Optimizer-state scalars the owning shard must hold for this group.
    pub state_scalars: usize,
    /// Per-step work units: gradient elements read + parameters written.
    pub work: usize,
}

impl GroupCost {
    /// Combined load used for balance decisions.
    pub fn load(&self) -> usize {
        self.work + self.state_scalars
    }
}

/// Cost of `group` under `kind`, from the paper's memory model.
pub fn group_cost(kind: OptimizerKind, group: &GroupSpec) -> GroupCost {
    GroupCost {
        state_scalars: group_state_scalars(kind, &group.shape),
        work: group.numel(),
    }
}

/// The result of partitioning: which shard owns which groups.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    pub kind: OptimizerKind,
    /// group index -> owning shard.
    pub owner: Vec<usize>,
    /// shard -> owned group indices, ascending.
    pub shards: Vec<Vec<usize>>,
    /// Per-shard optimizer-state scalars.
    pub state_scalars: Vec<usize>,
    /// Per-shard work units.
    pub work: Vec<usize>,
}

impl ShardPlan {
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Largest per-shard optimizer state, in scalars — the quantity the
    /// scaling experiment reports (x4 for bytes).
    pub fn peak_state_scalars(&self) -> usize {
        self.state_scalars.iter().copied().max().unwrap_or(0)
    }

    pub fn total_state_scalars(&self) -> usize {
        self.state_scalars.iter().sum()
    }

    /// Physical optimizer-state bytes held by one shard under `backend`.
    /// `groups` must be the same list the plan was built from.
    pub fn shard_state_bytes(
        &self,
        shard: usize,
        groups: &[GroupSpec],
        backend: crate::tensoring::StateBackend,
    ) -> usize {
        self.shards[shard]
            .iter()
            .map(|&gi| crate::tensoring::group_state_bytes(self.kind, &groups[gi].shape, backend))
            .sum()
    }

    /// Largest physical optimizer-state footprint on any single shard —
    /// what the scaling experiment reports and what the session scheduler
    /// uses when costing shard placement for admission control.
    pub fn peak_state_bytes(
        &self,
        groups: &[GroupSpec],
        backend: crate::tensoring::StateBackend,
    ) -> usize {
        (0..self.n_shards())
            .map(|s| self.shard_state_bytes(s, groups, backend))
            .max()
            .unwrap_or(0)
    }

    /// Physical optimizer-state bytes one shard holds under a
    /// [`StatePlan`] — per-group planned bytes instead of a uniform
    /// (kind, backend) assumption. `plan` must describe the same group list
    /// the `ShardPlan` was built from.
    pub fn shard_planned_bytes(&self, shard: usize, plan: &crate::budget::StatePlan) -> usize {
        self.shards[shard].iter().map(|&gi| plan.per_group[gi].bytes).sum()
    }

    /// Largest per-shard planned footprint (see
    /// [`ShardPlan::shard_planned_bytes`]).
    pub fn peak_planned_bytes(&self, plan: &crate::budget::StatePlan) -> usize {
        (0..self.n_shards()).map(|s| self.shard_planned_bytes(s, plan)).max().unwrap_or(0)
    }

    /// Max/mean work ratio across shards (1.0 = perfectly balanced).
    pub fn work_imbalance(&self) -> f64 {
        let max = self.work.iter().copied().max().unwrap_or(0) as f64;
        let mean = self.work.iter().sum::<usize>() as f64 / self.n_shards().max(1) as f64;
        if mean > 0.0 {
            max / mean
        } else {
            1.0
        }
    }
}

/// Bin-pack `groups` onto `n_shards` shards: heaviest group first, each
/// placed on the least-loaded shard that still fits its optimizer state
/// under `max_state_per_shard` (when given). Fully deterministic: ties
/// break toward the lower group index, then the lower shard index.
pub fn partition(
    kind: OptimizerKind,
    groups: &[GroupSpec],
    n_shards: usize,
    max_state_per_shard: Option<usize>,
) -> Result<ShardPlan> {
    let costs: Vec<GroupCost> = groups.iter().map(|g| group_cost(kind, g)).collect();
    partition_with_costs(kind, groups, &costs, n_shards, max_state_per_shard)
}

/// [`partition`] with per-group costs taken from a [`crate::budget::StatePlan`]
/// instead of a uniform (kind, backend): each group is charged its *chosen*
/// configuration's bytes (as f32-equivalent scalars), so a plan that keeps
/// one group at full AdaGrad and another at ET3/nf4 places them by their
/// real footprints. The plan must describe the same group list, in order.
pub fn partition_planned(
    plan: &crate::budget::StatePlan,
    groups: &[GroupSpec],
    n_shards: usize,
    max_state_per_shard: Option<usize>,
) -> Result<ShardPlan> {
    if plan.per_group.len() != groups.len() {
        bail!(
            "partition_planned: plan covers {} groups, model has {}",
            plan.per_group.len(),
            groups.len()
        );
    }
    for (c, g) in plan.per_group.iter().zip(groups) {
        if c.group != g.name {
            bail!("partition_planned: plan group '{}' does not match '{}'", c.group, g.name);
        }
    }
    let costs: Vec<GroupCost> = groups
        .iter()
        .zip(&plan.per_group)
        .map(|(g, c)| GroupCost {
            // f32-equivalent scalars, so planned and uniform placements are
            // commensurable (a q8 scalar weighs ~0.28 of a dense one).
            state_scalars: c.bytes.div_ceil(4),
            work: g.numel(),
        })
        .collect();
    // The ET-family kind tag is the mixed-rule convention (see
    // `budget::exec::PlanRule::kind`); per-group costs above are what
    // actually drive placement.
    partition_with_costs(OptimizerKind::Et(1), groups, &costs, n_shards, max_state_per_shard)
}

/// Core LPT packer over explicit per-group costs.
pub fn partition_with_costs(
    kind: OptimizerKind,
    groups: &[GroupSpec],
    costs: &[GroupCost],
    n_shards: usize,
    max_state_per_shard: Option<usize>,
) -> Result<ShardPlan> {
    if n_shards == 0 {
        bail!("partition: n_shards must be >= 1");
    }
    if groups.is_empty() {
        bail!("partition: no parameter groups");
    }
    if costs.len() != groups.len() {
        bail!("partition: {} costs for {} groups", costs.len(), groups.len());
    }
    let mut order: Vec<usize> = (0..groups.len()).collect();
    order.sort_by(|&a, &b| costs[b].load().cmp(&costs[a].load()).then(a.cmp(&b)));

    let mut owner = vec![0usize; groups.len()];
    let mut state = vec![0usize; n_shards];
    let mut work = vec![0usize; n_shards];
    for &gi in &order {
        let c = costs[gi];
        let mut best: Option<usize> = None;
        for s in 0..n_shards {
            if let Some(budget) = max_state_per_shard {
                if state[s] + c.state_scalars > budget {
                    continue;
                }
            }
            let better = match best {
                None => true,
                Some(b) => work[s] + state[s] < work[b] + state[b],
            };
            if better {
                best = Some(s);
            }
        }
        let Some(s) = best else {
            bail!(
                "group '{}' needs {} optimizer-state scalars but every shard would \
                 exceed the per-shard budget of {} (total so far: {:?})",
                groups[gi].name,
                c.state_scalars,
                max_state_per_shard.unwrap_or(0),
                state
            );
        };
        owner[gi] = s;
        state[s] += c.state_scalars;
        work[s] += c.work;
    }

    let mut shards: Vec<Vec<usize>> = vec![Vec::new(); n_shards];
    for (gi, &s) in owner.iter().enumerate() {
        shards[s].push(gi);
    }
    Ok(ShardPlan { kind, owner, shards, state_scalars: state, work })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn transformer_groups() -> Vec<GroupSpec> {
        let mut g = vec![GroupSpec::new("embed", &[2000, 512])];
        for l in 0..2 {
            g.push(GroupSpec::new(format!("l{l}.w"), &[512, 512]));
            g.push(GroupSpec::new(format!("l{l}.ln"), &[512]));
            g.push(GroupSpec::new(format!("l{l}.ff"), &[512, 2048]));
            g.push(GroupSpec::new(format!("l{l}.ffb"), &[2048]));
        }
        g
    }

    #[test]
    fn one_shard_owns_everything() {
        let gs = transformer_groups();
        let plan = partition(OptimizerKind::Et(2), &gs, 1, None).unwrap();
        assert_eq!(plan.shards.len(), 1);
        assert_eq!(plan.shards[0], (0..gs.len()).collect::<Vec<_>>());
        assert_eq!(plan.work[0], gs.iter().map(|g| g.numel()).sum::<usize>());
    }

    #[test]
    fn covers_each_group_exactly_once() {
        let gs = transformer_groups();
        for shards in [2usize, 3, 4, 16] {
            let plan = partition(OptimizerKind::AdaGrad, &gs, shards, None).unwrap();
            let mut seen = vec![false; gs.len()];
            for owned in &plan.shards {
                for &gi in owned {
                    assert!(!seen[gi], "group {gi} assigned twice");
                    seen[gi] = true;
                }
            }
            assert!(seen.iter().all(|&s| s));
            assert_eq!(plan.owner.len(), gs.len());
            for (gi, &s) in plan.owner.iter().enumerate() {
                assert!(plan.shards[s].contains(&gi));
            }
        }
    }

    #[test]
    fn accounting_matches_memory_model() {
        let gs = transformer_groups();
        for kind in [OptimizerKind::Adam, OptimizerKind::Et(3), OptimizerKind::EtInf] {
            let plan = partition(kind, &gs, 3, None).unwrap();
            let want: usize = gs.iter().map(|g| group_state_scalars(kind, &g.shape)).sum();
            assert_eq!(plan.total_state_scalars(), want, "kind {kind:?}");
            assert!(plan.peak_state_scalars() <= want);
        }
    }

    #[test]
    fn byte_accounting_matches_memory_model() {
        use crate::tensoring::{group_state_bytes, StateBackend};
        let gs = transformer_groups();
        for backend in [StateBackend::DenseF32, StateBackend::q8()] {
            for kind in [OptimizerKind::Adam, OptimizerKind::Et(2), OptimizerKind::EtInf] {
                let plan = partition(kind, &gs, 3, None).unwrap();
                let total: usize = (0..plan.n_shards())
                    .map(|s| plan.shard_state_bytes(s, &gs, backend))
                    .sum();
                let want: usize =
                    gs.iter().map(|g| group_state_bytes(kind, &g.shape, backend)).sum();
                assert_eq!(total, want, "kind {kind:?} backend {backend:?}");
                assert!(plan.peak_state_bytes(&gs, backend) <= want);
                assert!(plan.peak_state_bytes(&gs, backend) > 0 || want == 0);
            }
        }
    }

    #[test]
    fn balances_uniform_groups() {
        let gs: Vec<GroupSpec> =
            (0..16).map(|i| GroupSpec::new(format!("g{i}"), &[64, 64])).collect();
        let plan = partition(OptimizerKind::AdaGrad, &gs, 4, None).unwrap();
        for owned in &plan.shards {
            assert_eq!(owned.len(), 4);
        }
        assert!(plan.work_imbalance() < 1.01, "imbalance {}", plan.work_imbalance());
    }

    /// The asymmetry the subsystem exists for: under AdaGrad the embed
    /// group's state forces the budget; under ET3 the same groups fit in a
    /// tiny budget because state is sum-of-factors, not product.
    #[test]
    fn et_state_drives_budget_feasibility() {
        let gs = transformer_groups();
        let tight = 10_000; // scalars per shard
        assert!(partition(OptimizerKind::AdaGrad, &gs, 4, Some(tight)).is_err());
        let plan = partition(OptimizerKind::Et(3), &gs, 4, Some(tight)).unwrap();
        assert!(plan.peak_state_scalars() <= tight);
    }

    #[test]
    fn deterministic() {
        let gs = transformer_groups();
        let a = partition(OptimizerKind::Et(1), &gs, 4, None).unwrap();
        let b = partition(OptimizerKind::Et(1), &gs, 4, None).unwrap();
        assert_eq!(a.shards, b.shards);
        assert_eq!(a.owner, b.owner);
    }

    #[test]
    fn rejects_degenerate_inputs() {
        let gs = transformer_groups();
        assert!(partition(OptimizerKind::Sgd, &gs, 0, None).is_err());
        assert!(partition(OptimizerKind::Sgd, &[], 2, None).is_err());
    }

    /// Planned placement: per-group bytes come from the chosen configs, so
    /// a plan that quantizes the big groups packs them where a uniform-f32
    /// costing would not, and the per-shard planned-bytes accounting sums
    /// back to the plan total.
    #[test]
    fn planned_partition_costs_from_the_plan() {
        use crate::budget::{plan as budget_plan, PlannerOptions};
        let gs = transformer_groups();
        let sp = budget_plan(&gs, 64 * 1024, &PlannerOptions::default()).unwrap();
        let shard_plan = partition_planned(&sp, &gs, 3, None).unwrap();
        let total: usize =
            (0..shard_plan.n_shards()).map(|s| shard_plan.shard_planned_bytes(s, &sp)).sum();
        assert_eq!(total, sp.total_bytes());
        assert!(shard_plan.peak_planned_bytes(&sp) <= sp.total_bytes());
        assert_eq!(
            shard_plan.total_state_scalars(),
            sp.per_group.iter().map(|c| c.bytes.div_ceil(4)).sum::<usize>()
        );
        // Mismatched group lists are rejected loudly.
        assert!(partition_planned(&sp, &gs[..3], 2, None).is_err());
    }

    #[test]
    fn more_shards_than_groups_leaves_empty_shards() {
        let gs = vec![GroupSpec::new("a", &[8]), GroupSpec::new("b", &[8])];
        let plan = partition(OptimizerKind::Sgd, &gs, 5, None).unwrap();
        let owned: usize = plan.shards.iter().map(|s| s.len()).sum();
        assert_eq!(owned, 2);
        assert_eq!(plan.shards.len(), 5);
    }
}

//! Sharded optimizer-state engine: parallel extreme tensoring across
//! worker shards.
//!
//! The paper shrinks AdaGrad's preconditioner from `d` scalars to
//! `sum_i d_i`; this subsystem turns that memory result into a throughput
//! result. Because a group's entire slice-accumulator state is tiny, it
//! can live wholly on one worker thread — sharding the optimizer is a
//! *partition of groups*, with zero preconditioner communication:
//!
//! * [`partition`] — memory-budget-aware bin-packing of parameter groups
//!   onto N shards, costed by the paper's own footprint accounting
//!   ([`crate::tensoring::memory`]), so ET's asymmetric state drives
//!   placement rather than numel alone;
//! * [`bucketize`] — fuses small groups (biases, layer norms) into one
//!   dispatch unit to amortize channel overhead;
//! * [`ShardedOptimizer`] — persistent workers behind a
//!   [`crate::transport::ShardTransport`] (in-process threads by default,
//!   `ettrain shard-worker` child processes over UNIX sockets via
//!   [`crate::transport::SocketTransport`]), each owning shard-local state
//!   for any `OptimizerKind`, driven by fan-out/fan-in with an ack
//!   barrier. The engine is elastic: `reshard` grows or shrinks the
//!   worker set at a step boundary, and `take_snapshot`/`recover` survive
//!   worker death;
//! * [`SupervisedOptimizer`] — the self-healing layer on top: automatic
//!   snapshots at a [`RecoveryPolicy`] cadence, typed fault
//!   classification (transient timeouts back off; disconnects heal
//!   immediately; worker-reported errors fail fast), and
//!   bitwise-deterministic rewind-and-replay recovery, with every
//!   decision surfaced as a [`RecoveryEvent`].
//!
//! **Determinism contract:** sharded execution is bitwise-identical to
//! the single-threaded optimizer at any shard count. Each group's update
//! is computed by exactly one worker running exactly the single-threaded
//! per-group arithmetic, and the fan-in is a pure ack barrier — there is
//! no cross-shard arithmetic whose order could differ.
//! `rust/tests/sharded_parity.rs` enforces this for every optimizer kind
//! at 1, 2, and 4 shards.
//!
//! **Shard-aware checkpointing:** every worker owns an externalized
//! [`crate::optim::OptState`], so `ShardedOptimizer::export_state` /
//! `import_state` fan worker-local snapshots in and out as one global,
//! shard-count-independent [`crate::optim::StateExport`] — a checkpoint
//! taken at 2 shards restores at 1 or 4 (or single-threaded) bitwise
//! (`rust/tests/host_checkpoint.rs`).

pub mod bucket;
pub mod executor;
pub mod partition;
pub mod supervisor;

pub use bucket::{bucketize, Bucket, DEFAULT_MIN_BUCKET_NUMEL};
pub use executor::ShardedOptimizer;
pub use partition::{
    group_cost, partition, partition_planned, partition_with_costs, GroupCost, ShardPlan,
};
pub use supervisor::{RecoveryEvent, RecoveryPolicy, SupervisedOptimizer, SupervisorError};

//! Gradient bucketing: fuse many small parameter groups into one dispatch
//! unit.
//!
//! Transformer-shaped models are dominated by a few huge matrices plus a
//! long tail of biases and layer norms. Dispatching each tail group to a
//! worker individually would pay one channel round-trip per ~512-element
//! slice — more synchronization than arithmetic. A [`Bucket`] groups
//! consecutive shard-local groups until a minimum element count is
//! reached, so channel overhead is amortized over real work while large
//! groups still travel alone.

use crate::optim::GroupSpec;

/// A set of groups dispatched to a shard worker as one message.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Bucket {
    /// Global group indices, in the owning shard's ascending order.
    pub groups: Vec<usize>,
    /// Total gradient elements across the bucket.
    pub numel: usize,
}

/// Default fuse threshold: a bucket keeps absorbing groups until it holds
/// at least this many gradient elements (16Ki floats = 64KiB of gradient,
/// far above per-message channel cost).
pub const DEFAULT_MIN_BUCKET_NUMEL: usize = 1 << 14;

/// Split a shard's owned group list (`order`, ascending global indices)
/// into buckets of at least `min_numel` elements. The final undersized
/// remainder is folded into the previous bucket so tiny tails never pay a
/// full dispatch. Order within and across buckets preserves `order`.
pub fn bucketize(order: &[usize], groups: &[GroupSpec], min_numel: usize) -> Vec<Bucket> {
    let mut out: Vec<Bucket> = Vec::new();
    let mut cur = Bucket { groups: Vec::new(), numel: 0 };
    for &gi in order {
        cur.groups.push(gi);
        cur.numel += groups[gi].numel();
        if cur.numel >= min_numel {
            out.push(std::mem::replace(&mut cur, Bucket { groups: Vec::new(), numel: 0 }));
        }
    }
    if !cur.groups.is_empty() {
        match out.last_mut() {
            Some(last) => {
                last.groups.extend(cur.groups);
                last.numel += cur.numel;
            }
            None => out.push(cur),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn groups() -> Vec<GroupSpec> {
        vec![
            GroupSpec::new("w1", &[100, 100]), // 10_000
            GroupSpec::new("b1", &[100]),
            GroupSpec::new("b2", &[100]),
            GroupSpec::new("b3", &[100]),
            GroupSpec::new("w2", &[200, 100]), // 20_000
            GroupSpec::new("b4", &[50]),
        ]
    }

    fn flat(buckets: &[Bucket]) -> Vec<usize> {
        buckets.iter().flat_map(|b| b.groups.iter().copied()).collect()
    }

    #[test]
    fn covers_all_groups_in_order() {
        let gs = groups();
        let order: Vec<usize> = (0..gs.len()).collect();
        let buckets = bucketize(&order, &gs, 1 << 14);
        assert_eq!(flat(&buckets), order);
        let total: usize = buckets.iter().map(|b| b.numel).sum();
        assert_eq!(total, gs.iter().map(|g| g.numel()).sum::<usize>());
    }

    #[test]
    fn small_groups_fuse() {
        let gs = groups();
        // Only the biases, in shard order.
        let order = [1usize, 2, 3, 5];
        let buckets = bucketize(&order, &gs, 1000);
        assert_eq!(buckets.len(), 1, "{buckets:?}");
        assert_eq!(buckets[0].numel, 350);
    }

    #[test]
    fn big_groups_travel_alone() {
        let gs = groups();
        let order = [0usize, 4];
        let buckets = bucketize(&order, &gs, 5000);
        assert_eq!(buckets.len(), 2);
        assert_eq!(buckets[0].groups, vec![0]);
        assert_eq!(buckets[1].groups, vec![4]);
    }

    #[test]
    fn tail_folds_into_previous_bucket() {
        let gs = groups();
        let order = [0usize, 1, 2]; // w1 closes a bucket; b1+b2 are the tail
        let buckets = bucketize(&order, &gs, 5000);
        assert_eq!(buckets.len(), 1);
        assert_eq!(buckets[0].groups, vec![0, 1, 2]);
        assert_eq!(buckets[0].numel, 10_200);
    }

    #[test]
    fn threshold_one_isolates_every_group() {
        let gs = groups();
        let order: Vec<usize> = (0..gs.len()).collect();
        let buckets = bucketize(&order, &gs, 1);
        assert_eq!(buckets.len(), gs.len());
    }

    #[test]
    fn empty_order_yields_no_buckets() {
        let gs = groups();
        assert!(bucketize(&[], &gs, 1024).is_empty());
    }
}

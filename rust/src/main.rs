//! `ettrain` — the extreme-tensoring training coordinator CLI.
//!
//! Subcommands:
//!   train          run a training job from a TOML config
//!   experiment     regenerate a paper table/figure (table1|table2|fig2|fig3|table4|...)
//!   batch          run a user-authored batch of jobs from a jobs TOML
//!   gate           compare fresh BENCH files against checked-in goldens
//!   registry       report per-commit run trajectories from the registry
//!   plan-index     print the Table 3 / B.1 factorization tables
//!   memory-report  per-optimizer state accounting for a transformer config
//!   list-artifacts show compiled AOT artifacts and their shapes
//!
//! Run `ettrain <cmd> --help` (any bad flag prints usage).

use anyhow::{bail, Context, Result};
use extensor::coordinator::experiments;
use extensor::coordinator::ExpOptions;
use extensor::session::{self, Session};
use extensor::train::RunConfig;
use extensor::util::cli::{parse_set_overrides, Args, Spec};
use extensor::util::config::Config;
use std::path::PathBuf;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        print_help();
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "train" => cmd_train(rest),
        "experiment" => cmd_experiment(rest),
        "batch" => cmd_batch(rest),
        "gate" => cmd_gate(rest),
        "registry" => cmd_registry(rest),
        "trace" => cmd_trace(rest),
        "shard-worker" => cmd_shard_worker(rest),
        "plan" => cmd_plan(rest),
        "plan-index" => cmd_plan_index(rest),
        "memory-report" => cmd_memory_report(rest),
        "list-artifacts" => cmd_list_artifacts(rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (try `ettrain help`)"),
    }
}

fn print_help() {
    println!(
        "ettrain — Extreme Tensoring for Low-Memory Preconditioning (ICLR 2020) reproduction

USAGE: ettrain <subcommand> [options]

  train <config.toml> [--set k=v ...] [--resume]   run a training job
        (run.shards + run.host_optimizer train host-side via the sharded engine;
         --resume continues from runs/<name>/latest.hck (host) or latest.ck)
  experiment <id> [--steps N] [--csv] [--jobs N] [--mem-budget BYTES]
        regenerate a paper table/figure as a concurrent job batch
        ids: table1 fig1 table2 fig2 fig3 table4 fig4 sharding quantized-state
             pareto ablation all
        (sharding sweeps the worker-shard engine; --shards caps the sweep;
         quantized-state sweeps state backend x optimizer, memory vs quality;
         pareto sweeps opt-memory budget x task via the budget planner and
         emits BENCH_pareto.json; --jobs runs N jobs concurrently,
         --mem-budget bounds their summed optimizer-state/param bytes via
         admission control)
  batch <jobs.toml> [--jobs N] [--mem-budget BYTES]  run a custom job batch
        (each [job.<name>] section is one lm|convex|shard-bench|vision job)
  gate [--tolerance 10%] [--goldens goldens] [--bless | --schema-only]
       [--require-pinned]
        diff fresh BENCH_optim.json / BENCH_pareto.json against the
        checked-in goldens and fail on regressions beyond the band
        (--bless re-pins the goldens from the fresh outputs;
         --schema-only validates the bench JSON invariants, no goldens;
         --require-pinned makes unpinned goldens a hard failure)
  registry report [--dir results/registry] [--out dashboards]
        fold registry records + schedule logs into per-commit trajectory
        tables (every train/batch/experiment run is recorded automatically
        under results/registry/)
        (--ingest <dir,...> merges uploaded CI registry artifacts into the
         trajectory, deduplicated by run id)
  registry replay <run_id> [--dir results/registry]
        re-execute a recorded run's spec and diff the fresh metrics
        against the record bit-for-bit (typed divergence report;
        non-zero exit on divergence)
  registry compact [--dir results/registry] [--keep N]
        rewrite the registry keeping only the last N runs per distinct
        job spec (JSONL + CSV, atomically)
  trace [--kind et2] [--shards 2] [--transport inproc|socket|tcp[:addr]]
        [--steps 30] [--tag <tag>] [--out-dir results] [--min-coverage 95%]
        run a traced shard-bench: per-span flame summary table plus a
        Chrome trace-event JSON (load it at chrome://tracing) written to
        results/trace/<tag>.trace.json
  shard-worker (--connect <path> | --tcp-connect <addr>) [--shard N]
               [--retries N] [--backoff-ms N]
        run one out-of-process shard worker serving the transport wire
        protocol on a UNIX socket or TCP connection (spawned by the
        socket/tcp transports; not normally run by hand)
  plan [--budget 64m | --set run.opt_memory_budget=64m] [--layers N ...]
        solve and print the per-group (ET level x backend) state plan for a
        transformer under an optimizer-memory budget, without running
  plan-index --preset resnet18|transformer
  memory-report [--layers N] [--vocab V] [--d-model D] [--d-ff F]
  list-artifacts [--dir artifacts]

Artifacts must be built first: `make artifacts`."
    );
}

fn cmd_train(argv: &[String]) -> Result<()> {
    let spec = Spec {
        name: "train",
        about: "run a training job from a TOML config",
        options: vec![("set", None, "override config key=value (comma separated)")],
        flags: vec![
            ("quiet", "reduce logging"),
            ("resume", "resume from runs/<name>/latest.hck (host) or latest.ck (fused)"),
        ],
        positional: vec![("config", "path to run config TOML")],
    };
    let args = Args::parse(&spec, argv)?;
    if args.flag("quiet") {
        extensor::util::logging::set_verbosity(extensor::util::logging::Level::Warn);
    }
    let config_path = args
        .positional
        .first()
        .context("missing <config> (see configs/ for examples)")?;
    let overrides = match args.get("set") {
        Some(s) => parse_set_overrides(s)?,
        None => Vec::new(),
    };
    let mut cfg = RunConfig::load(config_path, &overrides)?;
    cfg.resume |= args.flag("resume");
    let name = cfg.name.clone();
    // Route the single run through the scheduler so it lands in the run
    // registry (and the schedule log) exactly like batch/experiment jobs.
    let spec = session::JobSpec::lm(name.clone(), cfg);
    let sched = session::SchedulerOptions {
        workers: 1,
        mem_budget: None,
        log_path: Some(PathBuf::from("results/schedule/train.jsonl")),
        registry_dir: Some(PathBuf::from("results/registry")),
    };
    let session = Session::new();
    let report = session::run_batch(&session, &[spec], &sched)?;
    let outcome = report.outcome(&name)?;
    let s = &outcome.as_lm().context("train: expected an LM outcome")?.summary;
    println!(
        "run '{name}': {} steps, final loss {:.4}, val ppl {:.2}, {:.1}s, {:.0} tok/s",
        s.steps, s.final_train_loss, s.final_eval_ppl, s.wall_seconds, s.tokens_per_sec
    );
    Ok(())
}

fn exp_options(args: &Args) -> Result<ExpOptions> {
    Ok(ExpOptions {
        artifact_dir: PathBuf::from(args.get("artifact-dir").unwrap_or("artifacts")),
        out_dir: PathBuf::from(args.get("out-dir").unwrap_or("results")),
        steps: args.get_u64("steps")?,
        seed: args.get_u64("seed")?,
        csv: args.flag("csv"),
        tune: args.flag("tune"),
        shards: args.get_usize("shards")?.max(1),
        jobs: args.get_usize("jobs")?.max(1),
        mem_budget: parse_mem_budget(args.get("mem-budget"))?,
    })
}

/// Parse `--mem-budget` (plain bytes, or with a k/m/g suffix).
fn parse_mem_budget(raw: Option<&str>) -> Result<Option<u64>> {
    let Some(raw) = raw else { return Ok(None) };
    let n = extensor::util::cli::parse_byte_size(raw)
        .map_err(|e| anyhow::anyhow!("--mem-budget: {e}"))?;
    Ok(Some(n))
}

fn cmd_experiment(argv: &[String]) -> Result<()> {
    let spec = Spec {
        name: "experiment",
        about: "regenerate a paper table/figure as a scheduler job batch",
        options: vec![
            ("steps", Some("300"), "training steps per run"),
            ("seed", Some("42"), "experiment seed"),
            ("artifact-dir", Some("artifacts"), "AOT artifact directory"),
            ("out-dir", Some("results"), "output directory"),
            ("shards", Some("8"), "max worker-shard count for the sharding sweep"),
            ("jobs", Some("1"), "concurrent scheduler workers"),
            ("mem-budget", None, "admission budget in bytes (k/m/g suffix ok)"),
        ],
        flags: vec![
            ("csv", "also write figure CSV series"),
            ("tune", "grid-search the global LR scale with probe runs"),
        ],
        positional: vec![(
            "id",
            "table1|fig1|table2|fig2|fig3|table4|fig4|sharding|quantized-state|pareto|\
             ablation|all",
        )],
    };
    let args = Args::parse(&spec, argv)?;
    let id = args.positional.first().context("missing experiment id")?.as_str();
    let mut opts = exp_options(&args)?;
    // One session per invocation: artifacts compile once and corpora
    // synthesize once across every sub-experiment of `all`.
    let session = Session::new();
    match id {
        "table1" | "fig1" => {
            opts.csv |= id == "fig1";
            experiments::table1(&session, &opts)
        }
        "table2" => experiments::table2(&session, &opts),
        "fig2" => experiments::fig2(&session, &opts),
        "fig3" => experiments::fig3(&session, &opts),
        "sharding" => experiments::sharding(&session, &opts),
        "quantized-state" => experiments::quantized_state(&session, &opts),
        "pareto" => experiments::pareto(&session, &opts),
        "table4" | "fig4" => {
            opts.csv |= id == "fig4";
            experiments::table4(&session, &opts)
        }
        "ablation" => extensor::coordinator::ablation::run(&session, &opts),
        "all" => {
            opts.csv = true;
            experiments::table1(&session, &opts)?;
            experiments::table2(&session, &opts)?;
            experiments::fig2(&session, &opts)?;
            experiments::fig3(&session, &opts)?;
            experiments::table4(&session, &opts)?;
            experiments::sharding(&session, &opts)?;
            experiments::quantized_state(&session, &opts)?;
            experiments::pareto(&session, &opts)?;
            extensor::coordinator::ablation::run(&session, &opts)
        }
        other => bail!("unknown experiment '{other}'"),
    }
}

fn cmd_batch(argv: &[String]) -> Result<()> {
    let spec = Spec {
        name: "batch",
        about: "run a custom batch of jobs from a jobs TOML",
        options: vec![
            ("jobs", Some("1"), "concurrent scheduler workers"),
            ("mem-budget", None, "admission budget in bytes (k/m/g suffix ok)"),
            ("out-dir", Some("results"), "output directory (schedule log)"),
        ],
        flags: vec![("quiet", "reduce logging")],
        positional: vec![("jobs_toml", "batch file: one [job.<name>] section per job")],
    };
    let args = Args::parse(&spec, argv)?;
    if args.flag("quiet") {
        extensor::util::logging::set_verbosity(extensor::util::logging::Level::Warn);
    }
    let path = args.positional.first().context("missing <jobs_toml>")?;
    let cfg = Config::load(path)?;
    let specs = session::batch_from_config(&cfg)?;
    let out_dir = PathBuf::from(args.get("out-dir").unwrap_or("results"));
    let sched = session::SchedulerOptions {
        workers: args.get_usize("jobs")?.max(1),
        mem_budget: parse_mem_budget(args.get("mem-budget"))?,
        log_path: Some(out_dir.join("schedule").join("batch.jsonl")),
        registry_dir: Some(out_dir.join("registry")),
    };
    let session = Session::new();
    let report = session::run_batch(&session, &specs, &sched)?;

    let mut table = extensor::coordinator::report::Table::new(
        &format!("batch '{path}' — {} jobs in {:.1}s", specs.len(), report.wall_seconds),
        &["Job", "Kind", "Status", "Wall s"],
    );
    for (r, s) in report.results.iter().zip(&specs) {
        table.row(vec![
            r.name.clone(),
            s.workload_label().to_string(),
            match &r.outcome {
                Ok(_) => "ok".to_string(),
                Err(e) => format!("FAILED: {e}"),
            },
            format!("{:.1}", r.wall_seconds),
        ]);
    }
    println!("{}", table.render());
    let counts = report.cache_counts();
    println!(
        "cache: {} artifact hits / {} loads, {} corpus hits / {} syntheses",
        counts.artifact_hits,
        counts.artifact_misses,
        counts.corpus_hits,
        counts.corpus_misses
    );
    let failed = report.failed();
    if !failed.is_empty() {
        bail!("{} of {} jobs failed", failed.len(), specs.len());
    }
    Ok(())
}

/// `ettrain gate` — the golden perf gate (see `extensor::registry::gate`).
fn cmd_gate(argv: &[String]) -> Result<()> {
    use extensor::registry::gate::{parse_tolerance, run_gate, GateOptions};
    let spec = Spec {
        name: "gate",
        about: "compare fresh BENCH files against checked-in goldens",
        options: vec![
            ("tolerance", Some("10%"), "allowed regression band (e.g. 10% or 0.1)"),
            ("goldens", Some("goldens"), "directory holding the golden BENCH files"),
            ("optim", Some("BENCH_optim.json"), "fresh optim bench JSON"),
            ("pareto", Some("BENCH_pareto.json"), "fresh pareto bench JSON"),
        ],
        flags: vec![
            ("bless", "re-pin the goldens from the fresh bench outputs"),
            ("schema-only", "validate the bench JSON invariants only (no goldens)"),
            ("require-pinned", "fail (instead of warn) when goldens are not pinned"),
        ],
        positional: vec![],
    };
    let args = Args::parse(&spec, argv)?;
    let opts = GateOptions {
        tolerance: parse_tolerance(args.get("tolerance").unwrap_or("10%"))?,
        goldens_dir: PathBuf::from(args.get("goldens").unwrap_or("goldens")),
        optim_path: PathBuf::from(args.get("optim").unwrap_or("BENCH_optim.json")),
        pareto_path: PathBuf::from(args.get("pareto").unwrap_or("BENCH_pareto.json")),
        bless: args.flag("bless"),
        schema_only: args.flag("schema-only"),
        require_pinned: args.flag("require-pinned"),
    };
    run_gate(&opts)
}

/// `ettrain registry report` — the trajectory dashboard (see
/// `extensor::registry::dashboard`).
fn cmd_registry(argv: &[String]) -> Result<()> {
    let spec = Spec {
        name: "registry",
        about: "inspect the run registry",
        options: vec![
            ("dir", Some("results/registry"), "registry directory"),
            ("out", None, "also write dashboard.md + trajectory.csv here"),
            ("keep", Some("20"), "compact: runs to keep per distinct spec"),
            ("ingest", None, "report: merge registry artifact dirs (comma separated)"),
        ],
        flags: vec![],
        positional: vec![("action", "report | replay | compact"), ("run_id", "replay: run id")],
    };
    let args = Args::parse(&spec, argv)?;
    let dir = PathBuf::from(args.get("dir").unwrap_or("results/registry"));
    match args.positional.first().map(String::as_str).unwrap_or("report") {
        "report" => {
            let ingest_dirs: Vec<PathBuf> = args
                .get("ingest")
                .map(|s| s.split(',').map(|d| PathBuf::from(d.trim())).collect())
                .unwrap_or_default();
            extensor::registry::dashboard::report_with_ingest(
                &dir,
                args.get("out").map(std::path::Path::new),
                &ingest_dirs,
            )
        }
        "replay" => {
            let run_id = args
                .positional
                .get(1)
                .context("registry replay: missing <run_id> (see `registry report`)")?;
            let report = extensor::registry::replay::replay(&dir, run_id)?;
            if !report.skipped.is_empty() {
                println!(
                    "replay '{}' ({}): skipped time-derived metrics: {}",
                    report.run_id,
                    report.job,
                    report.skipped.join(", ")
                );
            }
            if report.reproduced() {
                println!(
                    "replay '{}' ({}): bitwise reproduction, {} metric(s) identical",
                    report.run_id,
                    report.job,
                    report
                        .recorded
                        .as_obj()
                        .map_or(0, |m| m.len())
                        .saturating_sub(report.skipped.len())
                );
                return Ok(());
            }
            for d in &report.divergences {
                eprintln!("replay: {d}");
            }
            bail!(
                "replay '{}': {} divergence(s); first: {}",
                report.run_id,
                report.divergences.len(),
                report.divergences[0]
            );
        }
        "compact" => {
            let keep = args.get_usize("keep")?.max(1);
            let registry = extensor::registry::Registry::open(&dir)?;
            let stats = registry.compact(keep)?;
            println!(
                "registry compact: kept {} of {} runs across {} distinct specs (keep {keep})",
                stats.kept, stats.total, stats.specs
            );
            Ok(())
        }
        other => bail!("unknown registry action '{other}' (try 'report', 'replay', 'compact')"),
    }
}

/// `ettrain trace` — run one traced shard-bench job: per-span flame
/// summary on stdout, Chrome trace-event JSON (`chrome://tracing` /
/// Perfetto) on disk. See `extensor::trace`.
fn cmd_trace(argv: &[String]) -> Result<()> {
    let spec = Spec {
        name: "trace",
        about: "run a traced shard-bench and export a Chrome trace",
        options: vec![
            ("kind", Some("et2"), "optimizer kind (et1|et2|et3|etinf|adagrad|adam|...)"),
            ("shards", Some("2"), "worker shard count"),
            ("transport", Some("inproc"), "inproc | socket | tcp[:<addr>]"),
            ("steps", Some("30"), "timed steps (after warmup)"),
            ("tag", Some("trace"), "output name: <out-dir>/trace/<tag>.trace.json"),
            ("out-dir", Some("results"), "output directory"),
            ("min-coverage", None, "fail unless spans cover >= this % of step wall time"),
        ],
        flags: vec![],
        positional: vec![],
    };
    let args = Args::parse(&spec, argv)?;
    let kind_raw = args.get("kind").unwrap_or("et2");
    let kind = extensor::tensoring::OptimizerKind::parse(kind_raw)
        .with_context(|| format!("unknown optimizer kind '{kind_raw}'"))?;
    let transport =
        extensor::transport::TransportKind::parse(args.get("transport").unwrap_or("inproc"))?;
    let min_coverage: Option<f64> = match args.get("min-coverage") {
        Some(raw) => Some(
            raw.trim()
                .trim_end_matches('%')
                .parse::<f64>()
                .with_context(|| format!("bad --min-coverage '{raw}' (want e.g. 95%)"))?,
        ),
        None => None,
    };
    let tag = args.get("tag").unwrap_or("trace").to_string();
    let bench = session::ShardBenchSpec {
        kind,
        shards: args.get_usize("shards")?.max(1),
        iters: args.get_usize("steps")?.max(1),
        transport,
        ..session::ShardBenchSpec::default()
    };
    let job = session::JobSpec::shard_bench(format!("trace-{tag}"), bench);

    extensor::trace::enable();
    let sink = session::EventSink::discard(&job.name);
    let outcome = session::run_job(&job, &Session::new(), &sink);
    extensor::trace::disable();
    let threads = extensor::trace::drain();
    let outcome = outcome?;
    let bench_out = outcome.as_shard_bench().context("trace: expected a shard-bench outcome")?;
    let timing = bench_out.timing.as_ref().context("trace: no timing profile collected")?;

    // Per-span flame summary over the timed loop.
    let wall_ns = timing.get("wall_ns").and_then(|v| v.as_f64()).unwrap_or(0.0);
    let coverage = timing.get("coverage_pct").and_then(|v| v.as_f64()).unwrap_or(0.0);
    let mut table = extensor::coordinator::report::Table::new(
        &format!(
            "trace '{tag}' — {} x{} over {}, {:.1} steps/s",
            bench_out.optimizer,
            bench_out.shards,
            args.get("transport").unwrap_or("inproc"),
            bench_out.steps_per_sec
        ),
        &["span", "count", "p50 us", "p99 us", "max us", "total ms", "% wall"],
    );
    if let Some(kinds) = timing.get("kinds").and_then(|k| k.as_obj()) {
        for (name, v) in kinds {
            let g = |k: &str| v.get(k).and_then(|x| x.as_f64()).unwrap_or(0.0);
            let total = g("total_ns");
            table.row(vec![
                name.clone(),
                format!("{}", g("count") as u64),
                format!("{:.1}", g("p50_ns") / 1e3),
                format!("{:.1}", g("p99_ns") / 1e3),
                format!("{:.1}", g("max_ns") / 1e3),
                format!("{:.3}", total / 1e6),
                if wall_ns > 0.0 { format!("{:.1}", 100.0 * total / wall_ns) } else { "-".into() },
            ]);
        }
    }
    println!("{}", table.render());

    let out = PathBuf::from(args.get("out-dir").unwrap_or("results"))
        .join("trace")
        .join(format!("{tag}.trace.json"));
    extensor::trace::write_chrome_trace(&out, &threads)?;
    let spans: usize = threads.iter().map(|t| t.spans.len()).sum();
    let dropped: u64 = threads.iter().map(|t| t.dropped).sum();
    println!(
        "wrote {out:?}: {spans} spans across {} thread(s), {dropped} dropped, \
         coverage {coverage:.1}% of step wall time",
        threads.len()
    );
    if let Some(min) = min_coverage {
        if coverage < min {
            bail!("trace: span coverage {coverage:.1}% below --min-coverage {min:.1}%");
        }
    }
    Ok(())
}

/// `ettrain shard-worker` — one out-of-process shard worker (spawned by
/// `extensor::transport::SocketTransport` over UNIX sockets or
/// `extensor::transport::TcpTransport` over TCP; see those modules).
fn cmd_shard_worker(argv: &[String]) -> Result<()> {
    let spec = Spec {
        name: "shard-worker",
        about: "serve the shard transport wire protocol on a socket",
        options: vec![
            ("connect", None, "UNIX socket path to connect back to"),
            ("tcp-connect", None, "TCP address to connect back to (host:port)"),
            ("shard", Some("0"), "shard index, for log/error labels"),
            ("retries", None, "connect retry attempts (default from TransportTuning)"),
            ("backoff-ms", None, "base connect retry backoff in ms"),
        ],
        flags: vec![],
        positional: vec![],
    };
    let args = Args::parse(&spec, argv)?;
    let shard = args.get_usize("shard")?;
    let mut tuning = extensor::transport::TransportTuning::default();
    if args.get("retries").is_some() {
        tuning.connect_retries = args.get_u64("retries")? as u32;
    }
    if args.get("backoff-ms").is_some() {
        tuning.backoff_ms = args.get_u64("backoff-ms")?;
    }
    tuning.validate()?;
    match (args.get("connect"), args.get("tcp-connect")) {
        (Some(path), None) => {
            extensor::transport::run_socket_worker(std::path::Path::new(path), shard, tuning)
        }
        (None, Some(addr)) => extensor::transport::run_tcp_worker(addr, shard, tuning),
        (Some(_), Some(_)) => {
            bail!("shard-worker: --connect and --tcp-connect are mutually exclusive")
        }
        (None, None) => bail!("shard-worker: need --connect <path> or --tcp-connect <addr>"),
    }
}

/// `ettrain plan` — solve and print the per-group state plan for a
/// transformer-shaped model under an optimizer-memory budget, without
/// running anything. The budget comes from `--budget 64m` or the
/// config-key spelling `--set run.opt_memory_budget=64m` (both accept
/// k/m/g suffixes).
fn cmd_plan(argv: &[String]) -> Result<()> {
    use extensor::budget::{plan, PlannerOptions};
    let spec = Spec {
        name: "plan",
        about: "solve the per-group (ET level x backend) plan for a byte budget",
        options: vec![
            ("budget", None, "optimizer-state byte budget (k/m/g suffix ok)"),
            ("set", None, "config-style override; only run.opt_memory_budget is meaningful"),
            ("layers", Some("6"), "transformer layers"),
            ("vocab", Some("2000"), "vocabulary size"),
            ("d-model", Some("512"), "model width"),
            ("d-ff", Some("2048"), "feed-forward width"),
            ("json", None, "also write the serialized StatePlan to this path"),
        ],
        flags: vec![],
        positional: vec![],
    };
    let args = Args::parse(&spec, argv)?;
    let mut budget: Option<u64> = match args.get("budget") {
        Some(raw) => Some(
            extensor::util::cli::parse_byte_size(raw)
                .map_err(|e| anyhow::anyhow!("--budget: {e}"))?,
        ),
        None => None,
    };
    if let Some(raw) = args.get("set") {
        for (k, v) in parse_set_overrides(raw)? {
            match k.as_str() {
                "run.opt_memory_budget" => {
                    budget = Some(
                        extensor::util::cli::parse_byte_size(&v)
                            .map_err(|e| anyhow::anyhow!("--set {k}: {e}"))?,
                    );
                }
                other => bail!(
                    "plan: --set key '{other}' has no effect here \
                     (only run.opt_memory_budget)"
                ),
            }
        }
    }
    let budget = budget.context(
        "plan needs a budget: --budget 64m or --set run.opt_memory_budget=64m",
    )?;
    let groups = extensor::testing::transformer_groups(
        args.get_usize("layers")?,
        args.get_usize("vocab")?,
        args.get_usize("d-model")?,
        args.get_usize("d-ff")?,
    );
    let solved = plan(&groups, budget, &PlannerOptions::default())?;

    let mut table = extensor::coordinator::report::Table::new(
        &format!(
            "State plan under {} B budget — {} B planned, expressivity {:.0}",
            budget,
            solved.total_bytes(),
            solved.total_expressivity()
        ),
        &["Group", "Shape", "ET level", "Dims", "Backend", "Bytes", "DOF/param"],
    );
    for (g, c) in groups.iter().zip(&solved.per_group) {
        let dims = match c.kind {
            extensor::tensoring::OptimizerKind::Et(k) => format!(
                "{:?}",
                extensor::tensoring::plan(&g.shape, extensor::tensoring::Level::Et(k))
            ),
            extensor::tensoring::OptimizerKind::AdaGrad => "per-coordinate".to_string(),
            _ => "group scalar".to_string(),
        };
        table.row(vec![
            c.group.clone(),
            format!("{:?}", c.shape),
            c.kind.name(),
            dims,
            c.backend.name(),
            c.bytes.to_string(),
            format!("{:.4}", c.expressivity / g.numel().max(1) as f64),
        ]);
    }
    println!("{}", table.render());
    let params: usize = groups.iter().map(|g| g.numel()).sum();
    println!(
        "{} groups, {} params; plan uses {:.2}% of the budget \
         ({:.4} opt scalars/param in f32-equivalents)",
        groups.len(),
        params,
        100.0 * solved.total_bytes() as f64 / budget as f64,
        solved.total_bytes() as f64 / 4.0 / params as f64
    );
    if let Some(path) = args.get("json") {
        std::fs::write(path, solved.to_json().to_string_pretty())
            .with_context(|| format!("write {path}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_plan_index(argv: &[String]) -> Result<()> {
    let spec = Spec {
        name: "plan-index",
        about: "print factorization tables (paper Tables 3 / B.1)",
        options: vec![("preset", Some("transformer"), "resnet18 | transformer")],
        flags: vec![],
        positional: vec![],
    };
    let args = Args::parse(&spec, argv)?;
    experiments::plan_index(args.get("preset").unwrap_or("transformer"))
}

fn cmd_memory_report(argv: &[String]) -> Result<()> {
    let spec = Spec {
        name: "memory-report",
        about: "optimizer state accounting for a transformer config",
        options: vec![
            ("layers", Some("6"), "transformer layers"),
            ("vocab", Some("2000"), "vocabulary size"),
            ("d-model", Some("512"), "model width"),
            ("d-ff", Some("2048"), "feed-forward width"),
        ],
        flags: vec![],
        positional: vec![],
    };
    let args = Args::parse(&spec, argv)?;
    experiments::memory_report(
        args.get_usize("layers")?,
        args.get_usize("vocab")?,
        args.get_usize("d-model")?,
        args.get_usize("d-ff")?,
    )
}

fn cmd_list_artifacts(argv: &[String]) -> Result<()> {
    let spec = Spec {
        name: "list-artifacts",
        about: "show compiled AOT artifacts",
        options: vec![("dir", Some("artifacts"), "artifact directory")],
        flags: vec![],
        positional: vec![],
    };
    let args = Args::parse(&spec, argv)?;
    let dir = PathBuf::from(args.get("dir").unwrap_or("artifacts"));
    let mut names: Vec<String> = std::fs::read_dir(&dir)
        .with_context(|| format!("read {dir:?} (run `make artifacts`)"))?
        .filter_map(|e| e.ok())
        .filter_map(|e| {
            let n = e.file_name().to_string_lossy().to_string();
            n.strip_suffix(".json").map(|s| s.to_string())
        })
        .collect();
    names.sort();
    println!("{:<22} {:>12} {:>12}  kind", "artifact", "params", "opt state");
    for name in names {
        if let Ok(m) = extensor::runtime::Manifest::load(&dir, &name) {
            println!(
                "{:<22} {:>12} {:>12}  {:?}",
                m.name,
                m.total_params(),
                m.total_opt_state(),
                m.kind
            );
        }
    }
    Ok(())
}

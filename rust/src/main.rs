//! `ettrain` — the extreme-tensoring training coordinator CLI.
//!
//! Subcommands:
//!   train          run a training job from a TOML config
//!   experiment     regenerate a paper table/figure (table1|table2|fig2|fig3|table4)
//!   plan-index     print the Table 3 / B.1 factorization tables
//!   memory-report  per-optimizer state accounting for a transformer config
//!   list-artifacts show compiled AOT artifacts and their shapes
//!
//! Run `ettrain <cmd> --help` (any bad flag prints usage).

use anyhow::{bail, Context, Result};
use extensor::coordinator::experiments;
use extensor::coordinator::ExpOptions;
use extensor::train::{RunConfig, Trainer};
use extensor::util::cli::{Args, Spec};
use std::path::PathBuf;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        print_help();
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "train" => cmd_train(rest),
        "experiment" => cmd_experiment(rest),
        "plan-index" => cmd_plan_index(rest),
        "memory-report" => cmd_memory_report(rest),
        "list-artifacts" => cmd_list_artifacts(rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (try `ettrain help`)"),
    }
}

fn print_help() {
    println!(
        "ettrain — Extreme Tensoring for Low-Memory Preconditioning (ICLR 2020) reproduction

USAGE: ettrain <subcommand> [options]

  train <config.toml> [--set k=v ...]   run a training job
        (run.shards + run.host_optimizer train host-side via the sharded engine)
  experiment <id> [--steps N] [--csv]   regenerate a paper table/figure
        ids: table1 fig1 table2 fig2 fig3 table4 fig4 sharding quantized-state
             ablation all
        (sharding sweeps the worker-shard engine; --shards caps the sweep;
         quantized-state sweeps state backend x optimizer, memory vs quality)
  plan-index --preset resnet18|transformer
  memory-report [--layers N] [--vocab V] [--d-model D] [--d-ff F]
  list-artifacts [--dir artifacts]

Artifacts must be built first: `make artifacts`."
    );
}

fn cmd_train(argv: &[String]) -> Result<()> {
    let spec = Spec {
        name: "train",
        about: "run a training job from a TOML config",
        options: vec![("set", None, "override config key=value (comma separated)")],
        flags: vec![("quiet", "reduce logging")],
        positional: vec![("config", "path to run config TOML")],
    };
    let args = Args::parse(&spec, argv)?;
    if args.flag("quiet") {
        extensor::util::logging::set_verbosity(extensor::util::logging::Level::Warn);
    }
    let config_path = args
        .positional
        .first()
        .context("missing <config> (see configs/ for examples)")?;
    let overrides: Vec<(String, String)> = args
        .get("set")
        .map(|s| {
            s.split(',')
                .filter_map(|kv| kv.split_once('=').map(|(k, v)| (k.to_string(), v.to_string())))
                .collect()
        })
        .unwrap_or_default();
    let cfg = RunConfig::load(config_path, &overrides)?;
    let name = cfg.name.clone();
    let result = Trainer::new(cfg)?.run()?;
    let s = &result.summary;
    println!(
        "run '{name}': {} steps, final loss {:.4}, val ppl {:.2}, {:.1}s, {:.0} tok/s",
        s.steps, s.final_train_loss, s.final_eval_ppl, s.wall_seconds, s.tokens_per_sec
    );
    Ok(())
}

fn exp_options(args: &Args) -> Result<ExpOptions> {
    Ok(ExpOptions {
        artifact_dir: PathBuf::from(args.get("artifact-dir").unwrap_or("artifacts")),
        out_dir: PathBuf::from(args.get("out-dir").unwrap_or("results")),
        steps: args.get_u64("steps")?,
        seed: args.get_u64("seed")?,
        csv: args.flag("csv"),
        tune: args.flag("tune"),
        shards: args.get_usize("shards")?.max(1),
    })
}

fn cmd_experiment(argv: &[String]) -> Result<()> {
    let spec = Spec {
        name: "experiment",
        about: "regenerate a paper table/figure",
        options: vec![
            ("steps", Some("300"), "training steps per run"),
            ("seed", Some("42"), "experiment seed"),
            ("artifact-dir", Some("artifacts"), "AOT artifact directory"),
            ("out-dir", Some("results"), "output directory"),
            ("shards", Some("8"), "max worker-shard count for the sharding sweep"),
        ],
        flags: vec![
            ("csv", "also write figure CSV series"),
            ("tune", "grid-search the global LR scale with probe runs"),
        ],
        positional: vec![(
            "id",
            "table1|fig1|table2|fig2|fig3|table4|fig4|sharding|quantized-state|ablation|all",
        )],
    };
    let args = Args::parse(&spec, argv)?;
    let id = args.positional.first().context("missing experiment id")?.as_str();
    let mut opts = exp_options(&args)?;
    match id {
        "table1" | "fig1" => {
            opts.csv |= id == "fig1";
            experiments::table1(&opts)
        }
        "table2" => experiments::table2(&opts),
        "fig2" => experiments::fig2(&opts),
        "fig3" => experiments::fig3(&opts),
        "sharding" => experiments::sharding(&opts),
        "quantized-state" => experiments::quantized_state(&opts),
        "table4" | "fig4" => {
            opts.csv |= id == "fig4";
            experiments::table4(&opts)
        }
        "ablation" => {
            extensor::coordinator::ablation::run(&opts.out_dir, opts.steps as usize, opts.seed)
        }
        "all" => {
            opts.csv = true;
            experiments::table1(&opts)?;
            experiments::table2(&opts)?;
            experiments::fig2(&opts)?;
            experiments::fig3(&opts)?;
            experiments::table4(&opts)?;
            experiments::sharding(&opts)?;
            experiments::quantized_state(&opts)?;
            extensor::coordinator::ablation::run(&opts.out_dir, opts.steps as usize, opts.seed)
        }
        other => bail!("unknown experiment '{other}'"),
    }
}

fn cmd_plan_index(argv: &[String]) -> Result<()> {
    let spec = Spec {
        name: "plan-index",
        about: "print factorization tables (paper Tables 3 / B.1)",
        options: vec![("preset", Some("transformer"), "resnet18 | transformer")],
        flags: vec![],
        positional: vec![],
    };
    let args = Args::parse(&spec, argv)?;
    experiments::plan_index(args.get("preset").unwrap_or("transformer"))
}

fn cmd_memory_report(argv: &[String]) -> Result<()> {
    let spec = Spec {
        name: "memory-report",
        about: "optimizer state accounting for a transformer config",
        options: vec![
            ("layers", Some("6"), "transformer layers"),
            ("vocab", Some("2000"), "vocabulary size"),
            ("d-model", Some("512"), "model width"),
            ("d-ff", Some("2048"), "feed-forward width"),
        ],
        flags: vec![],
        positional: vec![],
    };
    let args = Args::parse(&spec, argv)?;
    experiments::memory_report(
        args.get_usize("layers")?,
        args.get_usize("vocab")?,
        args.get_usize("d-model")?,
        args.get_usize("d-ff")?,
    )
}

fn cmd_list_artifacts(argv: &[String]) -> Result<()> {
    let spec = Spec {
        name: "list-artifacts",
        about: "show compiled AOT artifacts",
        options: vec![("dir", Some("artifacts"), "artifact directory")],
        flags: vec![],
        positional: vec![],
    };
    let args = Args::parse(&spec, argv)?;
    let dir = PathBuf::from(args.get("dir").unwrap_or("artifacts"));
    let mut names: Vec<String> = std::fs::read_dir(&dir)
        .with_context(|| format!("read {dir:?} (run `make artifacts`)"))?
        .filter_map(|e| e.ok())
        .filter_map(|e| {
            let n = e.file_name().to_string_lossy().to_string();
            n.strip_suffix(".json").map(|s| s.to_string())
        })
        .collect();
    names.sort();
    println!("{:<22} {:>12} {:>12}  kind", "artifact", "params", "opt state");
    for name in names {
        if let Ok(m) = extensor::runtime::Manifest::load(&dir, &name) {
            println!(
                "{:<22} {:>12} {:>12}  {:?}",
                m.name,
                m.total_params(),
                m.total_opt_state(),
                m.kind
            );
        }
    }
    Ok(())
}

//! Test-support utilities (property-based testing harness, shared model
//! shapes). Compiled into the library (not `#[cfg(test)]`) so integration
//! tests and benches can reuse the generators.

pub mod bench;
pub mod prop;

use crate::optim::GroupSpec;

/// Transformer-shaped parameter groups (the Table 1 model family) for
/// experiments and benches that drive the pure-rust optimizer suite
/// without AOT artifacts. One definition so the scaling experiment and
/// `benches/sharded_step.rs` can never drift apart.
pub fn transformer_groups(layers: usize, vocab: usize, dm: usize, dff: usize) -> Vec<GroupSpec> {
    let mut g = vec![GroupSpec::new("embed", &[vocab, dm])];
    for l in 0..layers {
        for nm in ["wq", "wk", "wv", "wo"] {
            g.push(GroupSpec::new(format!("l{l}.{nm}"), &[dm, dm]));
        }
        g.push(GroupSpec::new(format!("l{l}.ln1"), &[dm]));
        g.push(GroupSpec::new(format!("l{l}.ln2"), &[dm]));
        g.push(GroupSpec::new(format!("l{l}.ff1"), &[dm, dff]));
        g.push(GroupSpec::new(format!("l{l}.ff1b"), &[dff]));
        g.push(GroupSpec::new(format!("l{l}.ff2"), &[dff, dm]));
        g.push(GroupSpec::new(format!("l{l}.ff2b"), &[dm]));
    }
    g.push(GroupSpec::new("ln_f", &[dm]));
    g
}

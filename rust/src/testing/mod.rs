//! Test-support utilities (property-based testing harness). Compiled into
//! the library (not `#[cfg(test)]`) so integration tests and benches can
//! reuse the generators.

pub mod bench;
pub mod prop;

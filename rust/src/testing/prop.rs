//! Minimal property-based testing harness.
//!
//! The offline environment has no `proptest` crate, so this module provides
//! the subset we need: a seeded case generator, a fixed case budget per
//! property, and failure reports that print the case seed so a failing case
//! can be replayed deterministically (`ETPROP_SEED=<n> cargo test`).

use crate::util::rng::Pcg64;

/// Per-case generator handed to property bodies.
pub struct Gen {
    pub rng: Pcg64,
    pub case: usize,
}

impl Gen {
    /// Uniform usize in [lo, hi] inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f32 in [lo, hi).
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.rng.next_f32()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Random tensor dims: order in [1, max_order], each dim in [1, max_dim].
    pub fn dims_upto(&mut self, max_order: usize, max_dim: usize) -> Vec<usize> {
        let p = self.usize_in(1, max_order);
        (0..p).map(|_| self.usize_in(1, max_dim)).collect()
    }

    /// A gradient-like vector: mix of dense gaussian, sparse, and large-range
    /// values — the regimes that stress accumulator numerics.
    pub fn grad_vec(&mut self, n: usize) -> Vec<f32> {
        let style = self.usize_in(0, 2);
        let mut v = vec![0.0f32; n];
        match style {
            0 => self.rng.fill_normal(&mut v, 1.0),
            1 => {
                // sparse: ~10% nonzero
                for x in v.iter_mut() {
                    if self.rng.next_f32() < 0.1 {
                        *x = self.rng.normal() as f32 * 3.0;
                    }
                }
            }
            _ => {
                // wide dynamic range
                for x in v.iter_mut() {
                    let e = self.f32_in(-6.0, 4.0);
                    *x = (self.rng.normal() as f32) * 10f32.powf(e);
                }
            }
        }
        v
    }
}

/// Run `cases` random cases of a property. Panics (with the replay seed) on
/// the first failing case. `ETPROP_SEED` pins the base seed.
pub fn props(name: &str, cases: usize, mut body: impl FnMut(&mut Gen)) {
    let base_seed: u64 = std::env::var("ETPROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xE7E7_0001);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut g = Gen { rng: Pcg64::new(seed, 0x9e37), case };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut g)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed on case {case} (replay with ETPROP_SEED={seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_ranges_hold() {
        props("gen_ranges", 50, |g| {
            let x = g.usize_in(3, 9);
            assert!((3..=9).contains(&x));
            let f = g.f32_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
            let dims = g.dims_upto(4, 9);
            assert!(!dims.is_empty() && dims.len() <= 4);
            assert!(dims.iter().all(|&d| (1..=9).contains(&d)));
        });
    }

    #[test]
    #[should_panic(expected = "replay with ETPROP_SEED=")]
    fn failure_reports_seed() {
        props("always_fails", 3, |_| panic!("boom"));
    }
}

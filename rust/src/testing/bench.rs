//! Minimal benchmark harness (no criterion offline): warmup + timed
//! iterations, reporting median / p10 / p90 wall time. Used by the
//! `cargo bench` targets (`harness = false`).

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
}

impl BenchResult {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.median_ns / 1e9)
    }

    pub fn report(&self) {
        println!(
            "{:<40} {:>12} {:>12} {:>12}   ({} iters)",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.p10_ns),
            fmt_ns(self.p90_ns),
            self.iters
        );
    }

    pub fn report_with_rate(&self, items_per_iter: f64, unit: &str) {
        println!(
            "{:<40} {:>12} median   {:>14.0} {unit}",
            self.name,
            fmt_ns(self.median_ns),
            self.throughput(items_per_iter)
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Print the standard header once per bench binary.
pub fn header(title: &str) {
    println!("\n### {title}");
    println!(
        "{:<40} {:>12} {:>12} {:>12}",
        "benchmark", "median", "p10", "p90"
    );
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pick = |p: f64| samples[((p * (samples.len() - 1) as f64).round() as usize).min(samples.len() - 1)];
    BenchResult {
        name: name.to_string(),
        iters,
        median_ns: pick(0.5),
        p10_ns: pick(0.1),
        p90_ns: pick(0.9),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = bench("noop-ish", 2, 20, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.median_ns > 0.0);
        assert!(r.p10_ns <= r.median_ns && r.median_ns <= r.p90_ns);
    }

    #[test]
    fn formats() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5e4).contains("us"));
        assert!(fmt_ns(5e7).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}

//! The paper's §5.4 synthetic convex substrate: multinomial logistic
//! regression on ill-conditioned Gaussian data.
//!
//! Data model (paper): `x_i ~ N(0, Sigma)` in `R^512` with
//! `cond(Sigma) ~ 1e4`; a ground-truth Gaussian `W in R^{10x512}`; labels
//! `Pr[y=j] ∝ exp((W x)_j)`. The optimization problem is the empirical
//! negative log-likelihood in `W` — convex, so preconditioner quality is
//! isolated from non-convex effects.
//!
//! The covariance is constructed as `H D H` where `D` has log-spaced
//! eigenvalues spanning the requested condition number and `H` is a product
//! of random Householder reflections (orthogonal, cheap to apply), so the
//! ill-conditioning is *not* axis-aligned — a diagonal preconditioner
//! cannot trivially undo it, which is exactly the regime where the
//! expressivity tradeoff of Figure 3 shows up.

pub mod softmax;

pub use softmax::SoftmaxRegression;

use crate::util::rng::Pcg64;

/// A generated dataset: row-major `x` (`n x d`) and labels in `[k]`.
pub struct ConvexDataset {
    pub n: usize,
    pub d: usize,
    pub k: usize,
    pub x: Vec<f32>,
    pub y: Vec<u32>,
    pub w_true: Vec<f32>,
    /// Householder unit vectors used to rotate the diagonal covariance.
    hs: Vec<Vec<f32>>,
    /// Per-eigendirection standard deviations (log-spaced).
    stds: Vec<f32>,
}

/// Configuration mirroring §5.4's setup.
#[derive(Clone, Debug)]
pub struct ConvexConfig {
    pub n: usize,
    pub d: usize,
    pub k: usize,
    pub cond: f64,
    pub householder: usize,
    pub seed: u64,
}

impl Default for ConvexConfig {
    fn default() -> Self {
        // Paper: 1e4 samples of x in R^512, 10 classes, cond ~ 1e4.
        ConvexConfig { n: 10_000, d: 512, k: 10, cond: 1e4, householder: 8, seed: 0x5ec4 }
    }
}

impl ConvexDataset {
    pub fn generate(cfg: &ConvexConfig) -> ConvexDataset {
        let mut rng = Pcg64::seeded(cfg.seed);
        let mut data_rng = rng.fork("data");
        let mut w_rng = rng.fork("w_true");
        let mut hh_rng = rng.fork("householder");

        // Log-spaced standard deviations: eigenvalues of Sigma span
        // [1, cond], so stddevs span [1, sqrt(cond)].
        let stds: Vec<f32> = (0..cfg.d)
            .map(|j| {
                let t = j as f64 / (cfg.d - 1).max(1) as f64;
                (cfg.cond.powf(t)).sqrt() as f32
            })
            .collect();

        // Householder vectors (unit norm).
        let mut hs: Vec<Vec<f32>> = Vec::with_capacity(cfg.householder);
        for _ in 0..cfg.householder {
            let mut v = vec![0.0f32; cfg.d];
            hh_rng.fill_normal(&mut v, 1.0);
            let norm = (crate::util::math::sq_norm(&v)).sqrt() as f32;
            for x in v.iter_mut() {
                *x /= norm;
            }
            hs.push(v);
        }

        // True weights.
        let mut w_true = vec![0.0f32; cfg.k * cfg.d];
        w_rng.fill_normal(&mut w_true, 1.0 / (cfg.d as f32).sqrt());

        let mut x = vec![0.0f32; cfg.n * cfg.d];
        let mut y = vec![0u32; cfg.n];
        let mut logits = vec![0.0f32; cfg.k];
        for i in 0..cfg.n {
            let row = &mut x[i * cfg.d..(i + 1) * cfg.d];
            data_rng.fill_normal(row, 1.0);
            for (v, &s) in row.iter_mut().zip(&stds) {
                *v *= s;
            }
            // Apply Householder reflections: row -= 2 (h . row) h
            for h in &hs {
                let dot = crate::util::math::dot(h, row) as f32;
                for (r, &hv) in row.iter_mut().zip(h) {
                    *r -= 2.0 * dot * hv;
                }
            }
            // Label from the log-linear model.
            for c in 0..cfg.k {
                logits[c] =
                    crate::util::math::dot(&w_true[c * cfg.d..(c + 1) * cfg.d], row) as f32;
            }
            crate::util::math::softmax_inplace(&mut logits);
            let weights: Vec<f64> = logits.iter().map(|&p| p as f64).collect();
            y[i] = data_rng.categorical(&weights) as u32;
        }
        ConvexDataset { n: cfg.n, d: cfg.d, k: cfg.k, x, y, w_true, hs, stds }
    }

    /// The `j`-th eigendirection of the constructed covariance: the basis
    /// vector `e_j` pushed through the Householder chain. Along this
    /// direction the population variance is `stds[j]^2`.
    pub fn eigendirection(&self, j: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; self.d];
        v[j] = 1.0;
        for h in &self.hs {
            let dot = crate::util::math::dot(h, &v) as f32;
            for (r, &hv) in v.iter_mut().zip(h) {
                *r -= 2.0 * dot * hv;
            }
        }
        v
    }

    /// Population standard deviation along eigendirection `j`.
    pub fn eigen_std(&self, j: usize) -> f32 {
        self.stds[j]
    }

    /// Empirical variance of sample projections along a unit direction.
    pub fn directional_variance(&self, v: &[f32]) -> f64 {
        let mut var = 0.0f64;
        for i in 0..self.n {
            let proj = crate::util::math::dot(&self.x[i * self.d..(i + 1) * self.d], v);
            var += proj * proj;
        }
        var / self.n as f64
    }

    /// Empirical condition-number proxy: variance ratio along the extreme
    /// constructed eigendirections.
    pub fn variance_spread(&self) -> f64 {
        let lo = self.directional_variance(&self.eigendirection(0));
        let hi = self.directional_variance(&self.eigendirection(self.d - 1));
        hi / lo
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ConvexConfig {
        ConvexConfig { n: 500, d: 32, k: 4, cond: 1e4, householder: 4, seed: 7 }
    }

    #[test]
    fn generates_right_shapes() {
        let cfg = tiny();
        let ds = ConvexDataset::generate(&cfg);
        assert_eq!(ds.x.len(), cfg.n * cfg.d);
        assert_eq!(ds.y.len(), cfg.n);
        assert!(ds.y.iter().all(|&c| (c as usize) < cfg.k));
        // all classes present in a 500-sample draw
        for c in 0..cfg.k as u32 {
            assert!(ds.y.contains(&c), "class {c} never sampled");
        }
    }

    #[test]
    fn deterministic() {
        let a = ConvexDataset::generate(&tiny());
        let b = ConvexDataset::generate(&tiny());
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn ill_conditioned() {
        let ds = ConvexDataset::generate(&tiny());
        // Along the constructed extreme eigendirections the empirical
        // variance ratio must be within sampling error of cond = 1e4.
        let spread = ds.variance_spread();
        assert!(
            spread > 1e3 && spread < 1e5,
            "spread {spread} not within an order of magnitude of 1e4"
        );
    }

    #[test]
    fn labels_correlate_with_wtrue() {
        // Predicting with w_true should beat chance substantially.
        let cfg = tiny();
        let ds = ConvexDataset::generate(&cfg);
        let mut correct = 0usize;
        for i in 0..ds.n {
            let row = &ds.x[i * ds.d..(i + 1) * ds.d];
            let mut best = (f64::NEG_INFINITY, 0usize);
            for c in 0..ds.k {
                let s = crate::util::math::dot(&ds.w_true[c * ds.d..(c + 1) * ds.d], row);
                if s > best.0 {
                    best = (s, c);
                }
            }
            if best.1 as u32 == ds.y[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / ds.n as f64;
        assert!(acc > 1.5 / cfg.k as f64, "accuracy {acc} vs chance {}", 1.0 / cfg.k as f64);
    }
}

//! Multinomial logistic (softmax) regression loss and gradient — the convex
//! objective of §5.4. Full-batch or mini-batch; f32 data with f64 loss
//! accumulation.

use super::ConvexDataset;
use crate::util::math::log_sum_exp;

/// Softmax-regression objective over a dataset; weights are a flat `k x d`
/// row-major matrix.
pub struct SoftmaxRegression<'a> {
    ds: &'a ConvexDataset,
}

impl<'a> SoftmaxRegression<'a> {
    pub fn new(ds: &'a ConvexDataset) -> Self {
        SoftmaxRegression { ds }
    }

    pub fn dim(&self) -> usize {
        self.ds.k * self.ds.d
    }

    /// Mean negative log-likelihood over the index set.
    pub fn loss(&self, w: &[f32], idx: &[usize]) -> f64 {
        assert_eq!(w.len(), self.dim());
        let (d, k) = (self.ds.d, self.ds.k);
        let mut logits = vec![0.0f32; k];
        let mut total = 0.0f64;
        for &i in idx {
            let row = &self.ds.x[i * d..(i + 1) * d];
            for c in 0..k {
                logits[c] = crate::util::math::dot(&w[c * d..(c + 1) * d], row) as f32;
            }
            let lse = log_sum_exp(&logits);
            total += (lse - logits[self.ds.y[i] as usize]) as f64;
        }
        total / idx.len().max(1) as f64
    }

    /// Mean NLL and its gradient wrt `w` over the index set. `grad` must be
    /// zeroed or will be overwritten.
    ///
    /// Hot path of the Figure 3 experiment (full-batch over 1e4 samples):
    /// logits and the gradient accumulation are written as plain f32 inner
    /// loops over contiguous slices so LLVM auto-vectorizes them; loss
    /// accumulation stays f64. (~8x over the scalar-f64 `dot`/`axpy`
    /// version — see EXPERIMENTS.md §Perf.)
    pub fn loss_grad(&self, w: &[f32], idx: &[usize], grad: &mut [f32]) -> f64 {
        assert_eq!(w.len(), self.dim());
        assert_eq!(grad.len(), self.dim());
        let (d, k) = (self.ds.d, self.ds.k);
        grad.iter_mut().for_each(|g| *g = 0.0);
        let mut logits = vec![0.0f32; k];
        let mut total = 0.0f64;
        let scale = 1.0 / idx.len().max(1) as f32;
        for &i in idx {
            let row = &self.ds.x[i * d..(i + 1) * d];
            for (c, l) in logits.iter_mut().enumerate() {
                let wc = &w[c * d..(c + 1) * d];
                let mut acc = 0.0f32;
                for (&wj, &xj) in wc.iter().zip(row) {
                    acc += wj * xj;
                }
                *l = acc;
            }
            let lse = log_sum_exp(&logits);
            let yi = self.ds.y[i] as usize;
            total += (lse - logits[yi]) as f64;
            for c in 0..k {
                let p = (logits[c] - lse).exp();
                let coef = (p - if c == yi { 1.0 } else { 0.0 }) * scale;
                if coef != 0.0 {
                    let gc = &mut grad[c * d..(c + 1) * d];
                    for (gj, &xj) in gc.iter_mut().zip(row) {
                        *gj += coef * xj;
                    }
                }
            }
        }
        total / idx.len().max(1) as f64
    }

    /// Classification accuracy over the index set.
    pub fn accuracy(&self, w: &[f32], idx: &[usize]) -> f64 {
        let (d, k) = (self.ds.d, self.ds.k);
        let mut correct = 0usize;
        for &i in idx {
            let row = &self.ds.x[i * d..(i + 1) * d];
            let mut best = (f64::NEG_INFINITY, 0usize);
            for c in 0..k {
                let s = crate::util::math::dot(&w[c * d..(c + 1) * d], row);
                if s > best.0 {
                    best = (s, c);
                }
            }
            if best.1 as u32 == self.ds.y[i] {
                correct += 1;
            }
        }
        correct as f64 / idx.len().max(1) as f64
    }
}

/// All indices `0..n` (the paper uses the full gradient in its plots).
pub fn full_batch(n: usize) -> Vec<usize> {
    (0..n).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::convex::{ConvexConfig, ConvexDataset};

    fn tiny() -> ConvexDataset {
        ConvexDataset::generate(&ConvexConfig {
            n: 200,
            d: 16,
            k: 3,
            cond: 100.0,
            householder: 2,
            seed: 11,
        })
    }

    #[test]
    fn zero_weights_give_log_k() {
        let ds = tiny();
        let obj = SoftmaxRegression::new(&ds);
        let w = vec![0.0f32; obj.dim()];
        let idx = full_batch(ds.n);
        let loss = obj.loss(&w, &idx);
        assert!((loss - (3.0f64).ln()).abs() < 1e-5, "loss {loss}");
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let ds = tiny();
        let obj = SoftmaxRegression::new(&ds);
        let idx: Vec<usize> = (0..50).collect();
        let mut w: Vec<f32> = (0..obj.dim()).map(|i| ((i * 13 % 7) as f32 - 3.0) * 0.05).collect();
        let mut grad = vec![0.0f32; obj.dim()];
        obj.loss_grad(&w, &idx, &mut grad);
        let h = 1e-3f32;
        for probe in [0usize, 7, obj.dim() / 2, obj.dim() - 1] {
            let orig = w[probe];
            w[probe] = orig + h;
            let lp = obj.loss(&w, &idx);
            w[probe] = orig - h;
            let lm = obj.loss(&w, &idx);
            w[probe] = orig;
            let fd = ((lp - lm) / (2.0 * h as f64)) as f32;
            assert!(
                (fd - grad[probe]).abs() < 1e-2 * (1.0 + fd.abs()),
                "coord {probe}: fd {fd} vs analytic {}",
                grad[probe]
            );
        }
    }

    #[test]
    fn loss_grad_and_loss_agree() {
        let ds = tiny();
        let obj = SoftmaxRegression::new(&ds);
        let idx = full_batch(ds.n);
        let w = vec![0.01f32; obj.dim()];
        let mut grad = vec![0.0f32; obj.dim()];
        let l1 = obj.loss(&w, &idx);
        let l2 = obj.loss_grad(&w, &idx, &mut grad);
        assert!((l1 - l2).abs() < 1e-9);
    }

    #[test]
    fn training_reduces_loss_and_beats_chance() {
        let ds = tiny();
        let obj = SoftmaxRegression::new(&ds);
        let idx = full_batch(ds.n);
        let mut w = vec![0.0f32; obj.dim()];
        let mut grad = vec![0.0f32; obj.dim()];
        let l0 = obj.loss(&w, &idx);
        for _ in 0..100 {
            obj.loss_grad(&w, &idx, &mut grad);
            for (wi, &gi) in w.iter_mut().zip(&grad) {
                *wi -= 0.05 * gi;
            }
        }
        let l1 = obj.loss(&w, &idx);
        assert!(l1 < l0 * 0.9, "{l0} -> {l1}");
        assert!(obj.accuracy(&w, &idx) > 1.0 / 3.0 + 0.05);
    }
}

//! Regret-bound instrumentation (§4 / §5.3, Figure 2).
//!
//! Theorem 4.1 bounds extreme tensoring's regret by
//! `D_inf * sqrt(2 Tr(H_T) Tr(Ĥ_T))` where
//!
//! * `Ĥ_T = diag(eps I + sum_t g_t g_t^T)^{1/2}` — the AdaGrad regularizer,
//! * `H_T = ⊗_i (eps I_{d_i} + sum_t G_t^i)^{1/2p}` — the ET regularizer,
//!
//! so ET's bound is `sqrt(Tr(H_T)/Tr(Ĥ_T))` times AdaGrad's. This module
//! mirrors a training run's gradients into both accumulators and reports
//! the traces and the multiplicative gap (paper measures ≈ 5.7 for ET1 on
//! the LM task).

use crate::tensoring::{EpsMode, SliceAccumulators, TensorIndex};
use anyhow::Result;

/// Tracks `Tr(H_T)` and `Tr(Ĥ_T)` for one parameter group.
pub struct GroupTraceTracker {
    /// ET slice accumulators (PerFactor eps mode — the Theorem 4.1 form).
    et: SliceAccumulators,
    /// Full AdaGrad accumulator `sum_t g_t^2` per coordinate.
    full: Vec<f64>,
    eps: f64,
}

impl GroupTraceTracker {
    pub fn new(dims: &[usize], eps: f32) -> Result<Self> {
        let ix = TensorIndex::new(dims)?;
        let n = ix.numel();
        Ok(GroupTraceTracker {
            et: SliceAccumulators::new(ix, eps, None, EpsMode::PerFactor),
            full: vec![0.0; n],
            eps: eps as f64,
        })
    }

    pub fn observe(&mut self, g: &[f32]) -> Result<()> {
        self.et.accumulate(g)?;
        for (s, &x) in self.full.iter_mut().zip(g) {
            *s += (x as f64) * (x as f64);
        }
        Ok(())
    }

    /// `Tr(H_T)` restricted to this group (Kronecker trace identity).
    pub fn trace_h(&self) -> f64 {
        self.et.trace_h()
    }

    /// `Tr(Ĥ_T)` restricted to this group.
    pub fn trace_h_hat(&self) -> f64 {
        self.full.iter().map(|&s| (self.eps + s).sqrt()).sum()
    }
}

/// Whole-model tracker: one group tracker per parameter group (the paper
/// runs independent copies of Algorithm 1 per group; preconditioners are a
/// tensor sum, so traces add).
pub struct TraceTracker {
    groups: Vec<GroupTraceTracker>,
    names: Vec<String>,
    steps: u64,
}

/// Summary for reporting (Figure 2's bars + the competitive ratio).
#[derive(Clone, Debug)]
pub struct TraceReport {
    pub trace_h: f64,
    pub trace_h_hat: f64,
    /// `sqrt(Tr(H_T)/Tr(Ĥ_T))` — the multiplicative regret-bound gap.
    pub ratio: f64,
    pub steps: u64,
    pub per_group: Vec<(String, f64, f64)>,
}

impl TraceTracker {
    /// `dims_per_group[i]` is the tensor-index dims chosen for group `i`.
    pub fn new(groups: &[(String, Vec<usize>)], eps: f32) -> Result<Self> {
        let mut gs = Vec::with_capacity(groups.len());
        let mut names = Vec::with_capacity(groups.len());
        for (name, dims) in groups {
            gs.push(GroupTraceTracker::new(dims, eps)?);
            names.push(name.clone());
        }
        Ok(TraceTracker { groups: gs, names, steps: 0 })
    }

    /// Observe one step's gradients (one flat slice per group).
    pub fn observe(&mut self, grads: &[&[f32]]) -> Result<()> {
        anyhow::ensure!(grads.len() == self.groups.len(), "group count mismatch");
        for (g, t) in grads.iter().zip(self.groups.iter_mut()) {
            t.observe(g)?;
        }
        self.steps += 1;
        Ok(())
    }

    pub fn report(&self) -> TraceReport {
        let mut h = 0.0;
        let mut hh = 0.0;
        let mut per_group = Vec::with_capacity(self.groups.len());
        for (t, n) in self.groups.iter().zip(&self.names) {
            let (th, thh) = (t.trace_h(), t.trace_h_hat());
            h += th;
            hh += thh;
            per_group.push((n.clone(), th, thh));
        }
        TraceReport {
            trace_h: h,
            trace_h_hat: hh,
            ratio: (h / hh.max(f64::MIN_POSITIVE)).sqrt(),
            steps: self.steps,
            per_group,
        }
    }
}

/// Online regret measurement for the convex experiments: cumulative loss of
/// the learner minus cumulative loss of a fixed comparator.
pub struct RegretMeter {
    cum_learner: f64,
    comparator_losses: Vec<f64>,
    learner_losses: Vec<f64>,
}

impl RegretMeter {
    pub fn new() -> Self {
        RegretMeter { cum_learner: 0.0, comparator_losses: Vec::new(), learner_losses: Vec::new() }
    }

    /// Record one round: the learner's loss `f_t(x_t)` and the comparator's
    /// loss `f_t(x*)` on the same function.
    pub fn observe(&mut self, learner_loss: f64, comparator_loss: f64) {
        self.cum_learner += learner_loss;
        self.learner_losses.push(learner_loss);
        self.comparator_losses.push(comparator_loss);
    }

    /// Regret after all observed rounds.
    pub fn regret(&self) -> f64 {
        self.cum_learner - self.comparator_losses.iter().sum::<f64>()
    }

    /// Regret curve (prefix sums), for plotting sublinearity.
    pub fn regret_curve(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.learner_losses.len());
        let mut acc = 0.0;
        for (l, c) in self.learner_losses.iter().zip(&self.comparator_losses) {
            acc += l - c;
            out.push(acc);
        }
        out
    }
}

impl Default for RegretMeter {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::{props, Gen};

    #[test]
    fn p1_traces_are_equal() {
        // With dims = [n] (p=1), H_T == Ĥ_T, so the ratio is exactly 1.
        let mut t =
            TraceTracker::new(&[("x".into(), vec![24])], 1e-8).unwrap();
        for step in 0..5 {
            let g: Vec<f32> = (0..24).map(|j| ((j + step * 7) % 5) as f32 * 0.3 - 0.5).collect();
            t.observe(&[&g]).unwrap();
        }
        let r = t.report();
        assert!((r.ratio - 1.0).abs() < 1e-6, "ratio {}", r.ratio);
    }

    /// Property (Lemma 4.3 at the trace level): Tr(H_T) >= Tr(Ĥ_T), i.e.
    /// the competitive ratio is always >= 1.
    #[test]
    fn prop_ratio_at_least_one() {
        props("trace_ratio_ge_1", 100, |g: &mut Gen| {
            let dims = g.dims_upto(3, 8);
            let n: usize = dims.iter().product();
            let mut t = TraceTracker::new(&[("x".into(), dims.clone())], 1e-6).unwrap();
            for _ in 0..g.usize_in(1, 4) {
                let grad = g.grad_vec(n);
                t.observe(&[&grad]).unwrap();
            }
            let r = t.report();
            assert!(
                r.ratio >= 1.0 - 1e-4,
                "ratio {} < 1 for dims {dims:?}",
                r.ratio
            );
        });
    }

    #[test]
    fn sparse_gradients_shrink_the_gap() {
        // Perfectly aligned one-hot gradients: slice sums concentrate and
        // the ratio stays near 1; dense uniform gradients inflate it.
        let dims = vec![8, 8];
        let mut sparse = TraceTracker::new(&[("x".into(), dims.clone())], 1e-10).unwrap();
        let mut dense = TraceTracker::new(&[("x".into(), dims.clone())], 1e-10).unwrap();
        let mut g_sparse = vec![0.0f32; 64];
        g_sparse[0] = 1.0;
        let g_dense = vec![0.125f32; 64];
        for _ in 0..10 {
            sparse.observe(&[&g_sparse]).unwrap();
            dense.observe(&[&g_dense]).unwrap();
        }
        let (rs, rd) = (sparse.report().ratio, dense.report().ratio);
        assert!(rs < rd, "sparse {rs} should be < dense {rd}");
    }

    #[test]
    fn regret_meter_prefix_sums() {
        let mut m = RegretMeter::new();
        m.observe(1.0, 0.5);
        m.observe(0.8, 0.5);
        m.observe(0.6, 0.5);
        assert!((m.regret() - 0.9).abs() < 1e-12);
        let curve = m.regret_curve();
        assert_eq!(curve.len(), 3);
        assert!((curve[1] - 0.8).abs() < 1e-12);
    }

    #[test]
    fn multiple_groups_add() {
        let mut t = TraceTracker::new(
            &[("a".into(), vec![4]), ("b".into(), vec![2, 3])],
            1e-8,
        )
        .unwrap();
        let ga = vec![1.0f32; 4];
        let gb = vec![0.5f32; 6];
        t.observe(&[&ga, &gb]).unwrap();
        let r = t.report();
        assert_eq!(r.per_group.len(), 2);
        let sum_h: f64 = r.per_group.iter().map(|(_, h, _)| h).sum();
        assert!((sum_h - r.trace_h).abs() < 1e-9);
    }
}

//! PJRT execution engine: loads an AOT artifact (HLO text + manifest),
//! compiles it on the CPU PJRT client, and drives the train/eval step loop
//! with all model and optimizer state held as XLA literals.
//!
//! Execution contract (verified in `rust/tests/pjrt_smoke.rs`): this
//! client returns one tuple-shaped buffer per execution; we decompose it
//! into leaves and feed the updated state straight into the next step.
//! `shape`/`size_bytes` must never be called on the tuple literal itself
//! (ShapeUtil::ByteSizeOf aborts on tuple shapes in xla_extension 0.5.1).

use super::manifest::{ArtifactKind, Dtype, Init, Manifest, TensorSpec};
use crate::util::rng::Pcg64;
use anyhow::{Context, Result};
use std::path::Path;
use std::sync::Arc;

/// A borrowed per-step data payload matching one manifest `data_inputs`
/// entry.
#[derive(Clone, Copy, Debug)]
pub enum DataArg<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

impl<'a> DataArg<'a> {
    pub fn len(&self) -> usize {
        match self {
            DataArg::F32(x) => x.len(),
            DataArg::I32(x) => x.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn dtype(&self) -> Dtype {
        match self {
            DataArg::F32(_) => Dtype::F32,
            DataArg::I32(_) => Dtype::I32,
        }
    }
}

/// Shared PJRT client (compile once, reuse across artifacts).
#[derive(Clone)]
pub struct Client {
    inner: Arc<xla::PjRtClient>,
}

impl Client {
    pub fn cpu() -> Result<Client> {
        Ok(Client { inner: Arc::new(xla::PjRtClient::cpu()?) })
    }

    pub fn platform(&self) -> String {
        self.inner.platform_name()
    }
}

/// Model + optimizer state as XLA literals, in manifest order.
pub struct TrainState {
    pub params: Vec<xla::Literal>,
    pub opt_state: Vec<xla::Literal>,
    pub step: u64,
}

impl TrainState {
    /// Total f32 scalars held (params + optimizer state).
    pub fn total_scalars(&self) -> usize {
        let count = |ls: &[xla::Literal]| ls.iter().map(|l| l.element_count()).sum::<usize>();
        count(&self.params) + count(&self.opt_state)
    }

    /// Copy a named parameter back to the host (for inspection/tests).
    pub fn param_to_vec(&self, manifest: &Manifest, name: &str) -> Result<Vec<f32>> {
        let i = manifest
            .params
            .iter()
            .position(|p| p.name == name)
            .with_context(|| format!("no param '{name}'"))?;
        Ok(self.params[i].to_vec::<f32>()?)
    }
}

/// A compiled artifact ready to execute.
pub struct Engine {
    pub manifest: Manifest,
    exe: xla::PjRtLoadedExecutable,
}

/// Result of one training step.
#[derive(Clone, Copy, Debug)]
pub struct StepOutput {
    pub loss: f32,
}

/// Result of one eval step.
#[derive(Clone, Copy, Debug)]
pub struct EvalOutput {
    pub total_nll: f64,
    pub token_count: f64,
}

fn literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

fn literal_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// Initialize one tensor per its manifest init rule. RNG is forked per
/// parameter name, so adding/removing a parameter does not shift others'
/// initialization (stable under model evolution).
fn init_tensor(spec: &TensorSpec, root: &mut Pcg64) -> Result<xla::Literal> {
    let mut data = vec![0.0f32; spec.numel()];
    match spec.init {
        Init::Zeros => {}
        Init::Ones => data.iter_mut().for_each(|v| *v = 1.0),
        Init::Normal { scale } => {
            let mut rng = root.fork(&spec.name);
            rng.fill_normal(&mut data, scale);
        }
    }
    literal_f32(&data, &spec.shape)
}

impl Engine {
    /// Load and compile `dir/<name>.{json,hlo.txt}`.
    pub fn load(client: &Client, dir: impl AsRef<Path>, name: &str) -> Result<Engine> {
        let manifest = Manifest::load(&dir, name)?;
        let hlo = manifest.hlo_path.to_str().context("non-utf8 artifact path")?;
        let proto = xla::HloModuleProto::from_text_file(hlo)
            .with_context(|| format!("parse HLO text {hlo}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.inner.compile(&comp).with_context(|| format!("compile {name}"))?;
        Ok(Engine { manifest, exe })
    }

    /// Fresh training state with seeded initialization.
    pub fn init_state(&self, seed: u64) -> Result<TrainState> {
        let mut root = Pcg64::new(seed, 0x1417);
        let params = self
            .manifest
            .params
            .iter()
            .map(|s| init_tensor(s, &mut root))
            .collect::<Result<Vec<_>>>()?;
        let opt_state = self
            .manifest
            .opt_state
            .iter()
            .map(|s| init_tensor(s, &mut root))
            .collect::<Result<Vec<_>>>()?;
        Ok(TrainState { params, opt_state, step: 0 })
    }

    /// Build state from explicit host vectors (golden tests, checkpoints).
    pub fn state_from_vecs(
        &self,
        params: &[Vec<f32>],
        opt_state: &[Vec<f32>],
        step: u64,
    ) -> Result<TrainState> {
        anyhow::ensure!(params.len() == self.manifest.params.len(), "param count mismatch");
        anyhow::ensure!(
            opt_state.len() == self.manifest.opt_state.len(),
            "opt state count mismatch"
        );
        let mk = |specs: &[TensorSpec], vecs: &[Vec<f32>]| -> Result<Vec<xla::Literal>> {
            specs
                .iter()
                .zip(vecs)
                .map(|(s, v)| {
                    anyhow::ensure!(v.len() == s.numel(), "{}: wrong length", s.name);
                    literal_f32(v, &s.shape)
                })
                .collect()
        };
        Ok(TrainState {
            params: mk(&self.manifest.params, params)?,
            opt_state: mk(&self.manifest.opt_state, opt_state)?,
            step,
        })
    }

    /// Validate and materialize the per-step data payloads as literals.
    fn data_literals(&self, data: &[DataArg<'_>]) -> Result<Vec<xla::Literal>> {
        anyhow::ensure!(
            data.len() == self.manifest.data_inputs.len(),
            "expected {} data inputs, got {}",
            self.manifest.data_inputs.len(),
            data.len()
        );
        data.iter()
            .zip(&self.manifest.data_inputs)
            .map(|(arg, spec)| {
                anyhow::ensure!(
                    arg.len() == spec.numel(),
                    "data '{}': len {} != {}",
                    spec.name,
                    arg.len(),
                    spec.numel()
                );
                anyhow::ensure!(
                    arg.dtype() == spec.dtype,
                    "data '{}': dtype mismatch",
                    spec.name
                );
                match arg {
                    DataArg::F32(x) => literal_f32(x, &spec.shape),
                    DataArg::I32(x) => literal_i32(x, &spec.shape),
                }
            })
            .collect()
    }

    fn execute_decomposed(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<&xla::Literal>(inputs)?;
        let mut tuple = result[0][0].to_literal_sync()?;
        let leaves = tuple.decompose_tuple()?;
        anyhow::ensure!(
            leaves.len() == self.manifest.output_arity(),
            "artifact returned {} leaves, manifest says {}",
            leaves.len(),
            self.manifest.output_arity()
        );
        Ok(leaves)
    }

    /// Execute one fused train step; state is replaced by the artifact's
    /// outputs.
    pub fn train_step(
        &self,
        state: &mut TrainState,
        data: &[DataArg<'_>],
        lr: f32,
    ) -> Result<StepOutput> {
        anyhow::ensure!(self.manifest.kind == ArtifactKind::TrainStep, "not a train artifact");
        state.step += 1;
        let data_lits = self.data_literals(data)?;
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(self.manifest.input_arity());
        inputs.extend(state.params.iter());
        inputs.extend(state.opt_state.iter());
        inputs.extend(data_lits.iter());
        let lr_lit = xla::Literal::scalar(lr);
        let step_lit = xla::Literal::scalar(state.step as f32);
        for extra in &self.manifest.extra_inputs {
            match extra.as_str() {
                "lr" => inputs.push(&lr_lit),
                "step" => inputs.push(&step_lit),
                other => anyhow::bail!("unknown extra input '{other}'"),
            }
        }
        let mut leaves = self.execute_decomposed(&inputs)?;
        let loss = leaves[0].to_vec::<f32>()?[0];
        // Replace state with updated tensors (loss | params' | opt').
        let mut it = leaves.drain(..);
        let _ = it.next(); // loss
        for p in state.params.iter_mut() {
            *p = it.next().unwrap();
        }
        for s in state.opt_state.iter_mut() {
            *s = it.next().unwrap();
        }
        Ok(StepOutput { loss })
    }

    /// LM convenience wrapper: single i32 token batch.
    pub fn train_step_tokens(
        &self,
        state: &mut TrainState,
        tokens: &[i32],
        lr: f32,
    ) -> Result<StepOutput> {
        self.train_step(state, &[DataArg::I32(tokens)], lr)
    }

    /// Execute one eval step: returns summed NLL (or summed error count for
    /// classification artifacts) and item count, so the caller can
    /// aggregate exact corpus-level metrics.
    pub fn eval_step(&self, state: &TrainState, data: &[DataArg<'_>]) -> Result<EvalOutput> {
        anyhow::ensure!(self.manifest.kind == ArtifactKind::EvalStep, "not an eval artifact");
        let data_lits = self.data_literals(data)?;
        let mut inputs: Vec<&xla::Literal> =
            Vec::with_capacity(state.params.len() + data_lits.len());
        inputs.extend(state.params.iter());
        inputs.extend(data_lits.iter());
        let leaves = self.execute_decomposed(&inputs)?;
        Ok(EvalOutput {
            total_nll: leaves[0].to_vec::<f32>()?[0] as f64,
            token_count: leaves[1].to_vec::<f32>()?[0] as f64,
        })
    }

    /// Execute a grad step (loss + per-param grads, no state update) — used
    /// by the trace instrumentation (Figure 2) and the golden tests.
    pub fn grad_step(
        &self,
        state: &TrainState,
        data: &[DataArg<'_>],
    ) -> Result<(f32, Vec<Vec<f32>>)> {
        anyhow::ensure!(self.manifest.kind == ArtifactKind::GradStep, "not a grad artifact");
        let data_lits = self.data_literals(data)?;
        let mut inputs: Vec<&xla::Literal> =
            Vec::with_capacity(state.params.len() + data_lits.len());
        inputs.extend(state.params.iter());
        inputs.extend(data_lits.iter());
        let leaves = self.execute_decomposed(&inputs)?;
        let loss = leaves[0].to_vec::<f32>()?[0];
        let grads = leaves[1..]
            .iter()
            .map(|l| Ok(l.to_vec::<f32>()?))
            .collect::<Result<Vec<_>>>()?;
        Ok((loss, grads))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_helpers_roundtrip() {
        let l = literal_f32(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
        assert_eq!(l.element_count(), 6);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let t = literal_i32(&[7, 8], &[2]).unwrap();
        assert_eq!(t.to_vec::<i32>().unwrap(), vec![7, 8]);
    }

    #[test]
    fn init_rules() {
        let mut rng = Pcg64::seeded(1);
        let ones = init_tensor(
            &TensorSpec { name: "ln".into(), shape: vec![4], init: Init::Ones },
            &mut rng,
        )
        .unwrap();
        assert_eq!(ones.to_vec::<f32>().unwrap(), vec![1.0; 4]);
        let zeros = init_tensor(
            &TensorSpec { name: "b".into(), shape: vec![3], init: Init::Zeros },
            &mut rng,
        )
        .unwrap();
        assert_eq!(zeros.to_vec::<f32>().unwrap(), vec![0.0; 3]);
        let normal = init_tensor(
            &TensorSpec { name: "w".into(), shape: vec![256], init: Init::Normal { scale: 0.1 } },
            &mut rng,
        )
        .unwrap();
        let v = normal.to_vec::<f32>().unwrap();
        let rms = (v.iter().map(|&x| x as f64 * x as f64).sum::<f64>() / 256.0).sqrt();
        assert!((rms - 0.1).abs() < 0.03, "rms {rms}");
    }

    #[test]
    fn init_is_stable_per_name() {
        // Same seed, same name -> same values even if other params change.
        let draw = |names: &[&str]| -> Vec<f32> {
            let mut rng = Pcg64::new(9, 0x1417);
            let mut out = Vec::new();
            for n in names {
                let lit = init_tensor(
                    &TensorSpec {
                        name: n.to_string(),
                        shape: vec![8],
                        init: Init::Normal { scale: 1.0 },
                    },
                    &mut rng,
                )
                .unwrap();
                if *n == "target" {
                    out = lit.to_vec::<f32>().unwrap();
                }
            }
            out
        };
        // NOTE: fork() consumes from the root stream, so stability holds
        // only for a fixed parameter *order prefix*; the manifest order is
        // part of the artifact contract, which is what we rely on.
        let a = draw(&["target", "other"]);
        let b = draw(&["target", "different"]);
        assert_eq!(a, b);
    }
}

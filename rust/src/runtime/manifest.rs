//! Artifact manifests — the contract between `python/compile/aot.py` (which
//! writes them) and the rust runtime (which is fully manifest-driven: no
//! model shape is hard-coded on the rust side).
//!
//! Each artifact `artifacts/<name>.hlo.txt` is accompanied by
//! `artifacts/<name>.json`:
//!
//! ```json
//! {
//!   "name": "lm_tiny_et2", "hlo": "lm_tiny_et2.hlo.txt",
//!   "kind": "train_step" | "eval_step" | "grad_step",
//!   "model": {"family": "transformer_lm", "vocab": 2004, ...},
//!   "optimizer": {"kind": "et2", "eps": 1e-8, "beta2": null},
//!   "params":    [{"name": "embed", "shape": [2004,128],
//!                  "init": "normal", "init_scale": 0.02}, ...],
//!   "opt_state": [{"name": "embed.s0", "shape": [2004]}, ...],
//!   "data_inputs": [{"name": "tokens", "shape": [8, 64], "dtype": "i32"}],
//!   "extra_inputs": ["lr", "step"],
//!   "outputs": ["loss", "params", "opt_state"]
//! }
//! ```
//!
//! Input order at execution time is always
//! `params ++ opt_state ++ data_inputs ++ extra_inputs`; output order is
//! `loss` (plus `token_count` for eval) followed by updated params and
//! optimizer state for train steps. aot.py and this module must agree —
//! the cross-layer golden tests in `rust/tests/` enforce it.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Parameter initialization rule (chosen python-side, executed rust-side so
/// the request path never needs python).
#[derive(Clone, Debug, PartialEq)]
pub enum Init {
    /// N(0, scale^2)
    Normal { scale: f32 },
    /// all zeros
    Zeros,
    /// all ones (layer-norm gains)
    Ones,
}

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: Init,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Dtype of a data input (the per-step payload rust uploads).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

/// One per-step data input (token batch, image batch, label batch...).
#[derive(Clone, Debug)]
pub struct DataSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl DataSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    /// (params, opt, tokens, lr, step) -> (loss, params', opt')
    TrainStep,
    /// (params, tokens) -> (total_nll, token_count)
    EvalStep,
    /// (params, tokens) -> (loss, grads...)
    GradStep,
}

/// Parsed manifest for one AOT artifact.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub name: String,
    pub kind: ArtifactKind,
    pub hlo_path: PathBuf,
    pub params: Vec<TensorSpec>,
    pub opt_state: Vec<TensorSpec>,
    pub data_inputs: Vec<DataSpec>,
    pub extra_inputs: Vec<String>,
    pub model: Json,
    pub optimizer: Json,
}

fn parse_init(obj: &Json) -> Result<Init> {
    match obj.get("init").and_then(|j| j.as_str()).unwrap_or("zeros") {
        "normal" => {
            let scale = obj.get("init_scale").and_then(|j| j.as_f64()).unwrap_or(0.02) as f32;
            Ok(Init::Normal { scale })
        }
        "zeros" => Ok(Init::Zeros),
        "ones" => Ok(Init::Ones),
        other => bail!("unknown init '{other}'"),
    }
}

fn parse_specs(arr: &Json, what: &str) -> Result<Vec<TensorSpec>> {
    let items = arr.as_arr().with_context(|| format!("manifest '{what}' not an array"))?;
    items
        .iter()
        .map(|it| {
            let name = it
                .get("name")
                .and_then(|j| j.as_str())
                .with_context(|| format!("{what}: missing name"))?
                .to_string();
            let shape = it
                .get("shape")
                .and_then(|j| j.as_shape())
                .with_context(|| format!("{what} '{name}': bad shape"))?;
            let init = parse_init(it)?;
            Ok(TensorSpec { name, shape, init })
        })
        .collect()
}

impl Manifest {
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("manifest json: {e}"))?;
        let name = j.get("name").and_then(|x| x.as_str()).context("missing name")?.to_string();
        let kind = match j.get("kind").and_then(|x| x.as_str()).context("missing kind")? {
            "train_step" => ArtifactKind::TrainStep,
            "eval_step" => ArtifactKind::EvalStep,
            "grad_step" => ArtifactKind::GradStep,
            other => bail!("unknown artifact kind '{other}'"),
        };
        let hlo_rel = j.get("hlo").and_then(|x| x.as_str()).context("missing hlo")?;
        let params = parse_specs(j.get("params").context("missing params")?, "params")?;
        let opt_state = match j.get("opt_state") {
            Some(arr) => parse_specs(arr, "opt_state")?,
            None => vec![],
        };
        let data_inputs = j
            .get("data_inputs")
            .and_then(|x| x.as_arr())
            .context("missing data_inputs")?
            .iter()
            .map(|it| {
                let name =
                    it.get("name").and_then(|x| x.as_str()).context("data input name")?.to_string();
                let shape =
                    it.get("shape").and_then(|x| x.as_shape()).context("data input shape")?;
                let dtype = match it.get("dtype").and_then(|x| x.as_str()).unwrap_or("i32") {
                    "i32" => Dtype::I32,
                    "f32" => Dtype::F32,
                    other => bail!("unknown data dtype '{other}'"),
                };
                Ok(DataSpec { name, shape, dtype })
            })
            .collect::<Result<Vec<_>>>()?;
        let extra_inputs = match j.get("extra_inputs") {
            Some(Json::Arr(v)) => v
                .iter()
                .map(|x| x.as_str().map(|s| s.to_string()).context("extra_inputs entry"))
                .collect::<Result<Vec<_>>>()?,
            _ => vec![],
        };
        Ok(Manifest {
            name,
            kind,
            hlo_path: dir.join(hlo_rel),
            params,
            opt_state,
            data_inputs,
            extra_inputs,
            model: j.get("model").cloned().unwrap_or(Json::Null),
            optimizer: j.get("optimizer").cloned().unwrap_or(Json::Null),
        })
    }

    /// Load `dir/<name>.json`.
    pub fn load(dir: impl AsRef<Path>, name: &str) -> Result<Manifest> {
        let dir = dir.as_ref();
        let path = dir.join(format!("{name}.json"));
        let text =
            std::fs::read_to_string(&path).with_context(|| format!("read manifest {path:?}"))?;
        let m = Self::parse(&text, dir)?;
        anyhow::ensure!(m.name == name, "manifest name '{}' != file stem '{name}'", m.name);
        Ok(m)
    }

    /// Total number of executable inputs.
    pub fn input_arity(&self) -> usize {
        self.params.len() + self.opt_state.len() + self.data_inputs.len() + self.extra_inputs.len()
    }

    /// Expected output leaf count.
    pub fn output_arity(&self) -> usize {
        match self.kind {
            ArtifactKind::TrainStep => 1 + self.params.len() + self.opt_state.len(),
            ArtifactKind::EvalStep => 2,
            ArtifactKind::GradStep => 1 + self.params.len(),
        }
    }

    /// Parameter groups as optimizer specs (for the rust-native oracle and
    /// memory accounting).
    pub fn group_specs(&self) -> Vec<crate::optim::GroupSpec> {
        self.params.iter().map(|p| crate::optim::GroupSpec::new(&p.name, &p.shape)).collect()
    }

    pub fn total_params(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    pub fn total_opt_state(&self) -> usize {
        self.opt_state.iter().map(|p| p.numel()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "name": "lm_tiny_et2", "kind": "train_step", "hlo": "lm_tiny_et2.hlo.txt",
      "model": {"family": "transformer_lm", "vocab": 100},
      "optimizer": {"kind": "et2", "eps": 1e-8},
      "params": [
        {"name": "embed", "shape": [100, 16], "init": "normal", "init_scale": 0.02},
        {"name": "ln", "shape": [16], "init": "ones"}
      ],
      "opt_state": [
        {"name": "embed.s0", "shape": [100]},
        {"name": "embed.s1", "shape": [16]},
        {"name": "ln.s0", "shape": [16]}
      ],
      "data_inputs": [{"name": "tokens", "shape": [4, 8], "dtype": "i32"}],
      "extra_inputs": ["lr", "step"]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/artifacts")).unwrap();
        assert_eq!(m.name, "lm_tiny_et2");
        assert_eq!(m.kind, ArtifactKind::TrainStep);
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.params[0].init, Init::Normal { scale: 0.02 });
        assert_eq!(m.params[1].init, Init::Ones);
        assert_eq!(m.opt_state.len(), 3);
        assert_eq!(m.data_inputs.len(), 1);
        assert_eq!(m.data_inputs[0].dtype, Dtype::I32);
        assert_eq!(m.data_inputs[0].numel(), 32);
        assert_eq!(m.input_arity(), 2 + 3 + 1 + 2);
        assert_eq!(m.output_arity(), 1 + 2 + 3);
        assert_eq!(m.total_params(), 1616);
        assert_eq!(m.hlo_path, Path::new("/tmp/artifacts/lm_tiny_et2.hlo.txt"));
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(Manifest::parse("{}", Path::new(".")).is_err());
        assert!(Manifest::parse(r#"{"name":"x","kind":"bogus","hlo":"x.hlo","params":[],"data_inputs":[]}"#, Path::new(".")).is_err());
    }

    #[test]
    fn eval_kind_arities() {
        let text = SAMPLE.replace("train_step", "eval_step");
        let m = Manifest::parse(&text, Path::new(".")).unwrap();
        assert_eq!(m.output_arity(), 2);
    }
}

//! PJRT runtime: manifest-driven loading and execution of the AOT artifacts
//! produced by `python/compile/aot.py`. See `engine` for the execution
//! contract and `manifest` for the artifact format.

pub mod engine;
pub mod manifest;

pub use engine::{Client, DataArg, Engine, EvalOutput, StepOutput, TrainState};
pub use manifest::{ArtifactKind, DataSpec, Dtype, Init, Manifest, TensorSpec};

/// Default artifact directory (relative to the repo root / cwd).
pub fn default_artifact_dir() -> std::path::PathBuf {
    std::env::var_os("ET_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}

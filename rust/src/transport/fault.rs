//! Deterministic fault injection for the shard transport layer.
//!
//! [`FaultTransport`] wraps any inner [`ShardTransport`] and injects
//! failures according to a [`FaultPlan`] — a declarative schedule of
//! actions (`kill shard 1 at step 5`, `two timeouts on shard 0 from step
//! 3`, `drop shard 1's second EXPORT`, `corrupt a frame to shard 0 at
//! step 4`). Every scenario that previously needed a hand-timed SIGKILL
//! race becomes a reproducible unit test: triggers are counted in
//! per-shard operation ordinals (steps seen, exports seen), never in
//! wall-clock time, so a plan fires at exactly the same point on every
//! run.
//!
//! Injection semantics, by action:
//!
//! * **kill** — with a process killer installed
//!   ([`FaultTransport::with_killer`], usually wired to
//!   `SocketTransport::pid_of` + SIGKILL) the victim worker is killed for
//!   real and the dispatch is forwarded, so the *genuine* dead-peer error
//!   path (EOF → [`TransportError::Disconnected`]) fires. Without a
//!   killer the wrapper severs the connection itself and synthesizes
//!   `Disconnected` — the right spelling for in-process inners.
//! * **timeout** — the dispatch is swallowed *before* reaching the
//!   worker and [`TransportError::Timeout`] is returned: no worker state
//!   mutates, exactly like a request lost in the network, so a
//!   supervised retry stays bitwise-correct.
//! * **corrupt** — synthesizes the [`TransportError::Protocol`] the wire
//!   layer's frame validation produces on a corrupt length prefix, and
//!   severs the connection (framing is unrecoverable).
//! * **export-drop** — the n-th `EXPORT` on a shard fails with
//!   `Disconnected` mid-stream and the connection is severed, modeling a
//!   peer lost while a snapshot is on the wire.
//!
//! Trigger counters live in the transport (not the connection), so they
//! persist across the reconnects a recovery performs: a fired action
//! stays fired, replayed steps keep advancing the ordinals, and a plan
//! can schedule a second failure *inside* the first recovery's replay
//! window.

use super::{GroupTask, ShardConnection, ShardTransport, TransportError, WorkerSpec};
use crate::optim::StateExport;
use crate::util::rng::Pcg64;
use anyhow::{bail, Result as AnyResult};
use std::sync::{Arc, Mutex, PoisonError};

/// One scheduled failure. `at_step` counts a shard's `next_step` calls
/// (1-based: the engine's k-th dispatched step on that connection slot),
/// `at_export` counts its `export_state` calls.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// SIGKILL (or sever) shard `shard`'s worker at step `at_step`.
    Kill { shard: usize, at_step: u64 },
    /// Swallow `count` consecutive step dispatches to `shard` starting at
    /// step `at_step`, returning `Timeout` for each.
    Timeout { shard: usize, at_step: u64, count: u32 },
    /// Deliver a corrupt frame to `shard` at step `at_step` (surfaces as
    /// `Protocol` and severs the connection).
    Corrupt { shard: usize, at_step: u64 },
    /// Fail shard `shard`'s `at_export`-th state export mid-stream.
    ExportDrop { shard: usize, at_export: u64 },
}

impl FaultAction {
    fn shard(&self) -> usize {
        match self {
            FaultAction::Kill { shard, .. }
            | FaultAction::Timeout { shard, .. }
            | FaultAction::Corrupt { shard, .. }
            | FaultAction::ExportDrop { shard, .. } => *shard,
        }
    }
}

impl std::fmt::Display for FaultAction {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FaultAction::Kill { shard, at_step } => write!(f, "kill@{shard}:{at_step}"),
            FaultAction::Timeout { shard, at_step, count } => {
                write!(f, "timeout@{shard}:{at_step}x{count}")
            }
            FaultAction::Corrupt { shard, at_step } => write!(f, "corrupt@{shard}:{at_step}"),
            FaultAction::ExportDrop { shard, at_export } => {
                write!(f, "export-drop@{shard}:{at_export}")
            }
        }
    }
}

/// A deterministic chaos schedule. The textual grammar (accepted by
/// [`FaultPlan::parse`], produced by `Display`, documented in
/// EXPERIMENTS.md §Recovery) is:
///
/// ```text
/// plan   := action (';' action)*
/// action := kind '@' shard ':' ordinal ['x' count]
/// kind   := 'kill' | 'timeout' | 'corrupt' | 'export-drop'
/// ```
///
/// `ordinal` is a step number for kill/timeout/corrupt and an export
/// ordinal for export-drop; `x count` (timeout only) injects that many
/// consecutive timeouts.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub actions: Vec<FaultAction>,
}

impl FaultPlan {
    pub fn new(actions: Vec<FaultAction>) -> FaultPlan {
        FaultPlan { actions }
    }

    pub fn is_empty(&self) -> bool {
        self.actions.is_empty()
    }

    /// Parse the plan grammar; errors name the offending clause.
    pub fn parse(s: &str) -> AnyResult<FaultPlan> {
        let mut actions = Vec::new();
        for clause in s.split(';').map(str::trim).filter(|c| !c.is_empty()) {
            let (kind, rest) = clause
                .split_once('@')
                .ok_or_else(|| anyhow::anyhow!("fault clause '{clause}': missing '@'"))?;
            let (shard, ordinal) = rest.split_once(':').ok_or_else(|| {
                anyhow::anyhow!("fault clause '{clause}': expected <shard>:<ordinal>")
            })?;
            let shard: usize = shard
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("fault clause '{clause}': bad shard index"))?;
            let (ordinal, count) = match ordinal.split_once('x') {
                Some((n, c)) => (n, Some(c)),
                None => (ordinal, None),
            };
            let n: u64 = ordinal
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("fault clause '{clause}': bad ordinal"))?;
            if n == 0 {
                bail!("fault clause '{clause}': ordinals are 1-based");
            }
            let count: Option<u32> = match count {
                Some(c) => Some(c.trim().parse().map_err(|_| {
                    anyhow::anyhow!("fault clause '{clause}': bad repeat count")
                })?),
                None => None,
            };
            if count == Some(0) {
                bail!("fault clause '{clause}': repeat count must be >= 1");
            }
            let action = match (kind.trim(), count) {
                ("kill", None) => FaultAction::Kill { shard, at_step: n },
                ("timeout", c) => {
                    FaultAction::Timeout { shard, at_step: n, count: c.unwrap_or(1) }
                }
                ("corrupt", None) => FaultAction::Corrupt { shard, at_step: n },
                ("export-drop", None) => FaultAction::ExportDrop { shard, at_export: n },
                (k @ ("kill" | "corrupt" | "export-drop"), Some(_)) => {
                    bail!("fault clause '{clause}': '{k}' does not take a repeat count")
                }
                (k, _) => bail!(
                    "fault clause '{clause}': unknown kind '{k}' \
                     (kill|timeout|corrupt|export-drop)"
                ),
            };
            actions.push(action);
        }
        if actions.is_empty() {
            bail!("empty fault plan");
        }
        Ok(FaultPlan { actions })
    }

    /// Derive a reproducible single-kill plan from a seed: some shard
    /// below `shards` dies at some step in `[2, steps]`. Same seed, same
    /// plan — a property test can sweep seeds without flaking.
    pub fn seeded_kill(seed: u64, shards: usize, steps: u64) -> FaultPlan {
        let mut rng = Pcg64::seeded(seed ^ 0xFA017);
        let shard = rng.below(shards.max(1) as u64) as usize;
        let at_step = 2 + rng.below(steps.max(3) - 2);
        FaultPlan { actions: vec![FaultAction::Kill { shard, at_step }] }
    }
}

impl std::fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut first = true;
        for a in &self.actions {
            if !first {
                write!(f, ";")?;
            }
            write!(f, "{a}")?;
            first = false;
        }
        Ok(())
    }
}

/// Per-shard trigger counters. Lives in the transport so reconnects (and
/// hence recoveries) do not reset the schedule.
#[derive(Default)]
struct ShardOrdinals {
    steps: u64,
    exports: u64,
    timeouts_left: u32,
}

struct FaultState {
    /// Unfired actions; fired ones are removed so they never re-trigger
    /// during a replay.
    pending: Mutex<Vec<FaultAction>>,
    ordinals: Mutex<Vec<ShardOrdinals>>,
}

impl FaultState {
    fn lock_pending(&self) -> std::sync::MutexGuard<'_, Vec<FaultAction>> {
        self.pending.lock().unwrap_or_else(PoisonError::into_inner)
    }

    fn lock_ordinals(&self) -> std::sync::MutexGuard<'_, Vec<ShardOrdinals>> {
        self.ordinals.lock().unwrap_or_else(PoisonError::into_inner)
    }
}

type Killer = dyn Fn(usize) + Send + Sync;

/// A chaos wrapper around any [`ShardTransport`]: connections it hands
/// out count their operations and fire the plan's actions at the
/// scheduled ordinals.
pub struct FaultTransport {
    inner: Arc<dyn ShardTransport>,
    state: Arc<FaultState>,
    killer: Option<Arc<Killer>>,
}

impl FaultTransport {
    pub fn new(inner: Arc<dyn ShardTransport>, plan: FaultPlan) -> FaultTransport {
        FaultTransport {
            inner,
            state: Arc::new(FaultState {
                pending: Mutex::new(plan.actions),
                ordinals: Mutex::new(Vec::new()),
            }),
            killer: None,
        }
    }

    /// Install a real process killer for `kill` actions (e.g. SIGKILL via
    /// `SocketTransport::pid_of`). Kill actions then exercise the genuine
    /// dead-peer error path instead of a synthesized disconnect.
    pub fn with_killer(mut self, killer: impl Fn(usize) + Send + Sync + 'static) -> FaultTransport {
        self.killer = Some(Arc::new(killer));
        self
    }

    /// Actions that have not fired yet (a completed plan returns 0).
    pub fn pending_actions(&self) -> usize {
        self.state.lock_pending().len()
    }
}

impl ShardTransport for FaultTransport {
    fn connect(
        &self,
        shard: usize,
        spec: WorkerSpec,
        queue_cap: usize,
    ) -> Result<Box<dyn ShardConnection>, TransportError> {
        let inner = self.inner.connect(shard, spec, queue_cap)?;
        {
            let mut ords = self.state.lock_ordinals();
            if ords.len() <= shard {
                ords.resize_with(shard + 1, ShardOrdinals::default);
            }
        }
        Ok(Box::new(FaultConnection {
            shard,
            inner: Some(inner),
            state: Arc::clone(&self.state),
            killer: self.killer.clone(),
        }))
    }

    fn name(&self) -> &'static str {
        // Keep the inner family label: the wrapper is transparent to
        // executor naming, and parity tests assert on the inner name.
        self.inner.name()
    }
}

/// What, if anything, to inject for the current dispatch on one shard.
enum Injection {
    Kill,
    Timeout,
    Corrupt,
}

struct FaultConnection {
    shard: usize,
    /// `None` once severed: every subsequent op reports `Disconnected`.
    inner: Option<Box<dyn ShardConnection>>,
    state: Arc<FaultState>,
    killer: Option<Arc<Killer>>,
}

impl FaultConnection {
    fn severed(&self, context: &'static str) -> TransportError {
        TransportError::Disconnected { shard: self.shard, context }
    }

    fn current_step(&self) -> u64 {
        self.state.lock_ordinals().get(self.shard).map(|o| o.steps).unwrap_or(0)
    }

    /// Decide the injection for a step dispatch at the current ordinal,
    /// consuming fired actions.
    fn step_injection(&mut self) -> Option<Injection> {
        let step = self.current_step();
        {
            let ords = self.state.lock_ordinals();
            if ords.get(self.shard).map(|o| o.timeouts_left).unwrap_or(0) > 0 {
                drop(ords);
                if let Some(o) = self.state.lock_ordinals().get_mut(self.shard) {
                    o.timeouts_left -= 1;
                }
                return Some(Injection::Timeout);
            }
        }
        let mut pending = self.state.lock_pending();
        let due = pending.iter().position(|a| {
            a.shard() == self.shard
                && match a {
                    FaultAction::Kill { at_step, .. }
                    | FaultAction::Corrupt { at_step, .. }
                    | FaultAction::Timeout { at_step, .. } => *at_step <= step,
                    FaultAction::ExportDrop { .. } => false,
                }
        })?;
        let action = pending.remove(due);
        drop(pending);
        match action {
            FaultAction::Kill { .. } => Some(Injection::Kill),
            FaultAction::Corrupt { .. } => Some(Injection::Corrupt),
            FaultAction::Timeout { count, .. } => {
                if let Some(o) = self.state.lock_ordinals().get_mut(self.shard) {
                    // This dispatch consumes one; the rest of the storm
                    // drains on subsequent dispatches.
                    o.timeouts_left = count.saturating_sub(1);
                }
                Some(Injection::Timeout)
            }
            FaultAction::ExportDrop { .. } => None,
        }
    }

    /// Whether this shard's next export should fail, consuming the action.
    fn export_due(&mut self) -> bool {
        let exports = {
            let mut ords = self.state.lock_ordinals();
            match ords.get_mut(self.shard) {
                Some(o) => {
                    o.exports += 1;
                    o.exports
                }
                None => return false,
            }
        };
        let mut pending = self.state.lock_pending();
        let due = pending.iter().position(|a| {
            matches!(a, FaultAction::ExportDrop { shard, at_export }
                if *shard == self.shard && *at_export <= exports)
        });
        match due {
            Some(i) => {
                pending.remove(i);
                true
            }
            None => false,
        }
    }
}

impl ShardConnection for FaultConnection {
    fn send_step(&mut self, lr: f32, tasks: Vec<GroupTask>) -> Result<(), TransportError> {
        match self.step_injection() {
            Some(Injection::Timeout) => {
                // Swallowed before the wire: the worker never sees the
                // dispatch, so no state mutates and a retry is bitwise.
                return Err(TransportError::Timeout { shard: self.shard, context: "step dispatch" });
            }
            Some(Injection::Corrupt) => {
                self.inner = None;
                return Err(TransportError::Protocol {
                    shard: self.shard,
                    message: "injected: frame length corrupted".to_string(),
                });
            }
            Some(Injection::Kill) => match (&self.killer, &mut self.inner) {
                (Some(kill), Some(_)) => {
                    // Real kill, then forward: the dead peer surfaces as a
                    // genuine Disconnected on the ack path.
                    kill(self.shard);
                }
                _ => {
                    self.inner = None;
                    return Err(self.severed("step dispatch"));
                }
            },
            None => {}
        }
        match self.inner.as_mut() {
            Some(c) => c.send_step(lr, tasks),
            None => Err(self.severed("step dispatch")),
        }
    }

    fn recv_step_ack(&mut self) -> Result<(), TransportError> {
        match self.inner.as_mut() {
            Some(c) => c.recv_step_ack(),
            None => Err(self.severed("step ack")),
        }
    }

    fn next_step(&mut self) -> Result<(), TransportError> {
        if let Some(o) = self.state.lock_ordinals().get_mut(self.shard) {
            o.steps += 1;
        }
        match self.inner.as_mut() {
            Some(c) => c.next_step(),
            None => Err(self.severed("next_step")),
        }
    }

    fn state_scalars(&mut self) -> Result<(usize, usize), TransportError> {
        match self.inner.as_mut() {
            Some(c) => c.state_scalars(),
            None => Err(self.severed("state query")),
        }
    }

    fn export_state(&mut self) -> Result<StateExport, TransportError> {
        if self.export_due() {
            self.inner = None;
            return Err(self.severed("state export"));
        }
        match self.inner.as_mut() {
            Some(c) => c.export_state(),
            None => Err(self.severed("state export")),
        }
    }

    fn import_state(&mut self, state: StateExport) -> Result<(), TransportError> {
        match self.inner.as_mut() {
            Some(c) => c.import_state(state),
            None => Err(self.severed("state import")),
        }
    }

    fn is_alive(&self) -> bool {
        self.inner.as_ref().is_some_and(|c| c.is_alive())
    }

    fn shutdown(&mut self) -> Result<(), TransportError> {
        match self.inner.as_mut() {
            Some(c) => c.shutdown(),
            None => Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_grammar_round_trips() {
        let text = "kill@1:5;timeout@0:3x2;corrupt@0:4;export-drop@1:2";
        let plan = FaultPlan::parse(text).unwrap();
        assert_eq!(plan.actions.len(), 4);
        assert_eq!(plan.to_string(), text);
        assert_eq!(FaultPlan::parse(&plan.to_string()).unwrap(), plan);
        assert_eq!(
            plan.actions.first(),
            Some(&FaultAction::Kill { shard: 1, at_step: 5 })
        );
    }

    #[test]
    fn plan_grammar_rejects_malformed_clauses() {
        for bad in [
            "",
            "kill@1",
            "kill@x:5",
            "kill@1:0",
            "kill@1:5x2",
            "explode@1:5",
            "timeout@0:3x0",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn seeded_plans_are_reproducible() {
        let a = FaultPlan::seeded_kill(42, 4, 10);
        let b = FaultPlan::seeded_kill(42, 4, 10);
        assert_eq!(a, b);
        let c = FaultPlan::seeded_kill(43, 4, 10);
        // Different seeds usually differ; at minimum both stay in range.
        for p in [&a, &c] {
            match p.actions.first() {
                Some(FaultAction::Kill { shard, at_step }) => {
                    assert!(*shard < 4);
                    assert!((2..=10).contains(at_step));
                }
                other => panic!("unexpected plan {other:?}"),
            }
        }
    }
}

//! Wire format for the socket transport.
//!
//! Frames are little-endian, length-prefixed, built from the
//! `util::codec` primitives — the same ones the ETHC/ETCK checkpoint
//! files use. Every frame starts with a `u32` opcode. Requests flow
//! parent → worker, replies worker → parent; the protocol is strictly
//! serial per connection (one outstanding request), so no sequence
//! numbers are needed.
//!
//! Request frames:
//!
//! ```text
//! SPEC     = OP_SPEC worker_spec
//! STEP     = OP_STEP lr:f32 n:u32 { local_gi:u32 x:f32s g:f32s }*n
//! NEXT     = OP_NEXT                      (no reply)
//! SCALARS  = OP_SCALARS
//! EXPORT   = OP_EXPORT
//! IMPORT   = OP_IMPORT etss-stream        (optim::stream framing)
//! SHUTDOWN = OP_SHUTDOWN                  (no reply; worker exits)
//! ```
//!
//! Reply frames:
//!
//! ```text
//! STEP_OK       = OP_STEP_OK n:u32 { local_gi:u32 x:f32s }*n
//! STEP_ERR      = OP_STEP_ERR msg:str
//! SCALARS_REPLY = OP_SCALARS_REPLY scalars:u64 bytes:u64
//! EXPORT_REPLY  = OP_EXPORT_REPLY etss-stream
//! IMPORT_OK     = OP_IMPORT_OK
//! IMPORT_ERR    = OP_IMPORT_ERR msg:str
//! ```
//!
//! `f32s` is the codec's `u64`-count-prefixed raw `f32` block; `str` is
//! the codec's `u32`-length-prefixed UTF-8 (≤ 4096 bytes — error messages
//! are truncated to fit, the only lossy spot in the protocol). The
//! [`WorkerSpec`] encoding carries a planned spec's `StatePlan` as its
//! canonical JSON text under its own `u64` length prefix with a 16 MiB
//! cap, since plans for many groups can exceed the codec string cap.

use crate::optim::{GroupSpec, Hyper};
use crate::tensoring::{OptimizerKind, StateBackend};
use crate::transport::WorkerSpec;
use crate::util::codec::{
    read_f32, read_str, read_u32, read_u64, write_f32, write_str, write_u32, write_u64,
};
use anyhow::Result;
use std::io::{Read, Write};

// Requests (parent -> worker).
pub const OP_SPEC: u32 = 10;
pub const OP_STEP: u32 = 11;
pub const OP_NEXT: u32 = 12;
pub const OP_SCALARS: u32 = 13;
pub const OP_EXPORT: u32 = 14;
pub const OP_IMPORT: u32 = 15;
pub const OP_SHUTDOWN: u32 = 16;

// Replies (worker -> parent).
pub const OP_STEP_OK: u32 = 20;
pub const OP_STEP_ERR: u32 = 21;
pub const OP_SCALARS_REPLY: u32 = 22;
pub const OP_EXPORT_REPLY: u32 = 23;
pub const OP_IMPORT_OK: u32 = 24;
pub const OP_IMPORT_ERR: u32 = 25;

/// Cap on the serialized `StatePlan` JSON inside a planned spec.
pub const MAX_PLAN_JSON: u64 = 16 << 20;
/// Cap on the number of groups in a spec frame.
pub const MAX_GROUPS: u32 = 1 << 20;
/// Cap on a group's rank (tensor order).
pub const MAX_NDIMS: u32 = 64;
/// Cap on a single group's element count: 2^34 f32 scalars is 64 GiB of
/// parameters, far beyond anything this coordinator schedules, so any
/// larger product is a corrupt or hostile frame rather than a real model.
pub const MAX_SHAPE_NUMEL: u64 = 1 << 34;

/// How many group slots to pre-reserve from a peer-controlled count.
/// Everything beyond this grows by amortized push as frames actually
/// arrive, so a hostile 4-byte count cannot reserve gigabytes up front.
const PREALLOC_GROUPS: usize = 64;

pub(crate) const SPEC_TAG_UNIFORM: u32 = 0;
pub(crate) const SPEC_TAG_PLANNED: u32 = 1;

/// Typed wire-protocol violation. Every malformed-frame failure in this
/// module carries one at the root of its `anyhow` chain, so transport
/// callers (`socket::classify`) can map "the peer broke framing" to
/// [`crate::transport::TransportError::Protocol`] by downcast instead of
/// by string matching.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ProtocolViolation(pub String);

impl std::fmt::Display for ProtocolViolation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "protocol violation: {}", self.0)
    }
}

impl std::error::Error for ProtocolViolation {}

fn bad(msg: impl Into<String>) -> anyhow::Error {
    anyhow::Error::new(ProtocolViolation(msg.into()))
}

pub fn write_op<W: Write>(w: &mut W, op: u32) -> Result<()> {
    write_u32(w, op)
}

pub fn read_op<R: Read>(r: &mut R) -> Result<u32> {
    read_u32(r)
}

/// Write an error message as a codec string, truncating (on a char
/// boundary) to the codec's string cap.
pub fn write_msg<W: Write>(w: &mut W, msg: &str) -> Result<()> {
    let mut end = msg.len().min(crate::util::codec::MAX_STR_LEN);
    while !msg.is_char_boundary(end) {
        end -= 1;
    }
    // The loop above lands on a char boundary, so `get` always succeeds;
    // the fallback keeps this path panic-free by construction.
    write_str(w, msg.get(..end).unwrap_or(""))
}

fn write_opt_f32<W: Write>(w: &mut W, v: Option<f32>) -> Result<()> {
    match v {
        Some(x) => {
            write_u32(w, 1)?;
            write_f32(w, x)
        }
        None => write_u32(w, 0),
    }
}

fn read_opt_f32<R: Read>(r: &mut R) -> Result<Option<f32>> {
    match read_u32(r)? {
        0 => Ok(None),
        1 => Ok(Some(read_f32(r)?)),
        flag => Err(bad(format!("invalid Option<f32> flag {flag}"))),
    }
}

fn write_hyper<W: Write>(w: &mut W, h: &Hyper) -> Result<()> {
    write_f32(w, h.eps)?;
    write_f32(w, h.beta1)?;
    write_opt_f32(w, h.beta2)?;
    write_opt_f32(w, h.et_beta2)?;
    write_str(w, &h.backend.name())
}

fn read_hyper<R: Read>(r: &mut R) -> Result<Hyper> {
    let eps = read_f32(r)?;
    let beta1 = read_f32(r)?;
    let beta2 = read_opt_f32(r)?;
    let et_beta2 = read_opt_f32(r)?;
    let backend_name = read_str(r)?;
    let backend = StateBackend::parse(&backend_name)
        .ok_or_else(|| bad(format!("unknown state backend {backend_name:?}")))?;
    Ok(Hyper { eps, beta2, beta1, et_beta2, backend })
}

fn write_groups<W: Write>(w: &mut W, groups: &[GroupSpec]) -> Result<()> {
    write_u32(w, groups.len() as u32)?;
    for g in groups {
        write_str(w, &g.name)?;
        write_u32(w, g.shape.len() as u32)?;
        for &d in &g.shape {
            write_u64(w, d as u64)?;
        }
    }
    Ok(())
}

fn read_groups<R: Read>(r: &mut R) -> Result<Vec<GroupSpec>> {
    let n = read_u32(r)?;
    if n > MAX_GROUPS {
        return Err(bad(format!("implausible group count {n} (cap {MAX_GROUPS})")));
    }
    // Bounded pre-reserve: the count is peer-controlled, so reserving all
    // `n` slots up front would let a 4-byte frame pin ~48 MiB; growing
    // past PREALLOC_GROUPS costs the peer real bytes per element instead.
    let mut groups = Vec::with_capacity((n as usize).min(PREALLOC_GROUPS));
    for _ in 0..n {
        let name = read_str(r)?;
        let ndims = read_u32(r)?;
        if ndims > MAX_NDIMS {
            return Err(bad(format!("implausible rank {ndims} for group {name:?} (cap {MAX_NDIMS})")));
        }
        let mut shape = Vec::with_capacity(ndims as usize);
        let mut numel: u64 = 1;
        for _ in 0..ndims {
            let d = read_u64(r)?;
            // Zero dims count as 1 so a 0 can't mask an oversized product.
            numel = numel
                .checked_mul(d.max(1))
                .filter(|&m| m <= MAX_SHAPE_NUMEL)
                .ok_or_else(|| {
                    bad(format!(
                        "implausible shape for group {name:?}: element count exceeds cap {MAX_SHAPE_NUMEL}"
                    ))
                })?;
            shape.push(d as usize);
        }
        groups.push(GroupSpec { name, shape });
    }
    Ok(groups)
}

fn write_plan_json<W: Write>(w: &mut W, json: &str) -> Result<()> {
    if json.len() as u64 > MAX_PLAN_JSON {
        return Err(bad(format!("state plan JSON is {} bytes (cap {MAX_PLAN_JSON})", json.len())));
    }
    write_u64(w, json.len() as u64)?;
    w.write_all(json.as_bytes())?;
    Ok(())
}

fn read_plan_json<R: Read>(r: &mut R) -> Result<String> {
    let len = read_u64(r)?;
    if len > MAX_PLAN_JSON {
        return Err(bad(format!("implausible state plan length {len} (cap {MAX_PLAN_JSON})")));
    }
    let mut buf = vec![0u8; len as usize];
    r.read_exact(&mut buf)?;
    String::from_utf8(buf).map_err(|_| bad("state plan JSON is not UTF-8"))
}

/// Serialize a worker spec (the body of an `OP_SPEC` frame).
pub fn write_worker_spec<W: Write>(w: &mut W, spec: &WorkerSpec) -> Result<()> {
    match spec {
        WorkerSpec::Uniform { kind, groups, hyper } => {
            write_u32(w, SPEC_TAG_UNIFORM)?;
            write_groups(w, groups)?;
            write_hyper(w, hyper)?;
            write_str(w, &kind.name())
        }
        WorkerSpec::Planned { groups, plan, hyper } => {
            write_u32(w, SPEC_TAG_PLANNED)?;
            write_groups(w, groups)?;
            write_hyper(w, hyper)?;
            write_plan_json(w, &plan.to_json().to_string())
        }
    }
}

/// Deserialize a worker spec (after the `OP_SPEC` opcode has been read).
pub fn read_worker_spec<R: Read>(r: &mut R) -> Result<WorkerSpec> {
    let tag = read_u32(r)?;
    let groups = read_groups(r)?;
    let hyper = read_hyper(r)?;
    match tag {
        SPEC_TAG_UNIFORM => {
            let kind_name = read_str(r)?;
            let kind = OptimizerKind::parse(&kind_name)
                .ok_or_else(|| bad(format!("unknown optimizer kind {kind_name:?}")))?;
            Ok(WorkerSpec::Uniform { kind, groups, hyper })
        }
        SPEC_TAG_PLANNED => {
            let text = read_plan_json(r)?;
            let json = crate::util::json::Json::parse(&text)
                .map_err(|e| bad(format!("state plan JSON parse: {e:?}")))?;
            let plan = crate::budget::StatePlan::from_json(&json)
                .map_err(|e| bad(format!("state plan decode: {e:#}")))?;
            Ok(WorkerSpec::Planned { groups, plan, hyper })
        }
        tag => Err(bad(format!("unknown worker spec tag {tag}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::budget::{GroupChoice, StatePlan};

    fn groups() -> Vec<GroupSpec> {
        vec![GroupSpec::new("embed", &[40, 8]), GroupSpec::new("bias", &[24])]
    }

    #[test]
    fn uniform_spec_round_trips() {
        let spec = WorkerSpec::Uniform {
            kind: OptimizerKind::Et(2),
            groups: groups(),
            hyper: Hyper {
                eps: 1e-8,
                beta2: Some(0.995),
                beta1: 0.9,
                et_beta2: None,
                backend: StateBackend::q8(),
            },
        };
        let mut buf = Vec::new();
        write_worker_spec(&mut buf, &spec).unwrap();
        let got = read_worker_spec(&mut buf.as_slice()).unwrap();
        match (&spec, &got) {
            (
                WorkerSpec::Uniform { kind, groups, hyper },
                WorkerSpec::Uniform { kind: k2, groups: g2, hyper: h2 },
            ) => {
                assert_eq!(kind, k2);
                assert_eq!(groups, g2);
                assert_eq!(hyper.eps.to_bits(), h2.eps.to_bits());
                assert_eq!(hyper.beta1.to_bits(), h2.beta1.to_bits());
                assert_eq!(hyper.beta2.map(f32::to_bits), h2.beta2.map(f32::to_bits));
                assert_eq!(hyper.et_beta2, h2.et_beta2);
                assert_eq!(hyper.backend, h2.backend);
            }
            _ => panic!("variant changed across the wire"),
        }
    }

    #[test]
    fn planned_spec_round_trips_via_json() {
        let gs = groups();
        let plan = StatePlan {
            budget_bytes: Some(4096),
            per_group: gs
                .iter()
                .map(|g| GroupChoice {
                    group: g.name.clone(),
                    shape: g.shape.clone(),
                    kind: OptimizerKind::AdaGrad,
                    backend: StateBackend::DenseF32,
                    buf_backends: vec![StateBackend::DenseF32],
                    bytes: 4 * g.numel(),
                    expressivity: 1.0,
                })
                .collect(),
        };
        let spec = WorkerSpec::Planned { groups: gs, plan: plan.clone(), hyper: Hyper::default() };
        let mut buf = Vec::new();
        write_worker_spec(&mut buf, &spec).unwrap();
        match read_worker_spec(&mut buf.as_slice()).unwrap() {
            WorkerSpec::Planned { plan: p2, .. } => assert_eq!(plan, p2),
            _ => panic!("variant changed across the wire"),
        }
    }

    #[test]
    fn truncated_spec_is_an_error() {
        let spec = WorkerSpec::Uniform {
            kind: OptimizerKind::Sgd,
            groups: groups(),
            hyper: Hyper::default(),
        };
        let mut buf = Vec::new();
        write_worker_spec(&mut buf, &spec).unwrap();
        buf.truncate(buf.len() / 2);
        assert!(read_worker_spec(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn opcode_and_tag_values_are_pinned() {
        // The wire format is cross-process: renumbering any frame tag is a
        // protocol break between a new parent and an old worker binary.
        // Pinning the values also gives every tag constant a test-side
        // reference, which etlint's wire-exhaustiveness rule checks.
        assert_eq!(
            [OP_SPEC, OP_STEP, OP_NEXT, OP_SCALARS, OP_EXPORT, OP_IMPORT, OP_SHUTDOWN],
            [10, 11, 12, 13, 14, 15, 16]
        );
        assert_eq!(
            [OP_STEP_OK, OP_STEP_ERR, OP_SCALARS_REPLY, OP_EXPORT_REPLY, OP_IMPORT_OK, OP_IMPORT_ERR],
            [20, 21, 22, 23, 24, 25]
        );
        assert_eq!([SPEC_TAG_UNIFORM, SPEC_TAG_PLANNED], [0, 1]);
    }

    #[test]
    fn oversized_group_count_is_a_typed_protocol_error() {
        let mut buf = Vec::new();
        write_u32(&mut buf, 0).unwrap(); // SPEC_TAG_UNIFORM
        write_u32(&mut buf, MAX_GROUPS + 1).unwrap();
        let err = read_worker_spec(&mut buf.as_slice()).unwrap_err();
        assert!(err.chain().any(|c| c.downcast_ref::<ProtocolViolation>().is_some()), "{err:#}");
    }

    #[test]
    fn oversized_shape_product_is_rejected_per_dim() {
        // Each dim fits a u64, but the product overflows the numel cap —
        // the case a per-dim check alone would miss.
        let mut buf = Vec::new();
        write_u32(&mut buf, SPEC_TAG_UNIFORM).unwrap();
        write_u32(&mut buf, 1).unwrap(); // one group
        write_str(&mut buf, "huge").unwrap();
        write_u32(&mut buf, 3).unwrap(); // rank 3
        for _ in 0..3 {
            write_u64(&mut buf, 1 << 30).unwrap();
        }
        let err = read_worker_spec(&mut buf.as_slice()).unwrap_err();
        assert!(err.chain().any(|c| c.downcast_ref::<ProtocolViolation>().is_some()), "{err:#}");
    }

    #[test]
    fn long_error_messages_truncate_on_char_boundary() {
        let msg = "é".repeat(4096); // 2 bytes per char: must cut at 4096, not mid-char
        let mut buf = Vec::new();
        write_msg(&mut buf, &msg).unwrap();
        let got = read_str(&mut buf.as_slice()).unwrap();
        assert_eq!(got.len(), 4096);
        assert!(msg.starts_with(&got));
    }
}

//! Out-of-process shard workers over UNIX domain sockets.
//!
//! The parent binds one listener per shard at `<dir>/shard-<s>.sock`,
//! spawns `ettrain shard-worker --connect <path> --shard <s>` as a child
//! process, and speaks the [`wire`](super::wire) protocol over the
//! accepted stream: strictly serial request → reply frames, one
//! outstanding request per connection.
//!
//! The executor's step path is pipelined (many `send_step`s before the
//! acks are drained), so each connection runs a **proxy thread** that owns
//! the stream: `send_step` enqueues a job and returns, the proxy
//! serializes jobs onto the wire one at a time, and `recv_step_ack`
//! drains the proxy's ack channel. The proxy reads the parameter and
//! gradient slices behind each [`GroupTask`]'s raw pointers at
//! job-processing time and writes the worker's updated parameters back at
//! reply time — both inside the executor's ack barrier, so the borrows
//! are still live (see the `GroupTask` safety contract).
//!
//! Failure handling: reads carry a per-request timeout
//! ([`TransportError::Timeout`]), EOF / broken pipe classify as
//! [`TransportError::Disconnected`], and any fatal transport error makes
//! the proxy drop all queued jobs unprocessed and exit — queued raw
//! pointers are never dereferenced after an error, and the closed ack
//! channel surfaces `Disconnected` to the executor. A step error
//! *reported by the worker* (`OP_STEP_ERR`) is non-fatal, exactly like
//! the in-process transport; on a failed snapshot import the worker exits
//! instead, because a half-applied stream leaves its state unusable.
//!
//! Snapshots cross the wire as the same chunk-framed ETSS stream that
//! ETHC checkpoints embed: exports are produced with
//! [`write_state_stream`] straight from live optimizer state, so the
//! worker's peak extra memory during an export is one chunk, not a full
//! dense copy of its shard state.

use super::wire::{
    read_op, read_worker_spec, write_msg, write_op, write_worker_spec, OP_EXPORT,
    OP_EXPORT_REPLY, OP_IMPORT, OP_IMPORT_ERR, OP_IMPORT_OK, OP_NEXT, OP_SCALARS,
    OP_SCALARS_REPLY, OP_SHUTDOWN, OP_SPEC, OP_STEP, OP_STEP_ERR, OP_STEP_OK,
};
use super::{GroupTask, ShardConnection, ShardTransport, TransportError, TransportTuning, WorkerSpec};
use crate::optim::stream::{import_stream, read_export_stream, write_export_stream,
    write_state_stream, STREAM_CHUNK_NUMEL};
use crate::optim::{Optimizer, StateExport};
use crate::util::codec::{read_f32s, read_str, read_u32, read_u64, write_f32, write_f32s,
    write_u32, write_u64};
use anyhow::{bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Upper bound on tasks per step frame (far above any real bucket count).
const MAX_STEP_TASKS: u32 = 1 << 20;

/// Spawns `ettrain shard-worker` child processes and talks to them over
/// UNIX sockets in `dir`.
pub struct SocketTransport {
    dir: PathBuf,
    worker_bin: PathBuf,
    tuning: TransportTuning,
    /// `(shard, pid)` of every worker this transport spawned, in spawn
    /// order. Exposed for tests (and the fault injector's process killer)
    /// that kill workers to exercise crash recovery.
    pids: Arc<Mutex<Vec<(usize, u32)>>>,
}

impl SocketTransport {
    pub fn new(dir: impl Into<PathBuf>, worker_bin: impl Into<PathBuf>) -> SocketTransport {
        SocketTransport {
            dir: dir.into(),
            worker_bin: worker_bin.into(),
            tuning: TransportTuning::default(),
            pids: Arc::new(Mutex::new(Vec::new())),
        }
    }

    /// Replace the timing knobs (read deadline, connect retry budget).
    pub fn with_tuning(mut self, tuning: TransportTuning) -> SocketTransport {
        self.tuning = tuning;
        self
    }

    /// Every worker PID this transport has spawned (including exited ones).
    pub fn spawned_pids(&self) -> Vec<u32> {
        // A panicked holder can't corrupt a Vec push, so poison is
        // benign: take the data and keep serving.
        self.pids
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .iter()
            .map(|&(_, pid)| pid)
            .collect()
    }

    /// The most recently spawned worker PID for `shard` (reconnects after
    /// recovery spawn a fresh process, so the latest entry wins).
    pub fn pid_of(&self, shard: usize) -> Option<u32> {
        self.pids
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .iter()
            .rev()
            .find(|&&(s, _)| s == shard)
            .map(|&(_, pid)| pid)
    }

    /// Accept with a deadline: `UnixListener` has no native accept timeout,
    /// so poll in non-blocking mode.
    fn accept_deadline(&self, listener: &UnixListener, shard: usize)
        -> Result<UnixStream, TransportError>
    {
        listener
            .set_nonblocking(true)
            .map_err(|e| TransportError::Io { shard, context: "listener setup", source: e })?;
        let deadline = Instant::now() + self.tuning.connect_budget();
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false).map_err(|e| TransportError::Io {
                        shard,
                        context: "accept",
                        source: e,
                    })?;
                    return Ok(stream);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        return Err(TransportError::Timeout { shard, context: "worker connect" });
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => {
                    return Err(TransportError::Io { shard, context: "accept", source: e })
                }
            }
        }
    }
}

impl ShardTransport for SocketTransport {
    fn connect(
        &self,
        shard: usize,
        spec: WorkerSpec,
        queue_cap: usize,
    ) -> Result<Box<dyn ShardConnection>, TransportError> {
        let io_err = |context: &'static str| {
            move |e: std::io::Error| TransportError::Io { shard, context, source: e }
        };
        std::fs::create_dir_all(&self.dir).map_err(io_err("socket dir"))?;
        let sock = self.dir.join(format!("shard-{shard}.sock"));
        if sock.exists() {
            std::fs::remove_file(&sock).map_err(io_err("stale socket removal"))?;
        }
        let listener = UnixListener::bind(&sock).map_err(io_err("bind"))?;
        let child = Command::new(&self.worker_bin)
            .arg("shard-worker")
            .arg("--connect")
            .arg(&sock)
            .arg("--shard")
            .arg(shard.to_string())
            .arg("--retries")
            .arg(self.tuning.connect_retries.to_string())
            .arg("--backoff-ms")
            .arg(self.tuning.backoff_ms.to_string())
            .stdin(Stdio::null())
            .spawn()
            .map_err(io_err("worker spawn"))?;
        self.pids
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .push((shard, child.id()));

        let stream = self.accept_deadline(&listener, shard)?;
        stream
            .set_read_timeout(Some(self.tuning.read_timeout()))
            .map_err(io_err("read timeout"))?;

        // Ship the spec before handing the stream to the proxy; the
        // executor's first state query doubles as the readiness check.
        let reader = stream.try_clone().map_err(io_err("stream clone"))?;
        let mut w = BufWriter::new(stream);
        let max_buf_numel = 2 * spec.max_group_numel();
        (|| -> Result<()> {
            write_op(&mut w, OP_SPEC)?;
            write_worker_spec(&mut w, &spec)?;
            w.flush()?;
            Ok(())
        })()
        .map_err(|e| classify(shard, "spec send", e))?;

        Ok(Box::new(SocketConnection::launch(
            shard,
            BufReader::new(reader),
            w,
            child,
            max_buf_numel,
            queue_cap,
        )?))
    }

    fn name(&self) -> &'static str {
        "socket"
    }
}

/// Classify an `anyhow` failure from the codec/wire layer into a typed
/// transport error by walking the chain for the root `io::Error`. Shared
/// with the TCP transport, whose streams speak the same wire format.
pub(crate) fn classify(shard: usize, context: &'static str, e: anyhow::Error) -> TransportError {
    for cause in e.chain() {
        // Typed framing violations from the wire layer map to Protocol
        // directly — the channel is intact, the peer's bytes are not.
        if let Some(v) = cause.downcast_ref::<crate::transport::wire::ProtocolViolation>() {
            return TransportError::Protocol { shard, message: format!("{context}: {v}") };
        }
        if let Some(ioe) = cause.downcast_ref::<std::io::Error>() {
            return match ioe.kind() {
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => {
                    TransportError::Timeout { shard, context }
                }
                std::io::ErrorKind::UnexpectedEof
                | std::io::ErrorKind::BrokenPipe
                | std::io::ErrorKind::ConnectionReset => {
                    TransportError::Disconnected { shard, context }
                }
                kind => TransportError::Io {
                    shard,
                    context,
                    source: std::io::Error::new(kind, cause.to_string()),
                },
            };
        }
    }
    TransportError::Protocol { shard, message: format!("{context}: {e:#}") }
}

enum ProxyJob {
    Step { lr: f32, tasks: Vec<GroupTask> },
    Next,
    Scalars,
    Export,
    Import(Box<StateExport>),
    Shutdown,
}

enum ProxyReply {
    StepDone,
    Scalars { scalars: usize, bytes: usize },
    State(Box<StateExport>),
    ImportDone,
}

type ProxyAck = Result<ProxyReply, TransportError>;

/// Parent-side handle to one worker process (UNIX socket or TCP — the
/// proxy machinery is generic over the stream).
pub struct SocketConnection {
    shard: usize,
    jobs: SyncSender<ProxyJob>,
    acks: Receiver<ProxyAck>,
    alive: Arc<AtomicBool>,
    proxy: Option<JoinHandle<()>>,
    child: Option<Child>,
}

impl SocketConnection {
    pub(crate) fn launch<R, W>(
        shard: usize,
        reader: BufReader<R>,
        writer: BufWriter<W>,
        child: Child,
        max_buf_numel: usize,
        queue_cap: usize,
    ) -> Result<SocketConnection, TransportError>
    where
        R: Read + Send + 'static,
        W: Write + Send + 'static,
    {
        let (job_tx, job_rx) = sync_channel::<ProxyJob>(queue_cap.max(1));
        let (ack_tx, ack_rx) = sync_channel::<ProxyAck>(queue_cap.max(1));
        let alive = Arc::new(AtomicBool::new(true));
        let alive_proxy = Arc::clone(&alive);
        let proxy = std::thread::Builder::new()
            .name(format!("et-sock-{shard}"))
            .spawn(move || {
                run_proxy(shard, reader, writer, max_buf_numel, job_rx, ack_tx, alive_proxy)
            })
            .map_err(|e| TransportError::Io { shard, context: "proxy thread spawn", source: e })?;
        Ok(SocketConnection {
            shard,
            jobs: job_tx,
            acks: ack_rx,
            alive,
            proxy: Some(proxy),
            child: Some(child),
        })
    }

    fn gone(&self, context: &'static str) -> TransportError {
        TransportError::Disconnected { shard: self.shard, context }
    }

    fn unexpected(&self, context: &'static str) -> TransportError {
        TransportError::Protocol {
            shard: self.shard,
            message: format!("unexpected reply to {context}"),
        }
    }
}

impl ShardConnection for SocketConnection {
    fn send_step(&mut self, lr: f32, tasks: Vec<GroupTask>) -> Result<(), TransportError> {
        self.jobs
            .send(ProxyJob::Step { lr, tasks })
            .map_err(|_| self.gone("step dispatch"))
    }

    fn recv_step_ack(&mut self) -> Result<(), TransportError> {
        match self.acks.recv() {
            Ok(Ok(ProxyReply::StepDone)) => Ok(()),
            Ok(Ok(_)) => Err(self.unexpected("step")),
            Ok(Err(e)) => Err(e),
            Err(_) => Err(self.gone("step ack")),
        }
    }

    fn next_step(&mut self) -> Result<(), TransportError> {
        self.jobs.send(ProxyJob::Next).map_err(|_| self.gone("next_step"))
    }

    fn state_scalars(&mut self) -> Result<(usize, usize), TransportError> {
        self.jobs.send(ProxyJob::Scalars).map_err(|_| self.gone("state query"))?;
        match self.acks.recv() {
            Ok(Ok(ProxyReply::Scalars { scalars, bytes })) => Ok((scalars, bytes)),
            Ok(Ok(_)) => Err(self.unexpected("state query")),
            Ok(Err(e)) => Err(e),
            Err(_) => Err(self.gone("state query")),
        }
    }

    fn export_state(&mut self) -> Result<StateExport, TransportError> {
        self.jobs.send(ProxyJob::Export).map_err(|_| self.gone("state export"))?;
        match self.acks.recv() {
            Ok(Ok(ProxyReply::State(e))) => Ok(*e),
            Ok(Ok(_)) => Err(self.unexpected("state export")),
            Ok(Err(e)) => Err(e),
            Err(_) => Err(self.gone("state export")),
        }
    }

    fn import_state(&mut self, state: StateExport) -> Result<(), TransportError> {
        self.jobs
            .send(ProxyJob::Import(Box::new(state)))
            .map_err(|_| self.gone("state import"))?;
        match self.acks.recv() {
            Ok(Ok(ProxyReply::ImportDone)) => Ok(()),
            Ok(Ok(_)) => Err(self.unexpected("state import")),
            Ok(Err(e)) => Err(e),
            Err(_) => Err(self.gone("state import")),
        }
    }

    fn is_alive(&self) -> bool {
        self.alive.load(Ordering::SeqCst)
    }

    fn shutdown(&mut self) -> Result<(), TransportError> {
        let clean = self.alive.load(Ordering::SeqCst);
        let _ = self.jobs.send(ProxyJob::Shutdown);
        if let Some(h) = self.proxy.take() {
            let _ = h.join();
        }
        self.alive.store(false, Ordering::SeqCst);
        if let Some(mut c) = self.child.take() {
            if !clean {
                // The transport already broke; don't wait on a wedged child.
                let _ = c.kill();
            }
            let _ = c.wait();
        }
        Ok(())
    }
}

impl Drop for SocketConnection {
    fn drop(&mut self) {
        let _ = self.shutdown();
    }
}

/// The connection's I/O thread: strictly serial request → reply. On a
/// fatal transport error it reports the error, drops every queued job
/// unprocessed (so queued `GroupTask` pointers are never dereferenced),
/// and exits, closing both stream halves.
fn run_proxy<R: Read, W: Write>(
    shard: usize,
    mut r: BufReader<R>,
    mut w: BufWriter<W>,
    max_buf_numel: usize,
    jobs: Receiver<ProxyJob>,
    acks: SyncSender<ProxyAck>,
    alive: Arc<AtomicBool>,
) {
    while let Ok(job) = jobs.recv() {
        let (context, outcome): (&'static str, Result<ProxyReply>) = match job {
            ProxyJob::Step { lr, tasks } => {
                ("step", proxy_step(shard, &mut r, &mut w, lr, &tasks))
            }
            ProxyJob::Next => {
                // Fire-and-forget: no ack, but a write failure kills the
                // connection.
                match write_op(&mut w, OP_NEXT).and_then(|()| Ok(w.flush()?)) {
                    Ok(()) => continue,
                    Err(e) => {
                        alive.store(false, Ordering::SeqCst);
                        let _ = acks.send(Err(classify(shard, "next_step", e)));
                        return;
                    }
                }
            }
            ProxyJob::Scalars => ("state query", proxy_scalars(&mut r, &mut w)),
            ProxyJob::Export => ("state export", proxy_export(&mut r, &mut w, max_buf_numel)),
            ProxyJob::Import(state) => ("state import", proxy_import(&mut r, &mut w, &state)),
            ProxyJob::Shutdown => {
                let _ = write_op(&mut w, OP_SHUTDOWN);
                let _ = w.flush();
                return;
            }
        };
        match outcome {
            Ok(reply) => {
                if acks.send(Ok(reply)).is_err() {
                    return; // parent gone
                }
            }
            Err(e) => {
                // Worker-reported failures keep the connection; transport
                // failures end it.
                let err = match e.downcast::<WorkerFailure>() {
                    Ok(wf) => TransportError::Worker { shard, message: wf.0 },
                    Err(e) => {
                        let classified = classify(shard, context, e);
                        alive.store(false, Ordering::SeqCst);
                        let _ = acks.send(Err(classified));
                        return;
                    }
                };
                if acks.send(Err(err)).is_err() {
                    return;
                }
            }
        }
    }
    // Parent dropped the job channel: close down quietly.
    let _ = write_op(&mut w, OP_SHUTDOWN);
    let _ = w.flush();
}

/// A failure the worker *reported* over a healthy connection
/// (`OP_STEP_ERR` / `OP_IMPORT_ERR`), carried through the anyhow layer so
/// the proxy can keep the connection open.
#[derive(Debug)]
struct WorkerFailure(String);

impl std::fmt::Display for WorkerFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for WorkerFailure {}

fn proxy_step<R: Read, W: Write>(
    shard: usize,
    r: &mut BufReader<R>,
    w: &mut BufWriter<W>,
    lr: f32,
    tasks: &[GroupTask],
) -> Result<ProxyReply> {
    let send_span = crate::trace::span(
        crate::trace::SpanKind::WireSend,
        shard as u32,
        crate::trace::NO_JOB,
    );
    write_op(w, OP_STEP)?;
    write_f32(w, lr)?;
    write_u32(w, tasks.len() as u32)?;
    for t in tasks {
        // SAFETY: sound per the GroupTask contract — `t.x`/`t.g` were
        // created from live `&mut [f32]`/`&[f32]` borrows of length
        // `x_len`/`g_len` (so they are non-null, aligned, and initialized),
        // and the executor holds those borrows until it drains our ack, so
        // the pointees outlive this read and nothing else mutates them
        // while the frame is serialized.
        let x = unsafe { std::slice::from_raw_parts(t.x as *const f32, t.x_len) };
        // SAFETY: same contract as `t.x` above, for the gradient slice.
        let g = unsafe { std::slice::from_raw_parts(t.g, t.g_len) };
        write_u32(w, t.local_gi as u32)?;
        write_f32s(w, x)?;
        write_f32s(w, g)?;
    }
    w.flush()?;
    drop(send_span);
    let _recv_span = crate::trace::span(
        crate::trace::SpanKind::WireRecv,
        shard as u32,
        crate::trace::NO_JOB,
    );
    match read_op(r)? {
        OP_STEP_OK => {
            let n = read_task_count(r, tasks.len())?;
            for t in tasks.iter().take(n) {
                let gi = read_u32(r)? as usize;
                anyhow::ensure!(
                    gi == t.local_gi,
                    "step reply group order mismatch: got {gi}, expected {}",
                    t.local_gi
                );
                let updated = read_f32s(r, t.x_len)?;
                anyhow::ensure!(
                    updated.len() == t.x_len,
                    "step reply length mismatch for local group {gi}"
                );
                // SAFETY: `t.x` came from a unique `&mut [f32]` borrow of
                // length `x_len` that the executor keeps alive (and
                // untouched) until our ack, so reconstructing the mutable
                // slice here cannot alias another live reference.
                let x = unsafe { std::slice::from_raw_parts_mut(t.x, t.x_len) };
                x.copy_from_slice(&updated);
            }
            Ok(ProxyReply::StepDone)
        }
        OP_STEP_ERR => {
            let msg = read_str(r)?;
            Err(anyhow::Error::new(WorkerFailure(msg)))
        }
        op => bail!("unexpected step reply opcode {op}"),
    }
}

/// Read the reply task count and require it to match the request exactly.
fn read_task_count<R: Read>(r: &mut BufReader<R>, expect: usize) -> Result<usize> {
    let n = read_u32(r)? as usize;
    anyhow::ensure!(n == expect, "step reply has {n} tasks, request had {expect}");
    Ok(n)
}

fn proxy_scalars<R: Read, W: Write>(
    r: &mut BufReader<R>,
    w: &mut BufWriter<W>,
) -> Result<ProxyReply> {
    write_op(w, OP_SCALARS)?;
    w.flush()?;
    let op = read_op(r)?;
    anyhow::ensure!(op == OP_SCALARS_REPLY, "unexpected scalars reply opcode {op}");
    let scalars = read_u64(r)? as usize;
    let bytes = read_u64(r)? as usize;
    Ok(ProxyReply::Scalars { scalars, bytes })
}

fn proxy_export<R: Read, W: Write>(
    r: &mut BufReader<R>,
    w: &mut BufWriter<W>,
    max_buf_numel: usize,
) -> Result<ProxyReply> {
    write_op(w, OP_EXPORT)?;
    w.flush()?;
    let op = read_op(r)?;
    anyhow::ensure!(op == OP_EXPORT_REPLY, "unexpected export reply opcode {op}");
    let state = read_export_stream(r, max_buf_numel)?;
    Ok(ProxyReply::State(Box::new(state)))
}

fn proxy_import<R: Read, W: Write>(
    r: &mut BufReader<R>,
    w: &mut BufWriter<W>,
    state: &StateExport,
) -> Result<ProxyReply> {
    write_op(w, OP_IMPORT)?;
    write_export_stream(w, state, STREAM_CHUNK_NUMEL)?;
    w.flush()?;
    match read_op(r)? {
        OP_IMPORT_OK => Ok(ProxyReply::ImportDone),
        OP_IMPORT_ERR => {
            let msg = read_str(r)?;
            Err(anyhow::Error::new(WorkerFailure(msg)))
        }
        op => bail!("unexpected import reply opcode {op}"),
    }
}

// ---------------------------------------------------------------------------
// Worker side
// ---------------------------------------------------------------------------

/// Entry point for `ettrain shard-worker`: connect to the parent's socket
/// (retrying with backoff while the parent finishes binding/accepting) and
/// serve the wire protocol until shutdown or parent exit. The retry budget
/// comes from the parent's [`TransportTuning`], forwarded on the command
/// line.
pub fn run_socket_worker(path: &Path, shard: usize, tuning: TransportTuning) -> Result<()> {
    let stream = connect_with_backoff(&tuning, || UnixStream::connect(path))
        .with_context(|| format!("shard {shard}: connecting to {}", path.display()))?;
    serve_stream(stream, shard)
}

/// Retry `connect` under the tuning's backoff schedule. The parent binds
/// the listener before spawning us, so in practice the first attempt
/// succeeds; the retry loop covers slow filesystems and racing restarts.
pub(crate) fn connect_with_backoff<S>(
    tuning: &TransportTuning,
    connect: impl Fn() -> std::io::Result<S>,
) -> Result<S> {
    let mut attempt = 0u32;
    loop {
        match connect() {
            Ok(s) => return Ok(s),
            Err(e) => {
                if attempt + 1 >= tuning.connect_retries {
                    return Err(e).with_context(|| {
                        format!(
                            "worker connect retries exhausted ({} attempts)",
                            tuning.connect_retries
                        )
                    });
                }
                std::thread::sleep(tuning.connect_backoff(attempt));
                attempt += 1;
            }
        }
    }
}

/// Serve one parent connection. Public within the crate so unit tests can
/// drive it over a `UnixStream::pair` without spawning a process.
pub(crate) fn serve_stream(stream: UnixStream, shard: usize) -> Result<()> {
    let reader = stream.try_clone().context("worker stream clone")?;
    serve_duplex(reader, stream, shard)
}

/// The transport-agnostic worker loop: the same protocol serves UNIX
/// sockets and TCP (`tcp::run_tcp_worker`).
pub(crate) fn serve_duplex<R: Read, W: Write>(reader: R, writer: W, shard: usize) -> Result<()> {
    let mut r = BufReader::new(reader);
    let mut w = BufWriter::new(writer);

    let op = read_op(&mut r).context("reading spec frame")?;
    anyhow::ensure!(op == OP_SPEC, "expected OP_SPEC, got opcode {op}");
    let spec = read_worker_spec(&mut r).context("decoding worker spec")?;
    let max_x_numel = spec.groups().iter().map(|g| g.numel()).max().unwrap_or(0);
    // Validated parent-side before spawn; a failure here still exits
    // loudly so the parent's first query reports a dead worker.
    let mut opt = spec.build().with_context(|| format!("shard {shard}: optimizer build"))?;

    loop {
        let op = match read_op(&mut r) {
            Ok(op) => op,
            Err(e) => {
                if is_eof(&e) {
                    return Ok(()); // parent exited; normal teardown
                }
                return Err(e.context("reading request opcode"));
            }
        };
        match op {
            OP_STEP => {
                let lr = crate::util::codec::read_f32(&mut r)?;
                let n = read_u32(&mut r)?;
                anyhow::ensure!(n <= MAX_STEP_TASKS, "implausible step task count {n}");
                // Read the whole request before applying anything so the
                // stream stays framed even when an update fails.
                let mut tasks = Vec::with_capacity(n as usize);
                for _ in 0..n {
                    let gi = read_u32(&mut r)? as usize;
                    let x = read_f32s(&mut r, max_x_numel)?;
                    let g = read_f32s(&mut r, max_x_numel)?;
                    tasks.push((gi, x, g));
                }
                let mut failure: Option<String> = None;
                for (gi, x, g) in tasks.iter_mut() {
                    if let Err(e) = opt.step(*gi, x, g, lr) {
                        failure = Some(format!("shard {shard}, local group {gi}: {e:#}"));
                        break;
                    }
                }
                match failure {
                    None => {
                        write_op(&mut w, OP_STEP_OK)?;
                        write_u32(&mut w, tasks.len() as u32)?;
                        for (gi, x, _) in &tasks {
                            write_u32(&mut w, *gi as u32)?;
                            write_f32s(&mut w, x)?;
                        }
                    }
                    Some(msg) => {
                        write_op(&mut w, OP_STEP_ERR)?;
                        write_msg(&mut w, &msg)?;
                    }
                }
                w.flush()?;
            }
            OP_NEXT => opt.next_step(),
            OP_SCALARS => {
                write_op(&mut w, OP_SCALARS_REPLY)?;
                write_u64(&mut w, opt.state_scalars() as u64)?;
                write_u64(&mut w, opt.state_bytes() as u64)?;
                w.flush()?;
            }
            OP_EXPORT => {
                write_op(&mut w, OP_EXPORT_REPLY)?;
                // Streamed straight from live state: peak extra memory is
                // one chunk, never a dense copy of the shard.
                write_state_stream(&mut w, opt.state(), STREAM_CHUNK_NUMEL)?;
                w.flush()?;
            }
            OP_IMPORT => {
                match import_stream(&mut r, opt.state_mut()) {
                    Ok(()) => {
                        write_op(&mut w, OP_IMPORT_OK)?;
                        w.flush()?;
                    }
                    Err(e) => {
                        // A failed stream import may have half-applied; the
                        // state is unusable, so report and exit.
                        write_op(&mut w, OP_IMPORT_ERR)?;
                        write_msg(&mut w, &format!("shard {shard}: state import: {e:#}"))?;
                        w.flush()?;
                        bail!("shard {shard}: state import failed: {e:#}");
                    }
                }
            }
            OP_SHUTDOWN => return Ok(()),
            // A stray reply opcode or garbage: the stream is unframed, bail.
            op => bail!("shard {shard}: unexpected request opcode {op}"),
        }
    }
}

fn is_eof(e: &anyhow::Error) -> bool {
    e.chain().any(|c| {
        c.downcast_ref::<std::io::Error>()
            .is_some_and(|ioe| ioe.kind() == std::io::ErrorKind::UnexpectedEof)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{GroupSpec, Hyper};
    use crate::tensoring::OptimizerKind;

    fn spec() -> WorkerSpec {
        WorkerSpec::Uniform {
            kind: OptimizerKind::AdaGrad,
            groups: vec![GroupSpec::new("a", &[4]), GroupSpec::new("b", &[2])],
            hyper: Hyper::default(),
        }
    }

    /// Drive `serve_stream` over a socketpair with hand-written frames:
    /// spec, step, scalars, export, import, shutdown.
    #[test]
    fn serve_stream_speaks_the_protocol() {
        let (parent, worker) = UnixStream::pair().unwrap();
        let server = std::thread::spawn(move || serve_stream(worker, 0));

        let mut w = BufWriter::new(parent.try_clone().unwrap());
        let mut r = BufReader::new(parent);
        write_op(&mut w, OP_SPEC).unwrap();
        write_worker_spec(&mut w, &spec()).unwrap();

        // One step over both groups.
        write_op(&mut w, OP_STEP).unwrap();
        write_f32(&mut w, 0.1).unwrap();
        write_u32(&mut w, 2).unwrap();
        let x0 = vec![1.0f32; 4];
        let g0 = vec![0.5f32, -0.5, 1.0, 0.0];
        let x1 = vec![2.0f32; 2];
        let g1 = vec![1.0f32, 2.0];
        write_u32(&mut w, 0).unwrap();
        write_f32s(&mut w, &x0).unwrap();
        write_f32s(&mut w, &g0).unwrap();
        write_u32(&mut w, 1).unwrap();
        write_f32s(&mut w, &x1).unwrap();
        write_f32s(&mut w, &g1).unwrap();
        w.flush().unwrap();

        assert_eq!(read_op(&mut r).unwrap(), OP_STEP_OK);
        assert_eq!(read_u32(&mut r).unwrap(), 2);
        assert_eq!(read_u32(&mut r).unwrap(), 0);
        let got0 = read_f32s(&mut r, 4).unwrap();
        assert_eq!(read_u32(&mut r).unwrap(), 1);
        let got1 = read_f32s(&mut r, 2).unwrap();

        // Reference: the same optimizer stepped inline.
        let groups = vec![GroupSpec::new("a", &[4]), GroupSpec::new("b", &[2])];
        let mut reference =
            crate::optim::build(OptimizerKind::AdaGrad, &groups, &Hyper::default());
        let (mut r0, mut r1) = (x0.clone(), x1.clone());
        reference.step(0, &mut r0, &g0, 0.1).unwrap();
        reference.step(1, &mut r1, &g1, 0.1).unwrap();
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&got0), bits(&r0));
        assert_eq!(bits(&got1), bits(&r1));

        write_op(&mut w, OP_SCALARS).unwrap();
        w.flush().unwrap();
        assert_eq!(read_op(&mut r).unwrap(), OP_SCALARS_REPLY);
        assert_eq!(read_u64(&mut r).unwrap(), 6);
        assert_eq!(read_u64(&mut r).unwrap(), 24);

        write_op(&mut w, OP_EXPORT).unwrap();
        w.flush().unwrap();
        assert_eq!(read_op(&mut r).unwrap(), OP_EXPORT_REPLY);
        let export = read_export_stream(&mut r, 8).unwrap();
        assert_eq!(export.groups.len(), 2);
        for (sv, &gv) in export.groups[0].bufs[0].1.iter().zip(&g0) {
            assert_eq!(*sv, gv * gv);
        }

        write_op(&mut w, OP_IMPORT).unwrap();
        write_export_stream(&mut w, &export, STREAM_CHUNK_NUMEL).unwrap();
        w.flush().unwrap();
        assert_eq!(read_op(&mut r).unwrap(), OP_IMPORT_OK);

        write_op(&mut w, OP_SHUTDOWN).unwrap();
        w.flush().unwrap();
        server.join().unwrap().unwrap();
    }

    #[test]
    fn serve_stream_reports_step_errors_and_survives() {
        let (parent, worker) = UnixStream::pair().unwrap();
        let server = std::thread::spawn(move || serve_stream(worker, 3));

        let mut w = BufWriter::new(parent.try_clone().unwrap());
        let mut r = BufReader::new(parent);
        write_op(&mut w, OP_SPEC).unwrap();
        write_worker_spec(&mut w, &spec()).unwrap();

        // Wrong-length x for group 0.
        write_op(&mut w, OP_STEP).unwrap();
        write_f32(&mut w, 0.1).unwrap();
        write_u32(&mut w, 1).unwrap();
        write_u32(&mut w, 0).unwrap();
        write_f32s(&mut w, &[0.0f32; 2]).unwrap();
        write_f32s(&mut w, &[0.0f32; 2]).unwrap();
        w.flush().unwrap();
        assert_eq!(read_op(&mut r).unwrap(), OP_STEP_ERR);
        let msg = read_str(&mut r).unwrap();
        assert!(msg.contains("shard 3"), "{msg}");

        // The connection must still be usable.
        write_op(&mut w, OP_SCALARS).unwrap();
        w.flush().unwrap();
        assert_eq!(read_op(&mut r).unwrap(), OP_SCALARS_REPLY);
        let _ = read_u64(&mut r).unwrap();
        let _ = read_u64(&mut r).unwrap();

        drop(w);
        drop(r);
        server.join().unwrap().unwrap(); // EOF is a clean exit
    }

    /// A worker that accepts the connection but never replies must produce
    /// `Timeout`, not a hang.
    #[test]
    fn read_timeout_classifies_as_timeout() {
        let (parent, _worker_held_open) = UnixStream::pair().unwrap();
        parent.set_read_timeout(Some(Duration::from_millis(100))).unwrap();
        let mut r = BufReader::new(parent);
        let err = read_op(&mut r).unwrap_err();
        let classified = classify(7, "state query", err);
        assert!(
            matches!(classified, TransportError::Timeout { shard: 7, .. }),
            "{classified}"
        );
    }

    #[test]
    fn eof_classifies_as_disconnected() {
        let (parent, worker) = UnixStream::pair().unwrap();
        drop(worker);
        let mut r = BufReader::new(parent);
        let err = read_op(&mut r).unwrap_err();
        let classified = classify(2, "step", err);
        assert!(
            matches!(classified, TransportError::Disconnected { shard: 2, .. }),
            "{classified}"
        );
    }
}

//! The shard transport layer: how the sharded optimizer executor talks to
//! its workers.
//!
//! PR 1's sharded engine hard-wired workers to `std::thread`s behind an
//! in-process channel. This module abstracts that protocol behind two
//! traits so "a shard" no longer implies "a thread in this process":
//!
//! * [`ShardConnection`] — one live worker: pipelined step dispatch with an
//!   explicit ack barrier, a fire-and-forget step-counter advance, state
//!   scalars, and snapshot export/import;
//! * [`ShardTransport`] — the factory that turns a [`WorkerSpec`] into a
//!   connection, one per shard.
//!
//! Two implementations ship:
//!
//! * [`InProcess`] ([`proto`]) — the refactored PR-1 protocol: a persistent
//!   thread per shard behind bounded `sync_channel`s, handing raw slice
//!   pointers ([`GroupTask`]) to the worker. Zero-copy and bitwise-
//!   identical to the pre-refactor engine (`rust/tests/sharded_parity.rs`
//!   passes unchanged).
//! * [`SocketTransport`] ([`socket`]) — out-of-process workers over UNIX
//!   domain sockets, spawned as `ettrain shard-worker` child processes.
//!   The wire format ([`wire`]) is length-prefixed little-endian frames
//!   reusing the `util::codec` primitives, and snapshots travel as the
//!   same chunk-framed ETSS stream (`optim::stream`) that ETHC checkpoints
//!   embed. Per-request read timeouts, connect retry with backoff, and
//!   typed [`TransportError`]s make worker death (socket EOF / process
//!   kill) a recoverable condition — see
//!   `ShardedOptimizer::{take_snapshot, recover}`.
//!
//! The determinism contract carries over unchanged from the in-process
//! engine: each group is updated by exactly one worker with single-threaded
//! arithmetic, and fan-in is a pure ack barrier, so results are bitwise
//! identical across transports and shard counts.

pub mod proto;
pub mod socket;
pub mod wire;

pub use proto::{GroupTask, InProcess, WorkerSpec};
pub use socket::{run_socket_worker, SocketTransport};

use crate::optim::StateExport;
use anyhow::{bail, Result as AnyResult};

/// Which transport a job should run its shard workers over. The spec-level
/// spelling of the [`ShardTransport`] choice: TOML-able, cheap to compare,
/// and resolved to an actual transport only at execution time (the socket
/// transport needs a scratch directory and a worker binary path).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TransportKind {
    /// Worker threads in this process (the default; zero-copy).
    #[default]
    InProcess,
    /// `ettrain shard-worker` child processes over UNIX sockets.
    Socket,
}

impl TransportKind {
    /// Canonical spelling, matching [`ShardTransport::name`].
    pub fn name(self) -> &'static str {
        match self {
            TransportKind::InProcess => "inproc",
            TransportKind::Socket => "socket",
        }
    }

    /// Parse a config spelling (accepts a few aliases).
    pub fn parse(s: &str) -> AnyResult<TransportKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "inproc" | "in-process" | "inprocess" | "thread" => Ok(TransportKind::InProcess),
            "socket" | "unix" | "uds" => Ok(TransportKind::Socket),
            other => bail!("unknown transport '{other}' (inproc|socket)"),
        }
    }
}

/// What went wrong talking to a shard worker. `Worker` is an
/// application-level failure reported *by* a healthy worker (a failing
/// update rule, a rejected import); everything else means the transport
/// itself broke.
#[derive(Debug)]
pub enum TransportError {
    /// An I/O error on the underlying channel.
    Io { shard: usize, context: &'static str, source: std::io::Error },
    /// The worker is gone: thread exited, socket EOF, process dead.
    Disconnected { shard: usize, context: &'static str },
    /// A reply did not arrive within the transport's read timeout.
    Timeout { shard: usize, context: &'static str },
    /// The worker answered, but with a frame the protocol does not allow
    /// here.
    Protocol { shard: usize, message: String },
    /// The worker reports an application-level failure.
    Worker { shard: usize, message: String },
}

impl TransportError {
    pub fn shard(&self) -> usize {
        match self {
            TransportError::Io { shard, .. }
            | TransportError::Disconnected { shard, .. }
            | TransportError::Timeout { shard, .. }
            | TransportError::Protocol { shard, .. }
            | TransportError::Worker { shard, .. } => *shard,
        }
    }

    /// Whether the connection is unusable after this error (as opposed to a
    /// clean worker-side failure report on a healthy channel).
    pub fn is_fatal(&self) -> bool {
        !matches!(self, TransportError::Worker { .. })
    }
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::Io { shard, context, source } => {
                write!(f, "shard {shard}: i/o error during {context}: {source}")
            }
            TransportError::Disconnected { shard, context } => {
                write!(f, "shard {shard}: worker disconnected during {context}")
            }
            TransportError::Timeout { shard, context } => {
                write!(f, "shard {shard}: worker timed out during {context}")
            }
            TransportError::Protocol { shard, message } => {
                write!(f, "shard {shard}: protocol violation: {message}")
            }
            TransportError::Worker { shard, message } => {
                write!(f, "shard {shard}: worker failure: {message}")
            }
        }
    }
}

impl std::error::Error for TransportError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            TransportError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

/// One live shard worker. Step dispatch is pipelined: any number of
/// [`ShardConnection::send_step`]s (bounded by the connection's queue
/// capacity) may be in flight before the matching
/// [`ShardConnection::recv_step_ack`]s are drained, and the executor MUST
/// drain one ack per send before releasing the parameter/gradient borrows
/// behind the dispatched [`GroupTask`]s — that barrier is the safety
/// contract that makes raw-pointer tasks sound on every transport.
pub trait ShardConnection: Send {
    /// Dispatch one bucket of group updates at learning rate `lr`.
    fn send_step(&mut self, lr: f32, tasks: Vec<GroupTask>) -> Result<(), TransportError>;

    /// Receive one step ack (FIFO with respect to `send_step`s).
    fn recv_step_ack(&mut self) -> Result<(), TransportError>;

    /// Advance the worker optimizer's shared step counter. Ordered before
    /// subsequent steps; never acked.
    fn next_step(&mut self) -> Result<(), TransportError>;

    /// The worker's allocated state footprint `(scalars, bytes)`. Also the
    /// startup readiness check: the first call proves the worker built its
    /// optimizer.
    fn state_scalars(&mut self) -> Result<(usize, usize), TransportError>;

    /// Snapshot the shard-local optimizer state (worker-local group order).
    fn export_state(&mut self) -> Result<StateExport, TransportError>;

    /// Replace the shard-local optimizer state.
    fn import_state(&mut self, state: StateExport) -> Result<(), TransportError>;

    /// Whether the worker is still believed reachable. Cheap; used by crash
    /// recovery to pick the surviving worker set.
    fn is_alive(&self) -> bool;

    /// Graceful shutdown (also attempted on drop).
    fn shutdown(&mut self) -> Result<(), TransportError>;
}

/// A way of launching shard workers. `queue_cap` bounds the number of
/// unacked in-flight requests the connection must tolerate (the executor
/// passes its per-shard bucket count plus slack).
pub trait ShardTransport: Send + Sync {
    fn connect(
        &self,
        shard: usize,
        spec: WorkerSpec,
        queue_cap: usize,
    ) -> Result<Box<dyn ShardConnection>, TransportError>;

    /// Short label for executor names and logs (`"inproc"`, `"socket"`).
    fn name(&self) -> &'static str;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transport_kind_round_trips_and_rejects_junk() {
        for k in [TransportKind::InProcess, TransportKind::Socket] {
            assert_eq!(TransportKind::parse(k.name()).unwrap(), k);
        }
        assert_eq!(TransportKind::parse("unix").unwrap(), TransportKind::Socket);
        assert_eq!(TransportKind::default(), TransportKind::InProcess);
        assert!(TransportKind::parse("carrier-pigeon").is_err());
    }
}

//! The shard transport layer: how the sharded optimizer executor talks to
//! its workers.
//!
//! PR 1's sharded engine hard-wired workers to `std::thread`s behind an
//! in-process channel. This module abstracts that protocol behind two
//! traits so "a shard" no longer implies "a thread in this process":
//!
//! * [`ShardConnection`] — one live worker: pipelined step dispatch with an
//!   explicit ack barrier, a fire-and-forget step-counter advance, state
//!   scalars, and snapshot export/import;
//! * [`ShardTransport`] — the factory that turns a [`WorkerSpec`] into a
//!   connection, one per shard.
//!
//! Two implementations ship:
//!
//! * [`InProcess`] ([`proto`]) — the refactored PR-1 protocol: a persistent
//!   thread per shard behind bounded `sync_channel`s, handing raw slice
//!   pointers ([`GroupTask`]) to the worker. Zero-copy and bitwise-
//!   identical to the pre-refactor engine (`rust/tests/sharded_parity.rs`
//!   passes unchanged).
//! * [`SocketTransport`] ([`socket`]) — out-of-process workers over UNIX
//!   domain sockets, spawned as `ettrain shard-worker` child processes.
//!   The wire format ([`wire`]) is length-prefixed little-endian frames
//!   reusing the `util::codec` primitives, and snapshots travel as the
//!   same chunk-framed ETSS stream (`optim::stream`) that ETHC checkpoints
//!   embed. Per-request read timeouts, connect retry with backoff, and
//!   typed [`TransportError`]s make worker death (socket EOF / process
//!   kill) a recoverable condition — see
//!   `ShardedOptimizer::{take_snapshot, recover}`.
//!
//! The determinism contract carries over unchanged from the in-process
//! engine: each group is updated by exactly one worker with single-threaded
//! arithmetic, and fan-in is a pure ack barrier, so results are bitwise
//! identical across transports and shard counts.

pub mod fault;
pub mod proto;
pub mod socket;
pub mod tcp;
pub mod wire;

pub use fault::{FaultAction, FaultPlan, FaultTransport};
pub use proto::{GroupTask, InProcess, WorkerSpec};
pub use socket::{run_socket_worker, SocketTransport};
pub use tcp::{run_tcp_worker, TcpTransport};

use crate::optim::StateExport;
use anyhow::{bail, Result as AnyResult};
use std::time::Duration;

/// Which transport a job should run its shard workers over. The spec-level
/// spelling of the [`ShardTransport`] choice: TOML-able, cheap to compare,
/// and resolved to an actual transport only at execution time (the socket
/// transport needs a scratch directory and a worker binary path; the TCP
/// transport carries its bind address right here).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum TransportKind {
    /// Worker threads in this process (the default; zero-copy).
    #[default]
    InProcess,
    /// `ettrain shard-worker` child processes over UNIX sockets.
    Socket,
    /// `ettrain shard-worker` child processes over TCP. The address is the
    /// bind host (`"host:port"`); port 0 asks the kernel for an ephemeral
    /// port per shard, which is the only safe spelling when several
    /// engines share a machine.
    Tcp(String),
}

impl TransportKind {
    /// Canonical spelling, matching what [`TransportKind::parse`] accepts
    /// (`"inproc"`, `"socket"`, `"tcp:<addr>"`). Round-trips through spec
    /// TOML.
    pub fn name(&self) -> String {
        match self {
            TransportKind::InProcess => "inproc".to_string(),
            TransportKind::Socket => "socket".to_string(),
            TransportKind::Tcp(addr) => format!("tcp:{addr}"),
        }
    }

    /// Short family label without the address (`"inproc"`, `"socket"`,
    /// `"tcp"`) — matches [`ShardTransport::name`] for the resolved
    /// transport.
    pub fn label(&self) -> &'static str {
        match self {
            TransportKind::InProcess => "inproc",
            TransportKind::Socket => "socket",
            TransportKind::Tcp(_) => "tcp",
        }
    }

    /// Parse a config spelling (accepts a few aliases). `"tcp"` alone
    /// binds loopback with ephemeral ports; `"tcp:<host:port>"` pins the
    /// bind address.
    pub fn parse(s: &str) -> AnyResult<TransportKind> {
        let t = s.trim();
        let lower = t.to_ascii_lowercase();
        if let Some(addr) = lower.strip_prefix("tcp:") {
            if addr.is_empty() {
                bail!("transport 'tcp:' needs an address (e.g. tcp:127.0.0.1:0)");
            }
            return Ok(TransportKind::Tcp(addr.to_string()));
        }
        match lower.as_str() {
            "inproc" | "in-process" | "inprocess" | "thread" => Ok(TransportKind::InProcess),
            "socket" | "unix" | "uds" => Ok(TransportKind::Socket),
            "tcp" => Ok(TransportKind::Tcp(tcp::DEFAULT_BIND.to_string())),
            other => bail!("unknown transport '{other}' (inproc|socket|tcp[:<addr>])"),
        }
    }
}

/// Transport timing knobs, threaded from job specs (`run.transport.*` via
/// TOML or `--set`) down to the socket/TCP transports. Replaces the
/// hardcoded connect-retry/read-timeout constants those transports
/// originally shipped with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TransportTuning {
    /// Per-reply read deadline in milliseconds
    /// (`run.transport.read_timeout_ms`).
    pub read_timeout_ms: u64,
    /// Worker connect attempts before giving up
    /// (`run.transport.connect_retries`).
    pub connect_retries: u32,
    /// Initial connect backoff in milliseconds, doubled per retry and
    /// capped at [`TransportTuning::BACKOFF_CAP_MS`]
    /// (`run.transport.backoff_ms`).
    pub backoff_ms: u64,
}

impl Default for TransportTuning {
    fn default() -> Self {
        // 24 retries of 10ms-doubling-capped-at-500ms backoff spans ~9.6s,
        // matching the old hardcoded 10s connect deadline.
        TransportTuning { read_timeout_ms: 60_000, connect_retries: 24, backoff_ms: 10 }
    }
}

impl TransportTuning {
    /// Ceiling on a single connect-retry backoff sleep.
    pub const BACKOFF_CAP_MS: u64 = 500;

    /// Reject zero knobs with errors naming the `--set` key.
    pub fn validate(&self) -> AnyResult<()> {
        if self.read_timeout_ms == 0 {
            bail!("run.transport.read_timeout_ms must be >= 1");
        }
        if self.connect_retries == 0 {
            bail!("run.transport.connect_retries must be >= 1");
        }
        if self.backoff_ms == 0 {
            bail!("run.transport.backoff_ms must be >= 1");
        }
        Ok(())
    }

    /// The per-reply read deadline as a [`Duration`].
    pub fn read_timeout(&self) -> Duration {
        Duration::from_millis(self.read_timeout_ms)
    }

    /// Backoff before connect retry `attempt` (0-based): `backoff_ms`
    /// doubled per attempt, capped.
    pub fn connect_backoff(&self, attempt: u32) -> Duration {
        let factor = if attempt >= 63 { u64::MAX } else { 1u64 << attempt };
        let ms = self.backoff_ms.saturating_mul(factor).min(Self::BACKOFF_CAP_MS);
        Duration::from_millis(ms)
    }

    /// Total worker-connect patience: the sum of every retry backoff. The
    /// parent's accept deadline uses the same budget so both sides give up
    /// together.
    pub fn connect_budget(&self) -> Duration {
        (0..self.connect_retries).map(|i| self.connect_backoff(i)).sum()
    }
}

/// What went wrong talking to a shard worker. `Worker` is an
/// application-level failure reported *by* a healthy worker (a failing
/// update rule, a rejected import); everything else means the transport
/// itself broke.
#[derive(Debug)]
pub enum TransportError {
    /// An I/O error on the underlying channel.
    Io { shard: usize, context: &'static str, source: std::io::Error },
    /// The worker is gone: thread exited, socket EOF, process dead.
    Disconnected { shard: usize, context: &'static str },
    /// A reply did not arrive within the transport's read timeout.
    Timeout { shard: usize, context: &'static str },
    /// The worker answered, but with a frame the protocol does not allow
    /// here.
    Protocol { shard: usize, message: String },
    /// The worker reports an application-level failure.
    Worker { shard: usize, message: String },
}

impl TransportError {
    pub fn shard(&self) -> usize {
        match self {
            TransportError::Io { shard, .. }
            | TransportError::Disconnected { shard, .. }
            | TransportError::Timeout { shard, .. }
            | TransportError::Protocol { shard, .. }
            | TransportError::Worker { shard, .. } => *shard,
        }
    }

    /// Whether the connection is unusable after this error (as opposed to a
    /// clean worker-side failure report on a healthy channel).
    pub fn is_fatal(&self) -> bool {
        !matches!(self, TransportError::Worker { .. })
    }

    /// Stable short label for the error's taxonomy bucket — what the
    /// supervision layer and the run registry record as `error_kind`.
    pub fn kind_label(&self) -> &'static str {
        match self {
            TransportError::Io { .. } => "io",
            TransportError::Disconnected { .. } => "disconnected",
            TransportError::Timeout { .. } => "timeout",
            TransportError::Protocol { .. } => "protocol",
            TransportError::Worker { .. } => "worker",
        }
    }
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::Io { shard, context, source } => {
                write!(f, "shard {shard}: i/o error during {context}: {source}")
            }
            TransportError::Disconnected { shard, context } => {
                write!(f, "shard {shard}: worker disconnected during {context}")
            }
            TransportError::Timeout { shard, context } => {
                write!(f, "shard {shard}: worker timed out during {context}")
            }
            TransportError::Protocol { shard, message } => {
                write!(f, "shard {shard}: protocol violation: {message}")
            }
            TransportError::Worker { shard, message } => {
                write!(f, "shard {shard}: worker failure: {message}")
            }
        }
    }
}

impl std::error::Error for TransportError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            TransportError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

/// One live shard worker. Step dispatch is pipelined: any number of
/// [`ShardConnection::send_step`]s (bounded by the connection's queue
/// capacity) may be in flight before the matching
/// [`ShardConnection::recv_step_ack`]s are drained, and the executor MUST
/// drain one ack per send before releasing the parameter/gradient borrows
/// behind the dispatched [`GroupTask`]s — that barrier is the safety
/// contract that makes raw-pointer tasks sound on every transport.
pub trait ShardConnection: Send {
    /// Dispatch one bucket of group updates at learning rate `lr`.
    fn send_step(&mut self, lr: f32, tasks: Vec<GroupTask>) -> Result<(), TransportError>;

    /// Receive one step ack (FIFO with respect to `send_step`s).
    fn recv_step_ack(&mut self) -> Result<(), TransportError>;

    /// Advance the worker optimizer's shared step counter. Ordered before
    /// subsequent steps; never acked.
    fn next_step(&mut self) -> Result<(), TransportError>;

    /// The worker's allocated state footprint `(scalars, bytes)`. Also the
    /// startup readiness check: the first call proves the worker built its
    /// optimizer.
    fn state_scalars(&mut self) -> Result<(usize, usize), TransportError>;

    /// Snapshot the shard-local optimizer state (worker-local group order).
    fn export_state(&mut self) -> Result<StateExport, TransportError>;

    /// Replace the shard-local optimizer state.
    fn import_state(&mut self, state: StateExport) -> Result<(), TransportError>;

    /// Whether the worker is still believed reachable. Cheap; used by crash
    /// recovery to pick the surviving worker set.
    fn is_alive(&self) -> bool;

    /// Graceful shutdown (also attempted on drop).
    fn shutdown(&mut self) -> Result<(), TransportError>;
}

/// A way of launching shard workers. `queue_cap` bounds the number of
/// unacked in-flight requests the connection must tolerate (the executor
/// passes its per-shard bucket count plus slack).
pub trait ShardTransport: Send + Sync {
    fn connect(
        &self,
        shard: usize,
        spec: WorkerSpec,
        queue_cap: usize,
    ) -> Result<Box<dyn ShardConnection>, TransportError>;

    /// Short label for executor names and logs (`"inproc"`, `"socket"`).
    fn name(&self) -> &'static str;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transport_kind_round_trips_and_rejects_junk() {
        for k in [
            TransportKind::InProcess,
            TransportKind::Socket,
            TransportKind::Tcp("127.0.0.1:0".to_string()),
            TransportKind::Tcp("10.0.0.7:9999".to_string()),
        ] {
            assert_eq!(TransportKind::parse(&k.name()).unwrap(), k);
        }
        assert_eq!(TransportKind::parse("unix").unwrap(), TransportKind::Socket);
        assert_eq!(
            TransportKind::parse("tcp").unwrap(),
            TransportKind::Tcp(tcp::DEFAULT_BIND.to_string())
        );
        assert_eq!(TransportKind::default(), TransportKind::InProcess);
        assert!(TransportKind::parse("carrier-pigeon").is_err());
        assert!(TransportKind::parse("tcp:").is_err());
    }

    #[test]
    fn tuning_validation_names_the_offending_key() {
        assert!(TransportTuning::default().validate().is_ok());
        let bad = TransportTuning { read_timeout_ms: 0, ..Default::default() };
        let msg = bad.validate().unwrap_err().to_string();
        assert!(msg.contains("run.transport.read_timeout_ms"), "{msg}");
        let bad = TransportTuning { connect_retries: 0, ..Default::default() };
        let msg = bad.validate().unwrap_err().to_string();
        assert!(msg.contains("run.transport.connect_retries"), "{msg}");
        let bad = TransportTuning { backoff_ms: 0, ..Default::default() };
        let msg = bad.validate().unwrap_err().to_string();
        assert!(msg.contains("run.transport.backoff_ms"), "{msg}");
    }

    #[test]
    fn connect_backoff_doubles_and_caps() {
        let t = TransportTuning::default();
        assert_eq!(t.connect_backoff(0), Duration::from_millis(10));
        assert_eq!(t.connect_backoff(1), Duration::from_millis(20));
        assert_eq!(t.connect_backoff(5), Duration::from_millis(320));
        assert_eq!(t.connect_backoff(6), Duration::from_millis(500));
        assert_eq!(t.connect_backoff(63), Duration::from_millis(500));
    }
}

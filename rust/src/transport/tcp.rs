//! Out-of-process shard workers over TCP.
//!
//! Mostly address plumbing on top of the socket transport's machinery:
//! the wire format is already endian-pinned and length-prefixed, the
//! proxy thread and the worker serve loop are generic over the stream
//! (`socket::{SocketConnection, serve_duplex}`), so this module only owns
//! the listener/connect lifecycle. The parent binds one `TcpListener` per
//! shard (port 0 asks the kernel for an ephemeral port, so concurrent
//! engines never collide), spawns `ettrain shard-worker --tcp-connect
//! <addr> --shard <s>` pointed at the bound address, and accepts exactly
//! one connection.
//!
//! Determinism, failure classification, timeouts, and crash recovery are
//! identical to the UNIX-socket transport: the same
//! [`classify`](super::socket::classify) maps stream errors to typed
//! [`TransportError`]s, and `rust/tests/sharded_parity.rs` runs the TCP
//! transport through the same bitwise matrix as inproc and socket.

use super::socket::{classify, connect_with_backoff, serve_duplex, SocketConnection};
use super::wire::{write_op, write_worker_spec, OP_SPEC};
use super::{ShardConnection, ShardTransport, TransportError, TransportTuning, WorkerSpec};
use anyhow::{Context, Result};
use std::io::{BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::process::{Command, Stdio};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Default bind address when a spec says just `transport = "tcp"`:
/// loopback with kernel-assigned ephemeral ports.
pub const DEFAULT_BIND: &str = "127.0.0.1:0";

/// Spawns `ettrain shard-worker` child processes and talks to them over
/// TCP. `bind` is the listen address; with port 0 every shard gets its
/// own ephemeral port and the actual address is passed to the child.
pub struct TcpTransport {
    bind: String,
    worker_bin: PathBuf,
    tuning: TransportTuning,
    /// `(shard, pid)` of every worker spawned, in spawn order — same
    /// contract as [`super::SocketTransport::spawned_pids`].
    pids: Arc<Mutex<Vec<(usize, u32)>>>,
}

impl TcpTransport {
    pub fn new(bind: impl Into<String>, worker_bin: impl Into<PathBuf>) -> TcpTransport {
        TcpTransport {
            bind: bind.into(),
            worker_bin: worker_bin.into(),
            tuning: TransportTuning::default(),
            pids: Arc::new(Mutex::new(Vec::new())),
        }
    }

    /// Replace the timing knobs (read deadline, connect retry budget).
    pub fn with_tuning(mut self, tuning: TransportTuning) -> TcpTransport {
        self.tuning = tuning;
        self
    }

    /// Every worker PID this transport has spawned (including exited ones).
    pub fn spawned_pids(&self) -> Vec<u32> {
        self.pids
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .iter()
            .map(|&(_, pid)| pid)
            .collect()
    }

    /// The most recently spawned worker PID for `shard`.
    pub fn pid_of(&self, shard: usize) -> Option<u32> {
        self.pids
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .iter()
            .rev()
            .find(|&&(s, _)| s == shard)
            .map(|&(_, pid)| pid)
    }

    /// Accept with a deadline, mirroring the UNIX transport's non-blocking
    /// poll (a raw `TcpListener` has no native accept timeout either).
    fn accept_deadline(
        &self,
        listener: &TcpListener,
        shard: usize,
    ) -> Result<TcpStream, TransportError> {
        listener
            .set_nonblocking(true)
            .map_err(|e| TransportError::Io { shard, context: "listener setup", source: e })?;
        let deadline = Instant::now() + self.tuning.connect_budget();
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false).map_err(|e| TransportError::Io {
                        shard,
                        context: "accept",
                        source: e,
                    })?;
                    return Ok(stream);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        return Err(TransportError::Timeout { shard, context: "worker connect" });
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => {
                    return Err(TransportError::Io { shard, context: "accept", source: e })
                }
            }
        }
    }
}

impl ShardTransport for TcpTransport {
    fn connect(
        &self,
        shard: usize,
        spec: WorkerSpec,
        queue_cap: usize,
    ) -> Result<Box<dyn ShardConnection>, TransportError> {
        let io_err = |context: &'static str| {
            move |e: std::io::Error| TransportError::Io { shard, context, source: e }
        };
        let listener = TcpListener::bind(&self.bind).map_err(io_err("bind"))?;
        let addr = listener.local_addr().map_err(io_err("local addr"))?;
        let child = Command::new(&self.worker_bin)
            .arg("shard-worker")
            .arg("--tcp-connect")
            .arg(addr.to_string())
            .arg("--shard")
            .arg(shard.to_string())
            .arg("--retries")
            .arg(self.tuning.connect_retries.to_string())
            .arg("--backoff-ms")
            .arg(self.tuning.backoff_ms.to_string())
            .stdin(Stdio::null())
            .spawn()
            .map_err(io_err("worker spawn"))?;
        self.pids
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .push((shard, child.id()));

        let stream = self.accept_deadline(&listener, shard)?;
        stream.set_nodelay(true).map_err(io_err("nodelay"))?;
        stream
            .set_read_timeout(Some(self.tuning.read_timeout()))
            .map_err(io_err("read timeout"))?;

        // Ship the spec before handing the stream to the proxy, exactly
        // like the UNIX transport.
        let reader = stream.try_clone().map_err(io_err("stream clone"))?;
        let mut w = BufWriter::new(stream);
        let max_buf_numel = 2 * spec.max_group_numel();
        (|| -> Result<()> {
            write_op(&mut w, OP_SPEC)?;
            write_worker_spec(&mut w, &spec)?;
            w.flush()?;
            Ok(())
        })()
        .map_err(|e| classify(shard, "spec send", e))?;

        Ok(Box::new(SocketConnection::launch(
            shard,
            BufReader::new(reader),
            w,
            child,
            max_buf_numel,
            queue_cap,
        )?))
    }

    fn name(&self) -> &'static str {
        "tcp"
    }
}

/// Entry point for `ettrain shard-worker --tcp-connect <addr>`: dial the
/// parent's listener (retrying under the forwarded backoff budget) and
/// serve the wire protocol until shutdown or parent exit.
pub fn run_tcp_worker(addr: &str, shard: usize, tuning: TransportTuning) -> Result<()> {
    let stream = connect_with_backoff(&tuning, || TcpStream::connect(addr))
        .with_context(|| format!("shard {shard}: connecting to {addr}"))?;
    stream.set_nodelay(true).context("nodelay")?;
    let reader = stream.try_clone().context("worker stream clone")?;
    serve_duplex(reader, stream, shard)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{GroupSpec, Hyper};
    use crate::tensoring::OptimizerKind;
    use crate::transport::wire::{read_op, OP_SCALARS, OP_SCALARS_REPLY, OP_SHUTDOWN};
    use crate::util::codec::read_u64;

    /// The worker loop over a real TCP socketpair, no child process: dial,
    /// ship a spec, query scalars, shut down.
    #[test]
    fn tcp_worker_serves_the_protocol() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let reader = stream.try_clone().unwrap();
            serve_duplex(reader, stream, 0)
        });

        let stream = TcpStream::connect(&addr).unwrap();
        let mut w = BufWriter::new(stream.try_clone().unwrap());
        let mut r = BufReader::new(stream);
        let spec = WorkerSpec::Uniform {
            kind: OptimizerKind::AdaGrad,
            groups: vec![GroupSpec::new("a", &[4])],
            hyper: Hyper::default(),
        };
        write_op(&mut w, OP_SPEC).unwrap();
        write_worker_spec(&mut w, &spec).unwrap();
        write_op(&mut w, OP_SCALARS).unwrap();
        w.flush().unwrap();
        assert_eq!(read_op(&mut r).unwrap(), OP_SCALARS_REPLY);
        assert_eq!(read_u64(&mut r).unwrap(), 4);
        let _ = read_u64(&mut r).unwrap();
        write_op(&mut w, OP_SHUTDOWN).unwrap();
        w.flush().unwrap();
        server.join().unwrap().unwrap();
    }
}

//! The worker protocol and the in-process transport.
//!
//! This is PR 1's shard worker, refactored out of `shard::worker` behind
//! the [`ShardTransport`]/[`ShardConnection`] traits. Each worker builds a
//! concrete [`crate::optim::StateOptimizer`] over exactly the groups its
//! shard owns, from an owned [`WorkerSpec`] — the uniform suite optimizer
//! or a `budget::StatePlan` slice — so *all* of a group's optimizer state
//! lives with one worker, with no `Box<dyn Optimizer>` indirection in
//! front of the update rule, and the per-step scratch arena
//! (`optim::StepScratch`) lives with it: each shard's steady-state ET
//! steps are allocation-free with zero cross-shard contention.
//!
//! [`InProcess`] is the channel transport: a persistent thread per shard,
//! requests over a bounded `sync_channel`, every [`Request::Step`]
//! acknowledged on the reply channel — which is what lets the executor
//! hand workers raw slice pointers safely (see the safety contract on
//! [`GroupTask`]). The socket transport (`super::socket`) reuses
//! [`WorkerSpec`] and the same request/ack shapes over a wire format
//! instead of a channel.

use super::{ShardConnection, ShardTransport, TransportError};
use crate::budget::StatePlan;
use crate::optim::{GroupSpec, Hyper, Optimizer, StateExport, StateOptimizer};
use crate::tensoring::OptimizerKind;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::thread::JoinHandle;

/// What a worker builds its shard-local optimizer from. Owned data (no
/// borrows), so construction happens *on the worker* — N shards allocate
/// their state concurrently and with first-touch locality in-process, and
/// an out-of-process worker can receive the whole spec over the wire
/// (`super::wire::write_worker_spec`). Planned specs are validated by the
/// executor (`budget::validate_plan`) before any worker launches, so a
/// worker-side build failure is a bug, not a user error; it is logged and
/// the worker exits, which the executor's startup reduction reports as a
/// failed shard.
pub enum WorkerSpec {
    Uniform { kind: OptimizerKind, groups: Vec<GroupSpec>, hyper: Hyper },
    Planned { groups: Vec<GroupSpec>, plan: StatePlan, hyper: Hyper },
}

impl WorkerSpec {
    pub(crate) fn build(self) -> anyhow::Result<StateOptimizer> {
        match self {
            WorkerSpec::Uniform { kind, groups, hyper } => {
                Ok(crate::optim::build_state(kind, &groups, &hyper))
            }
            WorkerSpec::Planned { groups, plan, hyper } => {
                crate::budget::build_planned(&groups, &plan, &hyper)
            }
        }
    }

    /// The groups this worker owns, in worker-local order.
    pub fn groups(&self) -> &[GroupSpec] {
        match self {
            WorkerSpec::Uniform { groups, .. } | WorkerSpec::Planned { groups, .. } => groups,
        }
    }

    /// Largest single group (the plausibility bound for wire-side buffer
    /// reads: no state buffer exceeds 2x its group's numel).
    pub fn max_group_numel(&self) -> usize {
        self.groups().iter().map(|g| g.numel()).max().unwrap_or(0)
    }
}

/// One group's update, described by raw slice parts so a persistent worker
/// can write the caller's buffers in place.
///
/// # Safety contract
///
/// The executor that creates a `GroupTask` must (1) derive `x`/`g` from
/// live, correctly-sized buffers, (2) never hand the same group to two
/// in-flight tasks, and (3) block until the worker acknowledges the step
/// before letting the underlying borrows end. `ShardedOptimizer::step_all`
/// upholds all three: groups are partitioned disjointly and the call does
/// not return until every dispatched bucket is acked. The socket transport
/// additionally relies on the same window to *read* `x`/`g` at dispatch
/// time and write the updated `x` back at ack time.
pub struct GroupTask {
    /// Index into the *worker-local* optimizer's group list.
    pub local_gi: usize,
    pub x: *mut f32,
    pub x_len: usize,
    pub g: *const f32,
    pub g_len: usize,
}

// SAFETY: raw pointers are not Send by default because the compiler cannot
// see their lifetime. Here the executor guarantees the invariant the
// compiler can't: `x`/`g` point into parameter and gradient slices whose
// borrows the executor holds for the full duration of the step barrier —
// from `send_step` until the matching `recv_step_ack` drains — and each
// group appears in at most one in-flight task, so the worker's temporary
// reconstruction of `&mut [f32]`/`&[f32]` views never aliases another live
// reference and never outlives the pointee.
unsafe impl Send for GroupTask {}

pub(crate) enum Request {
    /// Apply one bucket of group updates at learning rate `lr`.
    Step { lr: f32, tasks: Vec<GroupTask> },
    /// Advance the shard optimizer's shared step counter (Adam's `t`,
    /// ...). Ordered before subsequent `Step`s by the channel; no ack.
    NextStep,
    /// Reply with the shard optimizer's allocated state footprint.
    StateScalars,
    /// Reply with a dense snapshot of the shard-local optimizer state
    /// (groups in worker-local order).
    ExportState,
    /// Replace the shard-local optimizer state with a snapshot (same
    /// layout as an `ExportState` reply). Acked with `ImportDone`.
    ImportState(Box<StateExport>),
    /// Exit the worker loop.
    Shutdown,
}

pub(crate) enum Reply {
    /// Ack for one `Step` bucket; `Err` carries the failing group's error.
    StepDone(Result<(), String>),
    StateScalars { scalars: usize, bytes: usize },
    State(Box<StateExport>),
    ImportDone(Result<(), String>),
}

/// Worker main loop. Runs until `Shutdown` or channel disconnect. The
/// shard-local optimizer is built here, on the worker's own thread, from
/// the owned [`WorkerSpec`].
pub(crate) fn run_worker(
    shard: usize,
    spec: WorkerSpec,
    requests: Receiver<Request>,
    replies: SyncSender<Reply>,
) {
    let mut opt = match spec.build() {
        Ok(opt) => opt,
        Err(e) => {
            // Validated before spawn; reaching this is a bug. Dropping the
            // reply channel makes the executor's startup query fail loudly.
            crate::warnln!("shard {shard}: optimizer construction failed: {e:#}");
            return;
        }
    };
    while let Ok(req) = requests.recv() {
        match req {
            Request::Step { lr, tasks } => {
                let _sp = crate::trace::span(
                    crate::trace::SpanKind::OptimStep,
                    shard as u32,
                    crate::trace::NO_JOB,
                );
                let mut outcome: Result<(), String> = Ok(());
                for t in &tasks {
                    // SAFETY: sound per the GroupTask contract — the
                    // executor keeps the source `&mut [f32]` parameter
                    // borrow (length `x_len`) alive until our ack arrives,
                    // and no other task aliases this group, so the unique
                    // mutable view cannot overlap another live reference.
                    let x = unsafe { std::slice::from_raw_parts_mut(t.x, t.x_len) };
                    // SAFETY: same contract for the shared gradient view —
                    // `g` stays borrowed (and unmutated) until the ack.
                    let g = unsafe { std::slice::from_raw_parts(t.g, t.g_len) };
                    if let Err(e) = opt.step(t.local_gi, x, g, lr) {
                        outcome = Err(format!(
                            "shard {shard}, local group {}: {e:#}",
                            t.local_gi
                        ));
                        break;
                    }
                }
                if replies.send(Reply::StepDone(outcome)).is_err() {
                    return; // executor gone
                }
            }
            Request::NextStep => opt.next_step(),
            Request::StateScalars => {
                let reply = Reply::StateScalars {
                    scalars: opt.state_scalars(),
                    bytes: opt.state_bytes(),
                };
                if replies.send(reply).is_err() {
                    return;
                }
            }
            Request::ExportState => {
                if replies.send(Reply::State(Box::new(opt.export()))).is_err() {
                    return;
                }
            }
            Request::ImportState(export) => {
                let outcome = opt
                    .import(&export)
                    .map_err(|e| format!("shard {shard}: state import: {e:#}"));
                if replies.send(reply_import(outcome)).is_err() {
                    return;
                }
            }
            Request::Shutdown => return,
        }
    }
}

fn reply_import(outcome: Result<(), String>) -> Reply {
    Reply::ImportDone(outcome)
}

// ---------------------------------------------------------------------------
// The in-process transport
// ---------------------------------------------------------------------------

/// The channel transport: each `connect` spawns a persistent worker thread
/// (`et-shard-{s}`) wired up with bounded request/reply channels. This is
/// byte-for-byte the PR-1 execution path — raw-pointer tasks, zero copies,
/// in-place parameter writes on the worker thread.
#[derive(Clone, Copy, Debug, Default)]
pub struct InProcess;

impl ShardTransport for InProcess {
    fn connect(
        &self,
        shard: usize,
        spec: WorkerSpec,
        queue_cap: usize,
    ) -> Result<Box<dyn ShardConnection>, TransportError> {
        let (req_tx, req_rx) = sync_channel::<Request>(queue_cap.max(1));
        let (rep_tx, rep_rx) = sync_channel::<Reply>(queue_cap.max(1));
        let handle = std::thread::Builder::new()
            .name(format!("et-shard-{shard}"))
            .spawn(move || run_worker(shard, spec, req_rx, rep_tx))
            .map_err(|e| TransportError::Io { shard, context: "thread spawn", source: e })?;
        Ok(Box::new(InProcConnection {
            shard,
            requests: req_tx,
            replies: rep_rx,
            handle: Some(handle),
        }))
    }

    fn name(&self) -> &'static str {
        "inproc"
    }
}

/// Parent-side handle to one in-process worker thread.
pub struct InProcConnection {
    shard: usize,
    requests: SyncSender<Request>,
    replies: Receiver<Reply>,
    handle: Option<JoinHandle<()>>,
}

impl InProcConnection {
    fn gone(&self, context: &'static str) -> TransportError {
        TransportError::Disconnected { shard: self.shard, context }
    }

    fn unexpected(&self, context: &'static str) -> TransportError {
        TransportError::Protocol {
            shard: self.shard,
            message: format!("unexpected reply to {context}"),
        }
    }
}

impl ShardConnection for InProcConnection {
    fn send_step(&mut self, lr: f32, tasks: Vec<GroupTask>) -> Result<(), TransportError> {
        let _sp = crate::trace::span(
            crate::trace::SpanKind::WireSend,
            self.shard as u32,
            crate::trace::NO_JOB,
        );
        self.requests
            .send(Request::Step { lr, tasks })
            .map_err(|_| self.gone("step dispatch"))
    }

    fn recv_step_ack(&mut self) -> Result<(), TransportError> {
        let _sp = crate::trace::span(
            crate::trace::SpanKind::WireRecv,
            self.shard as u32,
            crate::trace::NO_JOB,
        );
        match self.replies.recv() {
            Ok(Reply::StepDone(Ok(()))) => Ok(()),
            Ok(Reply::StepDone(Err(message))) => {
                Err(TransportError::Worker { shard: self.shard, message })
            }
            Ok(_) => Err(self.unexpected("step")),
            Err(_) => Err(self.gone("step ack")),
        }
    }

    fn next_step(&mut self) -> Result<(), TransportError> {
        self.requests.send(Request::NextStep).map_err(|_| self.gone("next_step"))
    }

    fn state_scalars(&mut self) -> Result<(usize, usize), TransportError> {
        self.requests.send(Request::StateScalars).map_err(|_| self.gone("state query"))?;
        match self.replies.recv() {
            Ok(Reply::StateScalars { scalars, bytes }) => Ok((scalars, bytes)),
            Ok(_) => Err(self.unexpected("state query")),
            Err(_) => Err(self.gone("state query")),
        }
    }

    fn export_state(&mut self) -> Result<StateExport, TransportError> {
        self.requests.send(Request::ExportState).map_err(|_| self.gone("state export"))?;
        match self.replies.recv() {
            Ok(Reply::State(e)) => Ok(*e),
            Ok(_) => Err(self.unexpected("state export")),
            Err(_) => Err(self.gone("state export")),
        }
    }

    fn import_state(&mut self, state: StateExport) -> Result<(), TransportError> {
        self.requests
            .send(Request::ImportState(Box::new(state)))
            .map_err(|_| self.gone("state import"))?;
        match self.replies.recv() {
            Ok(Reply::ImportDone(Ok(()))) => Ok(()),
            Ok(Reply::ImportDone(Err(message))) => {
                Err(TransportError::Worker { shard: self.shard, message })
            }
            Ok(_) => Err(self.unexpected("state import")),
            Err(_) => Err(self.gone("state import")),
        }
    }

    fn is_alive(&self) -> bool {
        self.handle.as_ref().is_some_and(|h| !h.is_finished())
    }

    fn shutdown(&mut self) -> Result<(), TransportError> {
        let _ = self.requests.send(Request::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        Ok(())
    }
}

impl Drop for InProcConnection {
    fn drop(&mut self) {
        let _ = self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive one worker directly: its update must match the same optimizer
    /// run inline, and the ack must arrive after the write.
    #[test]
    fn worker_applies_steps_and_acks() {
        let groups = vec![GroupSpec::new("a", &[4]), GroupSpec::new("b", &[2])];
        let (req_tx, req_rx) = sync_channel::<Request>(4);
        let (rep_tx, rep_rx) = sync_channel::<Reply>(4);
        let spec = WorkerSpec::Uniform {
            kind: OptimizerKind::AdaGrad,
            groups: groups.clone(),
            hyper: Hyper::default(),
        };
        let handle = std::thread::spawn(move || run_worker(0, spec, req_rx, rep_tx));

        let mut x0 = vec![1.0f32; 4];
        let mut x1 = vec![2.0f32; 2];
        let g0 = vec![0.5f32, -0.5, 1.0, 0.0];
        let g1 = vec![1.0f32, 2.0];
        req_tx
            .send(Request::Step {
                lr: 0.1,
                tasks: vec![
                    GroupTask {
                        local_gi: 0,
                        x: x0.as_mut_ptr(),
                        x_len: x0.len(),
                        g: g0.as_ptr(),
                        g_len: g0.len(),
                    },
                    GroupTask {
                        local_gi: 1,
                        x: x1.as_mut_ptr(),
                        x_len: x1.len(),
                        g: g1.as_ptr(),
                        g_len: g1.len(),
                    },
                ],
            })
            .unwrap();
        match rep_rx.recv().unwrap() {
            Reply::StepDone(r) => r.unwrap(),
            _ => panic!("expected StepDone"),
        }

        // Inline reference.
        let mut reference =
            crate::optim::build(OptimizerKind::AdaGrad, &groups, &Hyper::default());
        let (mut r0, mut r1) = (vec![1.0f32; 4], vec![2.0f32; 2]);
        reference.step(0, &mut r0, &g0, 0.1).unwrap();
        reference.step(1, &mut r1, &g1, 0.1).unwrap();
        assert_eq!(x0, r0);
        assert_eq!(x1, r1);

        req_tx.send(Request::StateScalars).unwrap();
        match rep_rx.recv().unwrap() {
            Reply::StateScalars { scalars, bytes } => {
                assert_eq!(scalars, 6);
                assert_eq!(bytes, 24);
            }
            _ => panic!("expected StateScalars"),
        }

        // Export must reflect the accumulated squared gradients.
        req_tx.send(Request::ExportState).unwrap();
        let export = match rep_rx.recv().unwrap() {
            Reply::State(e) => *e,
            _ => panic!("expected State"),
        };
        assert_eq!(export.groups.len(), 2);
        assert_eq!(export.groups[0].name, "a");
        let s = &export.groups[0].bufs[0].1;
        for (sv, &gv) in s.iter().zip(&g0) {
            assert_eq!(*sv, gv * gv);
        }

        // Import it back (no-op round trip) — must ack cleanly.
        req_tx.send(Request::ImportState(Box::new(export))).unwrap();
        match rep_rx.recv().unwrap() {
            Reply::ImportDone(r) => r.unwrap(),
            _ => panic!("expected ImportDone"),
        }

        req_tx.send(Request::Shutdown).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn worker_reports_step_errors() {
        let groups = vec![GroupSpec::new("a", &[4])];
        let (req_tx, req_rx) = sync_channel::<Request>(2);
        let (rep_tx, rep_rx) = sync_channel::<Reply>(2);
        let spec = WorkerSpec::Uniform {
            kind: OptimizerKind::Sgd,
            groups,
            hyper: Hyper::default(),
        };
        let handle = std::thread::spawn(move || run_worker(3, spec, req_rx, rep_tx));
        let mut x = vec![0.0f32; 2]; // wrong length for the 4-element group
        let g = vec![0.0f32; 2];
        req_tx
            .send(Request::Step {
                lr: 0.1,
                tasks: vec![GroupTask {
                    local_gi: 0,
                    x: x.as_mut_ptr(),
                    x_len: x.len(),
                    g: g.as_ptr(),
                    g_len: g.len(),
                }],
            })
            .unwrap();
        match rep_rx.recv().unwrap() {
            Reply::StepDone(Err(msg)) => assert!(msg.contains("shard 3"), "{msg}"),
            _ => panic!("expected an error ack"),
        }
        drop(req_tx); // disconnect also terminates the loop
        handle.join().unwrap();
    }

    /// The trait surface over the same worker: connect, step, ack, export,
    /// import, shutdown — with a dead-thread `Disconnected` at the end.
    #[test]
    fn inproc_connection_round_trip() {
        let groups = vec![GroupSpec::new("a", &[4])];
        let spec = WorkerSpec::Uniform {
            kind: OptimizerKind::AdaGrad,
            groups: groups.clone(),
            hyper: Hyper::default(),
        };
        let mut conn = InProcess.connect(0, spec, 4).unwrap();
        assert!(conn.is_alive());
        let (scalars, bytes) = conn.state_scalars().unwrap();
        assert_eq!((scalars, bytes), (4, 16));

        let mut x = vec![1.0f32; 4];
        let g = vec![0.5f32; 4];
        conn.next_step().unwrap();
        conn.send_step(
            0.1,
            vec![GroupTask {
                local_gi: 0,
                x: x.as_mut_ptr(),
                x_len: x.len(),
                g: g.as_ptr(),
                g_len: g.len(),
            }],
        )
        .unwrap();
        conn.recv_step_ack().unwrap();
        let export = conn.export_state().unwrap();
        conn.import_state(export).unwrap();
        conn.shutdown().unwrap();
        assert!(!conn.is_alive());
        assert!(matches!(
            conn.state_scalars(),
            Err(TransportError::Disconnected { .. })
        ));
    }
}

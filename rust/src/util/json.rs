//! Minimal JSON codec.
//!
//! The offline build has no `serde`/`serde_json`, so the artifact manifests
//! (written by `python/compile/aot.py`), metric logs, and experiment reports
//! use this in-repo codec. It supports the full JSON data model with the
//! restrictions we control on both ends: numbers round-trip as `f64`
//! (manifest shapes are small integers, well inside the 2^53 exact range).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so serialization is
/// deterministic — important for golden-file tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors ----
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    // ---- accessors ----
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().filter(|n| n.fract() == 0.0).map(|n| n as i64)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `m["a"]["b"][2]`-style path access for manifest reading.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = match p.parse::<usize>() {
                Ok(i) => cur.as_arr()?.get(i)?,
                Err(_) => cur.get(p)?,
            };
        }
        Some(cur)
    }

    /// Convenience: read a `[1,2,3]`-style array of dims.
    pub fn as_shape(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|j| j.as_usize()).collect()
    }

    // ---- serialization ----
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Pretty form with two-space indent (used for reports).
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        let pad = |out: &mut String, d: usize| {
            for _ in 0..d {
                out.push_str("  ");
            }
        };
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    pad(out, depth + 1);
                    x.write_pretty(out, depth + 1);
                    if i + 1 < v.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                pad(out, depth);
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    pad(out, depth + 1);
                    write_str(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                    if i + 1 < m.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                pad(out, depth);
                out.push('}');
            }
            _ => self.write(out),
        }
    }

    // ---- parsing ----
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let bytes = s.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            return Err(p.err("trailing content"));
        }
        Ok(v)
    }
}

fn write_num(n: f64, out: &mut String) {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else if n.is_finite() {
        out.push_str(&format!("{n}"));
    } else {
        // JSON has no Inf/NaN; emit null (loss can transiently be non-finite
        // in diverging runs and we still want valid log lines).
        out.push_str("null");
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are not needed for our
                            // manifests; map lone surrogates to U+FFFD.
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a full UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3], "b": "hi\nthere", "c": true, "d": null}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        assert_eq!(v.at(&["a", "1"]).unwrap().as_f64(), Some(2.5));
        assert_eq!(v.get("b").unwrap().as_str(), Some("hi\nthere"));
    }

    #[test]
    fn shapes() {
        let v = Json::parse(r#"{"shape": [512, 2048]}"#).unwrap();
        assert_eq!(v.get("shape").unwrap().as_shape(), Some(vec![512, 2048]));
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\u{1}".to_string());
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn nonfinite_serializes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ✓\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ✓"));
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::obj(vec![
            ("x", Json::arr(vec![Json::num(1.0), Json::num(2.0)])),
            ("y", Json::obj(vec![("z", Json::str("w"))])),
        ]);
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn integer_precision() {
        let v = Json::parse("9007199254740992").unwrap(); // 2^53
        assert_eq!(v.as_i64(), Some(1 << 53));
    }
}

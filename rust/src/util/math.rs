//! Small numeric helpers shared across the convex substrate, the optimizers,
//! and the metrics code.

/// Numerically stable log-sum-exp over a slice.
pub fn log_sum_exp(xs: &[f32]) -> f32 {
    let m = xs.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    if !m.is_finite() {
        return m;
    }
    let s: f32 = xs.iter().map(|&x| (x - m).exp()).sum();
    m + s.ln()
}

/// In-place softmax.
pub fn softmax_inplace(xs: &mut [f32]) {
    let lse = log_sum_exp(xs);
    for x in xs.iter_mut() {
        *x = (*x - lse).exp();
    }
}

/// Dot product (f32 data, f64 accumulation for stability).
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

/// Squared l2 norm with f64 accumulation.
pub fn sq_norm(a: &[f32]) -> f64 {
    a.iter().map(|&x| x as f64 * x as f64).sum()
}

/// `y += alpha * x`.
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|&x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile (nearest-rank) of an unsorted slice.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Format a count with SI-ish suffix (paper tables use 1.2e5-style; we print
/// both). `fmt_count(120000) == "1.2e5"`.
pub fn fmt_count(n: usize) -> String {
    if n == 0 {
        return "0".into();
    }
    let x = n as f64;
    let e = x.log10().floor() as i32;
    if e < 3 {
        format!("{n}")
    } else {
        format!("{:.1}e{}", x / 10f64.powi(e), e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lse_stable() {
        let xs = [1000.0f32, 1000.0];
        let v = log_sum_exp(&xs);
        assert!((v - (1000.0 + 2f32.ln())).abs() < 1e-3);
        assert_eq!(log_sum_exp(&[f32::NEG_INFINITY]), f32::NEG_INFINITY);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut xs = [0.5f32, -1.0, 2.0, 0.0];
        softmax_inplace(&mut xs);
        let s: f32 = xs.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        assert!(xs.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn stats() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std_dev(&xs) - (1.25f64).sqrt()).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
    }

    #[test]
    fn count_formatting() {
        assert_eq!(fmt_count(1), "1");
        assert_eq!(fmt_count(90), "90");
        assert_eq!(fmt_count(120_000), "1.2e5");
        assert_eq!(fmt_count(35_000_000), "3.5e7");
    }

    #[test]
    fn axpy_dot() {
        let x = [1.0f32, 2.0, 3.0];
        let mut y = [1.0f32, 1.0, 1.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [3.0, 5.0, 7.0]);
        assert!((dot(&x, &x) - 14.0).abs() < 1e-12);
        assert!((sq_norm(&x) - 14.0).abs() < 1e-12);
    }
}

//! Structured JSONL logging for training runs and experiments.
//!
//! Every training run writes one JSON object per line to
//! `<run_dir>/metrics.jsonl`; the experiment harness parses these back to
//! assemble the paper's tables/figures, so the writer and reader live
//! together here.

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::fs::{File, OpenOptions};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::{Path, PathBuf};

/// Append-only JSONL metrics writer.
pub struct JsonlWriter {
    path: PathBuf,
    out: BufWriter<File>,
}

impl JsonlWriter {
    pub fn create(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("open {path:?}"))?;
        Ok(JsonlWriter { path, out: BufWriter::new(file) })
    }

    pub fn write(&mut self, record: &Json) -> Result<()> {
        self.out.write_all(record.to_string().as_bytes())?;
        self.out.write_all(b"\n")?;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// Read all records from a JSONL file.
pub fn read_jsonl(path: impl AsRef<Path>) -> Result<Vec<Json>> {
    let f = File::open(path.as_ref()).with_context(|| format!("open {:?}", path.as_ref()))?;
    let mut out = Vec::new();
    for (i, line) in BufReader::new(f).lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        out.push(
            Json::parse(&line).map_err(|e| anyhow::anyhow!("line {}: {e}", i + 1))?,
        );
    }
    Ok(out)
}

/// Leveled stderr logger with a global verbosity switch, used by the CLI.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Level {
    Error,
    Warn,
    Info,
    Debug,
}

static VERBOSITY: std::sync::atomic::AtomicU8 = std::sync::atomic::AtomicU8::new(2);

pub fn set_verbosity(level: Level) {
    VERBOSITY.store(level as u8, std::sync::atomic::Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    (level as u8) <= VERBOSITY.load(std::sync::atomic::Ordering::Relaxed)
}

pub fn log(level: Level, msg: &str) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
        };
        eprintln!("[{tag}] {msg}");
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, &format!($($arg)*)) };
}

#[macro_export]
macro_rules! warnln {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, &format!($($arg)*)) };
}

#[macro_export]
macro_rules! debugln {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, &format!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jsonl_roundtrip() {
        let dir = std::env::temp_dir().join(format!("etlog-{}", std::process::id()));
        let path = dir.join("m.jsonl");
        let _ = std::fs::remove_file(&path);
        let mut w = JsonlWriter::create(&path).unwrap();
        for i in 0..5 {
            w.write(&Json::obj(vec![
                ("step", Json::num(i as f64)),
                ("loss", Json::num(3.0 - 0.1 * i as f64)),
            ]))
            .unwrap();
        }
        w.flush().unwrap();
        let rec = read_jsonl(&path).unwrap();
        assert_eq!(rec.len(), 5);
        assert_eq!(rec[3].get("step").unwrap().as_usize(), Some(3));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn verbosity_gate() {
        set_verbosity(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_verbosity(Level::Info);
    }
}

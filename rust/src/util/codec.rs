//! Length-prefixed little-endian binary primitives, shared by the ETHC
//! host-checkpoint format (`train::checkpoint`), the streaming state-export
//! framing (`optim::state`), and the shard-transport wire protocol
//! (`transport::wire`). One codec, three consumers: a checkpoint written on
//! disk and a snapshot streamed over a socket use byte-identical encodings
//! for the same data.
//!
//! Conventions (all little-endian):
//! * scalars: raw `to_le_bytes` (`u32`, `u64`, `f32`, `f64`);
//! * strings: `len u32 | utf8 bytes`, capped at [`MAX_STR_LEN`];
//! * f32 tensors: `numel u64 | raw f32 data`, with the read side refusing
//!   lengths above a caller-supplied plausibility bound *before*
//!   allocating — a corrupted length field must produce a clean error, not
//!   a multi-gigabyte allocation.

use anyhow::{Context, Result};
use std::io::{Read, Write};

/// No tensor/group/buffer name (or optimizer-kind spelling, or plan JSON
/// header string) comes anywhere near this bound; longer means corruption.
pub const MAX_STR_LEN: usize = 4096;

pub fn write_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

pub fn write_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

pub fn write_f32(w: &mut impl Write, v: f32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

pub fn write_f64(w: &mut impl Write, v: f64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

pub fn write_str(w: &mut impl Write, s: &str) -> Result<()> {
    write_u32(w, s.len() as u32)?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

/// `numel u64` prefix followed by the raw f32 bytes (one bulk write).
pub fn write_f32s(w: &mut impl Write, data: &[f32]) -> Result<()> {
    write_u64(w, data.len() as u64)?;
    write_f32_data(w, data)
}

/// The raw f32 bytes of `data` with **no** length prefix — for chunked
/// framing where the frame header already carries the count.
pub fn write_f32_data(w: &mut impl Write, data: &[f32]) -> Result<()> {
    // SAFETY: `data` is a live `&[f32]`, so its pointer is non-null and
    // valid for `len * 4` bytes; u8 has alignment 1, so any f32 pointer is
    // suitably aligned, and every byte of an f32 is initialized. The view
    // is read-only and dropped before `data`'s borrow ends.
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    w.write_all(bytes)?;
    Ok(())
}

pub fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

pub fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

pub fn read_f32(r: &mut impl Read) -> Result<f32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(f32::from_le_bytes(b))
}

pub fn read_f64(r: &mut impl Read) -> Result<f64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(f64::from_le_bytes(b))
}

pub fn read_str(r: &mut impl Read) -> Result<String> {
    let len = read_u32(r)? as usize;
    anyhow::ensure!(len <= MAX_STR_LEN, "encoded string of {len} bytes is implausible");
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    String::from_utf8(buf).context("encoded string not utf8")
}

/// Read a length-prefixed f32 tensor, refusing lengths above `max_numel`
/// *before* allocating.
pub fn read_f32s(r: &mut impl Read, max_numel: usize) -> Result<Vec<f32>> {
    let numel = read_u64(r)? as usize;
    anyhow::ensure!(
        numel <= max_numel,
        "encoded tensor of {numel} scalars exceeds the plausible bound {max_numel}"
    );
    let mut data = vec![0.0f32; numel];
    read_f32_data(r, &mut data)?;
    Ok(data)
}

/// Fill `out` from the raw (unprefixed) f32 bytes — the read twin of
/// [`write_f32_data`].
pub fn read_f32_data(r: &mut impl Read, out: &mut [f32]) -> Result<()> {
    // SAFETY: `out` is a live unique `&mut [f32]` covering `len * 4` bytes
    // (non-null, aligned — u8 needs alignment 1 — and initialized, so
    // reading through the view is fine too). The u8 view is the only live
    // reference while it exists, and any bit pattern is a valid f32.
    let bytes: &mut [u8] =
        unsafe { std::slice::from_raw_parts_mut(out.as_mut_ptr() as *mut u8, out.len() * 4) };
    r.read_exact(bytes)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_and_str_roundtrip() {
        let mut buf = Vec::new();
        write_u32(&mut buf, 7).unwrap();
        write_u64(&mut buf, u64::MAX - 1).unwrap();
        write_f32(&mut buf, -0.0).unwrap();
        write_f64(&mut buf, f64::MIN_POSITIVE).unwrap();
        write_str(&mut buf, "embed/µ").unwrap();
        let mut r = buf.as_slice();
        assert_eq!(read_u32(&mut r).unwrap(), 7);
        assert_eq!(read_u64(&mut r).unwrap(), u64::MAX - 1);
        assert_eq!(read_f32(&mut r).unwrap().to_bits(), (-0.0f32).to_bits());
        assert_eq!(read_f64(&mut r).unwrap(), f64::MIN_POSITIVE);
        assert_eq!(read_str(&mut r).unwrap(), "embed/µ");
        assert!(r.is_empty());
    }

    #[test]
    fn f32s_roundtrip_bitwise_and_bound_check() {
        let data = vec![1.5f32, -0.0, f32::NAN, 3.0e-40];
        let mut buf = Vec::new();
        write_f32s(&mut buf, &data).unwrap();
        let back = read_f32s(&mut buf.as_slice(), 4).unwrap();
        let bits: Vec<u32> = back.iter().map(|x| x.to_bits()).collect();
        let want: Vec<u32> = data.iter().map(|x| x.to_bits()).collect();
        assert_eq!(bits, want);
        // A plausibility bound below the actual length must fail cleanly.
        assert!(read_f32s(&mut buf.as_slice(), 3).is_err());
    }

    #[test]
    fn implausible_string_rejected_before_alloc() {
        let mut buf = Vec::new();
        write_u32(&mut buf, u32::MAX).unwrap();
        assert!(read_str(&mut buf.as_slice()).is_err());
    }
}

//! Deterministic pseudo-random number generation.
//!
//! The whole reproduction is seeded: data synthesis, parameter
//! initialization, and shuffling all flow from [`Pcg64`], a PCG-XSL-RR
//! 128/64 generator (O'Neill 2014). We implement it in-repo because the
//! offline build environment has no `rand` crate; the generator is tiny,
//! fast, and has well-understood statistical quality.

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and stream id. Different streams with
    /// the same seed are independent sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive a child generator for an independent subsystem, keyed by a
    /// label. Used so e.g. "data" and "init" never share a stream.
    pub fn fork(&mut self, label: &str) -> Pcg64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in label.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        Pcg64::new(self.next_u64() ^ h, h | 1)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` via Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= lo.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Standard normal via Box-Muller (cached second value not kept: the
    /// callers draw in bulk, branchless simplicity wins).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fill a slice with N(0, sigma^2) samples as f32.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.normal() as f32 * sigma;
        }
    }

    /// Fill a slice with U[lo, hi) samples as f32.
    pub fn fill_uniform(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out.iter_mut() {
            *v = lo + (hi - lo) * self.next_f32();
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from an (unnormalized, non-negative) weight vector.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0, "categorical with zero total mass");
        let mut t = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(42, 7);
        let mut b = Pcg64::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(42, 1);
        let mut b = Pcg64::new(42, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Pcg64::seeded(3);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Pcg64::seeded(5);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let x = r.below(7) as usize;
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seeded(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seeded(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn categorical_respects_mass() {
        let mut r = Pcg64::seeded(13);
        let w = [0.0, 1.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..20_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[2] as f64 / counts[1] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn fork_changes_stream() {
        let mut root = Pcg64::seeded(1);
        let mut a = root.fork("data");
        let mut b = root.fork("init");
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}

//! Wall-clock timing helpers for the trainer and the bench harness.

use std::time::{Duration, Instant};

/// A simple stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Exponential moving average of step durations (for steady-state
/// throughput reporting that ignores warmup).
pub struct EmaRate {
    alpha: f64,
    ema_secs: Option<f64>,
}

impl EmaRate {
    pub fn new(alpha: f64) -> Self {
        EmaRate { alpha, ema_secs: None }
    }

    pub fn observe(&mut self, secs: f64) {
        self.ema_secs = Some(match self.ema_secs {
            None => secs,
            Some(prev) => self.alpha * secs + (1.0 - self.alpha) * prev,
        });
    }

    /// Events per second at the EMA rate.
    pub fn rate(&self) -> Option<f64> {
        self.ema_secs.map(|s| if s > 0.0 { 1.0 / s } else { f64::INFINITY })
    }

    pub fn secs(&self) -> Option<f64> {
        self.ema_secs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(t.elapsed_secs() >= 0.004);
    }

    #[test]
    fn ema_converges() {
        let mut e = EmaRate::new(0.5);
        for _ in 0..20 {
            e.observe(0.1);
        }
        let r = e.rate().unwrap();
        assert!((r - 10.0).abs() < 0.5, "{r}");
    }

    #[test]
    fn ema_empty() {
        assert!(EmaRate::new(0.1).rate().is_none());
    }
}

//! TOML-subset config parser for run configs.
//!
//! Supports exactly the subset our configs use: `[section]` and
//! `[section.sub]` headers, `key = value` with string / integer / float /
//! bool / array-of-scalars values, `#` comments. Values land in a flat
//! `section.key -> Value` map with typed accessors. Unknown syntax is an
//! error (configs are small; silent misparses are worse than strictness).

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_usize_arr(&self) -> Option<Vec<usize>> {
        match self {
            Value::Arr(v) => v.iter().map(|x| x.as_i64().map(|i| i as usize)).collect(),
            _ => None,
        }
    }
}

/// Flat `section.key -> Value` config map.
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, Value>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(inner) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                let name = inner.trim();
                if name.is_empty() {
                    bail!("line {}: empty section header", ln + 1);
                }
                section = name.to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected 'key = value'", ln + 1))?;
            let key = k.trim();
            if key.is_empty() {
                bail!("line {}: empty key", ln + 1);
            }
            let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
            let value = parse_value(v.trim())
                .with_context(|| format!("line {}: bad value for '{full}'", ln + 1))?;
            if cfg.values.insert(full.clone(), value).is_some() {
                bail!("line {}: duplicate key '{full}'", ln + 1);
            }
        }
        Ok(cfg)
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Config> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("read config {:?}", path.as_ref()))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default).to_string()
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_i64()).map(|i| i as usize).unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn bool(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    pub fn req_str(&self, key: &str) -> Result<String> {
        self.get(key)
            .and_then(|v| v.as_str())
            .map(|s| s.to_string())
            .ok_or_else(|| anyhow::anyhow!("config missing required string '{key}'"))
    }

    /// Override or insert a value (CLI `--set section.key=value`).
    pub fn set(&mut self, key: &str, raw: &str) -> Result<()> {
        let value = parse_value(raw)?;
        self.values.insert(key.to_string(), value);
        Ok(())
    }

    /// Insert an already-typed value (programmatic config construction,
    /// e.g. remapping `job.<name>.*` keys onto `run.*` for the batch
    /// runner).
    pub fn insert(&mut self, key: &str, value: Value) {
        self.values.insert(key.to_string(), value);
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(q) = s.strip_prefix('"') {
        let inner = q.strip_suffix('"').context("unterminated string")?;
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body.strip_suffix(']').context("unterminated array")?.trim();
        if body.is_empty() {
            return Ok(Value::Arr(vec![]));
        }
        let items: Result<Vec<Value>> = body.split(',').map(|x| parse_value(x.trim())).collect();
        return Ok(Value::Arr(items?));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value '{s}' (quote strings)");
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# run config
name = "table1-et2"

[model]
layers = 6          # transformer depth
d_model = 512
dims = [16, 32]
tied = true

[optim]
kind = "et2"
lr = 0.1
eps = 1e-8
"#;

    #[test]
    fn parses_sample() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str("name", ""), "table1-et2");
        assert_eq!(c.usize("model.layers", 0), 6);
        assert_eq!(c.f64("optim.lr", 0.0), 0.1);
        assert_eq!(c.f64("optim.eps", 0.0), 1e-8);
        assert!(c.bool("model.tied", false));
        assert_eq!(c.get("model.dims").unwrap().as_usize_arr(), Some(vec![16, 32]));
    }

    #[test]
    fn defaults_and_overrides() {
        let mut c = Config::parse("[a]\nx = 1").unwrap();
        assert_eq!(c.usize("a.y", 9), 9);
        c.set("a.x", "5").unwrap();
        assert_eq!(c.usize("a.x", 0), 5);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Config::parse("just words").is_err());
        assert!(Config::parse("k = ").is_err());
        assert!(Config::parse("[]\n").is_err());
        assert!(Config::parse("k = \"unterminated").is_err());
        assert!(Config::parse("k = 1\nk = 2").is_err());
    }

    #[test]
    fn comments_in_strings_survive() {
        let c = Config::parse("k = \"a#b\"").unwrap();
        assert_eq!(c.str("k", ""), "a#b");
    }

    #[test]
    fn numbers() {
        let c = Config::parse("a = -3\nb = 2.5e-4\nc = 1e4").unwrap();
        assert_eq!(c.get("a").unwrap().as_i64(), Some(-3));
        assert!((c.f64("b", 0.0) - 2.5e-4).abs() < 1e-12);
        assert!((c.f64("c", 0.0) - 1e4).abs() < 1e-9);
    }
}

//! Shared substrates: seeded RNG, JSON codec, CLI parsing, config files,
//! logging, timing. All in-repo because the offline build environment only
//! ships the `xla` crate's dependency closure.

pub mod cli;
pub mod codec;
pub mod config;
pub mod json;
pub mod logging;
pub mod math;
pub mod rng;
pub mod timer;

//! Tiny command-line parser (no `clap` in the offline environment).
//!
//! Supports `ettrain <subcommand> [--flag] [--key value] [positional...]`,
//! with typed accessors and an auto-generated usage string. Unknown flags
//! are errors — experiments must not silently ignore a typoed parameter.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Parsed arguments for one subcommand invocation.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

/// Declarative spec of what a subcommand accepts (for validation + usage).
#[derive(Debug, Clone)]
pub struct Spec {
    pub name: &'static str,
    pub about: &'static str,
    /// (key, default-or-None, help)
    pub options: Vec<(&'static str, Option<&'static str>, &'static str)>,
    pub flags: Vec<(&'static str, &'static str)>,
    pub positional: Vec<(&'static str, &'static str)>,
}

impl Spec {
    pub fn usage(&self) -> String {
        let mut s = format!("ettrain {} — {}\n", self.name, self.about);
        if !self.positional.is_empty() {
            s.push_str("  positional:\n");
            for (n, h) in &self.positional {
                s.push_str(&format!("    <{n}>  {h}\n"));
            }
        }
        if !self.options.is_empty() {
            s.push_str("  options:\n");
            for (k, d, h) in &self.options {
                match d {
                    Some(d) => s.push_str(&format!("    --{k} <v>  {h} (default {d})\n")),
                    None => s.push_str(&format!("    --{k} <v>  {h}\n")),
                }
            }
        }
        if !self.flags.is_empty() {
            s.push_str("  flags:\n");
            for (k, h) in &self.flags {
                s.push_str(&format!("    --{k}  {h}\n"));
            }
        }
        s
    }
}

impl Args {
    /// Parse raw argv (without the binary name) against a spec.
    pub fn parse(spec: &Spec, argv: &[String]) -> Result<Args> {
        let mut args = Args { subcommand: spec.name.to_string(), ..Default::default() };
        // seed defaults
        for (k, d, _) in &spec.options {
            if let Some(d) = d {
                args.options.insert(k.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                // --key=value form
                if let Some((k, v)) = name.split_once('=') {
                    if spec.options.iter().any(|(n, _, _)| *n == k) {
                        args.options.insert(k.to_string(), v.to_string());
                    } else {
                        bail!("unknown option --{k}\n{}", spec.usage());
                    }
                } else if spec.flags.iter().any(|(n, _)| *n == name) {
                    args.flags.push(name.to_string());
                } else if spec.options.iter().any(|(n, _, _)| *n == name) {
                    i += 1;
                    if i >= argv.len() {
                        bail!("option --{name} needs a value\n{}", spec.usage());
                    }
                    args.options.insert(name.to_string(), argv[i].clone());
                } else {
                    bail!("unknown option --{name}\n{}", spec.usage());
                }
            } else {
                if args.positional.len() >= spec.positional.len() {
                    bail!("unexpected positional '{a}'\n{}", spec.usage());
                }
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str) -> Result<usize> {
        let v = self.req(key)?;
        v.parse().map_err(|_| anyhow::anyhow!("--{key}: expected integer, got '{v}'"))
    }

    pub fn get_u64(&self, key: &str) -> Result<u64> {
        let v = self.req(key)?;
        v.parse().map_err(|_| anyhow::anyhow!("--{key}: expected integer, got '{v}'"))
    }

    pub fn get_f64(&self, key: &str) -> Result<f64> {
        let v = self.req(key)?;
        v.parse().map_err(|_| anyhow::anyhow!("--{key}: expected number, got '{v}'"))
    }

    pub fn req(&self, key: &str) -> Result<&str> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing required option --{key}"))
    }
}

/// Parse a human byte size: plain bytes, or with a binary `k`/`m`/`g`
/// suffix (`64m` = 64 MiB). Shared by `--mem-budget`, `ettrain plan
/// --budget`, and the `run.opt_memory_budget` config key.
pub fn parse_byte_size(raw: &str) -> Result<u64> {
    let s = raw.trim().to_ascii_lowercase();
    let (digits, mult): (&str, u64) = match s.chars().last() {
        Some('k') => (&s[..s.len() - 1], 1 << 10),
        Some('m') => (&s[..s.len() - 1], 1 << 20),
        Some('g') => (&s[..s.len() - 1], 1 << 30),
        _ => (s.as_str(), 1),
    };
    let n: u64 = digits
        .trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("expected BYTES[k|m|g], got '{raw}'"))?;
    Ok(n.saturating_mul(mult))
}

/// Parse a comma-separated `--set key=value,key2=value2` override list.
///
/// Every token must contain `=` with a non-empty key; a malformed token is
/// a hard error naming the offender (it used to be silently dropped, which
/// made a typoed override indistinguishable from an applied one).
pub fn parse_set_overrides(raw: &str) -> Result<Vec<(String, String)>> {
    let mut out = Vec::new();
    for tok in raw.split(',') {
        let tok = tok.trim();
        match tok.split_once('=') {
            Some((k, v)) if !k.trim().is_empty() => {
                out.push((k.trim().to_string(), v.trim().to_string()));
            }
            _ => bail!("--set: malformed override '{tok}' (expected key=value)"),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Spec {
        Spec {
            name: "train",
            about: "run a training job",
            options: vec![
                ("steps", Some("100"), "number of steps"),
                ("lr", None, "learning rate"),
            ],
            flags: vec![("csv", "emit csv")],
            positional: vec![("config", "config path")],
        }
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse(&spec(), &sv(&["cfg.toml", "--steps", "500", "--csv", "--lr=0.1"]))
            .unwrap();
        assert_eq!(a.positional, vec!["cfg.toml"]);
        assert_eq!(a.get_usize("steps").unwrap(), 500);
        assert_eq!(a.get_f64("lr").unwrap(), 0.1);
        assert!(a.flag("csv"));
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&spec(), &sv(&["cfg.toml"])).unwrap();
        assert_eq!(a.get_usize("steps").unwrap(), 100);
        assert!(a.get("lr").is_none());
    }

    #[test]
    fn rejects_unknown() {
        assert!(Args::parse(&spec(), &sv(&["--bogus", "1"])).is_err());
        assert!(Args::parse(&spec(), &sv(&["a", "b"])).is_err());
        assert!(Args::parse(&spec(), &sv(&["--steps"])).is_err());
    }

    #[test]
    fn byte_sizes_parse() {
        assert_eq!(parse_byte_size("4096").unwrap(), 4096);
        assert_eq!(parse_byte_size("64m").unwrap(), 64 << 20);
        assert_eq!(parse_byte_size("2K").unwrap(), 2048);
        assert_eq!(parse_byte_size(" 1g ").unwrap(), 1 << 30);
        assert!(parse_byte_size("64q").is_err());
        assert!(parse_byte_size("").is_err());
    }

    #[test]
    fn set_overrides_parse_or_fail_loudly() {
        assert_eq!(
            parse_set_overrides("run.steps=5, run.name = x").unwrap(),
            vec![
                ("run.steps".to_string(), "5".to_string()),
                ("run.name".to_string(), "x".to_string())
            ]
        );
        // values may themselves contain '='
        assert_eq!(
            parse_set_overrides("optim.schedule=constant:0.1").unwrap(),
            vec![("optim.schedule".to_string(), "constant:0.1".to_string())]
        );
        // no '=' at all, empty key, and stray trailing comma are all errors
        assert!(parse_set_overrides("run.steps").is_err());
        assert!(parse_set_overrides("=5").is_err());
        assert!(parse_set_overrides("a=1,,b=2").is_err());
        assert!(parse_set_overrides("a=1,b").is_err());
    }

    #[test]
    fn usage_mentions_everything() {
        let u = spec().usage();
        assert!(u.contains("--steps"));
        assert!(u.contains("--csv"));
        assert!(u.contains("<config>"));
    }
}

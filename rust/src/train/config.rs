//! Run configuration for `ettrain`: which artifact, schedule, data, and
//! budgets. Parsed from TOML (`util::config`) with CLI overrides.

use crate::optim::Schedule;
use crate::tensoring::{OptimizerKind, StateBackend};
use crate::util::config::Config;
use anyhow::{Context, Result};
use std::path::PathBuf;

/// Everything a training run needs.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Run name (directory under `runs/`).
    pub name: String,
    /// Artifact name, e.g. `lm_tiny_et2`.
    pub artifact: String,
    /// Eval artifact name, e.g. `lm_tiny_eval` (optional).
    pub eval_artifact: Option<String>,
    pub artifact_dir: PathBuf,
    pub out_dir: PathBuf,
    pub steps: u64,
    pub eval_every: u64,
    pub eval_batches: usize,
    pub log_every: u64,
    pub checkpoint_every: u64,
    pub schedule: Schedule,
    pub seed: u64,
    /// Corpus settings (LM runs).
    pub corpus_vocab: usize,
    pub corpus_sentences: usize,
    /// Max wall-clock seconds (0 = unlimited) — Table 2's equal-time budget.
    pub max_seconds: f64,
    /// Mirror gradients into the trace tracker (Figure 2). Costs one
    /// grad-artifact execution per sampled step.
    pub track_traces: bool,
    pub trace_every: u64,
    /// Worker shards for the host-side optimizer engine (`shard::`).
    /// Only meaningful together with `host_optimizer`; 1 = a single
    /// worker (still bitwise-identical to the in-thread optimizer).
    pub shards: usize,
    /// When set, train host-side: gradients come from the `<family>_grad`
    /// artifact and the update is applied by the (sharded) pure-rust
    /// optimizer suite instead of the fused train-step artifact.
    pub host_optimizer: Option<OptimizerKind>,
    /// Physical storage for host-optimizer state: `f32` (default),
    /// `q8`/`q8/<block>` (8-bit block-quantized), `nf4` (4-bit quantile),
    /// or the stochastic-rounding variants `q8sr`/`nf4sr`.
    pub state_backend: StateBackend,
    /// Optimizer-state byte budget (`"64m"`, `"512k"`, or plain bytes).
    /// When set, the run trains host-side under a `budget::StatePlan`: the
    /// planner picks the best (ET level, backend) per parameter group
    /// within the budget, overriding the uniform
    /// `host_optimizer`/`state_backend` pair.
    pub opt_memory_budget: Option<u64>,
    /// Resume from the run's latest checkpoint (`runs/<name>/latest.hck`
    /// for host-optimizer runs via the ETHC loader, `latest.ck` for fused
    /// artifact runs). Missing checkpoint = hard error, so a typoed run
    /// name cannot silently restart from scratch.
    pub resume: bool,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            name: "run".into(),
            artifact: "lm_tiny_et1".into(),
            eval_artifact: None,
            artifact_dir: PathBuf::from("artifacts"),
            out_dir: PathBuf::from("runs"),
            steps: 300,
            eval_every: 100,
            eval_batches: 8,
            log_every: 10,
            checkpoint_every: 0,
            schedule: Schedule::scaled_lm(1.0, 40),
            seed: 42,
            corpus_vocab: 1900,
            corpus_sentences: 20_000,
            max_seconds: 0.0,
            track_traces: false,
            trace_every: 10,
            shards: 1,
            host_optimizer: None,
            state_backend: StateBackend::DenseF32,
            opt_memory_budget: None,
            resume: false,
        }
    }
}

impl RunConfig {
    /// Load from a TOML file; `overrides` are `key=value` pairs applied on
    /// top (CLI `--set`).
    /// Config keys whose values are strings the CLI should accept unquoted
    /// (`--set run.state_backend=nf4`, `--set run.opt_memory_budget=64m`).
    /// Only string-typed keys are listed: auto-quoting a numeric key would
    /// turn a typo like `run.steps=1o0` into a silently ignored string.
    /// Every listed key's value is still validated by `from_config`, so a
    /// bad spelling remains a hard error.
    const STRING_KEYS: &'static [&'static str] = &[
        "run.name",
        "run.artifact",
        "run.eval_artifact",
        "run.artifact_dir",
        "run.out_dir",
        "run.host_optimizer",
        "run.state_backend",
        "run.opt_memory_budget",
        "optim.schedule",
    ];

    pub fn load(path: &str, overrides: &[(String, String)]) -> Result<RunConfig> {
        let mut cfg = Config::load(path).with_context(|| format!("load config {path}"))?;
        for (k, v) in overrides {
            if Self::STRING_KEYS.contains(&k.as_str()) && !v.starts_with('"') {
                cfg.set(k, &format!("\"{v}\""))?;
            } else {
                cfg.set(k, v)?;
            }
        }
        Self::from_config(&cfg)
    }

    pub fn from_config(cfg: &Config) -> Result<RunConfig> {
        let d = RunConfig::default();
        let schedule_str = cfg.str("optim.schedule", "warmup_rsqrt:1.0:40");
        let schedule = Schedule::parse(&schedule_str)
            .with_context(|| format!("bad schedule '{schedule_str}'"))?;
        Ok(RunConfig {
            name: cfg.str("run.name", &d.name),
            artifact: cfg.req_str("run.artifact")?,
            eval_artifact: cfg.get("run.eval_artifact").and_then(|v| v.as_str()).map(String::from),
            artifact_dir: PathBuf::from(cfg.str("run.artifact_dir", "artifacts")),
            out_dir: PathBuf::from(cfg.str("run.out_dir", "runs")),
            steps: cfg.usize("run.steps", d.steps as usize) as u64,
            eval_every: cfg.usize("run.eval_every", d.eval_every as usize) as u64,
            eval_batches: cfg.usize("run.eval_batches", d.eval_batches),
            log_every: cfg.usize("run.log_every", d.log_every as usize) as u64,
            checkpoint_every: cfg.usize("run.checkpoint_every", 0) as u64,
            schedule,
            seed: cfg.usize("run.seed", d.seed as usize) as u64,
            corpus_vocab: cfg.usize("data.vocab", d.corpus_vocab),
            corpus_sentences: cfg.usize("data.sentences", d.corpus_sentences),
            max_seconds: cfg.f64("run.max_seconds", 0.0),
            track_traces: cfg.bool("run.track_traces", false),
            trace_every: cfg.usize("run.trace_every", d.trace_every as usize) as u64,
            shards: cfg.usize("run.shards", 1).max(1),
            host_optimizer: match cfg.get("run.host_optimizer").and_then(|v| v.as_str()) {
                Some(s) => Some(
                    OptimizerKind::parse(s)
                        .with_context(|| format!("unknown host optimizer '{s}'"))?,
                ),
                None => None,
            },
            state_backend: match cfg.get("run.state_backend").and_then(|v| v.as_str()) {
                Some(s) => StateBackend::parse(s).with_context(|| {
                    format!(
                        "unknown state backend '{s}' \
                         (f32|q8|q8sr|nf4|nf4sr, optionally /<block>)"
                    )
                })?,
                None => StateBackend::DenseF32,
            },
            opt_memory_budget: match cfg.get("run.opt_memory_budget") {
                None => None,
                Some(v) => {
                    let raw = match v {
                        crate::util::config::Value::Str(s) => s.clone(),
                        crate::util::config::Value::Int(i) => i.to_string(),
                        other => anyhow::bail!(
                            "run.opt_memory_budget must be bytes or a \"64m\"-style string, \
                             got {other:?}"
                        ),
                    };
                    let bytes = crate::util::cli::parse_byte_size(&raw)
                        .with_context(|| format!("bad run.opt_memory_budget '{raw}'"))?;
                    anyhow::ensure!(bytes > 0, "run.opt_memory_budget must be positive");
                    Some(bytes)
                }
            },
            resume: cfg.bool("run.resume", false),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal() {
        let cfg = Config::parse(
            r#"
[run]
artifact = "lm_tiny_et2"
steps = 500

[optim]
schedule = "constant:0.05"
"#,
        )
        .unwrap();
        let rc = RunConfig::from_config(&cfg).unwrap();
        assert_eq!(rc.artifact, "lm_tiny_et2");
        assert_eq!(rc.steps, 500);
        assert_eq!(rc.schedule, Schedule::Constant(0.05));
    }

    #[test]
    fn parses_shard_knobs() {
        let cfg = Config::parse(
            r#"
[run]
artifact = "lm_tiny_et2"
shards = 4
host_optimizer = "et2"
state_backend = "q8"
"#,
        )
        .unwrap();
        let rc = RunConfig::from_config(&cfg).unwrap();
        assert_eq!(rc.shards, 4);
        assert_eq!(rc.host_optimizer, Some(OptimizerKind::Et(2)));
        assert_eq!(rc.state_backend, StateBackend::q8());
        assert!(!rc.resume);
        // default: single shard, fused-artifact training, dense f32 state
        let plain = Config::parse("[run]\nartifact = \"a\"").unwrap();
        let rc = RunConfig::from_config(&plain).unwrap();
        assert_eq!(rc.shards, 1);
        assert_eq!(rc.host_optimizer, None);
        assert_eq!(rc.state_backend, StateBackend::DenseF32);
    }

    #[test]
    fn cli_overrides_accept_unquoted_string_keys() {
        let dir = std::env::temp_dir().join("ettrain_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("base.toml");
        std::fs::write(&path, "[run]\nartifact = \"a\"\n").unwrap();
        let overrides = vec![
            ("run.state_backend".to_string(), "nf4".to_string()),
            ("run.opt_memory_budget".to_string(), "64m".to_string()),
            ("run.steps".to_string(), "77".to_string()),
        ];
        let rc = RunConfig::load(path.to_str().unwrap(), &overrides).unwrap();
        assert_eq!(rc.state_backend, StateBackend::nf4());
        assert_eq!(rc.opt_memory_budget, Some(64 << 20));
        assert_eq!(rc.steps, 77);
        // A typoed numeric value stays a hard error (no auto-quoting).
        let bad = vec![("run.steps".to_string(), "1o0".to_string())];
        assert!(RunConfig::load(path.to_str().unwrap(), &bad).is_err());
        // A bad string value is still rejected downstream.
        let bad_backend = vec![("run.state_backend".to_string(), "q4".to_string())];
        assert!(RunConfig::load(path.to_str().unwrap(), &bad_backend).is_err());
    }

    #[test]
    fn parses_opt_memory_budget() {
        let cfg = Config::parse(
            "[run]\nartifact = \"a\"\nopt_memory_budget = \"64m\"",
        )
        .unwrap();
        let rc = RunConfig::from_config(&cfg).unwrap();
        assert_eq!(rc.opt_memory_budget, Some(64 << 20));
        // Plain integer bytes also accepted.
        let cfg = Config::parse("[run]\nartifact = \"a\"\nopt_memory_budget = 4096").unwrap();
        assert_eq!(RunConfig::from_config(&cfg).unwrap().opt_memory_budget, Some(4096));
        // Garbage is a hard error.
        let cfg =
            Config::parse("[run]\nartifact = \"a\"\nopt_memory_budget = \"64q\"").unwrap();
        assert!(RunConfig::from_config(&cfg).is_err());
        // Default: no budget.
        let cfg = Config::parse("[run]\nartifact = \"a\"").unwrap();
        assert_eq!(RunConfig::from_config(&cfg).unwrap().opt_memory_budget, None);
    }

    #[test]
    fn parses_new_backends() {
        for (s, want) in [
            ("nf4", StateBackend::nf4()),
            ("nf4sr", StateBackend::nf4sr()),
            ("q8sr", StateBackend::q8sr()),
        ] {
            let cfg = Config::parse(&format!(
                "[run]\nartifact = \"a\"\nstate_backend = \"{s}\""
            ))
            .unwrap();
            assert_eq!(RunConfig::from_config(&cfg).unwrap().state_backend, want, "{s}");
        }
    }

    #[test]
    fn rejects_bad_state_backend() {
        let cfg =
            Config::parse("[run]\nartifact = \"a\"\nstate_backend = \"q4\"").unwrap();
        assert!(RunConfig::from_config(&cfg).is_err());
    }

    #[test]
    fn rejects_bad_host_optimizer() {
        let cfg =
            Config::parse("[run]\nartifact = \"a\"\nhost_optimizer = \"bogus\"").unwrap();
        assert!(RunConfig::from_config(&cfg).is_err());
    }

    #[test]
    fn requires_artifact() {
        let cfg = Config::parse("[run]\nsteps = 5").unwrap();
        assert!(RunConfig::from_config(&cfg).is_err());
    }

    #[test]
    fn rejects_bad_schedule() {
        let cfg =
            Config::parse("[run]\nartifact = \"a\"\n[optim]\nschedule = \"nope\"").unwrap();
        assert!(RunConfig::from_config(&cfg).is_err());
    }
}

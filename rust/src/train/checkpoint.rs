//! Checkpointing: save/restore the full training state (params + optimizer
//! state + step counter) in a simple length-prefixed binary format.
//!
//! Format (little-endian):
//! ```text
//! magic "ETCK" | version u32 | step u64 | n_tensors u32 |
//!   per tensor: name_len u32 | name bytes | numel u64 | f32 data
//! ```
//! Tensor order and names must match the artifact manifest; `load` verifies
//! both, so a checkpoint can never be silently applied to the wrong model.

use crate::runtime::{Engine, TrainState};
use anyhow::{bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"ETCK";
const VERSION: u32 = 1;

pub fn save(engine: &Engine, state: &TrainState, path: impl AsRef<Path>) -> Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let tmp = path.with_extension("tmp");
    {
        let mut w = BufWriter::new(std::fs::File::create(&tmp)?);
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&state.step.to_le_bytes())?;
        let names: Vec<&str> = engine
            .manifest
            .params
            .iter()
            .map(|p| p.name.as_str())
            .chain(engine.manifest.opt_state.iter().map(|s| s.name.as_str()))
            .collect();
        let tensors: Vec<&xla::Literal> =
            state.params.iter().chain(state.opt_state.iter()).collect();
        w.write_all(&(tensors.len() as u32).to_le_bytes())?;
        for (name, lit) in names.iter().zip(&tensors) {
            let data = lit.to_vec::<f32>()?;
            w.write_all(&(name.len() as u32).to_le_bytes())?;
            w.write_all(name.as_bytes())?;
            w.write_all(&(data.len() as u64).to_le_bytes())?;
            // bulk byte write
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
            };
            w.write_all(bytes)?;
        }
        w.flush()?;
    }
    std::fs::rename(&tmp, path)?; // atomic replace
    Ok(())
}

pub fn load(engine: &Engine, path: impl AsRef<Path>) -> Result<TrainState> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("open checkpoint {:?}", path.as_ref()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not an ETCK checkpoint");
    }
    let mut b4 = [0u8; 4];
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b4)?;
    let version = u32::from_le_bytes(b4);
    if version != VERSION {
        bail!("unsupported checkpoint version {version}");
    }
    r.read_exact(&mut b8)?;
    let step = u64::from_le_bytes(b8);
    r.read_exact(&mut b4)?;
    let n = u32::from_le_bytes(b4) as usize;

    let expected: Vec<(&str, usize)> = engine
        .manifest
        .params
        .iter()
        .map(|p| (p.name.as_str(), p.numel()))
        .chain(engine.manifest.opt_state.iter().map(|s| (s.name.as_str(), s.numel())))
        .collect();
    if n != expected.len() {
        bail!("checkpoint has {n} tensors, manifest expects {}", expected.len());
    }

    let mut params: Vec<Vec<f32>> = Vec::with_capacity(engine.manifest.params.len());
    let mut opt: Vec<Vec<f32>> = Vec::with_capacity(engine.manifest.opt_state.len());
    for (i, (want_name, want_numel)) in expected.iter().enumerate() {
        r.read_exact(&mut b4)?;
        let name_len = u32::from_le_bytes(b4) as usize;
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("tensor name not utf8")?;
        if name != *want_name {
            bail!("tensor {i}: checkpoint has '{name}', manifest expects '{want_name}'");
        }
        r.read_exact(&mut b8)?;
        let numel = u64::from_le_bytes(b8) as usize;
        if numel != *want_numel {
            bail!("tensor '{name}': {numel} values, manifest expects {want_numel}");
        }
        let mut data = vec![0.0f32; numel];
        let bytes: &mut [u8] = unsafe {
            std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, numel * 4)
        };
        r.read_exact(bytes)?;
        if i < engine.manifest.params.len() {
            params.push(data);
        } else {
            opt.push(data);
        }
    }
    engine.state_from_vecs(&params, &opt, step)
}

#[cfg(test)]
mod tests {
    // Checkpoint round-trip with a real engine requires artifacts; the
    // integration test `rust/tests/train_loop.rs` covers it. Here we test
    // the header validation on raw bytes.
    use super::*;

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join(format!("etck-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ck");
        std::fs::write(&path, b"NOPE").unwrap();
        // Need an engine to call load(); validate magic by parsing manually.
        let mut f = std::fs::File::open(&path).unwrap();
        let mut magic = [0u8; 4];
        use std::io::Read;
        f.read_exact(&mut magic).unwrap();
        assert_ne!(&magic, MAGIC);
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! Checkpointing: save/restore the full training state (params + optimizer
//! state + step counter) in a simple length-prefixed binary format.
//!
//! Two formats live here:
//!
//! * **ETCK** — artifact-engine checkpoints ([`save`]/[`load`]): the PJRT
//!   train state's tensors, validated against the artifact manifest.
//! * **ETHC** — host-optimizer checkpoints ([`save_host`]/[`load_host`]):
//!   host-resident parameters plus a dense [`StateExport`] of the
//!   externalized optimizer state, exactly as fanned in from the shard
//!   workers by `ShardedOptimizer::export_state`. Shard-count independent:
//!   a checkpoint taken at any `run.shards` restores at any other.
//!
//! ETCK format (little-endian):
//! ```text
//! magic "ETCK" | version u32 | step u64 | n_tensors u32 |
//!   per tensor: name_len u32 | name bytes | numel u64 | f32 data
//! ```
//! Tensor order and names must match the artifact manifest; `load` verifies
//! both, so a checkpoint can never be silently applied to the wrong model.
//!
//! ETHC v2 format (little-endian; strings are `len u32 | bytes`):
//! ```text
//! magic "ETHC" | version u32 | step u64 | n_params u32 |
//!   per param: name | numel u64 | f32 data
//! ETSS state stream (see `optim::stream`): kind, opt_step, chunk-framed
//!   group snapshots, trailing checksum
//! ```
//! The optimizer-state section is the chunk-framed streaming export — the
//! exact bytes the socket shard transport puts on the wire — written
//! straight out of the in-memory snapshot with bounded buffering and
//! verified by the stream's trailing checksum on load. Counters
//! (`opt_step`, per-group `steps`) are stored as exact `u64`s — never
//! rounded through `f32` — so restored training continues
//! bitwise-identically (`rust/tests/host_checkpoint.rs`).

use crate::optim::stream::{read_export_stream, write_export_stream, STREAM_CHUNK_NUMEL};
use crate::optim::{GroupSpec, StateExport};
use crate::runtime::{Engine, TrainState};
use crate::util::codec::{read_f32s, read_str, read_u32, read_u64, write_f32s, write_str};
use anyhow::{bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"ETCK";
const VERSION: u32 = 1;
const HOST_MAGIC: &[u8; 4] = b"ETHC";
const HOST_VERSION: u32 = 2;

pub fn save(engine: &Engine, state: &TrainState, path: impl AsRef<Path>) -> Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let tmp = path.with_extension("tmp");
    {
        let mut w = BufWriter::new(std::fs::File::create(&tmp)?);
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&state.step.to_le_bytes())?;
        let names: Vec<&str> = engine
            .manifest
            .params
            .iter()
            .map(|p| p.name.as_str())
            .chain(engine.manifest.opt_state.iter().map(|s| s.name.as_str()))
            .collect();
        let tensors: Vec<&xla::Literal> =
            state.params.iter().chain(state.opt_state.iter()).collect();
        w.write_all(&(tensors.len() as u32).to_le_bytes())?;
        for (name, lit) in names.iter().zip(&tensors) {
            let data = lit.to_vec::<f32>()?;
            w.write_all(&(name.len() as u32).to_le_bytes())?;
            w.write_all(name.as_bytes())?;
            w.write_all(&(data.len() as u64).to_le_bytes())?;
            // SAFETY: `data` is a live `Vec<f32>` owned by this iteration,
            // so its pointer covers `len * 4` initialized bytes; the u8
            // view (alignment 1) is read-only and dropped before `data`.
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
            };
            w.write_all(bytes)?;
        }
        w.flush()?;
    }
    std::fs::rename(&tmp, path)?; // atomic replace
    Ok(())
}

pub fn load(engine: &Engine, path: impl AsRef<Path>) -> Result<TrainState> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("open checkpoint {:?}", path.as_ref()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not an ETCK checkpoint");
    }
    let mut b4 = [0u8; 4];
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b4)?;
    let version = u32::from_le_bytes(b4);
    if version != VERSION {
        bail!("unsupported checkpoint version {version}");
    }
    r.read_exact(&mut b8)?;
    let step = u64::from_le_bytes(b8);
    r.read_exact(&mut b4)?;
    let n = u32::from_le_bytes(b4) as usize;

    let expected: Vec<(&str, usize)> = engine
        .manifest
        .params
        .iter()
        .map(|p| (p.name.as_str(), p.numel()))
        .chain(engine.manifest.opt_state.iter().map(|s| (s.name.as_str(), s.numel())))
        .collect();
    if n != expected.len() {
        bail!("checkpoint has {n} tensors, manifest expects {}", expected.len());
    }

    let mut params: Vec<Vec<f32>> = Vec::with_capacity(engine.manifest.params.len());
    let mut opt: Vec<Vec<f32>> = Vec::with_capacity(engine.manifest.opt_state.len());
    for (i, (want_name, want_numel)) in expected.iter().enumerate() {
        r.read_exact(&mut b4)?;
        let name_len = u32::from_le_bytes(b4) as usize;
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("tensor name not utf8")?;
        if name != *want_name {
            bail!("tensor {i}: checkpoint has '{name}', manifest expects '{want_name}'");
        }
        r.read_exact(&mut b8)?;
        let numel = u64::from_le_bytes(b8) as usize;
        if numel != *want_numel {
            bail!("tensor '{name}': {numel} values, manifest expects {want_numel}");
        }
        let mut data = vec![0.0f32; numel];
        // SAFETY: `data` was just allocated with exactly `numel` zeroed
        // f32s, so the u8 view (alignment 1) covers `numel * 4` valid,
        // initialized bytes; it is the only live reference to `data` while
        // `read_exact` fills it, and any bit pattern is a valid f32.
        let bytes: &mut [u8] = unsafe {
            std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, numel * 4)
        };
        r.read_exact(bytes)?;
        if i < engine.manifest.params.len() {
            params.push(data);
        } else {
            opt.push(data);
        }
    }
    engine.state_from_vecs(&params, &opt, step)
}

// ---------------------------------------------------------------------------
// Host-optimizer checkpoints (ETHC)
// ---------------------------------------------------------------------------

// The length-prefixed primitives live in `util::codec`, shared with the
// streaming state export and the shard-transport wire format so all three
// encodings stay byte-compatible.

/// Save a host-optimizer checkpoint: parameters (one flat vector per
/// `groups` entry, in order) plus the optimizer-state snapshot, written as
/// the chunk-framed ETSS stream. Atomic (tmp + rename), like [`save`].
pub fn save_host(
    groups: &[GroupSpec],
    params: &[Vec<f32>],
    state: &StateExport,
    step: u64,
    path: impl AsRef<Path>,
) -> Result<()> {
    anyhow::ensure!(
        groups.len() == params.len(),
        "save_host: {} groups but {} param vectors",
        groups.len(),
        params.len()
    );
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let tmp = path.with_extension("tmp");
    {
        let mut w = BufWriter::new(std::fs::File::create(&tmp)?);
        w.write_all(HOST_MAGIC)?;
        w.write_all(&HOST_VERSION.to_le_bytes())?;
        w.write_all(&step.to_le_bytes())?;
        w.write_all(&(groups.len() as u32).to_le_bytes())?;
        for (g, p) in groups.iter().zip(params) {
            anyhow::ensure!(
                p.len() == g.numel(),
                "save_host: group '{}' has {} values, expected {}",
                g.name,
                p.len(),
                g.numel()
            );
            write_str(&mut w, &g.name)?;
            write_f32s(&mut w, p)?;
        }
        write_export_stream(&mut w, state, STREAM_CHUNK_NUMEL)?;
        w.flush()?;
    }
    std::fs::rename(&tmp, path)?; // atomic replace
    Ok(())
}

/// Load a host-optimizer checkpoint saved by [`save_host`], validating the
/// parameters against `groups` (names + sizes, in order). The returned
/// [`StateExport`] is validated structurally on import
/// (`OptState::import` / `ShardedOptimizer::import_state`).
pub fn load_host(
    groups: &[GroupSpec],
    path: impl AsRef<Path>,
) -> Result<(Vec<Vec<f32>>, StateExport, u64)> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("open host checkpoint {:?}", path.as_ref()))?;
    read_host(groups, &mut BufReader::new(f))
}

/// [`load_host`] over any reader — the untrusted-byte entry point the
/// malformed-input tests and the `ethc_checkpoint` fuzz target drive
/// directly, so "bytes from disk" and "bytes from a fuzzer" take the same
/// path.
pub fn read_host(
    groups: &[GroupSpec],
    r: &mut impl Read,
) -> Result<(Vec<Vec<f32>>, StateExport, u64)> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != HOST_MAGIC {
        bail!("not an ETHC host checkpoint");
    }
    let version = read_u32(&mut r)?;
    if version != HOST_VERSION {
        bail!("unsupported host checkpoint version {version}");
    }
    let step = read_u64(&mut r)?;

    let n_params = read_u32(&mut r)? as usize;
    if n_params != groups.len() {
        bail!("host checkpoint has {n_params} params, expected {}", groups.len());
    }
    let mut params = Vec::with_capacity(n_params);
    for g in groups {
        let name = read_str(&mut r)?;
        if name != g.name {
            bail!("host checkpoint param '{name}', expected '{}'", g.name);
        }
        let data = read_f32s(&mut r, g.numel())?;
        if data.len() != g.numel() {
            bail!(
                "host checkpoint param '{name}': {} values, expected {}",
                data.len(),
                g.numel()
            );
        }
        params.push(data);
    }

    // The state section is the checksum-verified ETSS stream. Every state
    // layout has exactly one state group per parameter group, and no single
    // buffer exceeds 2x the group's numel (Adam/Adadelta hold two d-sized
    // buffers; ET mode vectors and Adafactor factors are all <= d) — bound
    // the stream reads accordingly so corrupted counts fail cleanly.
    let max_buf = 2 * groups.iter().map(|g| g.numel()).max().unwrap_or(0);
    let state = read_export_stream(&mut r, max_buf)
        .context("host checkpoint optimizer-state stream")?;
    if state.groups.len() != groups.len() {
        bail!(
            "host checkpoint has {} state groups, expected {}",
            state.groups.len(),
            groups.len()
        );
    }
    for (ge, g) in state.groups.iter().zip(groups) {
        if ge.name != g.name {
            bail!("host checkpoint state group '{}', expected '{}'", ge.name, g.name);
        }
    }
    Ok((params, state, step))
}

#[cfg(test)]
mod tests {
    // Checkpoint round-trip with a real engine requires artifacts; the
    // integration test `rust/tests/train_loop.rs` covers it (and
    // `rust/tests/host_checkpoint.rs` covers ETHC end to end). Here we
    // test header validation and the raw ETHC round trip.
    use super::*;

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join(format!("etck-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ck");
        std::fs::write(&path, b"NOPE").unwrap();
        // Need an engine to call load(); validate magic by parsing manually.
        let mut f = std::fs::File::open(&path).unwrap();
        let mut magic = [0u8; 4];
        use std::io::Read;
        f.read_exact(&mut magic).unwrap();
        assert_ne!(&magic, MAGIC);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn host_checkpoint_roundtrips_exactly() {
        use crate::optim::{self, Hyper, Optimizer};
        use crate::tensoring::OptimizerKind;
        let dir = std::env::temp_dir().join(format!("ethc-{}", std::process::id()));
        let path = dir.join("host.hck");
        let gs = vec![GroupSpec::new("w", &[4, 4]), GroupSpec::new("b", &[4])];
        let mut opt = optim::build_state(OptimizerKind::EtInf, &gs, &Hyper::default());
        let mut params: Vec<Vec<f32>> = gs.iter().map(|g| vec![0.5f32; g.numel()]).collect();
        let grads: Vec<Vec<f32>> = gs.iter().map(|g| vec![0.25f32; g.numel()]).collect();
        for _ in 0..3 {
            opt.next_step();
            opt.step_all(&mut params, &grads, 0.1).unwrap();
        }
        let state = opt.export();
        save_host(&gs, &params, &state, 3, &path).unwrap();
        let (p2, s2, step) = load_host(&gs, &path).unwrap();
        assert_eq!(step, 3);
        assert_eq!(p2, params);
        assert_eq!(s2, state); // includes the exact f64 wide accumulators

        // Wrong group list must be rejected.
        let other = vec![GroupSpec::new("w2", &[4, 4]), GroupSpec::new("b", &[4])];
        assert!(load_host(&other, &path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn host_load_rejects_etck_files() {
        let dir = std::env::temp_dir().join(format!("ethc-x-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.hck");
        std::fs::write(&path, b"ETCK\x01\x00\x00\x00").unwrap();
        assert!(load_host(&[], &path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}

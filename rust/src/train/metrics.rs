//! Run metrics: step records, perplexity aggregation, throughput.

use crate::util::json::Json;

/// One logged training step.
#[derive(Clone, Debug)]
pub struct StepRecord {
    pub step: u64,
    pub loss: f64,
    pub lr: f64,
    pub tokens_per_sec: f64,
}

impl StepRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::str("train")),
            ("step", Json::num(self.step as f64)),
            ("loss", Json::num(self.loss)),
            ("ppl", Json::num(self.loss.exp())),
            ("lr", Json::num(self.lr)),
            ("tokens_per_sec", Json::num(self.tokens_per_sec)),
        ])
    }
}

/// One logged evaluation.
#[derive(Clone, Debug)]
pub struct EvalRecord {
    pub step: u64,
    pub mean_nll: f64,
    pub tokens: f64,
}

impl EvalRecord {
    pub fn ppl(&self) -> f64 {
        self.mean_nll.exp()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::str("eval")),
            ("step", Json::num(self.step as f64)),
            ("mean_nll", Json::num(self.mean_nll)),
            ("ppl", Json::num(self.ppl())),
            ("tokens", Json::num(self.tokens)),
        ])
    }
}

/// Aggregates (total_nll, count) pairs into exact corpus-level perplexity.
#[derive(Default, Clone, Debug)]
pub struct PplAccumulator {
    total_nll: f64,
    total_count: f64,
}

impl PplAccumulator {
    pub fn add(&mut self, nll: f64, count: f64) {
        self.total_nll += nll;
        self.total_count += count;
    }

    pub fn mean_nll(&self) -> f64 {
        if self.total_count > 0.0 {
            self.total_nll / self.total_count
        } else {
            f64::NAN
        }
    }

    pub fn ppl(&self) -> f64 {
        self.mean_nll().exp()
    }

    pub fn tokens(&self) -> f64 {
        self.total_count
    }
}

/// Final run summary (one row of a paper table).
#[derive(Clone, Debug)]
pub struct RunSummary {
    pub name: String,
    pub optimizer: String,
    pub optimizer_scalars: usize,
    pub model_params: usize,
    pub steps: u64,
    pub final_train_loss: f64,
    pub final_eval_ppl: f64,
    pub wall_seconds: f64,
    pub tokens_per_sec: f64,
}

impl RunSummary {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::str("summary")),
            ("name", Json::str(self.name.clone())),
            ("optimizer", Json::str(self.optimizer.clone())),
            ("optimizer_scalars", Json::num(self.optimizer_scalars as f64)),
            ("model_params", Json::num(self.model_params as f64)),
            ("steps", Json::num(self.steps as f64)),
            ("final_train_loss", Json::num(self.final_train_loss)),
            ("final_eval_ppl", Json::num(self.final_eval_ppl)),
            ("wall_seconds", Json::num(self.wall_seconds)),
            ("tokens_per_sec", Json::num(self.tokens_per_sec)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ppl_aggregation_is_exact() {
        let mut acc = PplAccumulator::default();
        acc.add(10.0, 5.0);
        acc.add(2.0, 1.0);
        assert!((acc.mean_nll() - 2.0).abs() < 1e-12);
        assert!((acc.ppl() - 2f64.exp()).abs() < 1e-9);
        assert_eq!(acc.tokens(), 6.0);
    }

    #[test]
    fn empty_ppl_is_nan() {
        assert!(PplAccumulator::default().mean_nll().is_nan());
    }

    #[test]
    fn records_serialize() {
        let s = StepRecord { step: 3, loss: 1.5, lr: 0.1, tokens_per_sec: 100.0 };
        let j = s.to_json();
        assert_eq!(j.get("step").unwrap().as_usize(), Some(3));
        assert!(j.get("ppl").unwrap().as_f64().unwrap() > 4.0);
        let e = EvalRecord { step: 3, mean_nll: 0.0, tokens: 10.0 };
        assert_eq!(e.ppl(), 1.0);
    }
}

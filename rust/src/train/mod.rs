//! Training orchestration: run configs, the trainer loop, checkpoints and
//! metrics. See `trainer` for the step loop.

pub mod checkpoint;
pub mod config;
pub mod metrics;
pub mod trainer;
pub mod vision;

pub use config::RunConfig;
pub use metrics::{EvalRecord, PplAccumulator, RunSummary, StepRecord};
pub use trainer::{RunResult, Trainer};

//! The training orchestrator: owns the step loop over a compiled train-step
//! artifact, the prefetching data loader, periodic evaluation, JSONL
//! metrics, checkpoints, optional trace tracking (Figure 2), and both
//! step-count and wall-clock budgets (Table 2 needs equal-time runs).
//!
//! Python never appears here: the artifact was lowered once at build time;
//! this loop is pure rust + PJRT.

use super::checkpoint;
use super::config::RunConfig;
use super::metrics::{EvalRecord, PplAccumulator, RunSummary, StepRecord};
use crate::data::{Batcher, Loader, SyntheticConfig};
use crate::optim::{Hyper, Optimizer};
use crate::regret::TraceTracker;
use crate::runtime::{Client, DataArg, Engine, TrainState};
use crate::session::{EventSink, LmData, Session};
use crate::shard::ShardedOptimizer;
use crate::util::json::Json;
use crate::util::logging::JsonlWriter;
use crate::util::timer::{EmaRate, Timer};
use anyhow::{Context, Result};
use std::sync::Arc;

/// Outcome of a completed run.
pub struct RunResult {
    pub summary: RunSummary,
    pub eval_history: Vec<EvalRecord>,
    pub loss_history: Vec<(u64, f64)>,
    pub trace_report: Option<crate::regret::TraceReport>,
}

/// LM trainer bound to one artifact + corpus. Engines and the corpus are
/// shared, read-only session resources (`Arc`), so concurrent trainers in
/// one [`Session`] compile each artifact and synthesize each corpus at
/// most once.
pub struct Trainer {
    pub cfg: RunConfig,
    client: Client,
    engine: Arc<Engine>,
    eval_engine: Option<Arc<Engine>>,
    grad_engine: Option<Arc<Engine>>,
    data: Arc<LmData>,
    sink: Option<EventSink>,
}

impl Trainer {
    /// Standalone constructor: a private one-off [`Session`] (the
    /// compatibility path for `ettrain train` and library users).
    pub fn new(cfg: RunConfig) -> Result<Trainer> {
        let session = Session::new();
        Self::with_session(cfg, &session, None)
    }

    /// Construct against shared session resources, optionally reporting
    /// progress and cache lookups through `sink`.
    pub fn with_session(
        cfg: RunConfig,
        session: &Session,
        sink: Option<EventSink>,
    ) -> Result<Trainer> {
        let client = session.client()?;
        let report = |artifact: &str, hit: bool| {
            if let Some(s) = &sink {
                s.artifact_cache(artifact, hit);
            }
        };
        let (engine, hit) = session
            .engine(&cfg.artifact_dir, &cfg.artifact)
            .with_context(|| format!("load artifact '{}'", cfg.artifact))?;
        report(&cfg.artifact, hit);
        let eval_engine = match &cfg.eval_artifact {
            Some(name) => {
                let (e, hit) = session.engine(&cfg.artifact_dir, name)?;
                report(name, hit);
                Some(e)
            }
            None => None,
        };
        // grad artifact: derive name `<family>_grad` from the train
        // artifact. Needed for trace mirroring and for host-optimizer
        // training (where it replaces the fused train step entirely) —
        // budget-planned runs are host runs too.
        let grad_engine = if cfg.track_traces
            || cfg.host_optimizer.is_some()
            || cfg.opt_memory_budget.is_some()
        {
            let base = cfg
                .artifact
                .rsplit_once('_')
                .map(|(b, _)| b.to_string())
                .unwrap_or_else(|| cfg.artifact.clone());
            let name = format!("{base}_grad");
            let (e, hit) = session.engine(&cfg.artifact_dir, &name)?;
            report(&name, hit);
            Some(e)
        } else {
            None
        };
        let data_cfg = SyntheticConfig {
            vocab: cfg.corpus_vocab,
            sentences: cfg.corpus_sentences,
            seed: cfg.seed ^ 0xc0a9,
            ..SyntheticConfig::default()
        };
        let (data, hit) = session.lm_data(&data_cfg);
        if let Some(s) = &sink {
            s.corpus_cache(&Session::lm_data_key(&data_cfg), hit);
        }
        Ok(Trainer { cfg, client, engine, eval_engine, grad_engine, data, sink })
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    pub fn client(&self) -> &Client {
        &self.client
    }

    /// Build the batcher pipeline matching the artifact's token geometry
    /// over the (session-cached) corpus.
    pub fn build_data(&self) -> Result<(Batcher, Batcher)> {
        let m = &self.engine.manifest;
        let tokens = &m.data_inputs[0];
        anyhow::ensure!(tokens.shape.len() == 2, "expected 2-D token input");
        let (rows, seq) = (tokens.shape[0], tokens.shape[1]);
        let vocab = m
            .model
            .get("vocab")
            .and_then(|v| v.as_usize())
            .context("manifest missing model.vocab")?;
        let tok = &self.data.tokenizer;
        anyhow::ensure!(
            tok.vocab_size() <= vocab,
            "tokenizer vocab {} exceeds model vocab {vocab}",
            tok.vocab_size()
        );
        let (train, valid) = self.data.corpus.split(10);
        Ok((
            Batcher::new(tok, &train, seq, rows),
            Batcher::new(tok, &valid, seq, rows),
        ))
    }

    /// Emit a progress event (no-op without a sink).
    fn progress(&self, step: u64, loss: f64) {
        if let Some(s) = &self.sink {
            s.progress(step, self.cfg.steps, loss);
        }
    }

    /// Run the configured training job.
    pub fn run(&mut self) -> Result<RunResult> {
        if self.cfg.host_optimizer.is_some() || self.cfg.opt_memory_budget.is_some() {
            return self.run_host();
        }
        let run_dir = self.cfg.out_dir.join(&self.cfg.name);
        std::fs::create_dir_all(&run_dir)?;
        let mut log = JsonlWriter::create(run_dir.join("metrics.jsonl"))?;

        let (train_batcher, valid_batcher) = self.build_data()?;
        let tokens_per_batch = train_batcher.seq_len * train_batcher.batch_rows;
        let mut loader =
            Loader::spawn(train_batcher, self.cfg.seed, self.cfg.steps as usize, 4);

        let mut state = if self.cfg.resume {
            let path = run_dir.join("latest.ck");
            let st = checkpoint::load(&self.engine, &path)
                .with_context(|| format!("--resume: load checkpoint {path:?}"))?;
            // Fast-forward the deterministic batch stream so the resumed
            // run consumes exactly the batches the uninterrupted run would
            // have seen from this step on.
            for _ in 0..st.step {
                if loader.next().is_none() {
                    break;
                }
            }
            crate::info!("[{}] resumed from {path:?} at step {}", self.cfg.name, st.step);
            st
        } else {
            self.engine.init_state(self.cfg.seed)?
        };

        // Trace tracker mirrors the artifact's planned tensor indices.
        let mut tracker = if self.cfg.track_traces {
            Some(self.build_tracker()?)
        } else {
            None
        };

        let wall = Timer::start();
        let mut step_ema = EmaRate::new(0.1);
        let mut loss_history = Vec::new();
        let mut eval_history = Vec::new();
        let mut last_loss = f64::NAN;

        while state.step < self.cfg.steps {
            if self.cfg.max_seconds > 0.0 && wall.elapsed_secs() >= self.cfg.max_seconds {
                crate::info!("time budget reached at step {}", state.step);
                break;
            }
            let Some(batch) = loader.next() else { break };
            let lr = self.cfg.schedule.lr(state.step + 1) as f32;

            // Optional gradient mirroring for the Figure 2 traces (before
            // the update, at the current params).
            if let (Some(tracker), Some(grad_engine)) = (&mut tracker, &self.grad_engine) {
                if state.step % self.cfg.trace_every == 0 {
                    let (_, grads) = grad_engine.grad_step(&state, &[DataArg::I32(&batch.tokens)])?;
                    let views: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
                    tracker.observe(&views)?;
                }
            }

            let t0 = Timer::start();
            let out = self.engine.train_step_tokens(&mut state, &batch.tokens, lr)?;
            step_ema.observe(t0.elapsed_secs());
            last_loss = out.loss as f64;
            anyhow::ensure!(last_loss.is_finite(), "loss diverged at step {}", state.step);

            if state.step % self.cfg.log_every == 0 || state.step == self.cfg.steps {
                let tps = step_ema.rate().unwrap_or(0.0) * tokens_per_batch as f64;
                let rec = StepRecord {
                    step: state.step,
                    loss: last_loss,
                    lr: lr as f64,
                    tokens_per_sec: tps,
                };
                log.write(&rec.to_json())?;
                loss_history.push((state.step, last_loss));
                self.progress(state.step, last_loss);
                crate::debugln!(
                    "step {} loss {:.4} lr {:.2e} {:.0} tok/s",
                    state.step,
                    last_loss,
                    lr,
                    tps
                );
            }

            if self.cfg.eval_every > 0
                && state.step % self.cfg.eval_every == 0
                && self.eval_engine.is_some()
            {
                let rec = self.evaluate(&state, &valid_batcher)?;
                log.write(&rec.to_json())?;
                crate::info!(
                    "[{}] step {} val ppl {:.2}",
                    self.cfg.name,
                    state.step,
                    rec.ppl()
                );
                eval_history.push(rec);
            }

            if self.cfg.checkpoint_every > 0 && state.step % self.cfg.checkpoint_every == 0 {
                checkpoint::save(&self.engine, &state, run_dir.join("latest.ck"))?;
            }
        }

        // Final eval.
        let final_ppl = if self.eval_engine.is_some() {
            let rec = self.evaluate(&state, &valid_batcher)?;
            log.write(&rec.to_json())?;
            let p = rec.ppl();
            eval_history.push(rec);
            p
        } else {
            f64::NAN
        };

        if self.cfg.checkpoint_every > 0 {
            checkpoint::save(&self.engine, &state, run_dir.join("final.ck"))?;
        }

        let opt_scalars = self
            .engine
            .manifest
            .optimizer
            .get("state_scalars")
            .and_then(|v| v.as_usize())
            .unwrap_or(self.engine.manifest.total_opt_state());
        let summary = RunSummary {
            name: self.cfg.name.clone(),
            optimizer: self
                .engine
                .manifest
                .optimizer
                .get("kind")
                .and_then(|v| v.as_str())
                .unwrap_or("?")
                .to_string(),
            optimizer_scalars: opt_scalars,
            model_params: self.engine.manifest.total_params(),
            steps: state.step,
            final_train_loss: last_loss,
            final_eval_ppl: final_ppl,
            wall_seconds: wall.elapsed_secs(),
            tokens_per_sec: step_ema.rate().unwrap_or(0.0) * tokens_per_batch as f64,
        };
        log.write(&summary.to_json())?;
        log.flush()?;

        let trace_report = tracker.map(|t| t.report());
        if let Some(r) = &trace_report {
            log.write(&Json::obj(vec![
                ("kind", Json::str("traces")),
                ("trace_h", Json::num(r.trace_h)),
                ("trace_h_hat", Json::num(r.trace_h_hat)),
                ("ratio", Json::num(r.ratio)),
            ]))?;
            log.flush()?;
        }

        Ok(RunResult { summary, eval_history, loss_history, trace_report })
    }

    /// Host-side training: gradients come from the `<family>_grad`
    /// artifact; the update is applied by the pure-rust optimizer engine,
    /// fanned out over `cfg.shards` persistent workers
    /// ([`crate::shard::ShardedOptimizer`]). Parameters live as host
    /// vectors; optimizer state lives shard-local inside the workers (in
    /// the storage backend `cfg.state_backend` selects) and crosses a
    /// shard boundary only for checkpoints: with `checkpoint_every > 0`
    /// the worker-local state is fanned in and written as a
    /// shard-count-independent `latest.hck`/`final.hck`
    /// ([`checkpoint::save_host`]). With `shards = 1` this is
    /// bitwise-identical to running the plain optimizer in-thread.
    fn run_host(&mut self) -> Result<RunResult> {
        let grad_engine = self
            .grad_engine
            .as_ref()
            .context("host-optimizer training needs the <family>_grad artifact")?;
        let run_dir = self.cfg.out_dir.join(&self.cfg.name);
        std::fs::create_dir_all(&run_dir)?;
        let mut log = JsonlWriter::create(run_dir.join("metrics.jsonl"))?;

        let (train_batcher, valid_batcher) = self.build_data()?;
        let tokens_per_batch = train_batcher.seq_len * train_batcher.batch_rows;
        let mut loader =
            Loader::spawn(train_batcher, self.cfg.seed, self.cfg.steps as usize, 4);

        // Host-resident parameters, seeded exactly like the fused path.
        let gm = &grad_engine.manifest;
        let init = grad_engine.init_state(self.cfg.seed)?;
        let mut params: Vec<Vec<f32>> = gm
            .params
            .iter()
            .map(|p| init.param_to_vec(gm, &p.name))
            .collect::<Result<_>>()?;
        // The grad artifact carries no optimizer state; keep a zero block
        // matching its manifest so state reconstruction stays uniform.
        let opt_zeros: Vec<Vec<f32>> =
            gm.opt_state.iter().map(|s| vec![0.0f32; s.numel()]).collect();
        let groups = gm.group_specs();
        let shards = self.cfg.shards.max(1);
        let hyper = Hyper { backend: self.cfg.state_backend, ..Hyper::default() };
        // Budget-planned runs solve for (ET level, backend) per group and
        // execute the plan; otherwise the uniform host_optimizer kind runs.
        let mut opt = match self.cfg.opt_memory_budget {
            Some(budget) => {
                let plan =
                    crate::budget::plan(&groups, budget, &crate::budget::PlannerOptions::default())
                        .with_context(|| {
                            format!("[{}] solve run.opt_memory_budget", self.cfg.name)
                        })?;
                crate::info!(
                    "[{}] budget {} B: planned {} B over {} groups (expressivity {:.0}); \
                     run `ettrain plan` for the table",
                    self.cfg.name,
                    budget,
                    plan.total_bytes(),
                    plan.per_group.len(),
                    plan.total_expressivity()
                );
                if self.cfg.host_optimizer.is_some() {
                    crate::info!(
                        "[{}] run.opt_memory_budget overrides run.host_optimizer/state_backend",
                        self.cfg.name
                    );
                }
                ShardedOptimizer::with_state_plan(&groups, &hyper, shards, &plan)?
            }
            None => {
                let kind = self.cfg.host_optimizer.context("host_optimizer not set")?;
                ShardedOptimizer::new(kind, &groups, &hyper, shards)?
            }
        };
        let mut tracker = if self.cfg.track_traces {
            Some(self.build_tracker()?)
        } else {
            None
        };
        // Label the storage honestly: planned runs mix per-buffer backends
        // from the plan, so cfg.state_backend would be misleading there.
        let storage = if self.cfg.opt_memory_budget.is_some() {
            "planned/mixed".to_string()
        } else {
            self.cfg.state_backend.name()
        };
        crate::info!(
            "[{}] host optimizer {} ({} state scalars, {} state bytes [{}], peak {} per shard)",
            self.cfg.name,
            opt.name(),
            opt.state_scalars(),
            opt.state_bytes(),
            storage,
            opt.peak_state_scalars()
        );

        let mut step: u64 = 0;
        if self.cfg.resume {
            let path = run_dir.join("latest.hck");
            let (saved_params, export, saved_step) = checkpoint::load_host(&groups, &path)
                .with_context(|| format!("--resume: load host checkpoint {path:?}"))?;
            opt.import_state(&export)
                .with_context(|| format!("--resume: restore optimizer state from {path:?}"))?;
            params = saved_params;
            step = saved_step;
            // Align the deterministic batch stream with the uninterrupted
            // run (`rust/tests/host_checkpoint.rs` pins the arithmetic;
            // this pins the data).
            for _ in 0..step {
                if loader.next().is_none() {
                    break;
                }
            }
            crate::info!("[{}] resumed from {path:?} at step {step}", self.cfg.name);
        }

        let wall = Timer::start();
        let mut step_ema = EmaRate::new(0.1);
        let mut loss_history = Vec::new();
        let mut eval_history = Vec::new();
        let mut last_loss = f64::NAN;

        while step < self.cfg.steps {
            if self.cfg.max_seconds > 0.0 && wall.elapsed_secs() >= self.cfg.max_seconds {
                crate::info!("time budget reached at step {step}");
                break;
            }
            let Some(batch) = loader.next() else { break };
            step += 1;
            let lr = self.cfg.schedule.lr(step) as f32;

            let t0 = Timer::start();
            let state = grad_engine.state_from_vecs(&params, &opt_zeros, step)?;
            let (loss, grads) =
                grad_engine.grad_step(&state, &[DataArg::I32(&batch.tokens)])?;
            // Trace mirroring sees the gradients at the *current* params,
            // before the update — same convention as the fused path.
            if let Some(tracker) = &mut tracker {
                if step % self.cfg.trace_every == 0 {
                    let views: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
                    tracker.observe(&views)?;
                }
            }
            opt.next_step();
            opt.step_all(&mut params, &grads, lr)?;
            step_ema.observe(t0.elapsed_secs());
            last_loss = loss as f64;
            anyhow::ensure!(last_loss.is_finite(), "loss diverged at step {step}");

            if step % self.cfg.log_every == 0 || step == self.cfg.steps {
                let tps = step_ema.rate().unwrap_or(0.0) * tokens_per_batch as f64;
                let rec = StepRecord {
                    step,
                    loss: last_loss,
                    lr: lr as f64,
                    tokens_per_sec: tps,
                };
                log.write(&rec.to_json())?;
                loss_history.push((step, last_loss));
                self.progress(step, last_loss);
                crate::debugln!(
                    "step {step} loss {last_loss:.4} lr {lr:.2e} {tps:.0} tok/s [host/{shards}sh]"
                );
            }

            if self.cfg.eval_every > 0
                && step % self.cfg.eval_every == 0
                && self.eval_engine.is_some()
            {
                // Rebuild from the just-updated params so the eval record
                // matches its step (the fused path evaluates post-update).
                let eval_state = grad_engine.state_from_vecs(&params, &opt_zeros, step)?;
                let rec = self.evaluate(&eval_state, &valid_batcher)?;
                log.write(&rec.to_json())?;
                crate::info!("[{}] step {step} val ppl {:.2}", self.cfg.name, rec.ppl());
                eval_history.push(rec);
            }

            if self.cfg.checkpoint_every > 0 && step % self.cfg.checkpoint_every == 0 {
                // Shard-aware checkpoint: fan worker-local state in as one
                // global, shard-count-independent snapshot.
                let state = opt.export_state()?;
                checkpoint::save_host(
                    &groups,
                    &params,
                    &state,
                    step,
                    run_dir.join("latest.hck"),
                )?;
            }
        }

        // Final eval at the final parameters.
        let final_ppl = if self.eval_engine.is_some() {
            let state = grad_engine.state_from_vecs(&params, &opt_zeros, step)?;
            let rec = self.evaluate(&state, &valid_batcher)?;
            log.write(&rec.to_json())?;
            let p = rec.ppl();
            eval_history.push(rec);
            p
        } else {
            f64::NAN
        };

        if self.cfg.checkpoint_every > 0 {
            let state = opt.export_state()?;
            checkpoint::save_host(&groups, &params, &state, step, run_dir.join("final.hck"))?;
        }

        let summary = RunSummary {
            name: self.cfg.name.clone(),
            optimizer: opt.name(),
            optimizer_scalars: opt.state_scalars(),
            model_params: gm.total_params(),
            steps: step,
            final_train_loss: last_loss,
            final_eval_ppl: final_ppl,
            wall_seconds: wall.elapsed_secs(),
            tokens_per_sec: step_ema.rate().unwrap_or(0.0) * tokens_per_batch as f64,
        };
        log.write(&summary.to_json())?;
        log.flush()?;

        let trace_report = tracker.map(|t| t.report());
        if let Some(r) = &trace_report {
            log.write(&Json::obj(vec![
                ("kind", Json::str("traces")),
                ("trace_h", Json::num(r.trace_h)),
                ("trace_h_hat", Json::num(r.trace_h_hat)),
                ("ratio", Json::num(r.ratio)),
            ]))?;
            log.flush()?;
        }

        Ok(RunResult { summary, eval_history, loss_history, trace_report })
    }

    fn evaluate(&self, state: &TrainState, valid: &Batcher) -> Result<EvalRecord> {
        let eval_engine = self.eval_engine.as_ref().context("no eval artifact")?;
        let order = valid.epoch_order(0, self.cfg.seed);
        let mut acc = PplAccumulator::default();
        for b in 0..valid.batches_per_epoch().min(self.cfg.eval_batches) {
            let batch = valid.batch(&order, b).context("eval batch")?;
            let out = eval_engine.eval_step(state, &[DataArg::I32(&batch.tokens)])?;
            acc.add(out.total_nll, out.token_count);
        }
        Ok(EvalRecord { step: state.step, mean_nll: acc.mean_nll(), tokens: acc.tokens() })
    }

    /// Trace tracker over the artifact's ET tensor-index dims: each
    /// parameter's dims are recovered from the opt-state shapes when the
    /// artifact *is* an ET artifact, else planned at ET1 (the tracker is
    /// measuring what ET *would* store — Figure 2 compares against the
    /// AdaGrad baseline regardless of which optimizer trains).
    fn build_tracker(&self) -> Result<TraceTracker> {
        let m = &self.engine.manifest;
        let mut groups = Vec::new();
        for p in &m.params {
            let prefix = format!("{}.s", p.name);
            let mut dims: Vec<usize> = m
                .opt_state
                .iter()
                .filter(|s| s.name.starts_with(&prefix))
                .map(|s| s.shape[0])
                .collect();
            if dims.is_empty() || dims.iter().product::<usize>() != p.numel() {
                dims = crate::tensoring::plan(&p.shape, crate::tensoring::Level::Et(1));
            }
            groups.push((p.name.clone(), dims));
        }
        TraceTracker::new(&groups, 1e-8)
    }
}

//! Vision training loop for the appendix experiment (Table 4 / Figure 4):
//! drives the `cnn_*` train/eval artifacts over the synthetic image
//! substrate. Smaller than the LM trainer (in-memory dataset, no packing),
//! so it gets its own compact loop.

use crate::runtime::{Client, DataArg, Engine, TrainState};
use crate::session::{EventSink, Session, VisionData};
use crate::util::rng::Pcg64;
use crate::vision::{VisionConfig, VisionDataset, CHANNELS, IMG};
use anyhow::{Context, Result};
use std::sync::Arc;

pub struct VisionRun {
    pub optimizer: String,
    pub optimizer_scalars: usize,
    pub model_params: usize,
    pub final_test_error: f64,
    pub best_test_error: f64,
    pub final_train_loss: f64,
    pub steps: u64,
    pub loss_history: Vec<(u64, f64)>,
}

pub struct VisionTrainer {
    engine: Arc<Engine>,
    eval: Arc<Engine>,
    data: Arc<VisionData>,
    batch: usize,
    sink: Option<EventSink>,
}

impl VisionTrainer {
    pub fn new(
        client: &Client,
        artifact_dir: &std::path::Path,
        optimizer: &str,
        data_cfg: &VisionConfig,
    ) -> Result<VisionTrainer> {
        let engine = Arc::new(Engine::load(client, artifact_dir, &format!("cnn_{optimizer}"))?);
        let eval = Arc::new(Engine::load(client, artifact_dir, "cnn_eval")?);
        let (train, test) = VisionDataset::generate(data_cfg);
        Self::from_parts(engine, eval, Arc::new(VisionData { train, test }), None)
    }

    /// Construct against shared session resources: the `cnn_*` engines are
    /// compiled and the dataset synthesized at most once per session;
    /// cache lookups are reported through `sink`.
    pub fn with_session(
        session: &Session,
        artifact_dir: &std::path::Path,
        optimizer: &str,
        data_cfg: &VisionConfig,
        sink: Option<EventSink>,
    ) -> Result<VisionTrainer> {
        let train_name = format!("cnn_{optimizer}");
        let (engine, hit) = session.engine(artifact_dir, &train_name)?;
        let (eval, eval_hit) = session.engine(artifact_dir, "cnn_eval")?;
        let (data, data_hit) = session.vision_data(data_cfg);
        if let Some(s) = &sink {
            s.artifact_cache(&train_name, hit);
            s.artifact_cache("cnn_eval", eval_hit);
            s.corpus_cache(&Session::vision_key(data_cfg), data_hit);
        }
        Self::from_parts(engine, eval, data, sink)
    }

    fn from_parts(
        engine: Arc<Engine>,
        eval: Arc<Engine>,
        data: Arc<VisionData>,
        sink: Option<EventSink>,
    ) -> Result<VisionTrainer> {
        let batch = engine.manifest.data_inputs[0].shape[0];
        Ok(VisionTrainer { engine, eval, data, batch, sink })
    }

    fn gather_batch(&self, set: &VisionDataset, idx: &[usize]) -> (Vec<f32>, Vec<i32>) {
        let pix = CHANNELS * IMG * IMG;
        let mut images = Vec::with_capacity(idx.len() * pix);
        let mut labels = Vec::with_capacity(idx.len());
        for &i in idx {
            images.extend_from_slice(set.image(i));
            labels.push(set.y[i] as i32);
        }
        (images, labels)
    }

    /// Train for `steps` minibatch steps at constant `lr` (the appendix
    /// uses tuned constant rates), evaluating test error every
    /// `eval_every`.
    pub fn run(&mut self, steps: u64, lr: f32, eval_every: u64, seed: u64) -> Result<VisionRun> {
        let mut rng = Pcg64::seeded(seed);
        let mut state = self.engine.init_state(seed)?;
        let mut order: Vec<usize> = (0..self.data.train.n).collect();
        let mut cursor = self.data.train.n; // force initial shuffle
        let mut best_err = f64::INFINITY;
        let mut last_loss = f64::NAN;
        let mut loss_history = Vec::new();

        while state.step < steps {
            if cursor + self.batch > order.len() {
                rng.shuffle(&mut order);
                cursor = 0;
            }
            let idx = &order[cursor..cursor + self.batch];
            cursor += self.batch;
            let (images, labels) = self.gather_batch(&self.data.train, idx);
            let out = self.engine.train_step(
                &mut state,
                &[DataArg::F32(&images), DataArg::I32(&labels)],
                lr,
            )?;
            last_loss = out.loss as f64;
            anyhow::ensure!(last_loss.is_finite(), "vision loss diverged at {}", state.step);
            if state.step % 10 == 0 {
                loss_history.push((state.step, last_loss));
                if let Some(sink) = &self.sink {
                    sink.progress(state.step, steps, last_loss);
                }
            }
            if eval_every > 0 && state.step % eval_every == 0 {
                best_err = best_err.min(self.test_error(&state)?);
            }
        }
        let final_err = self.test_error(&state)?;
        best_err = best_err.min(final_err);

        let opt_scalars = self
            .engine
            .manifest
            .optimizer
            .get("state_scalars")
            .and_then(|v| v.as_usize())
            .unwrap_or(self.engine.manifest.total_opt_state());
        Ok(VisionRun {
            optimizer: self
                .engine
                .manifest
                .optimizer
                .get("kind")
                .and_then(|v| v.as_str())
                .unwrap_or("?")
                .to_string(),
            optimizer_scalars: opt_scalars,
            model_params: self.engine.manifest.total_params(),
            final_test_error: final_err,
            best_test_error: best_err,
            final_train_loss: last_loss,
            steps: state.step,
            loss_history,
        })
    }

    /// Exact test error over the full test set (batched).
    pub fn test_error(&self, state: &TrainState) -> Result<f64> {
        let mut wrong = 0.0f64;
        let mut total = 0.0f64;
        let mut i = 0;
        while i + self.batch <= self.data.test.n {
            let idx: Vec<usize> = (i..i + self.batch).collect();
            let (images, labels) = self.gather_batch(&self.data.test, &idx);
            let out = self
                .eval
                .eval_step(state, &[DataArg::F32(&images), DataArg::I32(&labels)])
                .context("cnn eval step")?;
            wrong += out.total_nll; // eval artifact returns (wrong_count, count)
            total += out.token_count;
            i += self.batch;
        }
        anyhow::ensure!(total > 0.0, "empty test set");
        Ok(wrong / total)
    }
}

//! The golden perf gate behind `ettrain gate`.
//!
//! Joins fresh `BENCH_optim.json` / `BENCH_pareto.json` rows to the
//! checked-in `goldens/` copies by row key and fails (non-zero exit,
//! named offending row + delta) on regressions beyond a tolerance band.
//!
//! Join keys: optim rows join by `name` (which already encodes
//! kind × backend for step rows and p × eps-mode × variant for kernel
//! rows); pareto rows join by `(task, budget_bytes)`.
//!
//! Cross-machine noise: raw ns/element is not comparable between hosts,
//! so the optim gate normalizes by the **median drift** — it computes
//! the ratio `current/golden` per joined row, takes the median ratio
//! `m` (the machine-speed factor), and fails rows whose ratio exceeds
//! `m * (1 + tolerance)`. A uniformly slower runner passes; a single
//! regressed kernel stands out. `speedup_vs_reference` and all pareto
//! quality metrics are machine-relative already and gate directly
//! against the band.
//!
//! Bootstrap goldens: a golden file with `"pinned": false` (the state
//! this repo checks in before a reference machine has run the suites)
//! downgrades comparison failures to warnings — run
//! `ettrain gate --bless` on the reference machine to pin real numbers.

use crate::coordinator::report::Table;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::fmt;
use std::path::{Path, PathBuf};

/// Typed gate failures; `Display` is the user-facing message.
#[derive(Clone, Debug, PartialEq)]
pub enum GateError {
    /// A golden row has no counterpart in the fresh bench output.
    MissingRow { file: String, key: String },
    /// The fresh bench output grew a row the goldens don't know.
    ExtraRow { file: String, key: String },
    /// A joined row moved beyond the tolerance band.
    Regression {
        file: String,
        key: String,
        metric: String,
        golden: String,
        current: String,
        delta_pct: f64,
    },
    /// The bench file itself is malformed.
    Schema { file: String, msg: String },
    /// A fresh pareto row is dominated by a golden row from a
    /// *different* budget: the golden spends no more plan memory yet
    /// reaches a better loss than the band allows. Per-key comparison
    /// cannot see this — it is a regression of the frontier's shape.
    Dominated { file: String, key: String, by: String, detail: String },
}

impl fmt::Display for GateError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            GateError::MissingRow { file, key } => {
                write!(f, "{file}: golden row '{key}' missing from current bench")
            }
            GateError::ExtraRow { file, key } => {
                write!(f, "{file}: row '{key}' not present in goldens (bless to accept)")
            }
            GateError::Regression { file, key, metric, golden, current, delta_pct } => {
                write!(
                    f,
                    "{file}: '{key}' {metric} regressed {delta_pct:+.1}% \
                     (golden {golden} -> current {current})"
                )
            }
            GateError::Schema { file, msg } => write!(f, "{file}: {msg}"),
            GateError::Dominated { file, key, by, detail } => {
                write!(f, "{file}: row '{key}' dominated by golden '{by}' ({detail})")
            }
        }
    }
}

/// One joined row for the delta table (shown for every row, pass or
/// fail, so a near-miss is visible before it regresses).
#[derive(Clone, Debug)]
pub struct DeltaRow {
    pub key: String,
    pub metric: String,
    pub golden: f64,
    pub current: f64,
    pub delta_pct: f64,
    pub ok: bool,
}

#[derive(Clone, Debug)]
pub struct GateOptions {
    /// Allowed fractional regression (0.10 = 10%).
    pub tolerance: f64,
    /// Directory holding the golden `BENCH_*.json` copies.
    pub goldens_dir: PathBuf,
    /// Fresh bench outputs (the paths the suites write to).
    pub optim_path: PathBuf,
    pub pareto_path: PathBuf,
    /// Re-pin the goldens from the fresh outputs instead of comparing.
    pub bless: bool,
    /// Schema validation only (the CI replacement for the inline
    /// Python asserts) — no goldens needed.
    pub schema_only: bool,
    /// Fail (instead of warn-and-pass) when the goldens are still
    /// bootstrap placeholders (`pinned: false`). Release CI sets this so
    /// a branch can't ship against numbers nobody has blessed.
    pub require_pinned: bool,
}

impl Default for GateOptions {
    fn default() -> Self {
        GateOptions {
            tolerance: 0.10,
            goldens_dir: PathBuf::from("goldens"),
            optim_path: PathBuf::from("BENCH_optim.json"),
            pareto_path: PathBuf::from("BENCH_pareto.json"),
            bless: false,
            schema_only: false,
            require_pinned: false,
        }
    }
}

/// Accept `"10%"` or a bare fraction `"0.1"`.
pub fn parse_tolerance(s: &str) -> Result<f64> {
    let t = s.trim();
    let v = if let Some(pct) = t.strip_suffix('%') {
        pct.trim().parse::<f64>().map(|p| p / 100.0)
    } else {
        t.parse::<f64>()
    }
    .with_context(|| format!("bad tolerance '{s}' (want e.g. '10%' or '0.1')"))?;
    if !v.is_finite() || v <= 0.0 || v >= 10.0 {
        bail!("tolerance '{s}' out of range (0, 1000%)");
    }
    Ok(v)
}

fn str_field<'a>(r: &'a Json, k: &str) -> Option<&'a str> {
    r.get(k).and_then(|v| v.as_str())
}

fn num_field(r: &Json, k: &str) -> Option<f64> {
    r.get(k).and_then(|v| v.as_f64())
}

/// The `bench_optim/v1` invariants — a faithful port of the former CI
/// inline-Python asserts.
pub fn check_optim_schema(doc: &Json, file: &str) -> Vec<GateError> {
    let mut errs = Vec::new();
    let schema = |msg: String| GateError::Schema { file: file.to_string(), msg };
    if str_field(doc, "schema") != Some("bench_optim/v1") {
        errs.push(schema(format!(
            "schema tag is {:?}, want \"bench_optim/v1\"",
            str_field(doc, "schema")
        )));
        return errs;
    }
    let Some(records) = doc.get("records").and_then(|v| v.as_arr()) else {
        errs.push(schema("missing 'records' array".to_string()));
        return errs;
    };
    if records.is_empty() {
        errs.push(schema("no records".to_string()));
    }
    for r in records {
        let name = str_field(r, "name").unwrap_or("<unnamed>");
        for k in ["name", "ns_per_element", "elements_per_sec"] {
            if r.get(k).is_none() {
                errs.push(schema(format!("record '{name}' missing '{k}'")));
            }
        }
        if let Some(ns) = num_field(r, "ns_per_element") {
            if ns.is_nan() || ns <= 0.0 {
                errs.push(schema(format!("record '{name}': ns_per_element {ns} not > 0")));
            }
        }
    }
    errs
}

/// Keys every `bench_pareto/v1` row must carry.
const PARETO_KEYS: [&str; 7] =
    ["task", "budget_bytes", "plan_bytes", "choice", "expressivity", "final_loss", "accuracy"];

/// The `bench_pareto/v1` invariants (same provenance as above).
pub fn check_pareto_schema(doc: &Json, file: &str) -> Vec<GateError> {
    let mut errs = Vec::new();
    let schema = |msg: String| GateError::Schema { file: file.to_string(), msg };
    if str_field(doc, "schema") != Some("bench_pareto/v1") {
        errs.push(schema(format!(
            "schema tag is {:?}, want \"bench_pareto/v1\"",
            str_field(doc, "schema")
        )));
        return errs;
    }
    let Some(rows) = doc.get("rows").and_then(|v| v.as_arr()) else {
        errs.push(schema("missing 'rows' array".to_string()));
        return errs;
    };
    if rows.is_empty() {
        errs.push(schema("no rows".to_string()));
    }
    for r in rows {
        let task = str_field(r, "task").unwrap_or("<untasked>");
        for k in PARETO_KEYS {
            if r.get(k).is_none() {
                errs.push(schema(format!("row '{task}' missing '{k}'")));
            }
        }
        if let (Some(p), Some(b)) = (num_field(r, "plan_bytes"), num_field(r, "budget_bytes")) {
            if p > b {
                errs.push(schema(format!("row '{task}': plan_bytes {p} over budget {b}")));
            }
        }
    }
    errs
}

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let n = xs.len();
    if n == 0 {
        return 1.0;
    }
    if n % 2 == 1 {
        xs[n / 2]
    } else {
        0.5 * (xs[n / 2 - 1] + xs[n / 2])
    }
}

fn keyed<'a>(
    rows: &'a [Json],
    key_of: impl Fn(&Json) -> Option<String>,
) -> Vec<(String, &'a Json)> {
    rows.iter().filter_map(|r| key_of(r).map(|k| (k, r))).collect()
}

fn join_errors(
    file: &str,
    golden: &[(String, &Json)],
    current: &[(String, &Json)],
) -> Vec<GateError> {
    let mut errs = Vec::new();
    for (k, _) in golden {
        if !current.iter().any(|(c, _)| c == k) {
            errs.push(GateError::MissingRow { file: file.to_string(), key: k.clone() });
        }
    }
    for (k, _) in current {
        if !golden.iter().any(|(g, _)| g == k) {
            errs.push(GateError::ExtraRow { file: file.to_string(), key: k.clone() });
        }
    }
    errs
}

/// Compare fresh optim records against goldens. Returns the typed
/// failures plus the full delta table (every joined row).
pub fn compare_optim(
    golden: &Json,
    current: &Json,
    tolerance: f64,
) -> (Vec<GateError>, Vec<DeltaRow>) {
    let file = "BENCH_optim.json";
    let empty = Vec::new();
    let g_rows = golden.get("records").and_then(|v| v.as_arr()).unwrap_or(&empty);
    let c_rows = current.get("records").and_then(|v| v.as_arr()).unwrap_or(&empty);
    let key_of = |r: &Json| str_field(r, "name").map(|s| s.to_string());
    let g = keyed(g_rows, key_of);
    let c = keyed(c_rows, key_of);
    let mut errs = join_errors(file, &g, &c);

    let joined: Vec<(&str, &Json, &Json)> = g
        .iter()
        .filter_map(|(k, gr)| {
            c.iter().find(|(ck, _)| ck == k).map(|(_, cr)| (k.as_str(), *gr, *cr))
        })
        .collect();

    // Median current/golden ns ratio = the machine-drift factor.
    let ratios: Vec<f64> = joined
        .iter()
        .filter_map(|(_, gr, cr)| {
            let g = num_field(gr, "ns_per_element")?;
            let c = num_field(cr, "ns_per_element")?;
            (g > 0.0 && c > 0.0).then_some(c / g)
        })
        .collect();
    let drift = median(ratios);

    let mut deltas = Vec::new();
    for (k, gr, cr) in &joined {
        if let (Some(g), Some(c)) =
            (num_field(gr, "ns_per_element"), num_field(cr, "ns_per_element"))
        {
            let ratio = if g > 0.0 { c / g } else { 1.0 };
            // Drift-normalized slowdown relative to the fleet median.
            let rel = if drift > 0.0 { ratio / drift } else { 1.0 };
            let ok = rel <= 1.0 + tolerance;
            let delta_pct = (rel - 1.0) * 100.0;
            deltas.push(DeltaRow {
                key: k.to_string(),
                metric: "ns/element (drift-normalized)".to_string(),
                golden: g,
                current: c,
                delta_pct,
                ok,
            });
            if !ok {
                errs.push(GateError::Regression {
                    file: file.to_string(),
                    key: k.to_string(),
                    metric: "ns_per_element".to_string(),
                    golden: format!("{g:.2}"),
                    current: format!("{c:.2}"),
                    delta_pct,
                });
            }
        }
        // Kernel rows carry a machine-relative speedup; gate directly.
        if let (Some(g), Some(c)) =
            (num_field(gr, "speedup_vs_reference"), num_field(cr, "speedup_vs_reference"))
        {
            let ok = c >= g * (1.0 - tolerance);
            let delta_pct = if g != 0.0 { (c / g - 1.0) * 100.0 } else { 0.0 };
            deltas.push(DeltaRow {
                key: k.to_string(),
                metric: "speedup_vs_reference".to_string(),
                golden: g,
                current: c,
                delta_pct,
                ok,
            });
            if !ok {
                errs.push(GateError::Regression {
                    file: file.to_string(),
                    key: k.to_string(),
                    metric: "speedup_vs_reference".to_string(),
                    golden: format!("{g:.3}"),
                    current: format!("{c:.3}"),
                    delta_pct,
                });
            }
        }
    }
    (errs, deltas)
}

/// Compare fresh pareto rows against goldens: plan bytes and planner
/// choice must match exactly (the planner is deterministic); quality
/// metrics gate on the band.
pub fn compare_pareto(
    golden: &Json,
    current: &Json,
    tolerance: f64,
) -> (Vec<GateError>, Vec<DeltaRow>) {
    let file = "BENCH_pareto.json";
    let empty = Vec::new();
    let g_rows = golden.get("rows").and_then(|v| v.as_arr()).unwrap_or(&empty);
    let c_rows = current.get("rows").and_then(|v| v.as_arr()).unwrap_or(&empty);
    let key_of = |r: &Json| {
        let task = str_field(r, "task")?;
        let budget = num_field(r, "budget_bytes")?;
        Some(format!("{task}/{budget}"))
    };
    let g = keyed(g_rows, key_of);
    let c = keyed(c_rows, key_of);
    let mut errs = join_errors(file, &g, &c);
    let mut deltas = Vec::new();

    for (k, gr) in &g {
        let Some((_, cr)) = c.iter().find(|(ck, _)| ck == k) else { continue };
        // Exact planner determinism: same budget -> same plan.
        for metric in ["plan_bytes", "choice"] {
            let (gv, cv) = (gr.get(metric), cr.get(metric));
            if gv != cv {
                errs.push(GateError::Regression {
                    file: file.to_string(),
                    key: k.clone(),
                    metric: metric.to_string(),
                    golden: gv.map(|v| v.to_string()).unwrap_or_default(),
                    current: cv.map(|v| v.to_string()).unwrap_or_default(),
                    delta_pct: 0.0,
                });
            }
        }
        // Quality band: lower loss / higher accuracy / higher
        // expressivity is better.
        let checks: [(&str, bool); 3] =
            [("expressivity", true), ("accuracy", true), ("final_loss", false)];
        for (metric, higher_is_better) in checks {
            let (Some(gv), Some(cv)) = (num_field(gr, metric), num_field(cr, metric)) else {
                continue;
            };
            let delta_pct = if gv != 0.0 { (cv / gv - 1.0) * 100.0 } else { 0.0 };
            let ok = if higher_is_better {
                cv >= gv * (1.0 - tolerance)
            } else {
                cv <= gv * (1.0 + tolerance)
            };
            deltas.push(DeltaRow {
                key: k.clone(),
                metric: metric.to_string(),
                golden: gv,
                current: cv,
                delta_pct,
                ok,
            });
            if !ok {
                errs.push(GateError::Regression {
                    file: file.to_string(),
                    key: k.clone(),
                    metric: metric.to_string(),
                    golden: format!("{gv:.6}"),
                    current: format!("{cv:.6}"),
                    delta_pct,
                });
            }
        }
    }
    (errs, deltas)
}

/// Pareto-frontier dominance check, on top of the per-key band in
/// [`compare_pareto`]: a fresh row must not be *dominated* by a golden
/// row of the same task at a different budget — one that spends no more
/// plan memory (`plan_bytes <=`) yet reaches a loss better than the
/// tolerance band (`current loss > golden loss * (1 + tolerance)`).
///
/// This catches frontier-shape regressions the keyed join cannot: if
/// the 4 KiB plan's loss drifts up until the 2 KiB golden beats it, the
/// larger budget has stopped buying anything, even though every keyed
/// row might still sit inside its own band.
pub fn compare_frontier(golden: &Json, current: &Json, tolerance: f64) -> Vec<GateError> {
    let file = "BENCH_pareto.json";
    let empty = Vec::new();
    let g_rows = golden.get("rows").and_then(|v| v.as_arr()).unwrap_or(&empty);
    let c_rows = current.get("rows").and_then(|v| v.as_arr()).unwrap_or(&empty);
    let key_of = |r: &Json| {
        let task = str_field(r, "task")?;
        let budget = num_field(r, "budget_bytes")?;
        Some(format!("{task}/{budget}"))
    };
    let mut errs = Vec::new();
    for cr in c_rows {
        let Some(ck) = key_of(cr) else { continue };
        let (Some(task), Some(c_plan), Some(c_loss)) =
            (str_field(cr, "task"), num_field(cr, "plan_bytes"), num_field(cr, "final_loss"))
        else {
            continue;
        };
        for gr in g_rows {
            let Some(gk) = key_of(gr) else { continue };
            if gk == ck || str_field(gr, "task") != Some(task) {
                continue; // same-key loss drift is compare_pareto's job
            }
            let (Some(g_plan), Some(g_loss)) =
                (num_field(gr, "plan_bytes"), num_field(gr, "final_loss"))
            else {
                continue;
            };
            if g_plan <= c_plan && c_loss > g_loss * (1.0 + tolerance) {
                errs.push(GateError::Dominated {
                    file: file.to_string(),
                    key: ck.clone(),
                    by: gk,
                    detail: format!(
                        "golden plan {g_plan:.0} B <= {c_plan:.0} B at loss \
                         {g_loss:.6} vs {c_loss:.6}"
                    ),
                });
            }
        }
    }
    errs
}

fn load_json(path: &Path) -> Result<Json> {
    let text = std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
    Json::parse(&text).map_err(|e| anyhow::anyhow!("parse {path:?}: {e}"))
}

/// `"pinned": false` marks bootstrap goldens (structure only, numbers
/// not yet from a reference machine); absent means pinned.
fn is_pinned(doc: &Json) -> bool {
    doc.get("pinned").and_then(|v| v.as_bool()).unwrap_or(true)
}

fn delta_table(title: &str, deltas: &[DeltaRow]) -> Table {
    let mut t = Table::new(title, &["row", "metric", "golden", "current", "delta %", "status"]);
    for d in deltas {
        t.row(vec![
            d.key.clone(),
            d.metric.clone(),
            format!("{:.4}", d.golden),
            format!("{:.4}", d.current),
            format!("{:+.1}", d.delta_pct),
            if d.ok { "ok".to_string() } else { "FAIL".to_string() },
        ]);
    }
    t
}

fn bless_one(src: &Path, dst_dir: &Path, check: impl Fn(&Json) -> Vec<GateError>) -> Result<()> {
    let mut doc = load_json(src)?;
    let errs = check(&doc);
    if let Some(e) = errs.first() {
        bail!("refusing to bless malformed bench output: {e}");
    }
    if let Json::Obj(map) = &mut doc {
        map.insert("pinned".to_string(), Json::Bool(true));
        map.insert("blessed_commit".to_string(), Json::str(&super::commit_string()));
        map.insert("blessed_host".to_string(), Json::str(&super::host()));
    }
    std::fs::create_dir_all(dst_dir)?;
    let dst = dst_dir.join(src.file_name().context("bless: bench path has no file name")?);
    std::fs::write(&dst, doc.to_string_pretty() + "\n")
        .with_context(|| format!("write {dst:?}"))?;
    println!("blessed {dst:?}");
    Ok(())
}

/// The `ettrain gate` entry point. Non-zero exit (an `Err`) names the
/// first offending row; the full delta table prints either way.
pub fn run_gate(opts: &GateOptions) -> Result<()> {
    if opts.bless {
        bless_one(&opts.optim_path, &opts.goldens_dir, |d| {
            check_optim_schema(d, "BENCH_optim.json")
        })?;
        bless_one(&opts.pareto_path, &opts.goldens_dir, |d| {
            check_pareto_schema(d, "BENCH_pareto.json")
        })?;
        return Ok(());
    }

    let optim = load_json(&opts.optim_path)?;
    let pareto = load_json(&opts.pareto_path)?;
    let mut schema_errs = check_optim_schema(&optim, "BENCH_optim.json");
    schema_errs.extend(check_pareto_schema(&pareto, "BENCH_pareto.json"));
    if let Some(e) = schema_errs.first() {
        for e in &schema_errs {
            eprintln!("schema: {e}");
        }
        bail!("gate: schema validation failed: {e}");
    }
    if opts.schema_only {
        let n_opt = optim.get("records").and_then(|v| v.as_arr()).map_or(0, |r| r.len());
        let n_par = pareto.get("rows").and_then(|v| v.as_arr()).map_or(0, |r| r.len());
        println!("ok: {n_opt} optim records, {n_par} pareto rows");
        return Ok(());
    }

    let g_optim = load_json(&opts.goldens_dir.join("BENCH_optim.json"))?;
    let g_pareto = load_json(&opts.goldens_dir.join("BENCH_pareto.json"))?;
    let pinned = is_pinned(&g_optim) && is_pinned(&g_pareto);
    if opts.require_pinned && !pinned {
        let which = [
            (!is_pinned(&g_optim)).then_some("BENCH_optim.json"),
            (!is_pinned(&g_pareto)).then_some("BENCH_pareto.json"),
        ]
        .into_iter()
        .flatten()
        .collect::<Vec<_>>()
        .join(", ");
        bail!(
            "gate: --require-pinned is set but goldens are bootstrap (pinned = false): {which}. \
             Run the bench suites on a reference machine and `ettrain gate --bless` to pin \
             real numbers."
        );
    }

    let (mut errs, optim_deltas) = compare_optim(&g_optim, &optim, opts.tolerance);
    let (pareto_errs, pareto_deltas) = compare_pareto(&g_pareto, &pareto, opts.tolerance);
    errs.extend(pareto_errs);
    errs.extend(compare_frontier(&g_pareto, &pareto, opts.tolerance));

    print!(
        "{}",
        delta_table(
            &format!("optim vs goldens (tolerance {:.0}%)", opts.tolerance * 100.0),
            &optim_deltas
        )
        .render()
    );
    print!("{}", delta_table("pareto vs goldens", &pareto_deltas).render());

    if errs.is_empty() {
        println!(
            "gate: ok ({} optim rows, {} pareto checks within the band)",
            optim_deltas.len(),
            pareto_deltas.len()
        );
        return Ok(());
    }
    if !pinned {
        for e in &errs {
            crate::warnln!("gate (unpinned goldens): {e}");
        }
        println!(
            "gate: goldens are bootstrap (pinned = false) — {} difference(s) reported as \
             warnings. Run the bench suites on a reference machine and `ettrain gate --bless` \
             to pin real numbers.",
            errs.len()
        );
        return Ok(());
    }
    for e in &errs {
        eprintln!("gate: {e}");
    }
    bail!("gate: {} regression(s); first: {}", errs.len(), errs[0]);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn optim_doc(rows: &[(&str, f64, f64)]) -> Json {
        Json::obj(vec![
            ("schema", Json::str("bench_optim/v1")),
            (
                "records",
                Json::Arr(
                    rows.iter()
                        .map(|(name, ns, speedup)| {
                            Json::obj(vec![
                                ("name", Json::str(name)),
                                ("ns_per_element", Json::num(*ns)),
                                ("elements_per_sec", Json::num(1e9 / ns)),
                                ("speedup_vs_reference", Json::num(*speedup)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    #[test]
    fn tolerance_spellings() {
        assert!((parse_tolerance("10%").unwrap() - 0.10).abs() < 1e-12);
        assert!((parse_tolerance("0.25").unwrap() - 0.25).abs() < 1e-12);
        assert!(parse_tolerance("-1").is_err());
        assert!(parse_tolerance("nope").is_err());
    }

    #[test]
    fn identical_files_pass() {
        let doc = optim_doc(&[("a", 2.0, 1.5), ("b", 3.0, 2.0), ("c", 4.0, 1.0)]);
        let (errs, deltas) = compare_optim(&doc, &doc, 0.10);
        assert!(errs.is_empty(), "{errs:?}");
        assert!(deltas.iter().all(|d| d.ok));
    }

    #[test]
    fn uniform_machine_drift_passes_single_row_regression_fails() {
        let golden = optim_doc(&[("a", 2.0, 1.5), ("b", 3.0, 2.0), ("c", 4.0, 1.0)]);
        // Everything 3x slower: a slower runner, not a regression.
        let slower = optim_doc(&[("a", 6.0, 1.5), ("b", 9.0, 2.0), ("c", 12.0, 1.0)]);
        let (errs, _) = compare_optim(&golden, &slower, 0.10);
        assert!(errs.is_empty(), "uniform drift must pass: {errs:?}");
        // Only row b 10x slower: a real regression, named.
        let one_bad = optim_doc(&[("a", 2.0, 1.5), ("b", 30.0, 2.0), ("c", 4.0, 1.0)]);
        let (errs, _) = compare_optim(&golden, &one_bad, 0.10);
        assert!(
            errs.iter().any(|e| matches!(
                e,
                GateError::Regression { key, metric, .. }
                    if key == "b" && metric == "ns_per_element"
            )),
            "{errs:?}"
        );
    }

    #[test]
    fn speedup_loss_fails_directly() {
        let golden = optim_doc(&[("a", 2.0, 3.0), ("b", 3.0, 1.0)]);
        let worse = optim_doc(&[("a", 2.0, 1.1), ("b", 3.0, 1.0)]);
        let (errs, _) = compare_optim(&golden, &worse, 0.10);
        assert!(errs.iter().any(|e| matches!(
            e,
            GateError::Regression { key, metric, .. }
                if key == "a" && metric == "speedup_vs_reference"
        )));
    }

    #[test]
    fn missing_and_extra_rows_are_typed() {
        let golden = optim_doc(&[("a", 2.0, 1.0), ("b", 3.0, 1.0)]);
        let current = optim_doc(&[("a", 2.0, 1.0), ("new", 1.0, 1.0)]);
        let (errs, _) = compare_optim(&golden, &current, 0.10);
        assert!(errs
            .iter()
            .any(|e| matches!(e, GateError::MissingRow { key, .. } if key == "b")));
        assert!(errs
            .iter()
            .any(|e| matches!(e, GateError::ExtraRow { key, .. } if key == "new")));
    }

    fn pareto_doc(rows: &[(&str, f64, f64, &str, f64, f64, f64)]) -> Json {
        Json::obj(vec![
            ("schema", Json::str("bench_pareto/v1")),
            (
                "rows",
                Json::Arr(
                    rows.iter()
                        .map(|(task, budget, plan, choice, expr, loss, acc)| {
                            Json::obj(vec![
                                ("task", Json::str(task)),
                                ("budget_bytes", Json::num(*budget)),
                                ("plan_bytes", Json::num(*plan)),
                                ("choice", Json::str(choice)),
                                ("expressivity", Json::num(*expr)),
                                ("final_loss", Json::num(*loss)),
                                ("accuracy", Json::num(*acc)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    #[test]
    fn pareto_loss_regression_fails() {
        let golden = pareto_doc(&[("convex", 4096.0, 4000.0, "ET2/f32", 128.0, 0.50, 0.90)]);
        let ok = pareto_doc(&[("convex", 4096.0, 4000.0, "ET2/f32", 128.0, 0.52, 0.89)]);
        let (errs, _) = compare_pareto(&golden, &ok, 0.10);
        assert!(errs.is_empty(), "{errs:?}");
        let bad = pareto_doc(&[("convex", 4096.0, 4000.0, "ET2/f32", 128.0, 0.80, 0.90)]);
        let (errs, _) = compare_pareto(&golden, &bad, 0.10);
        assert!(errs.iter().any(|e| matches!(
            e,
            GateError::Regression { metric, .. } if metric == "final_loss"
        )));
    }

    #[test]
    fn pareto_plan_change_is_exact_failure() {
        let golden = pareto_doc(&[("convex", 4096.0, 4000.0, "ET2/f32", 128.0, 0.5, 0.9)]);
        let drifted = pareto_doc(&[("convex", 4096.0, 3800.0, "ET2/f32", 128.0, 0.5, 0.9)]);
        let (errs, _) = compare_pareto(&golden, &drifted, 0.10);
        assert!(errs.iter().any(|e| matches!(
            e,
            GateError::Regression { metric, .. } if metric == "plan_bytes"
        )));
    }

    #[test]
    fn frontier_dominance_catches_cross_budget_regression() {
        // A healthy frontier: more budget -> lower loss.
        let golden = pareto_doc(&[
            ("convex", 2048.0, 2000.0, "ET4/q8", 64.0, 0.60, 0.85),
            ("convex", 4096.0, 4000.0, "ET2/f32", 128.0, 0.40, 0.90),
        ]);
        assert!(compare_frontier(&golden, &golden, 0.10).is_empty());

        // The 4 KiB row's loss drifts to 0.70: now the 2 KiB golden
        // (loss 0.60, half the memory) dominates it, even though both
        // keyed rows could individually sit near their own bands.
        let collapsed = pareto_doc(&[
            ("convex", 2048.0, 2000.0, "ET4/q8", 64.0, 0.60, 0.85),
            ("convex", 4096.0, 4000.0, "ET2/f32", 128.0, 0.70, 0.90),
        ]);
        let errs = compare_frontier(&golden, &collapsed, 0.10);
        assert_eq!(errs.len(), 1, "{errs:?}");
        assert!(matches!(
            &errs[0],
            GateError::Dominated { key, by, .. }
                if key == "convex/4096" && by == "convex/2048"
        ));

        // Within the band (0.60 * 1.10 = 0.66) is not dominance...
        let drifted = pareto_doc(&[
            ("convex", 2048.0, 2000.0, "ET4/q8", 64.0, 0.60, 0.85),
            ("convex", 4096.0, 4000.0, "ET2/f32", 128.0, 0.65, 0.90),
        ]);
        assert!(compare_frontier(&golden, &drifted, 0.10).is_empty());

        // ...and rows of a different task never dominate each other.
        let other_task = pareto_doc(&[
            ("convex", 2048.0, 2000.0, "ET4/q8", 64.0, 0.60, 0.85),
            ("lm", 4096.0, 4000.0, "ET2/f32", 128.0, 5.00, 0.10),
        ]);
        assert!(compare_frontier(&golden, &other_task, 0.10).is_empty());
    }

    #[test]
    fn require_pinned_turns_bootstrap_warnings_into_failure() {
        let dir = std::env::temp_dir().join(format!("etgate-pin-{}", std::process::id()));
        let goldens = dir.join("goldens");
        std::fs::create_dir_all(&goldens).unwrap();

        let mut g_optim = optim_doc(&[("a", 2.0, 1.5)]);
        if let Json::Obj(map) = &mut g_optim {
            map.insert("pinned".to_string(), Json::Bool(false));
        }
        let g_pareto = pareto_doc(&[("convex", 4096.0, 4000.0, "ET2/f32", 128.0, 0.5, 0.9)]);
        std::fs::write(goldens.join("BENCH_optim.json"), g_optim.to_string_pretty()).unwrap();
        std::fs::write(goldens.join("BENCH_pareto.json"), g_pareto.to_string_pretty()).unwrap();

        // Fresh outputs identical to the goldens: zero regressions either way.
        let optim_path = dir.join("BENCH_optim.json");
        let pareto_path = dir.join("BENCH_pareto.json");
        std::fs::write(&optim_path, optim_doc(&[("a", 2.0, 1.5)]).to_string_pretty()).unwrap();
        std::fs::write(&pareto_path, g_pareto.to_string_pretty()).unwrap();

        let opts = GateOptions {
            goldens_dir: goldens,
            optim_path,
            pareto_path,
            ..GateOptions::default()
        };
        // Unpinned goldens pass (warn-only) without the flag...
        run_gate(&opts).unwrap();
        // ...and hard-fail with it, naming the unpinned file.
        let strict = GateOptions { require_pinned: true, ..opts };
        let err = run_gate(&strict).unwrap_err().to_string();
        assert!(err.contains("--require-pinned"), "{err}");
        assert!(err.contains("BENCH_optim.json"), "{err}");
        assert!(!err.contains("BENCH_pareto.json"), "{err}");

        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn schema_checks_match_the_old_ci_asserts() {
        let good = optim_doc(&[("a", 2.0, 1.0)]);
        assert!(check_optim_schema(&good, "f").is_empty());
        let bad_tag = Json::obj(vec![("schema", Json::str("nope"))]);
        assert!(!check_optim_schema(&bad_tag, "f").is_empty());
        let zero_ns = optim_doc(&[("a", 0.0, 1.0)]);
        assert!(!check_optim_schema(&zero_ns, "f").is_empty());
        let over = pareto_doc(&[("convex", 100.0, 200.0, "c", 1.0, 1.0, 1.0)]);
        assert!(!check_pareto_schema(&over, "f").is_empty());
    }
}

//! The cross-commit observability layer: every batch the session executes
//! leaves a durable, deterministic trace.
//!
//! ```text
//!   session::run_batch ──▶ registry/v1 records ──▶ results/registry/
//!        │                 (one per JobSpec:        registry.jsonl + .csv
//!        │                  run id, commit, UTC,
//!        │                  canonical spec TOML,
//!        │                  solved StatePlan,
//!        │                  metrics, cache counts,
//!        │                  wall/queue seconds)
//!        │
//!        ├──▶ gate    ettrain gate — diff BENCH_optim.json/BENCH_pareto.json
//!        │            against checked-in goldens/ with a tolerance band
//!        │            (--bless re-pins, --schema-only replaces the old CI
//!        │            inline asserts)
//!        │
//!        └──▶ dashboard    ettrain registry report — fold records + event
//!                          logs into per-commit trajectories (Markdown +
//!                          CSV via coordinator::report::Table)
//! ```
//!
//! Three pieces:
//!
//! * [`record`] — the [`record::RunRecord`] type and the `registry/v1`
//!   CSV + JSONL encodings (pure-std via [`crate::util::json`]; the CSV
//!   codec does real RFC-4180-style quoting because spec TOML contains
//!   commas, quotes, and newlines). [`record_batch`] is the single entry
//!   point `session::run_batch` writes through, so every
//!   `ettrain train|batch|experiment` invocation is recorded for free.
//! * [`gate`] — the golden perf gate: join new bench rows to goldens by
//!   row key and fail on regressions beyond the band, with typed
//!   [`gate::GateError`]s for missing/extra rows and a per-row delta
//!   table.
//! * [`dashboard`] — the trajectory summarizer behind
//!   `ettrain registry report`, including per-commit step-time
//!   breakdowns folded out of each record's `timing` profile and
//!   `--ingest` merging of uploaded CI registry artifacts (dedup by
//!   run id).
//! * [`replay`] — `ettrain registry replay <run_id>`: re-execute a
//!   recorded spec on a fresh session and diff the fresh metrics
//!   against the record bit-for-bit, reporting typed divergences
//!   (time-derived metrics excluded).
//!
//! Determinism contract: a record's `spec_toml` is the canonical
//! [`crate::session::JobSpec::to_toml`] serialization, and re-executing it
//! reproduces the recorded metrics bitwise for step-bounded workloads
//! (`rust/tests/registry.rs`, the ASM `rep_det` pattern).

pub mod dashboard;
pub mod gate;
pub mod record;
pub mod replay;

pub use record::{record_batch, CompactStats, Registry, RunRecord, REGISTRY_SCHEMA};

use std::path::Path;

/// The git commit the process is running from: `ETTRAIN_COMMIT` env
/// override first (CI, tests), else a pure-std parse of `.git/HEAD`
/// walking up from the current directory (no `git` subprocess — the
/// registry must not fork on every batch).
pub fn git_commit() -> Option<String> {
    if let Ok(c) = std::env::var("ETTRAIN_COMMIT") {
        let c = c.trim().to_string();
        if !c.is_empty() {
            return Some(c);
        }
    }
    let mut dir = std::env::current_dir().ok()?;
    loop {
        let git = dir.join(".git");
        if git.is_dir() {
            return read_head(&git);
        }
        if !dir.pop() {
            return None;
        }
    }
}

fn read_head(git: &Path) -> Option<String> {
    let head = std::fs::read_to_string(git.join("HEAD")).ok()?;
    let head = head.trim();
    let Some(r) = head.strip_prefix("ref: ") else {
        // Detached HEAD: the file holds the hash itself.
        return if head.is_empty() { None } else { Some(head.to_string()) };
    };
    let r = r.trim();
    if let Ok(s) = std::fs::read_to_string(git.join(r)) {
        let s = s.trim().to_string();
        if !s.is_empty() {
            return Some(s);
        }
    }
    // Loose ref absent — look through packed-refs.
    let packed = std::fs::read_to_string(git.join("packed-refs")).ok()?;
    for line in packed.lines() {
        let line = line.trim();
        if line.starts_with('#') || line.starts_with('^') {
            continue;
        }
        if let Some((hash, name)) = line.split_once(' ') {
            if name.trim() == r {
                return Some(hash.to_string());
            }
        }
    }
    None
}

/// [`git_commit`] with an `"unknown"` fallback, for record fields that
/// must always be present.
pub fn commit_string() -> String {
    git_commit().unwrap_or_else(|| "unknown".to_string())
}

/// Seconds since the unix epoch (0 if the clock is before 1970).
pub fn unix_now() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

/// Best-effort host name for log headers: `HOSTNAME` env, then
/// `/etc/hostname`, then `"unknown"`.
pub fn host() -> String {
    if let Ok(h) = std::env::var("HOSTNAME") {
        let h = h.trim().to_string();
        if !h.is_empty() {
            return h;
        }
    }
    if let Ok(h) = std::fs::read_to_string("/etc/hostname") {
        let h = h.trim().to_string();
        if !h.is_empty() {
            return h;
        }
    }
    "unknown".to_string()
}

/// Format a unix timestamp as an ISO-8601 UTC string
/// (`1970-01-01T00:00:00Z`), pure std. Uses the standard civil-from-days
/// conversion (Howard Hinnant's algorithm), exact for any date this
/// codebase will ever log.
pub fn utc_string(unix: u64) -> String {
    let days = unix / 86_400;
    let secs = unix % 86_400;
    let (h, m, s) = (secs / 3600, (secs / 60) % 60, secs % 60);
    let z = days + 719_468;
    let era = z / 146_097;
    let doe = z - era * 146_097;
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = doy - (153 * mp + 2) / 5 + 1;
    let month = if mp < 10 { mp + 3 } else { mp - 9 };
    let year = yoe + era * 400 + u64::from(month <= 2);
    format!("{year:04}-{month:02}-{d:02}T{h:02}:{m:02}:{s:02}Z")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn utc_epoch_and_leap_day() {
        assert_eq!(utc_string(0), "1970-01-01T00:00:00Z");
        // 2000-02-29 00:00:00 UTC — a century leap day.
        assert_eq!(utc_string(951_782_400), "2000-02-29T00:00:00Z");
        assert_eq!(utc_string(951_782_400 + 3661), "2000-02-29T01:01:01Z");
        // 2026-08-08 00:00:00 UTC (day 20673 since the epoch).
        assert_eq!(utc_string(20_673 * 86_400), "2026-08-08T00:00:00Z");
    }

    #[test]
    fn commit_env_override_wins() {
        std::env::set_var("ETTRAIN_COMMIT", "deadbeef");
        assert_eq!(commit_string(), "deadbeef");
        std::env::remove_var("ETTRAIN_COMMIT");
    }

    #[test]
    fn host_is_nonempty() {
        assert!(!host().is_empty());
    }
}

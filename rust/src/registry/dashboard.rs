//! The run-trajectory summarizer behind `ettrain registry report`: folds
//! registry records (+ the schedule event logs they reference) into
//! per-commit trajectories — steps/sec, peak budget occupancy, cache hit
//! rate, queue wait, failure counts — rendered through
//! [`coordinator::report::Table`](crate::coordinator::report::Table) as
//! aligned text, Markdown (`dashboard.md`), and CSV series
//! (`trajectory.csv`).

use super::record::{Registry, RunRecord};
use crate::coordinator::report::Table;
use crate::util::logging::read_jsonl;
use anyhow::Result;
use std::collections::BTreeMap;
use std::path::Path;

/// One commit's aggregated slice of the registry.
struct CommitSlice<'a> {
    commit: &'a str,
    first_seen: u64,
    records: Vec<&'a RunRecord>,
}

fn by_commit(records: &[RunRecord]) -> Vec<CommitSlice<'_>> {
    let mut slices: Vec<CommitSlice<'_>> = Vec::new();
    for r in records {
        match slices.iter_mut().find(|s| s.commit == r.commit) {
            Some(s) => {
                s.first_seen = s.first_seen.min(r.started_unix);
                s.records.push(r);
            }
            None => slices.push(CommitSlice {
                commit: &r.commit,
                first_seen: r.started_unix,
                records: vec![r],
            }),
        }
    }
    slices.sort_by(|a, b| a.first_seen.cmp(&b.first_seen).then(a.commit.cmp(b.commit)));
    slices
}

fn metric(r: &RunRecord, key: &str) -> Option<f64> {
    r.metrics.get(key).and_then(|v| v.as_f64())
}

/// Throughput figure for one run: LM jobs report tokens/sec, shard-bench
/// jobs steps/sec; convex/vision runs have no rate metric.
fn rate_of(r: &RunRecord) -> Option<f64> {
    metric(r, "steps_per_sec").or_else(|| metric(r, "tokens_per_sec"))
}

fn mean(xs: &[f64]) -> Option<f64> {
    (!xs.is_empty()).then(|| xs.iter().sum::<f64>() / xs.len() as f64)
}

/// Peak scheduler budget occupancy per commit, reconstructed from the
/// `admitted` events of the schedule logs the records point at.
/// Best-effort: unreadable or absent logs contribute nothing.
pub fn peak_bytes_by_commit(records: &[RunRecord]) -> BTreeMap<String, u64> {
    let mut peaks: BTreeMap<String, u64> = BTreeMap::new();
    let mut seen: Vec<(&str, &str)> = Vec::new();
    for r in records {
        if r.event_log.is_empty() || seen.contains(&(r.commit.as_str(), r.event_log.as_str())) {
            continue;
        }
        seen.push((&r.commit, &r.event_log));
        let Ok(events) = read_jsonl(&r.event_log) else { continue };
        let peak = events
            .iter()
            .filter(|e| e.get("event").and_then(|v| v.as_str()) == Some("admitted"))
            .filter_map(|e| e.get("in_use_bytes").and_then(|v| v.as_i64()))
            .filter_map(|v| u64::try_from(v).ok())
            .max()
            .unwrap_or(0);
        let entry = peaks.entry(r.commit.clone()).or_insert(0);
        *entry = (*entry).max(peak);
    }
    peaks
}

/// Fold records into the dashboard tables: a per-commit trajectory plus a
/// per-workload breakdown. Pure (peaks are passed in) so the folding is
/// unit-testable without touching disk.
pub fn build_tables(records: &[RunRecord], peaks: &BTreeMap<String, u64>) -> Vec<Table> {
    let mut traj = Table::new(
        "Run trajectory by commit",
        &[
            "commit",
            "first utc",
            "jobs",
            "failed",
            "steps/s",
            "peak bytes",
            "cache hit %",
            "queue s",
            "wall s",
            "recov",
        ],
    );
    for s in by_commit(records) {
        let failed = s.records.iter().filter(|r| r.status != "ok").count();
        let rates: Vec<f64> = s.records.iter().filter_map(|r| rate_of(r)).collect();
        let hits: u64 = s.records.iter().map(|r| r.artifact_hits + r.corpus_hits).sum();
        let lookups: u64 = hits
            + s.records.iter().map(|r| r.artifact_misses + r.corpus_misses).sum::<u64>();
        // Peak from the event logs when available, else the largest
        // per-run optimizer-state figure the metrics carry.
        let peak = peaks.get(s.commit).copied().filter(|&p| p > 0).or_else(|| {
            s.records
                .iter()
                .filter_map(|r| {
                    metric(r, "state_bytes").or_else(|| metric(r, "peak_state_bytes_per_shard"))
                })
                .map(|b| b as u64)
                .max()
        });
        let utc = s.records.iter().min_by_key(|r| r.started_unix).map(|r| r.utc.clone());
        traj.row(vec![
            short_commit(s.commit),
            utc.unwrap_or_default(),
            s.records.len().to_string(),
            failed.to_string(),
            mean(&rates).map(|r| format!("{r:.1}")).unwrap_or_else(|| "-".into()),
            peak.map(|p| p.to_string()).unwrap_or_else(|| "-".into()),
            if lookups > 0 {
                format!("{:.0}", 100.0 * hits as f64 / lookups as f64)
            } else {
                "-".into()
            },
            format!(
                "{:.3}",
                mean(&s.records.iter().map(|r| r.queue_seconds).collect::<Vec<_>>())
                    .unwrap_or(0.0)
            ),
            format!("{:.2}", s.records.iter().map(|r| r.wall_seconds).sum::<f64>()),
            s.records.iter().map(|r| r.recoveries).sum::<u64>().to_string(),
        ]);
    }

    let mut kinds = Table::new(
        "Breakdown by workload",
        &["kind", "runs", "ok", "failed", "mean wall s", "mean queue s"],
    );
    let mut names: Vec<&str> = records.iter().map(|r| r.kind.as_str()).collect();
    names.sort_unstable();
    names.dedup();
    for kind in names {
        let rs: Vec<&RunRecord> = records.iter().filter(|r| r.kind == kind).collect();
        let ok = rs.iter().filter(|r| r.status == "ok").count();
        kinds.row(vec![
            kind.to_string(),
            rs.len().to_string(),
            ok.to_string(),
            (rs.len() - ok).to_string(),
            format!(
                "{:.2}",
                mean(&rs.iter().map(|r| r.wall_seconds).collect::<Vec<_>>()).unwrap_or(0.0)
            ),
            format!(
                "{:.3}",
                mean(&rs.iter().map(|r| r.queue_seconds).collect::<Vec<_>>()).unwrap_or(0.0)
            ),
        ]);
    }
    // Supervision incidents, split transient (timeout storms that healed
    // after backoff) vs fatal (disconnects, protocol faults, worker
    // errors). `healed` runs finished despite the incident; `gave up`
    // runs exhausted their recovery budget or hit an unrecoverable kind.
    let mut incidents = Table::new(
        "Incidents by error kind",
        &["error kind", "class", "runs", "recoveries", "healed", "gave up"],
    );
    let mut faults: Vec<&str> = records
        .iter()
        .filter(|r| !r.error_kind.is_empty())
        .map(|r| r.error_kind.as_str())
        .collect();
    faults.sort_unstable();
    faults.dedup();
    for kind in faults {
        let rs: Vec<&RunRecord> = records.iter().filter(|r| r.error_kind == kind).collect();
        let healed = rs.iter().filter(|r| r.status == "ok").count();
        incidents.row(vec![
            kind.to_string(),
            if kind == "timeout" { "transient" } else { "fatal" }.to_string(),
            rs.len().to_string(),
            rs.iter().map(|r| r.recoveries).sum::<u64>().to_string(),
            healed.to_string(),
            (rs.len() - healed).to_string(),
        ]);
    }
    vec![traj, kinds, incidents]
}

fn short_commit(c: &str) -> String {
    if c.len() > 12 && c.bytes().all(|b| b.is_ascii_hexdigit()) {
        c[..12].to_string()
    } else {
        c.to_string()
    }
}

/// The `ettrain registry report` entry point: load the registry at
/// `dir`, print the trajectory tables, and (with `--out`) write
/// `dashboard.md` + `trajectory.csv` under `out`.
pub fn report(dir: &Path, out: Option<&Path>) -> Result<()> {
    let records = Registry::load(dir)?;
    let peaks = peak_bytes_by_commit(&records);
    let tables = build_tables(&records, &peaks);
    for t in &tables {
        print!("{}", t.render());
    }
    println!("\n{} record(s) in {:?}", records.len(), dir.join("registry.jsonl"));
    if let Some(out) = out {
        std::fs::create_dir_all(out)?;
        let md: String = tables.iter().map(|t| t.render_markdown()).collect();
        let md_path = out.join("dashboard.md");
        std::fs::write(&md_path, format!("# ettrain run trajectories\n\n{md}"))?;
        tables[0].write_csv(out.join("trajectory.csv"))?;
        println!("wrote {:?} and {:?}", md_path, out.join("trajectory.csv"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn rec(commit: &str, job: &str, started: u64, ok: bool, rate: Option<f64>) -> RunRecord {
        let mut metrics = vec![("final_loss", Json::num(0.5))];
        if let Some(r) = rate {
            metrics.push(("steps_per_sec", Json::num(r)));
        }
        RunRecord {
            run_id: format!("{started}-0-{job}"),
            job: job.to_string(),
            kind: "convex".to_string(),
            commit: commit.to_string(),
            started_unix: started,
            utc: super::super::utc_string(started),
            spec_toml: String::new(),
            plan: None,
            status: if ok { "ok" } else { "failed" }.to_string(),
            error: String::new(),
            metrics: Json::obj(metrics),
            artifact_hits: 1,
            artifact_misses: 1,
            corpus_hits: 2,
            corpus_misses: 0,
            wall_seconds: 2.0,
            queue_seconds: 0.25,
            event_log: String::new(),
            recoveries: 0,
            error_kind: String::new(),
        }
    }

    #[test]
    fn trajectory_groups_and_orders_by_commit() {
        let records = vec![
            rec("bbbb", "j3", 200, true, Some(10.0)),
            rec("aaaa", "j1", 100, true, Some(20.0)),
            rec("aaaa", "j2", 120, false, None),
        ];
        let tables = build_tables(&records, &BTreeMap::new());
        assert_eq!(tables.len(), 3);
        let traj = &tables[0];
        assert_eq!(traj.rows.len(), 2, "two commits -> two rows");
        // Ordered by first-seen time: aaaa (100) before bbbb (200).
        assert_eq!(traj.rows[0][0], "aaaa");
        assert_eq!(traj.rows[0][2], "2", "two jobs on aaaa");
        assert_eq!(traj.rows[0][3], "1", "one failure on aaaa");
        assert_eq!(traj.rows[0][4], "20.0", "mean of the one rated job");
        // 3 hits + 1 miss per record, two records -> 6/8 = 75%.
        assert_eq!(traj.rows[0][6], "75");
        assert_eq!(traj.rows[1][0], "bbbb");
    }

    #[test]
    fn per_kind_breakdown_counts() {
        let records =
            vec![rec("c", "a", 1, true, None), rec("c", "b", 2, false, None)];
        let tables = build_tables(&records, &BTreeMap::new());
        let kinds = &tables[1];
        assert_eq!(kinds.rows.len(), 1);
        assert_eq!(kinds.rows[0][0], "convex");
        assert_eq!(kinds.rows[0][1], "2");
        assert_eq!(kinds.rows[0][2], "1");
        assert_eq!(kinds.rows[0][3], "1");
    }

    #[test]
    fn incident_table_splits_transient_from_fatal() {
        let mut healed = rec("c", "a", 1, true, None);
        healed.recoveries = 2;
        healed.error_kind = "timeout".to_string();
        let mut fatal = rec("c", "b", 2, false, None);
        fatal.recoveries = 4;
        fatal.error_kind = "disconnected".to_string();
        let clean = rec("c", "d", 3, true, None);
        let tables = build_tables(&[healed, fatal, clean], &BTreeMap::new());

        // Trajectory sums recoveries across the commit's runs.
        assert_eq!(tables[0].rows[0].last().unwrap(), "6");

        let inc = &tables[2];
        assert_eq!(inc.rows.len(), 2, "clean run contributes no incident row");
        assert_eq!(inc.rows[0], vec!["disconnected", "fatal", "1", "4", "0", "1"]);
        assert_eq!(inc.rows[1], vec!["timeout", "transient", "1", "2", "1", "0"]);
    }
}

//! The run-trajectory summarizer behind `ettrain registry report`: folds
//! registry records (+ the schedule event logs they reference) into
//! per-commit trajectories — steps/sec, peak budget occupancy, cache hit
//! rate, queue wait, failure counts, and per-span step-time breakdowns
//! (from each traced record's `trace_timing/v1` profile) — rendered
//! through [`coordinator::report::Table`](crate::coordinator::report::Table)
//! as aligned text, Markdown (`dashboard.md`), and CSV series
//! (`trajectory.csv`).
//!
//! `--ingest <dir>` merges registry artifacts other machines uploaded
//! (CI shards, teammates): every `registry.jsonl` found under the
//! directory loads and merges into the local trajectory, deduplicated by
//! run id with local records winning.

use super::record::{Registry, RunRecord};
use crate::coordinator::report::Table;
use crate::util::logging::read_jsonl;
use anyhow::{Context, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};

/// One commit's aggregated slice of the registry.
struct CommitSlice<'a> {
    commit: &'a str,
    first_seen: u64,
    records: Vec<&'a RunRecord>,
}

fn by_commit(records: &[RunRecord]) -> Vec<CommitSlice<'_>> {
    let mut slices: Vec<CommitSlice<'_>> = Vec::new();
    for r in records {
        match slices.iter_mut().find(|s| s.commit == r.commit) {
            Some(s) => {
                s.first_seen = s.first_seen.min(r.started_unix);
                s.records.push(r);
            }
            None => slices.push(CommitSlice {
                commit: &r.commit,
                first_seen: r.started_unix,
                records: vec![r],
            }),
        }
    }
    slices.sort_by(|a, b| a.first_seen.cmp(&b.first_seen).then(a.commit.cmp(b.commit)));
    slices
}

fn metric(r: &RunRecord, key: &str) -> Option<f64> {
    r.metrics.get(key).and_then(|v| v.as_f64())
}

/// Throughput figure for one run: LM jobs report tokens/sec, shard-bench
/// jobs steps/sec; convex/vision runs have no rate metric.
fn rate_of(r: &RunRecord) -> Option<f64> {
    metric(r, "steps_per_sec").or_else(|| metric(r, "tokens_per_sec"))
}

fn mean(xs: &[f64]) -> Option<f64> {
    (!xs.is_empty()).then(|| xs.iter().sum::<f64>() / xs.len() as f64)
}

/// Peak scheduler budget occupancy per commit, reconstructed from the
/// `admitted` events of the schedule logs the records point at.
/// Best-effort: unreadable or absent logs contribute nothing.
pub fn peak_bytes_by_commit(records: &[RunRecord]) -> BTreeMap<String, u64> {
    let mut peaks: BTreeMap<String, u64> = BTreeMap::new();
    let mut seen: Vec<(&str, &str)> = Vec::new();
    for r in records {
        if r.event_log.is_empty() || seen.contains(&(r.commit.as_str(), r.event_log.as_str())) {
            continue;
        }
        seen.push((&r.commit, &r.event_log));
        let Ok(events) = read_jsonl(&r.event_log) else { continue };
        let peak = events
            .iter()
            .filter(|e| e.get("event").and_then(|v| v.as_str()) == Some("admitted"))
            .filter_map(|e| e.get("in_use_bytes").and_then(|v| v.as_i64()))
            .filter_map(|v| u64::try_from(v).ok())
            .max()
            .unwrap_or(0);
        let entry = peaks.entry(r.commit.clone()).or_insert(0);
        *entry = (*entry).max(peak);
    }
    peaks
}

/// Fold records into the dashboard tables: a per-commit trajectory plus a
/// per-workload breakdown. Pure (peaks are passed in) so the folding is
/// unit-testable without touching disk.
pub fn build_tables(records: &[RunRecord], peaks: &BTreeMap<String, u64>) -> Vec<Table> {
    let mut traj = Table::new(
        "Run trajectory by commit",
        &[
            "commit",
            "first utc",
            "jobs",
            "failed",
            "steps/s",
            "peak bytes",
            "cache hit %",
            "queue s",
            "wall s",
            "recov",
        ],
    );
    for s in by_commit(records) {
        let failed = s.records.iter().filter(|r| r.status != "ok").count();
        let rates: Vec<f64> = s.records.iter().filter_map(|r| rate_of(r)).collect();
        let hits: u64 = s.records.iter().map(|r| r.artifact_hits + r.corpus_hits).sum();
        let lookups: u64 = hits
            + s.records.iter().map(|r| r.artifact_misses + r.corpus_misses).sum::<u64>();
        // Peak from the event logs when available, else the largest
        // per-run optimizer-state figure the metrics carry.
        let peak = peaks.get(s.commit).copied().filter(|&p| p > 0).or_else(|| {
            s.records
                .iter()
                .filter_map(|r| {
                    metric(r, "state_bytes").or_else(|| metric(r, "peak_state_bytes_per_shard"))
                })
                .map(|b| b as u64)
                .max()
        });
        let utc = s.records.iter().min_by_key(|r| r.started_unix).map(|r| r.utc.clone());
        traj.row(vec![
            short_commit(s.commit),
            utc.unwrap_or_default(),
            s.records.len().to_string(),
            failed.to_string(),
            mean(&rates).map(|r| format!("{r:.1}")).unwrap_or_else(|| "-".into()),
            peak.map(|p| p.to_string()).unwrap_or_else(|| "-".into()),
            if lookups > 0 {
                format!("{:.0}", 100.0 * hits as f64 / lookups as f64)
            } else {
                "-".into()
            },
            format!(
                "{:.3}",
                mean(&s.records.iter().map(|r| r.queue_seconds).collect::<Vec<_>>())
                    .unwrap_or(0.0)
            ),
            format!("{:.2}", s.records.iter().map(|r| r.wall_seconds).sum::<f64>()),
            s.records.iter().map(|r| r.recoveries).sum::<u64>().to_string(),
        ]);
    }

    let mut kinds = Table::new(
        "Breakdown by workload",
        &["kind", "runs", "ok", "failed", "mean wall s", "mean queue s"],
    );
    let mut names: Vec<&str> = records.iter().map(|r| r.kind.as_str()).collect();
    names.sort_unstable();
    names.dedup();
    for kind in names {
        let rs: Vec<&RunRecord> = records.iter().filter(|r| r.kind == kind).collect();
        let ok = rs.iter().filter(|r| r.status == "ok").count();
        kinds.row(vec![
            kind.to_string(),
            rs.len().to_string(),
            ok.to_string(),
            (rs.len() - ok).to_string(),
            format!(
                "{:.2}",
                mean(&rs.iter().map(|r| r.wall_seconds).collect::<Vec<_>>()).unwrap_or(0.0)
            ),
            format!(
                "{:.3}",
                mean(&rs.iter().map(|r| r.queue_seconds).collect::<Vec<_>>()).unwrap_or(0.0)
            ),
        ]);
    }
    // Supervision incidents, split transient (timeout storms that healed
    // after backoff) vs fatal (disconnects, protocol faults, worker
    // errors). `healed` runs finished despite the incident; `gave up`
    // runs exhausted their recovery budget or hit an unrecoverable kind.
    let mut incidents = Table::new(
        "Incidents by error kind",
        &["error kind", "class", "runs", "recoveries", "healed", "gave up"],
    );
    let mut faults: Vec<&str> = records
        .iter()
        .filter(|r| !r.error_kind.is_empty())
        .map(|r| r.error_kind.as_str())
        .collect();
    faults.sort_unstable();
    faults.dedup();
    for kind in faults {
        let rs: Vec<&RunRecord> = records.iter().filter(|r| r.error_kind == kind).collect();
        let healed = rs.iter().filter(|r| r.status == "ok").count();
        incidents.row(vec![
            kind.to_string(),
            if kind == "timeout" { "transient" } else { "fatal" }.to_string(),
            rs.len().to_string(),
            rs.iter().map(|r| r.recoveries).sum::<u64>().to_string(),
            healed.to_string(),
            (rs.len() - healed).to_string(),
        ]);
    }
    vec![traj, kinds, incidents, timing_table(records)]
}

/// Per-commit span-time breakdown out of each record's `trace_timing/v1`
/// profile: counts and totals sum across a commit's traced runs;
/// p50/p99/max take the worst run (percentiles do not sum). Untraced
/// records (empty `timing`) contribute nothing.
fn timing_table(records: &[RunRecord]) -> Table {
    let mut t = Table::new(
        "Step time breakdown by commit",
        &["commit", "span", "count", "p50 us", "p99 us", "max us", "total ms"],
    );
    let us = |ns: u64| format!("{:.1}", ns as f64 / 1e3);
    for s in by_commit(records) {
        // span name -> [count, total_ns, p50_ns, p99_ns, max_ns]
        let mut agg: BTreeMap<String, [u64; 5]> = BTreeMap::new();
        for r in &s.records {
            let Some(kinds) = r.timing.get("kinds").and_then(|k| k.as_obj()) else {
                continue;
            };
            for (name, v) in kinds {
                let g = |k: &str| v.get(k).and_then(|x| x.as_f64()).unwrap_or(0.0) as u64;
                let e = agg.entry(name.clone()).or_insert([0; 5]);
                e[0] += g("count");
                e[1] += g("total_ns");
                e[2] = e[2].max(g("p50_ns"));
                e[3] = e[3].max(g("p99_ns"));
                e[4] = e[4].max(g("max_ns"));
            }
        }
        for (name, e) in agg {
            t.row(vec![
                short_commit(s.commit),
                name,
                e[0].to_string(),
                us(e[2]),
                us(e[3]),
                us(e[4]),
                format!("{:.3}", e[1] as f64 / 1e6),
            ]);
        }
    }
    t
}

/// Recursively collect every `registry.jsonl` under `dir`, in sorted
/// path order so ingestion is deterministic.
fn find_registries(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    let mut paths: Vec<PathBuf> = entries.flatten().map(|e| e.path()).collect();
    paths.sort();
    for p in paths {
        if p.is_dir() {
            find_registries(&p, out);
        } else if p.file_name().and_then(|n| n.to_str()) == Some("registry.jsonl") {
            out.push(p);
        }
    }
}

/// Merge uploaded registry artifacts into `records`: every
/// `registry.jsonl` found under each ingest dir loads, and records whose
/// `run_id` is already present are dropped (local/first-seen wins).
/// Returns the number of records added.
pub fn ingest(records: &mut Vec<RunRecord>, dirs: &[PathBuf]) -> Result<usize> {
    let mut seen: BTreeSet<String> = records.iter().map(|r| r.run_id.clone()).collect();
    let mut added = 0usize;
    for dir in dirs {
        let mut files = Vec::new();
        find_registries(dir, &mut files);
        if files.is_empty() {
            crate::warnln!("ingest: no registry.jsonl found under {dir:?}");
        }
        for f in files {
            let parent = f.parent().unwrap_or_else(|| Path::new("."));
            let loaded =
                Registry::load(parent).with_context(|| format!("ingest {f:?}"))?;
            for r in loaded {
                if seen.insert(r.run_id.clone()) {
                    records.push(r);
                    added += 1;
                }
            }
        }
    }
    Ok(added)
}

fn short_commit(c: &str) -> String {
    if c.len() > 12 && c.bytes().all(|b| b.is_ascii_hexdigit()) {
        c[..12].to_string()
    } else {
        c.to_string()
    }
}

/// The `ettrain registry report` entry point: load the registry at
/// `dir`, print the trajectory tables, and (with `--out`) write
/// `dashboard.md` + `trajectory.csv` under `out`.
pub fn report(dir: &Path, out: Option<&Path>) -> Result<()> {
    report_with_ingest(dir, out, &[])
}

/// [`report`] plus `--ingest`: merge every `registry.jsonl` found under
/// the given directories (uploaded CI artifacts) into the trajectory,
/// deduplicated by run id.
pub fn report_with_ingest(dir: &Path, out: Option<&Path>, ingest_dirs: &[PathBuf]) -> Result<()> {
    let mut records = Registry::load(dir)?;
    let ingested = ingest(&mut records, ingest_dirs)?;
    let peaks = peak_bytes_by_commit(&records);
    let tables = build_tables(&records, &peaks);
    for t in &tables {
        print!("{}", t.render());
    }
    println!("\n{} record(s) in {:?}", records.len(), dir.join("registry.jsonl"));
    if !ingest_dirs.is_empty() {
        println!("merged {ingested} ingested record(s) from {} dir(s)", ingest_dirs.len());
    }
    if let Some(out) = out {
        std::fs::create_dir_all(out)?;
        let md: String = tables.iter().map(|t| t.render_markdown()).collect();
        let md_path = out.join("dashboard.md");
        std::fs::write(&md_path, format!("# ettrain run trajectories\n\n{md}"))?;
        tables[0].write_csv(out.join("trajectory.csv"))?;
        println!("wrote {:?} and {:?}", md_path, out.join("trajectory.csv"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn rec(commit: &str, job: &str, started: u64, ok: bool, rate: Option<f64>) -> RunRecord {
        let mut metrics = vec![("final_loss", Json::num(0.5))];
        if let Some(r) = rate {
            metrics.push(("steps_per_sec", Json::num(r)));
        }
        RunRecord {
            run_id: format!("{started}-0-{job}"),
            job: job.to_string(),
            kind: "convex".to_string(),
            commit: commit.to_string(),
            started_unix: started,
            utc: super::super::utc_string(started),
            spec_toml: String::new(),
            plan: None,
            status: if ok { "ok" } else { "failed" }.to_string(),
            error: String::new(),
            metrics: Json::obj(metrics),
            artifact_hits: 1,
            artifact_misses: 1,
            corpus_hits: 2,
            corpus_misses: 0,
            wall_seconds: 2.0,
            queue_seconds: 0.25,
            event_log: String::new(),
            recoveries: 0,
            error_kind: String::new(),
            timing: Json::obj(vec![]),
        }
    }

    #[test]
    fn trajectory_groups_and_orders_by_commit() {
        let records = vec![
            rec("bbbb", "j3", 200, true, Some(10.0)),
            rec("aaaa", "j1", 100, true, Some(20.0)),
            rec("aaaa", "j2", 120, false, None),
        ];
        let tables = build_tables(&records, &BTreeMap::new());
        assert_eq!(tables.len(), 4);
        let traj = &tables[0];
        assert_eq!(traj.rows.len(), 2, "two commits -> two rows");
        // Ordered by first-seen time: aaaa (100) before bbbb (200).
        assert_eq!(traj.rows[0][0], "aaaa");
        assert_eq!(traj.rows[0][2], "2", "two jobs on aaaa");
        assert_eq!(traj.rows[0][3], "1", "one failure on aaaa");
        assert_eq!(traj.rows[0][4], "20.0", "mean of the one rated job");
        // 3 hits + 1 miss per record, two records -> 6/8 = 75%.
        assert_eq!(traj.rows[0][6], "75");
        assert_eq!(traj.rows[1][0], "bbbb");
    }

    #[test]
    fn per_kind_breakdown_counts() {
        let records =
            vec![rec("c", "a", 1, true, None), rec("c", "b", 2, false, None)];
        let tables = build_tables(&records, &BTreeMap::new());
        let kinds = &tables[1];
        assert_eq!(kinds.rows.len(), 1);
        assert_eq!(kinds.rows[0][0], "convex");
        assert_eq!(kinds.rows[0][1], "2");
        assert_eq!(kinds.rows[0][2], "1");
        assert_eq!(kinds.rows[0][3], "1");
    }

    #[test]
    fn incident_table_splits_transient_from_fatal() {
        let mut healed = rec("c", "a", 1, true, None);
        healed.recoveries = 2;
        healed.error_kind = "timeout".to_string();
        let mut fatal = rec("c", "b", 2, false, None);
        fatal.recoveries = 4;
        fatal.error_kind = "disconnected".to_string();
        let clean = rec("c", "d", 3, true, None);
        let tables = build_tables(&[healed, fatal, clean], &BTreeMap::new());

        // Trajectory sums recoveries across the commit's runs.
        assert_eq!(tables[0].rows[0].last().unwrap(), "6");

        let inc = &tables[2];
        assert_eq!(inc.rows.len(), 2, "clean run contributes no incident row");
        assert_eq!(inc.rows[0], vec!["disconnected", "fatal", "1", "4", "0", "1"]);
        assert_eq!(inc.rows[1], vec!["timeout", "transient", "1", "2", "1", "0"]);
    }

    fn timing_json(count: f64, p50: f64, p99: f64, max: f64, total: f64) -> Json {
        Json::obj(vec![
            ("schema", Json::str("trace_timing/v1")),
            ("wall_ns", Json::num(total)),
            ("coverage_pct", Json::num(95.0)),
            (
                "kinds",
                Json::obj(vec![(
                    "step_all",
                    Json::obj(vec![
                        ("count", Json::num(count)),
                        ("p50_ns", Json::num(p50)),
                        ("p99_ns", Json::num(p99)),
                        ("max_ns", Json::num(max)),
                        ("total_ns", Json::num(total)),
                    ]),
                )]),
            ),
        ])
    }

    #[test]
    fn timing_table_sums_counts_and_keeps_worst_percentiles() {
        let mut a = rec("cccc", "a", 1, true, None);
        a.timing = timing_json(10.0, 1_000.0, 4_000.0, 9_000.0, 50_000.0);
        let mut b = rec("cccc", "b", 2, true, None);
        b.timing = timing_json(5.0, 2_000.0, 3_000.0, 6_000.0, 30_000.0);
        let untraced = rec("cccc", "d", 3, true, None);
        let tables = build_tables(&[a, b, untraced], &BTreeMap::new());
        let t = &tables[3];
        assert_eq!(t.rows.len(), 1, "one commit x one span kind");
        assert_eq!(
            t.rows[0],
            vec!["cccc", "step_all", "15", "2.0", "4.0", "9.0", "0.080"]
        );
    }

    #[test]
    fn ingest_merges_and_dedups_by_run_id() {
        let base = std::env::temp_dir()
            .join(format!("et-dash-ingest-{}", std::process::id()));
        std::fs::remove_dir_all(&base).ok();
        // An uploaded artifact tree with two nested registries.
        let up_a = base.join("ci/shard-a");
        let up_b = base.join("ci/shard-b");
        Registry::open(&up_a)
            .unwrap()
            .append(&[rec("aaaa", "j1", 100, true, None), rec("aaaa", "j2", 120, true, None)])
            .unwrap();
        Registry::open(&up_b)
            .unwrap()
            .append(&[rec("aaaa", "j2", 120, true, None), rec("bbbb", "j3", 200, true, None)])
            .unwrap();

        // Local records already hold j1: it must not duplicate.
        let mut records = vec![rec("aaaa", "j1", 100, true, None)];
        let added = ingest(&mut records, &[base.join("ci")]).unwrap();
        assert_eq!(added, 2, "j2 (once) and j3; duplicates dropped");
        assert_eq!(records.len(), 3);
        let ids: BTreeSet<&str> = records.iter().map(|r| r.run_id.as_str()).collect();
        assert_eq!(ids.len(), 3, "all run_ids distinct");

        // Missing ingest dirs add nothing and do not fail the report.
        let none = ingest(&mut records, &[base.join("absent")]).unwrap();
        assert_eq!(none, 0);
        std::fs::remove_dir_all(&base).ok();
    }
}

//! The `registry/v1` run record and its two on-disk encodings.
//!
//! One [`RunRecord`] per executed [`JobSpec`], appended to both
//! `registry.jsonl` (authoritative, header record
//! `{"schema":"registry/v1"}` on first write) and `registry.csv` (a
//! mirror for spreadsheet tooling, `#schema=registry/v1` comment line +
//! column header). The CSV codec does RFC-4180-style quoting — spec TOML
//! carries commas, quotes, and newlines, so the naive
//! `coordinator::report::Table::write_csv` join is not enough here.
//!
//! Both encodings round-trip bitwise: integers print as integers and
//! f64s go through Rust's shortest-round-trip `Display`, so
//! `load(append(r)) == r` including float bits (covered in
//! `rust/tests/registry.rs`).

use crate::session::{BatchReport, ConvexOpt, JobEvent, JobSpec, Workload};
use crate::util::json::Json;
use crate::util::logging::{read_jsonl, JsonlWriter};
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// Schema tag carried by the header record of both encodings.
pub const REGISTRY_SCHEMA: &str = "registry/v1";

/// CSV column order (also the field order of the JSONL objects).
const COLUMNS: [&str; 21] = [
    "run_id",
    "job",
    "kind",
    "commit",
    "started_unix",
    "utc",
    "spec_toml",
    "plan",
    "status",
    "error",
    "metrics",
    "artifact_hits",
    "artifact_misses",
    "corpus_hits",
    "corpus_misses",
    "wall_seconds",
    "queue_seconds",
    "event_log",
    "recoveries",
    "error_kind",
    "timing",
];

/// Process-wide sequence number so run ids stay unique when several
/// batches record within the same second.
static RUN_SEQ: AtomicU64 = AtomicU64::new(0);

/// One executed job: provenance (commit, UTC, canonical spec), the
/// solved state plan when the job was budget-planned, outcome metrics,
/// and scheduler accounting.
#[derive(Clone, Debug, PartialEq)]
pub struct RunRecord {
    /// `"<started_unix>-<seq>-<job>"` — unique per process lifetime.
    pub run_id: String,
    /// Job name within its batch.
    pub job: String,
    /// Workload label: `lm`, `convex`, `shard-bench`, or `vision`.
    pub kind: String,
    /// Git commit of the producing checkout (`"unknown"` off-repo).
    pub commit: String,
    /// Batch start, seconds since the unix epoch.
    pub started_unix: u64,
    /// `started_unix` as an ISO-8601 UTC string.
    pub utc: String,
    /// Canonical [`JobSpec::to_toml`] serialization — re-executing this
    /// reproduces `metrics` bitwise for step-bounded workloads.
    pub spec_toml: String,
    /// Solved `state_plan/v1` JSON for budget-planned jobs, else `None`.
    pub plan: Option<Json>,
    /// `"ok"` or `"failed"`.
    pub status: String,
    /// Failure message (empty when `status == "ok"`).
    pub error: String,
    /// Workload-specific final metrics as a JSON object (empty on
    /// failure).
    pub metrics: Json,
    pub artifact_hits: u64,
    pub artifact_misses: u64,
    pub corpus_hits: u64,
    pub corpus_misses: u64,
    pub wall_seconds: f64,
    /// Defer→admit wait inside the scheduler queue.
    pub queue_seconds: f64,
    /// Path of the schedule JSONL this run's events went to (empty when
    /// the batch ran without a log).
    pub event_log: String,
    /// Supervision incidents healed during the run (count of
    /// `recovered` recovery events; 0 for unsupervised runs).
    pub recoveries: u64,
    /// [`crate::transport::TransportError::kind_label`] of the last
    /// incident the supervisor reported (empty when fault-free) — lets
    /// `registry report` split transient timeouts from real failures.
    pub error_kind: String,
    /// `trace_timing/v1` span-histogram summary of the job's timed loop
    /// (empty object when the job ran untraced) — `registry report`
    /// renders these as per-commit time-breakdown rows.
    pub timing: Json,
}

impl RunRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("run_id", Json::str(&self.run_id)),
            ("job", Json::str(&self.job)),
            ("kind", Json::str(&self.kind)),
            ("commit", Json::str(&self.commit)),
            ("started_unix", Json::num(self.started_unix as f64)),
            ("utc", Json::str(&self.utc)),
            ("spec_toml", Json::str(&self.spec_toml)),
            ("plan", self.plan.clone().unwrap_or(Json::Null)),
            ("status", Json::str(&self.status)),
            ("error", Json::str(&self.error)),
            ("metrics", self.metrics.clone()),
            ("artifact_hits", Json::num(self.artifact_hits as f64)),
            ("artifact_misses", Json::num(self.artifact_misses as f64)),
            ("corpus_hits", Json::num(self.corpus_hits as f64)),
            ("corpus_misses", Json::num(self.corpus_misses as f64)),
            ("wall_seconds", Json::num(self.wall_seconds)),
            ("queue_seconds", Json::num(self.queue_seconds)),
            ("event_log", Json::str(&self.event_log)),
            ("recoveries", Json::num(self.recoveries as f64)),
            ("error_kind", Json::str(&self.error_kind)),
            ("timing", self.timing.clone()),
        ])
    }

    pub fn from_json(j: &Json) -> Result<RunRecord> {
        let s = |k: &str| -> Result<String> {
            Ok(j.get(k)
                .and_then(|v| v.as_str())
                .with_context(|| format!("registry record: missing string '{k}'"))?
                .to_string())
        };
        let u = |k: &str| -> Result<u64> {
            j.get(k)
                .and_then(|v| v.as_i64())
                .and_then(|v| u64::try_from(v).ok())
                .with_context(|| format!("registry record: missing count '{k}'"))
        };
        let f = |k: &str| -> Result<f64> {
            j.get(k)
                .and_then(|v| v.as_f64())
                .with_context(|| format!("registry record: missing number '{k}'"))
        };
        let plan = match j.get("plan") {
            None | Some(Json::Null) => None,
            Some(p) => Some(p.clone()),
        };
        let metrics = j.get("metrics").cloned().unwrap_or_else(|| Json::obj(vec![]));
        if metrics.as_obj().is_none() {
            bail!("registry record: 'metrics' must be an object");
        }
        Ok(RunRecord {
            run_id: s("run_id")?,
            job: s("job")?,
            kind: s("kind")?,
            commit: s("commit")?,
            started_unix: u("started_unix")?,
            utc: s("utc")?,
            spec_toml: s("spec_toml")?,
            plan,
            status: s("status")?,
            error: s("error")?,
            metrics,
            artifact_hits: u("artifact_hits")?,
            artifact_misses: u("artifact_misses")?,
            corpus_hits: u("corpus_hits")?,
            corpus_misses: u("corpus_misses")?,
            wall_seconds: f("wall_seconds")?,
            queue_seconds: f("queue_seconds")?,
            event_log: s("event_log")?,
            // Added after the first v1 files shipped; default rather than
            // fail so pre-existing registries keep loading.
            recoveries: j
                .get("recoveries")
                .and_then(|v| v.as_i64())
                .and_then(|v| u64::try_from(v).ok())
                .unwrap_or(0),
            error_kind: j
                .get("error_kind")
                .and_then(|v| v.as_str())
                .unwrap_or("")
                .to_string(),
            timing: j.get("timing").cloned().unwrap_or_else(|| Json::obj(vec![])),
        })
    }

    fn csv_row(&self) -> String {
        let cells = [
            self.run_id.clone(),
            self.job.clone(),
            self.kind.clone(),
            self.commit.clone(),
            self.started_unix.to_string(),
            self.utc.clone(),
            self.spec_toml.clone(),
            self.plan.as_ref().map(|p| p.to_string()).unwrap_or_default(),
            self.status.clone(),
            self.error.clone(),
            self.metrics.to_string(),
            self.artifact_hits.to_string(),
            self.artifact_misses.to_string(),
            self.corpus_hits.to_string(),
            self.corpus_misses.to_string(),
            format!("{}", self.wall_seconds),
            format!("{}", self.queue_seconds),
            self.event_log.clone(),
            self.recoveries.to_string(),
            self.error_kind.clone(),
            self.timing.to_string(),
        ];
        cells.iter().map(|c| csv_escape(c)).collect::<Vec<_>>().join(",")
    }

    fn from_cells(cells: &[String]) -> Result<RunRecord> {
        // 20-cell rows predate the `timing` column; keep loading them.
        if cells.len() != COLUMNS.len() && cells.len() != COLUMNS.len() - 1 {
            bail!("registry csv: expected {} cells, got {}", COLUMNS.len(), cells.len());
        }
        let u = |i: usize| -> Result<u64> {
            cells[i]
                .parse::<u64>()
                .with_context(|| format!("registry csv: bad {} '{}'", COLUMNS[i], cells[i]))
        };
        let f = |i: usize| -> Result<f64> {
            cells[i]
                .parse::<f64>()
                .with_context(|| format!("registry csv: bad {} '{}'", COLUMNS[i], cells[i]))
        };
        let plan = if cells[7].is_empty() {
            None
        } else {
            Some(Json::parse(&cells[7]).context("registry csv: bad plan JSON")?)
        };
        Ok(RunRecord {
            run_id: cells[0].clone(),
            job: cells[1].clone(),
            kind: cells[2].clone(),
            commit: cells[3].clone(),
            started_unix: u(4)?,
            utc: cells[5].clone(),
            spec_toml: cells[6].clone(),
            plan,
            status: cells[8].clone(),
            error: cells[9].clone(),
            metrics: Json::parse(&cells[10]).context("registry csv: bad metrics JSON")?,
            artifact_hits: u(11)?,
            artifact_misses: u(12)?,
            corpus_hits: u(13)?,
            corpus_misses: u(14)?,
            wall_seconds: f(15)?,
            queue_seconds: f(16)?,
            event_log: cells[17].clone(),
            recoveries: u(18)?,
            error_kind: cells[19].clone(),
            timing: match cells.get(20) {
                Some(c) if !c.is_empty() => {
                    Json::parse(c).context("registry csv: bad timing JSON")?
                }
                _ => Json::obj(vec![]),
            },
        })
    }
}

/// Quote a CSV cell iff it contains a separator, quote, or newline;
/// embedded quotes double.
fn csv_escape(cell: &str) -> String {
    if cell.contains(',') || cell.contains('"') || cell.contains('\n') || cell.contains('\r') {
        format!("\"{}\"", cell.replace('"', "\"\""))
    } else {
        cell.to_string()
    }
}

/// Parse a whole CSV document into rows of cells. A state machine rather
/// than line splitting: quoted cells may span lines.
fn csv_parse(text: &str) -> Result<Vec<Vec<String>>> {
    let mut rows = Vec::new();
    let mut row: Vec<String> = Vec::new();
    let mut cell = String::new();
    let mut in_quotes = false;
    let mut chars = text.chars().peekable();
    let mut any = false;
    while let Some(c) = chars.next() {
        any = true;
        if in_quotes {
            match c {
                '"' => {
                    if chars.peek() == Some(&'"') {
                        chars.next();
                        cell.push('"');
                    } else {
                        in_quotes = false;
                    }
                }
                _ => cell.push(c),
            }
            continue;
        }
        match c {
            '"' => in_quotes = true,
            ',' => row.push(std::mem::take(&mut cell)),
            '\r' => {} // swallowed; \n terminates the row
            '\n' => {
                row.push(std::mem::take(&mut cell));
                rows.push(std::mem::take(&mut row));
            }
            _ => cell.push(c),
        }
    }
    if in_quotes {
        bail!("registry csv: unterminated quoted cell");
    }
    // A final row without a trailing newline.
    if any && (!cell.is_empty() || !row.is_empty()) {
        row.push(cell);
        rows.push(row);
    }
    Ok(rows)
}

/// The on-disk registry: a directory holding `registry.jsonl`
/// (authoritative) and `registry.csv` (mirror), both append-only.
pub struct Registry {
    dir: PathBuf,
}

impl Registry {
    pub fn open(dir: impl AsRef<Path>) -> Result<Registry> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir).with_context(|| format!("create registry dir {dir:?}"))?;
        Ok(Registry { dir })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn jsonl_path(&self) -> PathBuf {
        self.dir.join("registry.jsonl")
    }

    pub fn csv_path(&self) -> PathBuf {
        self.dir.join("registry.csv")
    }

    /// Append records to both encodings, writing the versioned headers
    /// first when a file does not exist yet (or is empty).
    pub fn append(&self, records: &[RunRecord]) -> Result<()> {
        let jsonl = self.jsonl_path();
        let fresh = std::fs::metadata(&jsonl).map(|m| m.len() == 0).unwrap_or(true);
        let mut w = JsonlWriter::create(&jsonl)?;
        if fresh {
            w.write(&Json::obj(vec![("schema", Json::str(REGISTRY_SCHEMA))]))?;
        }
        for r in records {
            w.write(&r.to_json())?;
        }
        w.flush()?;

        let csv = self.csv_path();
        let fresh = std::fs::metadata(&csv).map(|m| m.len() == 0).unwrap_or(true);
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&csv)
            .with_context(|| format!("open {csv:?}"))?;
        let mut buf = String::new();
        if fresh {
            buf.push_str(&format!("#schema={REGISTRY_SCHEMA}\n"));
            buf.push_str(&COLUMNS.join(","));
            buf.push('\n');
        }
        for r in records {
            buf.push_str(&r.csv_row());
            buf.push('\n');
        }
        f.write_all(buf.as_bytes())?;
        Ok(())
    }

    /// Load every record from `registry.jsonl`, verifying the header.
    pub fn load(dir: impl AsRef<Path>) -> Result<Vec<RunRecord>> {
        let path = dir.as_ref().join("registry.jsonl");
        let raw = read_jsonl(&path)?;
        let Some(first) = raw.first() else {
            bail!("registry {path:?}: empty file");
        };
        if first.get("schema").and_then(|v| v.as_str()) != Some(REGISTRY_SCHEMA) {
            bail!("registry {path:?}: missing {REGISTRY_SCHEMA} header record");
        }
        raw.iter()
            .skip(1)
            .filter(|j| j.get("run_id").is_some()) // tolerate repeated headers
            .map(RunRecord::from_json)
            .collect()
    }

    /// Load the CSV mirror (round-trip checks; JSONL stays authoritative).
    pub fn load_csv(dir: impl AsRef<Path>) -> Result<Vec<RunRecord>> {
        let path = dir.as_ref().join("registry.csv");
        let text =
            std::fs::read_to_string(&path).with_context(|| format!("open {path:?}"))?;
        let rows = csv_parse(&text)?;
        let header = format!("#schema={REGISTRY_SCHEMA}");
        if rows.len() < 2 || rows[0].first() != Some(&header) {
            bail!("registry {path:?}: missing {header} header");
        }
        let want: Vec<String> = COLUMNS.iter().map(|c| c.to_string()).collect();
        if rows[1] != want {
            bail!("registry {path:?}: unexpected column header {:?}", rows[1]);
        }
        rows[2..].iter().map(|r| RunRecord::from_cells(r)).collect()
    }

    /// Retention: keep only the newest `keep_per_spec` records for each
    /// distinct `spec_toml` and atomically rewrite both encodings
    /// (tmp-file + rename, headers re-emitted). "Newest" is append
    /// order — the registry is append-only, so file order *is* run
    /// order. Surviving records keep their relative order, so a
    /// compacted registry loads and round-trips exactly like an
    /// append-built one.
    pub fn compact(&self, keep_per_spec: usize) -> Result<CompactStats> {
        let records = Registry::load(&self.dir)?;
        let total = records.len();

        // Count per spec, then keep the *last* `keep_per_spec` of each
        // in one forward pass (a record survives when fewer than
        // `keep_per_spec` records of its spec come after it).
        let mut remaining: std::collections::HashMap<&str, usize> = std::collections::HashMap::new();
        for r in &records {
            *remaining.entry(r.spec_toml.as_str()).or_insert(0) += 1;
        }
        let specs = remaining.len();
        let kept: Vec<&RunRecord> = records
            .iter()
            .filter(|r| {
                let n = remaining.get_mut(r.spec_toml.as_str()).expect("counted above");
                *n -= 1;
                *n < keep_per_spec
            })
            .collect();

        let mut jsonl = String::new();
        jsonl.push_str(&Json::obj(vec![("schema", Json::str(REGISTRY_SCHEMA))]).to_string());
        jsonl.push('\n');
        let mut csv = format!("#schema={REGISTRY_SCHEMA}\n{}\n", COLUMNS.join(","));
        for r in &kept {
            jsonl.push_str(&r.to_json().to_string());
            jsonl.push('\n');
            csv.push_str(&r.csv_row());
            csv.push('\n');
        }
        replace_file(&self.jsonl_path(), &jsonl)?;
        replace_file(&self.csv_path(), &csv)?;
        Ok(CompactStats { kept: kept.len(), total, specs })
    }
}

/// What [`Registry::compact`] did: how many records survived out of how
/// many, across how many distinct specs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CompactStats {
    pub kept: usize,
    pub total: usize,
    pub specs: usize,
}

/// Atomically replace `path` with `content` via a sibling tmp file +
/// rename, so a crash mid-compact never leaves a truncated registry.
fn replace_file(path: &Path, content: &str) -> Result<()> {
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, content).with_context(|| format!("write {tmp:?}"))?;
    std::fs::rename(&tmp, path).with_context(|| format!("rename {tmp:?} -> {path:?}"))?;
    Ok(())
}

/// Re-solve the state plan a budget-planned job executes, for the
/// record's `plan` field. Best-effort: planning failures (or LM jobs
/// whose artifact is gone) record `None` rather than failing the write.
fn solved_plan(spec: &JobSpec) -> Option<Json> {
    let opts = crate::budget::PlannerOptions::default();
    match &spec.workload {
        Workload::Convex(c) => match &c.opt {
            ConvexOpt::Planned { budget } => {
                let groups =
                    vec![crate::optim::GroupSpec::new("w", &[c.data.k, c.data.d])];
                crate::budget::plan(&groups, *budget, &opts).ok().map(|p| p.to_json())
            }
            _ => None,
        },
        Workload::Lm(cfg) => {
            let budget = cfg.opt_memory_budget?;
            let m = crate::data::Manifest::load(&cfg.artifact_dir, &cfg.artifact).ok()?;
            crate::budget::plan(&m.group_specs(), budget, &opts).ok().map(|p| p.to_json())
        }
        _ => None,
    }
}

/// Write one `registry/v1` record per job in `report` (executed and
/// prefailed alike — `status` tells them apart). Called by
/// `session::run_batch` when [`crate::session::SchedulerOptions::registry_dir`]
/// is set; returns the number of records written.
pub fn record_batch(
    dir: &Path,
    specs: &[JobSpec],
    report: &BatchReport,
    event_log: Option<&Path>,
) -> Result<usize> {
    let registry = Registry::open(dir)?;
    let commit = super::commit_string();
    let started = super::unix_now().saturating_sub(report.wall_seconds as u64);
    let utc = super::utc_string(started);
    let log = event_log.map(|p| p.display().to_string()).unwrap_or_default();

    let mut records = Vec::with_capacity(report.results.len());
    for res in &report.results {
        let Some(spec) = specs.iter().find(|s| s.name == res.name) else {
            continue; // cannot happen: results are assembled from specs
        };
        // Per-job cache and recovery tallies out of the shared event
        // stream.
        let (mut ah, mut am, mut ch, mut cm) = (0u64, 0u64, 0u64, 0u64);
        let (mut recoveries, mut error_kind) = (0u64, String::new());
        for e in &report.events {
            if e.event.job() != res.name {
                continue;
            }
            match &e.event {
                JobEvent::ArtifactCache { hit, .. } => {
                    if *hit {
                        ah += 1;
                    } else {
                        am += 1;
                    }
                }
                JobEvent::CorpusCache { hit, .. } => {
                    if *hit {
                        ch += 1;
                    } else {
                        cm += 1;
                    }
                }
                JobEvent::Recovery { phase, kind, .. } => {
                    if phase == "recovered" {
                        recoveries += 1;
                    }
                    if !kind.is_empty() {
                        error_kind = kind.clone();
                    }
                }
                _ => {}
            }
        }
        let (status, error, metrics, timing) = match &res.outcome {
            Ok(out) => (
                "ok".to_string(),
                String::new(),
                out.metrics_json(),
                out.timing_json().cloned().unwrap_or_else(|| Json::obj(vec![])),
            ),
            Err(e) => ("failed".to_string(), e.clone(), Json::obj(vec![]), Json::obj(vec![])),
        };
        let seq = RUN_SEQ.fetch_add(1, Ordering::Relaxed);
        records.push(RunRecord {
            run_id: format!("{started}-{seq}-{}", res.name),
            job: res.name.clone(),
            kind: spec.workload_label().to_string(),
            commit: commit.clone(),
            started_unix: started,
            utc: utc.clone(),
            spec_toml: spec.to_toml(),
            plan: solved_plan(spec),
            status,
            error,
            metrics,
            artifact_hits: ah,
            artifact_misses: am,
            corpus_hits: ch,
            corpus_misses: cm,
            wall_seconds: res.wall_seconds,
            queue_seconds: res.queue_seconds,
            event_log: log.clone(),
            recoveries,
            error_kind,
            timing,
        });
    }
    registry.append(&records)?;
    Ok(records.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_escape_quotes_only_when_needed() {
        assert_eq!(csv_escape("plain"), "plain");
        assert_eq!(csv_escape("a,b"), "\"a,b\"");
        assert_eq!(csv_escape("say \"hi\""), "\"say \"\"hi\"\"\"");
        assert_eq!(csv_escape("two\nlines"), "\"two\nlines\"");
    }

    #[test]
    fn csv_parse_handles_quoted_newlines_and_crlf() {
        let rows = csv_parse("a,\"b,\nc\",d\r\ne,\"f\"\"g\",h\n").unwrap();
        assert_eq!(
            rows,
            vec![
                vec!["a".to_string(), "b,\nc".to_string(), "d".to_string()],
                vec!["e".to_string(), "f\"g".to_string(), "h".to_string()],
            ]
        );
        assert!(csv_parse("a,\"open").is_err());
    }

    fn record(run_id: &str, spec: &str) -> RunRecord {
        RunRecord {
            run_id: run_id.to_string(),
            job: "j".to_string(),
            kind: "convex".to_string(),
            commit: "deadbeef".to_string(),
            started_unix: 1,
            utc: "1970-01-01T00:00:01Z".to_string(),
            spec_toml: spec.to_string(),
            plan: None,
            status: "ok".to_string(),
            error: String::new(),
            metrics: Json::obj(vec![("loss", Json::num(0.5))]),
            artifact_hits: 0,
            artifact_misses: 0,
            corpus_hits: 0,
            corpus_misses: 0,
            wall_seconds: 1.5,
            queue_seconds: 0.25,
            event_log: String::new(),
            recoveries: 0,
            error_kind: String::new(),
            timing: Json::obj(vec![]),
        }
    }

    #[test]
    fn compact_keeps_last_n_per_spec_and_round_trips() {
        let dir = std::env::temp_dir().join(format!("etreg-compact-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let registry = Registry::open(&dir).unwrap();
        // Two specs interleaved: a0..a3 and b0..b1.
        let mut all = Vec::new();
        for (id, spec) in
            [("a0", "A"), ("b0", "B"), ("a1", "A"), ("a2", "A"), ("b1", "B"), ("a3", "A")]
        {
            all.push(record(id, spec));
        }
        registry.append(&all).unwrap();

        let stats = registry.compact(2).unwrap();
        assert_eq!(stats, CompactStats { kept: 4, total: 6, specs: 2 });

        // Survivors are the newest 2 per spec, in original file order,
        // and both encodings still load and agree bitwise.
        let jsonl = Registry::load(&dir).unwrap();
        let ids: Vec<&str> = jsonl.iter().map(|r| r.run_id.as_str()).collect();
        assert_eq!(ids, ["b0", "a2", "b1", "a3"]);
        let csv = Registry::load_csv(&dir).unwrap();
        assert_eq!(jsonl, csv);

        // Appending after a compact must not re-emit headers.
        registry.append(&[record("a4", "A")]).unwrap();
        let after = Registry::load(&dir).unwrap();
        assert_eq!(after.len(), 5);
        assert_eq!(after.last().unwrap().run_id, "a4");

        // compact(1) keeps exactly one (the newest) per spec.
        let stats = registry.compact(1).unwrap();
        assert_eq!(stats, CompactStats { kept: 2, total: 5, specs: 2 });
        let ids: Vec<String> =
            Registry::load(&dir).unwrap().into_iter().map(|r| r.run_id).collect();
        assert_eq!(ids, ["b1", "a4"]);

        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn csv_parse_last_row_without_newline() {
        let rows = csv_parse("a,b\nc,d").unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1], vec!["c".to_string(), "d".to_string()]);
    }
}

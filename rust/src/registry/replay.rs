//! Registry replay: re-execute a recorded run's spec and diff the fresh
//! metrics against the record bit-for-bit.
//!
//! Every `registry/v1` record carries the canonical [`JobSpec`] TOML it
//! executed, and step-bounded workloads are bitwise deterministic — so a
//! record is a *replayable* experiment, not just bookkeeping
//! (`rust/tests/registry.rs` pins that contract). `ettrain registry
//! replay <run_id>` turns the contract into a tool: parse the recorded
//! TOML, run the job on a fresh [`Session`], and report every metric
//! that diverged as a typed [`Divergence`].
//!
//! Wall-clock-derived metrics (`steps_per_sec`, `tokens_per_sec`, trace
//! coverage) legitimately differ between executions of the same spec,
//! so they are excluded from the diff and listed as skipped instead.
//!
//! [`JobSpec`]: crate::session::JobSpec

use crate::registry::{Registry, RunRecord};
use crate::session::{batch_from_config, run_job, EventSink, Session};
use crate::util::config::Config;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::fmt;
use std::path::Path;

/// Metric keys excluded from the bitwise diff because they derive from
/// wall-clock time rather than the deterministic arithmetic.
pub const TIME_DERIVED: [&str; 3] = ["steps_per_sec", "tokens_per_sec", "coverage_pct"];

/// One way a replayed run diverged from its record.
#[derive(Clone, Debug, PartialEq)]
pub enum Divergence {
    /// The record has this metric; the replay did not produce it.
    Missing { key: String },
    /// The replay produced a metric the record lacks.
    Extra { key: String },
    /// Same key, different value (bitwise compare for numbers).
    Value { key: String, recorded: String, replayed: String },
    /// The replayed job failed outright.
    Failed { error: String },
}

impl fmt::Display for Divergence {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Divergence::Missing { key } => {
                write!(f, "metric '{key}': recorded but absent from the replay")
            }
            Divergence::Extra { key } => {
                write!(f, "metric '{key}': produced by the replay but not recorded")
            }
            Divergence::Value { key, recorded, replayed } => {
                write!(f, "metric '{key}': recorded {recorded}, replayed {replayed}")
            }
            Divergence::Failed { error } => write!(f, "replayed job failed: {error}"),
        }
    }
}

/// Outcome of one replay: the fresh metrics next to the recorded ones,
/// plus every divergence. Empty `divergences` = bitwise reproduction.
#[derive(Clone, Debug)]
pub struct ReplayReport {
    pub run_id: String,
    pub job: String,
    /// Metrics object from the registry record.
    pub recorded: Json,
    /// Metrics object the re-execution produced (empty if it failed).
    pub replayed: Json,
    pub divergences: Vec<Divergence>,
    /// Time-derived keys present on either side but excluded from the
    /// diff.
    pub skipped: Vec<String>,
}

impl ReplayReport {
    /// Did the replay reproduce the record bit-for-bit (modulo the
    /// time-derived skip list)?
    pub fn reproduced(&self) -> bool {
        self.divergences.is_empty()
    }
}

/// Replay `run_id` out of the registry at `dir`.
pub fn replay(dir: &Path, run_id: &str) -> Result<ReplayReport> {
    let records = Registry::load(dir)?;
    let rec = records
        .iter()
        .find(|r| r.run_id == run_id)
        .with_context(|| format!("run '{run_id}' not found in registry {dir:?}"))?;
    replay_record(rec)
}

/// Replay one loaded record.
pub fn replay_record(rec: &RunRecord) -> Result<ReplayReport> {
    if rec.status != "ok" {
        bail!(
            "run '{}' recorded status '{}' — only successful runs replay",
            rec.run_id,
            rec.status
        );
    }
    let cfg = Config::parse(&rec.spec_toml)
        .with_context(|| format!("run '{}': recorded spec TOML does not parse", rec.run_id))?;
    let specs = batch_from_config(&cfg)
        .with_context(|| format!("run '{}': recorded spec TOML is not a job batch", rec.run_id))?;
    let spec = specs
        .iter()
        .find(|s| s.name == rec.job)
        .or_else(|| specs.first())
        .with_context(|| format!("run '{}': recorded spec TOML holds no jobs", rec.run_id))?;

    let sink = EventSink::discard(&spec.name);
    let (replayed, mut divergences) = match run_job(spec, &Session::new(), &sink) {
        Ok(out) => (out.metrics_json(), Vec::new()),
        Err(e) => {
            (Json::obj(vec![]), vec![Divergence::Failed { error: format!("{e:#}") }])
        }
    };
    let mut skipped = Vec::new();
    if divergences.is_empty() {
        divergences = diff_metrics(&rec.metrics, &replayed, &mut skipped);
    }
    Ok(ReplayReport {
        run_id: rec.run_id.clone(),
        job: rec.job.clone(),
        recorded: rec.metrics.clone(),
        replayed,
        divergences,
        skipped,
    })
}

/// Render a value for the divergence report: shortest-round-trip for
/// numbers (so the printed value is itself bit-exact), JSON otherwise.
fn show(v: &Json) -> String {
    match v.as_f64() {
        Some(n) => format!("{n}"),
        None => v.to_string(),
    }
}

/// Key-by-key bitwise diff of two metrics objects, excluding the
/// [`TIME_DERIVED`] keys (collected into `skipped` instead).
fn diff_metrics(recorded: &Json, replayed: &Json, skipped: &mut Vec<String>) -> Vec<Divergence> {
    let mut out = Vec::new();
    let rec = recorded.as_obj().cloned().unwrap_or_default();
    let rep = replayed.as_obj().cloned().unwrap_or_default();
    let time_derived = |k: &str| TIME_DERIVED.contains(&k);
    for (k, rv) in &rec {
        if time_derived(k) {
            skipped.push(k.clone());
            continue;
        }
        match rep.get(k) {
            None => out.push(Divergence::Missing { key: k.clone() }),
            Some(pv) => {
                let same = match (rv.as_f64(), pv.as_f64()) {
                    (Some(a), Some(b)) => a.to_bits() == b.to_bits(),
                    _ => rv == pv,
                };
                if !same {
                    out.push(Divergence::Value {
                        key: k.clone(),
                        recorded: show(rv),
                        replayed: show(pv),
                    });
                }
            }
        }
    }
    for k in rep.keys() {
        if rec.contains_key(k) {
            continue;
        }
        if time_derived(k) {
            skipped.push(k.clone());
        } else {
            out.push(Divergence::Extra { key: k.clone() });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::obj(pairs)
    }

    #[test]
    fn diff_is_bitwise_and_skips_time_derived() {
        let rec = obj(vec![
            ("final_loss", Json::num(0.1 + 0.2)),
            ("accuracy", Json::num(0.75)),
            ("steps_per_sec", Json::num(123.4)),
            ("optimizer", Json::str("adagrad")),
        ]);
        let same = diff_metrics(&rec, &rec, &mut Vec::new());
        assert!(same.is_empty());

        let mut skipped = Vec::new();
        let rep = obj(vec![
            ("final_loss", Json::num(0.3)), // != 0.1+0.2 bitwise
            ("accuracy", Json::num(0.75)),
            ("steps_per_sec", Json::num(999.0)), // skipped
            ("optimizer", Json::str("adagrad")),
            ("tokens_per_sec", Json::num(1.0)), // skipped even when extra
            ("new_metric", Json::num(1.0)),
        ]);
        let d = diff_metrics(&rec, &rep, &mut skipped);
        assert_eq!(d.len(), 2, "{d:?}");
        assert!(matches!(&d[0], Divergence::Value { key, .. } if key == "final_loss"));
        assert!(matches!(&d[1], Divergence::Extra { key } if key == "new_metric"));
        assert!(skipped.contains(&"steps_per_sec".to_string()));
        assert!(skipped.contains(&"tokens_per_sec".to_string()));
    }

    #[test]
    fn missing_metrics_are_reported() {
        let rec = obj(vec![("final_loss", Json::num(1.0))]);
        let rep = obj(vec![]);
        let d = diff_metrics(&rec, &rep, &mut Vec::new());
        assert_eq!(d, vec![Divergence::Missing { key: "final_loss".to_string() }]);
    }
}
